#!/usr/bin/env bash
# CI entry point: tier-1 verification plus compile-and-run smoke coverage
# of the experiment/bench path, so a PR cannot silently break the binaries
# that only `cargo run`/`cargo bench` exercise.
#
# Usage: ./ci.sh [--quick]
#   --quick   skip the smoke runs (tier-1 only)

set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

echo "== lint: unsafe/provenance/facade/hot-path audit =="
# Self-test first: seeds one violation of each rule class (U unsafe
# hygiene, P pointer provenance, F sync-facade bypass, A hot-path
# allocation) in a temp tree and asserts each is caught with a non-zero
# exit while the clean/waived twins stay silent. Then the real tree
# must come back clean.
cargo run -q --bin lint -- --self-test
cargo run -q --bin lint

if [[ "${1:-}" == "--quick" ]]; then
    echo "== chaos (quick): fault-injection smoke subset (--cfg ggfault) =="
    # The smoke_ tests only: mid-chunk worker panic → typed error, byte-
    # identical rollback, self-healing respawn, store keeps serving —
    # plus the supervisor failover (worker loop death → respawn + exactly-
    # once replay, session never sees Closed), the straggler steal-around
    # (25ms Delay stall on one worker → siblings steal its chunks,
    # steal ledger grows), and the composed-fault smokes (panic during
    # heal, fault during degraded inline drain).
    RUSTFLAGS='--cfg ggfault' cargo test -q --test chaos smoke_
    echo "ci.sh --quick: tier-1 + lint + chaos smoke green, skipping full runs"
    exit 0
fi

echo "== model check: exhaustive bounded interleavings (--cfg ggcheck) =="
# Swaps the crate::sync facade onto the instrumented model primitives
# and exhaustively enumerates every bounded schedule of the
# work-stealing scheduler's park/unpark/steal/termination protocol
# (no lost wakeups on the shared monitor, termination only when the
# bucket is drained AND every worker is parked, steal order never
# reordering per-slot commits, shutdown racing first park), the
# admission shed/rollback path, the AtBarrier drain order, and the
# service supervisor's detect→respawn→replay handshake (every request
# acked exactly once across a loop death, no matter how the clients'
# sends interleave with the failover); failures print a replayable
# schedule seed. The distinct RUSTFLAGS fingerprint makes this a
# one-off rebuild.
RUSTFLAGS='--cfg ggcheck' cargo test -q --test model_check

echo "== chaos: deterministic fault injection, full site matrix (--cfg ggfault) =="
# Activates the registered fault sites (zero-cost no-ops in every other
# build) and runs the chaos suite: every site in faults::SITES ×
# first/second crossing × 1/4 shards × serial/scheduled execution,
# checked against a fault-free oracle — typed errors only, byte-
# identical ledger rollback, self-healing worker respawns, degraded
# groups still byte-identical, supervised service failover (restart +
# exactly-once replay, never ServiceDown for live sessions), Delay
# stalls surfacing in the p99/max latency ledger while stragglers are
# stolen around, and composed multi-step plans (FaultPlan::then) —
# panic-during-heal, fault-during-degraded-drain, double failover.
# See EXPERIMENTS.md §Robustness for the contract. The distinct
# RUSTFLAGS fingerprint makes this a one-off rebuild.
RUSTFLAGS='--cfg ggfault' cargo test -q --test chaos

echo "== clippy: -D warnings (curated allows) =="
# Style-only lints that the codebase deliberately trips are allowed;
# everything else is denied. Skipped gracefully where the component is
# not installed (offline minimal toolchains).
if cargo clippy -V >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings \
        -A clippy::too_many_arguments \
        -A clippy::new_without_default \
        -A clippy::needless_range_loop \
        -A clippy::type_complexity \
        -A clippy::module_inception
else
    echo "cargo clippy not installed; skipping"
fi

SMOKE_OUT="$(mktemp -d)"
trap 'rm -rf "$SMOKE_OUT"' EXIT

echo "== smoke: experiment binary (fig3, small sweep) =="
cargo run --release --bin repro -- fig3 --steps 4 --draws 200 --quiet --out "$SMOKE_OUT"

echo "== smoke: frontend backpressure (typed-rejection contract) =="
# frontend_backpressure fills a bounded session window and asserts the
# admission contract: full channel → typed Rejected with the payload
# handed back (no panic, no silent drop), the queue drains at the next
# sync point, subsequent requests succeed, and the shed_requests ledger
# in Stats matches exactly the rejections the clients observed.
cargo test -q --test frontend_backpressure

echo "== smoke: sharded two-phase example, serial executors (GG_THREADS=1) =="
# The example also asserts serial ≡ pooled checksums internally AND that
# two concurrent client sessions (AtBarrier merge) seal byte-identical
# epochs to the single-client run; each run covers both executor modes'
# layouts, and running it under both GG_THREADS settings additionally
# smoke-tests the env-var resolution path.
GG_THREADS=1 cargo run --release --example sharded_two_phase

echo "== smoke: sharded two-phase example, default scheduler =="
cargo run --release --example sharded_two_phase

echo "== smoke: tight-heap churn (compaction OOM/abort path end-to-end) =="
# tight_budget_churn asserts the epoch-owned VRAM transaction: seals
# under a budget too small for compaction's transient 2× must surface
# compaction OOMs (Response::Sealed + metrics), retain every segment
# byte-identically, conserve heap accounting, and recover after Clear.
cargo run --release --example tight_budget_churn

echo "== smoke: shard bench (parallel time model gate) =="
# bench_shards asserts the parallel-time-model acceptance criteria and
# exits non-zero when they fail:
#   * insert-heavy: 4-shard critical-path sim time < 1-shard,
#   * device totals exceed the critical path on multi-shard runs,
#   * sealed work cheaper than unsealed at 1 and 4 shards.
cargo bench --bench bench_shards

echo "== smoke: hot-path bench (BENCH_hotpath.json + wall-clock gates) =="
# bench_hotpath --smoke: short steady-state runs of insert dispatch
# (serial and through the work-stealing scheduler, including the
# skewed-routing case with one 3/4-hot shard) / scheduled seal / sealed
# query at 1 and 4 shards. Writes BENCH_hotpath.json (schema
# bench_hotpath/v3) at the repo root (the perf trajectory) and exits
# non-zero when:
#   * steady-state insert dispatch regresses >25% vs the committed
#     baseline (1-shard serial, 4-shard scheduled, skewed scheduled),
#   * the scheduled-seal median regresses >25% (4 shards),
#   * the measured 4-shard-scheduled vs 1-shard-serial insert-dispatch
#     wall-clock speedup for the large-batch steady-state run is ≤ 1.0
#     (needs no baseline),
#   * the skewed-routing speedup fails to beat the old fork/join pool's
#     max-shard bound of 4/3× (the work-stealing payoff gate — needs no
#     baseline, demoted to a notice below 4 cores),
#   * the skewed scheduled run records zero steals in the scheduler
#     ledger (the work-stealing path must actually engage — needs no
#     baseline or parallelism).
# Regression gates are skipped gracefully when no v3 baseline exists
# (first run / schema migration). Bypass everything with
# GG_BENCH_GATE=off on noisy machines.
cargo bench --bench bench_hotpath -- --smoke

echo "== smoke: frontend bench (BENCH_frontend.json, report-only) =="
# bench_frontend --smoke: sustained multi-client admission throughput
# and p50/p99 latency at 1/8/64 client threads through bounded sessions
# (eager merge). Writes BENCH_frontend.json (schema bench_frontend/v1)
# at the repo root. Report-only — no regression gate yet — but the run
# itself asserts conservation (sealed epoch == sum of accepted ledgers)
# and that the shed metric matches client-observed rejections, so a
# frontend correctness break fails CI here too. Smoke runs never
# overwrite an existing schema-matching baseline.
cargo bench --bench bench_frontend -- --smoke

echo "ci.sh: all green"
