#!/usr/bin/env bash
# CI entry point: tier-1 verification plus compile-and-run smoke coverage
# of the experiment/bench path, so a PR cannot silently break the binaries
# that only `cargo run`/`cargo bench` exercise.
#
# Usage: ./ci.sh [--quick]
#   --quick   skip the smoke runs (tier-1 only)

set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

if [[ "${1:-}" == "--quick" ]]; then
    echo "ci.sh --quick: tier-1 green, skipping smoke runs"
    exit 0
fi

SMOKE_OUT="$(mktemp -d)"
trap 'rm -rf "$SMOKE_OUT"' EXIT

echo "== smoke: experiment binary (fig3, small sweep) =="
cargo run --release --bin repro -- fig3 --steps 4 --draws 200 --quiet --out "$SMOKE_OUT"

echo "== smoke: sharded two-phase example (byte-identity + sealed payoff) =="
cargo run --release --example sharded_two_phase

echo "== smoke: shard bench (modeled sealed-vs-unsealed assertions) =="
cargo bench --bench bench_shards

echo "ci.sh: all green"
