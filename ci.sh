#!/usr/bin/env bash
# CI entry point: tier-1 verification plus compile-and-run smoke coverage
# of the experiment/bench path, so a PR cannot silently break the binaries
# that only `cargo run`/`cargo bench` exercise.
#
# Usage: ./ci.sh [--quick]
#   --quick   skip the smoke runs (tier-1 only)

set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

if [[ "${1:-}" == "--quick" ]]; then
    echo "ci.sh --quick: tier-1 green, skipping smoke runs"
    exit 0
fi

SMOKE_OUT="$(mktemp -d)"
trap 'rm -rf "$SMOKE_OUT"' EXIT

echo "== smoke: experiment binary (fig3, small sweep) =="
cargo run --release --bin repro -- fig3 --steps 4 --draws 200 --quiet --out "$SMOKE_OUT"

echo "== smoke: sharded two-phase example (byte-identity + sealed payoff) =="
cargo run --release --example sharded_two_phase

echo "== smoke: tight-heap churn (compaction OOM/abort path end-to-end) =="
# tight_budget_churn asserts the epoch-owned VRAM transaction: seals
# under a budget too small for compaction's transient 2× must surface
# compaction OOMs (Response::Sealed + metrics), retain every segment
# byte-identically, conserve heap accounting, and recover after Clear.
cargo run --release --example tight_budget_churn

echo "== smoke: shard bench (parallel time model gate) =="
# bench_shards asserts the parallel-time-model acceptance criteria and
# exits non-zero when they fail:
#   * insert-heavy: 4-shard critical-path sim time < 1-shard,
#   * device totals exceed the critical path on multi-shard runs,
#   * sealed work cheaper than unsealed at 1 and 4 shards.
cargo bench --bench bench_shards

echo "== smoke: hot-path bench (BENCH_hotpath.json + wall-clock gate) =="
# bench_hotpath --smoke: short steady-state runs of insert dispatch /
# pooled seal / sealed query at 1 and 4 shards. Writes BENCH_hotpath.json
# at the repo root (the perf trajectory) and exits non-zero when
# steady-state insert dispatch regresses >25% against the committed
# baseline; skipped gracefully when the baseline file is absent (first
# run). Bypass with GG_BENCH_GATE=off on noisy machines.
cargo bench --bench bench_hotpath -- --smoke

echo "ci.sh: all green"
