//! Mesh-refinement workload (the paper's motivating application class,
//! §VI.C: "computer geometry and triangular mesh refinement" — Hatipoglu
//! & Özturan-style longest-edge bisection).
//!
//! Each refinement sweep visits every triangle and, based on a local
//! error estimate, emits 1, 2 or 4 children — the output size is unknown
//! until the kernel runs. A static array must provision the 4× worst
//! case every sweep; GGArray grows to the actual size.
//!
//! ```sh
//! cargo run --release --example mesh_refinement
//! ```

use ggarray::ggarray::array::{GgArray, GgConfig};
use ggarray::insertion::InsertionKind;
use ggarray::sim::spec::DeviceSpec;
use ggarray::util::rng::Rng;
use ggarray::util::tables::fmt_bytes;

/// A triangle: packed vertex ids + a refinement level (toy encoding — the
/// point is the dynamic fan-out, not the geometry).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
struct Tri {
    id: u32,
    level: u8,
}

// GGArray stores Copy+Default values; pack Tri into u64.
fn pack(t: Tri) -> u64 {
    ((t.level as u64) << 32) | t.id as u64
}

fn unpack(x: u64) -> Tri {
    Tri { id: (x & 0xFFFF_FFFF) as u32, level: (x >> 32) as u8 }
}

/// Refinement rule: how many children a triangle emits this sweep.
/// Mimics an error estimator: refine probability decays with level.
fn fanout(t: Tri, rng: &mut Rng) -> usize {
    let p = 0.45 / (1.0 + t.level as f64);
    if rng.bernoulli(p) {
        if rng.bernoulli(0.5) {
            4 // full bisection of all three edges
        } else {
            2 // longest-edge bisection
        }
    } else {
        1 // unchanged
    }
}

fn main() {
    let spec = DeviceSpec::a100();
    let sweeps = 6;
    let initial = 20_000u32;
    let mut rng = Rng::new(2026);

    // Current generation lives in one GGArray; each sweep pushes the next
    // generation into a fresh one (classic double-buffered refinement).
    let cfg = GgConfig::new(64).with_first_bucket(256);
    let mut cur: GgArray<u64> = GgArray::new(cfg.clone(), spec.clone());
    cur.insert_bulk(
        &(0..initial).map(|id| pack(Tri { id, level: 0 })).collect::<Vec<_>>(),
        InsertionKind::WarpScan,
    )
    .unwrap();

    println!("== mesh refinement: {sweeps} sweeps from {initial} triangles ==");
    println!(
        "{:<6} {:>10} {:>10} {:>12} {:>12} {:>12} {:>9}",
        "sweep", "tris", "children", "gg_alloc", "static_worst", "saving", "sim_ms"
    );

    let mut worst_case_static = initial as u64; // static must hold 4^k growth
    let mut total_sim_ms = 0.0;
    for sweep in 1..=sweeps {
        let tris = cur.to_vec();
        // The "kernel": every thread (triangle) computes its fan-out and
        // pushes children — slot assignment is the scan-based insertion.
        let mut children: Vec<u64> = Vec::new();
        for &t in &tris {
            let tri = unpack(t);
            for c in 0..fanout(tri, &mut rng) {
                children.push(pack(Tri { id: tri.id.wrapping_mul(4).wrapping_add(c as u32), level: tri.level + 1 }));
            }
        }
        let mut next: GgArray<u64> = GgArray::new(cfg.clone(), spec.clone());
        let rep = next.insert_bulk(&children, InsertionKind::WarpScan).unwrap();
        let rw = next.read_write_block(30.0, |_| {}); // error-estimate pass
        total_sim_ms += rep.total_ms() + rw.total_ms();

        // Memory comparison: static array must be provisioned for 4× per
        // sweep (the worst case), compounding.
        worst_case_static *= 4;
        let gg_alloc = next.allocated_bytes();
        let static_alloc = worst_case_static * 8;
        println!(
            "{:<6} {:>10} {:>10} {:>12} {:>12} {:>11.1}x {:>9.3}",
            sweep,
            tris.len(),
            children.len(),
            fmt_bytes(gg_alloc),
            fmt_bytes(static_alloc),
            static_alloc as f64 / gg_alloc as f64,
            rep.total_ms() + rw.total_ms(),
        );
        // Sanity: the structure really holds the children.
        assert_eq!(next.len(), children.len());
        assert!(next.overhead_ratio() < 2.5, "overhead {:.2}", next.overhead_ratio());
        cur = next;
    }
    println!("total simulated GPU time: {total_sim_ms:.2} ms");
    println!(
        "final mesh: {} triangles; GGArray stayed ≤2.5x live data while static worst-case \
         provisioning compounds 4x per sweep",
        cur.len()
    );
    println!("mesh_refinement OK");
}
