//! Quickstart: create a GGArray, grow+insert from a (simulated) kernel,
//! read back, inspect memory overhead and simulated timings.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use ggarray::prelude::*;

fn main() {
    // A GGArray with 32 LFVectors on the A100 device model.
    let spec = DeviceSpec::a100();
    let mut gg: GgArray<u32> = GgArray::new(GgConfig::new(32), spec);

    // Phase 1: in-kernel insertion of 100k elements (warp-scan algorithm
    // assigns each "thread" a unique slot).
    let values: Vec<u32> = (0..100_000).collect();
    let ins = gg.grow_and_insert(&values, InsertionKind::WarpScan);
    println!(
        "insert: {} elements, {} buckets allocated, {:.3} ms simulated",
        ins.elements,
        ins.buckets_allocated,
        ins.total_ms()
    );

    // Phase 2: the paper's work op (+1, 30 times) via block-structured
    // access (rw_b).
    let rw = gg.read_write_block(30.0, |x| *x += 30);
    println!("rw_b:   {} elements, {:.3} ms simulated", rw.elements, rw.total_ms());

    // Reads through the global prefix index (global order is block-major).
    assert_eq!(gg.get(0), Some(30));
    assert!(gg.get(99_999).is_some());
    assert_eq!(gg.get(100_000), None);
    println!(
        "len {}  capacity {}  allocated {}  overhead {:.2}x (paper bound: 2x)",
        gg.len(),
        gg.capacity(),
        ggarray::util::tables::fmt_bytes(gg.allocated_bytes()),
        gg.overhead_ratio()
    );

    // The ledger shows where simulated time went.
    for (cat, us) in gg.clock().snapshot() {
        println!("  {:<8} {:>10.1} µs", cat.name(), us);
    }
    println!("quickstart OK");
}
