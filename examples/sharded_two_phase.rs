//! Sharded two-phase lifecycle end-to-end (paper §VI.D at service
//! scale): grow each epoch across N independent GGArray shards, **seal**
//! it — drain batches, flatten every shard, concatenate into the flat
//! fast-access view — and run the work phase at static-array cost while
//! the next insert epoch opens behind it.
//!
//! Demonstrates the headline properties of the sharded design:
//!
//! 1. **Layout invariance** — global routing + per-shard slicing makes
//!    the sealed bytes identical for any shard count (1 vs 4 here);
//! 2. **Two-phase payoff** — work over sealed (flat) epochs simulates
//!    markedly cheaper than the same work over unsealed GGArray data;
//! 3. **Executor-mode invariance** — the persistent shard-executor pool
//!    (really-parallel per-shard execution) is byte-identical to the
//!    serial worker, while the *measured* wall ledger shows where the
//!    host time went.
//!
//! ```sh
//! cargo run --release --example sharded_two_phase            # default pool
//! GG_THREADS=1 cargo run --release --example sharded_two_phase  # serial
//! ```

use std::time::Duration;

use ggarray::coordinator::batcher::BatchConfig;
use ggarray::coordinator::metrics::MetricsSnapshot;
use ggarray::coordinator::request::{Request, Response};
use ggarray::coordinator::service::{drive_workload, Coordinator, CoordinatorConfig, WorkloadRun};
use ggarray::workload::WorkloadSpec;

const FINAL_SIZE: u64 = 1 << 18; // 262144 elements after 3 doubling phases
const PHASES: u32 = 3;
const WORK_CALLS: u32 = 2;
const CHUNK: usize = 4096;
const TOTAL_BLOCKS: usize = 32;

fn config(shards: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        blocks: TOTAL_BLOCKS,
        shards,
        first_bucket_size: 64,
        use_artifacts: false,
        // max_values == CHUNK makes every insert request flush by size:
        // batch boundaries (and so routing) are identical across runs.
        batch: BatchConfig { max_values: CHUNK, max_delay: Duration::from_secs(3600) },
        ..CoordinatorConfig::default()
    }
}

/// Run a workload and capture (run summary, final flatten checksum,
/// final metrics snapshot). `executor_threads` 0 = config default
/// (GG_THREADS env / auto), 1 = serial worker, ≥2 = persistent pool.
fn run_with(w: &WorkloadSpec, shards: usize, executor_threads: usize) -> (WorkloadRun, u64, MetricsSnapshot) {
    let c = Coordinator::start(CoordinatorConfig { executor_threads, ..config(shards) });
    let run = drive_workload(&c, w, CHUNK);
    let final_checksum = match c.call(Request::Flatten) {
        Response::Flattened { checksum, len, .. } => {
            assert_eq!(len, w.expected_final, "final length mismatch");
            checksum
        }
        other => panic!("flatten failed: {other:?}"),
    };
    let stats = c.call(Request::Stats).expect_stats();
    c.shutdown();
    (run, final_checksum, stats)
}

/// Run under the config default executor mode (GG_THREADS env / auto).
fn run(w: &WorkloadSpec, shards: usize) -> (WorkloadRun, u64, MetricsSnapshot) {
    run_with(w, shards, 0)
}

fn main() {
    let sealed_wl = WorkloadSpec::two_phase_sharded(FINAL_SIZE, 1, WORK_CALLS, PHASES);
    let unsealed_wl = WorkloadSpec::two_phase(FINAL_SIZE, 1, WORK_CALLS, PHASES);
    println!("== sharded two-phase driver: {} ==", sealed_wl.name);
    println!("final size {} over {PHASES} phases, {TOTAL_BLOCKS} total blocks\n", sealed_wl.expected_final);

    // --- layout invariance: 1 shard vs 4 shards, byte-identical ---
    let (run1, final1, stats1) = run(&sealed_wl, 1);
    let (run4, final4, stats4) = run(&sealed_wl, 4);
    assert_eq!(
        run1.seal_checksums, run4.seal_checksums,
        "per-epoch sealed contents must be byte-identical across shard counts"
    );
    assert_eq!(final1, final4, "final flattened contents must be byte-identical");
    println!("layout invariance: 1-shard and 4-shard sealed epochs byte-identical ✓");
    for (i, sum) in run4.seal_checksums.iter().enumerate() {
        println!("  epoch {} checksum {sum:#018x}", i + 1);
    }

    // --- two-phase payoff: sealed work ≪ unsealed work ---
    let (run4_unsealed, _, _) = run(&unsealed_wl, 4);
    let sealed_ms = run4.work_sim_us / 1e3;
    let unsealed_ms = run4_unsealed.work_sim_us / 1e3;
    assert!(
        sealed_ms < unsealed_ms,
        "sealed work {sealed_ms} ms must beat unsealed {unsealed_ms} ms"
    );
    println!("\ntwo-phase payoff (4 shards, simulated work time across all phases):");
    println!("  unsealed (GGArray rw_b): {unsealed_ms:>9.3} ms");
    println!("  sealed   (flat path):    {sealed_ms:>9.3} ms   ({:.1}× faster)", unsealed_ms / sealed_ms);
    println!("  seal cost (flatten):     {:>9.3} ms", run4.seal_sim_us / 1e3);

    // --- parallel time model: shard speedup visible in sim time ---
    assert!(
        stats4.sim_insert_ms < stats1.sim_insert_ms,
        "4-shard insert critical path {} ms must beat 1-shard {} ms",
        stats4.sim_insert_ms,
        stats1.sim_insert_ms
    );
    println!("\nparallel time model (insert phases, simulated):");
    println!("  1 shard  critical path:  {:>9.3} ms", stats1.sim_insert_ms);
    println!(
        "  4 shards critical path:  {:>9.3} ms   ({:.1}× speedup, {:.3} ms device total)",
        stats4.sim_insert_ms,
        stats1.sim_insert_ms / stats4.sim_insert_ms,
        stats4.device_insert_ms
    );

    // --- executor-mode invariance: serial worker ≡ persistent pool ---
    // The same 4-shard workload through executor_threads = 1 (serial)
    // and = 2 (one executor thread per shard) must be byte-identical —
    // including the simulated ledger. What differs is the *measured*
    // wall ledger: the pool's fan-out tracks the sim critical path, the
    // serial loop tracks the device sum.
    let (run_serial, final_serial, stats_serial) = run_with(&sealed_wl, 4, 1);
    let (run_pooled, final_pooled, stats_pooled) = run_with(&sealed_wl, 4, 2);
    assert_eq!(
        run_serial.seal_checksums, run_pooled.seal_checksums,
        "serial and pooled executors must seal byte-identical epochs"
    );
    assert_eq!(final_serial, final_pooled, "final flatten must be byte-identical across modes");
    assert_eq!(
        run_serial.seal_sim_us, run_pooled.seal_sim_us,
        "the simulated ledger must not depend on the executor mode"
    );
    println!("\nexecutor modes (4 shards): serial ≡ pooled sealed bytes ✓");
    println!(
        "  serial  (1 thread):   insert wall {:>8.3} ms, seal wall {:>8.3} ms",
        stats_serial.wall_insert_ms, stats_serial.wall_flatten_ms
    );
    println!(
        "  pooled  ({} threads):  insert wall {:>8.3} ms, seal wall {:>8.3} ms",
        stats_pooled.executors, stats_pooled.wall_insert_ms, stats_pooled.wall_flatten_ms
    );

    println!("\n--- 4-shard coordinator metrics (default executor mode) ---\n{stats4}");
    println!("\nsharded_two_phase OK");
}
