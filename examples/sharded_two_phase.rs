//! Sharded two-phase lifecycle end-to-end (paper §VI.D at service
//! scale): grow each epoch across N independent GGArray shards, **seal**
//! it — drain batches, flatten every shard, concatenate into the flat
//! fast-access view — and run the work phase at static-array cost while
//! the next insert epoch opens behind it.
//!
//! Demonstrates the headline properties of the sharded design:
//!
//! 1. **Layout invariance** — global routing + per-shard slicing makes
//!    the sealed bytes identical for any shard count (1 vs 4 here);
//! 2. **Two-phase payoff** — work over sealed (flat) epochs simulates
//!    markedly cheaper than the same work over unsealed GGArray data;
//! 3. **Executor-mode invariance** — the persistent shard-executor pool
//!    (really-parallel per-shard execution) is byte-identical to the
//!    serial worker, while the *measured* wall ledger shows where the
//!    host time went.
//!
//! ```sh
//! cargo run --release --example sharded_two_phase            # default pool
//! GG_THREADS=1 cargo run --release --example sharded_two_phase  # serial
//! ```

use std::time::Duration;

use ggarray::coordinator::batcher::BatchConfig;
use ggarray::coordinator::frontend::{ClientSession, FrontendConfig, MergePolicy};
use ggarray::coordinator::metrics::MetricsSnapshot;
use ggarray::coordinator::request::{Request, Response};
use ggarray::coordinator::service::{drive_workload, Coordinator, CoordinatorConfig, WorkloadRun};
use ggarray::workload::{synth_f32, Step, WorkloadSpec};

const FINAL_SIZE: u64 = 1 << 18; // 262144 elements after 3 doubling phases
const PHASES: u32 = 3;
const WORK_CALLS: u32 = 2;
const CHUNK: usize = 4096;
const TOTAL_BLOCKS: usize = 32;

fn config(shards: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        blocks: TOTAL_BLOCKS,
        shards,
        first_bucket_size: 64,
        use_artifacts: false,
        // max_values == CHUNK makes every insert request flush by size:
        // batch boundaries (and so routing) are identical across runs.
        batch: BatchConfig { max_values: CHUNK, max_delay: Duration::from_secs(3600) },
        ..CoordinatorConfig::default()
    }
}

/// Run a workload and capture (run summary, final flatten checksum,
/// final metrics snapshot). `executor_threads` 0 = config default
/// (GG_THREADS env / auto), 1 = serial worker, ≥2 = persistent pool.
fn run_with(w: &WorkloadSpec, shards: usize, executor_threads: usize) -> (WorkloadRun, u64, MetricsSnapshot) {
    let c = Coordinator::start(CoordinatorConfig { executor_threads, ..config(shards) });
    let run = drive_workload(&c, w, CHUNK);
    let final_checksum = match c.call(Request::Flatten) {
        Response::Flattened { checksum, len, .. } => {
            assert_eq!(len, w.expected_final, "final length mismatch");
            checksum
        }
        other => panic!("flatten failed: {other:?}"),
    };
    let stats = c.call(Request::Stats).expect_stats();
    c.shutdown();
    (run, final_checksum, stats)
}

/// Run under the config default executor mode (GG_THREADS env / auto).
fn run(w: &WorkloadSpec, shards: usize) -> (WorkloadRun, u64, MetricsSnapshot) {
    run_with(w, shards, 0)
}

fn main() {
    let sealed_wl = WorkloadSpec::two_phase_sharded(FINAL_SIZE, 1, WORK_CALLS, PHASES);
    let unsealed_wl = WorkloadSpec::two_phase(FINAL_SIZE, 1, WORK_CALLS, PHASES);
    println!("== sharded two-phase driver: {} ==", sealed_wl.name);
    println!("final size {} over {PHASES} phases, {TOTAL_BLOCKS} total blocks\n", sealed_wl.expected_final);

    // --- layout invariance: 1 shard vs 4 shards, byte-identical ---
    let (run1, final1, stats1) = run(&sealed_wl, 1);
    let (run4, final4, stats4) = run(&sealed_wl, 4);
    assert_eq!(
        run1.seal_checksums, run4.seal_checksums,
        "per-epoch sealed contents must be byte-identical across shard counts"
    );
    assert_eq!(final1, final4, "final flattened contents must be byte-identical");
    println!("layout invariance: 1-shard and 4-shard sealed epochs byte-identical ✓");
    for (i, sum) in run4.seal_checksums.iter().enumerate() {
        println!("  epoch {} checksum {sum:#018x}", i + 1);
    }

    // --- two-phase payoff: sealed work ≪ unsealed work ---
    let (run4_unsealed, _, _) = run(&unsealed_wl, 4);
    let sealed_ms = run4.work_sim_us / 1e3;
    let unsealed_ms = run4_unsealed.work_sim_us / 1e3;
    assert!(
        sealed_ms < unsealed_ms,
        "sealed work {sealed_ms} ms must beat unsealed {unsealed_ms} ms"
    );
    println!("\ntwo-phase payoff (4 shards, simulated work time across all phases):");
    println!("  unsealed (GGArray rw_b): {unsealed_ms:>9.3} ms");
    println!("  sealed   (flat path):    {sealed_ms:>9.3} ms   ({:.1}× faster)", unsealed_ms / sealed_ms);
    println!("  seal cost (flatten):     {:>9.3} ms", run4.seal_sim_us / 1e3);

    // --- parallel time model: shard speedup visible in sim time ---
    assert!(
        stats4.sim_insert_ms < stats1.sim_insert_ms,
        "4-shard insert critical path {} ms must beat 1-shard {} ms",
        stats4.sim_insert_ms,
        stats1.sim_insert_ms
    );
    println!("\nparallel time model (insert phases, simulated):");
    println!("  1 shard  critical path:  {:>9.3} ms", stats1.sim_insert_ms);
    println!(
        "  4 shards critical path:  {:>9.3} ms   ({:.1}× speedup, {:.3} ms device total)",
        stats4.sim_insert_ms,
        stats1.sim_insert_ms / stats4.sim_insert_ms,
        stats4.device_insert_ms
    );

    // --- executor-mode invariance: serial worker ≡ persistent pool ---
    // The same 4-shard workload through executor_threads = 1 (serial)
    // and = 2 (one executor thread per shard) must be byte-identical —
    // including the simulated ledger. What differs is the *measured*
    // wall ledger: the pool's fan-out tracks the sim critical path, the
    // serial loop tracks the device sum.
    let (run_serial, final_serial, stats_serial) = run_with(&sealed_wl, 4, 1);
    let (run_pooled, final_pooled, stats_pooled) = run_with(&sealed_wl, 4, 2);
    assert_eq!(
        run_serial.seal_checksums, run_pooled.seal_checksums,
        "serial and pooled executors must seal byte-identical epochs"
    );
    assert_eq!(final_serial, final_pooled, "final flatten must be byte-identical across modes");
    assert_eq!(
        run_serial.seal_sim_us, run_pooled.seal_sim_us,
        "the simulated ledger must not depend on the executor mode"
    );
    println!("\nexecutor modes (4 shards): serial ≡ pooled sealed bytes ✓");
    println!(
        "  serial  (1 thread):   insert wall {:>8.3} ms, seal wall {:>8.3} ms",
        stats_serial.wall_insert_ms, stats_serial.wall_flatten_ms
    );
    println!(
        "  pooled  ({} threads):  insert wall {:>8.3} ms, seal wall {:>8.3} ms",
        stats_pooled.executors, stats_pooled.wall_insert_ms, stats_pooled.wall_flatten_ms
    );

    // --- multi-client session frontend: 2 concurrent clients ≡ 1 ---
    // The same sealed workload pushed by two racing client threads
    // through bounded sessions (AtBarrier merge: pools drain only at
    // sync points, in client-id order) must seal the exact same epochs
    // as the single-client runs above. Client 0 takes the low half of
    // every insert step and client 1 the high half, so the merged value
    // stream equals the serial one.
    let session_seals = run_sessions(&sealed_wl, 4);
    assert_eq!(
        session_seals, run4.seal_checksums,
        "2 concurrent sessions must seal byte-identical epochs to 1 client"
    );
    println!("\nsession frontend (4 shards): 2 concurrent clients ≡ 1 client sealed bytes ✓");

    println!("\n--- 4-shard coordinator metrics (default executor mode) ---\n{stats4}");
    println!("\nsharded_two_phase OK");
}

/// Push `[from, to)` of the global value stream through one session in
/// CHUNK-sized requests, retrying on typed rejections. Returns the shed
/// count the client observed.
fn push_range(sess: &mut ClientSession, from: u64, to: u64) -> u64 {
    let mut sheds = 0u64;
    let mut at = from;
    while at < to {
        let take = CHUNK.min((to - at) as usize);
        let values: Vec<f32> = (0..take as u64).map(|i| synth_f32(at + i)).collect();
        // Live worker draining at sync points: a generous bound — hitting
        // it would be a livelock, not overload.
        let (adm, retries) = sess.insert_retrying(values, 10_000);
        assert!(adm.is_accepted(), "insert [{at}..{}) not admitted: {adm:?}", at + take as u64);
        sheds += retries;
        at += take as u64;
    }
    sheds
}

/// Drive the workload through TWO concurrent client sessions (each
/// insert step split in half across racing threads) and return the
/// per-epoch seal checksums.
fn run_sessions(w: &WorkloadSpec, shards: usize) -> Vec<u64> {
    let c = Coordinator::start(CoordinatorConfig {
        // AtBarrier pins the merge order (client-id ascending at each
        // sync point) so the sealed layout is timing-independent.
        frontend: FrontendConfig { merge: MergePolicy::AtBarrier, ..FrontendConfig::default() },
        ..config(shards)
    });
    let mut s0 = c.session();
    let mut s1 = c.session();
    let (mut counter, mut sheds, mut seals) = (0u64, 0u64, Vec::new());
    for step in &w.steps {
        match step {
            Step::Insert(n) => {
                let mid = counter + n / 2;
                let end = counter + n;
                let (a, b) = std::thread::scope(|scope| {
                    let h0 = scope.spawn(|| push_range(&mut s0, counter, mid));
                    let h1 = scope.spawn(|| push_range(&mut s1, mid, end));
                    (h0.join().expect("client 0 panicked"), h1.join().expect("client 1 panicked"))
                });
                sheds += a + b;
                counter = end;
                // Pin the merge order per insert *step*: Stats is a sync
                // point, so both pools drain here — client 0's half then
                // client 1's half — which makes the merged stream exactly
                // the serial [start, end) order even when two Insert
                // steps share one epoch (the workload's opening step).
                // Every request is a full CHUNK, so this adds no extra
                // batch-flush boundary either.
                c.call(Request::Stats);
            }
            Step::Work(calls) => match c.call(Request::Work { calls: *calls }) {
                Response::Worked { .. } => {}
                other => panic!("work failed: {other:?}"),
            },
            Step::Flatten => {
                c.call(Request::Flatten);
            }
            Step::Seal => match c.call(Request::Seal) {
                Response::Sealed { checksum, .. } => seals.push(checksum),
                other => panic!("seal failed: {other:?}"),
            },
        }
    }
    let snap = c.call(Request::Stats).expect_stats();
    assert_eq!(snap.len, counter, "every admitted value must land");
    assert_eq!(snap.sessions, 2);
    assert_eq!(snap.shed_requests, sheds, "shed ledger must match client-observed rejections");
    c.shutdown();
    seals
}
