//! Failure injection: seal→compact churn under a VRAM budget too tight
//! for compaction's transient 2× residency.
//!
//! The epoch store is a real heap now: committed seals *transfer* their
//! flatten destinations into it, and a compaction gather must reserve
//! the merged destination while every source segment is still resident.
//! This driver runs the same `seal_cycles` trace twice:
//!
//! * **tight** — the epoch heap admits every seal but can never hold the
//!   gather's 2× transient: every compaction attempt OOMs and aborts
//!   byte-identically (segments retained, error surfaced in
//!   `Response::Sealed::compaction_oom` and the `compaction_ooms`
//!   metric) while the service keeps sealing and serving;
//! * **generous** — the same trace with headroom: compaction commits,
//!   the segment count stays bounded, and the sealed bytes are
//!   *identical* to the tight run's.
//!
//! ```sh
//! cargo run --release --example tight_budget_churn
//! ```

use std::time::Duration;

use ggarray::coordinator::batcher::BatchConfig;
use ggarray::coordinator::request::{Request, Response};
use ggarray::coordinator::service::{drive_workload, Coordinator, CoordinatorConfig};
use ggarray::workload::WorkloadSpec;

const PER_EPOCH: u64 = 1_200; // elements per insert→seal cycle
const EPOCHS: u32 = 4;
const PER_EPOCH_BYTES: u64 = PER_EPOCH * 4;
const CHUNK: usize = 4096;

fn config(epoch_heap: Option<u64>) -> CoordinatorConfig {
    CoordinatorConfig {
        blocks: 16,
        shards: 4,
        first_bucket_size: 32,
        use_artifacts: false,
        compact_segments: 2,
        // Shard heaps get a comfortable 1 MiB on top of the epoch carve:
        // the injected failure must be the epoch store's, not an insert
        // OOM.
        heap_capacity: epoch_heap.map(|e| e + (1 << 20)),
        epoch_heap,
        batch: BatchConfig { max_values: CHUNK, max_delay: Duration::from_secs(3600) },
        ..CoordinatorConfig::default()
    }
}

fn main() {
    let w = WorkloadSpec::seal_cycles(PER_EPOCH, EPOCHS, 1);
    println!("== tight-budget churn driver: {} ==", w.name);

    // Tight: admits all 4 epochs (4 × 4800 B ≤ 24000 B) but the gather
    // at seal 3 already needs 3 × 4800 B on top of the resident 3 ×
    // 4800 B — every compaction attempt must abort.
    let tight_budget = 5 * PER_EPOCH_BYTES;
    let tight = Coordinator::start(config(Some(tight_budget)));
    let run_tight = drive_workload(&tight, &w, CHUNK);
    let snap_tight = tight.call(Request::Stats).expect_stats();

    // Generous: identical trace, default (half-device) epoch heap.
    let generous = Coordinator::start(config(None));
    let run_gen = drive_workload(&generous, &w, CHUNK);
    let snap_gen = generous.call(Request::Stats).expect_stats();

    // --- the OOMs happened, were surfaced, and tore nothing ---
    assert_eq!(
        run_tight.compaction_ooms, 2,
        "seals 3 and 4 must each trigger a doomed gather (got {})",
        run_tight.compaction_ooms
    );
    assert_eq!(snap_tight.compaction_ooms, 2, "metrics must agree with the responses");
    assert_eq!(snap_tight.compactions, 0);
    assert_eq!(snap_tight.sealed_segments, EPOCHS as usize, "aborts retain every segment");
    assert_eq!(snap_tight.sealed_len, PER_EPOCH * EPOCHS as u64);
    assert_eq!(snap_tight.sealed_bytes, PER_EPOCH_BYTES * EPOCHS as u64);
    assert_eq!(
        snap_tight.heap_used_bytes, snap_tight.allocated_bytes,
        "conservation: every heap byte accounted"
    );
    println!(
        "tight   ({} B epoch heap): {} seals, {} compaction OOMs, {} segments retained",
        tight_budget, snap_tight.seals, snap_tight.compaction_ooms, snap_tight.sealed_segments
    );

    // --- generous run compacted; bytes identical across both regimes ---
    assert_eq!(run_gen.compaction_ooms, 0);
    assert!(snap_gen.compactions >= 1, "threshold 2 over 4 seals must compact");
    assert!(snap_gen.sealed_segments <= 2);
    assert_eq!(
        run_tight.seal_checksums, run_gen.seal_checksums,
        "aborted compactions must never change sealed bytes"
    );
    println!(
        "generous (half-device):    {} seals, {} compactions, {} segments",
        snap_gen.seals, snap_gen.compactions, snap_gen.sealed_segments
    );
    println!("byte-identity across budget regimes ✓");

    // --- the tight store still serves reads and recovers on Clear ---
    assert!(tight.call(Request::Query { index: 0 }).expect_value().is_some());
    tight.call(Request::Clear);
    let cleared = tight.call(Request::Stats).expect_stats();
    assert_eq!(cleared.heap_used_bytes, 0, "Clear must return every byte");
    assert_eq!(cleared.sealed_bytes, 0);
    // Post-clear, the same budget seals and compacts a small epoch fine.
    tight.call(Request::Insert { values: vec![1.0; 256] });
    match tight.call(Request::Seal) {
        Response::Sealed { compaction_oom: None, epoch_len: 256, .. } => {}
        other => panic!("post-clear seal should succeed cleanly: {other:?}"),
    }
    println!("recovery after Clear ✓");

    tight.shutdown();
    generous.shutdown();
    println!("\ntight_budget_churn OK");
}
