//! **End-to-end driver** (paper §VI.D / Fig 6): the two-phase application
//! through the full stack — Rust coordinator → routed/batched inserts →
//! AOT-compiled Pallas work kernel via PJRT → flatten — on a real
//! workload, reporting wall-clock latency/throughput, PJRT execution
//! counts, simulated GPU time, and the Fig 6 speedup shape.
//!
//! ```sh
//! make artifacts && cargo run --release --example two_phase
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use std::time::{Duration, Instant};

use ggarray::coordinator::batcher::BatchConfig;
use ggarray::coordinator::request::{Request, Response};
use ggarray::coordinator::service::{Coordinator, CoordinatorConfig};
use ggarray::experiments::fig6;
use ggarray::runtime::ArtifactManifest;
use ggarray::sim::spec::DeviceSpec;

const PHASES: u32 = 5;
const START: usize = 8_192; // grows ×2 per phase → ~262k final
const WORK_CALLS: u32 = 3;

fn main() {
    let artifacts = ArtifactManifest::available();
    println!("== two-phase end-to-end driver ==");
    println!("artifacts available: {artifacts} (PJRT work kernel {})", if artifacts { "ON" } else { "OFF — host fallback" });

    let cfg = CoordinatorConfig {
        blocks: 128,
        first_bucket_size: 64,
        use_artifacts: artifacts,
        batch: BatchConfig { max_values: 1 << 14, max_delay: Duration::from_millis(1) },
        ..CoordinatorConfig::default()
    };
    let work_iters = cfg.work_iters;
    let c = Coordinator::start(cfg);

    let t0 = Instant::now();
    let mut size = 0usize;
    let mut inserts = START;
    let mut total_inserted = 0usize;
    for phase in 1..=PHASES {
        // --- insert phase: many small client requests, batched ---
        let t_phase = Instant::now();
        let mut sent = 0;
        while sent < inserts {
            let n = 1024.min(inserts - sent);
            let values: Vec<f32> = (0..n).map(|i| (total_inserted + sent + i) as f32).collect();
            c.call(Request::Insert { values });
            sent += n;
        }
        size += inserts;
        total_inserted += inserts;
        let t_insert = t_phase.elapsed();

        // --- work phase: the +1×30 kernel, WORK_CALLS times ---
        let t_work0 = Instant::now();
        let (sim_us, pjrt) = match c.call(Request::Work { calls: WORK_CALLS }) {
            Response::Worked { sim_us, pjrt_executions, .. } => (sim_us, pjrt_executions),
            other => panic!("work failed: {other:?}"),
        };
        let t_work = t_work0.elapsed();

        // --- flatten for the next static-speed phase ---
        let (flat_len, flat_checksum) = match c.call(Request::Flatten) {
            Response::Flattened { len, checksum, .. } => (len, checksum),
            other => panic!("flatten failed: {other:?}"),
        };
        assert_eq!(flat_len as usize, size);

        println!(
            "phase {phase}: size {size:>7}  insert {:>7.1} ms  work {:>7.1} ms (sim {:>8.2} ms, {pjrt} PJRT execs)  flatten ok (checksum {:#018x})",
            t_insert.as_secs_f64() * 1e3,
            t_work.as_secs_f64() * 1e3,
            sim_us / 1e3,
            flat_checksum,
        );
        inserts = size; // duplicate next phase
    }
    let wall = t0.elapsed();

    // --- verification: element 0 went through PHASES × WORK_CALLS work
    // passes of +1×work_iters each ---
    let expect0 = (PHASES * WORK_CALLS * work_iters) as f32;
    let got0 = c.call(Request::Query { index: 0 }).expect_value().unwrap();
    assert_eq!(got0, expect0, "element 0 must accumulate every work pass");
    println!("numeric check: element[0] = {got0} == {expect0} ✓");

    if let Response::Stats(s) = c.call(Request::Stats) {
        println!("--- coordinator metrics ---\n{s}");
        println!(
            "throughput: {:.0} inserts/s wall, batching {:.1} req/batch",
            s.elements_inserted as f64 / wall.as_secs_f64(),
            s.coalescing()
        );
        assert!(s.overhead_ratio() < 2.3, "memory overhead bound violated");
        if artifacts {
            assert!(s.pjrt_executions > 0, "expected real PJRT executions");
        }
    }
    c.shutdown();

    // --- Fig 6 shape from the calibrated model, for the record ---
    let p = fig6::Params::default();
    let spec = DeviceSpec::a100();
    print!("Fig 6 speedup (A100 model, k=1): ");
    for w in [1u32, 10, 100, 1000] {
        let (mm, gg) = fig6::two_phase_times(&spec, &p, 1, w);
        print!("w={w}: {:.3}  ", mm / gg);
    }
    println!("\ntwo_phase end-to-end OK ({:.2} s wall)", wall.as_secs_f64());
}
