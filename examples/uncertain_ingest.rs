//! Fig 3 in action: ingestion under a VRAM budget when the total volume
//! is only known as a distribution (LogNormal(0, σ) × base size).
//!
//! The static array must provision the 99th percentile to keep its
//! failure rate at 1%; under a tight budget that allocation *itself*
//! fails. GGArray grows to the realised size and survives every run that
//! physically fits.
//!
//! ```sh
//! cargo run --release --example uncertain_ingest
//! ```

use ggarray::baselines::static_array::StaticArray;
use ggarray::ggarray::array::{GgArray, GgConfig};
use ggarray::insertion::InsertionKind;
use ggarray::sim::memory::VramHeap;
use ggarray::sim::spec::DeviceSpec;
use ggarray::util::math::lognormal_quantile;
use ggarray::util::rng::Rng;
use ggarray::util::tables::fmt_bytes;
use ggarray::workload::synth_values;

fn main() {
    let spec = DeviceSpec::a100();
    let base: usize = 50_000; // expected ingest size (elements)
    let budget: u64 = 1_200_000; // bytes of VRAM granted to this tenant
    let runs = 200;
    let mut rng = Rng::new(7);

    println!("== uncertain ingestion: base {base} elements, budget {} ==", fmt_bytes(budget));
    println!("{:<8} {:>14} {:>14} {:>10}", "sigma", "static_ok", "ggarray_ok", "gg_mean_ovh");

    for sigma in [0.25, 0.5, 1.0, 1.5, 2.0] {
        // Static tenant: must pre-allocate q99 of the distribution.
        let p99_elems = (base as f64 * lognormal_quantile(0.99, 0.0, sigma)).ceil() as usize;
        let mut static_ok = 0u32;
        let mut gg_ok = 0u32;
        let mut ovh_sum = 0.0;
        let mut ovh_n = 0u32;
        for _ in 0..runs {
            let actual = ((base as f64) * if sigma == 0.0 { 1.0 } else { rng.lognormal(0.0, sigma) })
                .max(1.0) as usize;

            // --- static: allocate p99 up front, then ingest ---
            if let Ok(mut st) = StaticArray::<u32>::try_new(spec.clone(), p99_elems, budget) {
                use ggarray::baselines::GrowableArray;
                if actual <= p99_elems {
                    st.insert_bulk(&synth_values(0, actual), InsertionKind::WarpScan).unwrap();
                    static_ok += 1;
                } // else: the 1% tail — segfault in the paper's terms
            } // else: the p99 allocation itself exceeds the budget

            // --- GGArray: grow to the realised size ---
            let heap = VramHeap::with_capacity(spec.clone(), budget);
            let mut gg: GgArray<u32> = GgArray::with_heap(
                GgConfig::new(16).with_first_bucket(64),
                spec.clone(),
                heap,
            );
            if gg.insert_bulk(&synth_values(0, actual), InsertionKind::WarpScan).is_ok() {
                gg_ok += 1;
                ovh_sum += gg.overhead_ratio();
                ovh_n += 1;
            }
        }
        println!(
            "{:<8} {:>12}/{runs} {:>12}/{runs} {:>9.2}x",
            sigma,
            static_ok,
            gg_ok,
            if ovh_n > 0 { ovh_sum / ovh_n as f64 } else { f64::NAN },
        );
    }

    println!(
        "\nreading: as σ grows the static tenant's q99 provision ({}× base at σ=2) stops \
         fitting the budget at all, while GGArray keeps succeeding whenever the *realised* \
         data fits — at ≤2x overhead. This is the paper's Fig 3 argument as a running system.",
        lognormal_quantile(0.99, 0.0, 2.0).round()
    );
    println!("uncertain_ingest OK");
}
