"""AOT pipeline: lower every L2 graph at every artifact size to HLO
**text** + write `manifest.json`.

HLO text (not `HloModuleProto.serialize()`) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: ``cd python && python -m compile.aot --out ../artifacts [--small]``
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model

#: Size families per graph. Power-of-two sizes let the Rust executor pick
#: the smallest fitting artifact and zero-pad.
FULL_SIZES = {
    "scan_warp_i32": [1024, 4096, 16384, 65536],
    "scan_mxu_i32": [1024, 4096, 16384, 65536],
    "work_f32": [1024, 16384, 262144, 1048576],
    "insert_pack_f32": [1024, 4096, 16384],
    "flatten_f32": [8192, 65536],  # 64 blocks × {128, 1024} cap
}
#: Reduced set for quick CI runs (--small).
SMALL_SIZES = {
    "scan_warp_i32": [1024, 4096],
    "scan_mxu_i32": [1024, 4096],
    "work_f32": [1024, 16384],
    "insert_pack_f32": [1024],
    "flatten_f32": [8192],
}


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (return_tuple=True so the
    Rust side can uniformly `to_tuple()`)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {"int32": "i32", "uint32": "u32", "float32": "f32", "bfloat16": "bf16"}.get(
        str(dt), str(dt)
    )


def lower_entry(name: str, fn, specs):
    """Lower one jitted graph; returns (hlo_text, manifest_entry_dict)."""
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    out_avals = lowered.out_info
    outputs = [
        {"shape": list(o.shape), "dtype": _dtype_name(o.dtype)}
        for o in jax.tree_util.tree_leaves(out_avals)
    ]
    inputs = [{"shape": list(s.shape), "dtype": _dtype_name(s.dtype)} for s in specs]
    return text, {"inputs": inputs, "outputs": outputs}


def build(out_dir: str, sizes_by_graph: dict, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = {}
    for gname, factory in model.GRAPHS.items():
        for n in sizes_by_graph[gname]:
            entry_name = f"{gname}_{n}"
            fn, specs = factory(n)
            text, entry = lower_entry(entry_name, fn, specs)
            fname = f"{entry_name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            entry["file"] = fname
            entry["graph"] = gname
            entries[entry_name] = entry
            if verbose:
                print(f"[aot] {entry_name}: {len(text)} chars -> {fname}")
    manifest = {"version": 1, "jax": jax.__version__, "entries": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    if verbose:
        print(f"[aot] manifest: {len(entries)} entries -> {out_dir}/manifest.json")
    return manifest


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="output directory")
    p.add_argument("--small", action="store_true", help="reduced size set (CI)")
    p.add_argument("--quiet", action="store_true")
    args = p.parse_args(argv)
    sizes = SMALL_SIZES if args.small else FULL_SIZES
    build(args.out, sizes, verbose=not args.quiet)
    return 0


if __name__ == "__main__":
    sys.exit(main())
