"""Pure-jnp oracles for every kernel — the build-time correctness signal.

Each Pallas kernel in this package must match its oracle bit-exactly on
integer data (and to fp tolerance on floats); `python/tests/` sweeps
shapes and distributions with hypothesis.
"""

import jax.numpy as jnp


def ref_scan_inclusive(x):
    """Inclusive prefix sum (any 1-D integer/float array)."""
    return jnp.cumsum(x, dtype=x.dtype)


def ref_scan_exclusive(x):
    """Exclusive prefix sum."""
    incl = ref_scan_inclusive(x)
    return incl - x


def ref_work(x, iters: int = 30):
    """The +1×iters work op."""
    return x + jnp.asarray(iters, dtype=x.dtype)


def ref_insert_pack(mask, values):
    """Offsets + packed output of a masked parallel insertion.

    Returns (offsets, packed, total): offsets[i] is the slot thread i
    writes (meaningful only where mask), packed is the dense result
    (padded with zeros), total the number of packed elements.
    """
    counts = mask.astype(jnp.int32)
    offsets = ref_scan_exclusive(counts)
    total = counts.sum()
    n = values.shape[0]
    positions = jnp.where(mask.astype(bool), offsets, n)  # n = drop
    packed = jnp.zeros_like(values).at[positions].set(values, mode="drop")
    return offsets, packed, total


def ref_flatten(blocks, sizes):
    """Flatten a bucketed (B, cap) array into block-major contiguous order.

    Returns (flat, total): flat has shape (B*cap,) with the first `total`
    entries valid.
    """
    b, cap = blocks.shape
    starts = ref_scan_exclusive(sizes.astype(jnp.int32))
    col = jnp.arange(cap, dtype=jnp.int32)[None, :]
    valid = col < sizes[:, None]
    positions = jnp.where(valid, starts[:, None] + col, b * cap)
    flat = (
        jnp.zeros(b * cap, dtype=blocks.dtype)
        .at[positions.reshape(-1)]
        .set(blocks.reshape(-1), mode="drop")
    )
    return flat, sizes.sum()
