"""L1 Pallas kernel: matmul prefix-sum on the MXU (i32 via f32 matmuls).

The TPU analogue of the paper's tensor-core scan (§III.B.3, Dakkak et al.
2019, "Accelerating reduction and scan using tensor core units"). CUDA
formulates the scan as WMMA 16×16 matmuls against triangular one-matrices;
the MXU systolic array is the direct counterpart, with the natural tile
being the 128×128 systolic step:

1. reshape to (R, 128) and compute the intra-row inclusive scan as
   ``X @ U`` with ``U`` the upper-triangular ones matrix — one MXU pass;
2. row totals are column 127 of that product; their exclusive scan is a
   second (tiny, R×R) triangular matmul — strict lower ones;
3. broadcast-add the carry.

FLOPs: 2·128 per element for step 1 (+ O(R²) for the carry), matching the
paper's observation that at a 1:1 data:thread ratio the tensor path does
~8× more raw arithmetic than the shuffle scan and only wins when data per
thread is high.

Exactness: i32 inputs are scanned in f32. f32 integer arithmetic is exact
below 2^24, and the AOT artifact sizes bound the totals well under that
(documented + asserted in the tests).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128

#: Totals must stay below this for f32 matmul exactness.
EXACT_LIMIT = 1 << 24


def _triangular(n: int, strict_lower: bool) -> jax.Array:
    row = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    if strict_lower:
        return (row > col).astype(jnp.float32)
    return (row <= col).astype(jnp.float32)


def _mxu_scan_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # (R, 128)
    rows = x.shape[0]
    # Step 1: intra-row inclusive scan = X @ upper-triangular ones (MXU).
    u = _triangular(LANES, strict_lower=False)
    intra = jax.lax.dot(x, u)  # (R, 128)
    # Step 2: exclusive scan of row totals = strict-lower ones @ totals.
    totals = intra[:, LANES - 1 :]  # (R, 1)
    l = _triangular(rows, strict_lower=True)
    carry = jax.lax.dot(l, totals)  # (R, 1) exclusive sums
    # Step 3: add carries, cast back.
    o_ref[...] = (intra + carry).astype(o_ref.dtype)


def scan_mxu(x: jax.Array) -> jax.Array:
    """Inclusive prefix sum of a 1-D i32 array via MXU matmuls."""
    n = x.shape[0]
    if n % LANES != 0:
        raise ValueError(f"scan_mxu needs n % {LANES} == 0, got {n}")
    rows = n // LANES
    x2 = x.reshape(rows, LANES)
    out = pl.pallas_call(
        _mxu_scan_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), x.dtype),
        interpret=True,
    )(x2)
    return out.reshape(n)


def flops(n: int) -> int:
    """MXU FLOPs: 2·128·n for the row scan + 2·R² for the carry matmul."""
    r = n // LANES
    return 2 * LANES * n + 2 * r * r


def mxu_utilisation_estimate(n: int) -> float:
    """Fraction of the 128×128 MXU actually producing needed results.

    Only the upper triangle of U contributes distinct partial sums, and the
    carry GEMV streams R×R — mirrors the paper's ~1/8-warps-busy argument.
    """
    r = n // LANES
    useful = n * (LANES + 1) / 2 + r * (r - 1) / 2
    issued = LANES * n + r * r
    return useful / issued
