"""L1 Pallas kernel: hierarchical blocked inclusive prefix-sum (i32).

The TPU analogue of the paper's warp-shuffle scan (§III.B.2): CUDA does a
Hillis–Steele scan with ``__shfl_up_sync`` inside each 32-lane warp, then
scans the warp totals. Here the 128-lane VPU register row plays the warp:

1. Hillis–Steele along the 128-lane axis (7 shift+add steps — each step is
   the vector-unit equivalent of a warp shuffle);
2. row totals form the "warp sums"; a second Hillis–Steele along the
   sublane axis scans them;
3. the exclusive row carry is broadcast-added back.

Everything stays in VMEM for the sizes we AOT (≤ 64 Ki i32 = 256 KiB,
comfortably under the ~16 MiB VMEM budget). ``interpret=True`` is
mandatory on the CPU backend — real TPU lowering emits a Mosaic
custom-call the CPU PJRT client cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The VPU register row width — the "warp size" of this adaptation.
LANES = 128


def _hillis_steele(x: jax.Array, axis: int, size: int) -> jax.Array:
    """Inclusive scan along ``axis`` by log2(size) shift+add steps.

    The shift is a zero-padded slice — exactly what ``__shfl_up_sync``
    gives a CUDA warp (lanes below the shift distance receive 0 via the
    predicate).
    """
    d = 1
    while d < size:
        pad = [(0, 0)] * x.ndim
        pad[axis] = (d, 0)
        shifted = jnp.pad(x, pad)
        # Drop the overflow at the tail of `axis`.
        idx = [slice(None)] * x.ndim
        idx[axis] = slice(0, size)
        x = x + shifted[tuple(idx)]
        d *= 2
    return x


def _scan_kernel(x_ref, o_ref):
    """Pallas kernel body: (R, 128) i32 → inclusive scan in row-major order."""
    x = x_ref[...]
    rows = x.shape[0]
    # Phase 1: scan within each 128-lane row (the "warp scan").
    intra = _hillis_steele(x, axis=1, size=LANES)
    # Phase 2: scan the row totals (the "warp sums" scan).
    totals = intra[:, LANES - 1 :]  # (R, 1)
    tot_incl = _hillis_steele(totals, axis=0, size=rows)
    carry = tot_incl - totals  # exclusive carry per row
    # Phase 3: broadcast-add the carry.
    o_ref[...] = intra + carry


def scan_vector(x: jax.Array) -> jax.Array:
    """Inclusive prefix sum of a 1-D i32 array (length divisible by 128)."""
    n = x.shape[0]
    if n % LANES != 0:
        raise ValueError(f"scan_vector needs n % {LANES} == 0, got {n}")
    rows = n // LANES
    x2 = x.reshape(rows, LANES)
    out = pl.pallas_call(
        _scan_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), x.dtype),
        interpret=True,  # CPU backend: Mosaic custom-calls are TPU-only
    )(x2)
    return out.reshape(n)


@functools.partial(jax.jit, static_argnums=())
def scan_vector_jit(x: jax.Array) -> jax.Array:
    return scan_vector(x)


def vmem_bytes(n: int, itemsize: int = 4) -> int:
    """Estimated VMEM footprint: input + intra + totals + output.

    Used by DESIGN.md §Perf for the TPU feasibility estimate (interpret
    mode gives no hardware numbers).
    """
    return 2 * n * itemsize + 2 * (n // LANES) * itemsize
