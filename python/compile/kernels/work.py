"""L1 Pallas kernel: the paper's work-phase op — "+1, 30 times, to each
element" (§VI.C). Deliberately compute-light so the pass is memory-bound,
exactly like the paper's kernel.

Uses a real BlockSpec grid: (8, 128) f32 tiles streamed HBM→VMEM→HBM, one
grid step per tile row — the schedule a real TPU would pipeline. The 30
additions run as a fori_loop in registers.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
SUBLANES = 8
#: Rows per grid step. 256×128 f32 = 128 KiB per buffer — a realistic
#: streaming tile (≈1.6% of a v4 core's VMEM with double buffering) that
#: also keeps the *interpret-mode* grid short: interpret lowers the grid
#: to a sequential while-loop with whole-array dynamic-update-slices, so
#: per-step overhead is O(array); 8-row tiles made the AOT work kernel
#: ~25 ms per execute at 262 Ki elements, 256-row tiles ~1 ms (perf pass,
#: EXPERIMENTS.md §Perf).
TILE_ROWS = 256
#: +1 iterations per call, from the paper.
DEFAULT_ITERS = 30


def _work_kernel(x_ref, o_ref, *, iters: int):
    x = x_ref[...]
    x = jax.lax.fori_loop(0, iters, lambda _, v: v + 1.0, x)
    o_ref[...] = x


def work(x: jax.Array, iters: int = DEFAULT_ITERS) -> jax.Array:
    """Apply the +1×iters op to a 1-D f32 array (length % 1024 == 0)."""
    n = x.shape[0]
    tile = SUBLANES * LANES
    if n % tile != 0:
        raise ValueError(f"work needs n % {tile} == 0, got {n}")
    rows = n // LANES
    # Largest power-of-two tile ≤ TILE_ROWS that divides rows (rows is a
    # multiple of 8 by the check above; our AOT sizes are powers of two).
    tile_rows = min(rows, TILE_ROWS)
    while rows % tile_rows != 0:
        tile_rows //= 2
    x2 = x.reshape(rows, LANES)
    grid = rows // tile_rows
    out = pl.pallas_call(
        functools.partial(_work_kernel, iters=iters),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), x.dtype),
        grid=(grid,),
        in_specs=[pl.BlockSpec((tile_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_rows, LANES), lambda i: (i, 0)),
        interpret=True,
    )(x2)
    return out.reshape(n)


def vmem_bytes() -> int:
    """Per-grid-step VMEM: one (TILE_ROWS,128) f32 tile in + one out,
    double-buffered by the pipeline → ×2."""
    return 2 * 2 * TILE_ROWS * LANES * 4


def arithmetic_intensity(iters: int = DEFAULT_ITERS) -> float:
    """FLOPs per byte moved: iters adds / 8 bytes (read+write f32).

    30/8 ≈ 3.75 — far below the ~240 FLOP/byte ridge of a TPU, so the
    kernel is memory-bound, matching the paper's static-array r/w numbers.
    """
    return iters / 8.0
