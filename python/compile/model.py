"""Layer 2: the JAX compute graphs composed from the L1 Pallas kernels.

These are the *whole programs* the Rust coordinator executes via PJRT —
the scan-based insertion step, the work phase and the flatten step.
Python runs only at build time (`make artifacts`); the lowered HLO is the
runtime interface.
"""

import jax
import jax.numpy as jnp

from .kernels import scan_mxu, scan_vector, work


def scan_warp_graph(n: int):
    """Inclusive i32 scan of a length-n vector (warp/VPU algorithm)."""

    def fn(x):
        return (scan_vector.scan_vector(x),)

    return fn, (jax.ShapeDtypeStruct((n,), jnp.int32),)


def scan_mxu_graph(n: int):
    """Inclusive i32 scan of a length-n vector (MXU matmul algorithm)."""

    def fn(x):
        return (scan_mxu.scan_mxu(x),)

    return fn, (jax.ShapeDtypeStruct((n,), jnp.int32),)


def work_graph(n: int, iters: int = work.DEFAULT_ITERS):
    """The +1×iters work phase over a length-n f32 vector."""

    def fn(x):
        return (work.work(x, iters=iters),)

    return fn, (jax.ShapeDtypeStruct((n,), jnp.float32),)


def insert_pack_graph(n: int, scan: str = "warp"):
    """Full insertion step: mask + values → (offsets, packed, total).

    Fuses the scan kernel with the scatter so one executable performs the
    whole index-assignment + placement (the L2 composition the paper's
    insertion algorithms implement in one CUDA kernel).
    """
    scan_fn = scan_vector.scan_vector if scan == "warp" else scan_mxu.scan_mxu

    def fn(mask, values):
        counts = mask.astype(jnp.int32)
        incl = scan_fn(counts)
        offsets = incl - counts  # exclusive
        total = incl[n - 1]
        positions = jnp.where(mask.astype(bool), offsets, n)
        packed = jnp.zeros_like(values).at[positions].set(values, mode="drop")
        return offsets, packed, total.reshape(1)

    return fn, (
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    )


def flatten_graph(blocks: int, cap: int):
    """Bucketed (B, cap) + sizes → block-major flat array + total."""

    def fn(vals, sizes):
        counts = sizes.astype(jnp.int32)
        incl = jnp.cumsum(counts)
        starts = incl - counts
        col = jnp.arange(cap, dtype=jnp.int32)[None, :]
        valid = col < counts[:, None]
        positions = jnp.where(valid, starts[:, None] + col, blocks * cap)
        flat = (
            jnp.zeros(blocks * cap, dtype=vals.dtype)
            .at[positions.reshape(-1)]
            .set(vals.reshape(-1), mode="drop")
        )
        return flat, incl[blocks - 1].reshape(1)

    return fn, (
        jax.ShapeDtypeStruct((blocks, cap), jnp.float32),
        jax.ShapeDtypeStruct((blocks,), jnp.int32),
    )


#: Blocks used by the AOT'd flatten graphs (cap = n // FLATTEN_BLOCKS).
FLATTEN_BLOCKS = 64


def _flatten_by_total(n: int):
    assert n % FLATTEN_BLOCKS == 0, n
    return flatten_graph(FLATTEN_BLOCKS, n // FLATTEN_BLOCKS)


#: Entry-point registry: name → factory(n). Names double as the family
#: prefixes the Rust Executor's `pick_size` uses.
GRAPHS = {
    "scan_warp_i32": scan_warp_graph,
    "scan_mxu_i32": scan_mxu_graph,
    "work_f32": work_graph,
    "insert_pack_f32": insert_pack_graph,
    "flatten_f32": _flatten_by_total,
}
