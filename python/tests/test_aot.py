"""AOT pipeline: manifest integrity + HLO text sanity + the 64-bit-id
pitfall guard (text, not serialized protos)."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    sizes = {k: v[:1] for k, v in aot.SMALL_SIZES.items()}  # 1 size each: fast
    manifest = aot.build(str(out), sizes, verbose=False)
    return out, manifest


def test_manifest_structure(built):
    out, manifest = built
    assert manifest["version"] == 1
    assert len(manifest["entries"]) == len(aot.SMALL_SIZES)
    on_disk = json.loads((out / "manifest.json").read_text())
    assert on_disk["entries"].keys() == manifest["entries"].keys()
    for name, e in on_disk["entries"].items():
        assert (out / e["file"]).exists(), name
        assert e["inputs"] and e["outputs"], name
        for t in e["inputs"] + e["outputs"]:
            assert t["dtype"] in {"i32", "f32"}
            assert all(isinstance(d, int) for d in t["shape"])


def test_hlo_text_is_parseable_hlo(built):
    out, manifest = built
    for name, e in manifest["entries"].items():
        text = (out / e["file"]).read_text()
        assert "ENTRY" in text, f"{name} doesn't look like HLO text"
        assert "HloModule" in text
        # Tuple return (rust side calls to_tuple()).
        assert "tuple" in text or "ROOT" in text


def test_scan_entry_shapes(built):
    _, manifest = built
    e = manifest["entries"]["scan_warp_i32_1024"]
    assert e["inputs"] == [{"shape": [1024], "dtype": "i32"}]
    assert e["outputs"] == [{"shape": [1024], "dtype": "i32"}]


def test_sizes_families_cover_rust_needs():
    # The Rust coordinator picks from these families; make sure the
    # full build includes the sizes the service relies on.
    assert 65536 in aot.FULL_SIZES["scan_warp_i32"]
    assert 1048576 in aot.FULL_SIZES["work_f32"]
    for fam, sizes in aot.FULL_SIZES.items():
        assert sizes == sorted(sizes), fam
        for n in sizes:
            assert n % 128 == 0, (fam, n)
