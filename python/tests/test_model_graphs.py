"""L2 correctness: the composed graphs (insert_pack, flatten) vs oracles,
and shape/dtype contracts of every registered entry point."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


@pytest.mark.parametrize("scan", ["warp", "mxu"])
def test_insert_pack_matches_ref(scan):
    n = 1024
    rng = np.random.default_rng(3)
    mask = jnp.asarray(rng.integers(0, 2, n), dtype=jnp.int32)
    values = jnp.asarray(rng.normal(size=n), dtype=jnp.float32)
    fn, _ = model.insert_pack_graph(n, scan=scan)
    offsets, packed, total = jax.jit(fn)(mask, values)
    r_off, r_packed, r_total = ref.ref_insert_pack(mask, values)
    np.testing.assert_array_equal(np.asarray(offsets), np.asarray(r_off))
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(r_packed))
    assert int(total[0]) == int(r_total)


def test_insert_pack_dense_prefix():
    # Packed output must be exactly the masked values, in order, as a
    # dense prefix.
    n = 512 * 2  # multiple of 128
    mask = jnp.asarray(([1, 0] * (n // 2)), dtype=jnp.int32)
    values = jnp.arange(n, dtype=jnp.float32)
    fn, _ = model.insert_pack_graph(n)
    _, packed, total = jax.jit(fn)(mask, values)
    assert int(total[0]) == n // 2
    np.testing.assert_array_equal(
        np.asarray(packed[: n // 2]), np.arange(0, n, 2, dtype=np.float32)
    )
    np.testing.assert_array_equal(np.asarray(packed[n // 2 :]), np.zeros(n // 2))


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=8),
    p=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_insert_pack_hypothesis(rows, p, seed):
    n = rows * 128
    rng = np.random.default_rng(seed)
    mask = jnp.asarray((rng.uniform(size=n) < p).astype(np.int32))
    values = jnp.asarray(rng.normal(size=n), dtype=jnp.float32)
    fn, _ = model.insert_pack_graph(n)
    offsets, packed, total = jax.jit(fn)(mask, values)
    want = np.asarray(values)[np.asarray(mask) == 1]
    assert int(total[0]) == want.shape[0]
    np.testing.assert_array_equal(np.asarray(packed[: want.shape[0]]), want)
    # Offsets where mask=1 are exactly 0..total-1, strictly increasing.
    got_off = np.asarray(offsets)[np.asarray(mask) == 1]
    np.testing.assert_array_equal(got_off, np.arange(want.shape[0]))


def test_flatten_graph_matches_ref():
    b, cap = 8, 64
    rng = np.random.default_rng(5)
    vals = jnp.asarray(rng.normal(size=(b, cap)), dtype=jnp.float32)
    sizes = jnp.asarray(rng.integers(0, cap + 1, b), dtype=jnp.int32)
    fn, _ = model.flatten_graph(b, cap)
    flat, total = jax.jit(fn)(vals, sizes)
    r_flat, r_total = ref.ref_flatten(vals, sizes)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(r_flat))
    assert int(total[0]) == int(r_total)


def test_flatten_block_major_order():
    b, cap = 3, 4
    vals = jnp.arange(12, dtype=jnp.float32).reshape(b, cap)
    sizes = jnp.asarray([2, 0, 3], dtype=jnp.int32)
    fn, _ = model.flatten_graph(b, cap)
    flat, total = jax.jit(fn)(vals, sizes)
    assert int(total[0]) == 5
    np.testing.assert_array_equal(np.asarray(flat[:5]), [0.0, 1.0, 8.0, 9.0, 10.0])


def test_registered_graphs_lower_and_run():
    # Every GRAPHS entry must trace, run, and respect its declared specs.
    for name, factory in model.GRAPHS.items():
        fn, specs = factory(1024)
        args = [jnp.zeros(s.shape, s.dtype) for s in specs]
        out = jax.jit(fn)(*args)
        assert isinstance(out, tuple), name
        for o in out:
            assert o.shape is not None
