"""L1 correctness: both Pallas scan kernels vs the pure-jnp oracle.

This is the core build-time correctness signal — hypothesis sweeps sizes,
value ranges and distributions; every case must match bit-exactly.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, scan_mxu, scan_vector

SIZES = [128, 256, 1024, 4096, 16384]
KERNELS = {"warp": scan_vector.scan_vector, "mxu": scan_mxu.scan_mxu}


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("name", list(KERNELS))
def test_scan_matches_cumsum_random(n, name):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.integers(0, 100, n), dtype=jnp.int32)
    got = KERNELS[name](x)
    want = ref.ref_scan_inclusive(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("name", list(KERNELS))
def test_scan_zeros_and_ones(name):
    n = 1024
    np.testing.assert_array_equal(
        np.asarray(KERNELS[name](jnp.zeros(n, jnp.int32))), np.zeros(n)
    )
    np.testing.assert_array_equal(
        np.asarray(KERNELS[name](jnp.ones(n, jnp.int32))), np.arange(1, n + 1)
    )


@pytest.mark.parametrize("name", list(KERNELS))
def test_scan_mask_pattern(name):
    # The insertion use case: 0/1 flags.
    n = 4096
    rng = np.random.default_rng(1)
    mask = jnp.asarray(rng.integers(0, 2, n), dtype=jnp.int32)
    got = KERNELS[name](mask)
    np.testing.assert_array_equal(np.asarray(got), np.cumsum(np.asarray(mask)))


@pytest.mark.parametrize("name", list(KERNELS))
def test_scan_rejects_unaligned(name):
    with pytest.raises(ValueError):
        KERNELS[name](jnp.zeros(100, jnp.int32))


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    hi=st.sampled_from([1, 2, 7, 1000, 10_000]),
)
def test_scan_hypothesis_sweep(rows, seed, hi):
    n = rows * 128
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, hi + 1, n), dtype=jnp.int32)
    want = np.cumsum(np.asarray(x))
    for name, k in KERNELS.items():
        got = np.asarray(k(x))
        np.testing.assert_array_equal(got, want, err_msg=f"{name} n={n} hi={hi}")


def test_mxu_exactness_domain():
    # f32 matmuls are exact below 2^24; the max total at our largest AOT
    # size with worst-case per-thread counts must stay under it.
    max_total = 65536 * 100  # 100 inserts/thread at the largest artifact
    assert max_total < scan_mxu.EXACT_LIMIT
    # And right at a large-total case the kernel stays exact:
    n = 1024
    x = jnp.full((n,), 1000, jnp.int32)  # total 1.024e6 < 2^24
    got = np.asarray(scan_mxu.scan_mxu(x))
    np.testing.assert_array_equal(got, np.cumsum(np.asarray(x)))


def test_both_algorithms_identical():
    # The paper's three insertion algorithms differ only in speed, never
    # in result (§III.B) — enforce it for the two kernel variants.
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.integers(0, 50, 4096), dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(scan_vector.scan_vector(x)), np.asarray(scan_mxu.scan_mxu(x))
    )


def test_vmem_estimates_fit_budget():
    # Structural perf check: the largest AOT'd scan fits VMEM (~16 MiB).
    assert scan_vector.vmem_bytes(65536) < 16 * 1024 * 1024
    # MXU utilisation estimate is in (0, 1] and ~0.5 for big n (upper
    # triangle of U is half the issued MACs).
    u = scan_mxu.mxu_utilisation_estimate(65536)
    assert 0.4 < u <= 1.0
