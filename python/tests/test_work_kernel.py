"""L1 correctness: the +1×30 work kernel vs its oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, work


def _iterative_f32(x, iters):
    """Bit-exact oracle: the same 30 sequential f32 additions the kernel
    performs (a single `x + 30` differs by rounding ULPs)."""
    acc = np.asarray(x, dtype=np.float32).copy()
    for _ in range(iters):
        acc = acc + np.float32(1.0)
    return acc


@pytest.mark.parametrize("n", [1024, 2048, 16384])
def test_work_adds_thirty(n):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=n), dtype=jnp.float32)
    got = np.asarray(work.work(x))
    # Bit-exact against the iterative oracle …
    np.testing.assert_array_equal(got, _iterative_f32(x, 30))
    # … and within fp tolerance of the semantic oracle (+30).
    np.testing.assert_allclose(got, np.asarray(ref.ref_work(x, 30)), rtol=1e-6, atol=1e-5)


def test_work_custom_iters():
    x = jnp.zeros(1024, jnp.float32)
    np.testing.assert_array_equal(np.asarray(work.work(x, iters=7)), np.full(1024, 7.0))
    np.testing.assert_array_equal(np.asarray(work.work(x, iters=0)), np.zeros(1024))


def test_work_rejects_unaligned():
    with pytest.raises(ValueError):
        work.work(jnp.zeros(1000, jnp.float32))


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_work_hypothesis(tiles, seed):
    n = tiles * 1024
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-1e6, 1e6, n), dtype=jnp.float32)
    got = np.asarray(work.work(x))
    np.testing.assert_array_equal(got, _iterative_f32(x, 30))


def test_memory_bound_by_design():
    # Paper's work op must be memory-bound: arithmetic intensity far below
    # the TPU ridge point (~240 FLOP/byte for bf16 MXU, ~40 for VPU f32).
    assert work.arithmetic_intensity(30) < 10
