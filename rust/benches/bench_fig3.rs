//! Bench target for Fig 3: regenerates the theoretical memory-usage
//! curves and times the Monte-Carlo engine itself.
//! Run: `cargo bench --bench bench_fig3`

use ggarray::experiments::fig3;
use ggarray::theory::memory_model;
use ggarray::util::benchkit::{black_box, BenchSuite};
use ggarray::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("fig3 — theoretic memory usage (GGArray vs static/semi-static)");
    suite.banner();

    // Regenerate the figure (the deliverable) and record headline values.
    let rep = fig3::run(&fig3::Params::default());
    rep.save(std::path::Path::new("reports")).expect("save fig3");
    let table = &rep.sections[0].table;
    for probe_sigma in ["0.500", "1.000", "2.000"] {
        if let Some(row) = table.rows().iter().find(|r| r[0] == probe_sigma) {
            let opt: f64 = row[1].parse().unwrap();
            let stat: f64 = row[2].parse().unwrap();
            let gg: f64 = row[5].parse().unwrap();
            suite.record(&format!("sigma={probe_sigma} static_p99/optimal ratio"), stat / opt * 1000.0);
            suite.record(&format!("sigma={probe_sigma} ggarray/optimal ratio"), gg / opt * 1000.0);
        }
    }

    // Wall-clock of the Monte-Carlo engine (the real computation here).
    let mut rng = Rng::new(99);
    suite.bench("expected_usage sigma=1.0 draws=2000", || {
        black_box(memory_model::expected_usage(1.0, 1_000_000, 512, 64, 2000, &mut rng));
    });
    suite.bench("sweep 11 points x 500 draws", || {
        black_box(memory_model::sweep(2.0, 10, 1_000_000, 512, 64, 500, 7));
    });

    std::fs::create_dir_all("reports").unwrap();
    std::fs::write("reports/bench_fig3.md", suite.markdown()).unwrap();
    eprintln!("wrote reports/bench_fig3.md and fig3 CSVs");
}
