//! Bench target for Fig 4: regenerates all three columns (insertion
//! algorithms / grow+insert vs #LFVectors / rw vs #LFVectors) from the
//! calibrated model, and cross-checks with real small-scale structure
//! runs (wall clock + simulated clock agreement on ordering).
//! Run: `cargo bench --bench bench_fig4`

use ggarray::experiments::fig4;
use ggarray::ggarray::array::{GgArray, GgConfig};
use ggarray::insertion::InsertionKind;
use ggarray::sim::spec::DeviceSpec;
use ggarray::util::benchkit::{black_box, BenchSuite};

fn main() {
    let mut suite = BenchSuite::new("fig4 — insertion algorithms and #LFVectors sweeps");
    suite.banner();

    let rep = fig4::run(&fig4::Params::default());
    rep.save(std::path::Path::new("reports")).expect("save fig4");

    // Col 1 headline (A100, final iteration): modeled ms per algorithm.
    let spec = DeviceSpec::a100();
    let n = 512_000_000u64;
    let shape = ggarray::insertion::InsertShape::static_array(&spec, n, n, 4);
    for kind in InsertionKind::ALL {
        suite.record(
            &format!("modeled insert 5.12e8 ({})", kind.name()),
            ggarray::insertion::cost_us(&spec, kind, &shape),
        );
    }

    // Real small-scale: the same ordering must hold on the simulated
    // clock with real data movement (1e6 elements).
    let data: Vec<u32> = (0..1_000_000u32).collect();
    for kind in InsertionKind::ALL {
        let mut gg: GgArray<u32> = GgArray::new(GgConfig::new(512), spec.clone());
        let rep = gg.insert_bulk(&data, kind).unwrap();
        suite.record(&format!("sim insert 1e6 via GGArray512 ({})", kind.name()), rep.us);
    }

    // Wall-clock of the real data path (what the host actually does).
    suite.bench("host insert_bulk 1e6 u32 into GGArray512", || {
        let mut gg: GgArray<u32> = GgArray::new(GgConfig::new(512), spec.clone());
        black_box(gg.insert_bulk(&data, InsertionKind::WarpScan).unwrap());
    });

    std::fs::create_dir_all("reports").unwrap();
    std::fs::write("reports/bench_fig4.md", suite.markdown()).unwrap();
    eprintln!("wrote reports/bench_fig4.md and fig4 CSVs");
}
