//! Bench target for Fig 5: regenerates the per-iteration duplication
//! series for all four structures on both GPU models, and runs the same
//! schedule for real at reduced scale to validate the orderings.
//! Run: `cargo bench --bench bench_fig5`

use ggarray::baselines::{memmap::MemMapArray, semistatic::SemiStaticArray, static_array::StaticArray, GrowableArray};
use ggarray::experiments::fig5;
use ggarray::ggarray::array::{GgArray, GgConfig};
use ggarray::insertion::InsertionKind;
use ggarray::sim::spec::DeviceSpec;
use ggarray::util::benchkit::BenchSuite;
use ggarray::workload::synth_values;

fn main() {
    let mut suite = BenchSuite::new("fig5 — grow/insert/rw per duplication iteration");
    suite.banner();

    let rep = fig5::run(&fig5::Params::default());
    rep.save(std::path::Path::new("reports")).expect("save fig5");

    // Modeled last-iteration values (A100) — the Fig 5 right edge.
    let spec = DeviceSpec::a100();
    let p = fig5::Params::default();
    for s in fig5::STRUCTURES {
        let series = fig5::duplication_series(&spec, s, &p);
        let last = series.last().unwrap();
        if let Some(g) = last.grow_ms {
            suite.record(&format!("modeled {s} grow (last iter)"), g * 1e3);
        }
        suite.record(&format!("modeled {s} insert (last iter)"), last.insert_ms * 1e3);
        suite.record(&format!("modeled {s} rw (last iter)"), last.rw_ms * 1e3);
    }

    // Real reduced-scale duplication (1e4 → 1.024e7 would be heavy; use
    // 1e4 → 1e5, 4 doublings… wall-clock of actual host work per iter).
    let start = 10_000usize;
    let iters = 4u32;
    suite.bench("real duplication static (1e4 x 4 doublings)", || {
        let mut st: StaticArray<u32> = StaticArray::new(spec.clone(), start << (iters + 1));
        let mut size = start;
        st.insert_bulk(&synth_values(0, size), InsertionKind::WarpScan).unwrap();
        for _ in 0..iters {
            st.insert_bulk(&synth_values(0, size), InsertionKind::WarpScan).unwrap();
            size *= 2;
            st.read_write(30.0, &mut |x| *x = x.wrapping_add(1));
        }
    });
    suite.bench("real duplication GGArray32 (1e4 x 4 doublings)", || {
        let mut gg: GgArray<u32> = GgArray::new(GgConfig::new(32).with_first_bucket(64), spec.clone());
        let mut size = start;
        gg.insert_bulk(&synth_values(0, size), InsertionKind::WarpScan).unwrap();
        for _ in 0..iters {
            gg.insert_bulk(&synth_values(0, size), InsertionKind::WarpScan).unwrap();
            size *= 2;
            gg.read_write_block(30.0, |x| *x = x.wrapping_add(1));
        }
    });
    suite.bench("real duplication memMap (1e4 x 4 doublings)", || {
        let mut mm: MemMapArray<u32> = MemMapArray::new(spec.clone(), 1 << 26);
        let mut size = start;
        mm.insert_bulk(&synth_values(0, size), InsertionKind::WarpScan).unwrap();
        for _ in 0..iters {
            mm.insert_bulk(&synth_values(0, size), InsertionKind::WarpScan).unwrap();
            size *= 2;
            mm.read_write(30.0, &mut |x| *x = x.wrapping_add(1));
        }
    });
    suite.bench("real duplication semi-static (1e4 x 4 doublings)", || {
        let mut sa: SemiStaticArray<u32> = SemiStaticArray::new(spec.clone(), 64);
        let mut size = start;
        sa.insert_bulk(&synth_values(0, size), InsertionKind::WarpScan).unwrap();
        for _ in 0..iters {
            sa.insert_bulk(&synth_values(0, size), InsertionKind::WarpScan).unwrap();
            size *= 2;
            sa.read_write(30.0, &mut |x| *x = x.wrapping_add(1));
        }
    });

    std::fs::create_dir_all("reports").unwrap();
    std::fs::write("reports/bench_fig5.md", suite.markdown()).unwrap();
    eprintln!("wrote reports/bench_fig5.md and fig5 CSVs");
}
