//! Bench target for Fig 6: regenerates the two-phase speedup curves and
//! runs the real (reduced-scale) two-phase pipeline through the
//! coordinator, reporting wall-clock throughput.
//! Run: `cargo bench --bench bench_fig6`

use std::time::Duration;

use ggarray::coordinator::batcher::BatchConfig;
use ggarray::coordinator::request::{Request, Response};
use ggarray::coordinator::service::{Coordinator, CoordinatorConfig};
use ggarray::experiments::fig6;
use ggarray::sim::spec::DeviceSpec;
use ggarray::util::benchkit::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("fig6 — two-phase application speedup (GGArray vs memMap)");
    suite.banner();

    let rep = fig6::run(&fig6::Params::default());
    rep.save(std::path::Path::new("reports")).expect("save fig6");

    // Headline speedups (A100 model), ×1000 so they read as milli-units.
    let p = fig6::Params::default();
    let spec = DeviceSpec::a100();
    for w in [1u32, 10, 100, 1000] {
        let (mm, gg) = fig6::two_phase_times(&spec, &p, 1, w);
        suite.record(&format!("speedup x1000 (k=1, w={w})"), mm / gg * 1000.0);
    }

    // Real two-phase run through the coordinator (reduced scale).
    let mk_cfg = || CoordinatorConfig {
        blocks: 64,
        first_bucket_size: 64,
        use_artifacts: ggarray::runtime::ArtifactManifest::available(),
        batch: BatchConfig { max_values: 1 << 14, max_delay: Duration::from_millis(1) },
        ..CoordinatorConfig::default()
    };
    // One long-running service (compiled artifacts stay warm — the
    // serving scenario); each iteration is a full two-phase cycle.
    let c = Coordinator::start(mk_cfg());
    suite.bench("coordinator two-phase 3x(insert 20k + work 2 + flatten)", || {
        for phase in 0..3 {
            let values: Vec<f32> = (0..20_000).map(|i| (phase * 20_000 + i) as f32).collect();
            c.call(Request::Insert { values });
            match c.call(Request::Work { calls: 2 }) {
                Response::Worked { .. } => {}
                other => panic!("{other:?}"),
            }
            c.call(Request::Flatten);
        }
        match c.call(Request::Clear) {
            Response::Cleared => {}
            other => panic!("{other:?}"),
        }
    });
    // Cold-start cost, measured separately (was folded into every
    // iteration before the perf pass).
    suite.bench("coordinator cold start + shutdown", || {
        let c = Coordinator::start(mk_cfg());
        c.call(Request::Insert { values: vec![1.0; 128] });
        c.shutdown();
    });
    c.shutdown();

    std::fs::create_dir_all("reports").unwrap();
    std::fs::write("reports/bench_fig6.md", suite.markdown()).unwrap();
    eprintln!("wrote reports/bench_fig6.md and fig6 CSVs");
}
