//! §Frontend bench: sustained multi-client throughput and per-request
//! admission latency through the bounded session frontend, at 1/8/64
//! client threads against one coordinator (4 shards, eager merge).
//!
//! Each client thread pushes a fixed share of the total values in
//! 256-value insert requests through its own [`ClientSession`],
//! retrying (with the hint, capped) on typed rejections. The run ends
//! with a seal barrier, so the wall clock covers admission + cross-client
//! merge + dispatch + seal — and the sealed epoch length must equal the
//! sum of the clients' accepted-value ledgers (nothing dropped, nothing
//! duplicated). Shed counts observed by the clients must match the
//! coordinator's `shed_requests` metric exactly.
//!
//! Emits `BENCH_frontend.json` (schema `bench_frontend/v1`) at the repo
//! root: per client level, sustained requests/sec plus mean/p50/p99
//! admission latency (µs) and the shed count. Report-only — no
//! regression gate yet (see EXPERIMENTS.md §Frontend for the field
//! definitions and re-baselining rules).
//!
//! Run: `cargo bench --bench bench_frontend` (full, 4M values) or
//!      `cargo bench --bench bench_frontend -- --smoke` (CI, 400k).

use std::path::PathBuf;
use std::time::{Duration, Instant};

use ggarray::coordinator::request::{Admission, Request, Response};
use ggarray::coordinator::service::{Coordinator, CoordinatorConfig};
use ggarray::util::benchkit::BenchSuite;
use ggarray::util::benchreport::{self, FrontendClientRow, FRONTEND_SCHEMA};
use ggarray::util::json;
use ggarray::util::stats::percentile;
use ggarray::workload::synth_f32;

/// Values per insert request (fixed, so req/s and values/s are
/// proportional across client levels).
const VALUES_PER_REQUEST: usize = 256;
const CLIENT_LEVELS: [usize; 3] = [1, 8, 64];

fn repo_root() -> PathBuf {
    std::env::var("CARGO_MANIFEST_DIR")
        .map(|m| PathBuf::from(m).join(".."))
        .unwrap_or_else(|_| PathBuf::from("."))
}

/// One client level: `clients` threads split `total_values` evenly,
/// each timing every request from first `try_insert` to acceptance
/// (retries included). Returns the report row.
fn run_level(suite: &mut BenchSuite, clients: usize, total_values: u64) -> FrontendClientRow {
    let c = Coordinator::start(CoordinatorConfig {
        blocks: 512,
        shards: 4,
        use_artifacts: false,
        ..CoordinatorConfig::default()
    });
    let requests_per_client = ((total_values as usize / VALUES_PER_REQUEST) / clients).max(1);
    let mut sessions: Vec<_> = (0..clients).map(|_| c.session()).collect();

    let t0 = Instant::now();
    let outcomes: Vec<(Vec<f64>, u64, u64)> = std::thread::scope(|s| {
        let handles: Vec<_> = sessions
            .iter_mut()
            .enumerate()
            .map(|(k, sess)| {
                s.spawn(move || {
                    let mut latencies = Vec::with_capacity(requests_per_client);
                    let mut sheds = 0u64;
                    let mut accepted = 0u64;
                    for r in 0..requests_per_client {
                        let base = ((k * requests_per_client + r) * VALUES_PER_REQUEST) as u64;
                        let mut values: Vec<f32> =
                            (0..VALUES_PER_REQUEST as u64).map(|i| synth_f32(base + i)).collect();
                        let q0 = Instant::now();
                        loop {
                            match sess.try_insert(values) {
                                Admission::Accepted { session_values, .. } => {
                                    accepted = session_values;
                                    break;
                                }
                                Admission::Rejected { retry_after_hint, values: returned } => {
                                    sheds += 1;
                                    values = returned;
                                    std::thread::sleep(
                                        retry_after_hint.min(Duration::from_micros(100)),
                                    );
                                }
                                Admission::Closed { .. } => panic!("coordinator closed mid-bench"),
                            }
                        }
                        latencies.push(q0.elapsed().as_secs_f64() * 1e6);
                    }
                    (latencies, sheds, accepted)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread panicked")).collect()
    });

    // Seal barrier: drains every client pool, applies every batch, and
    // closes the wall-clock window — "sustained" includes the merge.
    let epoch_len = match c.call(Request::Seal) {
        Response::Sealed { epoch_len, .. } => epoch_len,
        other => panic!("seal failed: {other:?}"),
    };
    let wall_s = t0.elapsed().as_secs_f64();

    let accepted_total: u64 = outcomes.iter().map(|(_, _, a)| a).sum();
    let shed_total: u64 = outcomes.iter().map(|(_, s, _)| s).sum();
    // Conservation: every accepted value is sealed exactly once.
    assert_eq!(
        epoch_len, accepted_total,
        "{clients} clients: sealed epoch must hold exactly the accepted values"
    );
    let snap = c.call(Request::Stats).expect_stats();
    assert_eq!(snap.sessions, clients as u64);
    assert_eq!(
        snap.shed_requests, shed_total,
        "metrics shed ledger must match client-observed rejections"
    );
    assert_eq!(snap.admitted_values, accepted_total);
    c.shutdown();

    let all_latencies: Vec<f64> = outcomes.into_iter().flat_map(|(l, _, _)| l).collect();
    let requests_total = (clients * requests_per_client) as f64;
    let row = FrontendClientRow {
        clients,
        req_per_s: requests_total / wall_s,
        mean_us: all_latencies.iter().sum::<f64>() / all_latencies.len() as f64,
        p50_us: percentile(&all_latencies, 50.0),
        p99_us: percentile(&all_latencies, 99.0),
        shed: shed_total,
    };
    suite.record_samples(&format!("admission latency ({clients} clients)"), &all_latencies);
    eprintln!(
        "  {:<44} {:>12.0} req/s  (p50 {:.2} µs, p99 {:.2} µs, {} shed)",
        format!("sustained throughput ({clients} clients)"),
        row.req_per_s,
        row.p50_us,
        row.p99_us,
        row.shed
    );
    row
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let total_values: u64 = if smoke { 400_000 } else { 4_000_000 };

    let mut suite = BenchSuite::new(if smoke {
        "frontend admission (smoke) — bounded sessions, cross-client merge, eager drain"
    } else {
        "frontend admission — bounded sessions, cross-client merge, eager drain"
    });
    suite.banner();

    let rows: Vec<FrontendClientRow> =
        CLIENT_LEVELS.iter().map(|&n| run_level(&mut suite, n, total_values)).collect();

    let fresh = benchreport::frontend_report(smoke, VALUES_PER_REQUEST, total_values, &rows);
    let path = repo_root().join("BENCH_frontend.json");
    // Same write policy as bench_hotpath: full runs re-baseline, smoke
    // runs only bootstrap a missing or schema-mismatched file, so ci.sh
    // never overwrites the committed baseline with smoke noise.
    let baseline_ok = std::fs::read_to_string(&path)
        .ok()
        .and_then(|text| json::parse(&text).ok())
        .map(|b| benchreport::schema_of(&b) == Some(FRONTEND_SCHEMA))
        .unwrap_or(false);
    if !smoke || !baseline_ok {
        std::fs::write(&path, fresh.to_string_pretty()).expect("write BENCH_frontend.json");
        eprintln!("wrote {}", path.display());
    } else {
        eprintln!("smoke run: committed baseline {} left intact", path.display());
    }
}
