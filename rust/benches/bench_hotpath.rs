//! §Perf hot-path benches: the *real* (wall-clock) cost of the request
//! path — steady-state insert dispatch through the scratch arena, the
//! pooled seal/flatten gather, sealed queries, and the underlying
//! micro-operations (LFVector appends, routing, prefix lookups, rw
//! passes, PJRT execution).
//!
//! Emits `BENCH_hotpath.json` at the **repo root** so the perf
//! trajectory is recorded PR over PR, and exits non-zero when
//! steady-state insert dispatch regresses more than
//! [`GATE_TOLERANCE`] against the committed baseline (skipped when no
//! baseline exists — e.g. the first run — or `GG_BENCH_GATE=off`).
//! See EXPERIMENTS.md §Perf for the field definitions and how to
//! re-baseline.
//!
//! Run: `cargo bench --bench bench_hotpath` (full) or
//!      `cargo bench --bench bench_hotpath -- --smoke` (CI smoke: fewer
//!      iterations, micro benches skipped).

use std::path::PathBuf;
use std::time::{Duration, Instant};

use ggarray::coordinator::batcher::BatchConfig;
use ggarray::coordinator::request::{Request, Response};
use ggarray::coordinator::router::{self, DispatchScratch, Policy};
use ggarray::coordinator::service::{dispatch_insert, Coordinator, CoordinatorConfig};
use ggarray::coordinator::shard::{Shard, ShardConfig};
use ggarray::ggarray::array::{GgArray, GgConfig};
use ggarray::ggarray::flatten::flatten;
use ggarray::ggarray::index::PrefixIndex;
use ggarray::ggarray::lfvector::LfVector;
use ggarray::insertion::InsertionKind;
use ggarray::runtime::{ArtifactManifest, Executor};
use ggarray::sim::clock::Clock;
use ggarray::sim::memory::VramHeap;
use ggarray::sim::spec::DeviceSpec;
use ggarray::util::benchkit::{black_box, BenchConfig, BenchSuite};
use ggarray::util::json::{self, Json};
use ggarray::util::rng::Rng;
use ggarray::workload::synth_f32;

/// Elements per steady-state measurement (the issue's 1e6 f32).
const ELEMENTS: usize = 1_000_000;
/// Dispatch batch size (ELEMENTS / BATCHES values per batch).
const BATCHES: usize = 20;
/// Regression gate: fail when steady-state insert dispatch is slower
/// than baseline × (1 + GATE_TOLERANCE).
const GATE_TOLERANCE: f64 = 0.25;

fn repo_root() -> PathBuf {
    // cargo runs bench binaries with cwd = the package root (rust/);
    // the workspace root is one level up.
    std::env::var("CARGO_MANIFEST_DIR")
        .map(|m| PathBuf::from(m).join(".."))
        .unwrap_or_else(|_| PathBuf::from("."))
}

fn build_shards(shard_count: usize, blocks_total: usize) -> Vec<Shard> {
    (0..shard_count)
        .map(|id| {
            Shard::new(ShardConfig {
                id,
                blocks: blocks_total / shard_count,
                first_bucket_size: 1024,
                insertion: InsertionKind::WarpScan,
                device: DeviceSpec::a100(),
                heap_bytes: 1 << 33,
            })
        })
        .collect()
}

/// Steady-state insert dispatch: 1e6 f32 per iteration through the
/// scratch-arena path (route → shard ranges → bulk placement), after a
/// 1e6-element warm-up so buckets and arena buffers are hot. Returns the
/// mean µs per 1e6 elements.
fn bench_insert_dispatch(suite: &mut BenchSuite, shard_count: usize) -> f64 {
    let blocks_total = 512;
    let bps = blocks_total / shard_count;
    let mut shards = build_shards(shard_count, blocks_total);
    let mut scratch = DispatchScratch::new();
    let batch: Vec<f32> = (0..(ELEMENTS / BATCHES) as u64).map(synth_f32).collect();
    let mut seq = 0u64;
    for _ in 0..BATCHES {
        dispatch_insert(&mut shards, bps, Policy::Even, seq, &batch, &mut scratch);
        seq += 1;
    }
    let result = suite.bench(
        &format!("insert dispatch 1e6 f32 ({shard_count} shard{})", if shard_count == 1 { "" } else { "s" }),
        || {
            for _ in 0..BATCHES {
                black_box(dispatch_insert(&mut shards, bps, Policy::Even, seq, &batch, &mut scratch));
                seq += 1;
            }
        },
    );
    result.mean_us()
}

/// Seal (pooled cross-shard gather + epoch commit) and sealed queries
/// through the running coordinator service. Returns
/// `(seal_us, query_1k_us)` means.
fn bench_seal_and_query(suite: &mut BenchSuite, shard_count: usize, samples: usize) -> (f64, f64) {
    let chunk = ELEMENTS / BATCHES;
    let c = Coordinator::start(CoordinatorConfig {
        blocks: 512,
        shards: shard_count,
        use_artifacts: false,
        batch: BatchConfig { max_values: chunk, max_delay: Duration::from_secs(3600) },
        // Segment hygiene off: each sample times exactly one epoch's
        // gather, not an occasional compaction pass.
        compact_segments: 0,
        ..CoordinatorConfig::default()
    });
    let mut counter = 0u64;
    let mut seal_samples = Vec::with_capacity(samples);
    for _ in 0..samples {
        for _ in 0..BATCHES {
            let values: Vec<f32> = (counter..counter + chunk as u64).map(synth_f32).collect();
            counter += chunk as u64;
            c.call(Request::Insert { values });
        }
        let t0 = Instant::now();
        match c.call(Request::Seal) {
            Response::Sealed { epoch_len, .. } => assert_eq!(epoch_len, ELEMENTS as u64),
            other => panic!("seal failed: {other:?}"),
        }
        seal_samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let seal_us = suite
        .record_samples(
            &format!("seal+flatten 1e6 f32 ({shard_count} shard{})", if shard_count == 1 { "" } else { "s" }),
            &seal_samples,
        )
        .mean_us();

    // Sealed queries: 1k random reads over the sealed prefix per sample.
    let sealed_len = (samples * ELEMENTS) as u64;
    let mut rng = Rng::new(0xBE7C);
    let mut query_samples = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..1000 {
            let idx = rng.below(sealed_len);
            match c.call(Request::Query { index: idx }) {
                Response::Value(Some(_)) => {}
                other => panic!("sealed query({idx}) failed: {other:?}"),
            }
        }
        query_samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let query_us = suite
        .record_samples(
            &format!("sealed query ×1k ({shard_count} shard{})", if shard_count == 1 { "" } else { "s" }),
            &query_samples,
        )
        .mean_us();
    c.shutdown();
    (seal_us, query_us)
}

/// Compare fresh steady-state numbers against the committed baseline;
/// returns the failure messages (empty = gate passes).
fn gate_against_baseline(baseline: &Json, fresh: &Json) -> Vec<String> {
    let mut failures = Vec::new();
    for shard_key in ["1", "4"] {
        let old = baseline.get("shards").and_then(|s| s.get(shard_key)).and_then(|s| s.get("insert_dispatch_us")).and_then(Json::as_f64);
        let new = fresh.get("shards").and_then(|s| s.get(shard_key)).and_then(|s| s.get("insert_dispatch_us")).and_then(Json::as_f64);
        match (old, new) {
            (Some(old), Some(new)) if new > old * (1.0 + GATE_TOLERANCE) => failures.push(format!(
                "insert dispatch ({shard_key} shard) regressed: {new:.0} µs vs baseline {old:.0} µs (>{:.0}%)",
                GATE_TOLERANCE * 100.0
            )),
            _ => {}
        }
    }
    failures
}

fn micro_benches(spec: &DeviceSpec) {
    let mut suite = BenchSuite::new("hotpath micro — request-path operations");
    suite.banner();

    // --- LFVector bulk append (1e6 u32) ---
    let data: Vec<u32> = (0..1_000_000u32).collect();
    suite.bench("lfvector push_back_bulk 1e6 u32", || {
        let mut heap = VramHeap::new(spec.clone());
        let mut clock = Clock::new();
        let mut v: LfVector<u32> = LfVector::new(1024);
        black_box(v.push_back_bulk(&data, &mut heap, &mut clock).unwrap());
    });

    // --- GGArray insert (512 blocks) ---
    suite.bench("ggarray insert_bulk 1e6 u32 (512 blocks)", || {
        let mut gg: GgArray<u32> = GgArray::new(GgConfig::new(512), spec.clone());
        black_box(gg.insert_bulk(&data, InsertionKind::WarpScan).unwrap());
    });

    // --- rw_b over 1e6 ---
    let mut gg: GgArray<u32> = GgArray::new(GgConfig::new(512), spec.clone());
    gg.insert_bulk(&data, InsertionKind::WarpScan).unwrap();
    suite.bench("ggarray rw_b 1e6 (+1)", || {
        black_box(gg.read_write_block(30.0, |x| *x = x.wrapping_add(1)));
    });

    // --- flatten 1e6 (collecting) vs pooled destination ---
    suite.bench("ggarray flatten 1e6", || {
        black_box(flatten(&mut gg).unwrap());
    });
    let mut pool: Vec<u32> = Vec::new();
    suite.bench("ggarray flatten_into 1e6 (pooled)", || {
        pool.clear();
        black_box(ggarray::ggarray::flatten::flatten_into(&mut gg, &mut pool).unwrap());
    });

    // --- prefix index lookups ---
    let mut idx = PrefixIndex::new();
    idx.rebuild((0..512).map(|_| 2000u64));
    let mut rng = Rng::new(3);
    let probes: Vec<u64> = (0..10_000).map(|_| rng.below(512 * 2000)).collect();
    suite.bench("prefix locate x10k (512 blocks)", || {
        for &p in &probes {
            black_box(idx.locate(p));
        }
    });

    // --- router: collecting vs scratch-arena ---
    let sizes: Vec<u64> = (0..512).map(|i| (i * 37) as u64 % 5000).collect();
    for policy in [Policy::Even, Policy::LeastLoaded, Policy::Hash] {
        suite.bench(&format!("route 1e5 into 512 blocks ({})", policy.name()), || {
            black_box(router::route(policy, &sizes, 100_000, 42));
        });
        let mut scratch = DispatchScratch::new();
        scratch.sizes.extend_from_slice(&sizes);
        suite.bench(&format!("route_into 1e5, 512 blocks ({})", policy.name()), || {
            black_box(scratch.route(policy, 100_000, 42));
        });
    }

    // --- PJRT execution (the real AOT kernels) ---
    if ArtifactManifest::available() {
        let exec = Executor::from_default_dir().unwrap();
        exec.warm_up().unwrap();
        let counts: Vec<i32> = vec![3; 1024];
        suite.bench("pjrt scan_warp_i32_1024 execute", || {
            black_box(exec.run_i32("scan_warp_i32_1024", &[&counts], 1024).unwrap());
        });
        let xs: Vec<f32> = vec![1.0; 16384];
        suite.bench("pjrt work_f32_16384 execute", || {
            black_box(exec.run_f32("work_f32_16384", &[&xs], 16384).unwrap());
        });
        if exec.manifest().get("scan_mxu_i32_1024").is_some() {
            suite.bench("pjrt scan_mxu_i32_1024 execute", || {
                black_box(exec.run_i32("scan_mxu_i32_1024", &[&counts], 1024).unwrap());
            });
        }
    } else {
        eprintln!("  (artifacts missing — PJRT benches skipped; run `make artifacts`)");
    }

    std::fs::create_dir_all("reports").unwrap();
    std::fs::write("reports/bench_hotpath.md", suite.markdown()).unwrap();
    eprintln!("wrote reports/bench_hotpath.md");
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let spec = DeviceSpec::a100();

    // Steady-state coordinator sections (always run; these feed the
    // BENCH_hotpath.json trajectory and the regression gate).
    let mut suite = BenchSuite::new(if smoke {
        "hotpath steady-state (smoke) — scratch-arena dispatch, pooled seal, sealed query"
    } else {
        "hotpath steady-state — scratch-arena dispatch, pooled seal, sealed query"
    })
    .with_config(BenchConfig {
        warmup_iters: 1,
        min_iters: if smoke { 2 } else { 8 },
        min_time: Duration::ZERO,
        max_iters: if smoke { 2 } else { 8 },
    });
    suite.banner();

    let seal_samples = if smoke { 2 } else { 5 };
    let mut shard_sections = Vec::new();
    for shard_count in [1usize, 4] {
        let insert_us = bench_insert_dispatch(&mut suite, shard_count);
        let (seal_us, query_us) = bench_seal_and_query(&mut suite, shard_count, seal_samples);
        shard_sections.push((
            shard_count.to_string(),
            Json::obj(vec![
                ("insert_dispatch_us", Json::num(insert_us)),
                ("seal_us", Json::num(seal_us)),
                ("sealed_query_1k_us", Json::num(query_us)),
            ]),
        ));
    }

    let fresh = Json::obj(vec![
        ("schema", Json::str("bench_hotpath/v1")),
        ("smoke", Json::Bool(smoke)),
        ("elements", Json::num(ELEMENTS as f64)),
        ("shards", Json::Obj(shard_sections.into_iter().collect())),
    ]);

    // Gate against the committed baseline before any write.
    let path = repo_root().join("BENCH_hotpath.json");
    let gate_enabled = std::env::var("GG_BENCH_GATE").map(|v| v != "off").unwrap_or(true);
    let mut baseline_exists = true;
    let failures = match std::fs::read_to_string(&path) {
        Ok(text) => match json::parse(&text) {
            Ok(baseline) => gate_against_baseline(&baseline, &fresh),
            Err(e) => {
                eprintln!("baseline {path:?} unparsable ({e}); skipping gate");
                Vec::new()
            }
        },
        Err(_) => {
            eprintln!("no baseline at {path:?} (first run) — gate skipped");
            baseline_exists = false;
            Vec::new()
        }
    };

    // Full runs re-baseline; smoke runs only bootstrap a missing file.
    // Overwriting the committed baseline with 2-iteration smoke numbers
    // on every ci.sh run would make the gate compare against noise (and
    // leave the work tree dirty, inviting an accidental commit).
    if !smoke || !baseline_exists {
        std::fs::write(&path, fresh.to_string_pretty()).expect("write BENCH_hotpath.json");
        eprintln!("wrote {}", path.display());
    } else {
        eprintln!("smoke run: committed baseline {} left intact", path.display());
    }

    if !failures.is_empty() {
        for f in &failures {
            eprintln!("REGRESSION: {f}");
        }
        if gate_enabled {
            eprintln!("bench_hotpath: wall-clock gate FAILED (set GG_BENCH_GATE=off to bypass)");
            std::process::exit(1);
        }
        eprintln!("bench_hotpath: regressions reported but GG_BENCH_GATE=off");
    }

    if !smoke {
        micro_benches(&spec);
    }
}
