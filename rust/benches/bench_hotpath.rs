//! §Perf hot-path microbenches: the *real* (wall-clock) cost of every
//! operation on the request path — LFVector appends, routing, prefix
//! lookups, rw passes, flatten, and PJRT execution. These are the numbers
//! the performance pass optimises; before/after lands in EXPERIMENTS.md.
//! Run: `cargo bench --bench bench_hotpath`

use ggarray::coordinator::router::{self, Policy};
use ggarray::ggarray::array::{GgArray, GgConfig};
use ggarray::ggarray::flatten::flatten;
use ggarray::ggarray::index::PrefixIndex;
use ggarray::ggarray::lfvector::LfVector;
use ggarray::insertion::InsertionKind;
use ggarray::runtime::{ArtifactManifest, Executor};
use ggarray::sim::clock::Clock;
use ggarray::sim::memory::VramHeap;
use ggarray::sim::spec::DeviceSpec;
use ggarray::util::benchkit::{black_box, BenchSuite};
use ggarray::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("hotpath — real wall-clock of the request-path operations");
    suite.banner();
    let spec = DeviceSpec::a100();

    // --- LFVector bulk append (1e6 u32) ---
    let data: Vec<u32> = (0..1_000_000u32).collect();
    suite.bench("lfvector push_back_bulk 1e6 u32", || {
        let mut heap = VramHeap::new(spec.clone());
        let mut clock = Clock::new();
        let mut v: LfVector<u32> = LfVector::new(1024);
        black_box(v.push_back_bulk(&data, &mut heap, &mut clock).unwrap());
    });

    // --- GGArray insert (512 blocks) ---
    suite.bench("ggarray insert_bulk 1e6 u32 (512 blocks)", || {
        let mut gg: GgArray<u32> = GgArray::new(GgConfig::new(512), spec.clone());
        black_box(gg.insert_bulk(&data, InsertionKind::WarpScan).unwrap());
    });

    // --- rw_b over 1e6 ---
    let mut gg: GgArray<u32> = GgArray::new(GgConfig::new(512), spec.clone());
    gg.insert_bulk(&data, InsertionKind::WarpScan).unwrap();
    suite.bench("ggarray rw_b 1e6 (+1)", || {
        black_box(gg.read_write_block(30.0, |x| *x = x.wrapping_add(1)));
    });

    // --- flatten 1e6 ---
    suite.bench("ggarray flatten 1e6", || {
        black_box(flatten(&mut gg).unwrap());
    });

    // --- prefix index lookups ---
    let mut idx = PrefixIndex::new();
    idx.rebuild((0..512).map(|_| 2000u64));
    let mut rng = Rng::new(3);
    let probes: Vec<u64> = (0..10_000).map(|_| rng.below(512 * 2000)).collect();
    suite.bench("prefix locate x10k (512 blocks)", || {
        for &p in &probes {
            black_box(idx.locate(p));
        }
    });

    // --- router ---
    let sizes: Vec<u64> = (0..512).map(|i| (i * 37) as u64 % 5000).collect();
    for policy in [Policy::Even, Policy::LeastLoaded, Policy::Hash] {
        suite.bench(&format!("route 1e5 into 512 blocks ({})", policy.name()), || {
            black_box(router::route(policy, &sizes, 100_000, 42));
        });
    }

    // --- PJRT execution (the real AOT kernels) ---
    if ArtifactManifest::available() {
        let exec = Executor::from_default_dir().unwrap();
        exec.warm_up().unwrap();
        let counts: Vec<i32> = vec![3; 1024];
        suite.bench("pjrt scan_warp_i32_1024 execute", || {
            black_box(exec.run_i32("scan_warp_i32_1024", &[&counts], 1024).unwrap());
        });
        let xs: Vec<f32> = vec![1.0; 16384];
        suite.bench("pjrt work_f32_16384 execute", || {
            black_box(exec.run_f32("work_f32_16384", &[&xs], 16384).unwrap());
        });
        if exec.manifest().get("scan_mxu_i32_1024").is_some() {
            suite.bench("pjrt scan_mxu_i32_1024 execute", || {
                black_box(exec.run_i32("scan_mxu_i32_1024", &[&counts], 1024).unwrap());
            });
        }
    } else {
        eprintln!("  (artifacts missing — PJRT benches skipped; run `make artifacts`)");
    }

    std::fs::create_dir_all("reports").unwrap();
    std::fs::write("reports/bench_hotpath.md", suite.markdown()).unwrap();
    eprintln!("wrote reports/bench_hotpath.md");
}
