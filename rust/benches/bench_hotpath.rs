//! §Perf hot-path benches: the *real* (wall-clock) cost of the request
//! path — steady-state insert dispatch through the scratch arena (serial
//! and through the work-stealing scheduler), the scheduled seal/flatten
//! gather, sealed queries, and the underlying micro-operations (LFVector
//! appends, routing, prefix lookups, rw passes, PJRT execution).
//!
//! Emits `BENCH_hotpath.json` (schema `bench_hotpath/v3`) at the **repo
//! root** so the perf trajectory is recorded PR over PR, and exits
//! non-zero when any of the gates fail (all skipped gracefully when no
//! v3 baseline exists, all bypassable with `GG_BENCH_GATE=off`):
//!
//! * steady-state insert dispatch regressed > [`GATE_TOLERANCE`] vs the
//!   committed baseline (1-shard serial, 4-shard scheduled, and the
//!   skewed 4-shard scheduled row);
//! * scheduled-seal *median* regressed > [`GATE_TOLERANCE`] (4 shards);
//! * measured 4-shard-scheduled-vs-1-shard-serial insert-dispatch
//!   speedup for the large-batch steady-state run is ≤ 1.0 (absolute,
//!   needs no baseline);
//! * the skewed-routing case (one hot shard holding 3/4 of every batch)
//!   fails to beat [`FORKJOIN_SKEW_BOUND`] — the old fork/join pool's
//!   max-shard barrier bound, which the work-stealing scheduler exists
//!   to break (absolute, needs no baseline, ≥ 4 cores);
//! * the skewed scheduled run records **zero steals** in the scheduler's
//!   ledger — wall-clock can pass by luck on a fast machine, but a zero
//!   steal count means the work-stealing path is not engaging at all
//!   (absolute, needs no baseline or parallelism: an empty-deque worker
//!   steals under time-slicing too).
//!
//! See EXPERIMENTS.md §Perf for the field definitions and how to
//! re-baseline (v1/v2 baselines measured a different executor and are
//! treated as absent and rewritten).
//!
//! Run: `cargo bench --bench bench_hotpath` (full) or
//!      `cargo bench --bench bench_hotpath -- --smoke` (CI smoke: fewer
//!      iterations, micro benches skipped).

use std::path::PathBuf;
use std::time::{Duration, Instant};

use ggarray::coordinator::batcher::BatchConfig;
use ggarray::coordinator::scheduler::Scheduler;
use ggarray::coordinator::request::{Request, Response};
use ggarray::coordinator::router::{self, DispatchScratch, Policy};
use ggarray::coordinator::service::{
    dispatch_insert, dispatch_insert_pooled, Coordinator, CoordinatorConfig,
};
use ggarray::coordinator::shard::{Shard, ShardConfig};
use ggarray::ggarray::array::{GgArray, GgConfig};
use ggarray::ggarray::flatten::flatten;
use ggarray::ggarray::index::PrefixIndex;
use ggarray::ggarray::lfvector::LfVector;
use ggarray::insertion::InsertionKind;
use ggarray::runtime::{ArtifactManifest, Executor};
use ggarray::sim::clock::Clock;
use ggarray::sim::memory::VramHeap;
use ggarray::sim::spec::DeviceSpec;
use ggarray::util::benchkit::{black_box, BenchConfig, BenchSuite};
use ggarray::util::benchreport::{
    self, shard_field, speedup_field, HotpathShardRow, HotpathSpeedup, HOTPATH_SCHEMA,
};
use ggarray::util::json::{self, Json};
use ggarray::util::rng::Rng;
use ggarray::workload::synth_f32;

/// Elements per steady-state measurement (the issue's 1e6 f32).
const ELEMENTS: usize = 1_000_000;
/// Dispatch batch size for the service-shaped runs (ELEMENTS / BATCHES
/// values per batch).
const BATCHES: usize = 20;
/// Batch size of the large-batch speedup run: big enough that per-shard
/// copy work dominates the scheduler's monitor handoff, which is the
/// regime the worker group is for (the service-shaped 50k batches are
/// also measured, but the tentpole gate reads this one).
const LARGE_BATCH: usize = 250_000;
/// Regression gate: fail when a gated metric is slower than
/// baseline × (1 + GATE_TOLERANCE).
const GATE_TOLERANCE: f64 = 0.25;
/// Hot-shard share of every batch in the skewed-routing case: shard 0
/// receives `SKEW_HOT_NUM / SKEW_HOT_DEN` of the values (the regime a
/// Hash-policy remainder run produces when it lands inside one shard,
/// scaled up to a measurable batch).
const SKEW_HOT_NUM: usize = 3;
const SKEW_HOT_DEN: usize = 4;
/// The old fork/join pool's best possible skewed speedup: it paid the
/// hot shard's whole copy serially at its barrier, so with 3/4 of the
/// batch on one shard it could never beat serial by more than
/// 1 / (3/4) = 4/3 regardless of executor count. The work-stealing
/// scheduler splits the hot shard into stealable block runs and must
/// clear this bound.
const FORKJOIN_SKEW_BOUND: f64 = SKEW_HOT_DEN as f64 / SKEW_HOT_NUM as f64;

fn repo_root() -> PathBuf {
    // cargo runs bench binaries with cwd = the package root (rust/);
    // the workspace root is one level up.
    std::env::var("CARGO_MANIFEST_DIR")
        .map(|m| PathBuf::from(m).join(".."))
        .unwrap_or_else(|_| PathBuf::from("."))
}

fn build_shards(shard_count: usize, blocks_total: usize) -> Vec<Shard> {
    (0..shard_count)
        .map(|id| {
            Shard::new(ShardConfig {
                id,
                blocks: blocks_total / shard_count,
                first_bucket_size: 1024,
                insertion: InsertionKind::WarpScan,
                device: DeviceSpec::a100(),
                heap_bytes: 1 << 33,
            })
        })
        .collect()
}

/// Steady-state insert dispatch of `ELEMENTS` f32 per iteration through
/// the scratch-arena path (route → shard ranges → bulk placement),
/// serial or through the persistent work-stealing scheduler, after a
/// full warm-up iteration so buckets, arena buffers and worker deques
/// are hot. Returns `(mean_us, median_us)` per `ELEMENTS` elements.
fn bench_insert_dispatch(
    suite: &mut BenchSuite,
    shard_count: usize,
    sched: Option<&Scheduler>,
    batch_elems: usize,
    label: &str,
) -> (f64, f64) {
    let blocks_total = 512;
    let bps = blocks_total / shard_count;
    let mut shards = build_shards(shard_count, blocks_total);
    let mut scratch = DispatchScratch::new();
    let batch: Vec<f32> = (0..batch_elems as u64).map(synth_f32).collect();
    let batches_per_iter = ELEMENTS / batch_elems;
    let mut seq = 0u64;
    let mut run = |shards: &mut Vec<Shard>, scratch: &mut DispatchScratch, seq: &mut u64| {
        for _ in 0..batches_per_iter {
            match sched {
                Some(sched) => {
                    black_box(dispatch_insert_pooled(
                        sched, shards, bps, Policy::Even, *seq, &batch, scratch,
                    ));
                }
                None => {
                    black_box(dispatch_insert(shards, bps, Policy::Even, *seq, &batch, scratch));
                }
            }
            *seq += 1;
        }
    };
    run(&mut shards, &mut scratch, &mut seq); // warm-up
    let result = suite.bench(label, || run(&mut shards, &mut scratch, &mut seq));
    (result.mean_us(), result.summary.p50)
}

/// Skewed-routing steady state: shard 0 receives [`SKEW_HOT_NUM`]/
/// [`SKEW_HOT_DEN`] of every `LARGE_BATCH`-element batch, the rest is
/// spread evenly over the cold shards. The per-block counts are built
/// by hand once (a Hash remainder run produces exactly this shape —
/// one contiguous hot run of blocks — but only at sub-block-count
/// batch sizes, so the bench scales it to a measurable batch), and the
/// serial and scheduled runs consume the *identical* pre-routed
/// scratch: the measured ratio isolates the executor. The old
/// fork/join pool sat at its barrier for the hot shard's entire copy
/// ([`FORKJOIN_SKEW_BOUND`]); the scheduler carves the hot shard into
/// chunk-sized block runs that every worker steals. Returns
/// `(mean_us, median_us)` per `ELEMENTS` elements.
fn bench_skewed_insert(suite: &mut BenchSuite, sched: Option<&Scheduler>, label: &str) -> (f64, f64) {
    let shard_count = 4;
    let blocks_total = 512;
    let bps = blocks_total / shard_count;
    let mut shards = build_shards(shard_count, blocks_total);
    let mut scratch = DispatchScratch::new();
    let batch: Vec<f32> = (0..LARGE_BATCH as u64).map(synth_f32).collect();
    let batches_per_iter = ELEMENTS / LARGE_BATCH;
    // Hand-routed skew: the hot shard's blocks carry SKEW_HOT of the
    // batch, the cold blocks split the rest; remainders land on the
    // first blocks of each region so sum(counts) == LARGE_BATCH holds
    // exactly (the conservation contract dispatch relies on).
    let hot = LARGE_BATCH * SKEW_HOT_NUM / SKEW_HOT_DEN;
    let cold = LARGE_BATCH - hot;
    let cold_blocks = blocks_total - bps;
    scratch.counts.clear();
    for i in 0..blocks_total {
        scratch.counts.push(if i < bps {
            hot / bps + usize::from(i < hot % bps)
        } else {
            let j = i - bps;
            cold / cold_blocks + usize::from(j < cold % cold_blocks)
        });
    }
    scratch.split_for_shards(bps);
    let mut run = |shards: &mut Vec<Shard>, scratch: &DispatchScratch| {
        for _ in 0..batches_per_iter {
            match sched {
                Some(sched) => {
                    black_box(sched.run_insert(shards, bps, &batch, scratch));
                }
                None => {
                    // The serial dispatch loop on the same fixed routing.
                    for (k, shard) in shards.iter_mut().enumerate() {
                        let (off, take) = scratch.ranges[k];
                        black_box(
                            shard.apply_counts(scratch.shard_counts(k, bps), &batch[off..off + take]),
                        );
                    }
                }
            }
        }
    };
    run(&mut shards, &scratch); // warm-up
    let result = suite.bench(label, || run(&mut shards, &scratch));
    (result.mean_us(), result.summary.p50)
}

/// Seal (cross-shard gather + epoch commit — through the work-stealing
/// scheduler when `executor_threads > 1`, which now names the *worker*
/// count directly) and sealed queries through the running coordinator
/// service. Returns `(seal_mean_us, seal_median_us, query_1k_mean_us)`.
fn bench_seal_and_query(
    suite: &mut BenchSuite,
    shard_count: usize,
    executor_threads: usize,
    samples: usize,
) -> (f64, f64, f64) {
    let chunk = ELEMENTS / BATCHES;
    let c = Coordinator::start(CoordinatorConfig {
        blocks: 512,
        shards: shard_count,
        use_artifacts: false,
        executor_threads,
        batch: BatchConfig { max_values: chunk, max_delay: Duration::from_secs(3600) },
        // Segment hygiene off: each sample times exactly one epoch's
        // gather, not an occasional compaction pass.
        compact_segments: 0,
        ..CoordinatorConfig::default()
    });
    let mode = if executor_threads > 1 { "scheduled" } else { "serial" };
    let mut counter = 0u64;
    let mut seal_samples = Vec::with_capacity(samples);
    for _ in 0..samples {
        for _ in 0..BATCHES {
            let values: Vec<f32> = (counter..counter + chunk as u64).map(synth_f32).collect();
            counter += chunk as u64;
            c.call(Request::Insert { values });
        }
        let t0 = Instant::now();
        match c.call(Request::Seal) {
            Response::Sealed { epoch_len, .. } => assert_eq!(epoch_len, ELEMENTS as u64),
            other => panic!("seal failed: {other:?}"),
        }
        seal_samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let seal = suite.record_samples(
        &format!(
            "seal+flatten 1e6 f32 ({shard_count} shard{}, {mode})",
            if shard_count == 1 { "" } else { "s" }
        ),
        &seal_samples,
    );
    let (seal_us, seal_median_us) = (seal.mean_us(), seal.summary.p50);

    // Sealed queries: 1k random reads over the sealed prefix per sample.
    let sealed_len = (samples * ELEMENTS) as u64;
    let mut rng = Rng::new(0xBE7C);
    let mut query_samples = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..1000 {
            let idx = rng.below(sealed_len);
            match c.call(Request::Query { index: idx }) {
                Response::Value(Some(_)) => {}
                other => panic!("sealed query({idx}) failed: {other:?}"),
            }
        }
        query_samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let query_us = suite
        .record_samples(
            &format!("sealed query ×1k ({shard_count} shard{})", if shard_count == 1 { "" } else { "s" }),
            &query_samples,
        )
        .mean_us();
    c.shutdown();
    (seal_us, seal_median_us, query_us)
}

/// Compare fresh steady-state numbers against the committed baseline and
/// apply the absolute speedup gate; returns the failure messages (empty
/// = all gates pass).
fn gate_results(baseline: Option<&Json>, fresh: &Json) -> Vec<String> {
    let mut failures = Vec::new();
    // The writer and this gate share the benchreport accessors, and the
    // build → serialize → parse → extract round trip is unit-tested in
    // util::benchreport (the nesting can no longer drift silently).
    let lookup = shard_field;
    if let Some(baseline) = baseline {
        // Regression gates: insert dispatch (both shard counts) and the
        // pooled-seal median (4 shards).
        for (shard_key, field, what) in [
            ("1", "insert_dispatch_us", "insert dispatch (1 shard, serial)"),
            ("4", "insert_dispatch_us", "insert dispatch (4 shards, scheduled)"),
            ("4", "skewed_insert_dispatch_us", "skewed insert dispatch (4 shards, scheduled)"),
            ("4", "seal_us_median", "scheduled-seal median (4 shards)"),
        ] {
            match (lookup(baseline, shard_key, field), lookup(fresh, shard_key, field)) {
                (Some(old), Some(new)) if new > old * (1.0 + GATE_TOLERANCE) => {
                    failures.push(format!(
                        "{what} regressed: {new:.0} µs vs baseline {old:.0} µs (>{:.0}%)",
                        GATE_TOLERANCE * 100.0
                    ));
                }
                _ => {}
            }
        }
    }
    // Absolute tentpole gate, baseline or not: the pooled 4-shard
    // executor must beat 1-shard serial wall-clock for large-batch
    // steady-state insert dispatch. Only meaningful where the host can
    // actually run shards in parallel — on a single-core runner the 4
    // executors time-slice one core and lose to serial by pure handoff
    // overhead with fully correct code, so the gate demotes to a notice
    // there instead of failing CI.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if let Some(speedup) = speedup_field(fresh, "insert_dispatch_large_batch_4v1") {
        if speedup <= 1.0 {
            if cores >= 2 {
                failures.push(format!(
                    "measured insert-dispatch speedup (4-shard scheduled vs 1-shard serial, \
                     {LARGE_BATCH}-element batches) is {speedup:.2}× on {cores} cores — the \
                     scheduler must beat serial wall-clock (> 1.0×)"
                ));
            } else {
                eprintln!(
                    "NOTE: measured insert-dispatch speedup {speedup:.2}× ≤ 1.0, but only \
                     {cores} core(s) available — parallel speedup is physically impossible \
                     here; gate skipped"
                );
            }
        }
    }
    // The work-stealing payoff gate: under skewed routing the old
    // fork/join pool was capped at FORKJOIN_SKEW_BOUND (it paid the hot
    // shard's whole copy at its barrier). The scheduler steals the hot
    // shard's chunks across all workers, so it must clear that bound —
    // anything at or below it means the hot-shard barrier penalty is
    // back. Needs ≥ 4 cores to be meaningful (fewer cores cap the
    // achievable speedup near the bound itself), so it demotes to a
    // notice on small runners.
    if let Some(speedup) = speedup_field(fresh, "skewed_insert_4v1") {
        if speedup <= FORKJOIN_SKEW_BOUND {
            if cores >= 4 {
                failures.push(format!(
                    "measured skewed insert-dispatch speedup ({SKEW_HOT_NUM}/{SKEW_HOT_DEN}-hot \
                     shard, 4 workers vs serial) is {speedup:.2}× on {cores} cores — the \
                     work-stealing scheduler must beat the fork/join max-shard bound \
                     ({FORKJOIN_SKEW_BOUND:.2}×)"
                ));
            } else {
                eprintln!(
                    "NOTE: measured skewed insert-dispatch speedup {speedup:.2}× ≤ \
                     {FORKJOIN_SKEW_BOUND:.2}× bound, but only {cores} core(s) available — \
                     clearing the fork/join bound needs real 4-way parallelism; gate skipped"
                );
            }
        }
    }
    failures
}

fn micro_benches(spec: &DeviceSpec) {
    let mut suite = BenchSuite::new("hotpath micro — request-path operations");
    suite.banner();

    // --- LFVector bulk append (1e6 u32) ---
    let data: Vec<u32> = (0..1_000_000u32).collect();
    suite.bench("lfvector push_back_bulk 1e6 u32", || {
        let mut heap = VramHeap::new(spec.clone());
        let mut clock = Clock::new();
        let mut v: LfVector<u32> = LfVector::new(1024);
        black_box(v.push_back_bulk(&data, &mut heap, &mut clock).unwrap());
    });

    // --- GGArray insert (512 blocks) ---
    suite.bench("ggarray insert_bulk 1e6 u32 (512 blocks)", || {
        let mut gg: GgArray<u32> = GgArray::new(GgConfig::new(512), spec.clone());
        black_box(gg.insert_bulk(&data, InsertionKind::WarpScan).unwrap());
    });

    // --- rw_b over 1e6 ---
    let mut gg: GgArray<u32> = GgArray::new(GgConfig::new(512), spec.clone());
    gg.insert_bulk(&data, InsertionKind::WarpScan).unwrap();
    suite.bench("ggarray rw_b 1e6 (+1)", || {
        black_box(gg.read_write_block(30.0, |x| *x = x.wrapping_add(1)));
    });

    // --- flatten 1e6 (collecting) vs pooled destination ---
    suite.bench("ggarray flatten 1e6", || {
        black_box(flatten(&mut gg).unwrap());
    });
    let mut pool: Vec<u32> = Vec::new();
    suite.bench("ggarray flatten_into 1e6 (pooled)", || {
        pool.clear();
        black_box(ggarray::ggarray::flatten::flatten_into(&mut gg, &mut pool).unwrap());
    });

    // --- sealed-query index math (the locate shift path) ---
    let mut lf: LfVector<u32> = LfVector::new(1024);
    {
        let mut heap = VramHeap::new(spec.clone());
        let mut clock = Clock::new();
        lf.push_back_bulk(&data, &mut heap, &mut clock).unwrap();
    }
    let mut rng = Rng::new(7);
    let lf_probes: Vec<usize> = (0..10_000).map(|_| rng.below(1_000_000) as usize).collect();
    suite.bench("lfvector get x10k (shift locate)", || {
        for &p in &lf_probes {
            black_box(lf.get(p));
        }
    });

    // --- prefix index lookups ---
    let mut idx = PrefixIndex::new();
    idx.rebuild((0..512).map(|_| 2000u64));
    let mut rng = Rng::new(3);
    let probes: Vec<u64> = (0..10_000).map(|_| rng.below(512 * 2000)).collect();
    suite.bench("prefix locate x10k (512 blocks)", || {
        for &p in &probes {
            black_box(idx.locate(p));
        }
    });

    // --- router: collecting vs scratch-arena ---
    let sizes: Vec<u64> = (0..512).map(|i| (i * 37) as u64 % 5000).collect();
    for policy in [Policy::Even, Policy::LeastLoaded, Policy::Hash] {
        suite.bench(&format!("route 1e5 into 512 blocks ({})", policy.name()), || {
            black_box(router::route(policy, &sizes, 100_000, 42));
        });
        let mut scratch = DispatchScratch::new();
        scratch.sizes.extend_from_slice(&sizes);
        suite.bench(&format!("route_into 1e5, 512 blocks ({})", policy.name()), || {
            black_box(scratch.route(policy, 100_000, 42));
        });
    }

    // --- PJRT execution (the real AOT kernels) ---
    if ArtifactManifest::available() {
        let exec = Executor::from_default_dir().unwrap();
        exec.warm_up().unwrap();
        let counts: Vec<i32> = vec![3; 1024];
        suite.bench("pjrt scan_warp_i32_1024 execute", || {
            black_box(exec.run_i32("scan_warp_i32_1024", &[&counts], 1024).unwrap());
        });
        let xs: Vec<f32> = vec![1.0; 16384];
        suite.bench("pjrt work_f32_16384 execute", || {
            black_box(exec.run_f32("work_f32_16384", &[&xs], 16384).unwrap());
        });
        if exec.manifest().get("scan_mxu_i32_1024").is_some() {
            suite.bench("pjrt scan_mxu_i32_1024 execute", || {
                black_box(exec.run_i32("scan_mxu_i32_1024", &[&counts], 1024).unwrap());
            });
        }
    } else {
        eprintln!("  (artifacts missing — PJRT benches skipped; run `make artifacts`)");
    }

    std::fs::create_dir_all("reports").unwrap();
    std::fs::write("reports/bench_hotpath.md", suite.markdown()).unwrap();
    eprintln!("wrote reports/bench_hotpath.md");
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let spec = DeviceSpec::a100();

    // Steady-state coordinator sections (always run; these feed the
    // BENCH_hotpath.json trajectory and the gates).
    let mut suite = BenchSuite::new(if smoke {
        "hotpath steady-state (smoke) — scratch-arena dispatch, work-stealing scheduler, scheduled seal, sealed query"
    } else {
        "hotpath steady-state — scratch-arena dispatch, work-stealing scheduler, scheduled seal, sealed query"
    })
    .with_config(BenchConfig {
        warmup_iters: 1,
        min_iters: if smoke { 3 } else { 8 },
        min_time: Duration::ZERO,
        max_iters: if smoke { 3 } else { 8 },
    });
    suite.banner();

    let seal_samples = if smoke { 3 } else { 5 };
    let chunk = ELEMENTS / BATCHES;

    // 1 shard: serial (a 1-worker scheduler would only add handoff
    // latency).
    let (insert1, _) =
        bench_insert_dispatch(&mut suite, 1, None, chunk, "insert dispatch 1e6 f32 (1 shard, serial)");
    let (seal1, seal1_median, query1) = bench_seal_and_query(&mut suite, 1, 1, seal_samples);

    // 4 shards: the production default (scheduled), plus the serial
    // loop at the same shard count so the scheduler's own win is
    // visible in one file.
    let (insert4_serial, _) = bench_insert_dispatch(
        &mut suite,
        4,
        None,
        chunk,
        "insert dispatch 1e6 f32 (4 shards, serial)",
    );
    let sched4 = Scheduler::new(4);
    let (insert4, _) = bench_insert_dispatch(
        &mut suite,
        4,
        Some(&sched4),
        chunk,
        "insert dispatch 1e6 f32 (4 shards, scheduled)",
    );
    let (seal4, seal4_median, query4) = bench_seal_and_query(&mut suite, 4, 4, seal_samples);

    // Large-batch steady-state speedup run: the tentpole measurement.
    // Per-shard sub-batches are ~62k elements here, so the fan-out copy
    // work dominates the monitor handoff and the measured speedup
    // reflects the shard parallelism.
    let (_, large1_median) = bench_insert_dispatch(
        &mut suite,
        1,
        None,
        LARGE_BATCH,
        "insert dispatch 1e6 f32, 250k batches (1 shard, serial)",
    );
    let (_, large4_median) = bench_insert_dispatch(
        &mut suite,
        4,
        Some(&sched4),
        LARGE_BATCH,
        "insert dispatch 1e6 f32, 250k batches (4 shards, scheduled)",
    );

    // Skewed routing: the work-stealing payoff case. Same fixed
    // 3/4-hot-shard routing for both runs; the old fork/join pool was
    // capped at FORKJOIN_SKEW_BOUND here.
    let (skew_serial, skew_serial_median) = bench_skewed_insert(
        &mut suite,
        None,
        "skewed insert dispatch 1e6 f32, 3/4-hot shard (4 shards, serial)",
    );
    let steals_before_skew = sched4.counters().steals;
    let (skew_sched, skew_sched_median) = bench_skewed_insert(
        &mut suite,
        Some(&sched4),
        "skewed insert dispatch 1e6 f32, 3/4-hot shard (4 shards, scheduled)",
    );
    let skew_steals = sched4.counters().steals - steals_before_skew;
    drop(sched4);

    let insert_speedup = large1_median / large4_median;
    let skewed_speedup = skew_serial_median / skew_sched_median;
    let seal_speedup = seal1_median / seal4_median;
    eprintln!(
        "  measured 4v1 speedup: insert dispatch {insert_speedup:.2}× (large batches, medians), \
         skewed {skewed_speedup:.2}× (fork/join bound {FORKJOIN_SKEW_BOUND:.2}×), \
         seal {seal_speedup:.2}× — sim model predicts up to 4×"
    );

    let fresh = benchreport::hotpath_report(
        smoke,
        ELEMENTS,
        &[
            HotpathShardRow {
                shards: 1,
                insert_dispatch_us: insert1,
                insert_dispatch_serial_us: None,
                skewed_insert_dispatch_us: None,
                skewed_insert_serial_us: None,
                seal_us: seal1,
                seal_us_median: seal1_median,
                sealed_query_1k_us: query1,
            },
            HotpathShardRow {
                shards: 4,
                insert_dispatch_us: insert4,
                insert_dispatch_serial_us: Some(insert4_serial),
                skewed_insert_dispatch_us: Some(skew_sched),
                skewed_insert_serial_us: Some(skew_serial),
                seal_us: seal4,
                seal_us_median: seal4_median,
                sealed_query_1k_us: query4,
            },
        ],
        &HotpathSpeedup {
            batch_elements: LARGE_BATCH,
            insert_dispatch_large_batch_4v1: insert_speedup,
            skewed_insert_4v1: skewed_speedup,
            seal_4v1: seal_speedup,
        },
    );

    // Gate against the committed baseline before any write. A baseline
    // with a different schema (v1 pre-pool, v2 fork/join pool) measured
    // a different executor — treat it as absent and re-baseline.
    let path = repo_root().join("BENCH_hotpath.json");
    let gate_enabled = std::env::var("GG_BENCH_GATE").map(|v| v != "off").unwrap_or(true);
    let mut baseline_exists = true;
    let baseline = match std::fs::read_to_string(&path) {
        Ok(text) => match json::parse(&text) {
            Ok(b) if benchreport::schema_of(&b) == Some(HOTPATH_SCHEMA) => Some(b),
            Ok(b) => {
                eprintln!(
                    "baseline {path:?} has schema {:?} (want {HOTPATH_SCHEMA}); re-baselining, regression gate skipped",
                    benchreport::schema_of(&b)
                );
                baseline_exists = false;
                None
            }
            Err(e) => {
                eprintln!("baseline {path:?} unparsable ({e}); skipping regression gate");
                None
            }
        },
        Err(_) => {
            eprintln!("no baseline at {path:?} (first run) — regression gate skipped");
            baseline_exists = false;
            None
        }
    };
    let mut failures = gate_results(baseline.as_ref(), &fresh);
    // Steal-ledger gate: the skewed run only clears the fork/join bound
    // *because* idle workers steal the hot shard's chunks. A zero steal
    // count means the work-stealing path silently stopped engaging
    // (single-deque regression, chunk carving gone coarse, …) even when
    // wall-clock happens to pass on a fast machine. Stealing needs no
    // real parallelism — a worker whose own deque drains steals under
    // time-slicing too — so this holds on any core count.
    if skew_steals == 0 {
        failures.push(
            "skewed scheduled run recorded 0 steals in the scheduler ledger — \
             the work-stealing path is not engaging on the hot shard's chunks"
                .to_string(),
        );
    } else {
        eprintln!("  skewed scheduled run: {skew_steals} chunk steals (work-stealing engaged)");
    }

    // Full runs re-baseline; smoke runs only bootstrap a missing (or
    // schema-mismatched) file. Overwriting the committed baseline with
    // short smoke numbers on every ci.sh run would make the gate compare
    // against noise (and leave the work tree dirty, inviting an
    // accidental commit).
    if !smoke || !baseline_exists {
        std::fs::write(&path, fresh.to_string_pretty()).expect("write BENCH_hotpath.json");
        eprintln!("wrote {}", path.display());
    } else {
        eprintln!("smoke run: committed baseline {} left intact", path.display());
    }

    if !failures.is_empty() {
        for f in &failures {
            eprintln!("REGRESSION: {f}");
        }
        if gate_enabled {
            eprintln!("bench_hotpath: wall-clock gate FAILED (set GG_BENCH_GATE=off to bypass)");
            std::process::exit(1);
        }
        eprintln!("bench_hotpath: regressions reported but GG_BENCH_GATE=off");
    }

    if !smoke {
        micro_benches(&spec);
    }
}
