//! Sharded-coordinator benches: wall-clock request-path throughput vs
//! shard count, plus the modeled (simulated-GPU) cost split between the
//! sealed flat path and the unsealed GGArray path — now under the
//! parallel time model (critical path = max over concurrent shards;
//! `device_*` = aggregate device-seconds).
//!
//! This bench doubles as the CI gate for the parallel time model: it
//! *asserts* that 4-shard critical-path sim time beats 1-shard on the
//! insert-heavy scenario (the speedup the old sum-over-shards ledger
//! could never show), and that sealed work stays cheaper than unsealed.
//! Run: `cargo bench --bench bench_shards`

use std::time::Duration;

use ggarray::coordinator::batcher::BatchConfig;
use ggarray::coordinator::request::{Request, Response};
use ggarray::coordinator::service::{Coordinator, CoordinatorConfig};
use ggarray::util::benchkit::{black_box, BenchConfig, BenchSuite};

const TOTAL_BLOCKS: usize = 64;
const CHUNK: usize = 4096;
const INSERTS: usize = 1 << 17; // 131072 elements per iteration

fn config(shards: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        blocks: TOTAL_BLOCKS,
        shards,
        first_bucket_size: 64,
        use_artifacts: false,
        batch: BatchConfig { max_values: CHUNK, max_delay: Duration::from_millis(2) },
        ..CoordinatorConfig::default()
    }
}

fn insert_all(c: &Coordinator) {
    let mut sent = 0usize;
    while sent < INSERTS {
        let n = CHUNK.min(INSERTS - sent);
        let values: Vec<f32> = (sent..sent + n).map(|i| i as f32).collect();
        c.call(Request::Insert { values });
        sent += n;
    }
}

/// Insert-heavy scenario: drive the full stream, then read the insert
/// ledger — `(critical_path_ms, device_total_ms)`.
fn insert_heavy_sim(shards: usize) -> (f64, f64) {
    let c = Coordinator::start(config(shards));
    insert_all(&c);
    // Stats barriers pending batches itself.
    let snap = c.call(Request::Stats).expect_stats();
    c.shutdown();
    (snap.sim_insert_ms, snap.device_insert_ms)
}

fn main() {
    let mut suite = BenchSuite::new("shards — request path vs shard count, sealed vs unsealed work")
        .with_config(BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            min_time: Duration::from_millis(100),
            max_iters: 20,
        });
    suite.banner();

    // --- wall-clock: insert+seal pipeline per shard count ---
    for shards in [1usize, 2, 4, 8] {
        suite.bench(&format!("insert {INSERTS} + seal ({shards} shards)"), || {
            let c = Coordinator::start(config(shards));
            insert_all(&c);
            match c.call(Request::Seal) {
                Response::Sealed { epoch_len, .. } => assert_eq!(epoch_len, INSERTS as u64),
                other => panic!("{other:?}"),
            }
            black_box(c.call(Request::Stats));
            c.shutdown();
        });
    }

    // --- speedup API gate: None before any charged op, Some after ---
    {
        let c = Coordinator::start(config(4));
        let idle = c.call(Request::Stats).expect_stats();
        assert_eq!(
            idle.parallel_speedup(),
            None,
            "an idle ledger must report no speedup, not NaN"
        );
        insert_all(&c);
        let busy = c.call(Request::Stats).expect_stats();
        let speedup = busy.parallel_speedup().expect("charged ledger must report a speedup");
        assert!(speedup.is_finite() && speedup >= 1.0, "speedup {speedup}");
        suite.record("observed parallel speedup (4 shards) [×]", speedup);
        c.shutdown();
    }

    // --- modeled: insert-heavy critical path vs device total (CI gate) ---
    let (sim1, _) = insert_heavy_sim(1);
    suite.record("sim insert critical path (1 shard) [µs]", sim1 * 1e3);
    for shards in [2usize, 4, 8] {
        let (sim_s, dev_s) = insert_heavy_sim(shards);
        suite.record(&format!("sim insert critical path ({shards} shards) [µs]"), sim_s * 1e3);
        suite.record(
            &format!("sim insert speedup ({shards} shards) [×]"),
            sim1 / sim_s,
        );
        assert!(
            dev_s > sim_s,
            "{shards} shards: device total {dev_s} ms !> critical path {sim_s} ms"
        );
        if shards == 4 {
            // The ci.sh gate: multi-shard speedup must be visible in the
            // sim-time wall-model, not just in wall-clock.
            assert!(
                sim_s < sim1,
                "insert-heavy: 4-shard critical path {sim_s} ms !< 1-shard {sim1} ms"
            );
        }
    }

    // --- modeled: one work pass, unsealed vs sealed, per shard count ---
    for shards in [1usize, 4] {
        let c = Coordinator::start(config(shards));
        insert_all(&c);
        let unsealed_us = match c.call(Request::Work { calls: 1 }) {
            Response::Worked { sim_us, .. } => sim_us,
            other => panic!("{other:?}"),
        };
        let seal_us = match c.call(Request::Seal) {
            Response::Sealed { sim_us, .. } => sim_us,
            other => panic!("{other:?}"),
        };
        let sealed_us = match c.call(Request::Work { calls: 1 }) {
            Response::Worked { sim_us, .. } => sim_us,
            other => panic!("{other:?}"),
        };
        suite.record(&format!("sim work unsealed rw_b ({shards} shards)"), unsealed_us);
        suite.record(&format!("sim seal (flatten+concat, {shards} shards)"), seal_us);
        suite.record(&format!("sim work sealed flat ({shards} shards)"), sealed_us);
        assert!(
            sealed_us < unsealed_us,
            "{shards} shards: sealed {sealed_us} µs !< unsealed {unsealed_us} µs"
        );
        c.shutdown();
    }

    println!("\n{}", suite.markdown());
}
