//! Bench target for Table II: regenerates the table (modeled vs paper)
//! and asserts the fidelity bands hold — this bench doubles as a
//! regression gate on the cost-model calibration.
//! Run: `cargo bench --bench bench_table2`

use ggarray::experiments::table2;
use ggarray::util::benchkit::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("table2 — duplicate 5.12e8 elements, last iteration, A100 model");
    suite.banner();

    let rep = table2::run();
    rep.save(std::path::Path::new("reports")).expect("save table2");
    println!("{}", rep.markdown());

    let rows = rep.sections[0].table.rows().to_vec();
    for row in &rows {
        let name = &row[0];
        for (col, label) in [(1usize, "grow"), (2, "insert"), (3, "rw")] {
            if let Ok(ms) = row[col].parse::<f64>() {
                suite.record(&format!("{name} {label} (modeled ms→µs)"), ms * 1e3);
            }
        }
    }

    // Fidelity gate (mirrors the unit test so `cargo bench` alone also
    // validates calibration).
    let cell = |name: &str, col: usize| -> f64 {
        rows.iter().find(|r| r[0] == name).unwrap()[col].parse().unwrap_or(f64::NAN)
    };
    let checks = [
        ("static insert", cell("static", 2), 7.07),
        ("static rw", cell("static", 3), 6.27),
        ("memMap grow", cell("memMap", 1), 5.21),
        ("GGArray512 grow", cell("GGArray512", 1), 8.76),
        ("GGArray512 insert", cell("GGArray512", 2), 11.79),
        ("GGArray512 rw", cell("GGArray512", 3), 69.73),
        ("GGArray32 grow", cell("GGArray32", 1), 0.52),
        ("GGArray32 insert", cell("GGArray32", 2), 27.90),
    ];
    let mut worst: (f64, &str) = (0.0, "");
    for (name, model, paper) in checks {
        let rel = (model - paper).abs() / paper;
        if rel > worst.0 {
            worst = (rel, name);
        }
        assert!(rel < 0.35, "calibration drift: {name} modeled {model} vs paper {paper}");
    }
    eprintln!("calibration OK — worst relative error {:.1}% ({})", worst.0 * 100.0, worst.1);

    std::fs::create_dir_all("reports").unwrap();
    std::fs::write("reports/bench_table2.md", suite.markdown()).unwrap();
    eprintln!("wrote reports/bench_table2.md");
}
