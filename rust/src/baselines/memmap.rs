//! memMap baseline (paper §III.A.2, VMM variant; Fig 5 / Table II's
//! "memMap"): a semi-static array over the CUDA low-level virtual memory
//! management API. A large VA range is reserved once; growth maps new
//! physical pages into place — contiguous indexing, **no copy** — at the
//! cost of page-granular slack and a host-driven map call.

use crate::ggarray::array::OpReport;
use crate::insertion::{self, InsertionKind, InsertShape};
use crate::sim::clock::{Category, Clock, Phase};
use crate::sim::kernel::{self, KernelProfile};
use crate::sim::memory::OomError;
use crate::sim::spec::DeviceSpec;
use crate::sim::vmm::{PhysicalPool, VmmError, VmmRange};

use super::GrowableArray;

/// VMM-backed growable array.
#[derive(Debug)]
pub struct MemMapArray<T> {
    spec: DeviceSpec,
    pool: PhysicalPool,
    range: VmmRange,
    clock: Clock,
    data: Vec<T>,
    len: usize,
    capacity: usize,
    _marker: std::marker::PhantomData<T>,
}

fn vmm_to_oom(e: VmmError) -> OomError {
    match e {
        VmmError::PhysicalExhausted { need, available } => OomError {
            requested: need * 2 * 1024 * 1024,
            free: available * 2 * 1024 * 1024,
            capacity: 0,
        },
        VmmError::ReservationExhausted { need, reserved } => OomError { requested: need, free: 0, capacity: reserved },
        VmmError::BadShrink { .. } => OomError { requested: 0, free: 0, capacity: 0 },
    }
}

impl<T: Copy + Default> MemMapArray<T> {
    /// Reserve `va_bytes` of address space (the worst case the program
    /// will ever need — reservation is nearly free, only mapping costs).
    pub fn new(spec: DeviceSpec, va_bytes: u64) -> MemMapArray<T> {
        let mut clock = Clock::new();
        let pool = PhysicalPool::new(&spec);
        let range = VmmRange::reserve(&spec, va_bytes, &mut clock);
        MemMapArray {
            spec,
            pool,
            range,
            clock,
            data: Vec::new(),
            len: 0,
            capacity: 0,
            _marker: std::marker::PhantomData,
        }
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Physical bytes currently mapped (page granular).
    pub fn mapped_bytes(&self) -> u64 {
        self.range.mapped_bytes()
    }

    /// Page slack (mapped − used) — the VMM's fragmentation cost.
    pub fn page_slack(&self) -> u64 {
        self.range.mapped_bytes().saturating_sub((self.len * std::mem::size_of::<T>()) as u64)
    }

    pub fn peak_mapped_bytes(&self) -> u64 {
        self.pool.peak_bytes()
    }

    /// Grow to hold `target` elements, doubling like the paper's
    /// semi-static scheme (capacity *policy* is doubling; the *mechanism*
    /// is page mapping without copy).
    fn grow_to(&mut self, target: usize) -> Result<(), OomError> {
        if target <= self.capacity {
            return Ok(());
        }
        let elem = std::mem::size_of::<T>();
        let new_cap = target.max(self.capacity.max(1) * 2);
        // Host orchestrates the mapping call.
        self.clock.charge(Category::Host, self.spec.cost.host_sync_us);
        self.range
            .grow_to(&self.spec, &mut self.pool, (new_cap * elem) as u64, &mut self.clock)
            .map_err(vmm_to_oom)?;
        self.data.resize(new_cap, T::default());
        self.capacity = new_cap;
        Ok(())
    }
}

impl<T: Copy + Default> GrowableArray<T> for MemMapArray<T> {
    fn name(&self) -> &'static str {
        "memMap"
    }

    fn len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn allocated_bytes(&self) -> u64 {
        self.range.mapped_bytes()
    }

    fn grow_for(&mut self, extra: usize) -> Result<OpReport, OomError> {
        let phase = Phase::start(&self.clock);
        self.grow_to(self.len + extra)?;
        Ok(OpReport { us: phase.elapsed_us(&self.clock), buckets_allocated: 0, elements: extra as u64 })
    }

    fn insert_bulk(&mut self, values: &[T], kind: InsertionKind) -> Result<OpReport, OomError> {
        self.grow_to(self.len + values.len())?;
        let phase = Phase::start(&self.clock);
        self.data[self.len..self.len + values.len()].copy_from_slice(values);
        self.len += values.len();
        // Indexing is contiguous in VA space: insertion behaves exactly
        // like the static array's.
        let shape = InsertShape::static_array(
            &self.spec,
            values.len().max(self.len) as u64,
            values.len() as u64,
            std::mem::size_of::<T>() as u64,
        );
        kernel::launch(&self.spec, &mut self.clock, &insertion::profile(&self.spec, kind, &shape));
        Ok(OpReport { us: phase.elapsed_us(&self.clock), buckets_allocated: 0, elements: values.len() as u64 })
    }

    fn read_write(&mut self, flops_per_elem: f64, f: &mut dyn FnMut(&mut T)) -> OpReport {
        let phase = Phase::start(&self.clock);
        for v in &mut self.data[..self.len] {
            f(v);
        }
        let n = self.len as f64;
        let elem = std::mem::size_of::<T>() as f64;
        // Slight TLB pressure vs a dense cudaMalloc region is negligible:
        // VA-contiguous access is coalesced, same as static (Table II:
        // 6.28 vs 6.27 ms).
        let mut p = KernelProfile::streaming(
            crate::util::math::ceil_div(self.len.max(1) as u64, 1024),
            1024,
            2.0 * elem * n,
            self.spec.cost.coalesced_eff,
        );
        p.flops_fp32 = flops_per_elem * n;
        kernel::launch(&self.spec, &mut self.clock, &p);
        OpReport { us: phase.elapsed_us(&self.clock), buckets_allocated: 0, elements: self.len as u64 }
    }

    fn get(&self, i: u64) -> Option<T> {
        if (i as usize) < self.len {
            Some(self.data[i as usize])
        } else {
            None
        }
    }

    fn elapsed_us(&self) -> f64 {
        self.clock.now_us()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grow_without_copy() {
        let spec = DeviceSpec::a100();
        let mut m: MemMapArray<u32> = MemMapArray::new(spec, 1 << 30);
        m.insert_bulk(&(0..1000u32).collect::<Vec<_>>(), InsertionKind::WarpScan).unwrap();
        let t0 = m.elapsed_us();
        m.grow_for(1_000_000).unwrap();
        let grow_us = m.elapsed_us() - t0;
        // Mapping 2 pages (4 MiB for 1M u32 doubled) ≈ 2 × 5.1 µs + host
        // sync — far below any copy-based resize of 1M elements.
        assert!(grow_us < 100.0, "grow cost {grow_us} µs");
        // Data survived untouched.
        for i in 0..1000 {
            assert_eq!(m.get(i), Some(i as u32));
        }
    }

    #[test]
    fn page_slack_bounded_by_one_page() {
        let spec = DeviceSpec::a100();
        let page = spec.cost.vmm_page_bytes;
        let mut m: MemMapArray<u8> = MemMapArray::new(spec, 1 << 30);
        m.grow_for(100).unwrap();
        m.insert_bulk(&vec![1u8; 100], InsertionKind::WarpScan).unwrap();
        // capacity policy doubles, so slack = mapped − len·1B ≤ one page +
        // capacity surplus; mapped itself is page-granular.
        assert!(m.mapped_bytes() % page == 0);
        assert!(m.mapped_bytes() <= page);
    }

    #[test]
    fn reservation_exhaustion_is_oom() {
        let spec = DeviceSpec::a100();
        let mut m: MemMapArray<u64> = MemMapArray::new(spec, 1024 * 1024); // 1 MiB VA
        let err = m.grow_for(1_000_000).unwrap_err(); // needs 8 MB
        assert!(err.requested > 0);
    }

    #[test]
    fn doubling_policy() {
        let spec = DeviceSpec::a100();
        let mut m: MemMapArray<u32> = MemMapArray::new(spec, 1 << 30);
        m.insert_bulk(&vec![1u32; 10], InsertionKind::WarpScan).unwrap();
        let c1 = m.capacity();
        m.insert_bulk(&vec![1u32; c1], InsertionKind::WarpScan).unwrap();
        let c2 = m.capacity();
        assert!(c2 >= 2 * c1, "capacity must at least double: {c1} → {c2}");
    }
}
