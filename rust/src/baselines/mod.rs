//! Comparison structures from the paper (§III.A):
//!
//! * [`static_array`] — flat pre-allocated array; insertions in-kernel,
//!   no resize possible (must be provisioned for the worst case);
//! * [`semistatic`] — host-resized doubling array (allocate 2×, copy,
//!   free) — the classic `device_vector` pattern;
//! * [`memmap`] — semi-static over the CUDA virtual-memory-management
//!   API: VA reserved once, physical pages mapped on growth, **no copy**
//!   (Perry & Sakharnykh 2020). The paper's strongest baseline.
//!
//! All three implement [`GrowableArray`] so experiments can sweep
//! structures uniformly.

pub mod memmap;
pub mod semistatic;
pub mod static_array;

use crate::ggarray::array::OpReport;
use crate::insertion::InsertionKind;
use crate::sim::memory::OomError;

/// Uniform interface over the comparison structures (and implemented by
/// `GgArray` wrappers in the experiment harness).
pub trait GrowableArray<T: Copy + Default> {
    /// Structure name for reports ("static", "memMap", …).
    fn name(&self) -> &'static str;

    /// Live elements.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Allocated element slots.
    fn capacity(&self) -> usize;

    /// Bytes of (simulated) VRAM held.
    fn allocated_bytes(&self) -> u64;

    /// Grow phase: make room for `extra` more elements. Static arrays
    /// return an error if `extra` exceeds the pre-allocated capacity.
    fn grow_for(&mut self, extra: usize) -> Result<OpReport, OomError>;

    /// Insertion phase: append `values` with algorithm `kind`.
    fn insert_bulk(&mut self, values: &[T], kind: InsertionKind) -> Result<OpReport, OomError>;

    /// Work phase: apply `f` to every element (`flops_per_elem` is the
    /// modeled ALU work, e.g. 30 for the paper's +1×30 op).
    fn read_write(&mut self, flops_per_elem: f64, f: &mut dyn FnMut(&mut T)) -> OpReport;

    /// Read element `i`.
    fn get(&self, i: u64) -> Option<T>;

    /// Simulated time consumed so far (µs).
    fn elapsed_us(&self) -> f64;
}

#[cfg(test)]
mod tests {
    use super::memmap::MemMapArray;
    use super::semistatic::SemiStaticArray;
    use super::static_array::StaticArray;
    use super::*;
    use crate::sim::spec::DeviceSpec;

    /// All baselines must agree on data semantics with each other.
    #[test]
    fn baselines_agree_on_contents() {
        let spec = DeviceSpec::a100();
        let mut structures: Vec<Box<dyn GrowableArray<u32>>> = vec![
            Box::new(StaticArray::new(spec.clone(), 10_000)),
            Box::new(SemiStaticArray::new(spec.clone(), 64)),
            Box::new(MemMapArray::new(spec.clone(), 1 << 20)),
        ];
        let chunk1: Vec<u32> = (0..1000).collect();
        let chunk2: Vec<u32> = (1000..2500).collect();
        for s in structures.iter_mut() {
            s.grow_for(chunk1.len()).unwrap();
            s.insert_bulk(&chunk1, InsertionKind::WarpScan).unwrap();
            s.grow_for(chunk2.len()).unwrap();
            s.insert_bulk(&chunk2, InsertionKind::WarpScan).unwrap();
            s.read_write(30.0, &mut |x| *x += 1);
        }
        for i in 0..2500u64 {
            let want = i as u32 + 1;
            for s in &structures {
                assert_eq!(s.get(i), Some(want), "{} at {i}", s.name());
            }
        }
        for s in &structures {
            assert_eq!(s.len(), 2500);
            assert_eq!(s.get(2500), None);
            assert!(s.elapsed_us() > 0.0);
        }
    }

    #[test]
    fn memmap_grow_cheaper_than_semistatic_at_scale() {
        // The VMM API's no-copy growth is the reason the paper uses it as
        // the semi-static representative.
        let spec = DeviceSpec::a100();
        let n = 4 << 20; // 4 Mi elements = 16 MiB
        let mut semi: SemiStaticArray<u32> = SemiStaticArray::new(spec.clone(), n);
        let mut mm: MemMapArray<u32> = MemMapArray::new(spec.clone(), 1 << 30);
        semi.insert_bulk(&vec![1u32; n], InsertionKind::WarpScan).unwrap();
        mm.insert_bulk(&vec![1u32; n], InsertionKind::WarpScan).unwrap();
        let t_semi = {
            let t0 = semi.elapsed_us();
            semi.grow_for(n).unwrap();
            semi.elapsed_us() - t0
        };
        let t_mm = {
            let t0 = mm.elapsed_us();
            mm.grow_for(n).unwrap();
            mm.elapsed_us() - t0
        };
        assert!(t_semi > t_mm, "semi {t_semi} !> memmap {t_mm}");
    }
}
