//! Semi-static baseline (paper §III.A.2, classic variant): a flat array
//! resized **from the host** with the doubling scheme — allocate a new
//! buffer of 2× capacity, copy all elements, free the old one. Every grow
//! pays a host synchronisation round-trip plus the full copy, and the peak
//! memory during a resize is `old + new = 3× the live data`.

use crate::ggarray::array::OpReport;
use crate::insertion::{self, InsertionKind, InsertShape};
use crate::sim::clock::{Category, Clock, Phase};
use crate::sim::kernel::{self, KernelProfile};
use crate::sim::memory::{AllocId, OomError, VramHeap};
use crate::sim::spec::DeviceSpec;

use super::GrowableArray;

/// Host-resized doubling array.
#[derive(Debug)]
pub struct SemiStaticArray<T> {
    spec: DeviceSpec,
    heap: VramHeap,
    clock: Clock,
    data: Vec<T>,
    len: usize,
    capacity: usize,
    alloc: AllocId,
    grows: u32,
}

impl<T: Copy + Default> SemiStaticArray<T> {
    /// Start with `initial_capacity` slots (must be ≥ 1).
    pub fn new(spec: DeviceSpec, initial_capacity: usize) -> SemiStaticArray<T> {
        let initial_capacity = initial_capacity.max(1);
        let mut heap = VramHeap::new(spec.clone());
        let mut clock = Clock::new();
        let alloc = heap
            .alloc((initial_capacity * std::mem::size_of::<T>()) as u64, &mut clock)
            .expect("initial capacity larger than device memory");
        SemiStaticArray {
            spec,
            heap,
            clock,
            data: vec![T::default(); initial_capacity],
            len: 0,
            capacity: initial_capacity,
            alloc,
            grows: 0,
        }
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Peak simulated VRAM (includes the transient 3× during copies).
    pub fn peak_bytes(&self) -> u64 {
        self.heap.peak()
    }

    pub fn grows(&self) -> u32 {
        self.grows
    }

    /// Double capacity until ≥ `target`, paying host sync + alloc + copy +
    /// free per doubling step (costs follow the real pattern: one
    /// host-initiated `cudaMalloc`+`cudaMemcpyDtoD`+`cudaFree` each).
    fn grow_to(&mut self, target: usize) -> Result<(), OomError> {
        while self.capacity < target {
            let new_cap = (self.capacity * 2).max(target.min(self.capacity * 2));
            // Host round-trip to orchestrate the resize.
            self.clock.charge(Category::Host, self.spec.cost.host_sync_us);
            let elem = std::mem::size_of::<T>();
            let new_alloc = self.heap.alloc((new_cap * elem) as u64, &mut self.clock)?;
            // Device-to-device copy of the live prefix.
            let copy_bytes = (self.len * elem) as f64;
            if copy_bytes > 0.0 {
                let profile = KernelProfile::streaming(
                    crate::util::math::ceil_div(self.len.max(1) as u64, 1024),
                    1024,
                    2.0 * copy_bytes, // read + write
                    self.spec.cost.coalesced_eff,
                );
                kernel::launch(&self.spec, &mut self.clock, &profile);
            }
            let old = std::mem::replace(&mut self.alloc, new_alloc);
            self.heap.free(old, &mut self.clock);
            let mut new_data = vec![T::default(); new_cap];
            new_data[..self.len].copy_from_slice(&self.data[..self.len]);
            self.data = new_data;
            self.capacity = new_cap;
            self.grows += 1;
        }
        Ok(())
    }
}

impl<T: Copy + Default> GrowableArray<T> for SemiStaticArray<T> {
    fn name(&self) -> &'static str {
        "semi-static"
    }

    fn len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn allocated_bytes(&self) -> u64 {
        (self.capacity * std::mem::size_of::<T>()) as u64
    }

    fn grow_for(&mut self, extra: usize) -> Result<OpReport, OomError> {
        let phase = Phase::start(&self.clock);
        self.grow_to(self.len + extra)?;
        Ok(OpReport { us: phase.elapsed_us(&self.clock), buckets_allocated: 0, elements: extra as u64 })
    }

    fn insert_bulk(&mut self, values: &[T], kind: InsertionKind) -> Result<OpReport, OomError> {
        self.grow_to(self.len + values.len())?;
        let phase = Phase::start(&self.clock);
        self.data[self.len..self.len + values.len()].copy_from_slice(values);
        self.len += values.len();
        let shape = InsertShape::static_array(
            &self.spec,
            values.len().max(self.len) as u64,
            values.len() as u64,
            std::mem::size_of::<T>() as u64,
        );
        kernel::launch(&self.spec, &mut self.clock, &insertion::profile(&self.spec, kind, &shape));
        Ok(OpReport { us: phase.elapsed_us(&self.clock), buckets_allocated: 0, elements: values.len() as u64 })
    }

    fn read_write(&mut self, flops_per_elem: f64, f: &mut dyn FnMut(&mut T)) -> OpReport {
        let phase = Phase::start(&self.clock);
        for v in &mut self.data[..self.len] {
            f(v);
        }
        let n = self.len as f64;
        let elem = std::mem::size_of::<T>() as f64;
        let profile = KernelProfile::streaming(
            crate::util::math::ceil_div(self.len.max(1) as u64, 1024),
            1024,
            2.0 * elem * n,
            self.spec.cost.coalesced_eff,
        );
        let mut p = profile;
        p.flops_fp32 = flops_per_elem * n;
        kernel::launch(&self.spec, &mut self.clock, &p);
        OpReport { us: phase.elapsed_us(&self.clock), buckets_allocated: 0, elements: self.len as u64 }
    }

    fn get(&self, i: u64) -> Option<T> {
        if (i as usize) < self.len {
            Some(self.data[i as usize])
        } else {
            None
        }
    }

    fn elapsed_us(&self) -> f64 {
        self.clock.now_us()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doubles_until_fit() {
        let mut s: SemiStaticArray<u32> = SemiStaticArray::new(DeviceSpec::a100(), 4);
        s.insert_bulk(&(0..100).collect::<Vec<_>>(), InsertionKind::WarpScan).unwrap();
        assert_eq!(s.len(), 100);
        assert!(s.capacity() >= 100);
        assert!(s.capacity() <= 256);
        assert!(s.grows() >= 5, "4→128 needs ≥5 doublings, got {}", s.grows());
        for i in 0..100 {
            assert_eq!(s.get(i), Some(i as u32));
        }
    }

    #[test]
    fn peak_memory_hits_3x_during_copy() {
        let spec = DeviceSpec::a100();
        let n = 1 << 16;
        let mut s: SemiStaticArray<u64> = SemiStaticArray::new(spec, n);
        s.insert_bulk(&vec![1u64; n], InsertionKind::WarpScan).unwrap();
        s.grow_for(1).unwrap(); // forces 2n alloc while n is live
        let peak = s.peak_bytes() as f64;
        let live = (n * 8) as f64;
        assert!(peak >= 2.9 * live, "peak {peak} vs live {live}");
    }

    #[test]
    fn grow_costs_scale_with_copy_size() {
        let spec = DeviceSpec::a100();
        let mut small: SemiStaticArray<u32> = SemiStaticArray::new(spec.clone(), 1 << 10);
        let mut large: SemiStaticArray<u32> = SemiStaticArray::new(spec, 1 << 22);
        small.insert_bulk(&vec![1; 1 << 10], InsertionKind::WarpScan).unwrap();
        large.insert_bulk(&vec![1; 1 << 22], InsertionKind::WarpScan).unwrap();
        let t_small = small.grow_for(1).unwrap().us;
        let t_large = large.grow_for(1).unwrap().us;
        assert!(t_large > t_small, "copy cost must grow: {t_small} vs {t_large}");
    }

    #[test]
    fn host_sync_charged_on_grow() {
        let mut s: SemiStaticArray<u32> = SemiStaticArray::new(DeviceSpec::a100(), 2);
        s.insert_bulk(&[1, 2, 3, 4, 5], InsertionKind::WarpScan).unwrap();
        assert!(s.clock().total(Category::Host) > 0.0);
    }
}
