//! Static baseline (paper §III.A.1): a flat array `cudaMalloc`ed once at
//! program start. In-kernel insertions work (parallel insertion algorithm
//! over a global size counter) but the capacity can never change — the
//! program must know the worst case up front or die with a segfault
//! (here: a simulated OOM).

use crate::ggarray::array::OpReport;
use crate::insertion::{self, InsertionKind, InsertShape};
use crate::sim::clock::{Clock, Phase};
use crate::sim::kernel::{self, KernelProfile};
use crate::sim::memory::{OomError, VramHeap};
use crate::sim::spec::DeviceSpec;

use super::GrowableArray;

/// Pre-allocated flat device array.
#[derive(Debug)]
pub struct StaticArray<T> {
    spec: DeviceSpec,
    heap: VramHeap,
    clock: Clock,
    data: Vec<T>,
    len: usize,
    capacity: usize,
}

impl<T: Copy + Default> StaticArray<T> {
    /// Allocate `capacity` slots up front.
    pub fn new(spec: DeviceSpec, capacity: usize) -> StaticArray<T> {
        let mut heap = VramHeap::new(spec.clone());
        let mut clock = Clock::new();
        heap.alloc((capacity * std::mem::size_of::<T>()) as u64, &mut clock)
            .expect("static array larger than device memory");
        StaticArray { spec, heap, clock, data: vec![T::default(); capacity], len: 0, capacity }
    }

    /// As [`new`](Self::new) but fallible (budget experiments).
    pub fn try_new(spec: DeviceSpec, capacity: usize, heap_capacity: u64) -> Result<StaticArray<T>, OomError> {
        let mut heap = VramHeap::with_capacity(spec.clone(), heap_capacity);
        let mut clock = Clock::new();
        heap.alloc((capacity * std::mem::size_of::<T>()) as u64, &mut clock)?;
        Ok(StaticArray { spec, heap, clock, data: vec![T::default(); capacity], len: 0, capacity })
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    pub fn spec(&self) -> &DeviceSpec {
        &self.spec
    }

    /// Peak simulated VRAM (= the full pre-allocation, by construction).
    pub fn peak_bytes(&self) -> u64 {
        self.heap.peak()
    }

    /// Direct slice access (flatten target, work-phase kernels).
    pub fn as_slice(&self) -> &[T] {
        &self.data[..self.len]
    }

    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.data[..self.len]
    }

    /// Adopt `values` wholesale (used as the flatten destination).
    pub fn fill_from(&mut self, values: &[T]) -> Result<(), OomError> {
        if values.len() > self.capacity {
            return Err(OomError {
                requested: (values.len() * std::mem::size_of::<T>()) as u64,
                free: ((self.capacity - self.len) * std::mem::size_of::<T>()) as u64,
                capacity: (self.capacity * std::mem::size_of::<T>()) as u64,
            });
        }
        self.data[..values.len()].copy_from_slice(values);
        self.len = values.len();
        Ok(())
    }
}

impl<T: Copy + Default> GrowableArray<T> for StaticArray<T> {
    fn name(&self) -> &'static str {
        "static"
    }

    fn len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn allocated_bytes(&self) -> u64 {
        (self.capacity * std::mem::size_of::<T>()) as u64
    }

    /// Static arrays cannot grow: succeeds as a no-op when capacity
    /// already suffices, otherwise reports the would-be segfault as OOM.
    fn grow_for(&mut self, extra: usize) -> Result<OpReport, OomError> {
        if self.len + extra <= self.capacity {
            Ok(OpReport::default())
        } else {
            Err(OomError {
                requested: (extra * std::mem::size_of::<T>()) as u64,
                free: ((self.capacity - self.len) * std::mem::size_of::<T>()) as u64,
                capacity: (self.capacity * std::mem::size_of::<T>()) as u64,
            })
        }
    }

    fn insert_bulk(&mut self, values: &[T], kind: InsertionKind) -> Result<OpReport, OomError> {
        self.grow_for(values.len())?;
        let phase = Phase::start(&self.clock);
        self.data[self.len..self.len + values.len()].copy_from_slice(values);
        self.len += values.len();
        let shape = InsertShape::static_array(
            &self.spec,
            values.len().max(self.len) as u64,
            values.len() as u64,
            std::mem::size_of::<T>() as u64,
        );
        kernel::launch(&self.spec, &mut self.clock, &insertion::profile(&self.spec, kind, &shape));
        Ok(OpReport { us: phase.elapsed_us(&self.clock), buckets_allocated: 0, elements: values.len() as u64 })
    }

    fn read_write(&mut self, flops_per_elem: f64, f: &mut dyn FnMut(&mut T)) -> OpReport {
        let phase = Phase::start(&self.clock);
        for v in &mut self.data[..self.len] {
            f(v);
        }
        let n = self.len as f64;
        let elem = std::mem::size_of::<T>() as f64;
        let profile = KernelProfile {
            blocks: crate::util::math::ceil_div(self.len.max(1) as u64, 1024),
            threads_per_block: 1024,
            bytes: 2.0 * elem * n,
            coalescing_eff: self.spec.cost.coalesced_eff,
            flops_fp32: flops_per_elem * n,
            flops_mxu: 0.0,
            mxu_utilisation: 1.0,
            per_block_us: 0.0,
            atomic_us: 0.0,
            extra_us: 0.0,
        };
        kernel::launch(&self.spec, &mut self.clock, &profile);
        OpReport { us: phase.elapsed_us(&self.clock), buckets_allocated: 0, elements: self.len as u64 }
    }

    fn get(&self, i: u64) -> Option<T> {
        if (i as usize) < self.len {
            Some(self.data[i as usize])
        } else {
            None
        }
    }

    fn elapsed_us(&self) -> f64 {
        self.clock.now_us()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_read() {
        let mut s: StaticArray<u32> = StaticArray::new(DeviceSpec::a100(), 100);
        s.insert_bulk(&[1, 2, 3], InsertionKind::Atomic).unwrap();
        s.insert_bulk(&[4, 5], InsertionKind::WarpScan).unwrap();
        assert_eq!(s.len(), 5);
        assert_eq!(s.get(0), Some(1));
        assert_eq!(s.get(4), Some(5));
        assert_eq!(s.get(5), None);
        assert_eq!(s.capacity(), 100);
    }

    #[test]
    fn overflow_is_simulated_segfault() {
        let mut s: StaticArray<u8> = StaticArray::new(DeviceSpec::a100(), 4);
        s.insert_bulk(&[1, 2, 3], InsertionKind::WarpScan).unwrap();
        assert!(s.insert_bulk(&[4, 5], InsertionKind::WarpScan).is_err());
        assert_eq!(s.len(), 3, "failed insert must not partially apply");
    }

    #[test]
    fn grow_is_noop_within_capacity() {
        let mut s: StaticArray<u64> = StaticArray::new(DeviceSpec::titan_rtx(), 10);
        let rep = s.grow_for(10).unwrap();
        assert_eq!(rep.us, 0.0);
        assert!(s.grow_for(11).is_err());
    }

    #[test]
    fn rw_applies_and_is_fast() {
        let mut s: StaticArray<u32> = StaticArray::new(DeviceSpec::a100(), 1 << 20);
        s.insert_bulk(&vec![10u32; 1 << 20], InsertionKind::WarpScan).unwrap();
        let rep = s.read_write(30.0, &mut |x| *x += 1);
        assert_eq!(s.get(0), Some(11));
        assert!(rep.us > 0.0);
    }

    #[test]
    fn fill_from_respects_capacity() {
        let mut s: StaticArray<u32> = StaticArray::new(DeviceSpec::a100(), 4);
        s.fill_from(&[9, 8, 7]).unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(s.as_slice(), &[9, 8, 7]);
        assert!(s.fill_from(&[0; 5]).is_err());
    }
}
