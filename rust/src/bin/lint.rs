//! Repo lint: the static-analysis gate for the concurrency-sensitive
//! parts of the crate (`cargo run --bin lint`; wired into ci.sh,
//! including `--quick`).
//!
//! Five textual rule classes over `src/**/*.rs`:
//!
//! * **U — unsafe hygiene**: every `unsafe {` block and `unsafe impl`
//!   must carry a `// SAFETY:` justification on the same line or in the
//!   contiguous comment block directly above it. (`unsafe fn`
//!   *declarations* are exempt — they document their contract with a
//!   `# Safety` doc section; the compiler's `unsafe_op_in_unsafe_fn`
//!   deny in lib.rs forces their bodies back through `unsafe {`
//!   blocks, which this rule does check.)
//! * **P — pointer provenance**: raw-pointer↔`usize` laundering
//!   (`ptr as usize`, integer `as *mut`) is rejected everywhere except
//!   the provenance-preserving wrapper `src/sync/sendptr.rs`. Crossing
//!   a thread boundary as an integer strips provenance and hides the
//!   aliasing contract from both the compiler and Miri — use
//!   `SendPtr`/`SendSlice`/`SendSliceMut`.
//! * **F — facade bypass**: `src/coordinator/**` must not name
//!   `std::sync`/`std::thread` directly — all synchronisation goes
//!   through the `crate::sync` facade so `--cfg ggcheck` can swap in
//!   the model checker. A direct import silently opts that state out
//!   of model checking.
//! * **A — hot-path allocation**: files listed in
//!   `hotpath_manifest.txt` (crate-root relative) must keep non-test
//!   code free of heap-allocating calls (`vec![`, `.to_vec()`,
//!   `format!(`, `String::from(`, `.to_string()`, `Box::new(`,
//!   `.to_owned()`) — the review-time twin of the alloc-counter test.
//! * **X — panic-prone lock/recv**: `src/coordinator/**` must not call
//!   bare `.unwrap()`/`.expect(` on a `.lock()` or `.recv(`-family
//!   result. A panicking worker poisons a bare-unwrapped mutex and the
//!   next lock attempt panics too, cascading one contained fault into a
//!   dead coordinator — go through `crate::sync::lock_recover` (data
//!   stays coherent: every monitor invariant is re-established before
//!   the panic can propagate) or match the recv error into a typed
//!   `ServiceDown`/`Closed`.
//!
//! Shared conventions: everything from the first `#[cfg(test)]` line to
//! end-of-file is skipped (the repo keeps test modules last);
//! `//`-comments are stripped before token matching (string literals
//! are tracked, block comments are not — keep `/* */` out of linted
//! code); a deliberate exception is waived inline with
//! `// lint: allow(alloc|ptr-cast|std-sync|unwrap) — <reason>`. This
//! file is excluded from its own walk (its rule tables would
//! self-match).
//!
//! Exit codes: 0 clean, 1 violations, 2 internal error.
//! `--self-test` seeds one violation of each rule class (plus clean,
//! waived and `#[cfg(test)]` twins) in a temp tree and asserts the
//! engine catches exactly the seeded set — proving a non-zero exit for
//! every class — then cleans up.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

#[derive(Debug, Clone, PartialEq, Eq)]
struct Violation {
    /// Crate-root-relative path, e.g. `src/coordinator/scheduler/mod.rs`.
    file: String,
    line: usize,
    rule: char,
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "lint: {}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

const ALLOC_TOKENS: &[&str] = &[
    "vec![",
    ".to_vec()",
    "format!(",
    "String::from(",
    ".to_string()",
    "Box::new(",
    ".to_owned()",
];

/// Strip a trailing `//` comment, tracking double-quoted string
/// literals so `"//"` inside a string survives. Returns the code part.
fn strip_line_comment(line: &str) -> &str {
    let bytes = line.as_bytes();
    let mut in_string = false;
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' if in_string => i += 1, // skip escaped char
            b'"' => in_string = !in_string,
            b'/' if !in_string && i + 1 < bytes.len() && bytes[i + 1] == b'/' => {
                return &line[..i];
            }
            _ => {}
        }
        i += 1;
    }
    line
}

/// `needle` present in `hay` with non-word characters (or edges) on
/// both sides.
fn word_match(hay: &str, needle: &str) -> Option<usize> {
    let is_word = |c: u8| c.is_ascii_alphanumeric() || c == b'_';
    let bytes = hay.as_bytes();
    let mut from = 0;
    while let Some(pos) = hay[from..].find(needle) {
        let at = from + pos;
        let before_ok = at == 0 || !is_word(bytes[at - 1]);
        let after = at + needle.len();
        let after_ok = after >= bytes.len() || !is_word(bytes[after]);
        if before_ok && after_ok {
            return Some(at);
        }
        from = at + needle.len().max(1);
    }
    None
}

fn has_waiver(raw_line: &str, class: &str) -> bool {
    raw_line.contains(&format!("lint: allow({class})"))
}

/// `// SAFETY:` on this raw line, or anywhere in the contiguous block
/// of comment lines directly above it.
fn has_adjacent_safety(raw_lines: &[&str], idx: usize) -> bool {
    if raw_lines[idx].contains("SAFETY:") {
        return true;
    }
    let mut i = idx;
    while i > 0 {
        i -= 1;
        let t = raw_lines[i].trim_start();
        if t.starts_with("//") {
            if t.contains("SAFETY:") {
                return true;
            }
        } else {
            break;
        }
    }
    false
}

/// Lint one file's contents. `rel` is crate-root relative with `/`
/// separators (e.g. `src/coordinator/scheduler/group.rs`).
fn lint_file(rel: &str, contents: &str, hot_manifest: &[String], out: &mut Vec<Violation>) {
    let raw_lines: Vec<&str> = contents.lines().collect();
    let in_coordinator = rel.starts_with("src/coordinator/");
    let is_hot = hot_manifest.iter().any(|m| m == rel);
    let ptr_whitelisted = rel == "src/sync/sendptr.rs";

    for (i, raw) in raw_lines.iter().enumerate() {
        if raw.trim() == "#[cfg(test)]" {
            break; // convention: test modules run to end-of-file
        }
        let code = strip_line_comment(raw);
        if code.trim().is_empty() {
            continue;
        }
        let lineno = i + 1;

        // U — unsafe blocks / impls need an adjacent SAFETY comment.
        if let Some(at) = word_match(code, "unsafe") {
            let rest = code[at + "unsafe".len()..].trim_start();
            let is_fn_decl = rest.starts_with("fn ") || rest.starts_with("fn<");
            if !is_fn_decl && !has_adjacent_safety(&raw_lines, i) {
                out.push(Violation {
                    file: rel.into(),
                    line: lineno,
                    rule: 'U',
                    msg: "`unsafe` without an adjacent `// SAFETY:` justification".into(),
                });
            }
        }

        // P — pointer-provenance laundering through usize.
        if !ptr_whitelisted && !has_waiver(raw, "ptr-cast") {
            let ptr_to_int = code.contains("as usize")
                && (code.contains("ptr")
                    || code.contains("*mut")
                    || code.contains("*const")
                    || code.contains(".add("));
            let int_to_ptr = (code.contains("as *mut") || code.contains("as *const"))
                && code.contains("usize");
            if ptr_to_int || int_to_ptr {
                out.push(Violation {
                    file: rel.into(),
                    line: lineno,
                    rule: 'P',
                    msg: "raw-pointer/usize cast outside sync::sendptr — use SendPtr/SendSlice"
                        .into(),
                });
            }
        }

        // F — coordinator must use the crate::sync facade.
        if in_coordinator
            && !has_waiver(raw, "std-sync")
            && (code.contains("std::sync") || code.contains("std::thread"))
        {
            out.push(Violation {
                file: rel.into(),
                line: lineno,
                rule: 'F',
                msg: "direct std::sync/std::thread in coordinator/ bypasses the crate::sync facade"
                    .into(),
            });
        }

        // A — no heap allocation in manifest-listed hot-path modules.
        if is_hot && !has_waiver(raw, "alloc") {
            if let Some(tok) = ALLOC_TOKENS.iter().find(|t| code.contains(**t)) {
                out.push(Violation {
                    file: rel.into(),
                    line: lineno,
                    rule: 'A',
                    msg: format!("heap-allocating `{tok}` in hot-path module (hotpath_manifest.txt)"),
                });
            }
        }

        // X — no bare unwrap/expect on lock/recv results in coordinator/.
        if in_coordinator
            && !has_waiver(raw, "unwrap")
            && (code.contains(".lock()") || code.contains(".recv("))
            && (code.contains(".unwrap()") || code.contains(".expect("))
        {
            out.push(Violation {
                file: rel.into(),
                line: lineno,
                rule: 'X',
                msg: "bare unwrap/expect on a lock/recv result in coordinator/ — use \
                      sync::lock_recover or match the error into a typed response"
                    .into(),
            });
        }
    }
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read_dir entry in {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            walk_rs(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn read_manifest(crate_root: &Path) -> Result<Vec<String>, String> {
    let path = crate_root.join("hotpath_manifest.txt");
    let text = fs::read_to_string(&path)
        .map_err(|e| format!("hot-path manifest {} unreadable: {e}", path.display()))?;
    let mut entries = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if !crate_root.join(line).is_file() {
            return Err(format!("hot-path manifest lists nonexistent file: {line}"));
        }
        entries.push(line.to_string());
    }
    Ok(entries)
}

/// Run every rule over `<crate_root>/src`, returning violations sorted
/// by (file, line).
fn run(crate_root: &Path) -> Result<Vec<Violation>, String> {
    let manifest = read_manifest(crate_root)?;
    let src = crate_root.join("src");
    let mut files = Vec::new();
    walk_rs(&src, &mut files)?;
    files.sort();
    let mut violations = Vec::new();
    for path in &files {
        let rel_os = path
            .strip_prefix(crate_root)
            .map_err(|_| format!("file {} escapes crate root", path.display()))?;
        let rel = rel_os.to_string_lossy().replace('\\', "/");
        if rel == "src/bin/lint.rs" {
            continue; // the lint's own rule tables would self-match
        }
        let contents = fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        lint_file(&rel, &contents, &manifest, &mut violations);
    }
    violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(violations)
}

fn exit_code_for(violations: &[Violation]) -> u8 {
    if violations.is_empty() {
        0
    } else {
        1
    }
}

// ---------------- self-test ----------------

/// Seed one violation of each rule class (plus clean / waived /
/// cfg(test) twins that must NOT fire), run the engine, and assert the
/// report matches exactly — including that the seeded tree's exit code
/// is non-zero. Files live in a temp tree that is removed afterwards.
fn self_test() -> Result<(), String> {
    let root = std::env::temp_dir().join(format!("gg-lint-selftest-{}", std::process::id()));
    let result = seed_and_check(&root);
    let _ = fs::remove_dir_all(&root); // best-effort cleanup either way
    result
}

fn write(root: &Path, rel: &str, contents: &str) -> Result<(), String> {
    let path = root.join(rel);
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent).map_err(|e| format!("mkdir {}: {e}", parent.display()))?;
    }
    fs::write(&path, contents).map_err(|e| format!("write {}: {e}", path.display()))
}

fn seed_and_check(root: &Path) -> Result<(), String> {
    // Manifest covers only hot.rs; bad_sync.rs proves rule F fires on
    // non-manifest coordinator files too.
    write(root, "hotpath_manifest.txt", "src/coordinator/hot.rs\n")?;

    // Rule A seed + waived twin + cfg(test)-skipped twin.
    write(
        root,
        "src/coordinator/hot.rs",
        concat!(
            "pub fn hot(n: usize) -> Vec<u8> {\n",
            "    let v = vec![0u8; n]; // seeded violation: rule A\n",
            "    v\n",
            "}\n",
            "pub fn cold() -> Vec<u8> {\n",
            "    vec![1u8] // lint: allow(alloc) — seeded waiver, must not fire\n",
            "}\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    pub fn in_tests() -> String { format!(\"skipped\") }\n",
            "}\n",
        ),
    )?;

    // Rule F seed (coordinator file naming std::sync directly).
    write(
        root,
        "src/coordinator/bad_sync.rs",
        "pub fn bypass() {\n    let _m = std::sync::Mutex::new(0u32); // seeded violation: rule F\n}\n",
    )?;

    // Rule X seed + waived and recover-idiom twins that must not fire
    // (named via the crate::sync facade so rule F stays out of the way).
    write(
        root,
        "src/coordinator/bad_unwrap.rs",
        concat!(
            "pub fn stuck(m: &crate::sync::Mutex<u32>) -> u32 {\n",
            "    *m.lock().unwrap() // seeded violation: rule X\n",
            "}\n",
            "pub fn waived(m: &crate::sync::Mutex<u32>) -> u32 {\n",
            "    *m.lock().unwrap() // lint: allow(unwrap) — seeded waiver, must not fire\n",
            "}\n",
            "pub fn recovered(m: &crate::sync::Mutex<u32>) -> u32 {\n",
            "    *m.lock().unwrap_or_else(|e| e.into_inner())\n",
            "}\n",
        ),
    )?;

    // Rule U seed + SAFETY-commented twin that must not fire.
    write(
        root,
        "src/bad_unsafe.rs",
        concat!(
            "pub fn naked(p: &mut u32) {\n",
            "    unsafe { std::ptr::write(p, 1) } // seeded violation: rule U\n",
            "}\n",
            "pub fn documented(p: &mut u32) {\n",
            "    // SAFETY: `p` is a live exclusive borrow, so the write\n",
            "    // is just `*p = 2` spelled with ptr::write.\n",
            "    unsafe { std::ptr::write(p, 2) }\n",
            "}\n",
        ),
    )?;

    // Rule P seed (and its SAFETY comment keeps rule U out of the way).
    write(
        root,
        "src/bad_cast.rs",
        "pub fn launder(ptr: *mut u8) -> usize {\n    ptr as usize // seeded violation: rule P\n}\n",
    )?;

    // A fully clean file: no rule may fire on it.
    write(
        root,
        "src/clean.rs",
        "pub fn add(a: u64, b: u64) -> u64 {\n    a.wrapping_add(b)\n}\n",
    )?;

    let violations = run(root)?;
    for v in &violations {
        println!("self-test observed: {v}");
    }

    let expected: &[(char, &str, usize)] = &[
        ('P', "src/bad_cast.rs", 2),
        ('U', "src/bad_unsafe.rs", 2),
        ('F', "src/coordinator/bad_sync.rs", 2),
        ('X', "src/coordinator/bad_unwrap.rs", 2),
        ('A', "src/coordinator/hot.rs", 2),
    ];
    if violations.len() != expected.len() {
        return Err(format!(
            "self-test: expected exactly {} violations (one per rule class), got {}",
            expected.len(),
            violations.len()
        ));
    }
    for (rule, file, line) in expected {
        let hit = violations
            .iter()
            .any(|v| v.rule == *rule && v.file == *file && v.line == *line);
        if !hit {
            return Err(format!("self-test: seeded rule-{rule} violation in {file}:{line} was not caught"));
        }
        println!("self-test: rule {rule} fires and exits non-zero");
    }
    if exit_code_for(&violations) == 0 {
        return Err("self-test: seeded tree must produce a non-zero exit code".into());
    }
    println!("lint self-test passed: all {} rule classes fire, twins stay clean", expected.len());
    Ok(())
}

// ---------------- entry ----------------

fn main() -> ExitCode {
    let self_test_mode = std::env::args().any(|a| a == "--self-test");
    if self_test_mode {
        return match self_test() {
            Ok(()) => ExitCode::from(0),
            Err(e) => {
                eprintln!("lint --self-test FAILED: {e}");
                ExitCode::from(1)
            }
        };
    }

    let crate_root = Path::new(env!("CARGO_MANIFEST_DIR"));
    match run(crate_root) {
        Ok(violations) => {
            for v in &violations {
                println!("{v}");
            }
            if violations.is_empty() {
                println!("lint: clean ({} rules over src/)", 5);
                ExitCode::from(0)
            } else {
                eprintln!("lint: {} violation(s)", violations.len());
                ExitCode::from(exit_code_for(&violations))
            }
        }
        Err(e) => {
            eprintln!("lint: internal error: {e}");
            ExitCode::from(2)
        }
    }
}
