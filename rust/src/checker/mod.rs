//! Bounded exhaustive-interleaving model checker for the coordinator's
//! concurrency protocols (vendor-free, in the spirit of `rust/vendor/`).
//!
//! The checker runs a *model* — a closure that spawns logical threads
//! through [`rt::spawn`] and synchronises through the [`crate::sync`]
//! facade — under a cooperative scheduler that serialises execution:
//! exactly one model thread runs at a time, and control returns to the
//! scheduler at every *yield point* (every lock, channel op, atomic op,
//! or explicit [`rt::yield_point`]). At each point where more than one
//! thread is runnable the scheduler records a decision, and a
//! depth-first search over those decisions enumerates every bounded
//! schedule. A failing schedule (assertion panic, deadlock, or step
//! budget exhaustion) is reported as a [`Failure`] carrying a compact
//! *schedule seed* (`"0.2.1"` — the dot-separated choice indices) which
//! [`replay`] re-executes deterministically.
//!
//! Model semantics (documented limitations):
//!
//! * **Sequential consistency only.** The facade's model atomics map
//!   every ordering to `SeqCst`; relaxed-memory reorderings are out of
//!   scope. The protocols under test (scheduler monitor, admission
//!   shed, barrier drain) are lock/channel based, where SeqCst is the
//!   intended contract.
//! * **Spurious wakeups are the norm.** `Condvar::notify_*` wakes every
//!   waiter; woken threads re-contend for the mutex and re-check their
//!   predicate. This is a sound superset of `std`, which also permits
//!   spurious wakeups — code that survives the model survives `std`.
//! * **Deadlock detection.** A state with unfinished threads and no
//!   runnable thread fails the schedule; lost-wakeup bugs surface here.
//! * **Scheduling decisions are only recorded when there is a real
//!   choice** (two or more runnable threads), so seeds stay compact and
//!   replay stays stable across engine-internal bookkeeping steps.
//!
//! The engine itself is plain safe `std` code compiled in every build
//! (its own unit tests run under tier-1); the instrumented sync
//! primitives that route onto [`rt`] live in `crate::sync::model` and
//! only compile under `--cfg ggcheck`. See `rust/tests/model_check.rs`
//! for the protocol suites and `EXPERIMENTS.md` §Analysis for the
//! matrix.

use std::cell::{Cell, RefCell};
use std::fmt;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver as StdReceiver, Sender as StdSender};
use std::sync::{Arc, Mutex as StdMutex, MutexGuard as StdMutexGuard};
use std::thread::JoinHandle;

// ------------------------------------------------------------------ API

/// Exploration budget for one [`check`] call.
#[derive(Debug, Clone)]
pub struct Config {
    /// Stop after this many schedules and report `complete: false`.
    pub max_schedules: usize,
    /// Fail a single schedule after this many scheduler steps
    /// (livelock guard — e.g. a spin loop that never blocks).
    pub max_steps_per_schedule: usize,
}

impl Default for Config {
    fn default() -> Config {
        Config { max_schedules: 100_000, max_steps_per_schedule: 10_000 }
    }
}

/// Summary of a completed (non-failing) exploration.
#[derive(Debug, Clone)]
pub struct Report {
    /// Number of distinct schedules executed.
    pub schedules: usize,
    /// True iff the DFS exhausted every schedule within budget.
    pub complete: bool,
    /// Deepest decision stack seen across all schedules.
    pub max_decisions: usize,
}

/// A failing schedule: what went wrong and how to re-run it.
#[derive(Debug, Clone)]
pub struct Failure {
    /// Model name passed to [`check`].
    pub name: String,
    /// Panic message, deadlock report, or budget overrun.
    pub message: String,
    /// The scheduling choices that led here (one entry per decision
    /// point with ≥ 2 runnable threads).
    pub schedule: Vec<usize>,
}

impl Failure {
    /// Compact replay seed: dot-separated decision indices, `"-"` for
    /// the empty (fully forced) schedule.
    pub fn seed(&self) -> String {
        if self.schedule.is_empty() {
            "-".to_string()
        } else {
            let parts: Vec<String> = self.schedule.iter().map(|c| c.to_string()).collect();
            parts.join(".")
        }
    }
}

impl fmt::Display for Failure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "model check '{}' failed: {}", self.name, self.message)?;
        writeln!(f, "  schedule seed: {}", self.seed())?;
        write!(
            f,
            "  replay: ggarray::checker::replay(\"{}\", \"{}\", <model>)",
            self.name,
            self.seed()
        )
    }
}

impl std::error::Error for Failure {}

/// Parse a seed printed by [`Failure::seed`] back into choice indices.
pub fn parse_seed(seed: &str) -> Result<Vec<usize>, String> {
    let trimmed = seed.trim();
    if trimmed.is_empty() || trimmed == "-" {
        return Ok(Vec::new());
    }
    trimmed
        .split('.')
        .map(|p| p.parse::<usize>().map_err(|e| format!("bad seed component '{p}': {e}")))
        .collect()
}

/// Exhaustively explore the model's bounded schedules. Returns the
/// exploration [`Report`] on success or the first failing schedule.
///
/// The model closure is invoked once per schedule and must construct
/// all of its state fresh on each call (the closure is the root model
/// thread; spawn more with [`rt::spawn`]).
pub fn check(
    name: &str,
    cfg: &Config,
    model: impl Fn() + Send + Sync + 'static,
) -> Result<Report, Failure> {
    let model: Arc<dyn Fn() + Send + Sync> = Arc::new(model);
    let mut script: Vec<usize> = Vec::new();
    let mut schedules = 0usize;
    let mut max_decisions = 0usize;
    loop {
        if schedules >= cfg.max_schedules {
            return Ok(Report { schedules, complete: false, max_decisions });
        }
        schedules += 1;
        match run_one(&model, &script, cfg.max_steps_per_schedule) {
            RunOutcome::Failed { message, schedule } => {
                return Err(Failure { name: name.to_string(), message, schedule });
            }
            RunOutcome::Done { decisions } => {
                max_decisions = max_decisions.max(decisions.len());
                // Backtrack to the deepest decision with an unexplored
                // sibling; absence means the DFS is exhausted.
                let mut next: Option<Vec<usize>> = None;
                for i in (0..decisions.len()).rev() {
                    let (chosen, alternatives) = decisions[i];
                    if chosen + 1 < alternatives {
                        let mut s: Vec<usize> =
                            decisions[..i].iter().map(|d| d.0).collect();
                        s.push(chosen + 1);
                        next = Some(s);
                        break;
                    }
                }
                match next {
                    Some(s) => script = s,
                    None => return Ok(Report { schedules, complete: true, max_decisions }),
                }
            }
        }
    }
}

/// [`check`] that panics with the full [`Failure`] display (seed
/// included) — the form the model-check tests use.
pub fn check_or_panic(name: &str, cfg: &Config, model: impl Fn() + Send + Sync + 'static) -> Report {
    match check(name, cfg, model) {
        Ok(report) => report,
        Err(failure) => panic!("{failure}"),
    }
}

/// Re-run one specific schedule from its printed seed. `Ok(())` means
/// the schedule no longer fails (e.g. after a fix); `Err` carries the
/// reproduced failure.
pub fn replay(
    name: &str,
    seed: &str,
    model: impl Fn() + Send + Sync + 'static,
) -> Result<(), Failure> {
    let script = match parse_seed(seed) {
        Ok(s) => s,
        Err(message) => {
            return Err(Failure { name: name.to_string(), message, schedule: Vec::new() })
        }
    };
    let model: Arc<dyn Fn() + Send + Sync> = Arc::new(model);
    match run_one(&model, &script, Config::default().max_steps_per_schedule) {
        RunOutcome::Done { .. } => Ok(()),
        RunOutcome::Failed { message, schedule } => {
            Err(Failure { name: name.to_string(), message, schedule })
        }
    }
}

// --------------------------------------------------------------- engine

/// Scheduler → model-thread step permit (or cancellation).
enum Go {
    Step,
    Cancel,
}

/// Panic payload used to unwind cancelled model threads without
/// tripping the panic hook (`resume_unwind` skips it by design).
struct CancelToken;

/// Model thread → scheduler notifications. `Yielded`/`Blocked`/
/// `Finished` all simply return control (the thread updated its own
/// phase first); `Panicked` carries the failure message.
enum Event {
    Yielded(usize),
    Blocked(usize),
    Finished(usize),
    Panicked(usize, String),
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum BlockOn {
    Mutex(usize),
    Resource(usize),
    Join(usize),
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Phase {
    Runnable,
    Blocked(BlockOn),
    Finished,
}

struct ThreadSlot {
    phase: Phase,
    go_tx: StdSender<Go>,
    handle: Option<JoinHandle<()>>,
}

struct State {
    threads: Vec<ThreadSlot>,
    /// `true` = held. Index is the id minted by [`rt::new_mutex`].
    mutexes: Vec<bool>,
    /// Wait-resource id counter (condvars, channels).
    next_resource: usize,
    event_tx: StdSender<Event>,
}

struct Execution {
    state: StdMutex<State>,
}

/// Poison-tolerant state lock: the engine never panics while holding
/// it, but a cancelled thread may have unwound through a frame that
/// did — tolerate rather than cascade.
fn lock_state(exec: &Execution) -> StdMutexGuard<'_, State> {
    exec.state.lock().unwrap_or_else(|e| e.into_inner())
}

struct Ctx {
    exec: Arc<Execution>,
    tid: usize,
    go_rx: StdReceiver<Go>,
    event_tx: StdSender<Event>,
}

thread_local! {
    static CTX: RefCell<Option<Ctx>> = RefCell::new(None);
    /// Set once this model thread has been handed [`Go::Cancel`]. From
    /// that point the thread is unwinding via [`CancelToken`]; rt calls
    /// reached from `Drop` impls during that unwind must neither block
    /// (the scheduler is no longer stepping us — a recv would hang the
    /// teardown join) nor panic (a second panic during unwind aborts),
    /// so they degrade to non-blocking no-ops.
    static CANCELLED: Cell<bool> = Cell::new(false);
}

fn with_ctx<R>(f: impl FnOnce(&Ctx) -> R) -> R {
    CTX.with(|c| {
        let b = c.borrow();
        let ctx = b
            .as_ref()
            .expect("checker rt call outside a model-checked execution (rt::active() was false)");
        f(ctx)
    })
}

/// Park until the scheduler grants the next step. Cancellation (or a
/// vanished scheduler) unwinds silently via [`CancelToken`].
fn wait_go(ctx: &Ctx) {
    match ctx.go_rx.recv() {
        Ok(Go::Step) => {}
        Ok(Go::Cancel) | Err(_) => {
            CANCELLED.with(|c| c.set(true));
            resume_unwind(Box::new(CancelToken));
        }
    }
}

fn wake_where(st: &mut State, on: BlockOn) {
    for t in &mut st.threads {
        if t.phase == Phase::Blocked(on) {
            t.phase = Phase::Runnable;
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "model thread panicked (non-string payload)".to_string()
    }
}

/// Register and start one model thread (used for the root thread and by
/// [`rt::spawn`]). The new OS thread parks in [`wait_go`] before
/// touching the model, preserving the one-runner-at-a-time invariant.
fn spawn_model_thread(exec: &Arc<Execution>, f: Box<dyn FnOnce() + Send + 'static>) -> usize {
    let (go_tx, go_rx) = channel::<Go>();
    let (tid, event_tx) = {
        let mut st = lock_state(exec);
        let tid = st.threads.len();
        st.threads.push(ThreadSlot { phase: Phase::Runnable, go_tx, handle: None });
        (tid, st.event_tx.clone())
    };
    let exec2 = Arc::clone(exec);
    let handle = std::thread::Builder::new()
        .name(format!("ggcheck-{tid}"))
        .spawn(move || {
            CTX.with(|c| {
                *c.borrow_mut() = Some(Ctx { exec: exec2, tid, go_rx, event_tx });
            });
            // First step permit: the spawner is still mid-step.
            with_ctx(wait_go);
            let result = catch_unwind(AssertUnwindSafe(f));
            match result {
                Ok(()) => {
                    with_ctx(|ctx| {
                        {
                            let mut st = lock_state(&ctx.exec);
                            st.threads[ctx.tid].phase = Phase::Finished;
                            wake_where(&mut st, BlockOn::Join(ctx.tid));
                        }
                        ctx.event_tx.send(Event::Finished(ctx.tid)).ok();
                    });
                }
                Err(payload) => {
                    if payload.downcast_ref::<CancelToken>().is_some() {
                        // Cancelled by the scheduler: exit silently,
                        // the scheduler is already joining us.
                    } else {
                        let msg = panic_message(payload.as_ref());
                        with_ctx(|ctx| {
                            {
                                let mut st = lock_state(&ctx.exec);
                                st.threads[ctx.tid].phase = Phase::Finished;
                                wake_where(&mut st, BlockOn::Join(ctx.tid));
                            }
                            ctx.event_tx.send(Event::Panicked(ctx.tid, msg)).ok();
                        });
                    }
                }
            }
        })
        .expect("spawn model-checker thread");
    {
        let mut st = lock_state(exec);
        st.threads[tid].handle = Some(handle);
    }
    tid
}

enum RunOutcome {
    Done { decisions: Vec<(usize, usize)> },
    Failed { message: String, schedule: Vec<usize> },
}

/// Execute one schedule. `script` forces the recorded decisions (DFS
/// prefix or replay seed); beyond it the scheduler defaults to choice 0.
fn run_one(model: &Arc<dyn Fn() + Send + Sync>, script: &[usize], max_steps: usize) -> RunOutcome {
    let (event_tx, event_rx) = channel::<Event>();
    let exec = Arc::new(Execution {
        state: StdMutex::new(State {
            threads: Vec::new(),
            mutexes: Vec::new(),
            next_resource: 0,
            event_tx,
        }),
    });
    let m = Arc::clone(model);
    spawn_model_thread(&exec, Box::new(move || m()));

    let mut decisions: Vec<(usize, usize)> = Vec::new();
    let mut steps = 0usize;
    let mut failure: Option<String> = None;

    loop {
        let runnable: Vec<usize> = {
            let st = lock_state(&exec);
            st.threads
                .iter()
                .enumerate()
                .filter(|(_, t)| t.phase == Phase::Runnable)
                .map(|(i, _)| i)
                .collect()
        };
        if runnable.is_empty() {
            let unfinished: Vec<usize> = {
                let st = lock_state(&exec);
                st.threads
                    .iter()
                    .enumerate()
                    .filter(|(_, t)| t.phase != Phase::Finished)
                    .map(|(i, _)| i)
                    .collect()
            };
            if !unfinished.is_empty() {
                failure = Some(format!(
                    "deadlock: threads {unfinished:?} blocked with no runnable thread"
                ));
            }
            break;
        }
        steps += 1;
        if steps > max_steps {
            failure =
                Some(format!("step budget exceeded ({max_steps} steps): possible livelock"));
            break;
        }
        let pick = if runnable.len() > 1 {
            let want = script.get(decisions.len()).copied().unwrap_or(0);
            if want >= runnable.len() {
                failure = Some(format!(
                    "schedule seed invalid at decision {} ({} runnable, seed wanted {})",
                    decisions.len(),
                    runnable.len(),
                    want
                ));
                break;
            }
            decisions.push((want, runnable.len()));
            want
        } else {
            0
        };
        let tid = runnable[pick];
        let go_tx = {
            let st = lock_state(&exec);
            st.threads[tid].go_tx.clone()
        };
        if go_tx.send(Go::Step).is_err() {
            failure = Some(format!("model thread {tid} exited without reporting an event"));
            break;
        }
        match event_rx.recv() {
            Ok(Event::Yielded(_)) | Ok(Event::Blocked(_)) | Ok(Event::Finished(_)) => {}
            Ok(Event::Panicked(_, msg)) => {
                failure = Some(msg);
                break;
            }
            Err(_) => {
                failure = Some("model thread hung up without sending an event".to_string());
                break;
            }
        }
    }

    // Tear down: every non-finished thread is parked in wait_go (the
    // lockstep invariant), so a Cancel permit unwinds it; then join all
    // handles so no model thread outlives its schedule.
    let handles: Vec<JoinHandle<()>> = {
        let mut st = lock_state(&exec);
        for t in &mut st.threads {
            if t.phase != Phase::Finished {
                t.go_tx.send(Go::Cancel).ok();
            }
        }
        st.threads.iter_mut().filter_map(|t| t.handle.take()).collect()
    };
    for h in handles {
        let _ = h.join();
    }

    match failure {
        None => RunOutcome::Done { decisions },
        Some(message) => RunOutcome::Failed {
            message,
            schedule: decisions.iter().map(|d| d.0).collect(),
        },
    }
}

// ------------------------------------------------------------------- rt

/// Runtime hooks the instrumented `crate::sync::model` primitives call
/// into. Everything here must only run on a model thread (inside a
/// [`check`] execution); [`rt::active`] is the discriminator the
/// dual-flavor facade types use at construction time.
///
/// Contract for callers (the facade): operations that *release* or
/// *wake* ([`rt::mutex_release`], [`rt::wake_resource`]) never yield
/// and never panic — they are called from `Drop` impls and must be
/// unwind-safe. Operations that *acquire* or *block* yield first, so
/// every contended transition is a scheduling decision.
pub mod rt {
    use super::*;

    /// True iff the calling thread is a model thread of a live
    /// execution. The facade checks this at construction time to pick
    /// the std or model flavor.
    pub fn active() -> bool {
        CTX.with(|c| c.borrow().is_some())
    }

    /// True iff this model thread is unwinding after a scheduler
    /// cancellation. The facade's blocking loops bail out instead of
    /// spinning/blocking when this is set (see `CANCELLED`).
    pub fn cancelled() -> bool {
        CANCELLED.with(|c| c.get())
    }

    /// Hand control to the scheduler; returns when this thread is next
    /// scheduled. Every visible side effect boundary in the facade
    /// routes through here.
    pub fn yield_point() {
        if cancelled() {
            return;
        }
        with_ctx(|ctx| {
            ctx.event_tx.send(Event::Yielded(ctx.tid)).ok();
            wait_go(ctx);
        });
    }

    /// Mint a model mutex; returns its id.
    pub fn new_mutex() -> usize {
        with_ctx(|ctx| {
            let mut st = lock_state(&ctx.exec);
            let id = st.mutexes.len();
            st.mutexes.push(false);
            id
        })
    }

    /// Attempt to take the mutex. No yield — callers yield first.
    /// During cancellation unwind the lock always "succeeds": the
    /// execution's state is already condemned and the caller must be
    /// allowed to finish its `Drop` without blocking.
    pub fn mutex_try_acquire(id: usize) -> bool {
        if cancelled() {
            return true;
        }
        with_ctx(|ctx| {
            let mut st = lock_state(&ctx.exec);
            if st.mutexes[id] {
                false
            } else {
                st.mutexes[id] = true;
                true
            }
        })
    }

    /// Release the mutex and make its blocked waiters runnable. Never
    /// yields (safe from `Drop`, including during unwind).
    pub fn mutex_release(id: usize) {
        if cancelled() {
            return;
        }
        with_ctx(|ctx| {
            let mut st = lock_state(&ctx.exec);
            st.mutexes[id] = false;
            wake_where(&mut st, BlockOn::Mutex(id));
        });
    }

    /// Park this thread until [`mutex_release`] of `id` wakes it.
    pub fn block_on_mutex(id: usize) {
        block(BlockOn::Mutex(id));
    }

    /// Mint a wait-resource id (condvar or channel wakeup set).
    pub fn new_resource() -> usize {
        with_ctx(|ctx| {
            let mut st = lock_state(&ctx.exec);
            let id = st.next_resource;
            st.next_resource += 1;
            id
        })
    }

    /// Park this thread until [`wake_resource`] of `id` wakes it.
    pub fn block_on_resource(id: usize) {
        block(BlockOn::Resource(id));
    }

    /// Make every thread parked on `id` runnable (notify-all / spurious
    /// wakeup superset). Never yields (safe from `Drop`).
    pub fn wake_resource(id: usize) {
        if cancelled() {
            return;
        }
        with_ctx(|ctx| {
            let mut st = lock_state(&ctx.exec);
            wake_where(&mut st, BlockOn::Resource(id));
        });
    }

    /// Spawn a model thread; returns its tid for [`join`].
    pub fn spawn(f: impl FnOnce() + Send + 'static) -> usize {
        with_ctx(|ctx| spawn_model_thread(&ctx.exec, Box::new(f)))
    }

    /// True iff `tid` has finished (normally or by panic).
    pub fn thread_finished(tid: usize) -> bool {
        with_ctx(|ctx| {
            let st = lock_state(&ctx.exec);
            st.threads[tid].phase == Phase::Finished
        })
    }

    /// Block until `tid` finishes. Cooperative: between the yield and
    /// the block no other thread runs, so the finish wakeup cannot be
    /// missed. Returns immediately during cancellation unwind.
    pub fn join(tid: usize) {
        loop {
            if cancelled() {
                return;
            }
            yield_point();
            if thread_finished(tid) {
                return;
            }
            block(BlockOn::Join(tid));
        }
    }

    fn block(on: BlockOn) {
        if cancelled() {
            return;
        }
        with_ctx(|ctx| {
            {
                let mut st = lock_state(&ctx.exec);
                st.threads[ctx.tid].phase = Phase::Blocked(on);
            }
            ctx.event_tx.send(Event::Blocked(ctx.tid)).ok();
            wait_go(ctx);
        });
    }
}

// ---------------------------------------------------------------- tests

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn single_thread_model_is_one_forced_schedule() {
        let report = check("single", &Config::default(), || {
            rt::yield_point();
            rt::yield_point();
            rt::yield_point();
        })
        .expect("no failure");
        assert_eq!(report.schedules, 1, "one thread → every choice forced");
        assert!(report.complete);
        assert_eq!(report.max_decisions, 0);
    }

    fn two_yielders_model() {
        let a = rt::spawn(|| {
            rt::yield_point();
            rt::yield_point();
        });
        let b = rt::spawn(|| {
            rt::yield_point();
            rt::yield_point();
        });
        rt::join(a);
        rt::join(b);
    }

    #[test]
    fn exploration_is_exhaustive_and_deterministic() {
        let r1 = check("two-yielders", &Config::default(), two_yielders_model).expect("ok");
        let r2 = check("two-yielders", &Config::default(), two_yielders_model).expect("ok");
        assert!(r1.complete && r2.complete);
        assert!(r1.schedules > 1, "two free threads must interleave");
        assert_eq!(r1.schedules, r2.schedules, "DFS must be deterministic");
        assert_eq!(r1.max_decisions, r2.max_decisions);
    }

    #[test]
    fn schedule_budget_caps_exploration() {
        let cfg = Config { max_schedules: 3, max_steps_per_schedule: 10_000 };
        let report = check("capped", &cfg, two_yielders_model).expect("ok");
        assert_eq!(report.schedules, 3);
        assert!(!report.complete);
    }

    /// Classic lost update: two threads read-modify-write a shared
    /// counter with a yield between load and store.
    fn racy_increment_model() {
        let counter = Arc::new(AtomicUsize::new(0));
        let mk = |c: Arc<AtomicUsize>| {
            move || {
                let v = c.load(Ordering::SeqCst);
                rt::yield_point();
                c.store(v + 1, Ordering::SeqCst);
            }
        };
        let a = rt::spawn(mk(Arc::clone(&counter)));
        let b = rt::spawn(mk(Arc::clone(&counter)));
        rt::join(a);
        rt::join(b);
        assert_eq!(counter.load(Ordering::SeqCst), 2, "lost update");
    }

    #[test]
    fn racy_increment_is_caught_and_seed_replays() {
        let failure = check("racy-increment", &Config::default(), racy_increment_model)
            .expect_err("the race must be found");
        assert!(failure.message.contains("lost update"), "message: {}", failure.message);
        let seed = failure.seed();
        assert!(parse_seed(&seed).is_ok());
        let replayed = replay("racy-increment", &seed, racy_increment_model)
            .expect_err("seed must reproduce the failure");
        assert!(replayed.message.contains("lost update"));
    }

    /// Raw lock protocol used by the facade's model mutex: yield, try,
    /// block on contention.
    fn raw_lock(id: usize) {
        loop {
            rt::yield_point();
            if rt::mutex_try_acquire(id) {
                return;
            }
            rt::block_on_mutex(id);
        }
    }

    fn abba_model() {
        let a = rt::new_mutex();
        let b = rt::new_mutex();
        let t1 = rt::spawn(move || {
            raw_lock(a);
            rt::yield_point();
            raw_lock(b);
            rt::mutex_release(b);
            rt::mutex_release(a);
        });
        let t2 = rt::spawn(move || {
            raw_lock(b);
            rt::yield_point();
            raw_lock(a);
            rt::mutex_release(a);
            rt::mutex_release(b);
        });
        rt::join(t1);
        rt::join(t2);
    }

    #[test]
    fn abba_deadlock_is_detected_with_replayable_seed() {
        let failure =
            check("abba", &Config::default(), abba_model).expect_err("deadlock must be found");
        assert!(failure.message.contains("deadlock"), "message: {}", failure.message);
        let replayed =
            replay("abba", &failure.seed(), abba_model).expect_err("seed must reproduce");
        assert!(replayed.message.contains("deadlock"));
    }

    #[test]
    fn mutex_protocol_has_no_false_deadlocks() {
        // Same ABBA bodies but with a consistent lock order: must
        // explore completely with zero failures.
        let report = check("ordered-locks", &Config::default(), || {
            let a = rt::new_mutex();
            let b = rt::new_mutex();
            let t1 = rt::spawn(move || {
                raw_lock(a);
                rt::yield_point();
                raw_lock(b);
                rt::mutex_release(b);
                rt::mutex_release(a);
            });
            let t2 = rt::spawn(move || {
                raw_lock(a);
                rt::yield_point();
                raw_lock(b);
                rt::mutex_release(b);
                rt::mutex_release(a);
            });
            rt::join(t1);
            rt::join(t2);
        })
        .expect("consistent lock order cannot deadlock");
        assert!(report.complete);
    }

    #[test]
    fn seed_codec_round_trips() {
        assert_eq!(parse_seed("0.2.1").unwrap(), vec![0, 2, 1]);
        assert_eq!(parse_seed("-").unwrap(), Vec::<usize>::new());
        assert_eq!(parse_seed("").unwrap(), Vec::<usize>::new());
        assert!(parse_seed("0.x.1").is_err());
        let f = Failure {
            name: "n".into(),
            message: "m".into(),
            schedule: vec![0, 2, 1],
        };
        assert_eq!(f.seed(), "0.2.1");
        let empty = Failure { name: "n".into(), message: "m".into(), schedule: vec![] };
        assert_eq!(empty.seed(), "-");
        assert_eq!(parse_seed(&empty.seed()).unwrap(), Vec::<usize>::new());
    }
}
