//! Per-service insert batching: accumulate small insert requests into one
//! device-sized batch, flushing on size or deadline — amortising kernel
//! launches and the per-insert scan overhead exactly the way a serving
//! router amortises prefill batches.
//!
//! This module is listed in `rust/hotpath_manifest.txt`: the repo lint
//! (`cargo run --bin lint`) rejects heap-allocating calls in its
//! non-test code, pinning the buffer-recycling contract below.

use std::time::{Duration, Instant};

/// Batching configuration.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Flush when this many values are pending.
    pub max_values: usize,
    /// Flush when the oldest pending value has waited this long.
    pub max_delay: Duration,
}

impl Default for BatchConfig {
    fn default() -> BatchConfig {
        BatchConfig { max_values: 1 << 16, max_delay: Duration::from_millis(2) }
    }
}

/// A flushed batch.
#[derive(Debug)]
pub struct Batch {
    pub values: Vec<f32>,
    /// How many client requests were coalesced.
    pub requests: usize,
    /// Age of the oldest request at flush time.
    pub oldest_age: Duration,
}

/// Accumulator. Not thread-safe by itself — the service owns it inside
/// its event loop.
#[derive(Debug)]
pub struct Batcher {
    cfg: BatchConfig,
    pending: Vec<f32>,
    /// Recycled batch buffer: a flush hands `pending` out inside the
    /// [`Batch`] and swaps this in; the consumer returns the buffer via
    /// [`Batcher::recycle`], so steady-state flushes ping-pong two
    /// buffers instead of allocating one per flush.
    spare: Vec<f32>,
    requests: usize,
    oldest: Option<Instant>,
    flushes: u64,
    coalesced_total: u64,
}

impl Batcher {
    pub fn new(cfg: BatchConfig) -> Batcher {
        Batcher {
            cfg,
            pending: Vec::new(),
            spare: Vec::new(),
            requests: 0,
            oldest: None,
            flushes: 0,
            coalesced_total: 0,
        }
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn flushes(&self) -> u64 {
        self.flushes
    }

    /// Total client requests coalesced across all flushes (the numerator
    /// of the batching-effectiveness ratio surfaced in
    /// `MetricsSnapshot`).
    pub fn coalesced_total(&self) -> u64 {
        self.coalesced_total
    }

    /// Mean requests coalesced per flush (batching effectiveness metric).
    pub fn mean_coalescing(&self) -> f64 {
        if self.flushes == 0 {
            0.0
        } else {
            self.coalesced_total as f64 / self.flushes as f64
        }
    }

    /// Add values; returns a batch if the size threshold tripped.
    pub fn push(&mut self, values: &[f32]) -> Option<Batch> {
        if self.pending.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.pending.extend_from_slice(values);
        self.requests += 1;
        if self.pending.len() >= self.cfg.max_values {
            return Some(self.flush_now());
        }
        None
    }

    /// Owned-buffer variant of [`Batcher::push`] for the frontend drain
    /// loop: the values join the pending batch and the drained client
    /// buffer goes straight into the recycle pool, so cross-client
    /// coalescing adds no steady-state worker-side allocations.
    pub fn push_owned(&mut self, values: Vec<f32>) -> Option<Batch> {
        let out = self.push(&values);
        self.recycle(values);
        out
    }

    /// Deadline check — the event loop calls this on idle ticks.
    pub fn poll_deadline(&mut self) -> Option<Batch> {
        match self.oldest {
            Some(t) if t.elapsed() >= self.cfg.max_delay && !self.pending.is_empty() => Some(self.flush_now()),
            _ => None,
        }
    }

    /// Unconditional flush (shutdown, explicit barrier before Work/
    /// Flatten/Query so ordering is preserved).
    pub fn flush(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            None
        } else {
            Some(self.flush_now())
        }
    }

    fn flush_now(&mut self) -> Batch {
        // Swap the recycled spare in as the next pending buffer instead
        // of leaving a fresh (capacity-0) vector behind.
        let values = std::mem::replace(&mut self.pending, std::mem::take(&mut self.spare));
        let requests = std::mem::replace(&mut self.requests, 0);
        let oldest_age = self.oldest.take().map(|t| t.elapsed()).unwrap_or_default();
        self.flushes += 1;
        self.coalesced_total += requests as u64;
        Batch { values, requests, oldest_age }
    }

    /// Return a consumed batch's buffer for reuse by a later flush. The
    /// larger capacity wins, so once the biggest batch size has been
    /// seen the flush loop stops touching the allocator.
    pub fn recycle(&mut self, mut values: Vec<f32>) {
        values.clear();
        if values.capacity() > self.spare.capacity() {
            self.spare = values;
        }
    }

    /// Time until the current deadline expires (event-loop park hint).
    pub fn time_to_deadline(&self) -> Option<Duration> {
        self.oldest.map(|t| self.cfg.max_delay.saturating_sub(t.elapsed()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_threshold_flushes() {
        let mut b = Batcher::new(BatchConfig { max_values: 10, max_delay: Duration::from_secs(60) });
        assert!(b.push(&[1.0; 4]).is_none());
        assert!(b.push(&[2.0; 4]).is_none());
        let batch = b.push(&[3.0; 4]).expect("threshold crossed");
        assert_eq!(batch.values.len(), 12);
        assert_eq!(batch.requests, 3);
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.flushes(), 1);
    }

    #[test]
    fn deadline_flushes() {
        let mut b = Batcher::new(BatchConfig { max_values: 1000, max_delay: Duration::from_millis(1) });
        b.push(&[1.0]);
        assert!(b.poll_deadline().is_none() || b.poll_deadline().is_some()); // may or may not have expired yet
        std::thread::sleep(Duration::from_millis(3));
        let batch = b.poll_deadline().expect("deadline expired");
        assert_eq!(batch.values, vec![1.0]);
        assert!(batch.oldest_age >= Duration::from_millis(1));
    }

    #[test]
    fn explicit_flush_and_empty() {
        let mut b = Batcher::new(BatchConfig::default());
        assert!(b.flush().is_none());
        b.push(&[5.0, 6.0]);
        let batch = b.flush().unwrap();
        assert_eq!(batch.values, vec![5.0, 6.0]);
        assert!(b.flush().is_none());
    }

    #[test]
    fn recycled_buffers_ping_pong_without_reallocating() {
        let mut b = Batcher::new(BatchConfig { max_values: 8, max_delay: Duration::from_secs(60) });
        // Flush 1 allocates the first buffer; recycle it.
        let batch1 = b.push(&[1.0; 8]).expect("size flush");
        let p1 = batch1.values.as_ptr();
        b.recycle(batch1.values);
        // Flush 2's buffer was freshly grown (pending had no capacity
        // yet); recycling it completes the two-buffer pool.
        let batch2 = b.push(&[2.0; 8]).expect("size flush");
        let p2 = batch2.values.as_ptr();
        b.recycle(batch2.values);
        // From here on the two buffers ping-pong: every flush hands back
        // one of the recycled pointers and conserves the values.
        for round in 0..6 {
            let batch = b.push(&[round as f32; 8]).expect("size flush");
            assert_eq!(batch.values, vec![round as f32; 8]);
            assert!(
                batch.values.as_ptr() == p1 || batch.values.as_ptr() == p2,
                "round {round}: flush must reuse a recycled buffer"
            );
            b.recycle(batch.values);
        }
        assert_eq!(b.flushes(), 8);
        assert_eq!(b.coalesced_total(), 8);
    }

    #[test]
    fn push_owned_coalesces_and_recycles_the_client_buffer() {
        let mut b = Batcher::new(BatchConfig { max_values: 8, max_delay: Duration::from_secs(60) });
        let client_buf = Vec::from([1.0f32; 6]);
        assert!(b.push_owned(client_buf).is_none());
        assert_eq!(b.pending_len(), 6);
        // Second owned push trips the threshold; the flushed batch holds
        // both requests' values in admission order.
        let batch = b.push_owned(Vec::from([2.0f32; 6])).expect("size flush");
        assert_eq!(batch.requests, 2);
        assert_eq!(&batch.values[..6], &[1.0; 6]);
        assert_eq!(&batch.values[6..], &[2.0; 6]);
        // The drained client buffer was recycled into the spare slot, so
        // the next pending buffer reuses it instead of allocating.
        b.recycle(batch.values);
        assert!(b.push_owned(Vec::from([3.0f32; 4])).is_none());
        assert_eq!(b.pending_len(), 4);
    }

    #[test]
    fn coalescing_metric() {
        let mut b = Batcher::new(BatchConfig { max_values: 4, max_delay: Duration::from_secs(1) });
        b.push(&[1.0]);
        b.push(&[2.0]);
        b.push(&[3.0]);
        let _ = b.push(&[4.0]).unwrap(); // 4 requests → 1 flush
        b.push(&[9.0; 4]).unwrap(); // 1 request → 1 flush
        assert_eq!(b.flushes(), 2);
        assert!((b.mean_coalescing() - 2.5).abs() < 1e-12);
    }
}
