//! Multi-client admission frontend for the coordinator.
//!
//! The coordinator worker owns the shards and serialises every mutation,
//! but the request loop it shipped with was single-producer: one
//! unbounded envelope channel, one caller at a time. This module puts an
//! admission layer in front of it, in the style of febft's `RqProcessor`:
//!
//! * each concurrent writer holds a [`ClientSession`] with a stable
//!   client id and a monotonic per-session sequence number;
//! * every session feeds the worker through its own **bounded** MPSC
//!   channel (`sync_channel(queue_requests)`), so a fast producer can
//!   never OOM the queue — admission fails fast instead;
//! * the worker drains all client pools into the shared [`Batcher`]
//!   (cross-client coalescing into one proposed batch), always in
//!   ascending client-id order with per-client FIFO preserved.
//!
//! # Backpressure contract
//!
//! [`ClientSession::try_insert`] never blocks the worker and never drops
//! silently. When the session's channel is full it returns
//! [`Admission::Rejected`] with a `retry_after_hint` **and hands the
//! payload back** so the caller can retry without recloning; the
//! rejection is counted in the shared shed ledger, which surfaces as
//! `shed_requests` in the metrics snapshot. A rejected request consumes
//! no sequence number — the accepted stream stays contiguous.
//!
//! # Determinism contract
//!
//! The sealed layout depends only on the order values reach the batcher
//! (flushes are size-triggered, never timing-triggered mid-stream). Two
//! merge policies trade determinism against latency:
//!
//! * [`MergePolicy::AtBarrier`] drains client pools **only at sync
//!   points** (seal / flatten / work / stats / clear / shutdown). With
//!   clients quiesced before each barrier, the merged stream is exactly
//!   "phase by phase, client id ascending, per-client FIFO" — a priori
//!   identical to replaying the same requests serially through one
//!   session, so sealed epochs are byte-identical.
//! * [`MergePolicy::Eager`] (default) additionally drains on every
//!   admission poke and idle tick — the throughput mode, where merge
//!   order is timing-dependent.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Duration;

use super::request::{Admission, Request, Response};
use super::service::Envelope;

/// When the worker merges admitted client pools into the batcher.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergePolicy {
    /// Drain on every admission poke and idle tick: lowest latency,
    /// timing-dependent merge order.
    Eager,
    /// Drain only at sync points, in client-id order: with clients
    /// quiesced at each barrier, sealed layout is byte-identical to a
    /// serial single-session replay.
    AtBarrier,
}

/// Admission-layer configuration, embedded in `CoordinatorConfig`.
#[derive(Debug, Clone)]
pub struct FrontendConfig {
    /// Bound of each client's request channel: the per-session admission
    /// window. A full channel sheds (typed rejection), it never grows.
    pub queue_requests: usize,
    /// Hint returned with [`Admission::Rejected`] — how long the client
    /// should wait before retrying. Advisory, not enforced.
    pub retry_after: Duration,
    pub merge: MergePolicy,
}

impl Default for FrontendConfig {
    fn default() -> FrontendConfig {
        FrontendConfig {
            queue_requests: 128,
            retry_after: Duration::from_micros(200),
            merge: MergePolicy::Eager,
        }
    }
}

/// One admitted insert travelling a session's bounded channel.
#[derive(Debug)]
pub struct SessionInsert {
    /// Per-session monotonic sequence number (admission order).
    pub seq: u64,
    pub values: Vec<f32>,
}

/// State shared between every session and the worker: client-id
/// allocation plus the admission/shed ledgers. All counters are
/// monotonic except `pooled_values`, a gauge of admitted-but-unmerged
/// values.
#[derive(Debug, Default)]
pub struct FrontendShared {
    next_client: AtomicU64,
    pooled_values: AtomicUsize,
    shed_requests: AtomicU64,
}

impl FrontendShared {
    /// Sessions ever opened on this coordinator.
    pub fn sessions(&self) -> u64 {
        self.next_client.load(Ordering::Acquire)
    }

    /// Requests shed by admission (typed rejections) across all sessions.
    pub fn shed_total(&self) -> u64 {
        self.shed_requests.load(Ordering::Acquire)
    }

    /// Values admitted but not yet merged into the batcher (gauge).
    pub fn pooled_values(&self) -> usize {
        self.pooled_values.load(Ordering::Acquire)
    }

    pub(crate) fn allocate_client(&self) -> u64 {
        self.next_client.fetch_add(1, Ordering::AcqRel)
    }

    pub(crate) fn add_pooled(&self, n: usize) {
        self.pooled_values.fetch_add(n, Ordering::AcqRel);
    }

    pub(crate) fn sub_pooled(&self, n: usize) {
        self.pooled_values.fetch_sub(n, Ordering::AcqRel);
    }

    pub(crate) fn add_shed(&self) {
        self.shed_requests.fetch_add(1, Ordering::AcqRel);
    }
}

/// Worker-side end of one session: the bounded receiver plus the next
/// sequence number expected from it (admission-order contiguity check).
pub(crate) struct ClientLane {
    pub(crate) id: u64,
    pub(crate) rx: Receiver<SessionInsert>,
    pub(crate) next_seq: u64,
}

/// A client's handle into the admission layer. Obtained from
/// `Coordinator::session()`; one per writer thread (`Send`, not
/// `Clone` — the sequence number is the session's identity).
///
/// Inserts go through [`ClientSession::try_insert`] (bounded, sheds on
/// overload); every other request kind goes through
/// [`ClientSession::call`], which is synchronous and acts as a barrier
/// for this session's admitted inserts under any [`MergePolicy`].
pub struct ClientSession {
    id: u64,
    next_seq: u64,
    accepted_values: u64,
    data: SyncSender<SessionInsert>,
    tx: mpsc::Sender<Envelope>,
    shared: Arc<FrontendShared>,
    retry_after: Duration,
    eager: bool,
}

impl ClientSession {
    /// Open a session: allocate a client id, build the bounded data
    /// channel, and register the worker-side lane. Data admitted before
    /// the registration envelope is processed simply waits in the
    /// channel — no ordering race.
    pub(crate) fn connect(
        tx: mpsc::Sender<Envelope>,
        shared: Arc<FrontendShared>,
        cfg: &FrontendConfig,
    ) -> ClientSession {
        let id = shared.allocate_client();
        let (data, rx) = mpsc::sync_channel::<SessionInsert>(cfg.queue_requests.max(1));
        let _ = tx.send(Envelope::Register { id, rx });
        ClientSession {
            id,
            next_seq: 0,
            accepted_values: 0,
            data,
            tx,
            shared,
            retry_after: cfg.retry_after,
            eager: cfg.merge == MergePolicy::Eager,
        }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// Sequence number the next accepted insert will get (== accepted
    /// request count so far).
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Values accepted through this session so far (the client-side
    /// ledger the worker's `elements_inserted` must reconcile with).
    pub fn accepted_values(&self) -> u64 {
        self.accepted_values
    }

    /// Non-blocking admission. `Accepted` took ownership of the payload;
    /// `Rejected`/`Closed` hand it back untouched so the caller can
    /// retry or repurpose it without a clone.
    pub fn try_insert(&mut self, values: Vec<f32>) -> Admission {
        let n = values.len();
        // Optimistically count the values as pooled *before* try_send:
        // once the send succeeds the worker may drain (and decrement)
        // immediately, so incrementing afterwards could underflow the
        // gauge. Roll back on rejection.
        self.shared.add_pooled(n);
        match self.data.try_send(SessionInsert { seq: self.next_seq, values }) {
            Ok(()) => {
                self.next_seq += 1;
                self.accepted_values += n as u64;
                if self.eager {
                    let _ = self.tx.send(Envelope::Poke);
                }
                Admission::Accepted { seq: self.next_seq - 1, session_values: self.accepted_values }
            }
            Err(TrySendError::Full(ins)) => {
                self.shared.sub_pooled(n);
                self.shared.add_shed();
                Admission::Rejected { retry_after_hint: self.retry_after, values: ins.values }
            }
            Err(TrySendError::Disconnected(ins)) => {
                self.shared.sub_pooled(n);
                Admission::Closed { values: ins.values }
            }
        }
    }

    /// Admission with bounded-sleep retries until accepted (or the
    /// coordinator closes). Returns the final admission plus how many
    /// times this request was shed along the way.
    ///
    /// Under [`MergePolicy::AtBarrier`] a full channel only drains at a
    /// sync point, so callers must size `queue_requests` to cover a full
    /// between-barriers burst — this helper cannot unstick an
    /// under-provisioned window on its own.
    pub fn insert_retrying(&mut self, values: Vec<f32>) -> (Admission, u64) {
        let mut sheds = 0u64;
        let mut payload = values;
        loop {
            match self.try_insert(payload) {
                Admission::Rejected { retry_after_hint, values } => {
                    sheds += 1;
                    payload = values;
                    std::thread::sleep(retry_after_hint.min(Duration::from_millis(1)));
                }
                done => return (done, sheds),
            }
        }
    }

    /// Synchronous request on the control channel (same contract as
    /// `Client::call`). Seal/flatten/work/stats/clear are sync points:
    /// the worker drains every registered client pool before serving
    /// them, so this session's accepted inserts are always visible to
    /// its own subsequent sync calls.
    pub fn call(&self, req: Request) -> Response {
        let (rtx, rrx) = mpsc::channel();
        if self.tx.send(Envelope::Call(req, rtx)).is_err() {
            return Response::Error("coordinator stopped".into());
        }
        rrx.recv().unwrap_or_else(|_| Response::Error("coordinator dropped reply".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::super::request::{checksum, Request};
    use super::super::service::{Coordinator, CoordinatorConfig};
    use super::*;

    fn frontend_cfg(merge: MergePolicy) -> CoordinatorConfig {
        CoordinatorConfig {
            blocks: 8,
            shards: 1,
            first_bucket_size: 16,
            use_artifacts: false,
            frontend: FrontendConfig { queue_requests: 8, merge, ..FrontendConfig::default() },
            ..CoordinatorConfig::default()
        }
    }

    #[test]
    fn session_ids_monotonic_and_counted() {
        let c = Coordinator::start(frontend_cfg(MergePolicy::Eager));
        let a = c.session();
        let b = c.session();
        assert_eq!(a.id(), 0);
        assert_eq!(b.id(), 1);
        let snap = c.call(Request::Stats).expect_stats();
        assert_eq!(snap.sessions, 2);
        c.shutdown();
    }

    #[test]
    fn at_barrier_merges_in_client_id_order() {
        // Session 1 admits first, session 0 second — AtBarrier still
        // merges client 0 before client 1 at the flatten barrier, so the
        // layout matches the deterministic merge order, not wall time.
        let c = Coordinator::start(frontend_cfg(MergePolicy::AtBarrier));
        let mut s0 = c.session();
        let mut s1 = c.session();
        let (seq, total) = s1.try_insert(vec![10.0, 11.0]).expect_accepted();
        assert_eq!((seq, total), (0, 2));
        let (seq, total) = s0.try_insert(vec![1.0, 2.0]).expect_accepted();
        assert_eq!((seq, total), (0, 2));
        match s0.call(Request::Flatten) {
            Response::Flattened { len, checksum: got, .. } => {
                assert_eq!(len, 4);
                assert_eq!(got, checksum(&[1.0, 2.0, 10.0, 11.0]));
            }
            other => panic!("flatten failed: {other:?}"),
        }
        assert_eq!(s0.accepted_values(), 2);
        assert_eq!(s1.accepted_values(), 2);
        c.shutdown();
    }

    #[test]
    fn session_insert_then_own_sync_call_sees_data() {
        let c = Coordinator::start(frontend_cfg(MergePolicy::Eager));
        let mut s = c.session();
        for i in 0..4 {
            let adm = s.try_insert(vec![i as f32; 8]);
            assert!(adm.is_accepted(), "unexpected admission: {adm:?}");
        }
        assert_eq!(s.next_seq(), 4);
        let snap = s.call(Request::Stats).expect_stats();
        assert_eq!(snap.len, 32);
        assert_eq!(snap.admitted_requests, 4);
        assert_eq!(snap.admitted_values, 32);
        assert_eq!(snap.shed_requests, 0);
        c.shutdown();
    }

    #[test]
    fn closed_coordinator_hands_payload_back() {
        let c = Coordinator::start(frontend_cfg(MergePolicy::Eager));
        let mut s = c.session();
        c.shutdown();
        match s.try_insert(vec![1.0, 2.0, 3.0]) {
            Admission::Closed { values } => assert_eq!(values, vec![1.0, 2.0, 3.0]),
            other => panic!("expected Closed, got {other:?}"),
        }
        assert!(matches!(s.call(Request::Stats), Response::Error(_)));
    }
}
