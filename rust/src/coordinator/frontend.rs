//! Multi-client admission frontend for the coordinator.
//!
//! The coordinator worker owns the shards and serialises every mutation,
//! but the request loop it shipped with was single-producer: one
//! unbounded envelope channel, one caller at a time. This module puts an
//! admission layer in front of it, in the style of febft's `RqProcessor`:
//!
//! * each concurrent writer holds a [`ClientSession`] with a stable
//!   client id and a monotonic per-session sequence number;
//! * every session feeds the worker through its own **bounded** MPSC
//!   channel (`sync_channel(queue_requests)`), so a fast producer can
//!   never OOM the queue — admission fails fast instead;
//! * the worker drains all client pools into the shared [`Batcher`]
//!   (cross-client coalescing into one proposed batch), always in
//!   ascending client-id order with per-client FIFO preserved — the
//!   sweep itself lives here as [`drain_lanes`], shared by the worker,
//!   the [`FrontendRig`] test harness, and the `ggcheck` model suite.
//!
//! All synchronisation comes from the [`crate::sync`] facade, so under
//! `--cfg ggcheck` the admission window, the shed path, and the barrier
//! drain are exhaustively model-checked (`tests/model_check.rs`).
//!
//! # Backpressure contract
//!
//! [`ClientSession::try_insert`] never blocks the worker and never drops
//! silently. When the session's channel is full it returns
//! [`Admission::Rejected`] with a `retry_after_hint` **and hands the
//! payload back** so the caller can retry without recloning; the
//! rejection is counted in the shared shed ledger, which surfaces as
//! `shed_requests` in the metrics snapshot. A rejected request consumes
//! no sequence number — the accepted stream stays contiguous (pinned by
//! `rejected_admission_rolls_back_ledgers_exactly` and model-checked
//! under every bounded interleaving).
//!
//! # Determinism contract
//!
//! The sealed layout depends only on the order values reach the batcher
//! (flushes are size-triggered, never timing-triggered mid-stream). Two
//! merge policies trade determinism against latency:
//!
//! * [`MergePolicy::AtBarrier`] drains client pools **only at sync
//!   points** (seal / flatten / work / stats / clear / shutdown). With
//!   clients quiesced before each barrier, the merged stream is exactly
//!   "phase by phase, client id ascending, per-client FIFO" — a priori
//!   identical to replaying the same requests serially through one
//!   session, so sealed epochs are byte-identical.
//! * [`MergePolicy::Eager`] (default) additionally drains on every
//!   admission poke and idle tick — the throughput mode, where merge
//!   order is timing-dependent.

use crate::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use crate::sync::mpsc::{self, Receiver, SyncSender, TryRecvError, TrySendError};
use crate::sync::thread;
use crate::sync::Arc;
use std::time::Duration;

use super::request::{Admission, ExecError, Request, Response};
use super::service::Envelope;

/// When the worker merges admitted client pools into the batcher.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergePolicy {
    /// Drain on every admission poke and idle tick: lowest latency,
    /// timing-dependent merge order.
    Eager,
    /// Drain only at sync points, in client-id order: with clients
    /// quiesced at each barrier, sealed layout is byte-identical to a
    /// serial single-session replay.
    AtBarrier,
}

/// Admission-layer configuration, embedded in `CoordinatorConfig`.
#[derive(Debug, Clone)]
pub struct FrontendConfig {
    /// Bound of each client's request channel: the per-session admission
    /// window. A full channel sheds (typed rejection), it never grows.
    pub queue_requests: usize,
    /// Hint returned with [`Admission::Rejected`] — how long the client
    /// should wait before retrying. Advisory, not enforced.
    pub retry_after: Duration,
    pub merge: MergePolicy,
}

impl Default for FrontendConfig {
    fn default() -> FrontendConfig {
        FrontendConfig {
            queue_requests: 128,
            retry_after: Duration::from_micros(200),
            merge: MergePolicy::Eager,
        }
    }
}

/// One admitted insert travelling a session's bounded channel.
#[derive(Debug)]
pub struct SessionInsert {
    /// Per-session monotonic sequence number (admission order).
    pub seq: u64,
    pub values: Vec<f32>,
}

/// State shared between every session and the worker: client-id
/// allocation plus the admission/shed ledgers. All counters are
/// monotonic except `pooled_values`, a gauge of admitted-but-unmerged
/// values.
#[derive(Debug, Default)]
pub struct FrontendShared {
    next_client: AtomicU64,
    pooled_values: AtomicUsize,
    shed_requests: AtomicU64,
}

impl FrontendShared {
    /// Sessions ever opened on this coordinator.
    pub fn sessions(&self) -> u64 {
        self.next_client.load(Ordering::Acquire)
    }

    /// Requests shed by admission (typed rejections) across all sessions.
    pub fn shed_total(&self) -> u64 {
        self.shed_requests.load(Ordering::Acquire)
    }

    /// Values admitted but not yet merged into the batcher (gauge).
    pub fn pooled_values(&self) -> usize {
        self.pooled_values.load(Ordering::Acquire)
    }

    pub(crate) fn allocate_client(&self) -> u64 {
        self.next_client.fetch_add(1, Ordering::AcqRel)
    }

    pub(crate) fn add_pooled(&self, n: usize) {
        self.pooled_values.fetch_add(n, Ordering::AcqRel);
    }

    pub(crate) fn sub_pooled(&self, n: usize) {
        self.pooled_values.fetch_sub(n, Ordering::AcqRel);
    }

    pub(crate) fn add_shed(&self) {
        self.shed_requests.fetch_add(1, Ordering::AcqRel);
    }
}

/// Worker-side end of one session: the bounded receiver plus the next
/// sequence number expected from it (admission-order contiguity check).
pub(crate) struct ClientLane {
    pub(crate) id: u64,
    pub(crate) rx: Receiver<SessionInsert>,
    pub(crate) next_seq: u64,
}

/// What one [`drain_lanes`] call moved.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DrainStats {
    /// Requests moved out of client pools.
    pub moved_requests: u64,
    /// Values inside those requests.
    pub moved_values: u64,
    /// Outer sweeps that moved at least one request (each counts as one
    /// proposal in the worker's metrics).
    pub productive_sweeps: u64,
}

/// The merge sweep shared by the worker's event loop, the
/// [`FrontendRig`] harness, and the model-check suite: visit the lanes
/// in ascending client-id order (the `lanes` vec is kept sorted by the
/// registrar), move each lane's queued requests in FIFO order — at most
/// `per_sweep` per lane per sweep, so one hot producer cannot starve
/// the loop — and hand every request to `sink` *after* updating the
/// gap-free sequence check and the shared pooled gauge. Disconnected
/// lanes (session dropped, pool fully drained) are retired in place. A
/// `barrier` drain repeats the sweep until nothing moves (quiesced
/// clients ⇒ one productive sweep); a pressure drain does one sweep.
pub(crate) fn drain_lanes(
    lanes: &mut Vec<ClientLane>,
    shared: &FrontendShared,
    per_sweep: usize,
    barrier: bool,
    mut sink: impl FnMut(u64, SessionInsert),
) -> DrainStats {
    let mut stats = DrainStats::default();
    loop {
        let mut moved = 0usize;
        let mut lane_idx = 0;
        while lane_idx < lanes.len() {
            let mut disconnected = false;
            for _ in 0..per_sweep.max(1) {
                let lane = &mut lanes[lane_idx];
                match lane.rx.try_recv() {
                    Ok(ins) => {
                        debug_assert_eq!(
                            ins.seq, lane.next_seq,
                            "client {} admission stream must be gap-free",
                            lane.id
                        );
                        lane.next_seq = ins.seq + 1;
                        moved += 1;
                        stats.moved_requests += 1;
                        stats.moved_values += ins.values.len() as u64;
                        shared.sub_pooled(ins.values.len());
                        sink(lane.id, ins);
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        // Session dropped and its pool is fully drained
                        // (Disconnected is only returned on an empty
                        // buffer) — retire the lane.
                        disconnected = true;
                        break;
                    }
                }
            }
            if disconnected {
                lanes.remove(lane_idx);
            } else {
                lane_idx += 1;
            }
        }
        if moved > 0 {
            stats.productive_sweeps += 1;
        }
        if !(barrier && moved > 0) {
            return stats;
        }
    }
}

/// A worker-less admission frontend for tests: real sessions, real
/// bounded channels, real [`drain_lanes`] sweep — but the drain is
/// driven explicitly by the test instead of a live event loop, which
/// makes shed/rollback/ordering assertions deterministic. The `ggcheck`
/// model suite drives the same rig under the checker's scheduler.
pub struct FrontendRig {
    shared: Arc<FrontendShared>,
    tx: mpsc::Sender<Envelope>,
    rx: mpsc::Receiver<Envelope>,
    cfg: FrontendConfig,
    lanes: Vec<ClientLane>,
}

impl FrontendRig {
    pub fn new(cfg: FrontendConfig) -> FrontendRig {
        let (tx, rx) = mpsc::channel();
        FrontendRig {
            shared: Arc::new(FrontendShared::default()),
            tx,
            rx,
            cfg,
            lanes: Vec::new(),
        }
    }

    /// Open a session against the rig (same path as
    /// `Coordinator::session`).
    pub fn session(&self) -> ClientSession {
        ClientSession::connect(self.tx.clone(), Arc::clone(&self.shared), &self.cfg)
    }

    /// Process queued `Register` envelopes into lanes (sorted insert,
    /// exactly like the worker). `Poke`s are ignored — the rig drains
    /// explicitly — and `Call`s are dropped (their reply channel closes,
    /// signalling "coordinator stopped" to the caller).
    pub fn absorb_registrations(&mut self) {
        while let Ok(env) = self.rx.try_recv() {
            if let Envelope::Register { id, rx } = env {
                let at = self.lanes.partition_point(|l| l.id < id);
                self.lanes.insert(at, ClientLane { id, rx, next_seq: 0 });
            }
        }
    }

    /// One explicit merge: absorb pending registrations, then run the
    /// worker's sweep, handing each drained insert to `sink` in merge
    /// order.
    pub fn drain(&mut self, barrier: bool, sink: impl FnMut(u64, SessionInsert)) -> DrainStats {
        self.absorb_registrations();
        drain_lanes(&mut self.lanes, &self.shared, self.cfg.queue_requests.max(1), barrier, sink)
    }

    /// Registered (non-retired) lanes.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn shared(&self) -> &FrontendShared {
        &self.shared
    }
}

/// A client's handle into the admission layer. Obtained from
/// `Coordinator::session()`; one per writer thread (`Send`, not
/// `Clone` — the sequence number is the session's identity).
///
/// Inserts go through [`ClientSession::try_insert`] (bounded, sheds on
/// overload); every other request kind goes through
/// [`ClientSession::call`], which is synchronous and acts as a barrier
/// for this session's admitted inserts under any [`MergePolicy`].
pub struct ClientSession {
    id: u64,
    next_seq: u64,
    accepted_values: u64,
    data: SyncSender<SessionInsert>,
    tx: mpsc::Sender<Envelope>,
    shared: Arc<FrontendShared>,
    retry_after: Duration,
    eager: bool,
}

impl ClientSession {
    /// Open a session: allocate a client id, build the bounded data
    /// channel, and register the worker-side lane. Data admitted before
    /// the registration envelope is processed simply waits in the
    /// channel — no ordering race.
    pub(crate) fn connect(
        tx: mpsc::Sender<Envelope>,
        shared: Arc<FrontendShared>,
        cfg: &FrontendConfig,
    ) -> ClientSession {
        let id = shared.allocate_client();
        let (data, rx) = mpsc::sync_channel::<SessionInsert>(cfg.queue_requests.max(1));
        let _ = tx.send(Envelope::Register { id, rx });
        ClientSession {
            id,
            next_seq: 0,
            accepted_values: 0,
            data,
            tx,
            shared,
            retry_after: cfg.retry_after,
            eager: cfg.merge == MergePolicy::Eager,
        }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// Sequence number the next accepted insert will get (== accepted
    /// request count so far).
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Values accepted through this session so far (the client-side
    /// ledger the worker's `elements_inserted` must reconcile with).
    pub fn accepted_values(&self) -> u64 {
        self.accepted_values
    }

    /// Non-blocking admission. `Accepted` took ownership of the payload;
    /// `Rejected`/`Closed` hand it back untouched so the caller can
    /// retry or repurpose it without a clone.
    pub fn try_insert(&mut self, values: Vec<f32>) -> Admission {
        let n = values.len();
        // Optimistically count the values as pooled *before* try_send:
        // once the send succeeds the worker may drain (and decrement)
        // immediately, so incrementing afterwards could underflow the
        // gauge. Roll back on rejection.
        self.shared.add_pooled(n);
        match self.data.try_send(SessionInsert { seq: self.next_seq, values }) {
            Ok(()) => {
                self.next_seq += 1;
                self.accepted_values += n as u64;
                if self.eager {
                    let _ = self.tx.send(Envelope::Poke);
                }
                Admission::Accepted { seq: self.next_seq - 1, session_values: self.accepted_values }
            }
            Err(TrySendError::Full(ins)) => {
                self.shared.sub_pooled(n);
                self.shared.add_shed();
                Admission::Rejected { retry_after_hint: self.retry_after, values: ins.values }
            }
            Err(TrySendError::Disconnected(ins)) => {
                self.shared.sub_pooled(n);
                Admission::Closed { values: ins.values }
            }
        }
    }

    /// Admission with **bounded** sleep-and-retry: up to `max_attempts`
    /// admission tries, honouring the worker's `retry_after_hint`
    /// between them (capped at [`Self::RETRY_SLEEP_CAP`] so a
    /// misconfigured hint cannot park the caller indefinitely). Returns
    /// the final admission plus how many times this request was shed
    /// along the way. Terminal outcomes:
    ///
    /// * `Accepted` — admitted within the bound;
    /// * `Closed` — the coordinator stopped (payload handed back);
    /// * [`Admission::Exhausted`] — every one of `max_attempts` tries
    ///   was shed; the payload is handed back untouched and every shed
    ///   is ledgered. The loop can never spin forever.
    ///
    /// Under [`MergePolicy::AtBarrier`] a full channel only drains at a
    /// sync point, so callers must size `queue_requests` to cover a full
    /// between-barriers burst — this helper surfaces an
    /// under-provisioned window as `Exhausted` rather than unsticking
    /// (or livelocking on) it.
    pub fn insert_retrying(&mut self, values: Vec<f32>, max_attempts: u32) -> (Admission, u64) {
        let max_attempts = max_attempts.max(1);
        let mut sheds = 0u64;
        let mut payload = values;
        loop {
            match self.try_insert(payload) {
                Admission::Rejected { retry_after_hint, values } => {
                    sheds += 1;
                    if sheds >= u64::from(max_attempts) {
                        return (Admission::Exhausted { attempts: max_attempts, values }, sheds);
                    }
                    payload = values;
                    thread::sleep(retry_after_hint.min(Self::RETRY_SLEEP_CAP));
                }
                done => return (done, sheds),
            }
        }
    }

    /// Upper bound on one retry back-off sleep in
    /// [`ClientSession::insert_retrying`]: the configured hint is
    /// honoured up to this cap, which only guards against a pathological
    /// `retry_after` configuration stalling the caller for seconds.
    pub const RETRY_SLEEP_CAP: Duration = Duration::from_millis(50);

    /// Synchronous request on the control channel (same contract as
    /// `Client::call`). Seal/flatten/work/stats/clear are sync points:
    /// the worker drains every registered client pool before serving
    /// them, so this session's accepted inserts are always visible to
    /// its own subsequent sync calls.
    ///
    /// A dead worker — stopped, or crashed mid-request so the reply
    /// sender dropped unanswered — surfaces as the typed
    /// `Response::Failed(ServiceDown)`; a session never hangs on a
    /// vanished coordinator.
    pub fn call(&self, req: Request) -> Response {
        let (rtx, rrx) = mpsc::channel();
        if self.tx.send(Envelope::Call(req, rtx)).is_err() {
            return Response::Failed(ExecError::ServiceDown);
        }
        rrx.recv().unwrap_or_else(|_| Response::Failed(ExecError::ServiceDown))
    }
}

#[cfg(test)]
mod tests {
    use super::super::request::{checksum, Request};
    use super::super::service::{Coordinator, CoordinatorConfig};
    use super::*;

    fn frontend_cfg(merge: MergePolicy) -> CoordinatorConfig {
        CoordinatorConfig {
            blocks: 8,
            shards: 1,
            first_bucket_size: 16,
            use_artifacts: false,
            frontend: FrontendConfig { queue_requests: 8, merge, ..FrontendConfig::default() },
            ..CoordinatorConfig::default()
        }
    }

    #[test]
    fn session_ids_monotonic_and_counted() {
        let c = Coordinator::start(frontend_cfg(MergePolicy::Eager));
        let a = c.session();
        let b = c.session();
        assert_eq!(a.id(), 0);
        assert_eq!(b.id(), 1);
        let snap = c.call(Request::Stats).expect_stats();
        assert_eq!(snap.sessions, 2);
        c.shutdown();
    }

    #[test]
    fn at_barrier_merges_in_client_id_order() {
        // Session 1 admits first, session 0 second — AtBarrier still
        // merges client 0 before client 1 at the flatten barrier, so the
        // layout matches the deterministic merge order, not wall time.
        let c = Coordinator::start(frontend_cfg(MergePolicy::AtBarrier));
        let mut s0 = c.session();
        let mut s1 = c.session();
        let (seq, total) = s1.try_insert(vec![10.0, 11.0]).expect_accepted();
        assert_eq!((seq, total), (0, 2));
        let (seq, total) = s0.try_insert(vec![1.0, 2.0]).expect_accepted();
        assert_eq!((seq, total), (0, 2));
        match s0.call(Request::Flatten) {
            Response::Flattened { len, checksum: got, .. } => {
                assert_eq!(len, 4);
                assert_eq!(got, checksum(&[1.0, 2.0, 10.0, 11.0]));
            }
            other => panic!("flatten failed: {other:?}"),
        }
        assert_eq!(s0.accepted_values(), 2);
        assert_eq!(s1.accepted_values(), 2);
        c.shutdown();
    }

    #[test]
    fn session_insert_then_own_sync_call_sees_data() {
        let c = Coordinator::start(frontend_cfg(MergePolicy::Eager));
        let mut s = c.session();
        for i in 0..4 {
            let adm = s.try_insert(vec![i as f32; 8]);
            assert!(adm.is_accepted(), "unexpected admission: {adm:?}");
        }
        assert_eq!(s.next_seq(), 4);
        let snap = s.call(Request::Stats).expect_stats();
        assert_eq!(snap.len, 32);
        assert_eq!(snap.admitted_requests, 4);
        assert_eq!(snap.admitted_values, 32);
        assert_eq!(snap.shed_requests, 0);
        c.shutdown();
    }

    #[test]
    fn closed_coordinator_hands_payload_back() {
        let c = Coordinator::start(frontend_cfg(MergePolicy::Eager));
        let mut s = c.session();
        c.shutdown();
        match s.try_insert(vec![1.0, 2.0, 3.0]) {
            Admission::Closed { values } => assert_eq!(values, vec![1.0, 2.0, 3.0]),
            other => panic!("expected Closed, got {other:?}"),
        }
        assert!(matches!(s.call(Request::Stats), Response::Failed(ExecError::ServiceDown)));
    }

    /// The CHANGES.md "watch" item pinned as a test: a `Rejected`
    /// admission must leave the pooled-values gauge, the session's
    /// sequence counter, and the shed ledger exactly consistent — no
    /// leaked gauge, no consumed seq, exactly one shed. Deterministic
    /// (worker-less rig, explicit drain); the `ggcheck` model suite
    /// re-checks the same invariants under every bounded interleaving.
    #[test]
    fn rejected_admission_rolls_back_ledgers_exactly() {
        let cfg = FrontendConfig {
            queue_requests: 2,
            merge: MergePolicy::AtBarrier,
            ..FrontendConfig::default()
        };
        let mut rig = FrontendRig::new(cfg);
        let mut s = rig.session();
        assert!(s.try_insert(vec![1.0; 3]).is_accepted());
        assert!(s.try_insert(vec![2.0; 4]).is_accepted());
        assert_eq!(rig.shared().pooled_values(), 7);
        assert_eq!(s.next_seq(), 2);

        // Window full: the third insert sheds. Payload handed back,
        // gauge rolled back, no sequence number consumed, one shed.
        match s.try_insert(vec![3.0; 5]) {
            Admission::Rejected { values, .. } => assert_eq!(values, vec![3.0; 5]),
            other => panic!("expected Rejected, got {other:?}"),
        }
        assert_eq!(rig.shared().pooled_values(), 7, "rejected values must not stay pooled");
        assert_eq!(rig.shared().shed_total(), 1);
        assert_eq!(s.next_seq(), 2, "a rejection consumes no sequence number");
        assert_eq!(s.accepted_values(), 7);

        // Barrier drain: exactly the accepted stream arrives, gap-free,
        // and the gauge returns to zero.
        let mut got = Vec::new();
        let stats = rig.drain(true, |id, ins| got.push((id, ins.seq, ins.values.len())));
        assert_eq!(stats.moved_requests, 2);
        assert_eq!(stats.moved_values, 7);
        assert_eq!(stats.productive_sweeps, 1);
        assert_eq!(got, vec![(0, 0, 3), (0, 1, 4)]);
        assert_eq!(rig.shared().pooled_values(), 0);
        assert_eq!(rig.lanes(), 1);

        // Window freed: the next insert takes the next seq; the shed
        // ledger is monotonic.
        let (seq, _) = s.try_insert(vec![4.0; 2]).expect_accepted();
        assert_eq!(seq, 2);
        assert_eq!(rig.shared().shed_total(), 1);
    }

    /// The retry helper must terminate: against a window nobody drains
    /// (worker-less rig, no `drain` call), `insert_retrying` performs
    /// exactly `max_attempts` admissions, ledgers every shed, and hands
    /// the payload back as the typed `Exhausted` outcome — no unbounded
    /// spin, no silent drop, no consumed sequence number.
    #[test]
    fn insert_retrying_exhausts_with_payload_after_the_bound() {
        let cfg = FrontendConfig {
            queue_requests: 1,
            retry_after: Duration::from_micros(50),
            merge: MergePolicy::AtBarrier,
        };
        let rig = FrontendRig::new(cfg);
        let mut s = rig.session();
        assert!(s.try_insert(vec![1.0; 4]).is_accepted());

        let (adm, sheds) = s.insert_retrying(vec![2.0; 3], 5);
        match adm {
            Admission::Exhausted { attempts, values } => {
                assert_eq!(attempts, 5);
                assert_eq!(values, vec![2.0; 3], "exhaustion hands the payload back");
            }
            other => panic!("expected Exhausted, got {other:?}"),
        }
        assert_eq!(sheds, 5, "every attempt was shed");
        assert_eq!(rig.shared().shed_total(), 5, "each shed is ledgered");
        assert_eq!(s.next_seq(), 1, "exhaustion consumes no sequence number");
        assert_eq!(rig.shared().pooled_values(), 4, "only the accepted payload stays pooled");

        // A zero bound still performs one admission (the bound is an
        // attempt count, not a retry count).
        let (adm, sheds) = s.insert_retrying(vec![3.0; 2], 0);
        assert!(matches!(adm, Admission::Exhausted { attempts: 1, .. }));
        assert_eq!(sheds, 1);
    }

    /// Once capacity exists, a bounded retry succeeds without burning
    /// the whole budget and reports how many sheds it survived.
    #[test]
    fn insert_retrying_accepts_within_the_bound() {
        let cfg = FrontendConfig {
            queue_requests: 2,
            retry_after: Duration::from_micros(50),
            merge: MergePolicy::AtBarrier,
        };
        let mut rig = FrontendRig::new(cfg);
        let mut s = rig.session();
        let (adm, sheds) = s.insert_retrying(vec![1.0; 4], 3);
        assert!(adm.is_accepted());
        assert_eq!(sheds, 0);

        // Fill the window, then free it and verify the next bounded
        // retry lands on the recovered capacity.
        assert!(s.try_insert(vec![2.0; 4]).is_accepted());
        let mut moved = 0u64;
        let stats = rig.drain(true, |_, _| moved += 1);
        assert_eq!(stats.moved_requests, 2);
        assert_eq!(moved, 2);
        let (adm, sheds) = s.insert_retrying(vec![3.0; 4], 3);
        let (seq, _) = adm.expect_accepted();
        assert_eq!(seq, 2);
        assert_eq!(sheds, 0);
    }
}
