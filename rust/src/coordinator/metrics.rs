//! Service metrics: request counters, simulated-time ledger, wall-clock
//! latency summaries.

use std::time::Instant;

use crate::util::stats::Welford;

/// Live metrics owned by the service worker.
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    pub inserts_requested: u64,
    pub elements_inserted: u64,
    pub batches: u64,
    pub work_calls: u64,
    pub flattens: u64,
    /// Epoch seals performed (two-phase lifecycle).
    pub seals: u64,
    pub queries: u64,
    pub errors: u64,
    pub pjrt_executions: u64,
    /// Simulated GPU µs per op class.
    pub sim_insert_us: f64,
    pub sim_work_us: f64,
    pub sim_flatten_us: f64,
    /// Wall-clock per-request latency (µs).
    latency: Welford,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            inserts_requested: 0,
            elements_inserted: 0,
            batches: 0,
            work_calls: 0,
            flattens: 0,
            seals: 0,
            queries: 0,
            errors: 0,
            pjrt_executions: 0,
            sim_insert_us: 0.0,
            sim_work_us: 0.0,
            sim_flatten_us: 0.0,
            latency: Welford::new(),
        }
    }

    pub fn observe_latency_us(&mut self, us: f64) {
        self.latency.push(us);
    }

    pub fn snapshot(&self, len: u64, capacity: u64, allocated_bytes: u64) -> MetricsSnapshot {
        MetricsSnapshot {
            uptime_s: self.started.elapsed().as_secs_f64(),
            inserts_requested: self.inserts_requested,
            elements_inserted: self.elements_inserted,
            batches: self.batches,
            work_calls: self.work_calls,
            flattens: self.flattens,
            seals: self.seals,
            queries: self.queries,
            errors: self.errors,
            pjrt_executions: self.pjrt_executions,
            sim_insert_ms: self.sim_insert_us / 1e3,
            sim_work_ms: self.sim_work_us / 1e3,
            sim_flatten_ms: self.sim_flatten_us / 1e3,
            mean_latency_us: self.latency.mean(),
            p_latency_count: self.latency.count(),
            len,
            capacity,
            allocated_bytes,
            // Sharding/epoch context defaults to a single-shard store;
            // sharded services attach theirs via
            // [`MetricsSnapshot::with_sharding`].
            shards: 1,
            epoch: 0,
            sealed_len: 0,
            per_shard_len: Vec::new(),
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Immutable snapshot returned by `Request::Stats`.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub uptime_s: f64,
    pub inserts_requested: u64,
    pub elements_inserted: u64,
    pub batches: u64,
    pub work_calls: u64,
    pub flattens: u64,
    pub seals: u64,
    pub queries: u64,
    pub errors: u64,
    pub pjrt_executions: u64,
    pub sim_insert_ms: f64,
    pub sim_work_ms: f64,
    pub sim_flatten_ms: f64,
    pub mean_latency_us: f64,
    pub p_latency_count: u64,
    pub len: u64,
    pub capacity: u64,
    pub allocated_bytes: u64,
    /// Number of GGArray shards behind the service.
    pub shards: usize,
    /// Current inserting-epoch sequence number.
    pub epoch: u64,
    /// Elements in the sealed (flat, fast-access) prefix.
    pub sealed_len: u64,
    /// Live-epoch elements per shard (aggregated OpReports land in the
    /// sim_* ledgers; this exposes the balance).
    pub per_shard_len: Vec<u64>,
}

impl MetricsSnapshot {
    /// Attach the shard/epoch context in one step (the raw counters are
    /// shard-agnostic, so `snapshot()` cannot fill these itself).
    pub fn with_sharding(
        mut self,
        shards: usize,
        epoch: u64,
        sealed_len: u64,
        per_shard_len: Vec<u64>,
    ) -> MetricsSnapshot {
        self.shards = shards;
        self.epoch = epoch;
        self.sealed_len = sealed_len;
        self.per_shard_len = per_shard_len;
        self
    }

    /// Memory overhead vs live data (the paper's ≤2× claim, observable
    /// live).
    pub fn overhead_ratio(&self) -> f64 {
        if self.len == 0 {
            return f64::NAN;
        }
        self.allocated_bytes as f64 / (self.len * 4) as f64
    }

    /// Mean batching effectiveness.
    pub fn coalescing(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.inserts_requested as f64 / self.batches as f64
        }
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "uptime               {:.2}s", self.uptime_s)?;
        writeln!(f, "insert requests      {}", self.inserts_requested)?;
        writeln!(f, "elements inserted    {}", self.elements_inserted)?;
        writeln!(f, "batches (coalescing) {} ({:.1}×)", self.batches, self.coalescing())?;
        writeln!(f, "work calls           {}", self.work_calls)?;
        writeln!(f, "flattens / seals     {} / {}", self.flattens, self.seals)?;
        writeln!(f, "queries              {}", self.queries)?;
        writeln!(f, "errors               {}", self.errors)?;
        writeln!(f, "PJRT executions      {}", self.pjrt_executions)?;
        writeln!(f, "sim insert/work/flat {:.2} / {:.2} / {:.2} ms", self.sim_insert_ms, self.sim_work_ms, self.sim_flatten_ms)?;
        writeln!(f, "mean request latency {:.1} µs over {}", self.mean_latency_us, self.p_latency_count)?;
        writeln!(
            f,
            "shards / epoch       {} / {} (sealed prefix {} elements)",
            self.shards, self.epoch, self.sealed_len
        )?;
        writeln!(f, "len / capacity       {} / {}", self.len, self.capacity)?;
        write!(f, "allocated            {} (overhead {:.2}×)", crate::util::tables::fmt_bytes(self.allocated_bytes), self.overhead_ratio())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_carries_counters() {
        let mut m = Metrics::new();
        m.inserts_requested = 10;
        m.batches = 4;
        m.elements_inserted = 1000;
        m.observe_latency_us(50.0);
        m.observe_latency_us(150.0);
        let s = m.snapshot(1000, 2000, 8000);
        assert_eq!(s.inserts_requested, 10);
        assert!((s.coalescing() - 2.5).abs() < 1e-12);
        assert!((s.mean_latency_us - 100.0).abs() < 1e-9);
        assert!((s.overhead_ratio() - 2.0).abs() < 1e-12);
        let text = s.to_string();
        assert!(text.contains("overhead 2.00×"));
    }

    #[test]
    fn empty_overhead_is_nan() {
        let m = Metrics::new();
        assert!(m.snapshot(0, 0, 0).overhead_ratio().is_nan());
    }
}
