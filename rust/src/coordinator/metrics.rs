//! Service metrics: request counters, simulated-time ledger, wall-clock
//! latency summaries.
//!
//! The simulated-time ledger uses the **parallel time model**: shards are
//! thread-block groups of one device executing concurrently, so an
//! operation's wall-model cost is the *max* over the participating
//! shards' clock deltas (the critical path) plus any serial coordinator
//! term — not the sum. The sum survives as `device_*` totals
//! (device-seconds of work issued), and the two together give the
//! shard-parallel utilisation. [`ParallelCost`] carries both.

use std::time::Instant;

use crate::coordinator::scheduler::GroupCounters;
use crate::util::stats::Welford;

/// Simulated cost of one service operation under the parallel time
/// model.
///
/// * `critical_path_us` — the wall-model: serial coordinator work plus
///   the slowest participating shard (shards run concurrently on the
///   device, DynaSOAr-style, so the op completes when the last one
///   does).
/// * `total_device_us` — aggregate device-seconds: the *sum* of every
///   participant's delta plus the serial term. This is what the ledger
///   summed (incorrectly, as wall time) before the parallel model.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ParallelCost {
    pub critical_path_us: f64,
    pub total_device_us: f64,
}

impl ParallelCost {
    pub fn zero() -> ParallelCost {
        ParallelCost::default()
    }

    /// A purely serial cost (coordinator-side work: routing, sync,
    /// single-kernel passes over the sealed store).
    pub fn serial(us: f64) -> ParallelCost {
        ParallelCost { critical_path_us: us, total_device_us: us }
    }

    /// Fold per-shard clock deltas executed *concurrently*: the critical
    /// path is the slowest shard, the device total is the sum.
    pub fn from_parallel(deltas: impl IntoIterator<Item = f64>) -> ParallelCost {
        let mut cost = ParallelCost::zero();
        for d in deltas {
            cost.critical_path_us = cost.critical_path_us.max(d);
            cost.total_device_us += d;
        }
        cost
    }

    /// Sequential composition: `other` starts after `self` finishes
    /// (e.g. the sealed-store pass launched behind the shard kernels).
    pub fn then(self, other: ParallelCost) -> ParallelCost {
        ParallelCost {
            critical_path_us: self.critical_path_us + other.critical_path_us,
            total_device_us: self.total_device_us + other.total_device_us,
        }
    }

    /// Parallel speedup exposed by the op: device-seconds issued per
    /// wall-model second (1.0 = fully serial, S = perfect S-shard
    /// scaling). `None` before anything was charged — callers used to
    /// receive a silent `0/0 = NaN` here.
    pub fn speedup(&self) -> Option<f64> {
        if self.critical_path_us > 0.0 {
            Some(self.total_device_us / self.critical_path_us)
        } else {
            None
        }
    }
}

/// Fixed-footprint log2 latency histogram: 64 power-of-two microsecond
/// buckets plus the exact observed max. Bucket `i ≥ 1` holds
/// observations in `[2^(i-1), 2^i)` µs (bucket 0 holds exact zeros), so
/// a percentile query returns the upper edge of the rank's bucket —
/// clamped to the true max — and therefore never *under*-reports a
/// tail. That one-sided error is what lets chaos runs assert hard
/// lower bounds ("p99 ≥ the injected stall") without a full reservoir.
#[derive(Debug, Clone)]
struct LatencyHistogram {
    buckets: [u64; 64],
    count: u64,
    max_us: u64,
}

impl LatencyHistogram {
    fn new() -> LatencyHistogram {
        LatencyHistogram { buckets: [0; 64], count: 0, max_us: 0 }
    }

    fn bucket(us: u64) -> usize {
        if us == 0 {
            0
        } else {
            (64 - us.leading_zeros() as usize).min(63)
        }
    }

    fn push(&mut self, us: f64) {
        let us = us.max(0.0) as u64;
        self.buckets[Self::bucket(us)] += 1;
        self.count += 1;
        self.max_us = self.max_us.max(us);
    }

    /// Upper-bound estimate of the `p`-quantile (0 < p ≤ 1): the upper
    /// edge of the bucket holding the rank-⌈p·count⌉ observation,
    /// clamped to the exact observed max. Zero before any observation.
    fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                let edge = if i == 0 { 0 } else { (1u64 << i) - 1 };
                return edge.min(self.max_us);
            }
        }
        self.max_us
    }
}

/// Live metrics owned by the service worker.
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    pub inserts_requested: u64,
    pub elements_inserted: u64,
    /// Frontend-admitted insert requests the worker has merged out of the
    /// client pools (each also counts once in `inserts_requested`).
    pub admitted_requests: u64,
    /// Values carried by those merged requests.
    pub admitted_values: u64,
    /// Frontend drain sweeps that moved at least one pooled request into
    /// the batcher (febft-style "proposed batch" formations).
    pub proposals: u64,
    pub batches: u64,
    pub work_calls: u64,
    pub flattens: u64,
    /// Epoch seals performed (two-phase lifecycle).
    pub seals: u64,
    pub queries: u64,
    pub errors: u64,
    pub pjrt_executions: u64,
    /// Sealed-segment compaction passes performed.
    pub compactions: u64,
    /// Compaction attempts aborted because the epoch heap could not hold
    /// the gather's transient 2× residency (segments retained).
    pub compaction_ooms: u64,
    /// Simulated wall-model (critical-path) µs per op class — shards
    /// execute concurrently, so these are max-over-shards, not sums.
    pub sim_insert_us: f64,
    pub sim_work_us: f64,
    pub sim_flatten_us: f64,
    /// Aggregate device-seconds per op class (sum over shards) — the
    /// utilisation companion to the `sim_*` wall-model.
    pub device_insert_us: f64,
    pub device_work_us: f64,
    pub device_flatten_us: f64,
    /// *Measured* host wall-clock µs per op class — the time the worker
    /// actually spent in the shard-dispatching section (executor-pool
    /// fan-out + barrier, or the serial loop). Where `sim_*` is the
    /// modeled critical path and `device_*` the modeled sum, `wall_*` is
    /// what the machine really did: with the pool enabled it should
    /// scale like `sim_*` across shard counts, and a pooled-vs-serial
    /// comparison of the same workload is the *measured* shard speedup
    /// (`bench_hotpath` records it as the 4-vs-1 columns; seal wall time
    /// lands in `wall_flatten_us`, mirroring the sim ledger).
    pub wall_insert_us: f64,
    pub wall_work_us: f64,
    pub wall_flatten_us: f64,
    /// Service-worker restarts performed by the supervisor after a
    /// loop-level panic (each one respawned the handler loop over the
    /// surviving store state).
    pub worker_restarts: u64,
    /// Un-acked requests the supervisor replayed exactly once after a
    /// worker restart.
    pub replayed_requests: u64,
    /// Wall-clock per-request latency (µs): mean via Welford, tail via
    /// the log2 histogram (p50/p99/max) — the straggler-injection
    /// contract asserts against the tail ledger.
    latency: Welford,
    latency_hist: LatencyHistogram,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            inserts_requested: 0,
            elements_inserted: 0,
            admitted_requests: 0,
            admitted_values: 0,
            proposals: 0,
            batches: 0,
            work_calls: 0,
            flattens: 0,
            seals: 0,
            queries: 0,
            errors: 0,
            pjrt_executions: 0,
            compactions: 0,
            compaction_ooms: 0,
            sim_insert_us: 0.0,
            sim_work_us: 0.0,
            sim_flatten_us: 0.0,
            device_insert_us: 0.0,
            device_work_us: 0.0,
            device_flatten_us: 0.0,
            wall_insert_us: 0.0,
            wall_work_us: 0.0,
            wall_flatten_us: 0.0,
            worker_restarts: 0,
            replayed_requests: 0,
            latency: Welford::new(),
            latency_hist: LatencyHistogram::new(),
        }
    }

    pub fn observe_latency_us(&mut self, us: f64) {
        self.latency.push(us);
        self.latency_hist.push(us);
    }

    /// Charge one op's [`ParallelCost`] to the insert ledger.
    pub fn charge_insert(&mut self, cost: ParallelCost) {
        self.sim_insert_us += cost.critical_path_us;
        self.device_insert_us += cost.total_device_us;
    }

    /// Charge one op's [`ParallelCost`] to the work ledger.
    pub fn charge_work(&mut self, cost: ParallelCost) {
        self.sim_work_us += cost.critical_path_us;
        self.device_work_us += cost.total_device_us;
    }

    /// Charge one op's [`ParallelCost`] to the flatten/seal ledger.
    pub fn charge_flatten(&mut self, cost: ParallelCost) {
        self.sim_flatten_us += cost.critical_path_us;
        self.device_flatten_us += cost.total_device_us;
    }

    pub fn snapshot(&self, len: u64, capacity: u64, allocated_bytes: u64) -> MetricsSnapshot {
        MetricsSnapshot {
            uptime_s: self.started.elapsed().as_secs_f64(),
            inserts_requested: self.inserts_requested,
            elements_inserted: self.elements_inserted,
            admitted_requests: self.admitted_requests,
            admitted_values: self.admitted_values,
            proposals: self.proposals,
            batches: self.batches,
            work_calls: self.work_calls,
            flattens: self.flattens,
            seals: self.seals,
            queries: self.queries,
            errors: self.errors,
            pjrt_executions: self.pjrt_executions,
            compactions: self.compactions,
            compaction_ooms: self.compaction_ooms,
            sim_insert_ms: self.sim_insert_us / 1e3,
            sim_work_ms: self.sim_work_us / 1e3,
            sim_flatten_ms: self.sim_flatten_us / 1e3,
            device_insert_ms: self.device_insert_us / 1e3,
            device_work_ms: self.device_work_us / 1e3,
            device_flatten_ms: self.device_flatten_us / 1e3,
            wall_insert_ms: self.wall_insert_us / 1e3,
            wall_work_ms: self.wall_work_us / 1e3,
            wall_flatten_ms: self.wall_flatten_us / 1e3,
            mean_latency_us: self.latency.mean(),
            p_latency_count: self.latency.count(),
            p50_latency_us: self.latency_hist.percentile(0.50),
            p99_latency_us: self.latency_hist.percentile(0.99),
            max_latency_us: self.latency_hist.max_us,
            worker_restarts: self.worker_restarts,
            replayed_requests: self.replayed_requests,
            len,
            capacity,
            allocated_bytes,
            // Sharding/epoch context defaults to a single-shard store;
            // sharded services attach theirs via
            // [`MetricsSnapshot::with_sharding`].
            shards: 1,
            epoch: 0,
            sealed_len: 0,
            sealed_segments: 0,
            sealed_bytes: 0,
            heap_used_bytes: 0,
            per_shard_len: Vec::new(),
            // Batcher ledger defaults to zero; the worker attaches the
            // real counters via [`MetricsSnapshot::with_batching`].
            flushes: 0,
            coalesced_requests: 0,
            // Serial execution unless the worker attaches its scheduler
            // via [`MetricsSnapshot::with_executors`]; the
            // steal/park/chunk ledger stays zeroed until
            // [`MetricsSnapshot::with_scheduler`].
            executors: 1,
            steals: 0,
            parks: 0,
            chunks_executed: 0,
            spawn_failures: 0,
            worker_respawns: 0,
            degraded_workers: 0,
            // Frontend session/shed context defaults to "no sessions";
            // the worker attaches the shared admission ledger via
            // [`MetricsSnapshot::with_frontend`].
            sessions: 0,
            shed_requests: 0,
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Immutable snapshot returned by `Request::Stats`.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub uptime_s: f64,
    pub inserts_requested: u64,
    pub elements_inserted: u64,
    /// Frontend-admitted insert requests merged out of client pools
    /// (subset of `inserts_requested`; `Request::Insert` calls on the
    /// legacy single-producer path don't count here).
    pub admitted_requests: u64,
    /// Values carried by those merged requests.
    pub admitted_values: u64,
    /// Frontend drain sweeps that moved pooled requests into the batcher.
    pub proposals: u64,
    pub batches: u64,
    pub work_calls: u64,
    pub flattens: u64,
    pub seals: u64,
    pub queries: u64,
    pub errors: u64,
    pub pjrt_executions: u64,
    /// Sealed-segment compaction passes performed.
    pub compactions: u64,
    /// Compaction attempts aborted on the epoch heap's transient 2×.
    pub compaction_ooms: u64,
    /// Wall-model (critical-path) simulated ms per op class.
    pub sim_insert_ms: f64,
    pub sim_work_ms: f64,
    pub sim_flatten_ms: f64,
    /// Aggregate device-seconds (sum-over-shards) ms per op class.
    pub device_insert_ms: f64,
    pub device_work_ms: f64,
    pub device_flatten_ms: f64,
    /// Measured host wall-clock ms per op class (the shard-dispatching
    /// sections only — fan-out + barrier, or the serial loop). Seal wall
    /// time lands in `wall_flatten_ms`, mirroring the sim ledger. See
    /// EXPERIMENTS.md §Perf "measured vs modeled parallelism".
    pub wall_insert_ms: f64,
    pub wall_work_ms: f64,
    pub wall_flatten_ms: f64,
    pub mean_latency_us: f64,
    pub p_latency_count: u64,
    /// Tail-latency ledger from the worker's log2 histogram (µs).
    /// Percentiles are bucket-upper-edge estimates clamped to the true
    /// max — never under the real quantile — so chaos runs can assert
    /// "p99 ≥ injected stall" deterministically.
    pub p50_latency_us: u64,
    pub p99_latency_us: u64,
    pub max_latency_us: u64,
    /// Service-worker restarts performed by the supervisor (transparent
    /// failover after a loop-level panic).
    pub worker_restarts: u64,
    /// Un-acked requests replayed exactly once across those restarts.
    pub replayed_requests: u64,
    pub len: u64,
    pub capacity: u64,
    pub allocated_bytes: u64,
    /// Number of GGArray shards behind the service.
    pub shards: usize,
    /// Current inserting-epoch sequence number.
    pub epoch: u64,
    /// Elements in the sealed (flat, fast-access) prefix.
    pub sealed_len: u64,
    /// Flat segments currently backing the sealed prefix (compaction
    /// keeps this bounded).
    pub sealed_segments: usize,
    /// Bytes held by the epoch-owned sealed store's heap.
    pub sealed_bytes: u64,
    /// Total simulated VRAM in use: per-shard heaps (live-epoch buckets)
    /// plus the epoch-owned sealed store — the conservation companion to
    /// `allocated_bytes` (every heap byte is accounted to a live
    /// structure, and vice versa).
    pub heap_used_bytes: u64,
    /// Live-epoch elements per shard (aggregated OpReports land in the
    /// sim_* ledgers; this exposes the balance).
    pub per_shard_len: Vec<u64>,
    /// Batcher flushes performed (size, deadline and barrier flushes).
    pub flushes: u64,
    /// Client requests coalesced across those flushes — the batcher's
    /// own ledger, as opposed to the worker-side `batches` counter.
    pub coalesced_requests: u64,
    /// Shard-executor threads behind the worker: 1 = serial execution on
    /// the worker thread, N = N persistent work-stealing workers
    /// ([`crate::coordinator::scheduler::Scheduler`]; the worker count
    /// is decoupled from the shard count).
    pub executors: usize,
    /// Chunks a scheduler worker executed from *another* worker's deque
    /// (zero in serial mode and under perfectly balanced load).
    pub steals: u64,
    /// Times a scheduler worker parked on the shared monitor (every
    /// `finish` barrier parks all workers, so this grows with phases).
    pub parks: u64,
    /// Total chunks executed by the scheduler — conserved against the
    /// per-op chunk decomposition (fills + work + gather ranges), see
    /// the scheduler's conservation test.
    pub chunks_executed: u64,
    /// Scheduler worker spawn attempts that failed (construction or
    /// respawn) — the group degrades instead of aborting.
    pub spawn_failures: u64,
    /// Dead scheduler workers successfully respawned after a contained
    /// chunk panic (the self-healing ledger).
    pub worker_respawns: u64,
    /// Scheduler workers permanently lost to failed spawns/respawns;
    /// the group keeps serving down to inline (serial) draining.
    pub degraded_workers: u64,
    /// Client sessions ever opened on the admission frontend.
    pub sessions: u64,
    /// Insert requests shed by admission (typed `Rejected` responses):
    /// the backpressure ledger — every rejection a client observed is
    /// counted here, never dropped silently.
    pub shed_requests: u64,
}

impl MetricsSnapshot {
    /// Attach the shard/epoch context in one step (the raw counters are
    /// shard-agnostic, so `snapshot()` cannot fill these itself).
    pub fn with_sharding(
        mut self,
        shards: usize,
        epoch: u64,
        sealed_len: u64,
        sealed_segments: usize,
        per_shard_len: Vec<u64>,
    ) -> MetricsSnapshot {
        self.shards = shards;
        self.epoch = epoch;
        self.sealed_len = sealed_len;
        self.sealed_segments = sealed_segments;
        self.per_shard_len = per_shard_len;
        self
    }

    /// Attach the memory-accounting context (sealed-store residency and
    /// total heap usage across shard + epoch heaps).
    pub fn with_memory(mut self, sealed_bytes: u64, heap_used_bytes: u64) -> MetricsSnapshot {
        self.sealed_bytes = sealed_bytes;
        self.heap_used_bytes = heap_used_bytes;
        self
    }

    /// Attach the batcher's flush ledger (`coalesced_requests / flushes`
    /// is the batching-effectiveness ratio from the batcher's own
    /// accounting).
    pub fn with_batching(mut self, flushes: u64, coalesced_requests: u64) -> MetricsSnapshot {
        self.flushes = flushes;
        self.coalesced_requests = coalesced_requests;
        self
    }

    /// Attach the shard-executor context (1 = serial worker, N = N
    /// work-stealing scheduler workers).
    pub fn with_executors(mut self, executors: usize) -> MetricsSnapshot {
        self.executors = executors;
        self
    }

    /// Attach the scheduler's steal/park/chunk ledger (zeroed default
    /// for serial mode, where no scheduler exists).
    pub fn with_scheduler(mut self, counters: GroupCounters) -> MetricsSnapshot {
        self.steals = counters.steals;
        self.parks = counters.parks;
        self.chunks_executed = counters.executed;
        self.spawn_failures = counters.spawn_failures;
        self.worker_respawns = counters.worker_respawns;
        self.degraded_workers = counters.degraded_workers;
        self
    }

    /// Attach the admission frontend's shared ledger (session count and
    /// shed-request total live in atomics outside the worker's
    /// [`Metrics`], since sessions update them without a worker round
    /// trip).
    pub fn with_frontend(mut self, sessions: u64, shed_requests: u64) -> MetricsSnapshot {
        self.sessions = sessions;
        self.shed_requests = shed_requests;
        self
    }

    /// Mean requests coalesced per batcher flush (0 before any flush).
    pub fn flush_coalescing(&self) -> f64 {
        if self.flushes == 0 {
            0.0
        } else {
            self.coalesced_requests as f64 / self.flushes as f64
        }
    }

    /// Observed shard-parallel speedup: device-seconds issued per
    /// wall-model second across all op classes (1.0 = serial; up to
    /// `shards` for perfectly balanced dispatch). `None` before any
    /// simulated work — the old `f64` version leaked `0/0 = NaN` to
    /// callers that read stats before the first charged op.
    pub fn parallel_speedup(&self) -> Option<f64> {
        let sim = self.sim_insert_ms + self.sim_work_ms + self.sim_flatten_ms;
        if sim <= 0.0 {
            return None;
        }
        let device = self.device_insert_ms + self.device_work_ms + self.device_flatten_ms;
        Some(device / sim)
    }

    /// Memory overhead vs live data (the paper's ≤2× claim, observable
    /// live).
    pub fn overhead_ratio(&self) -> f64 {
        if self.len == 0 {
            return f64::NAN;
        }
        self.allocated_bytes as f64 / (self.len * 4) as f64
    }

    /// Mean batching effectiveness.
    pub fn coalescing(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.inserts_requested as f64 / self.batches as f64
        }
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "uptime               {:.2}s", self.uptime_s)?;
        writeln!(f, "insert requests      {}", self.inserts_requested)?;
        writeln!(f, "elements inserted    {}", self.elements_inserted)?;
        writeln!(f, "batches (coalescing) {} ({:.1}×)", self.batches, self.coalescing())?;
        writeln!(f, "batcher flushes      {} ({:.1}× coalesced)", self.flushes, self.flush_coalescing())?;
        writeln!(
            f,
            "frontend sessions    {} ({} admitted requests / {} values, {} shed, {} proposals)",
            self.sessions, self.admitted_requests, self.admitted_values, self.shed_requests, self.proposals
        )?;
        writeln!(f, "work calls           {}", self.work_calls)?;
        writeln!(f, "flattens / seals     {} / {}", self.flattens, self.seals)?;
        writeln!(f, "queries              {}", self.queries)?;
        writeln!(f, "errors               {}", self.errors)?;
        writeln!(f, "PJRT executions      {}", self.pjrt_executions)?;
        writeln!(f, "sim insert/work/flat {:.2} / {:.2} / {:.2} ms (critical path)", self.sim_insert_ms, self.sim_work_ms, self.sim_flatten_ms)?;
        writeln!(
            f,
            "device insert/work/flat {:.2} / {:.2} / {:.2} ms (speedup {})",
            self.device_insert_ms,
            self.device_work_ms,
            self.device_flatten_ms,
            match self.parallel_speedup() {
                Some(s) => format!("{s:.2}×"),
                None => "—".into(),
            }
        )?;
        writeln!(
            f,
            "wall insert/work/flat {:.2} / {:.2} / {:.2} ms (measured, {} executor{})",
            self.wall_insert_ms,
            self.wall_work_ms,
            self.wall_flatten_ms,
            self.executors,
            if self.executors == 1 { ": serial" } else { "s: scheduled" }
        )?;
        writeln!(
            f,
            "scheduler ledger     {} chunks ({} steals, {} parks; {} respawns, {} degraded, {} spawn failures)",
            self.chunks_executed,
            self.steals,
            self.parks,
            self.worker_respawns,
            self.degraded_workers,
            self.spawn_failures
        )?;
        writeln!(
            f,
            "mean request latency {:.1} µs over {} (p50 {} / p99 {} / max {} µs)",
            self.mean_latency_us, self.p_latency_count, self.p50_latency_us, self.p99_latency_us, self.max_latency_us
        )?;
        writeln!(
            f,
            "supervisor           {} worker restarts, {} replayed requests",
            self.worker_restarts, self.replayed_requests
        )?;
        writeln!(
            f,
            "shards / epoch       {} / {} (sealed prefix {} elements in {} segments, {} compactions, {} compaction OOMs)",
            self.shards, self.epoch, self.sealed_len, self.sealed_segments, self.compactions, self.compaction_ooms
        )?;
        writeln!(
            f,
            "heap in use          {} ({} sealed, epoch-owned)",
            crate::util::tables::fmt_bytes(self.heap_used_bytes),
            crate::util::tables::fmt_bytes(self.sealed_bytes)
        )?;
        writeln!(f, "len / capacity       {} / {}", self.len, self.capacity)?;
        write!(f, "allocated            {} (overhead {:.2}×)", crate::util::tables::fmt_bytes(self.allocated_bytes), self.overhead_ratio())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_carries_counters() {
        let mut m = Metrics::new();
        m.inserts_requested = 10;
        m.batches = 4;
        m.elements_inserted = 1000;
        m.observe_latency_us(50.0);
        m.observe_latency_us(150.0);
        let s = m.snapshot(1000, 2000, 8000);
        assert_eq!(s.inserts_requested, 10);
        assert!((s.coalescing() - 2.5).abs() < 1e-12);
        assert!((s.mean_latency_us - 100.0).abs() < 1e-9);
        assert!((s.overhead_ratio() - 2.0).abs() < 1e-12);
        let text = s.to_string();
        assert!(text.contains("overhead 2.00×"));
    }

    #[test]
    fn empty_overhead_is_nan() {
        let m = Metrics::new();
        assert!(m.snapshot(0, 0, 0).overhead_ratio().is_nan());
    }

    #[test]
    fn parallel_cost_folds_max_and_sum() {
        let c = ParallelCost::from_parallel([10.0, 4.0, 7.0]);
        assert_eq!(c.critical_path_us, 10.0);
        assert_eq!(c.total_device_us, 21.0);
        assert!((c.speedup().unwrap() - 2.1).abs() < 1e-12);
        // Sequential composition adds both components.
        let s = c.then(ParallelCost::serial(5.0));
        assert_eq!(s.critical_path_us, 15.0);
        assert_eq!(s.total_device_us, 26.0);
        assert_eq!(ParallelCost::from_parallel([]), ParallelCost::zero());
    }

    #[test]
    fn speedup_is_none_before_any_charge() {
        // Regression: 0/0 used to leak NaN to every caller except
        // Display's is_finite guard.
        assert_eq!(ParallelCost::zero().speedup(), None);
        let m = Metrics::new();
        let s = m.snapshot(0, 0, 0);
        assert_eq!(s.parallel_speedup(), None);
        // And the Display path renders the em-dash placeholder, not NaN.
        assert!(s.to_string().contains("speedup —"), "{s}");
    }

    #[test]
    fn ledger_separates_critical_path_from_device_totals() {
        let mut m = Metrics::new();
        m.charge_insert(ParallelCost { critical_path_us: 100.0, total_device_us: 400.0 });
        m.charge_work(ParallelCost { critical_path_us: 50.0, total_device_us: 50.0 });
        let s = m.snapshot(10, 10, 40);
        assert!((s.sim_insert_ms - 0.1).abs() < 1e-12);
        assert!((s.device_insert_ms - 0.4).abs() < 1e-12);
        assert!((s.sim_work_ms - 0.05).abs() < 1e-12);
        // Speedup over both classes: 450 device µs in 150 wall µs.
        assert!((s.parallel_speedup().unwrap() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn with_batching_attaches_flush_ledger() {
        let m = Metrics::new();
        let s = m.snapshot(10, 20, 400).with_batching(4, 10);
        assert_eq!(s.flushes, 4);
        assert_eq!(s.coalesced_requests, 10);
        assert!((s.flush_coalescing() - 2.5).abs() < 1e-12);
        assert!(s.to_string().contains("batcher flushes"), "{s}");
        // Before any flush the ratio is a clean zero, not NaN.
        assert_eq!(m.snapshot(0, 0, 0).flush_coalescing(), 0.0);
    }

    #[test]
    fn wall_ledger_and_executor_context_flow_into_snapshot() {
        let mut m = Metrics::new();
        m.wall_insert_us = 1500.0;
        m.wall_work_us = 250.0;
        m.wall_flatten_us = 4000.0;
        let s = m.snapshot(10, 20, 400);
        assert!((s.wall_insert_ms - 1.5).abs() < 1e-12);
        assert!((s.wall_work_ms - 0.25).abs() < 1e-12);
        assert!((s.wall_flatten_ms - 4.0).abs() < 1e-12);
        assert_eq!(s.executors, 1, "serial until the worker attaches its scheduler");
        assert!(s.to_string().contains("1 executor: serial"), "{s}");
        let s = s.with_executors(4);
        assert_eq!(s.executors, 4);
        assert!(s.to_string().contains("4 executors: scheduled"), "{s}");
        assert!(s.to_string().contains("wall insert/work/flat"), "{s}");
    }

    #[test]
    fn with_scheduler_attaches_steal_park_chunk_ledger() {
        let m = Metrics::new();
        let s = m.snapshot(10, 20, 400);
        // Zeroed default: serial mode has no scheduler.
        assert_eq!((s.steals, s.parks, s.chunks_executed), (0, 0, 0));
        assert_eq!((s.spawn_failures, s.worker_respawns, s.degraded_workers), (0, 0, 0));
        let s = s.with_scheduler(GroupCounters {
            steals: 3,
            parks: 8,
            executed: 21,
            worker_respawns: 2,
            degraded_workers: 1,
            ..Default::default()
        });
        assert_eq!(s.steals, 3);
        assert_eq!(s.parks, 8);
        assert_eq!(s.chunks_executed, 21);
        assert_eq!(s.worker_respawns, 2);
        assert_eq!(s.degraded_workers, 1);
        assert!(
            s.to_string().contains("21 chunks (3 steals, 8 parks; 2 respawns, 1 degraded, 0 spawn failures)"),
            "{s}"
        );
    }

    #[test]
    fn with_frontend_attaches_admission_ledger() {
        let mut m = Metrics::new();
        m.admitted_requests = 12;
        m.admitted_values = 480;
        m.proposals = 3;
        let s = m.snapshot(480, 512, 2048);
        // Worker-side admission counters flow through snapshot()...
        assert_eq!(s.admitted_requests, 12);
        assert_eq!(s.admitted_values, 480);
        assert_eq!(s.proposals, 3);
        // ...while the shared session/shed ledger defaults to zero until
        // the worker attaches it.
        assert_eq!((s.sessions, s.shed_requests), (0, 0));
        let s = s.with_frontend(2, 5);
        assert_eq!(s.sessions, 2);
        assert_eq!(s.shed_requests, 5);
        assert!(s.to_string().contains("frontend sessions"), "{s}");
        assert!(s.to_string().contains("5 shed"), "{s}");
    }

    #[test]
    fn latency_histogram_percentiles_bound_the_tail() {
        let mut m = Metrics::new();
        // 99 fast requests and one 30 ms straggler: p50 stays in the
        // fast band, p99 and max must cover the straggler.
        for _ in 0..99 {
            m.observe_latency_us(100.0);
        }
        m.observe_latency_us(30_000.0);
        let s = m.snapshot(0, 0, 0);
        assert_eq!(s.p_latency_count, 100);
        assert!(s.p50_latency_us >= 100, "p50 must cover the fast band: {}", s.p50_latency_us);
        assert!(s.p50_latency_us < 1_000, "p50 must not leak into the tail: {}", s.p50_latency_us);
        assert!(s.p99_latency_us >= 30_000, "p99 must cover the straggler: {}", s.p99_latency_us);
        assert_eq!(s.max_latency_us, 30_000);
        // The percentile estimate never exceeds the observed max.
        assert!(s.p99_latency_us <= s.max_latency_us);
        assert!(s.to_string().contains("p50"), "{s}");
    }

    #[test]
    fn latency_histogram_is_zero_before_observations() {
        let s = Metrics::new().snapshot(0, 0, 0);
        assert_eq!((s.p50_latency_us, s.p99_latency_us, s.max_latency_us), (0, 0, 0));
    }

    #[test]
    fn supervisor_counters_flow_into_snapshot() {
        let mut m = Metrics::new();
        m.worker_restarts = 2;
        m.replayed_requests = 1;
        let s = m.snapshot(0, 0, 0);
        assert_eq!(s.worker_restarts, 2);
        assert_eq!(s.replayed_requests, 1);
        assert!(s.to_string().contains("2 worker restarts, 1 replayed requests"), "{s}");
    }

    #[test]
    fn with_memory_attaches_heap_accounting() {
        let m = Metrics::new();
        let s = m.snapshot(10, 20, 400).with_memory(160, 560);
        assert_eq!(s.sealed_bytes, 160);
        assert_eq!(s.heap_used_bytes, 560);
        assert!(s.to_string().contains("sealed, epoch-owned"), "{s}");
    }
}
