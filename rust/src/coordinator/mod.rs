//! Dynamic-memory workload coordinator (Layer 3 service).
//!
//! Routes insertion/work/flatten requests over N independent GGArray
//! [`shard::Shard`]s (each with its own VRAM budget carved from the
//! shared device), batches them per the global block space, and drives
//! the AOT work kernels via the PJRT runtime. The paper's two-phase
//! lifecycle is first-class: sealing an epoch flattens every shard into
//! one contiguous fast-access view (see [`shard::EpochManager`]) while a
//! fresh insert epoch opens behind it; sealed residency is epoch-owned
//! (commit *transfers* each flatten destination into the epoch store's
//! own heap, freeing the shard budgets), and sealed segments are
//! compacted — a reserve-then-commit VRAM transaction that can OOM and
//! abort — once their count passes the configured threshold. Simulated
//! time is
//! charged under the parallel time model ([`metrics::ParallelCost`]):
//! critical path (max over concurrent shards) for the wall-model,
//! sum for the `device_*` aggregate totals — and shard execution is
//! *really* concurrent through the persistent work-stealing
//! [`scheduler::Scheduler`] (a bucketed worker group with per-worker
//! deques, steal-on-empty and drained+parked termination detection;
//! serial mode stays byte-identical via
//! `CoordinatorConfig::executor_threads`). See [`service`] for the
//! event loop.
//!
//! Concurrent writers enter through the admission [`frontend`]: each
//! holds a [`frontend::ClientSession`] (stable client id, monotonic
//! sequence numbers) feeding the worker over its own *bounded* channel.
//! A full channel sheds with a typed `Rejected { retry_after_hint }` —
//! payload handed back, counted in the `shed_requests` metric — never
//! blocking the worker and never dropping silently. The worker merges
//! all client pools into the shared [`batcher::Batcher`] in ascending
//! client-id order with per-client FIFO preserved, so under
//! [`frontend::MergePolicy::AtBarrier`] sealed layouts are byte-identical
//! to a serial single-session replay of the same requests.

pub mod batcher;
pub mod frontend;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod service;
pub mod shard;
pub(crate) mod supervisor;
