//! Dynamic-memory workload coordinator (Layer 3 service).
//!
//! Routes insertion/work/flatten requests onto the GGArray's per-block
//! LFVectors, batches them per block, and drives the AOT work kernels via
//! the PJRT runtime. See `service` for the event loop.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod service;
