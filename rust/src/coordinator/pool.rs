//! Persistent shard-executor pool: the threads that turn the simulated
//! max-over-shards critical path into *measured* wall-clock parallelism.
//!
//! The coordinator's parallel time model (PR 2) charges each dispatching
//! op the slowest shard, mirroring the paper's concurrent thread blocks —
//! but until this pool, every shard still *executed* serially on the one
//! worker thread, so the wall numbers never showed the speedup the sim
//! ledger promised. [`ShardPool`] closes that gap: N long-lived executor
//! threads (one per shard) are spawned once at `Coordinator::start`, each
//! parked on a pre-allocated SPSC [`Mailbox`] (one `Mutex` + two
//! `Condvar`s from the [`crate::sync`] facade — `std` in normal builds,
//! the model-checkable flavor under `--cfg ggcheck`). A shard-dispatching
//! op fans one job per shard out to the mailboxes and fans back in at a
//! barrier — the host-side analogue of the paper's per-block
//! `__syncthreads()`. The handoff/barrier/shutdown protocol itself is
//! exhaustively model-checked in `tests/model_check.rs`.
//!
//! ## Ownership and safety
//!
//! Shards stay owned by the coordinator worker (it needs cheap direct
//! access for routing, stats, and queries between ops); executor threads
//! hold **no** shard state. Each fan-out *leases* shard `k` to executor
//! `k` for exactly one job: the job carries provenance-preserving
//! [`SendPtr`]/[`SendSlice`]/[`SendSliceMut`] wrappers (never
//! pointer→`usize` laundering), and the public `run_*` methods restore
//! safety structurally —
//!
//! * submission and the blocking join happen inside one `&mut`-borrowing
//!   call, so the worker provably cannot touch a shard, the batch
//!   values, or a gather destination while a job referencing them is in
//!   flight;
//! * each executor receives a distinct shard (its pointer taken from a
//!   distinct `iter_mut` element) and, for gathers, a destination
//!   sub-slice carved disjoint with `split_at_mut` *before* wrapping —
//!   so concurrent jobs never alias, by construction rather than by
//!   offset arithmetic;
//! * every mailbox holds at most one job and one result (SPSC by
//!   construction — the worker is the single producer, the executor the
//!   single consumer).
//!
//! ## Zero-alloc steady state
//!
//! Mailboxes are pre-allocated at pool construction; jobs and results
//! are plain enums moved through an `Option` slot in place. A
//! steady-state insert batch therefore performs **zero** heap
//! allocations end-to-end, mailbox handoff included — extended coverage
//! in `tests/alloc_guard.rs` (4-shard pooled section). This module is in
//! the lint's hot-path manifest (`rust/hotpath_manifest.txt`), so CI
//! rejects new allocating calls here.
//!
//! ## Byte-identity
//!
//! Per-shard operations are the *same* `Shard` methods the serial path
//! calls, and each shard's simulated clock/heap is touched only by its
//! own job, so execution order across shards cannot change any per-shard
//! state. The service pre-screens VRAM demand before fanning out (a
//! guaranteed-fit op cannot OOM mid-flight) and falls back to the serial
//! path otherwise, so even OOM traces are byte-identical across executor
//! modes — property-tested in `tests/properties.rs`.

use crate::sync::thread;
use crate::sync::{Arc, Condvar, Mutex, MutexGuard, SendPtr, SendSlice, SendSliceMut};

use crate::sim::memory::OomError;

use super::router::DispatchScratch;
use super::service::DispatchOutcome;
use super::shard::{SealPart, Shard, ShardInsertOutcome};

/// One leased unit of work for one shard. `Send` falls out of the
/// wrapper types' leases (no integer casts); the public `run_*` wrappers
/// are the only constructors and uphold the lease contract documented on
/// the module.
enum Job {
    /// Apply a routed sub-batch: `counts` is the shard's slice of the
    /// global per-block decision, `values` its contiguous sub-slice of
    /// the batch.
    Insert { shard: SendPtr<Shard>, counts: SendSlice<usize>, values: SendSlice<f32> },
    /// One work call on this shard: the real numeric update (host path)
    /// plus the modeled `rw_b` charge on non-empty shards.
    Work { shard: SendPtr<Shard>, iters: u32 },
    /// Non-destructive snapshot gather into a disjoint destination
    /// sub-slice (simulated destination released immediately).
    FlattenTemp { shard: SendPtr<Shard>, dst: SendSliceMut<f32> },
    /// Seal phase-1 gather into a disjoint destination sub-slice (the
    /// destination allocation stays live in the shard heap — the
    /// caller's two-phase commit decides its fate).
    SealFlatten { shard: SendPtr<Shard>, dst: SendSliceMut<f32> },
}

/// Result slot contents, one variant per job kind.
enum JobResult {
    Insert(ShardInsertOutcome),
    Work { pjrt: u64 },
    Flatten(Result<usize, OomError>),
    Seal(Result<SealPart, OomError>),
}

/// SPSC mailbox: the single producer deposits one job, the single
/// consumer deposits one result. Pre-allocated; steady-state traffic is
/// two `Option` moves and two condvar signals per op, no heap.
///
/// Generic over the job/result payloads so the model-check suite can
/// drive the *exact* production protocol (`submit`/`executor_loop`/
/// `join`/`signal_shutdown`) with observable payloads.
pub struct Mailbox<J, R> {
    slot: Mutex<Slot<J, R>>,
    job_ready: Condvar,
    result_ready: Condvar,
}

struct Slot<J, R> {
    job: Option<J>,
    result: Option<R>,
    shutdown: bool,
}

impl<J, R> Mailbox<J, R> {
    pub fn new() -> Mailbox<J, R> {
        Mailbox {
            slot: Mutex::new(Slot { job: None, result: None, shutdown: false }),
            job_ready: Condvar::new(),
            result_ready: Condvar::new(),
        }
    }

    /// Poison-tolerant slot lock: shutdown/teardown paths run from
    /// `Drop` and must never double-panic; the slot state is two
    /// `Option`s and a flag, meaningful even after a payload panic.
    fn lock_slot(&self) -> MutexGuard<'_, Slot<J, R>> {
        self.slot.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Deposit one job and wake the executor. SPSC contract: the
    /// producer never submits while a job or result is outstanding.
    pub fn submit(&self, job: J) {
        let mut slot = self.lock_slot();
        debug_assert!(slot.job.is_none() && slot.result.is_none(), "SPSC: mailbox busy");
        slot.job = Some(job);
        drop(slot);
        self.job_ready.notify_one();
    }

    /// Block until the executor deposits its result (the fan-in
    /// barrier: no result is ever read before this).
    pub fn join(&self) -> R {
        let mut slot = self.lock_slot();
        loop {
            if let Some(result) = slot.result.take() {
                return result;
            }
            slot = self.result_ready.wait(slot).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Flag shutdown and wake the executor. Never panics (called from
    /// `Drop`).
    pub fn signal_shutdown(&self) {
        let mut slot = self.lock_slot();
        slot.shutdown = true;
        drop(slot);
        self.job_ready.notify_one();
    }

    /// The executor side: park on the mailbox, run each job through
    /// `run`, deposit the result, repeat until shutdown. Checking
    /// `shutdown` only with the slot lock held (and after draining any
    /// pending job takes priority below it) means a submitted job is
    /// never lost to a racing shutdown signal.
    pub fn executor_loop(&self, mut run: impl FnMut(J) -> R) {
        let mut guard = self.lock_slot();
        loop {
            if guard.shutdown {
                return;
            }
            if let Some(job) = guard.job.take() {
                drop(guard);
                let result = run(job);
                guard = self.lock_slot();
                debug_assert!(guard.result.is_none(), "SPSC: stale result");
                guard.result = Some(result);
                self.result_ready.notify_one();
            } else {
                guard = self.job_ready.wait(guard).unwrap_or_else(|e| e.into_inner());
            }
        }
    }
}

impl<J, R> Default for Mailbox<J, R> {
    fn default() -> Mailbox<J, R> {
        Mailbox::new()
    }
}

/// Execute one leased job.
///
/// Every `unsafe` block below re-materialises a reference from a lease
/// wrapper; the shared justification is the module's lease contract:
/// the submitting `run_*` call (a) derived every wrapper from a live
/// borrow it holds across submit *and* join, (b) handed this executor a
/// shard and destination range no concurrent job references, and (c)
/// blocks until this result is deposited — so for the job's lifetime
/// this thread is the sole accessor of every pointed-to region.
fn execute(job: Job) -> JobResult {
    match job {
        Job::Insert { shard, counts, values } => {
            // SAFETY: lease contract above — exclusive shard access
            // for the duration of the job.
            let shard = unsafe { shard.deref_mut() };
            // SAFETY: lease contract above — the scratch counts and
            // batch values are borrowed by the blocked submitter and
            // written by no one.
            let counts = unsafe { counts.as_slice() };
            // SAFETY: as for `counts`.
            let values = unsafe { values.as_slice() };
            JobResult::Insert(shard.apply_counts(counts, values))
        }
        Job::Work { shard, iters } => {
            // SAFETY: lease contract above — exclusive shard access.
            let shard = unsafe { shard.deref_mut() };
            // Same per-shard sequence as the serial worker: real
            // numeric update (host path — the PJRT client is not
            // shared across executors; see `Worker::handle`), then
            // the modeled rw_b launch on non-empty shards.
            let pjrt = shard.work_pass(None, iters);
            if !shard.is_empty() {
                shard.charge_rw_block(iters as f64);
            }
            JobResult::Work { pjrt }
        }
        Job::FlattenTemp { shard, dst } => {
            // SAFETY: lease contract above — exclusive shard access.
            let shard = unsafe { shard.deref_mut() };
            // SAFETY: lease contract above — `dst` was carved disjoint
            // with split_at_mut before wrapping; no other job holds an
            // overlapping range.
            let dst = unsafe { dst.as_mut_slice() };
            JobResult::Flatten(shard.flatten_temp_to_slice(dst))
        }
        Job::SealFlatten { shard, dst } => {
            // SAFETY: lease contract above — exclusive shard access.
            let shard = unsafe { shard.deref_mut() };
            // SAFETY: as for FlattenTemp — disjoint by construction.
            let dst = unsafe { dst.as_mut_slice() };
            JobResult::Seal(shard.seal_flatten_to_slice(dst))
        }
    }
}

/// The persistent executor pool: one thread + mailbox per shard, spawned
/// once and reused for every subsequent fan-out (never per batch).
pub struct ShardPool {
    mailboxes: Vec<Arc<Mailbox<Job, JobResult>>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl ShardPool {
    /// Spawn `threads` executor threads (one per shard slot). Threads
    /// park on their mailbox condvar between jobs — no busy-waiting.
    pub fn new(threads: usize) -> ShardPool {
        assert!(threads > 0, "executor pool needs at least one thread");
        let mailboxes: Vec<Arc<Mailbox<Job, JobResult>>> =
            (0..threads).map(|_| Arc::new(Mailbox::new())).collect();
        let handles = mailboxes
            .iter()
            .enumerate()
            .map(|(k, mb)| {
                let mb = Arc::clone(mb);
                thread::Builder::new()
                    .name(format!("ggarray-shard-exec-{k}")) // lint: allow(alloc) — once per pool construction, never per batch
                    .spawn(move || mb.executor_loop(execute))
                    .expect("spawn shard executor")
            })
            .collect();
        ShardPool { mailboxes, handles }
    }

    /// Number of executor threads (== shard slots).
    pub fn threads(&self) -> usize {
        self.mailboxes.len()
    }

    /// Fan an already-routed insert batch out to the executors and fan
    /// back in: shard `k` applies `scratch.shard_counts(k, bps)` to its
    /// contiguous `&values[off..off+len]` sub-slice (ranges from
    /// `scratch.ranges`), concurrently across shards. Shards whose range
    /// is empty get no job — no phantom kernels, same as the serial loop.
    ///
    /// The caller pre-screened VRAM demand (`insert_demand_fits`), so no
    /// shard can OOM; should one anyway (a pre-screen bug), the lowest
    /// failing shard is surfaced in the outcome, mirroring the serial
    /// error report.
    pub fn run_insert(
        &self,
        shards: &mut [Shard],
        blocks_per_shard: usize,
        values: &[f32],
        scratch: &DispatchScratch,
    ) -> DispatchOutcome {
        let n = shards.len();
        debug_assert!(n <= self.threads());
        // Each job's shard pointer comes from a distinct `iter_mut`
        // element (disjoint provenance — never `base.add(k)` over one
        // borrow), and `shards` is not reborrowed until every job has
        // joined, so the fan-out window contains no live reference that
        // could alias an executor's write.
        for (k, shard) in shards.iter_mut().enumerate() {
            let (offset, take) = scratch.ranges[k];
            if take == 0 {
                continue;
            }
            let counts = scratch.shard_counts(k, blocks_per_shard);
            let sub = &values[offset..offset + take];
            self.mailboxes[k].submit(Job::Insert {
                shard: SendPtr::new(shard),
                counts: SendSlice::new(counts),
                values: SendSlice::new(sub),
            });
        }
        // Barrier: collect in shard order (the shard id order the serial
        // loop reports in, and the order `cost_since` folds deltas in).
        let mut applied = 0u64;
        let mut oom_k: Option<(usize, OomError)> = None;
        for k in 0..n {
            if scratch.ranges[k].1 == 0 {
                continue;
            }
            match self.mailboxes[k].join() {
                JobResult::Insert(out) => {
                    applied += out.applied as u64;
                    if let Some(e) = out.error {
                        debug_assert!(false, "insert fan-out OOM despite pre-screen on shard {k}");
                        if oom_k.is_none() {
                            oom_k = Some((k, e));
                        }
                    }
                }
                _ => unreachable!("insert mailbox returned a foreign result"),
            }
        }
        // Post-barrier: safe to borrow the shards again.
        DispatchOutcome { applied, oom: oom_k.map(|(k, e)| (shards[k].id(), e)) }
    }

    /// One work call fanned across non-empty shards: per-shard numeric
    /// update plus the modeled `rw_b` charge, concurrently. Empty live
    /// shards get no job at all — the serial loop does nothing to them
    /// either (no data, no rw_b launch), so on a mostly-sealed store the
    /// fan-out pays zero handoffs instead of a wake/join round trip per
    /// idle shard per call. Returns PJRT executions performed (always 0
    /// — executors run the host path).
    pub fn run_work(&self, shards: &mut [Shard], iters: u32) -> u64 {
        let n = shards.len();
        debug_assert!(n <= self.threads());
        // Snapshot emptiness before any job is in flight: work never
        // changes a shard's length, so the skip decision is stable, and
        // reading it later would alias the executors' writes.
        let active: Vec<bool> = shards.iter().map(|s| !s.is_empty()).collect();
        for (k, shard) in shards.iter_mut().enumerate() {
            if active[k] {
                self.mailboxes[k].submit(Job::Work { shard: SendPtr::new(shard), iters });
            }
        }
        let mut pjrt = 0u64;
        for k in 0..n {
            if !active[k] {
                continue;
            }
            match self.mailboxes[k].join() {
                JobResult::Work { pjrt: p } => pjrt += p,
                _ => unreachable!("work mailbox returned a foreign result"),
            }
        }
        pjrt
    }

    /// Parallel snapshot gather: shard `k` writes its contents into
    /// `dst[ranges[k].0 .. +ranges[k].1]` (disjoint by construction —
    /// ranges are the prefix sums of the shard lengths, and the
    /// destination is carved with `split_at_mut` so disjointness is
    /// structural) and releases its simulated destination. The caller
    /// pre-screened VRAM fit; an unexpected failure is surfaced as the
    /// lowest failing shard's error (the destination contents are
    /// discarded by the caller).
    pub fn run_flatten_temp(
        &self,
        shards: &mut [Shard],
        dst: &mut [f32],
        ranges: &[(usize, usize)],
    ) -> Result<(), OomError> {
        let n = shards.len();
        debug_assert_eq!(n, ranges.len());
        debug_assert_eq!(ranges.iter().map(|r| r.1).sum::<usize>(), dst.len());
        let mut rest: &mut [f32] = dst;
        let mut covered = 0usize;
        for ((k, shard), &(off, len)) in shards.iter_mut().enumerate().zip(ranges.iter()) {
            debug_assert_eq!(off, covered, "gather ranges must be contiguous prefix sums");
            let chunk = std::mem::take(&mut rest);
            let (head, tail) = chunk.split_at_mut(len);
            rest = tail;
            covered += len;
            self.mailboxes[k].submit(Job::FlattenTemp {
                shard: SendPtr::new(shard),
                dst: SendSliceMut::new(head),
            });
        }
        let mut failed: Option<OomError> = None;
        for k in 0..n {
            match self.mailboxes[k].join() {
                JobResult::Flatten(Ok(_)) => {}
                JobResult::Flatten(Err(e)) => {
                    debug_assert!(false, "flatten fan-out OOM despite pre-screen on shard {k}");
                    if failed.is_none() {
                        failed = Some(e);
                    }
                }
                _ => unreachable!("flatten mailbox returned a foreign result"),
            }
        }
        match failed {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Parallel seal phase-1 gather: shard `k` seals and flattens into
    /// its disjoint `ranges[k]` sub-slice of the shared destination,
    /// concurrently. Per-shard results land in `out` in shard order
    /// (`Ok(SealPart)` whose destination allocation the caller's
    /// two-phase commit owns, or the shard's `Err` — the failing shard
    /// already reopened itself, exactly like the appending path).
    pub fn run_seal(
        &self,
        shards: &mut [Shard],
        dst: &mut [f32],
        ranges: &[(usize, usize)],
        out: &mut Vec<Result<SealPart, OomError>>,
    ) {
        let n = shards.len();
        debug_assert_eq!(n, ranges.len());
        debug_assert_eq!(ranges.iter().map(|r| r.1).sum::<usize>(), dst.len());
        let mut rest: &mut [f32] = dst;
        let mut covered = 0usize;
        for ((k, shard), &(off, len)) in shards.iter_mut().enumerate().zip(ranges.iter()) {
            debug_assert_eq!(off, covered, "gather ranges must be contiguous prefix sums");
            let chunk = std::mem::take(&mut rest);
            let (head, tail) = chunk.split_at_mut(len);
            rest = tail;
            covered += len;
            self.mailboxes[k].submit(Job::SealFlatten {
                shard: SendPtr::new(shard),
                dst: SendSliceMut::new(head),
            });
        }
        for k in 0..n {
            match self.mailboxes[k].join() {
                JobResult::Seal(r) => out.push(r),
                _ => unreachable!("seal mailbox returned a foreign result"),
            }
        }
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        for mb in &self.mailboxes {
            // Poison-tolerant (inside signal_shutdown): a panicked
            // executor already holds a dead thread — still signal the
            // healthy ones, and never panic inside drop (a double panic
            // would abort the process).
            mb.signal_shutdown();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::Policy;
    use crate::coordinator::shard::ShardConfig;
    use crate::insertion::InsertionKind;
    use crate::sim::spec::DeviceSpec;

    fn build_shards(n: usize, blocks: usize) -> Vec<Shard> {
        (0..n)
            .map(|id| {
                Shard::new(ShardConfig {
                    id,
                    blocks,
                    first_bucket_size: 16,
                    insertion: InsertionKind::WarpScan,
                    device: DeviceSpec::a100(),
                    heap_bytes: 1 << 26,
                })
            })
            .collect()
    }

    /// Route + split a batch the way the service does.
    fn routed(shards: &[Shard], bps: usize, n: usize, scratch: &mut DispatchScratch) {
        scratch.sizes.clear();
        for shard in shards.iter() {
            scratch.sizes.extend(shard.block_sizes_iter());
        }
        scratch.route(Policy::Even, n, 0);
        scratch.split_for_shards(bps);
    }

    #[test]
    fn pooled_insert_matches_serial_per_shard_state() {
        let bps = 2;
        let values: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let mut scratch = DispatchScratch::new();

        let mut serial = build_shards(4, bps);
        routed(&serial, bps, values.len(), &mut scratch);
        let mut applied_serial = 0u64;
        for (k, shard) in serial.iter_mut().enumerate() {
            let (off, take) = scratch.ranges[k];
            let out = shard.apply_counts(scratch.shard_counts(k, bps), &values[off..off + take]);
            assert!(out.error.is_none());
            applied_serial += out.applied as u64;
        }

        let pool = ShardPool::new(4);
        let mut pooled = build_shards(4, bps);
        routed(&pooled, bps, values.len(), &mut scratch);
        let out = pool.run_insert(&mut pooled, bps, &values, &scratch);
        assert_eq!(out.applied, applied_serial);
        assert!(out.oom.is_none());
        for (s, p) in serial.iter().zip(&pooled) {
            assert_eq!(s.len(), p.len());
            assert_eq!(s.heap_used(), p.heap_used());
            assert_eq!(s.sim_now_us(), p.sim_now_us(), "per-shard clocks must agree exactly");
            for i in 0..s.len() as u64 {
                assert_eq!(s.get(i), p.get(i));
            }
        }
    }

    #[test]
    fn pooled_work_matches_serial_values_and_clocks() {
        let bps = 2;
        let values: Vec<f32> = (0..256).map(|i| i as f32 * 0.5).collect();
        let mut scratch = DispatchScratch::new();
        let mut serial = build_shards(2, bps);
        routed(&serial, bps, values.len(), &mut scratch);
        for (k, shard) in serial.iter_mut().enumerate() {
            let (off, take) = scratch.ranges[k];
            shard.apply_counts(scratch.shard_counts(k, bps), &values[off..off + take]);
        }
        let pool = ShardPool::new(2);
        let mut pooled = build_shards(2, bps);
        routed(&pooled, bps, values.len(), &mut scratch);
        pool.run_insert(&mut pooled, bps, &values, &scratch);

        for shard in serial.iter_mut() {
            shard.work_pass(None, 30);
            if !shard.is_empty() {
                shard.charge_rw_block(30.0);
            }
        }
        assert_eq!(pool.run_work(&mut pooled, 30), 0);
        for (s, p) in serial.iter().zip(&pooled) {
            assert_eq!(s.get(0), p.get(0));
            assert_eq!(s.sim_now_us(), p.sim_now_us());
        }
    }

    #[test]
    fn pooled_gathers_write_disjoint_ranges_in_shard_order() {
        let bps = 2;
        let values: Vec<f32> = (0..300).map(|i| i as f32).collect();
        let mut scratch = DispatchScratch::new();
        let pool = ShardPool::new(3);
        let mut shards = build_shards(3, bps);
        routed(&shards, bps, values.len(), &mut scratch);
        pool.run_insert(&mut shards, bps, &values, &scratch);

        // Reference: serial appending flatten.
        let mut reference = Vec::new();
        let mut check = build_shards(3, bps);
        routed(&check, bps, values.len(), &mut scratch);
        for (k, shard) in check.iter_mut().enumerate() {
            let (off, take) = scratch.ranges[k];
            shard.apply_counts(scratch.shard_counts(k, bps), &values[off..off + take]);
        }
        for shard in check.iter_mut() {
            shard.flatten_temp_into(&mut reference).unwrap();
        }

        let lens: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        let ranges = scratch.fill_gather_ranges(lens.into_iter()).to_vec();
        let mut dst = vec![0.0f32; values.len()];
        pool.run_flatten_temp(&mut shards, &mut dst, &ranges).unwrap();
        assert_eq!(dst, reference, "parallel gather must be byte-identical to serial append");

        // Seal gather: parts in shard order, destination allocs live.
        let mut seal_dst = vec![0.0f32; values.len()];
        let mut parts = Vec::new();
        pool.run_seal(&mut shards, &mut seal_dst, &ranges, &mut parts);
        assert_eq!(seal_dst, reference);
        assert_eq!(parts.len(), 3);
        for (k, (part, shard)) in parts.into_iter().zip(shards.iter_mut()).enumerate() {
            let mut part = part.expect("pre-screened seal cannot OOM");
            assert_eq!(part.len, ranges[k].1);
            assert!(part.alloc.is_some());
            shard.abort_seal(part.alloc.take()); // clean up the lease
        }
    }

    #[test]
    fn pool_drop_joins_executors() {
        let pool = ShardPool::new(4);
        assert_eq!(pool.threads(), 4);
        drop(pool); // must not hang or leak threads
    }

    #[test]
    fn generic_mailbox_round_trips_and_shuts_down() {
        let mb: Arc<Mailbox<u32, u32>> = Arc::new(Mailbox::new());
        let worker = {
            let mb = Arc::clone(&mb);
            thread::Builder::new()
                .name("mailbox-test-exec".to_string())
                .spawn(move || mb.executor_loop(|j| j * 2))
                .expect("spawn")
        };
        mb.submit(21);
        assert_eq!(mb.join(), 42);
        mb.submit(7);
        assert_eq!(mb.join(), 14);
        mb.signal_shutdown();
        worker.join().expect("executor exits cleanly");
    }
}
