//! Request/response protocol of the coordinator service.

use std::time::Duration;

use super::metrics::MetricsSnapshot;

/// Operations a client can submit.
#[derive(Debug, Clone)]
pub enum Request {
    /// Append values to the growable array (routed + batched per block).
    Insert { values: Vec<f32> },
    /// Run the +1×30 work kernel `calls` times over the whole array
    /// (through the AOT PJRT executable when artifacts are available).
    Work { calls: u32 },
    /// Flatten into a contiguous buffer (two-phase pattern); the array
    /// keeps its contents.
    Flatten,
    /// Seal the current epoch: drain in-flight batches, flatten every
    /// shard, concatenate into the sealed flat view (fast access path),
    /// and open a fresh insert epoch behind it.
    Seal,
    /// Read one element by global index.
    Query { index: u64 },
    /// Metrics snapshot.
    Stats,
    /// Drop all contents (keeps the service and compiled artifacts warm).
    Clear,
    /// Drain and stop the worker.
    Shutdown,
}

/// Replies, one per request.
#[derive(Debug, Clone)]
pub enum Response {
    Inserted {
        count: u64,
        /// Simulated GPU time charged (µs).
        sim_us: f64,
        /// New total length.
        len: u64,
    },
    Worked {
        calls: u32,
        /// Wall-model simulated time (µs): serial coordinator term plus
        /// the critical path over concurrently-executing shards.
        sim_us: f64,
        /// Aggregate device-seconds (µs): the sum over every
        /// participating shard — `device_us / sim_us` is the op's
        /// shard-parallel speedup.
        device_us: f64,
        /// PJRT executions performed (0 on the host fallback path).
        pjrt_executions: u64,
    },
    Flattened {
        len: u64,
        /// Wall-model simulated time (µs, critical path over shards).
        sim_us: f64,
        /// Aggregate device-seconds (µs, sum over shards).
        device_us: f64,
        /// Checksum of the flattened data (order-sensitive) for e2e
        /// validation.
        checksum: u64,
    },
    Sealed {
        /// The new (now inserting) epoch sequence number.
        epoch: u64,
        /// Elements sealed by this request.
        epoch_len: u64,
        /// Total elements across all sealed epochs.
        sealed_len: u64,
        /// Flat segments backing the sealed prefix after this seal
        /// (compaction keeps it bounded).
        sealed_segments: usize,
        /// Wall-model simulated time (µs, critical path over shards,
        /// compaction gather included).
        sim_us: f64,
        /// Aggregate device-seconds (µs, sum over shards).
        device_us: f64,
        /// Checksum of this epoch's flattened data (order-sensitive).
        checksum: u64,
        /// Set when this seal triggered a compaction pass that aborted on
        /// VRAM: the epoch heap could not hold the gather's transient 2×
        /// residency. The seal itself committed and the store keeps
        /// serving (segments retained byte-identically) — this surfaces
        /// the skipped hygiene pass so operators can widen the budget.
        compaction_oom: Option<String>,
    },
    Value(Option<f32>),
    Stats(MetricsSnapshot),
    Cleared,
    ShuttingDown,
    Error(String),
    /// The op did not run to completion: a typed execution fault
    /// (contained worker panic, handler panic, or a dead service). For
    /// `ChunkPanic` the op was rolled back byte-identically — the store
    /// is exactly as if the request had never been submitted, and
    /// subsequent requests keep working.
    Failed(ExecError),
}

/// Typed execution faults surfaced by the panic-safe coordinator. These
/// are *contained* failures: the service (and, for `ChunkPanic`, the
/// store's simulated ledger) survives them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExecError {
    /// A scheduler worker panicked while executing this op's chunks.
    /// The coordinator rolled back the op's serial pre-charges, so the
    /// shards are byte-identical to the op never running (`Work` partial
    /// numeric updates excepted — f32 adds on completed shards cannot be
    /// exactly undone; the simulated ledger still rewinds fully).
    ChunkPanic {
        /// Which phase died (`"insert"`, `"work"`, `"flatten"`, `"seal"`).
        op: &'static str,
        /// Chunks that panicked before the phase drained.
        chunks: u64,
    },
    /// The service worker's request handler panicked outside a scheduler
    /// phase. The request is lost; the worker and store keep serving.
    HandlerPanic,
    /// The service worker is gone (channel disconnected): the request
    /// was not processed. Payload-carrying paths hand the data back via
    /// [`Admission::Closed`] instead.
    ServiceDown,
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::ChunkPanic { op, chunks } => {
                write!(f, "worker panic aborted {op} op ({chunks} chunk(s) failed; rolled back)")
            }
            ExecError::HandlerPanic => write!(f, "request handler panicked (request lost)"),
            ExecError::ServiceDown => write!(f, "coordinator service is down"),
        }
    }
}

impl Response {
    /// Convenience for tests: panic unless the response is the expected
    /// success variant.
    pub fn expect_inserted(self) -> (u64, f64, u64) {
        match self {
            Response::Inserted { count, sim_us, len } => (count, sim_us, len),
            other => panic!("expected Inserted, got {other:?}"),
        }
    }

    pub fn expect_value(self) -> Option<f32> {
        match self {
            Response::Value(v) => v,
            other => panic!("expected Value, got {other:?}"),
        }
    }

    /// Convenience for tests/benches: the metrics snapshot or panic.
    pub fn expect_stats(self) -> MetricsSnapshot {
        match self {
            Response::Stats(s) => s,
            other => panic!("expected Stats, got {other:?}"),
        }
    }

    /// Convenience for tests: `(epoch, epoch_len, sealed_len, sim_us,
    /// checksum)` or panic.
    pub fn expect_sealed(self) -> (u64, u64, u64, f64, u64) {
        match self {
            Response::Sealed { epoch, epoch_len, sealed_len, sim_us, checksum, .. } => {
                (epoch, epoch_len, sealed_len, sim_us, checksum)
            }
            other => panic!("expected Sealed, got {other:?}"),
        }
    }
}

/// Admission verdict for a session insert (the bounded-frontend
/// counterpart of `Response::Inserted`). Backpressure contract: a
/// non-accepted verdict always hands the payload back — admission never
/// drops values silently and never blocks the worker.
#[derive(Debug, Clone, PartialEq)]
pub enum Admission {
    /// The insert was admitted to the session's bounded channel.
    Accepted {
        /// Sequence number this request got (per-session, monotonic,
        /// gap-free over accepted requests).
        seq: u64,
        /// Total values accepted through the session so far.
        session_values: u64,
    },
    /// The session's channel is full: load was shed. Retry after the
    /// hint (advisory); the payload is returned untouched.
    Rejected { retry_after_hint: Duration, values: Vec<f32> },
    /// A bounded retry loop (`ClientSession::insert_retrying`) gave up:
    /// every one of its `attempts` admissions was shed. The payload is
    /// returned untouched — the caller decides whether to back off
    /// further, reroute, or drop. Distinct from `Rejected` (one shed,
    /// immediate retry advised) so exhaustion is a *typed* outcome
    /// rather than an invisible livelock.
    Exhausted { attempts: u32, values: Vec<f32> },
    /// The coordinator has stopped; the payload is returned untouched.
    Closed { values: Vec<f32> },
}

impl Admission {
    pub fn is_accepted(&self) -> bool {
        matches!(self, Admission::Accepted { .. })
    }

    /// Convenience for tests: `(seq, session_values)` or panic.
    pub fn expect_accepted(self) -> (u64, u64) {
        match self {
            Admission::Accepted { seq, session_values } => (seq, session_values),
            other => panic!("expected Accepted, got {other:?}"),
        }
    }
}

/// Order-sensitive checksum used by `Flattened` (FNV-1a over bit
/// patterns).
pub fn checksum(data: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for x in data {
        h ^= x.to_bits() as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checksum_is_order_sensitive() {
        assert_ne!(checksum(&[1.0, 2.0]), checksum(&[2.0, 1.0]));
        assert_eq!(checksum(&[1.0, 2.0]), checksum(&[1.0, 2.0]));
        assert_ne!(checksum(&[]), checksum(&[0.0]));
    }

    #[test]
    #[should_panic(expected = "expected Inserted")]
    fn expect_inserted_panics_on_error() {
        Response::Error("nope".into()).expect_inserted();
    }

    #[test]
    fn exec_error_displays_each_variant() {
        let e = ExecError::ChunkPanic { op: "insert", chunks: 2 };
        assert!(e.to_string().contains("insert"));
        assert!(e.to_string().contains("rolled back"));
        assert!(ExecError::HandlerPanic.to_string().contains("handler"));
        assert!(ExecError::ServiceDown.to_string().contains("down"));
    }

    #[test]
    fn admission_verdicts_round_trip_payloads() {
        let accepted = Admission::Accepted { seq: 3, session_values: 40 };
        assert!(accepted.is_accepted());
        assert_eq!(accepted.expect_accepted(), (3, 40));
        let rejected = Admission::Rejected {
            retry_after_hint: Duration::from_micros(200),
            values: vec![1.0, 2.0],
        };
        assert!(!rejected.is_accepted());
        match rejected {
            Admission::Rejected { retry_after_hint, values } => {
                assert_eq!(retry_after_hint, Duration::from_micros(200));
                assert_eq!(values, vec![1.0, 2.0]);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn exhausted_verdict_hands_the_payload_back() {
        let exhausted = Admission::Exhausted { attempts: 8, values: vec![3.0, 4.0] };
        assert!(!exhausted.is_accepted());
        match exhausted {
            Admission::Exhausted { attempts, values } => {
                assert_eq!(attempts, 8);
                assert_eq!(values, vec![3.0, 4.0]);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    #[should_panic(expected = "expected Accepted")]
    fn expect_accepted_panics_on_rejection() {
        Admission::Rejected { retry_after_hint: Duration::ZERO, values: Vec::new() }
            .expect_accepted();
    }
}
