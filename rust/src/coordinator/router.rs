//! Insertion routing: decide how a batch of new elements is split across
//! the GGArray's LFVectors (thread blocks).
//!
//! The paper's insertions are even by construction (one per existing
//! element). A service sees arbitrary batches, so the router also offers
//! a least-loaded policy that keeps LFVector sizes balanced — important
//! because the rw_b critical path is the *largest* LFVector, and the
//! worst-contended per-block size counter bounds the atomic path.

/// Routing policy for insert batches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Split the batch evenly over all blocks (paper's scheme).
    Even,
    /// Fill the currently-smallest blocks first (rebalancing).
    LeastLoaded,
    /// Deterministic hash of a batch sequence number (decorrelates hot
    /// spots across batches without tracking sizes).
    Hash,
}

impl Policy {
    pub fn by_name(s: &str) -> Option<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "even" => Some(Policy::Even),
            "least_loaded" | "leastloaded" | "balance" => Some(Policy::LeastLoaded),
            "hash" => Some(Policy::Hash),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Even => "even",
            Policy::LeastLoaded => "least_loaded",
            Policy::Hash => "hash",
        }
    }
}

/// Compute per-block insert counts for a batch of `n` elements given the
/// current per-block sizes. Guarantees `sum(counts) == n` (conservation).
///
/// Collecting convenience wrapper over [`route_into`] — callers on the
/// dispatch hot path hold a [`DispatchScratch`] and route into it
/// instead of allocating a fresh counts vector per batch.
pub fn route(policy: Policy, sizes: &[u64], n: usize, batch_seq: u64) -> Vec<usize> {
    let mut counts = Vec::new();
    let mut order = Vec::new();
    route_into(policy, sizes, n, batch_seq, &mut counts, &mut order);
    counts
}

/// In-place [`route`]: writes the per-block counts into `counts`
/// (cleared first) using `order` as index-sort scratch for
/// [`Policy::LeastLoaded`]. Both buffers keep their capacity across
/// calls, so a warmed dispatch loop routes without heap traffic. The
/// decision is identical to the collecting path for every policy (the
/// LeastLoaded sort breaks size ties by block index, which is exactly
/// what the previous stable sort produced).
pub fn route_into(
    policy: Policy,
    sizes: &[u64],
    n: usize,
    batch_seq: u64,
    counts: &mut Vec<usize>,
    order: &mut Vec<usize>,
) {
    let b = sizes.len();
    assert!(b > 0, "router needs at least one block");
    counts.clear();
    match policy {
        Policy::Even => {
            counts.extend((0..b).map(|i| n / b + usize::from(i < n % b)));
        }
        Policy::LeastLoaded => {
            // Water-filling: raise the lowest blocks to a common level.
            // Monotone fill invariant: a level pass never raises a block
            // past the next (untouched) block's size, and the remainder
            // is spread base + at-most-one, so whenever `n` covers the
            // total gap to the tallest block the post-route spread is
            // max−min ≤ 1.
            counts.resize(b, 0);
            order.clear();
            order.extend(0..b);
            // (size, index) key: deterministic tie-break equal to the
            // stable sort, but through the alloc-free unstable sorter
            // (a stable `sort_by_key` allocates its merge buffer).
            order.sort_unstable_by_key(|&i| (sizes[i], i));
            let mut remaining = n as u64;
            // Grow the active prefix: raise the `filled` lowest blocks
            // exactly to the next block's size while the budget covers
            // the full step (the tight gap: width × height, no +1 slack).
            let mut level = sizes[order[0]];
            let mut filled = 1usize;
            while filled < b {
                let next = sizes[order[filled]];
                let step = (next - level).saturating_mul(filled as u64);
                if step > remaining {
                    break;
                }
                remaining -= step;
                level = next;
                filled += 1;
            }
            // Spread what's left over the active prefix: base for all,
            // one extra for the first `remaining % filled` — final
            // heights within the prefix differ by at most 1 and never
            // exceed the first untouched block's size.
            let base = remaining / filled as u64;
            let extra = (remaining % filled as u64) as usize;
            for (j, &i) in order[..filled].iter().enumerate() {
                counts[i] = (level - sizes[i] + base + u64::from(j < extra)) as usize;
            }
        }
        Policy::Hash => {
            // The even split rotated by a hash of the sequence number,
            // computed directly per slot (no temporary even vector).
            let shift = (batch_seq.wrapping_mul(0x9E3779B97F4A7C15) % b as u64) as usize;
            counts.extend((0..b).map(|i| {
                let j = (i + b - shift) % b;
                n / b + usize::from(j < n % b)
            }));
        }
    }
}

/// Split a *global* per-block routing decision across shards that own
/// `blocks_per_shard` consecutive blocks each: shard `k` receives the
/// counts for blocks `[k·bps, (k+1)·bps)` together with the offset of its
/// first value in the batch (values are consumed in block order, so each
/// shard's slice is contiguous).
///
/// Routing globally and then slicing is what makes the sharded store's
/// layout independent of the shard count: S shards × B/S blocks see
/// exactly the per-block pushes one S=1 store with B blocks would, so a
/// sealed flatten concatenation is byte-identical across shard counts.
pub fn split_for_shards(counts: &[usize], blocks_per_shard: usize) -> Vec<(usize, &[usize])> {
    assert!(blocks_per_shard > 0, "blocks_per_shard must be positive");
    assert_eq!(counts.len() % blocks_per_shard, 0, "blocks not divisible into shards");
    let mut out = Vec::with_capacity(counts.len() / blocks_per_shard);
    let mut offset = 0usize;
    for chunk in counts.chunks(blocks_per_shard) {
        out.push((offset, chunk));
        offset += chunk.iter().sum::<usize>();
    }
    out
}

/// In-place [`split_for_shards`]: writes one `(value_offset, value_len)`
/// range per shard into `ranges` (cleared first). The range indexes the
/// *batch value slice* — shard `k`'s values are
/// `&values[offset..offset + len]` and its counts are
/// `&counts[k·bps..(k+1)·bps]` — so the dispatcher hands every shard a
/// sub-slice of the original batch instead of materialising per-shard
/// vectors. Same contiguity/conservation contract as the collecting
/// version (which is retained as the reference path).
pub fn split_for_shards_into(
    counts: &[usize],
    blocks_per_shard: usize,
    ranges: &mut Vec<(usize, usize)>,
) {
    assert!(blocks_per_shard > 0, "blocks_per_shard must be positive");
    assert_eq!(counts.len() % blocks_per_shard, 0, "blocks not divisible into shards");
    ranges.clear();
    let mut offset = 0usize;
    for chunk in counts.chunks(blocks_per_shard) {
        let len = chunk.iter().sum::<usize>();
        ranges.push((offset, len));
        offset += len;
    }
}

/// Reusable buffers of the coordinator's dispatch hot path. One arena
/// lives in the coordinator worker for the whole service lifetime; every
/// buffer is cleared (capacity retained), never dropped, so the
/// steady-state batch loop performs zero heap allocations — the
/// DynaSOAr-style allocation discipline applied to the host side.
#[derive(Debug, Default)]
pub struct DispatchScratch {
    /// Global per-block sizes (the dispatcher refreshes these per batch).
    pub sizes: Vec<u64>,
    /// Global per-block insert counts ([`route_into`] output).
    pub counts: Vec<usize>,
    /// Per-shard `(value_offset, value_len)` ranges into the batch slice
    /// ([`split_for_shards_into`] output).
    pub ranges: Vec<(usize, usize)>,
    /// Per-shard `(offset, len)` ranges into a shared gather destination
    /// (the shard scheduler's parallel flatten/seal fan-out) — index-based
    /// like `ranges`, so jobs carry plain offsets instead of borrows, and
    /// kept separate from `ranges` so a barriered gather never clobbers
    /// the last routed batch's slicing.
    pub gather_ranges: Vec<(usize, usize)>,
    /// Per-shard simulated-clock marks (cost accounting around one op).
    pub marks: Vec<f64>,
    /// Index-sort scratch for [`Policy::LeastLoaded`].
    order: Vec<usize>,
}

impl DispatchScratch {
    pub fn new() -> DispatchScratch {
        DispatchScratch::default()
    }

    /// Route `n` elements over `self.sizes` into `self.counts`.
    pub fn route(&mut self, policy: Policy, n: usize, batch_seq: u64) -> &[usize] {
        route_into(policy, &self.sizes, n, batch_seq, &mut self.counts, &mut self.order);
        &self.counts
    }

    /// Slice the routed counts per shard into `self.ranges`.
    pub fn split_for_shards(&mut self, blocks_per_shard: usize) -> &[(usize, usize)] {
        split_for_shards_into(&self.counts, blocks_per_shard, &mut self.ranges);
        &self.ranges
    }

    /// The counts sub-slice owned by shard `k` (its `blocks_per_shard`
    /// consecutive blocks of the global decision).
    pub fn shard_counts(&self, k: usize, blocks_per_shard: usize) -> &[usize] {
        &self.counts[k * blocks_per_shard..(k + 1) * blocks_per_shard]
    }

    /// Fill `self.gather_ranges` with the prefix-sum carve of a shared
    /// gather destination: shard `k` owns `(Σ lens[..k], lens[k])`. The
    /// buffer keeps its capacity across calls, so steady-state gathers
    /// slice without heap traffic.
    pub fn fill_gather_ranges(
        &mut self,
        lens: impl Iterator<Item = usize>,
    ) -> &[(usize, usize)] {
        self.gather_ranges.clear();
        let mut offset = 0usize;
        for len in lens {
            self.gather_ranges.push((offset, len));
            offset += len;
        }
        &self.gather_ranges
    }
}

/// Max/min block size after applying `counts` — the balance metric.
pub fn imbalance_after(sizes: &[u64], counts: &[usize]) -> f64 {
    let after: Vec<u64> = sizes.iter().zip(counts).map(|(&s, &c)| s + c as u64).collect();
    let max = *after.iter().max().unwrap() as f64;
    let min = *after.iter().min().unwrap() as f64;
    if min == 0.0 {
        if max == 0.0 {
            1.0
        } else {
            f64::INFINITY
        }
    } else {
        max / min
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conservation_all_policies() {
        let sizes = vec![10u64, 0, 500, 30, 30, 2];
        for policy in [Policy::Even, Policy::LeastLoaded, Policy::Hash] {
            for n in [0usize, 1, 5, 6, 7, 1000, 12345] {
                let counts = route(policy, &sizes, n, 7);
                assert_eq!(counts.iter().sum::<usize>(), n, "{policy:?} n={n}");
                assert_eq!(counts.len(), sizes.len());
            }
        }
    }

    #[test]
    fn even_split_shape() {
        let counts = route(Policy::Even, &[0; 4], 10, 0);
        assert_eq!(counts, vec![3, 3, 2, 2]);
    }

    #[test]
    fn least_loaded_rebalances() {
        let sizes = vec![100u64, 0, 0, 100];
        let counts = route(Policy::LeastLoaded, &sizes, 200, 0);
        let after: Vec<u64> = sizes.iter().zip(&counts).map(|(&s, &c)| s + c as u64).collect();
        let max = *after.iter().max().unwrap();
        let min = *after.iter().min().unwrap();
        assert!(max - min <= 1, "after {after:?}");
        // Strictly better balance than the even split.
        let even = route(Policy::Even, &sizes, 200, 0);
        assert!(imbalance_after(&sizes, &counts) < imbalance_after(&sizes, &even));
    }

    #[test]
    fn least_loaded_level_pass_never_overshoots() {
        // Regression: the old level pass capped the fill at
        // `remaining/(k+1) + 1`, which could raise low blocks past the
        // next level and leave a max−min of 2+ even when the batch was
        // big enough to fully level the store (e.g. [0,0,0] with n=4
        // produced [2,2,0]).
        let sizes = vec![0u64, 0, 0];
        let counts = route(Policy::LeastLoaded, &sizes, 4, 0);
        let after: Vec<u64> = sizes.iter().zip(&counts).map(|(&s, &c)| s + c as u64).collect();
        let max = *after.iter().max().unwrap();
        let min = *after.iter().min().unwrap();
        assert!(max - min <= 1, "after {after:?}");
        // Partial fills stay below the first untouched block.
        let sizes = vec![10u64, 2, 50];
        let counts = route(Policy::LeastLoaded, &sizes, 11, 0);
        let after: Vec<u64> = sizes.iter().zip(&counts).map(|(&s, &c)| s + c as u64).collect();
        // 8 raise block 1 to 10, remaining 3 spread over {0,1}: ≤ 12.
        assert!(after[0] <= 12 && after[1] <= 12, "after {after:?}");
        assert_eq!(after[2], 50, "tallest block untouched by a partial fill");
        assert!(after.iter().take(2).all(|&h| h <= 50));
    }

    #[test]
    fn least_loaded_handles_small_batches() {
        let sizes = vec![5u64, 1, 9];
        let counts = route(Policy::LeastLoaded, &sizes, 2, 0);
        assert_eq!(counts.iter().sum::<usize>(), 2);
        // Both go to the smallest block.
        assert_eq!(counts[1], 2, "{counts:?}");
    }

    #[test]
    fn hash_varies_with_sequence() {
        let sizes = vec![0u64; 8];
        let a = route(Policy::Hash, &sizes, 9, 1);
        let b = route(Policy::Hash, &sizes, 9, 2);
        assert_eq!(a.iter().sum::<usize>(), 9);
        assert_eq!(b.iter().sum::<usize>(), 9);
        assert_ne!(a, b, "different sequence numbers should rotate the split");
    }

    #[test]
    fn split_for_shards_slices_are_contiguous_and_conserving() {
        let sizes = vec![3u64, 9, 0, 4, 4, 4, 100, 2];
        for policy in [Policy::Even, Policy::LeastLoaded, Policy::Hash] {
            let counts = route(policy, &sizes, 1234, 5);
            let shards = split_for_shards(&counts, 2);
            assert_eq!(shards.len(), 4);
            let mut expect_offset = 0usize;
            let mut total = 0usize;
            for (k, (offset, sub)) in shards.into_iter().enumerate() {
                assert_eq!(offset, expect_offset, "{policy:?} shard {k}");
                assert_eq!(sub, &counts[k * 2..(k + 1) * 2]);
                expect_offset += sub.iter().sum::<usize>();
                total += sub.iter().sum::<usize>();
            }
            assert_eq!(total, 1234, "{policy:?}");
        }
    }

    #[test]
    #[should_panic(expected = "divisible")]
    fn split_for_shards_rejects_ragged() {
        split_for_shards(&[1, 2, 3], 2);
    }

    /// The pre-refactor copying implementations, retained verbatim as
    /// the reference the scratch-arena path is property-tested against
    /// (see also the service-level byte-identity test in
    /// `tests/properties.rs`).
    mod reference {
        use super::super::Policy;

        pub fn route(policy: Policy, sizes: &[u64], n: usize, batch_seq: u64) -> Vec<usize> {
            let b = sizes.len();
            assert!(b > 0);
            match policy {
                Policy::Even => (0..b).map(|i| n / b + usize::from(i < n % b)).collect(),
                Policy::LeastLoaded => {
                    let mut order: Vec<usize> = (0..b).collect();
                    order.sort_by_key(|&i| sizes[i]); // stable sort
                    let mut counts = vec![0usize; b];
                    let mut remaining = n as u64;
                    let mut level = sizes[order[0]];
                    let mut filled = 1usize;
                    while filled < b {
                        let next = sizes[order[filled]];
                        let step = (next - level).saturating_mul(filled as u64);
                        if step > remaining {
                            break;
                        }
                        remaining -= step;
                        level = next;
                        filled += 1;
                    }
                    let base = remaining / filled as u64;
                    let extra = (remaining % filled as u64) as usize;
                    for (j, &i) in order[..filled].iter().enumerate() {
                        counts[i] = (level - sizes[i] + base + u64::from(j < extra)) as usize;
                    }
                    counts
                }
                Policy::Hash => {
                    let even = route(Policy::Even, sizes, n, 0);
                    let shift = (batch_seq.wrapping_mul(0x9E3779B97F4A7C15) % b as u64) as usize;
                    (0..b).map(|i| even[(i + b - shift) % b]).collect()
                }
            }
        }

        pub fn split_for_shards(counts: &[usize], bps: usize) -> Vec<(usize, Vec<usize>)> {
            let mut out = Vec::new();
            let mut offset = 0usize;
            for chunk in counts.chunks(bps) {
                out.push((offset, chunk.to_vec()));
                offset += chunk.iter().sum::<usize>();
            }
            out
        }
    }

    #[test]
    fn route_into_matches_reference_for_every_policy() {
        let mut rng = crate::util::rng::Rng::new(0xD15);
        let mut scratch = DispatchScratch::new();
        for case in 0..300 {
            let b = rng.range(1, 33) as usize;
            let sizes: Vec<u64> = (0..b).map(|_| rng.below(1000)).collect();
            let n = rng.below(5000) as usize;
            let seq = rng.below(1 << 20);
            for policy in [Policy::Even, Policy::LeastLoaded, Policy::Hash] {
                let want = reference::route(policy, &sizes, n, seq);
                scratch.sizes.clear();
                scratch.sizes.extend_from_slice(&sizes);
                let got = scratch.route(policy, n, seq);
                assert_eq!(got, want, "case {case} {policy:?} sizes={sizes:?} n={n}");
                // The collecting wrapper agrees too.
                assert_eq!(route(policy, &sizes, n, seq), want);
            }
        }
    }

    #[test]
    fn split_into_ranges_match_reference_slices() {
        let mut rng = crate::util::rng::Rng::new(0x51ab);
        let mut scratch = DispatchScratch::new();
        for _ in 0..200 {
            let shards = rng.range(1, 9) as usize;
            let bps = rng.range(1, 9) as usize;
            let counts: Vec<usize> = (0..shards * bps).map(|_| rng.below(100) as usize).collect();
            let want = reference::split_for_shards(&counts, bps);
            scratch.counts.clear();
            scratch.counts.extend_from_slice(&counts);
            let ranges = scratch.split_for_shards(bps).to_vec();
            assert_eq!(ranges.len(), want.len());
            for (k, ((offset, len), (want_off, want_counts))) in
                ranges.iter().zip(&want).enumerate()
            {
                assert_eq!(offset, want_off, "shard {k}");
                assert_eq!(*len, want_counts.iter().sum::<usize>(), "shard {k}");
                assert_eq!(scratch.shard_counts(k, bps), &want_counts[..], "shard {k}");
            }
        }
    }

    #[test]
    fn gather_ranges_are_prefix_sums_and_reuse_capacity() {
        let mut scratch = DispatchScratch::new();
        let ranges = scratch.fill_gather_ranges([3usize, 0, 7, 2].into_iter()).to_vec();
        assert_eq!(ranges, vec![(0, 3), (3, 0), (3, 7), (10, 2)]);
        let ptr = scratch.gather_ranges.as_ptr();
        for _ in 0..10 {
            scratch.fill_gather_ranges([1usize, 2, 3, 4].into_iter());
        }
        assert_eq!(scratch.gather_ranges.as_ptr(), ptr, "gather ranges buffer must be reused");
        assert_eq!(scratch.gather_ranges, vec![(0, 1), (1, 2), (3, 3), (6, 4)]);
        // Disjoint from the insert ranges.
        scratch.counts.extend_from_slice(&[5, 5]);
        scratch.split_for_shards(1);
        assert_eq!(scratch.ranges, vec![(0, 5), (5, 5)]);
        assert_eq!(scratch.gather_ranges, vec![(0, 1), (1, 2), (3, 3), (6, 4)]);
    }

    #[test]
    fn scratch_buffers_keep_capacity_across_batches() {
        let mut scratch = DispatchScratch::new();
        scratch.sizes.extend_from_slice(&[5, 5, 5, 5]);
        scratch.route(Policy::LeastLoaded, 100, 0);
        scratch.split_for_shards(2);
        let (pc, pr) = (scratch.counts.as_ptr(), scratch.ranges.as_ptr());
        for seq in 1..50u64 {
            scratch.sizes.clear();
            scratch.sizes.extend_from_slice(&[9, 1, 7, 3]);
            scratch.route(Policy::LeastLoaded, 64, seq);
            scratch.split_for_shards(2);
        }
        assert_eq!(scratch.counts.as_ptr(), pc, "counts buffer must be reused");
        assert_eq!(scratch.ranges.as_ptr(), pr, "ranges buffer must be reused");
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in [Policy::Even, Policy::LeastLoaded, Policy::Hash] {
            assert_eq!(Policy::by_name(p.name()), Some(p));
        }
        assert_eq!(Policy::by_name("bogus"), None);
    }
}
