//! Stealable work units for the scheduler's fan-out phases.
//!
//! A [`Chunk`] is pure *data movement*: every simulated heap/clock
//! charge that a phase owes was already paid serially, in shard order,
//! by the coordinator before any chunk was injected (the charge/copy
//! split — see [`crate::coordinator::shard::Shard::prepare_counts`] /
//! `seal_flatten_charge` / `flatten_temp_charge` / the hoisted rw_b
//! pre-charge in `Scheduler::run_work`). Host-side copies are free in
//! simulated time, so executing chunks in *any* steal order yields
//! byte-identical array contents, heap residency, and exact `sim_us` —
//! the property `tests/properties.rs` pins across executor modes. The
//! split also powers abort rollback: a chunk that panics (fault
//! injection or a real bug) has mutated nothing but its own disjoint
//! data range, so the coordinator can rewind the serial charges and
//! surface a typed error.
//!
//! ## Lease discipline
//!
//! Chunks carry the same provenance-preserving wrappers the old
//! mailbox pool used ([`SendPtr`]/[`SendSlice`]/[`SendSliceMut`]), with
//! one refinement: gather chunks re-materialise a *shared* shard
//! reference (`SendPtr::deref_ref`), so several range chunks of one
//! large shard read it concurrently, while insert-fill chunks own
//! disjoint `split_at_mut`-carved block ranges of one shard. The
//! submitting `run_*` call holds the `&mut [Shard]` borrow across the
//! whole phase and `WorkPhase::finish` is the barrier, so every
//! pointed-to region outlives its chunk and is never aliased by a
//! writer.

use crate::sync::{Arc, SendPtr, SendSlice, SendSliceMut};

use crate::ggarray::lfvector::LfVector;
use crate::runtime::Executor;

use super::super::shard::Shard;

/// One stealable job. Constructed only by the `run_*` phase builders in
/// [`super::Scheduler`], which uphold the module's lease contract.
pub(super) enum Chunk {
    /// Fill reserved tail slots of a contiguous block range of one
    /// shard with its contiguous sub-slice of the batch (pure copy;
    /// the charges happened in `prepare_counts`). `counts[i]` is the
    /// number of values owed to `blocks[i]`.
    InsertFill {
        blocks: SendSliceMut<LfVector<f32>>,
        counts: SendSlice<usize>,
        values: SendSlice<f32>,
    },
    /// One work call on one shard: the real numeric update only — the
    /// modeled `rw_b` charge was pre-paid serially by `run_work` so an
    /// aborted phase can rewind it. The PJRT client handle is shared
    /// across workers — each worker compiles into its own thread-local
    /// cache.
    Work { shard: SendPtr<Shard>, exec: Option<Arc<Executor>>, iters: u32 },
    /// Copy shard elements `src_start..src_start + dst.len()`
    /// (block-major flatten order) into a disjoint destination range.
    /// Reads the shard through a shared reference, so one large shard
    /// fans out into many concurrent gather chunks.
    GatherCopy { shard: SendPtr<Shard>, src_start: usize, dst: SendSliceMut<f32> },
}

impl Chunk {
    /// Execute one chunk on a worker thread. Returns the number of PJRT
    /// executions performed (non-zero only for `Work`).
    ///
    /// Every `unsafe` block re-materialises a reference from a lease
    /// wrapper; the shared justification is the module's lease
    /// contract: the `run_*` call that injected this chunk (a) derived
    /// every wrapper from a live borrow it holds across the whole
    /// phase, (b) carved writers disjoint (`split_at_mut` for slices, a
    /// distinct `iter_mut` element per Work shard) and gave readers no
    /// concurrent writer, and (c) blocks in `finish()` until this chunk
    /// completes.
    pub(super) fn execute(self) -> u64 {
        match self {
            Chunk::InsertFill { blocks, counts, values } => {
                // Fault site before any write: an injected panic here
                // models a worker dying with the chunk consumed but the
                // copy not yet started (ggfault builds only). The
                // `.slow` twin stalls instead of dying — a straggler
                // the other workers must steal around.
                crate::faults::point("scheduler.worker.fill");
                crate::faults::stall("scheduler.worker.fill.slow");
                // SAFETY: lease contract above — this chunk is the sole
                // owner of this block range for the phase.
                let blocks = unsafe { blocks.as_mut_slice() };
                // SAFETY: lease contract above — router scratch and
                // batch values are borrowed by the blocked submitter
                // and written by no one.
                let counts = unsafe { counts.as_slice() };
                // SAFETY: as for `counts`.
                let values = unsafe { values.as_slice() };
                let mut off = 0usize;
                for (v, &c) in blocks.iter_mut().zip(counts) {
                    if c == 0 {
                        continue;
                    }
                    let start = v.len() - c;
                    v.write_range(start, &values[off..off + c]);
                    off += c;
                }
                debug_assert_eq!(off, values.len(), "fill chunk must consume its whole sub-slice");
                0
            }
            Chunk::Work { shard, exec, iters } => {
                // Fault site before the numeric update (ggfault builds
                // only): the shard's rw_b charge was already paid
                // serially by `run_work`, so an abort rewinds it there.
                // The `.slow` twin simulates a straggling shard.
                crate::faults::point("scheduler.worker.work");
                crate::faults::stall("scheduler.worker.work.slow");
                // SAFETY: lease contract above — work chunks are
                // per-shard, so this is the phase's only access path to
                // this shard (clock included).
                let shard = unsafe { shard.deref_mut() };
                // Pure numeric update; the modeled rw_b launch is
                // pre-charged serially by `run_work` so an aborted phase
                // can rewind it (f64 addition of the same deltas in the
                // same per-shard order keeps sim_us byte-identical).
                shard.work_pass(exec.as_deref(), iters)
            }
            Chunk::GatherCopy { shard, src_start, dst } => {
                // Fault site before the copy (ggfault builds only);
                // the `.slow` twin stalls the gather instead.
                crate::faults::point("scheduler.worker.copy");
                crate::faults::stall("scheduler.worker.copy.slow");
                // SAFETY: lease contract above — gather phases never
                // inject a writer for this shard, so shared reads may
                // alias freely across its range chunks.
                let shard = unsafe { shard.deref_ref() };
                // SAFETY: lease contract above — `dst` was carved
                // disjoint with split_at_mut before wrapping.
                let dst = unsafe { dst.as_mut_slice() };
                shard.gather_copy_range(src_start, dst);
                0
            }
        }
    }
}
