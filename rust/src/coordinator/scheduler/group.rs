//! Bucketed worker group: persistent threads, per-worker deques,
//! steal-on-empty, and one shared monitor for park/unpark/termination.
//!
//! This is the concurrency core of the [`super`] scheduler, kept
//! generic over the job type so `tests/model_check.rs` can drive the
//! *exact* production protocol with tiny observable payloads (`u32`
//! jobs, slot writes) under the `--cfg ggcheck` checker.
//!
//! ## Protocol
//!
//! One `Mutex<GroupState>` + two `Condvar`s form the monitor:
//!
//! * **Injection** (coordinator): for each job, `pending += 1` under
//!   the monitor *before* the job is pushed onto a deque — so `pending`
//!   can never undercount work in flight. Jobs spread round-robin
//!   across the per-worker deques. `finish` then bumps `epoch` and
//!   `notify_all`s the work condvar.
//! * **Workers**: pop their own deque front, else steal another deque's
//!   back. On empty, they take the monitor and either observe
//!   `shutdown`, observe `epoch != seen` (an injection raced the scan —
//!   rescan), or park on the work condvar. `seen` is only ever
//!   refreshed while the monitor is held, which is what makes the
//!   park decision sound: a worker parks only if every job of every
//!   epoch it has seen was already popped by someone.
//! * **Termination** (coordinator): a phase is over when the bucket is
//!   drained *and* every worker is parked — `pending == 0 && parked ==
//!   workers`, checked under the same monitor. Workers signal the done
//!   condvar when they complete the last pending job and when they park
//!   with nothing pending. No per-worker barrier exists anywhere.
//!
//! ## Why no lost wakeup
//!
//! A worker parks only while holding the monitor with `epoch == seen`.
//! Every injection bumps `epoch` under the monitor and `notify_all`s
//! after its pushes. So a push that a scan missed either (a) completed
//! before the scan — impossible, the scan locks every deque after
//! `seen` was read, so it would have found the job — or (b) raced the
//! scan, in which case the worker sees `epoch != seen` at the park
//! check, or parks before the bump and is notified. All three suites
//! are exhaustively model-checked in `tests/model_check.rs`.
//!
//! ## Panic containment and self-healing
//!
//! A job payload that panics must not take the group down. The worker
//! wraps every `run(job)` in `catch_unwind`; on a contained panic it
//! restores the monitor bookkeeping (`pending -= 1`, the job counts in
//! `failed` instead of `executed`), deregisters itself from `live`,
//! records its index for healing, signals the done condvar, and exits.
//! The monitor is never poisoned — every mutation of `GroupState`
//! happens either before the payload runs or after the unwind is
//! caught, and readers recover from a stale poison flag via
//! [`crate::sync::lock_recover`] anyway.
//!
//! Termination therefore compares `parked` against `live`, not the
//! spawn-time thread count: `pending == 0 && parked == live`. A dead
//! worker's in-flight job decremented `pending` on the containment
//! path, and its deque is stealable by every survivor, so the drained+
//! parked argument above carries over unchanged. If *every* worker is
//! dead (`live == 0`) the coordinator drains the remaining chunks
//! inline in [`WorkPhase::finish`] — the floor-1 ≡ serial guarantee.
//! [`WorkPhase::finish`] returns a [`PhaseReport`] with the contained-
//! failure count so the scheduler can abort + roll back the op, then
//! respawns each dead worker (ledgered as `worker_respawns`) or, when
//! the respawn itself fails — deterministically injectable via the
//! `scheduler.spawn` fault site — permanently degrades the group
//! (`degraded_workers`/`spawn_failures`). Construction takes the same
//! path: a failed spawn degrades to however many workers came up
//! instead of panicking `Coordinator::start`.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

use crate::sync::lock_recover as lock;
use crate::sync::thread;
use crate::sync::{Arc, Condvar, Mutex};

/// Everything the monitor protects. Counters live here too (not in
/// atomics): every event that bumps one already holds the monitor, so
/// the ledger rides along for free and stays exactly consistent with
/// the protocol state it describes.
struct GroupState {
    /// Bumped once per non-empty phase; workers re-scan when it moves.
    epoch: u64,
    /// Jobs injected but not yet executed (incremented *before* the
    /// deque push, decremented *after* the job body returns).
    pending: usize,
    /// Workers currently blocked on the work condvar.
    parked: usize,
    /// Set once by `Drop`; workers exit at the next park decision.
    shutdown: bool,
    /// Worker threads currently alive. Termination compares `parked`
    /// against this; a contained panic decrements it.
    live: usize,
    /// Jobs whose payload panicked this phase (contained). Read and
    /// reset by `finish`.
    failed: u64,
    /// Indices of workers that died this phase, awaiting healing.
    dead: Vec<usize>,
    /// Ledger: jobs popped from a deque the worker does not own.
    steals: u64,
    /// Ledger: park events (condvar waits entered).
    parks: u64,
    /// Ledger: jobs executed to completion.
    executed: u64,
    /// Ledger: worker spawn attempts that failed (construction or
    /// respawn).
    spawn_failures: u64,
    /// Ledger: dead workers successfully respawned after a contained
    /// panic.
    worker_respawns: u64,
    /// Ledger: workers permanently lost (their spawn or respawn
    /// failed). The group keeps serving down to zero live workers —
    /// `finish` drains inline, i.e. serial.
    degraded_workers: u64,
}

/// Monotonic ledger snapshot, exported through
/// [`crate::coordinator::metrics::MetricsSnapshot`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GroupCounters {
    pub steals: u64,
    pub parks: u64,
    pub executed: u64,
    pub spawn_failures: u64,
    pub worker_respawns: u64,
    pub degraded_workers: u64,
}

/// What a phase's termination observed. Returned by
/// [`WorkPhase::finish`] so the scheduler can abort the op when any
/// chunk panicked.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PhaseReport {
    /// Chunks whose payload panicked (contained, consumed, not
    /// executed). Zero in a healthy run.
    pub failed: u64,
}

impl PhaseReport {
    /// Did every chunk of the phase execute to completion?
    pub fn ok(&self) -> bool {
        self.failed == 0
    }
}

struct Inner<J> {
    monitor: Mutex<GroupState>,
    /// Workers park here between phases.
    work_cv: Condvar,
    /// The coordinator parks here awaiting phase termination.
    done_cv: Condvar,
    /// One stealable bucket per worker. Jobs are injected round-robin;
    /// owners pop the front, thieves pop the back.
    deques: Vec<Mutex<VecDeque<J>>>,
}

impl<J> Inner<J> {
    /// Pop one job: own deque first (front), then sweep the others
    /// (back) starting at the neighbour. Returns the job and whether it
    /// was stolen.
    fn find_job(&self, k: usize) -> Option<(J, bool)> {
        if let Some(job) = lock(&self.deques[k]).pop_front() {
            return Some((job, false));
        }
        let n = self.deques.len();
        for d in 1..n {
            let victim = (k + d) % n;
            if let Some(job) = lock(&self.deques[victim]).pop_back() {
                return Some((job, true));
            }
        }
        None
    }

    fn worker_loop(&self, k: usize, run: &(dyn Fn(J) + Send + Sync)) {
        // `epoch` starts at 0 and only moves under the monitor, so the
        // initial `seen` needs no lock.
        let mut seen = 0u64;
        loop {
            if let Some((job, stolen)) = self.find_job(k) {
                if let Err(payload) = catch_unwind(AssertUnwindSafe(|| run(job))) {
                    // The model checker cancels losing branches by
                    // unwinding a private token through every frame —
                    // that unwind is scheduler machinery, not a payload
                    // fault, and must pass through untouched.
                    if crate::checker::rt::cancelled() {
                        resume_unwind(payload);
                    }
                    // Contained payload panic: restore the monitor
                    // bookkeeping (the job was admitted and consumed),
                    // deregister from the live set, and die. Everything
                    // the monitor guards is counters and flags mutated
                    // only outside the payload, so the state stays
                    // coherent; `lock_recover` covers the poison flag.
                    let mut st = lock(&self.monitor);
                    debug_assert!(st.pending > 0, "failed a job the monitor never admitted");
                    st.pending -= 1;
                    st.failed += 1;
                    st.live -= 1;
                    st.dead.push(k);
                    // Both termination conditions may have just become
                    // true: pending can be 0, and parked == live can
                    // hold with one fewer live worker.
                    self.done_cv.notify_all();
                    return;
                }
                let mut st = lock(&self.monitor);
                debug_assert!(st.pending > 0, "executed a job the monitor never admitted");
                st.pending -= 1;
                st.executed += 1;
                if stolen {
                    st.steals += 1;
                }
                seen = st.epoch;
                if st.pending == 0 {
                    self.done_cv.notify_all();
                }
                continue;
            }
            let mut st = lock(&self.monitor);
            if st.shutdown {
                return;
            }
            if st.epoch != seen {
                // An injection raced the scan; its jobs may sit in a
                // deque the sweep already passed. Rescan, never park.
                seen = st.epoch;
                continue;
            }
            st.parked += 1;
            st.parks += 1;
            if st.pending == 0 {
                // This park may complete the all-parked + drained
                // termination condition.
                self.done_cv.notify_all();
            }
            st = self.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            st.parked -= 1;
            seen = st.epoch;
        }
    }
}

/// A persistent group of worker threads sharing one stealable bucket of
/// jobs (see the module doc for the full protocol). Spawned once,
/// reused for every phase, joined on drop.
pub struct WorkerGroup<J: Send + 'static> {
    inner: Arc<Inner<J>>,
    /// Kept for healing: respawned workers run the same closure.
    run: Arc<dyn Fn(J) + Send + Sync>,
    /// Under a mutex so `finish` (which only holds `&WorkerGroup`) can
    /// push respawned handles; uncontended everywhere (the coordinator
    /// is single-threaded by contract).
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl<J: Send + 'static> WorkerGroup<J> {
    /// Spawn `workers` threads, each running injected jobs through
    /// `run`. Threads park on the shared monitor between phases — no
    /// busy-waiting. A failed spawn does not panic: the group degrades
    /// to however many workers came up (ledgered as `spawn_failures`/
    /// `degraded_workers`), down to zero — `finish` then drains phases
    /// inline, which is the serial floor.
    pub fn new(workers: usize, run: impl Fn(J) + Send + Sync + 'static) -> WorkerGroup<J> {
        assert!(workers > 0, "worker group needs at least one thread");
        let inner = Arc::new(Inner {
            monitor: Mutex::new(GroupState {
                epoch: 0,
                pending: 0,
                parked: 0,
                shutdown: false,
                live: workers,
                failed: 0,
                dead: Vec::new(),
                steals: 0,
                parks: 0,
                executed: 0,
                spawn_failures: 0,
                worker_respawns: 0,
                degraded_workers: 0,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            // Deques stay at the requested count even when fewer
            // workers spawn: injection spreads round-robin over all of
            // them and stealing covers unowned deques.
            deques: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
        });
        let run: Arc<dyn Fn(J) + Send + Sync> = Arc::new(run);
        let mut handles = Vec::with_capacity(workers);
        for k in 0..workers {
            match Self::spawn_worker(&inner, &run, k) {
                Ok(h) => handles.push(h),
                Err(()) => {
                    let mut st = lock(&inner.monitor);
                    st.live -= 1;
                    st.spawn_failures += 1;
                    st.degraded_workers += 1;
                }
            }
        }
        WorkerGroup { inner, run, handles: Mutex::new(handles) }
    }

    /// One spawn attempt for worker `k`. The `scheduler.spawn` fault
    /// site simulates the OS refusing the thread (ggfault builds
    /// only); a real `Builder::spawn` error takes the same path.
    fn spawn_worker(
        inner: &Arc<Inner<J>>,
        run: &Arc<dyn Fn(J) + Send + Sync>,
        k: usize,
    ) -> Result<thread::JoinHandle<()>, ()> {
        if crate::faults::injected("scheduler.spawn") {
            return Err(());
        }
        let inner = Arc::clone(inner);
        let run = Arc::clone(run);
        thread::Builder::new()
            .name(format!("ggarray-sched-{k}")) // lint: allow(alloc) — once per spawn (construction/respawn), never per batch
            .spawn(move || inner.worker_loop(k, run.as_ref()))
            .map_err(|_| ())
    }

    /// Respawn the workers that died this phase (called by `finish`
    /// after termination, so no phase is in flight). A worker whose
    /// respawn fails is permanently lost — the group degrades instead
    /// of retrying forever.
    fn heal(&self, dead: Vec<usize>) {
        for k in dead {
            // Count the worker live *before* the spawn so a fast new
            // worker parking early can never make `parked` exceed
            // `live`.
            {
                let mut st = lock(&self.inner.monitor);
                st.live += 1;
            }
            match Self::spawn_worker(&self.inner, &self.run, k) {
                Ok(h) => {
                    lock(&self.handles).push(h);
                    let mut st = lock(&self.inner.monitor);
                    st.worker_respawns += 1;
                }
                Err(()) => {
                    let mut st = lock(&self.inner.monitor);
                    st.live -= 1;
                    st.spawn_failures += 1;
                    st.degraded_workers += 1;
                }
            }
        }
    }

    /// Floor-1 serial fallback: every worker is dead, so the phase's
    /// remaining chunks run inline on the coordinator thread.
    fn drain_inline(&self) {
        let inner = &self.inner;
        loop {
            let job = inner.deques.iter().find_map(|d| lock(d).pop_front());
            let Some(job) = job else { return };
            let ok = match catch_unwind(AssertUnwindSafe(|| (self.run)(job))) {
                Ok(()) => true,
                Err(payload) => {
                    if crate::checker::rt::cancelled() {
                        resume_unwind(payload);
                    }
                    false
                }
            };
            let mut st = lock(&inner.monitor);
            st.pending -= 1;
            if ok {
                st.executed += 1;
            } else {
                st.failed += 1;
            }
        }
    }

    /// Number of worker threads the group was built for (deque count —
    /// the round-robin injection width, even when degraded).
    pub fn threads(&self) -> usize {
        self.inner.deques.len()
    }

    /// Worker threads currently alive (≤ [`WorkerGroup::threads`] once
    /// spawns have failed or respawns degraded).
    pub fn live_workers(&self) -> usize {
        lock(&self.inner.monitor).live
    }

    /// Ledger snapshot (monotonic over the group's lifetime).
    pub fn counters(&self) -> GroupCounters {
        let st = lock(&self.inner.monitor);
        GroupCounters {
            steals: st.steals,
            parks: st.parks,
            executed: st.executed,
            spawn_failures: st.spawn_failures,
            worker_respawns: st.worker_respawns,
            degraded_workers: st.degraded_workers,
        }
    }

    /// Open a phase: inject any number of jobs, then `finish` blocks
    /// until the bucket is drained and every worker is parked. The
    /// coordinator is single-threaded by contract — phases never
    /// overlap (every `run_*` caller holds the one `&mut` shard borrow
    /// for the phase's whole lifetime).
    pub fn phase(&self) -> WorkPhase<'_, J> {
        WorkPhase { group: self, injected: 0, next: 0 }
    }

    /// Convenience for small call sites and the model suites: one phase
    /// containing `jobs`, run to termination.
    pub fn run_phase(&self, jobs: impl IntoIterator<Item = J>) -> PhaseReport {
        let mut phase = self.phase();
        for job in jobs {
            phase.inject(job);
        }
        phase.finish()
    }
}

impl<J: Send + 'static> Drop for WorkerGroup<J> {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.inner.monitor);
            st.shutdown = true;
            self.inner.work_cv.notify_all();
        }
        let handles = std::mem::take(&mut *lock(&self.handles));
        for h in handles {
            let _ = h.join();
        }
    }
}

/// One open phase on a [`WorkerGroup`]. Injection is cheap (two short
/// uncontended locks per job, no allocation in steady state — the
/// deques keep their capacity across phases); nothing starts a parked
/// worker until [`WorkPhase::finish`] publishes the epoch.
pub struct WorkPhase<'a, J: Send + 'static> {
    group: &'a WorkerGroup<J>,
    injected: usize,
    next: usize,
}

impl<J: Send + 'static> WorkPhase<'_, J> {
    /// Admit one job: count it as pending under the monitor *first*,
    /// then push it round-robin. A spinning (not yet parked) worker may
    /// legally pop it before `finish` — `pending` already covers it.
    pub fn inject(&mut self, job: J) {
        let inner = &self.group.inner;
        {
            let mut st = lock(&inner.monitor);
            st.pending += 1;
        }
        lock(&inner.deques[self.next]).push_back(job);
        self.next = (self.next + 1) % inner.deques.len();
        self.injected += 1;
    }

    /// Publish the phase (bump epoch, wake everyone) and block until
    /// termination: bucket drained (`pending == 0`) and all *live*
    /// workers parked. An empty phase skips the wakeup entirely —
    /// parked workers stay parked, exactly like the old pool skipping
    /// idle shards.
    ///
    /// Containment lives here too: if every worker died (`live == 0`)
    /// the remaining chunks are drained inline on this thread (floor 1
    /// ≡ serial), and after termination each worker that died this
    /// phase is respawned or the group permanently degrades. The
    /// returned [`PhaseReport`] carries the contained-failure count so
    /// the caller can abort + roll back the op.
    pub fn finish(self) -> PhaseReport {
        let inner = &self.group.inner;
        let mut st = lock(&inner.monitor);
        if self.injected > 0 {
            st.epoch += 1;
            inner.work_cv.notify_all();
        }
        loop {
            if st.pending == 0 && st.parked == st.live {
                break;
            }
            if st.live == 0 {
                drop(st);
                self.group.drain_inline();
                st = lock(&inner.monitor);
                continue;
            }
            st = inner.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        let failed = st.failed;
        st.failed = 0;
        let dead = std::mem::take(&mut st.dead);
        drop(st);
        if !dead.is_empty() {
            self.group.heal(dead);
        }
        PhaseReport { failed }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::atomic::{AtomicU64, Ordering};
    use crate::sync::SendSliceMut;

    #[test]
    fn group_runs_jobs_and_terminates_each_phase() {
        let sum = Arc::new(AtomicU64::new(0));
        let acc = Arc::clone(&sum);
        let group: WorkerGroup<u64> =
            WorkerGroup::new(3, move |j| {
                acc.fetch_add(j, Ordering::SeqCst);
            });
        group.run_phase(1..=100u64);
        // Termination is a barrier: every job completed before finish
        // returned, in every schedule.
        assert_eq!(sum.load(Ordering::SeqCst), 5050);
        group.run_phase(std::iter::once(50u64));
        assert_eq!(sum.load(Ordering::SeqCst), 5100);
        let c = group.counters();
        assert_eq!(c.executed, 101, "ledger counts every executed job");
    }

    #[test]
    fn empty_phases_are_free_and_legal() {
        let group: WorkerGroup<u64> = WorkerGroup::new(2, |_| {});
        for _ in 0..3 {
            group.run_phase(std::iter::empty());
        }
        assert_eq!(group.counters().executed, 0);
    }

    #[test]
    fn disjoint_slot_writes_land_regardless_of_steal_order() {
        let mut buf = vec![0u32; 64];
        {
            let group: WorkerGroup<(SendSliceMut<u32>, u32)> = WorkerGroup::new(4, |(dst, v)| {
                // SAFETY: each job's slice was carved disjoint with
                // split_at_mut below and the parent buffer outlives the
                // phase (finish() is the barrier).
                unsafe { dst.as_mut_slice() }.fill(v);
            });
            let mut phase = group.phase();
            let mut rest: &mut [u32] = &mut buf;
            let mut v = 1u32;
            while !rest.is_empty() {
                let take = rest.len().min(8);
                let chunk = std::mem::take(&mut rest);
                let (head, tail) = chunk.split_at_mut(take);
                rest = tail;
                phase.inject((SendSliceMut::new(head), v));
                v += 1;
            }
            phase.finish();
        }
        for (i, &x) in buf.iter().enumerate() {
            assert_eq!(x, (i / 8) as u32 + 1, "slot {i} written by the wrong chunk");
        }
    }

    #[test]
    fn contained_panic_respawns_and_keeps_serving() {
        crate::faults::quiet_panic_hook();
        let group: WorkerGroup<u32> = WorkerGroup::new(2, |j| {
            if j == 13 {
                panic!("{} chunk payload", crate::faults::EXPECTED_PANIC);
            }
        });
        let report = group.run_phase([1u32, 2, 13, 4]);
        assert_eq!(report.failed, 1);
        assert!(!report.ok());
        assert_eq!(group.live_workers(), 2, "dead worker respawned at phase end");
        let c = group.counters();
        assert_eq!(c.worker_respawns, 1);
        assert_eq!(c.degraded_workers, 0);
        assert_eq!(c.executed, 3, "the failed job counts in failed, not executed");
        // The group keeps serving after the contained panic.
        assert!(group.run_phase(0..100u32).ok());
        assert_eq!(group.counters().executed, 103);
    }

    #[test]
    fn all_workers_dead_mid_phase_drains_inline() {
        crate::faults::quiet_panic_hook();
        let group: WorkerGroup<u32> = WorkerGroup::new(1, |j| {
            if j >= 100 {
                panic!("{} every chunk", crate::faults::EXPECTED_PANIC);
            }
        });
        // The lone worker dies on the first poison job it pops; the
        // rest of the bucket drains inline on the coordinator thread
        // (floor 1 ≡ serial), containing each panic in turn.
        let report = group.run_phase([100u32, 101, 102, 103]);
        assert_eq!(report.failed, 4);
        assert_eq!(group.live_workers(), 1, "respawned after the phase");
        assert_eq!(group.counters().worker_respawns, 1);
        // Healthy phases still run on the respawned worker.
        assert!(group.run_phase([1u32, 2, 3]).ok());
        assert_eq!(group.counters().executed, 3);
    }

    #[test]
    fn drop_joins_workers_even_when_idle() {
        let group: WorkerGroup<u32> = WorkerGroup::new(4, |_| {});
        assert_eq!(group.threads(), 4);
        drop(group); // must not hang or leak threads
    }

    #[test]
    fn steal_and_park_ledgers_move() {
        let group: WorkerGroup<u64> = WorkerGroup::new(2, |j| {
            if j == 0 {
                thread::yield_now();
            }
        });
        for _ in 0..50 {
            group.run_phase(0..8u64);
        }
        let c = group.counters();
        assert_eq!(c.executed, 400);
        // Parks are guaranteed (every phase terminates all-parked);
        // steals are opportunistic, so only assert the ledger is sane.
        assert!(c.parks >= 2, "workers must have parked between phases");
        assert!(c.steals <= c.executed);
    }
}
