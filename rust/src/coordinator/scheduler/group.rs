//! Bucketed worker group: persistent threads, per-worker deques,
//! steal-on-empty, and one shared monitor for park/unpark/termination.
//!
//! This is the concurrency core of the [`super`] scheduler, kept
//! generic over the job type so `tests/model_check.rs` can drive the
//! *exact* production protocol with tiny observable payloads (`u32`
//! jobs, slot writes) under the `--cfg ggcheck` checker.
//!
//! ## Protocol
//!
//! One `Mutex<GroupState>` + two `Condvar`s form the monitor:
//!
//! * **Injection** (coordinator): for each job, `pending += 1` under
//!   the monitor *before* the job is pushed onto a deque — so `pending`
//!   can never undercount work in flight. Jobs spread round-robin
//!   across the per-worker deques. `finish` then bumps `epoch` and
//!   `notify_all`s the work condvar.
//! * **Workers**: pop their own deque front, else steal another deque's
//!   back. On empty, they take the monitor and either observe
//!   `shutdown`, observe `epoch != seen` (an injection raced the scan —
//!   rescan), or park on the work condvar. `seen` is only ever
//!   refreshed while the monitor is held, which is what makes the
//!   park decision sound: a worker parks only if every job of every
//!   epoch it has seen was already popped by someone.
//! * **Termination** (coordinator): a phase is over when the bucket is
//!   drained *and* every worker is parked — `pending == 0 && parked ==
//!   workers`, checked under the same monitor. Workers signal the done
//!   condvar when they complete the last pending job and when they park
//!   with nothing pending. No per-worker barrier exists anywhere.
//!
//! ## Why no lost wakeup
//!
//! A worker parks only while holding the monitor with `epoch == seen`.
//! Every injection bumps `epoch` under the monitor and `notify_all`s
//! after its pushes. So a push that a scan missed either (a) completed
//! before the scan — impossible, the scan locks every deque after
//! `seen` was read, so it would have found the job — or (b) raced the
//! scan, in which case the worker sees `epoch != seen` at the park
//! check, or parks before the bump and is notified. All three suites
//! are exhaustively model-checked in `tests/model_check.rs`.

use std::collections::VecDeque;

use crate::sync::thread;
use crate::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Poison-tolerant lock: teardown runs from `Drop` and must never
/// double-panic; the protected state stays meaningful after a payload
/// panic (counters and flags only).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Everything the monitor protects. Counters live here too (not in
/// atomics): every event that bumps one already holds the monitor, so
/// the ledger rides along for free and stays exactly consistent with
/// the protocol state it describes.
struct GroupState {
    /// Bumped once per non-empty phase; workers re-scan when it moves.
    epoch: u64,
    /// Jobs injected but not yet executed (incremented *before* the
    /// deque push, decremented *after* the job body returns).
    pending: usize,
    /// Workers currently blocked on the work condvar.
    parked: usize,
    /// Set once by `Drop`; workers exit at the next park decision.
    shutdown: bool,
    /// Ledger: jobs popped from a deque the worker does not own.
    steals: u64,
    /// Ledger: park events (condvar waits entered).
    parks: u64,
    /// Ledger: jobs executed to completion.
    executed: u64,
}

/// Monotonic ledger snapshot, exported through
/// [`crate::coordinator::metrics::MetricsSnapshot`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GroupCounters {
    pub steals: u64,
    pub parks: u64,
    pub executed: u64,
}

struct Inner<J> {
    monitor: Mutex<GroupState>,
    /// Workers park here between phases.
    work_cv: Condvar,
    /// The coordinator parks here awaiting phase termination.
    done_cv: Condvar,
    /// One stealable bucket per worker. Jobs are injected round-robin;
    /// owners pop the front, thieves pop the back.
    deques: Vec<Mutex<VecDeque<J>>>,
}

impl<J> Inner<J> {
    /// Pop one job: own deque first (front), then sweep the others
    /// (back) starting at the neighbour. Returns the job and whether it
    /// was stolen.
    fn find_job(&self, k: usize) -> Option<(J, bool)> {
        if let Some(job) = lock(&self.deques[k]).pop_front() {
            return Some((job, false));
        }
        let n = self.deques.len();
        for d in 1..n {
            let victim = (k + d) % n;
            if let Some(job) = lock(&self.deques[victim]).pop_back() {
                return Some((job, true));
            }
        }
        None
    }

    fn worker_loop(&self, k: usize, run: &(dyn Fn(J) + Send + Sync)) {
        // `epoch` starts at 0 and only moves under the monitor, so the
        // initial `seen` needs no lock.
        let mut seen = 0u64;
        loop {
            if let Some((job, stolen)) = self.find_job(k) {
                run(job);
                let mut st = lock(&self.monitor);
                debug_assert!(st.pending > 0, "executed a job the monitor never admitted");
                st.pending -= 1;
                st.executed += 1;
                if stolen {
                    st.steals += 1;
                }
                seen = st.epoch;
                if st.pending == 0 {
                    self.done_cv.notify_all();
                }
                continue;
            }
            let mut st = lock(&self.monitor);
            if st.shutdown {
                return;
            }
            if st.epoch != seen {
                // An injection raced the scan; its jobs may sit in a
                // deque the sweep already passed. Rescan, never park.
                seen = st.epoch;
                continue;
            }
            st.parked += 1;
            st.parks += 1;
            if st.pending == 0 {
                // This park may complete the all-parked + drained
                // termination condition.
                self.done_cv.notify_all();
            }
            st = self.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            st.parked -= 1;
            seen = st.epoch;
        }
    }
}

/// A persistent group of worker threads sharing one stealable bucket of
/// jobs (see the module doc for the full protocol). Spawned once,
/// reused for every phase, joined on drop.
pub struct WorkerGroup<J: Send + 'static> {
    inner: Arc<Inner<J>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl<J: Send + 'static> WorkerGroup<J> {
    /// Spawn `workers` threads, each running injected jobs through
    /// `run`. Threads park on the shared monitor between phases — no
    /// busy-waiting.
    pub fn new(workers: usize, run: impl Fn(J) + Send + Sync + 'static) -> WorkerGroup<J> {
        assert!(workers > 0, "worker group needs at least one thread");
        let inner = Arc::new(Inner {
            monitor: Mutex::new(GroupState {
                epoch: 0,
                pending: 0,
                parked: 0,
                shutdown: false,
                steals: 0,
                parks: 0,
                executed: 0,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            deques: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
        });
        let run: Arc<dyn Fn(J) + Send + Sync> = Arc::new(run);
        let handles = (0..workers)
            .map(|k| {
                let inner = Arc::clone(&inner);
                let run = Arc::clone(&run);
                thread::Builder::new()
                    .name(format!("ggarray-sched-{k}")) // lint: allow(alloc) — once per group construction, never per batch
                    .spawn(move || inner.worker_loop(k, run.as_ref()))
                    .expect("spawn scheduler worker")
            })
            .collect();
        WorkerGroup { inner, handles }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.inner.deques.len()
    }

    /// Ledger snapshot (monotonic over the group's lifetime).
    pub fn counters(&self) -> GroupCounters {
        let st = lock(&self.inner.monitor);
        GroupCounters { steals: st.steals, parks: st.parks, executed: st.executed }
    }

    /// Open a phase: inject any number of jobs, then `finish` blocks
    /// until the bucket is drained and every worker is parked. The
    /// coordinator is single-threaded by contract — phases never
    /// overlap (every `run_*` caller holds the one `&mut` shard borrow
    /// for the phase's whole lifetime).
    pub fn phase(&self) -> WorkPhase<'_, J> {
        WorkPhase { group: self, injected: 0, next: 0 }
    }

    /// Convenience for small call sites and the model suites: one phase
    /// containing `jobs`, run to termination.
    pub fn run_phase(&self, jobs: impl IntoIterator<Item = J>) {
        let mut phase = self.phase();
        for job in jobs {
            phase.inject(job);
        }
        phase.finish();
    }
}

impl<J: Send + 'static> Drop for WorkerGroup<J> {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.inner.monitor);
            st.shutdown = true;
            self.inner.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One open phase on a [`WorkerGroup`]. Injection is cheap (two short
/// uncontended locks per job, no allocation in steady state — the
/// deques keep their capacity across phases); nothing starts a parked
/// worker until [`WorkPhase::finish`] publishes the epoch.
pub struct WorkPhase<'a, J: Send + 'static> {
    group: &'a WorkerGroup<J>,
    injected: usize,
    next: usize,
}

impl<J: Send + 'static> WorkPhase<'_, J> {
    /// Admit one job: count it as pending under the monitor *first*,
    /// then push it round-robin. A spinning (not yet parked) worker may
    /// legally pop it before `finish` — `pending` already covers it.
    pub fn inject(&mut self, job: J) {
        let inner = &self.group.inner;
        {
            let mut st = lock(&inner.monitor);
            st.pending += 1;
        }
        lock(&inner.deques[self.next]).push_back(job);
        self.next = (self.next + 1) % inner.deques.len();
        self.injected += 1;
    }

    /// Publish the phase (bump epoch, wake everyone) and block until
    /// termination: bucket drained (`pending == 0`) and all workers
    /// parked. An empty phase skips the wakeup entirely — parked
    /// workers stay parked, exactly like the old pool skipping idle
    /// shards.
    pub fn finish(self) {
        let inner = &self.group.inner;
        let workers = inner.deques.len();
        let mut st = lock(&inner.monitor);
        if self.injected > 0 {
            st.epoch += 1;
            inner.work_cv.notify_all();
        }
        while !(st.pending == 0 && st.parked == workers) {
            st = inner.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::atomic::{AtomicU64, Ordering};
    use crate::sync::SendSliceMut;

    #[test]
    fn group_runs_jobs_and_terminates_each_phase() {
        let sum = Arc::new(AtomicU64::new(0));
        let acc = Arc::clone(&sum);
        let group: WorkerGroup<u64> =
            WorkerGroup::new(3, move |j| {
                acc.fetch_add(j, Ordering::SeqCst);
            });
        group.run_phase(1..=100u64);
        // Termination is a barrier: every job completed before finish
        // returned, in every schedule.
        assert_eq!(sum.load(Ordering::SeqCst), 5050);
        group.run_phase(std::iter::once(50u64));
        assert_eq!(sum.load(Ordering::SeqCst), 5100);
        let c = group.counters();
        assert_eq!(c.executed, 101, "ledger counts every executed job");
    }

    #[test]
    fn empty_phases_are_free_and_legal() {
        let group: WorkerGroup<u64> = WorkerGroup::new(2, |_| {});
        for _ in 0..3 {
            group.run_phase(std::iter::empty());
        }
        assert_eq!(group.counters().executed, 0);
    }

    #[test]
    fn disjoint_slot_writes_land_regardless_of_steal_order() {
        let mut buf = vec![0u32; 64];
        {
            let group: WorkerGroup<(SendSliceMut<u32>, u32)> = WorkerGroup::new(4, |(dst, v)| {
                // SAFETY: each job's slice was carved disjoint with
                // split_at_mut below and the parent buffer outlives the
                // phase (finish() is the barrier).
                unsafe { dst.as_mut_slice() }.fill(v);
            });
            let mut phase = group.phase();
            let mut rest: &mut [u32] = &mut buf;
            let mut v = 1u32;
            while !rest.is_empty() {
                let take = rest.len().min(8);
                let chunk = std::mem::take(&mut rest);
                let (head, tail) = chunk.split_at_mut(take);
                rest = tail;
                phase.inject((SendSliceMut::new(head), v));
                v += 1;
            }
            phase.finish();
        }
        for (i, &x) in buf.iter().enumerate() {
            assert_eq!(x, (i / 8) as u32 + 1, "slot {i} written by the wrong chunk");
        }
    }

    #[test]
    fn drop_joins_workers_even_when_idle() {
        let group: WorkerGroup<u32> = WorkerGroup::new(4, |_| {});
        assert_eq!(group.threads(), 4);
        drop(group); // must not hang or leak threads
    }

    #[test]
    fn steal_and_park_ledgers_move() {
        let group: WorkerGroup<u64> = WorkerGroup::new(2, |j| {
            if j == 0 {
                thread::yield_now();
            }
        });
        for _ in 0..50 {
            group.run_phase(0..8u64);
        }
        let c = group.counters();
        assert_eq!(c.executed, 400);
        // Parks are guaranteed (every phase terminates all-parked);
        // steals are opportunistic, so only assert the ledger is sane.
        assert!(c.parks >= 2, "workers must have parked between phases");
        assert!(c.steals <= c.executed);
    }
}
