//! Work-stealing shard scheduler: the executor layer that turns the
//! simulated max-over-shards critical path into *measured* wall-clock
//! parallelism — without inheriting the old pool's max-shard barrier.
//!
//! The previous executor (`coordinator/pool.rs`, PR 5) pinned one
//! thread + SPSC mailbox to each shard: a fan-out paid the latency of
//! its *slowest* shard at every barrier, so a skewed routing (one hot
//! shard) ran essentially serially. This subsystem replaces it end to
//! end with a bucketed worker group:
//!
//! * [`group::WorkerGroup`] — N persistent workers, per-worker deques,
//!   steal-on-empty, one shared Mutex+Condvar monitor, and
//!   coordinator-side termination detection (bucket drained + all
//!   workers parked). The protocol is generic over the job type and
//!   exhaustively model-checked in `tests/model_check.rs`.
//! * [`chunk::Chunk`] — stealable units: insert dispatch, `Work`,
//!   `Flatten` and seal phase-1 gathers each decompose into per-shard
//!   — and, for large shards, sub-shard-range — chunks over
//!   `SendPtr`/`SendSlice`/`SendSliceMut` leases.
//!
//! ## The charge/copy split (byte-identity)
//!
//! Serial mode (`GG_THREADS=1`) and the scheduler must agree on every
//! byte *including exact `sim_us`*. Steal order is nondeterministic, so
//! no chunk may touch simulated state another chunk can observe. The
//! scheduler therefore splits every phase:
//!
//! 1. **Charge** (coordinator, serial, shard-id order): bucket
//!    reserves, kernel launches, flatten allocations, index rebuilds —
//!    every heap/clock mutation, in exactly the serial loop's order
//!    ([`Shard::prepare_counts`], [`Shard::seal_flatten_charge`],
//!    [`Shard::flatten_temp_charge`]).
//! 2. **Copy** (workers, stolen in any order): pure data movement into
//!    slots the charge phase reserved. Host-side copies are free in
//!    simulated time, so the charges are *identical* to the fused
//!    serial operations — pinned per layer by unit tests and end to end
//!    by the PR 5/PR 6 property suites.
//!
//! `Work` is the one exception: its chunks advance their shard's own
//! clock, which is safe because work chunks stay per-shard (each shard's
//! clock is touched by exactly one chunk, whatever the steal order) —
//! and results are committed in deterministic shard/range order
//! regardless of which worker ran what.
//!
//! ## VRAM pre-screen
//!
//! Unchanged from the pool: the service fans out only demand-checked
//! ops (`insert_demand_fits` / `gather_demand_fits`), so a pooled phase
//! cannot OOM mid-flight; OOM-able batches take the serial prefix path
//! in every mode. Unexpected errors still unwind in shard order behind
//! a `debug_assert`.
//!
//! ## Zero-alloc steady state
//!
//! Worker deques are pre-allocated and keep their capacity across
//! phases; chunks are plain enums moved by value; `Arc<Executor>`
//! clones are refcount bumps. A steady-state insert batch performs
//! **zero** heap allocations end to end (extended coverage in
//! `tests/alloc_guard.rs`), so this module is in the lint's hot-path
//! manifest (`rust/hotpath_manifest.txt`).

pub mod group;
mod chunk;

pub use group::{GroupCounters, PhaseReport, WorkerGroup, WorkPhase};

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{Arc, SendPtr, SendSlice, SendSliceMut};

use crate::ggarray::lfvector::LfVector;
use crate::runtime::Executor;
use crate::sim::memory::OomError;

use super::request::ExecError;
use super::router::DispatchScratch;
use super::service::DispatchOutcome;
use super::shard::{SealPart, Shard};

use chunk::Chunk;

/// Why a scheduled gather phase did not complete: a worker-panic abort
/// (the op's serial charges were rolled back, the shards are
/// byte-identical to the op never running) or the pre-screen-impossible
/// OOM kept for parity with the serial path.
#[derive(Debug)]
pub enum PhaseAbort {
    Panic(ExecError),
    Oom(OomError),
}

/// Minimum batch values per insert-fill chunk. Fill chunks group whole
/// blocks (one `&mut LfVector` lease each) until they hold at least
/// this many values, so a hot shard fans into several stealable pieces
/// while a small batch stays one chunk per shard.
const FILL_CHUNK_ELEMS: usize = 1 << 14;

/// Maximum elements per gather chunk: large shards split into
/// sub-shard ranges so all workers help drain one hot shard.
const GATHER_CHUNK_ELEMS: usize = 1 << 15;

/// The shard scheduler: a persistent [`WorkerGroup`] executing
/// [`Chunk`]s, plus the serial charge-phase drivers. Public API mirrors
/// the old `ShardPool` (`run_insert` / `run_work` / `run_flatten_temp`
/// / `run_seal` / `threads`), with two generalisations: the worker
/// count is decoupled from the shard count, and `run_work` takes the
/// shared PJRT executor handle.
pub struct Scheduler {
    group: WorkerGroup<Chunk>,
    /// Per-phase PJRT execution tally (sum over shards — order-free).
    pjrt: Arc<AtomicU64>,
}

impl Scheduler {
    /// Spawn `threads` persistent workers. Workers park on the shared
    /// monitor between phases — no busy-waiting.
    pub fn new(threads: usize) -> Scheduler {
        assert!(threads > 0, "scheduler needs at least one worker");
        let pjrt = Arc::new(AtomicU64::new(0));
        let acc = Arc::clone(&pjrt);
        let group = WorkerGroup::new(threads, move |c: Chunk| {
            let p = c.execute();
            if p > 0 {
                acc.fetch_add(p, Ordering::Relaxed);
            }
        });
        Scheduler { group, pjrt }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.group.threads()
    }

    /// Steal/park/chunk ledger (monotonic over the scheduler's life).
    pub fn counters(&self) -> GroupCounters {
        self.group.counters()
    }

    /// Fan an already-routed insert batch out: charges run serially in
    /// shard order ([`Shard::prepare_counts`] — byte-identical clocks),
    /// then the pure fills go to the workers as stealable block-range
    /// chunks. Shards with an empty range get neither charge nor chunk
    /// — no phantom kernels, same as the serial loop.
    ///
    /// The caller pre-screened VRAM demand (`insert_demand_fits`), so
    /// no shard can OOM; should one anyway (a pre-screen bug), the
    /// charge loop stops at the first failing shard exactly like the
    /// serial prefix path, and the outcome reports it.
    ///
    /// Abort safety: if a worker panics mid-phase (fault injection or a
    /// real bug), the panic is contained by the worker group, the phase
    /// drains, and every *prepared* shard is rolled back — fresh buckets
    /// freed, length/index restored, clock ledger and heap counters
    /// rewound to the pre-op marks — so `Err(ChunkPanic)` leaves the
    /// shards byte-identical to the batch never having been dispatched.
    pub fn run_insert(
        &self,
        shards: &mut [Shard],
        blocks_per_shard: usize,
        values: &[f32],
        scratch: &DispatchScratch,
    ) -> Result<DispatchOutcome, ExecError> {
        // Phase 1: serial charges, shard-id order.
        let mut applied = 0u64;
        let mut oom: Option<(usize, usize, OomError)> = None; // (shard pos, applied prefix, error)
        for (k, shard) in shards.iter_mut().enumerate() {
            let (_, take) = scratch.ranges[k];
            if take == 0 {
                continue;
            }
            shard.save_abort_mark();
            let out = shard.prepare_counts(scratch.shard_counts(k, blocks_per_shard), take);
            applied += out.applied as u64;
            if let Some(e) = out.error {
                debug_assert!(false, "insert fan-out OOM despite pre-screen on shard {k}");
                oom = Some((k, out.applied, e));
                break;
            }
        }
        // Phase 2: stealable fills over the prepared prefix.
        let stop = oom.as_ref().map(|t| (t.0, t.1));
        let mut phase = self.group.phase();
        for (k, shard) in shards.iter_mut().enumerate() {
            let (off, take) = scratch.ranges[k];
            if take == 0 {
                continue;
            }
            let applied_k = match stop {
                Some((ok, _)) if k > ok => break,
                Some((ok, a)) if k == ok => a,
                _ => take,
            };
            if applied_k == 0 {
                continue;
            }
            let counts = scratch.shard_counts(k, blocks_per_shard);
            inject_fill(&mut phase, shard, counts, &values[off..off + applied_k]);
        }
        let report = phase.finish();
        if !report.ok() {
            // Roll back every shard the charge loop prepared, walking the
            // same prefix phase 2 did (the OOM shard, if any, rolls back
            // its partial prefix — panic-abort supersedes the OOM
            // outcome). Completed fill chunks only wrote tail slots the
            // rollback truncates away, so no visible byte survives.
            for (k, shard) in shards.iter_mut().enumerate() {
                let (_, take) = scratch.ranges[k];
                if take == 0 {
                    continue;
                }
                let applied_k = match stop {
                    Some((ok, _)) if k > ok => break, // never prepared
                    Some((ok, a)) if k == ok => a,
                    _ => take,
                };
                shard.rollback_insert(scratch.shard_counts(k, blocks_per_shard), applied_k);
            }
            return Err(ExecError::ChunkPanic { op: "insert", chunks: report.failed });
        }
        Ok(DispatchOutcome { applied, oom: oom.map(|(k, _, e)| (shards[k].id(), e)) })
    }

    /// One work call fanned across non-empty shards: per-shard numeric
    /// update concurrently, with the modeled `rw_b` charge pre-paid
    /// serially in shard order. Empty live shards get neither chunk nor
    /// charge — the serial loop does nothing to them either. `exec` is
    /// the shared PJRT handle: pooled Work runs the AOT kernels whenever
    /// the serial path would (each worker compiles into its own
    /// thread-local cache). Returns PJRT executions performed.
    ///
    /// The serial path charges *after* its numeric pass; pre-charging is
    /// still byte-identical because `charge_rw_block`'s cost depends
    /// only on shard length and device spec (work never changes length)
    /// and each shard's clock sees the same single delta. The hoist
    /// exists so an aborted phase can rewind the charges: on
    /// `Err(ChunkPanic)` the simulated ledger is exactly as if the call
    /// never ran. Real f32 updates on shards whose chunk completed
    /// before the panic are NOT undone (sequential f32 adds cannot be
    /// exactly reversed) — the documented exception to abort
    /// byte-identity, covering only `Work` numerics.
    pub fn run_work(
        &self,
        shards: &mut [Shard],
        exec: Option<&Arc<Executor>>,
        iters: u32,
    ) -> Result<u64, ExecError> {
        self.pjrt.store(0, Ordering::Relaxed);
        // Serial pre-charge, shard-id order (the charge/copy split).
        for shard in shards.iter_mut() {
            if shard.is_empty() {
                continue;
            }
            shard.save_abort_mark();
            shard.charge_rw_block(iters as f64);
        }
        let mut phase = self.group.phase();
        for shard in shards.iter_mut() {
            // Read before this shard's chunk exists; work never changes
            // a shard's length, so the skip decision is stable.
            if shard.is_empty() {
                continue;
            }
            phase.inject(Chunk::Work {
                shard: SendPtr::new(shard),
                exec: exec.map(Arc::clone),
                iters,
            });
        }
        let report = phase.finish();
        if !report.ok() {
            for shard in shards.iter_mut() {
                if shard.is_empty() {
                    continue;
                }
                shard.rewind_abort();
            }
            return Err(ExecError::ChunkPanic { op: "work", chunks: report.failed });
        }
        Ok(self.pjrt.load(Ordering::Relaxed))
    }

    /// Parallel snapshot gather: serial per-shard charges (destination
    /// alloc + gather kernel, released immediately), then sub-shard
    /// range chunks copy into disjoint carves of `dst`. The caller
    /// pre-screened VRAM fit; an unexpected failure surfaces as the
    /// lowest failing shard's error and skips the (discarded) copy.
    pub fn run_flatten_temp(
        &self,
        shards: &mut [Shard],
        dst: &mut [f32],
        ranges: &[(usize, usize)],
    ) -> Result<(), PhaseAbort> {
        debug_assert_eq!(shards.len(), ranges.len());
        debug_assert_eq!(ranges.iter().map(|r| r.1).sum::<usize>(), dst.len());
        let mut failed: Option<OomError> = None;
        for (k, shard) in shards.iter_mut().enumerate() {
            shard.save_abort_mark();
            match shard.flatten_temp_charge() {
                Ok(len) => debug_assert_eq!(len, ranges[k].1, "stale gather range for shard {k}"),
                Err(e) => {
                    debug_assert!(false, "flatten fan-out OOM despite pre-screen on shard {k}");
                    if failed.is_none() {
                        failed = Some(e);
                    }
                }
            }
        }
        if let Some(e) = failed {
            return Err(PhaseAbort::Oom(e));
        }
        let mut phase = self.group.phase();
        let mut rest: &mut [f32] = dst;
        let mut covered = 0usize;
        for (shard, &(off, len)) in shards.iter_mut().zip(ranges.iter()) {
            debug_assert_eq!(off, covered, "gather ranges must be contiguous prefix sums");
            let carve = std::mem::take(&mut rest);
            let (head, tail) = carve.split_at_mut(len);
            rest = tail;
            covered += len;
            inject_gather(&mut phase, shard, head);
        }
        let report = phase.finish();
        if !report.ok() {
            // The snapshot destination is caller-discarded on error and
            // the gather chunks never touch shard state, so rewinding
            // the charge marks is the whole rollback.
            for shard in shards.iter_mut() {
                shard.rewind_abort();
            }
            return Err(PhaseAbort::Panic(ExecError::ChunkPanic {
                op: "flatten",
                chunks: report.failed,
            }));
        }
        Ok(())
    }

    /// Seal phase-1 gather: serial seal + flatten charges in shard
    /// order (results pushed to `out` in that order — `Ok(SealPart)`
    /// whose destination allocation the caller's two-phase commit owns,
    /// or the shard's `Err`, the shard having already reopened itself),
    /// then range chunks copy every successfully charged shard into its
    /// disjoint carve of `dst`.
    ///
    /// On a worker-panic abort the unwind happens *here* (the caller's
    /// two-phase commit never starts): every charged shard releases its
    /// fresh flatten destination and reopens, all costs rewind to the
    /// pre-seal marks, and this seal's entries are dropped from `out` —
    /// `Err(ChunkPanic)` leaves the store byte-identical to the seal
    /// never having been requested.
    pub fn run_seal(
        &self,
        shards: &mut [Shard],
        dst: &mut [f32],
        ranges: &[(usize, usize)],
        out: &mut Vec<Result<SealPart, OomError>>,
    ) -> Result<(), ExecError> {
        debug_assert_eq!(shards.len(), ranges.len());
        debug_assert_eq!(ranges.iter().map(|r| r.1).sum::<usize>(), dst.len());
        let base = out.len();
        for shard in shards.iter_mut() {
            shard.save_abort_mark();
            out.push(shard.seal_flatten_charge());
        }
        let mut phase = self.group.phase();
        let mut rest: &mut [f32] = dst;
        let mut covered = 0usize;
        for ((k, shard), &(off, len)) in shards.iter_mut().enumerate().zip(ranges.iter()) {
            debug_assert_eq!(off, covered, "gather ranges must be contiguous prefix sums");
            let carve = std::mem::take(&mut rest);
            let (head, tail) = carve.split_at_mut(len);
            rest = tail;
            covered += len;
            if out[base + k].is_ok() {
                inject_gather(&mut phase, shard, head);
            }
        }
        let report = phase.finish();
        if !report.ok() {
            for (k, shard) in shards.iter_mut().enumerate() {
                if let Ok(part) = &mut out[base + k] {
                    shard.abort_seal(part.alloc.take());
                }
                // Err shards already reopened themselves; the rewind
                // erases whatever partial charges their failed attempt
                // (or the abort_seal free above) left behind.
                shard.rewind_abort();
            }
            out.truncate(base);
            return Err(ExecError::ChunkPanic { op: "seal", chunks: report.failed });
        }
        Ok(())
    }
}

/// Carve one shard's fill into stealable chunks: contiguous runs of
/// whole blocks (a block's `LfVector` is one exclusive lease — fills
/// never split inside a block) holding at least [`FILL_CHUNK_ELEMS`]
/// values each. `values` is the shard's *applied prefix*: after a
/// prepare OOM only fully-extended blocks are owed a fill.
fn inject_fill(
    phase: &mut WorkPhase<'_, Chunk>,
    shard: &mut Shard,
    counts: &[usize],
    values: &[f32],
) {
    let mut blocks: &mut [LfVector<f32>] = shard.vectors_mut();
    debug_assert_eq!(blocks.len(), counts.len());
    let mut counts = counts;
    let mut values = values;
    while !values.is_empty() {
        let mut acc = 0usize;
        let mut nb = 0usize;
        while nb < counts.len() && acc < FILL_CHUNK_ELEMS && acc + counts[nb] <= values.len() {
            acc += counts[nb];
            nb += 1;
        }
        if nb == 0 {
            debug_assert!(false, "fill values not aligned to a whole-block prefix");
            break;
        }
        let rest = std::mem::take(&mut blocks);
        let (bh, bt) = rest.split_at_mut(nb);
        blocks = bt;
        let (ch, ct) = counts.split_at(nb);
        counts = ct;
        let (vh, vt) = values.split_at(acc);
        values = vt;
        if acc == 0 {
            continue; // a run of zero-count blocks — nothing to copy
        }
        phase.inject(Chunk::InsertFill {
            blocks: SendSliceMut::new(bh),
            counts: SendSlice::new(ch),
            values: SendSlice::new(vh),
        });
    }
}

/// Carve one shard's gather destination into sub-shard range chunks
/// (shared shard reads — all workers can help drain a hot shard).
fn inject_gather(phase: &mut WorkPhase<'_, Chunk>, shard: &mut Shard, dst: &mut [f32]) {
    let sp = SendPtr::new(shard);
    let mut rest = dst;
    let mut src = 0usize;
    while !rest.is_empty() {
        let take = rest.len().min(GATHER_CHUNK_ELEMS);
        let carve = std::mem::take(&mut rest);
        let (head, tail) = carve.split_at_mut(take);
        rest = tail;
        phase.inject(Chunk::GatherCopy { shard: sp, src_start: src, dst: SendSliceMut::new(head) });
        src += take;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::Policy;
    use crate::coordinator::shard::ShardConfig;
    use crate::insertion::InsertionKind;
    use crate::sim::spec::DeviceSpec;

    fn build_shards(n: usize, blocks: usize) -> Vec<Shard> {
        (0..n)
            .map(|id| {
                Shard::new(ShardConfig {
                    id,
                    blocks,
                    first_bucket_size: 16,
                    insertion: InsertionKind::WarpScan,
                    device: DeviceSpec::a100(),
                    heap_bytes: 1 << 26,
                })
            })
            .collect()
    }

    /// Route + split a batch the way the service does.
    fn routed(shards: &[Shard], bps: usize, n: usize, scratch: &mut DispatchScratch) {
        scratch.sizes.clear();
        for shard in shards.iter() {
            scratch.sizes.extend(shard.block_sizes_iter());
        }
        scratch.route(Policy::Even, n, 0);
        scratch.split_for_shards(bps);
    }

    #[test]
    fn scheduled_insert_matches_serial_per_shard_state() {
        let bps = 2;
        let values: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let mut scratch = DispatchScratch::new();

        let mut serial = build_shards(4, bps);
        routed(&serial, bps, values.len(), &mut scratch);
        let mut applied_serial = 0u64;
        for (k, shard) in serial.iter_mut().enumerate() {
            let (off, take) = scratch.ranges[k];
            let out = shard.apply_counts(scratch.shard_counts(k, bps), &values[off..off + take]);
            assert!(out.error.is_none());
            applied_serial += out.applied as u64;
        }

        let sched = Scheduler::new(4);
        let mut pooled = build_shards(4, bps);
        routed(&pooled, bps, values.len(), &mut scratch);
        let out = sched.run_insert(&mut pooled, bps, &values, &scratch).unwrap();
        assert_eq!(out.applied, applied_serial);
        assert!(out.oom.is_none());
        for (s, p) in serial.iter().zip(&pooled) {
            assert_eq!(s.len(), p.len());
            assert_eq!(s.heap_used(), p.heap_used());
            assert_eq!(s.sim_now_us(), p.sim_now_us(), "per-shard clocks must agree exactly");
            for i in 0..s.len() as u64 {
                assert_eq!(s.get(i), p.get(i));
            }
        }
        assert_eq!(sched.counters().executed as usize, {
            // One fill chunk per shard with a non-empty range (batch is
            // far below FILL_CHUNK_ELEMS, so no shard splits).
            scratch.ranges.iter().filter(|r| r.1 > 0).count()
        });
    }

    #[test]
    fn more_shards_than_workers_is_legal() {
        // The old pool pinned thread k to shard k; the scheduler
        // decouples them — 2 workers drain 4 shards' chunks.
        let bps = 2;
        let values: Vec<f32> = (0..800).map(|i| (i % 97) as f32).collect();
        let mut scratch = DispatchScratch::new();

        let mut serial = build_shards(4, bps);
        routed(&serial, bps, values.len(), &mut scratch);
        for (k, shard) in serial.iter_mut().enumerate() {
            let (off, take) = scratch.ranges[k];
            shard.apply_counts(scratch.shard_counts(k, bps), &values[off..off + take]);
        }

        let sched = Scheduler::new(2);
        let mut pooled = build_shards(4, bps);
        routed(&pooled, bps, values.len(), &mut scratch);
        sched.run_insert(&mut pooled, bps, &values, &scratch).unwrap();
        for (s, p) in serial.iter().zip(&pooled) {
            assert_eq!(s.sim_now_us(), p.sim_now_us());
            for i in 0..s.len() as u64 {
                assert_eq!(s.get(i), p.get(i));
            }
        }
    }

    #[test]
    fn scheduled_work_matches_serial_values_and_clocks() {
        let bps = 2;
        let values: Vec<f32> = (0..256).map(|i| i as f32 * 0.5).collect();
        let mut scratch = DispatchScratch::new();
        let mut serial = build_shards(2, bps);
        routed(&serial, bps, values.len(), &mut scratch);
        for (k, shard) in serial.iter_mut().enumerate() {
            let (off, take) = scratch.ranges[k];
            shard.apply_counts(scratch.shard_counts(k, bps), &values[off..off + take]);
        }
        let sched = Scheduler::new(2);
        let mut pooled = build_shards(2, bps);
        routed(&pooled, bps, values.len(), &mut scratch);
        sched.run_insert(&mut pooled, bps, &values, &scratch).unwrap();

        for shard in serial.iter_mut() {
            shard.work_pass(None, 30);
            if !shard.is_empty() {
                shard.charge_rw_block(30.0);
            }
        }
        assert_eq!(sched.run_work(&mut pooled, None, 30).unwrap(), 0);
        for (s, p) in serial.iter().zip(&pooled) {
            assert_eq!(s.get(0), p.get(0));
            assert_eq!(s.sim_now_us(), p.sim_now_us());
        }
    }

    #[test]
    fn work_shares_one_executor_handle_across_workers() {
        // Regression for the deleted "artifacts live → serial path"
        // special case: pooled Work must accept a live executor handle
        // and stay byte-identical to the serial path given the same
        // handle. An empty manifest exercises the full shared-Arc
        // plumbing (Send + Sync Executor, per-chunk clone) with the
        // host-fallback numerics.
        let dir = std::env::temp_dir().join("ggarray_sched_exec_share");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"version":1,"entries":{}}"#).unwrap();
        let exec = Arc::new(Executor::new(&dir).expect("empty manifest loads"));
        let _ = std::fs::remove_dir_all(&dir);

        let bps = 2;
        let values: Vec<f32> = (0..512).map(|i| i as f32 * 0.25).collect();
        let mut scratch = DispatchScratch::new();
        let mut serial = build_shards(4, bps);
        routed(&serial, bps, values.len(), &mut scratch);
        for (k, shard) in serial.iter_mut().enumerate() {
            let (off, take) = scratch.ranges[k];
            shard.apply_counts(scratch.shard_counts(k, bps), &values[off..off + take]);
        }
        let sched = Scheduler::new(4);
        let mut pooled = build_shards(4, bps);
        routed(&pooled, bps, values.len(), &mut scratch);
        sched.run_insert(&mut pooled, bps, &values, &scratch).unwrap();

        for shard in serial.iter_mut() {
            shard.work_pass(Some(&*exec), 7);
            if !shard.is_empty() {
                shard.charge_rw_block(7.0);
            }
        }
        let pjrt = sched.run_work(&mut pooled, Some(&exec), 7).unwrap();
        assert_eq!(pjrt, exec.executions(), "tally must equal the handle's own counter");
        for (s, p) in serial.iter().zip(&pooled) {
            assert_eq!(s.sim_now_us(), p.sim_now_us());
            for i in 0..s.len() as u64 {
                assert_eq!(s.get(i), p.get(i));
            }
        }
    }

    #[test]
    fn scheduled_gathers_write_disjoint_ranges_in_shard_order() {
        let bps = 2;
        let values: Vec<f32> = (0..300).map(|i| i as f32).collect();
        let mut scratch = DispatchScratch::new();
        let sched = Scheduler::new(3);
        let mut shards = build_shards(3, bps);
        routed(&shards, bps, values.len(), &mut scratch);
        sched.run_insert(&mut shards, bps, &values, &scratch).unwrap();

        // Reference: serial appending flatten.
        let mut reference = Vec::new();
        let mut check = build_shards(3, bps);
        routed(&check, bps, values.len(), &mut scratch);
        for (k, shard) in check.iter_mut().enumerate() {
            let (off, take) = scratch.ranges[k];
            shard.apply_counts(scratch.shard_counts(k, bps), &values[off..off + take]);
        }
        for shard in check.iter_mut() {
            shard.flatten_temp_into(&mut reference).unwrap();
        }

        let lens: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        let ranges = scratch.fill_gather_ranges(lens.into_iter()).to_vec();
        let mut dst = vec![0.0f32; values.len()];
        sched.run_flatten_temp(&mut shards, &mut dst, &ranges).unwrap();
        assert_eq!(dst, reference, "parallel gather must be byte-identical to serial append");

        // Seal gather: parts in shard order, destination allocs live.
        let mut seal_dst = vec![0.0f32; values.len()];
        let mut parts = Vec::new();
        sched.run_seal(&mut shards, &mut seal_dst, &ranges, &mut parts).unwrap();
        assert_eq!(seal_dst, reference);
        assert_eq!(parts.len(), 3);
        for (k, (part, shard)) in parts.into_iter().zip(shards.iter_mut()).enumerate() {
            let mut part = part.expect("pre-screened seal cannot OOM");
            assert_eq!(part.len, ranges[k].1);
            assert!(part.alloc.is_some());
            shard.abort_seal(part.alloc.take()); // clean up the lease
        }
    }

    #[test]
    fn hot_shard_gather_splits_into_range_chunks() {
        // One shard far above GATHER_CHUNK_ELEMS must fan out into
        // multiple chunks (the skewed-routing payoff), and the copy
        // must still be byte-exact at every split boundary.
        let bps = 2;
        let n = GATHER_CHUNK_ELEMS * 2 + 1234;
        let values: Vec<f32> = (0..n).map(|i| (i % 1013) as f32).collect();
        let mut scratch = DispatchScratch::new();
        let sched = Scheduler::new(2);
        let mut shards = build_shards(1, bps);
        routed(&shards, bps, values.len(), &mut scratch);
        let out = sched.run_insert(&mut shards, bps, &values, &scratch).unwrap();
        assert!(out.oom.is_none());
        let fills = sched.counters().executed;
        assert!(fills > 1, "hot-shard fill must split (got {fills} chunks)");

        let ranges = vec![(0usize, n)];
        let mut dst = vec![0.0f32; n];
        sched.run_flatten_temp(&mut shards, &mut dst, &ranges).unwrap();
        let gathers = sched.counters().executed - fills;
        assert_eq!(gathers, n.div_ceil(GATHER_CHUNK_ELEMS) as u64);
        let mut reference = Vec::new();
        shards[0].flatten_temp_into(&mut reference).unwrap();
        assert_eq!(dst, reference);
    }

    #[test]
    fn chunk_ledger_conserves_per_op_counts() {
        // `chunks_executed` must equal the sum of each op's chunk
        // decomposition: one fill chunk per shard with a routed range
        // (small batch — no splitting), one work chunk per non-empty
        // shard, and ceil(len / GATHER_CHUNK_ELEMS) gather chunks per
        // non-empty shard.
        let bps = 2;
        let values: Vec<f32> = (0..600).map(|i| i as f32).collect();
        let mut scratch = DispatchScratch::new();
        let sched = Scheduler::new(3);
        let mut shards = build_shards(3, bps);
        routed(&shards, bps, values.len(), &mut scratch);
        let fills = scratch.ranges.iter().filter(|r| r.1 > 0).count() as u64;
        sched.run_insert(&mut shards, bps, &values, &scratch).unwrap();
        assert_eq!(sched.counters().executed, fills);

        let works = shards.iter().filter(|s| !s.is_empty()).count() as u64;
        sched.run_work(&mut shards, None, 5).unwrap();
        assert_eq!(sched.counters().executed, fills + works);

        let gathers: u64 = shards.iter().map(|s| s.len().div_ceil(GATHER_CHUNK_ELEMS) as u64).sum();
        let lens: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        let ranges = scratch.fill_gather_ranges(lens.into_iter()).to_vec();
        let mut dst = vec![0.0f32; values.len()];
        sched.run_flatten_temp(&mut shards, &mut dst, &ranges).unwrap();
        assert_eq!(
            sched.counters().executed,
            fills + works + gathers,
            "ledger must conserve the per-op chunk decomposition"
        );
    }

    #[test]
    fn scheduler_drop_joins_workers() {
        let sched = Scheduler::new(4);
        assert_eq!(sched.threads(), 4);
        drop(sched); // must not hang or leak threads
    }
}
