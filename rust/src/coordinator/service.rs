//! The coordinator service: a worker thread owning N independent GGArray
//! [`Shard`]s plus the sealed-epoch store, fed by an mpsc request
//! channel. Insert requests are routed globally (per [`router`]) across
//! the shards' combined block space, batched (per [`batcher`]), and
//! sliced per shard; Work/Flatten run through the PJRT runtime when AOT
//! artifacts are available and fall back to host compute when not (the
//! numerics are identical — the integration tests assert it).
//!
//! The two-phase lifecycle (paper §VI.D) is first-class: `Request::Seal`
//! drains in-flight batches, flattens every shard, concatenates the
//! results into one contiguous [`ShardedFlattened`] view held by the
//! [`EpochManager`], and opens a fresh insert epoch behind it. Reads and
//! work over the sealed prefix run at static-array (coalesced) cost; the
//! live epoch keeps paying GGArray costs until it, too, is sealed.
//!
//! VRAM is one physical budget carved once: the epoch-owned sealed store
//! (`CoordinatorConfig::epoch_heap`) first, the per-shard heaps from the
//! remainder. A seal is a real memory transaction — flatten every shard,
//! reserve epoch-store admission for the whole seal, then *transfer* each
//! destination out of its shard heap into the epoch heap; any failure
//! aborts the entire seal in one pass with every byte restored. The
//! compaction gather is the same shape of transaction (merged destination
//! reserved while the sources are resident — a transient 2×), and on OOM
//! it aborts byte-identically, surfacing the error in `Response::Sealed`
//! and the `compaction_ooms` metric while the store keeps serving.
//!
//! Simulated time follows the **parallel time model**: shards are
//! concurrent thread-block groups of one device, so each dispatching op
//! (insert batch, work, flatten, seal) charges the ledger the *max* over
//! the participating shards' clock deltas — the critical path — plus an
//! explicit serial coordinator term (host sync for routing/dispatch) and
//! any serial single-kernel passes over the sealed store. The per-shard
//! sums survive as `device_*` aggregate totals; see
//! [`super::metrics::ParallelCost`].
//!
//! Shard execution is **really parallel** by default: the worker owns a
//! persistent work-stealing [`Scheduler`] (a bucketed worker group,
//! spawned once at `Coordinator::start`) and fans insert dispatch, work
//! passes, snapshot gathers and the seal's phase-1 gather out as
//! stealable per-shard / sub-shard-range chunks — so the measured
//! `wall_*` ledger tracks the modeled `sim_*` critical path instead of
//! the `device_*` sum, and a skewed routing no longer pays the
//! slowest-shard latency at a fork/join barrier (idle workers steal the
//! hot shard's chunks). Ops that could OOM mid-flight are pre-screened
//! against exact VRAM demand and fall back to the serial loop when a
//! fit is not guaranteed, which keeps every trace — OOM traces included
//! — byte-identical across executor modes
//! (`CoordinatorConfig::executor_threads`, `GG_THREADS`).
//!
//! No async runtime is available offline; the event loop is a plain
//! blocking channel with deadline-aware `recv_timeout`, which for an
//! in-process service is equivalent to (and simpler than) a tokio
//! single-worker runtime.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

use crate::sync::mpsc::{self, Receiver};
use crate::sync::thread::{self, JoinHandle};
use crate::sync::Arc;

use crate::ggarray::flatten::ShardedFlattened;
use crate::ggarray::lfvector::buckets_for_len;
use crate::insertion::InsertionKind;
use crate::runtime::Executor;
use crate::sim::clock::{Category, Clock};
use crate::sim::memory::OomError;
use crate::sim::spec::DeviceSpec;
use crate::workload::{synth_f32, Step, WorkloadSpec};

use super::batcher::{BatchConfig, Batcher};
use super::frontend::{
    drain_lanes, ClientLane, ClientSession, FrontendConfig, FrontendShared, MergePolicy,
    SessionInsert,
};
use super::metrics::{Metrics, ParallelCost};
use super::request::{checksum, ExecError, Request, Response};
use super::router::{DispatchScratch, Policy};
use super::scheduler::{PhaseAbort, Scheduler};
use super::shard::{concat_parts, EpochManager, SealPart, Shard, ShardConfig};

/// Service configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub device: DeviceSpec,
    /// Total LFVectors (thread blocks) across ALL shards; must divide
    /// evenly by `shards`. Keeping the total fixed while varying the
    /// shard count leaves the global data layout unchanged.
    pub blocks: usize,
    pub first_bucket_size: usize,
    pub insertion: InsertionKind,
    pub routing: Policy,
    pub batch: BatchConfig,
    /// Try to load AOT artifacts; fall back to host compute when absent.
    pub use_artifacts: bool,
    /// +1 iterations per work call (paper: 30).
    pub work_iters: u32,
    /// Total simulated VRAM budget in bytes (None = the device's full
    /// memory). The epoch-owned sealed store is carved out first (see
    /// [`CoordinatorConfig::epoch_heap`]); the remainder is split evenly
    /// into per-shard heap budgets.
    /// Used by failure-injection tests and multi-tenant scenarios.
    pub heap_capacity: Option<u64>,
    /// Bytes of the total budget reserved for the epoch-owned sealed
    /// store ([`EpochManager`]'s heap): committed sealed segments live
    /// there — and the compaction gather's transient 2× residency pushes
    /// through it — so live-epoch budgets are never squatted on by old
    /// epochs, and a tight sealed-store budget makes seal admission or
    /// compaction OOM without touching the shards. `None` reserves half
    /// the total budget.
    pub epoch_heap: Option<u64>,
    /// Independent GGArray shards, each owning `blocks / shards`
    /// consecutive blocks of the global block space.
    pub shards: usize,
    /// Sealed-segment compaction threshold: once the epoch store holds
    /// more than this many flat segments, a seal triggers one modeled
    /// gather pass merging them into a single segment (0 disables).
    pub compact_segments: usize,
    /// Shard-executor parallelism. `1` = serial: the worker applies every
    /// per-shard op inline on its own thread (byte-identical to the
    /// scheduler at every shard count — property-tested). Any value ≥ 2
    /// = scheduled: a persistent work-stealing [`Scheduler`] with that
    /// many workers — the worker count is decoupled from the shard
    /// count, so 2 workers can drain 8 shards' chunks and 8 workers can
    /// gang up on one hot shard's sub-ranges. `0` = auto: honour the
    /// `GG_THREADS` environment variable if set, else one worker per
    /// shard whenever there is more than one shard.
    pub executor_threads: usize,
    /// Multi-client admission layer (see [`super::frontend`]): per-session
    /// bounded channel depth, retry hint, and the merge policy governing
    /// when pooled client requests coalesce into the batcher.
    pub frontend: FrontendConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> CoordinatorConfig {
        CoordinatorConfig {
            device: DeviceSpec::a100(),
            blocks: 512,
            first_bucket_size: 1024,
            insertion: InsertionKind::WarpScan,
            routing: Policy::Even,
            batch: BatchConfig::default(),
            use_artifacts: true,
            work_iters: 30,
            heap_capacity: None,
            epoch_heap: None,
            shards: 1,
            compact_segments: 4,
            executor_threads: 0,
            frontend: FrontendConfig::default(),
        }
    }
}

/// Typed rejection of an invalid [`CoordinatorConfig`] — returned by
/// [`Coordinator::try_start`] instead of tripping asserts (or silently
/// dropping blocks) deep inside the worker.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConfigError {
    /// `shards == 0`: the worker needs at least one shard.
    NoShards,
    /// `blocks == 0`: the router needs at least one block.
    NoBlocks,
    /// `blocks % shards != 0`: integer division would silently drop the
    /// remainder blocks from the global block space and later trip the
    /// `split_for_shards` divisibility assert.
    UnevenBlocks { blocks: usize, shards: usize },
    /// `epoch_heap` exceeds the total VRAM budget: the sealed store is
    /// carved out of the same physical memory the shards share, so it
    /// cannot be promised more than the whole card.
    EpochHeapExceedsBudget { epoch_heap: u64, total: u64 },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::NoShards => write!(f, "coordinator needs at least one shard"),
            ConfigError::NoBlocks => write!(f, "coordinator needs at least one block"),
            ConfigError::UnevenBlocks { blocks, shards } => write!(
                f,
                "blocks ({blocks}) must divide evenly into shards ({shards}); \
                 {} remainder block(s) would be lost",
                blocks % shards
            ),
            ConfigError::EpochHeapExceedsBudget { epoch_heap, total } => write!(
                f,
                "epoch heap ({epoch_heap} B) exceeds the total VRAM budget ({total} B); \
                 the sealed store is carved out of the same device memory the shards share"
            ),
        }
    }
}

impl std::error::Error for ConfigError {}

impl CoordinatorConfig {
    /// Check the shard/block geometry before any worker state is built.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.shards == 0 {
            return Err(ConfigError::NoShards);
        }
        if self.blocks == 0 {
            return Err(ConfigError::NoBlocks);
        }
        if self.blocks % self.shards != 0 {
            return Err(ConfigError::UnevenBlocks { blocks: self.blocks, shards: self.shards });
        }
        let total = self.heap_capacity.unwrap_or_else(|| self.device.memory_bytes());
        if let Some(epoch_heap) = self.epoch_heap {
            if epoch_heap > total {
                return Err(ConfigError::EpochHeapExceedsBudget { epoch_heap, total });
            }
        }
        Ok(())
    }

    /// The VRAM carve implied by this config: `(epoch_heap_bytes,
    /// shard_heap_total)` — the sealed store's budget and what is left
    /// for the per-shard heaps. Requires a validated config.
    pub fn heap_carve(&self) -> (u64, u64) {
        let total = self.heap_capacity.unwrap_or_else(|| self.device.memory_bytes());
        let epoch = self.epoch_heap.unwrap_or(total / 2);
        (epoch, total - epoch)
    }

    /// Resolve [`CoordinatorConfig::executor_threads`] to a scheduler
    /// worker count: `1` = serial on the worker thread (no scheduler is
    /// built). `0` defers to the `GG_THREADS` environment variable
    /// (unparsable values are treated as unset), defaulting to one
    /// worker per shard whenever there is more than one shard.
    pub fn executor_workers(&self) -> usize {
        match self.executor_threads {
            0 => match std::env::var("GG_THREADS").ok().and_then(|v| v.parse::<usize>().ok()) {
                Some(n) => n.max(1),
                None => self.shards,
            },
            n => n,
        }
    }

    /// `true` when this config runs the work-stealing scheduler
    /// (`executor_workers() > 1`), `false` for the serial worker loop.
    pub fn pooled_execution(&self) -> bool {
        self.executor_workers() > 1
    }
}

/// Carve a total heap budget into per-shard budgets without losing the
/// remainder: every shard gets `total / shards` bytes and the first
/// `total % shards` shards get one extra byte each, so the budgets sum
/// to exactly `total`. `shards` must be positive (the coordinator
/// guarantees it via [`CoordinatorConfig::validate`]).
pub fn split_heap_budget(total: u64, shards: usize) -> Vec<u64> {
    debug_assert!(shards > 0, "split_heap_budget needs at least one shard");
    let base = total / shards as u64;
    let rem = total % shards as u64;
    (0..shards as u64).map(|k| base + u64::from(k < rem)).collect()
}

/// Serial-clock snapshot taken at the start of an op (the per-shard
/// marks live in the dispatch scratch arena); see [`Worker::cost_since`].
struct ClockMarks {
    epochs: f64,
    coord: f64,
}

/// Outcome of routing one batch across the shards.
#[derive(Debug)]
pub struct DispatchOutcome {
    /// Elements actually placed across all shards.
    pub applied: u64,
    /// The shard that hit its VRAM budget mid-batch, if any. Dispatch
    /// stops at the first OOMing shard so the surviving data stays a
    /// contiguous prefix of the batch (byte-identical across shard
    /// counts even under OOM).
    pub oom: Option<(usize, OomError)>,
}

/// The allocation-free core of the insert hot path: refresh the global
/// per-block sizes in the scratch arena, route the batch, slice the
/// decision per shard as `(offset, len)` ranges into `values`, and hand
/// every shard its `&[f32]` sub-slice — no per-shard vectors, no fresh
/// count buffers, zero heap allocations once the arena and the shard
/// buckets are warm (regression-tested in `tests/alloc_guard.rs`).
///
/// Free-standing so the coordinator worker, the allocation guard and the
/// wall-clock bench drive the *same* code.
pub fn dispatch_insert(
    shards: &mut [Shard],
    blocks_per_shard: usize,
    policy: Policy,
    batch_seq: u64,
    values: &[f32],
    scratch: &mut DispatchScratch,
) -> DispatchOutcome {
    route_batch(shards, blocks_per_shard, policy, batch_seq, values.len(), scratch);
    apply_routed_serial(shards, blocks_per_shard, values, scratch)
}

/// Scheduled twin of [`dispatch_insert`]: same global routing, then the
/// per-shard charges run serially (byte-identical clocks) and the fills
/// fan out to the work-stealing scheduler as block-range chunks. Before
/// fanning out, the exact VRAM demand of the routed decision
/// (missing-bucket bytes per shard) is checked against each shard's
/// free budget: a guaranteed fit cannot OOM mid-flight, and anything
/// else falls back to the serial loop — whose stop-at-first-OOM prefix
/// semantics the parallel path could not honour — so outcomes are
/// byte-identical across executor modes.
///
/// `Err(ChunkPanic)` means a scheduler worker died mid-phase: the batch
/// was rolled back byte-identically and none of it was applied (the
/// serial fallback path cannot fail this way).
pub fn dispatch_insert_pooled(
    sched: &Scheduler,
    shards: &mut [Shard],
    blocks_per_shard: usize,
    policy: Policy,
    batch_seq: u64,
    values: &[f32],
    scratch: &mut DispatchScratch,
) -> Result<DispatchOutcome, ExecError> {
    route_batch(shards, blocks_per_shard, policy, batch_seq, values.len(), scratch);
    if !insert_demand_fits(shards, blocks_per_shard, scratch) {
        return Ok(apply_routed_serial(shards, blocks_per_shard, values, scratch));
    }
    sched.run_insert(shards, blocks_per_shard, values, scratch)
}

/// Routing half of a dispatch: refresh the global per-block sizes in the
/// scratch arena, route the batch, and slice the decision per shard as
/// `(offset, len)` ranges into the batch values.
fn route_batch(
    shards: &[Shard],
    blocks_per_shard: usize,
    policy: Policy,
    batch_seq: u64,
    n: usize,
    scratch: &mut DispatchScratch,
) {
    scratch.sizes.clear();
    for shard in shards.iter() {
        scratch.sizes.extend(shard.block_sizes_iter());
    }
    scratch.route(policy, n, batch_seq);
    scratch.split_for_shards(blocks_per_shard);
}

/// Application half of the serial dispatch: hand every shard its
/// sub-slice in shard order, stopping at the first OOM.
fn apply_routed_serial(
    shards: &mut [Shard],
    blocks_per_shard: usize,
    values: &[f32],
    scratch: &DispatchScratch,
) -> DispatchOutcome {
    let mut applied = 0u64;
    let mut oom = None;
    for (k, shard) in shards.iter_mut().enumerate() {
        let (offset, take) = scratch.ranges[k];
        if take == 0 {
            // No sub-batch → no kernel launch on this shard. Charging
            // idle shards a phantom insertion pass would let them set
            // the max-over-shards critical path under skewed routing.
            continue;
        }
        let out =
            shard.apply_counts(scratch.shard_counts(k, blocks_per_shard), &values[offset..offset + take]);
        applied += out.applied as u64;
        if let Some(e) = out.error {
            // No rollback — elements placed before the OOM stay visible,
            // matching device semantics; the shard left its index
            // consistent. But dispatch STOPS here: handing later shards
            // their slices would leave a mid-stream hole.
            oom = Some((shard.id(), e));
            break;
        }
    }
    DispatchOutcome { applied, oom }
}

/// Exact VRAM-demand pre-screen for a routed batch: for every shard, sum
/// the bytes of the buckets the routed counts will force each block to
/// allocate (the allocated-bucket prefix equals `buckets_for(len)` —
/// coordinator shards only grow or clear, never shrink) and compare with
/// the shard's free budget. `true` means no allocation in the fan-out
/// can fail; `false` sends the batch down the serial path, which handles
/// a mid-batch OOM with prefix semantics.
fn insert_demand_fits(
    shards: &[Shard],
    blocks_per_shard: usize,
    scratch: &DispatchScratch,
) -> bool {
    for (k, shard) in shards.iter().enumerate() {
        let fbs = shard.first_bucket_size();
        let mut need = 0u64;
        for b in 0..blocks_per_shard {
            let gi = k * blocks_per_shard + b;
            let c = scratch.counts[gi];
            if c == 0 {
                continue;
            }
            let len = scratch.sizes[gi] as usize;
            let have = buckets_for_len(fbs, len);
            let want = buckets_for_len(fbs, len + c);
            for bucket in have..want {
                need += ((fbs as u64) << bucket) * 4;
            }
        }
        if need > shard.heap_free() {
            return false;
        }
    }
    true
}

/// Pre-screen for a pooled gather (flatten snapshot or seal phase 1):
/// each shard's flatten allocates exactly `len × 4` destination bytes in
/// its own heap, so fit is checkable up front. A non-fit falls back to
/// the serial loop, whose first-failure abort semantics stay intact.
fn gather_demand_fits(shards: &[Shard]) -> bool {
    shards.iter().all(|s| s.len() as u64 * 4 <= s.heap_free())
}

/// Requests that act as frontend sync points: every registered client
/// pool is merged into the batcher before these are served. Queries and
/// legacy inserts deliberately do NOT drain — a read must not perturb
/// the deterministic merge order, so mid-phase queries observe state
/// frozen at the last sync point (plus legacy-path inserts).
fn needs_frontend_barrier(req: &Request) -> bool {
    matches!(
        req,
        Request::Seal
            | Request::Flatten
            | Request::Work { .. }
            | Request::Stats
            | Request::Clear
            | Request::Shutdown
    )
}

pub(crate) enum Envelope {
    Call(Request, mpsc::Sender<Response>),
    /// A new [`ClientSession`] handing the worker its lane: the receiving
    /// end of the session's bounded data channel.
    Register { id: u64, rx: Receiver<SessionInsert> },
    /// A session admitted an insert (eager merge mode): wake the worker
    /// so it drains the client pools without waiting for a sync point.
    Poke,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Envelope>,
    worker: Option<JoinHandle<()>>,
    /// Admission-frontend state shared with every [`ClientSession`].
    shared: Arc<FrontendShared>,
    frontend_cfg: FrontendConfig,
}

impl Coordinator {
    /// Start the worker thread, panicking on an invalid config (tests
    /// and examples; services that own their config should prefer
    /// [`Coordinator::try_start`]).
    pub fn start(cfg: CoordinatorConfig) -> Coordinator {
        Coordinator::try_start(cfg).unwrap_or_else(|e| panic!("invalid coordinator config: {e}"))
    }

    /// Validate the config and start the worker thread, or report what
    /// is wrong with the geometry as a typed [`ConfigError`].
    pub fn try_start(cfg: CoordinatorConfig) -> Result<Coordinator, ConfigError> {
        cfg.validate()?;
        let (tx, rx) = mpsc::channel::<Envelope>();
        let shared = Arc::new(FrontendShared::default());
        let frontend_cfg = cfg.frontend.clone();
        let worker_shared = Arc::clone(&shared);
        let worker = thread::Builder::new()
            .name("ggarray-coordinator".into())
            .spawn(move || super::supervisor::supervise(Worker::new(cfg, worker_shared), rx))
            .expect("spawn coordinator worker");
        Ok(Coordinator { tx, worker: Some(worker), shared, frontend_cfg })
    }

    /// Synchronous call (delegates to a [`Client`] over the same
    /// channel).
    pub fn call(&self, req: Request) -> Response {
        self.client().call(req)
    }

    /// Fire-and-forget insert (no response wait) — throughput path.
    pub fn insert_nowait(&self, values: Vec<f32>) {
        self.client().insert_nowait(values);
    }

    /// A cloneable client handle for concurrent callers (each thread gets
    /// its own reply channel; the worker serialises requests).
    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone() }
    }

    /// Open an admission-controlled [`ClientSession`]: a stable client
    /// id, a monotonic sequence number, and a **bounded** insert channel
    /// that sheds (typed rejection) instead of growing without limit.
    /// One per writer thread; see [`super::frontend`] for the
    /// backpressure and determinism contracts.
    pub fn session(&self) -> ClientSession {
        ClientSession::connect(self.tx.clone(), Arc::clone(&self.shared), &self.frontend_cfg)
    }

    /// Graceful stop.
    pub fn shutdown(mut self) {
        let _ = self.call(Request::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(h) = self.worker.take() {
            let (rtx, _r) = mpsc::channel();
            let _ = self.tx.send(Envelope::Call(Request::Shutdown, rtx));
            let _ = h.join();
        }
    }
}

/// Cloneable, `Send` handle to a running coordinator — hand one to each
/// client thread.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Envelope>,
}

impl Client {
    /// Synchronous call (same contract as [`Coordinator::call`]). A dead
    /// worker — request channel closed, or the reply sender dropped
    /// without answering — surfaces as the typed
    /// `Response::Failed(ServiceDown)` instead of hanging or panicking,
    /// so callers can distinguish "service gone" from an op-level error.
    pub fn call(&self, req: Request) -> Response {
        let (rtx, rrx) = mpsc::channel();
        if self.tx.send(Envelope::Call(req, rtx)).is_err() {
            return Response::Failed(ExecError::ServiceDown);
        }
        rrx.recv().unwrap_or_else(|_| Response::Failed(ExecError::ServiceDown))
    }

    /// Fire-and-forget insert (no response wait) — throughput path.
    pub fn insert_nowait(&self, values: Vec<f32>) {
        let (rtx, _rrx) = mpsc::channel();
        let _ = self.tx.send(Envelope::Call(Request::Insert { values }, rtx));
    }
}

/// The `Envelope::Call` the worker is currently serving, recorded by
/// [`Worker::serve`] *before* the fatal-fault site (and before any
/// mutation the call performs) so the supervisor can replay it exactly
/// once after a worker death: a request is either fully handled and
/// acked, or died un-acked before touching anything — never half-done.
pub(crate) struct InFlight {
    pub(crate) req: Request,
    pub(crate) reply: mpsc::Sender<Response>,
}

pub(crate) struct Worker {
    cfg: CoordinatorConfig,
    shards: Vec<Shard>,
    blocks_per_shard: usize,
    epochs: EpochManager,
    batcher: Batcher,
    metrics: Metrics,
    /// Shared AOT/PJRT executor handle: `Arc`ed so pooled Work hands
    /// every scheduler worker the same compiled-kernel manifest (each
    /// worker lazily compiles into its own thread-local cache).
    executor: Option<Arc<Executor>>,
    batch_seq: u64,
    /// Serial coordinator clock: host-side sync charged once per
    /// shard-dispatching op — the explicit serial term of the parallel
    /// time model (it cannot overlap with any shard's kernels).
    coord: Clock,
    /// Dispatch scratch arena: every per-batch buffer of the insert hot
    /// path lives here for the worker's lifetime — cleared, never
    /// dropped, so the steady-state loop is allocation-free.
    scratch: DispatchScratch,
    /// Pooled destination of `Request::Flatten` snapshots (cleared per
    /// use, capacity retained across snapshots).
    flatten_pool: Vec<f32>,
    /// Persistent work-stealing scheduler (`None` = serial execution):
    /// spawned once here, never per batch; shard-dispatching ops fan out
    /// to it as stealable chunks and its `finish` barrier (all chunks
    /// done + all workers parked) is the fan-in.
    scheduler: Option<Scheduler>,
    /// Admission ledger shared with every [`ClientSession`].
    shared: Arc<FrontendShared>,
    /// Registered client lanes, kept sorted by client id — the
    /// deterministic drain order of the cross-client merge.
    lanes: Vec<ClientLane>,
}

impl Worker {
    /// Build the worker state. The config was validated by
    /// [`Coordinator::try_start`], so the geometry divides evenly here.
    pub(crate) fn new(cfg: CoordinatorConfig, shared: Arc<FrontendShared>) -> Worker {
        debug_assert!(cfg.validate().is_ok());
        let blocks_per_shard = cfg.blocks / cfg.shards;
        let executor = if cfg.use_artifacts {
            match Executor::from_default_dir() {
                Ok(e) => Some(Arc::new(e)),
                Err(err) => {
                    eprintln!("[coordinator] artifacts unavailable, using host fallback: {err}");
                    None
                }
            }
        } else {
            None
        };
        // One physical budget, carved once: the epoch-owned sealed store
        // takes its reservation first, the rest splits evenly into the
        // per-shard heaps (remainder bytes included). Bytes committed to
        // sealed epochs can never be promised to live-epoch growth, and
        // vice versa.
        let (epoch_heap_bytes, shard_heap_total) = cfg.heap_carve();
        let shards: Vec<Shard> = split_heap_budget(shard_heap_total, cfg.shards)
            .into_iter()
            .enumerate()
            .map(|(id, heap_bytes)| {
                Shard::new(ShardConfig {
                    id,
                    blocks: blocks_per_shard,
                    first_bucket_size: cfg.first_bucket_size,
                    insertion: cfg.insertion,
                    device: cfg.device.clone(),
                    heap_bytes,
                })
            })
            .collect();
        // Scheduler workers: spawned once for the worker's lifetime
        // (threads are never created per batch).
        let scheduler =
            if cfg.pooled_execution() { Some(Scheduler::new(cfg.executor_workers())) } else { None };
        Worker {
            shards,
            blocks_per_shard,
            epochs: EpochManager::new(cfg.device.clone(), epoch_heap_bytes),
            batcher: Batcher::new(cfg.batch.clone()),
            metrics: Metrics::new(),
            executor,
            batch_seq: 0,
            coord: Clock::new(),
            scratch: DispatchScratch::new(),
            flatten_pool: Vec::new(),
            scheduler,
            shared,
            lanes: Vec::new(),
            cfg,
        }
    }

    /// The event loop, run under the supervisor's containment net
    /// ([`super::supervisor::supervise`]). Returns on graceful shutdown
    /// (the Shutdown request was handled and acked) or when every
    /// request sender is gone. A panic escaping this frame is a worker
    /// *death*: the supervisor catches it, respawns the loop over the
    /// surviving `self`, and replays `inflight` — which this loop
    /// records before the fatal site and before any mutation, so the
    /// replay is exactly-once.
    pub(crate) fn serve(&mut self, rx: &Receiver<Envelope>, inflight: &mut Option<InFlight>) {
        loop {
            let wait = self
                .batcher
                .time_to_deadline()
                .unwrap_or(Duration::from_millis(50))
                .max(Duration::from_micros(100));
            match rx.recv_timeout(wait) {
                Ok(Envelope::Call(req, reply)) => {
                    // Record the call for the supervisor *before* the
                    // fatal site: nothing of the request has run yet, so
                    // a death between here and the ack leaves a replay
                    // that is indistinguishable from a fresh execution.
                    *inflight = Some(InFlight { req: req.clone(), reply: reply.clone() });
                    // Fatal-fault site: an injected panic here kills the
                    // handler loop outright, modelling an uncontainable
                    // crash — the path the supervisor's detect→respawn→
                    // replay handshake covers.
                    crate::faults::point("service.worker.fatal");
                    let stop = self.complete_call(req, reply);
                    *inflight = None;
                    if stop {
                        return;
                    }
                }
                Ok(Envelope::Register { id, rx }) => {
                    let at = self.lanes.partition_point(|l| l.id < id);
                    self.lanes.insert(at, ClientLane { id, rx, next_seq: 0 });
                }
                Ok(Envelope::Poke) => {
                    if self.cfg.frontend.merge == MergePolicy::Eager {
                        self.drain_frontend(false);
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if let Some(batch) = self.batcher.poll_deadline() {
                        self.apply_batch(batch.values, batch.requests);
                    }
                    if self.cfg.frontend.merge == MergePolicy::Eager && !self.lanes.is_empty() {
                        self.drain_frontend(false);
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }
    }

    /// Serve one `Envelope::Call` to completion: barrier-drain the
    /// client pools if the request is a sync point, handle it under the
    /// panic-containment net, ledger the latency, ack the reply.
    /// Returns `true` when the request was Shutdown (the loop must
    /// stop). Also the supervisor's replay entry point — everything a
    /// call mutates happens inside this frame, which is what makes the
    /// record-before / clear-after protocol in [`Worker::serve`] sound.
    pub(crate) fn complete_call(&mut self, req: Request, reply: mpsc::Sender<Response>) -> bool {
        // Sync points merge every client pool first (the barrier
        // drain), so a session's accepted inserts are always visible to
        // the sync ops that follow them — and so the AtBarrier merge
        // order is exactly client-id ascending, per-client FIFO.
        if needs_frontend_barrier(&req) && !self.lanes.is_empty() {
            self.drain_frontend(true);
        }
        let t0 = Instant::now();
        let stop = matches!(req, Request::Shutdown);
        // Contain handler panics: the request is lost (typed
        // `HandlerPanic`) but the worker, shards and sessions keep
        // serving. Checker cancellation tokens must pass through, or a
        // model-checked schedule could not be abandoned.
        let resp = match catch_unwind(AssertUnwindSafe(|| self.handle(req))) {
            Ok(resp) => resp,
            Err(payload) => {
                if crate::checker::rt::cancelled() {
                    std::panic::resume_unwind(payload);
                }
                self.metrics.errors += 1;
                Response::Failed(ExecError::HandlerPanic)
            }
        };
        self.metrics.observe_latency_us(t0.elapsed().as_secs_f64() * 1e6);
        let _ = reply.send(resp);
        stop
    }

    /// Supervisor ledger: the handler loop died and was respawned over
    /// this surviving state. Not an `errors` bump — the failover is
    /// transparent (the un-acked request is replayed and acked), so the
    /// client-observable trace stays identical to the fault-free run.
    pub(crate) fn note_restart(&mut self) {
        self.metrics.worker_restarts += 1;
    }

    /// Supervisor ledger: the un-acked request recorded at death was
    /// replayed (exactly once).
    pub(crate) fn note_replay(&mut self) {
        self.metrics.replayed_requests += 1;
    }

    /// Supervisor ledger: a replay itself died — the request is lost
    /// (its reply sender dropped, so the caller gets a typed
    /// `ServiceDown`) and that IS client-observable.
    pub(crate) fn note_failed_replay(&mut self) {
        self.metrics.errors += 1;
    }

    // ---------- aggregate views ----------

    /// Elements in the live (unsealed) epoch across all shards.
    fn live_len(&self) -> u64 {
        self.shards.iter().map(|s| s.len() as u64).sum()
    }

    /// Total elements: sealed prefix + live epoch.
    fn total_len(&self) -> u64 {
        self.epochs.sealed_len() + self.live_len()
    }

    /// Snapshot every simulated clock that can advance during one op:
    /// the per-shard clocks (concurrent, written into the scratch arena's
    /// marks buffer), the flat-path clock and the coordinator clock
    /// (both serial).
    fn clock_marks(&mut self) -> ClockMarks {
        self.scratch.marks.clear();
        self.scratch.marks.extend(self.shards.iter().map(|s| s.sim_now_us()));
        ClockMarks { epochs: self.epochs.now_us(), coord: self.coord.now_us() }
    }

    /// The parallel-model cost of everything since `marks`: shards ran
    /// concurrently (max over deltas on the critical path, sum on the
    /// device total); the flat-path and coordinator deltas are serial
    /// launches that cannot overlap the shard kernels.
    fn cost_since(&self, marks: &ClockMarks) -> ParallelCost {
        let shard_cost = ParallelCost::from_parallel(
            self.shards.iter().zip(&self.scratch.marks).map(|(s, &t0)| s.sim_now_us() - t0),
        );
        let serial =
            (self.epochs.now_us() - marks.epochs) + (self.coord.now_us() - marks.coord);
        shard_cost.then(ParallelCost::serial(serial))
    }

    /// Charge the serial coordinator term of one shard-dispatching op
    /// (routing decision + launch sync on the host).
    fn charge_dispatch(&mut self) {
        self.coord.charge(Category::Host, self.cfg.device.cost.host_sync_us);
    }

    /// Read a global index: the sealed prefix first, then the live epoch
    /// in shard order.
    fn read_global(&self, i: u64) -> Option<f32> {
        let sealed = self.epochs.sealed_len();
        if i < sealed {
            return self.epochs.get(i);
        }
        let mut j = i - sealed;
        for shard in &self.shards {
            let n = shard.len() as u64;
            if j < n {
                return shard.get(j);
            }
            j -= n;
        }
        None
    }

    /// Flush pending inserts before any op that observes array state.
    fn barrier(&mut self) {
        if let Some(batch) = self.batcher.flush() {
            self.apply_batch(batch.values, batch.requests);
        }
    }

    /// Merge admitted client-pool inserts into the batcher (the
    /// febft-style proposal step). The sweep itself —
    /// [`super::frontend::drain_lanes`], shared with the `FrontendRig`
    /// harness and the `ggcheck` model suite — visits lanes in ascending
    /// client-id order, per-client FIFO, bounded to `queue_requests` per
    /// lane per sweep so one hot producer cannot starve the loop; a
    /// `barrier` drain repeats until nothing moves. The worker's sink
    /// maps each drained insert into metrics and the batcher, with
    /// size-triggered flushes dispatching inline (preserving merged
    /// stream order). Lanes are taken out of `self` for the sweep so the
    /// sink can borrow the worker mutably for `apply_batch`.
    fn drain_frontend(&mut self, barrier: bool) {
        let mut lanes = std::mem::take(&mut self.lanes);
        let per_sweep = self.cfg.frontend.queue_requests.max(1);
        let shared = Arc::clone(&self.shared);
        let stats = drain_lanes(&mut lanes, &shared, per_sweep, barrier, |_, ins| {
            self.metrics.inserts_requested += 1;
            self.metrics.admitted_requests += 1;
            self.metrics.admitted_values += ins.values.len() as u64;
            if let Some(batch) = self.batcher.push_owned(ins.values) {
                self.apply_batch(batch.values, batch.requests);
            }
        });
        self.metrics.proposals += stats.productive_sweeps;
        self.lanes = lanes;
    }

    /// Dispatch one flushed batch. Returns the typed abort if a
    /// scheduler worker panicked mid-dispatch: the batch was rolled back
    /// byte-identically (none of it landed) and the worker keeps
    /// serving. Only the synchronous `Request::Insert` path propagates
    /// the error to a caller; fire-and-forget drains observe it through
    /// the `errors` metric.
    fn apply_batch(&mut self, values: Vec<f32>, requests: usize) -> Option<ExecError> {
        if values.is_empty() {
            self.batcher.recycle(values);
            return None;
        }
        let marks = self.clock_marks();
        self.charge_dispatch();
        // Scratch-arena dispatch: shard k owns blocks [k·bps, (k+1)·bps)
        // and receives a contiguous `&values[..]` sub-slice. The
        // sub-batches execute concurrently — on the modeled device
        // (disjoint block ranges, so the ledger charges the slowest
        // shard, not the sum — see `cost_since`) and, with the
        // scheduler, on the host for real (wall ledger).
        let wall0 = Instant::now();
        let outcome = match &self.scheduler {
            Some(sched) => dispatch_insert_pooled(
                sched,
                &mut self.shards,
                self.blocks_per_shard,
                self.cfg.routing,
                self.batch_seq,
                &values,
                &mut self.scratch,
            ),
            None => Ok(dispatch_insert(
                &mut self.shards,
                self.blocks_per_shard,
                self.cfg.routing,
                self.batch_seq,
                &values,
                &mut self.scratch,
            )),
        };
        self.metrics.wall_insert_us += wall0.elapsed().as_secs_f64() * 1e6;
        self.batch_seq += 1;
        let outcome = match outcome {
            Ok(outcome) => outcome,
            Err(err) => {
                // Panic abort: the dispatch rolled every shard back, so
                // the only charges standing are the serial dispatch term
                // — ledger them (the host sync really happened) and keep
                // the batch accounting consistent for later batches.
                eprintln!("[coordinator] {err}");
                self.metrics.errors += 1;
                let cost = self.cost_since(&marks);
                self.metrics.charge_insert(cost);
                self.metrics.batches += 1;
                self.batcher.recycle(values);
                return Some(err);
            }
        };
        #[cfg(debug_assertions)]
        self.cross_check_scan_offsets(values.len());
        if let Some((shard, e)) = &outcome.oom {
            eprintln!("[coordinator] simulated OOM during insert on shard {shard}: {e}");
            self.metrics.errors += 1;
        }
        let cost = self.cost_since(&marks);
        self.metrics.charge_insert(cost);
        self.metrics.batches += 1;
        self.metrics.elements_inserted += outcome.applied;
        let _ = requests;
        // The consumed batch buffer returns to the batcher: steady-state
        // flushes ping-pong two buffers instead of allocating.
        self.batcher.recycle(values);
        None
    }

    /// Debug-build-only self-check: cross-check the routed per-block
    /// offsets against the AOT scan kernel (the real index-assignment
    /// path) and the host oracle. Release builds skip the whole block —
    /// the expectation vectors (`counts_i32`, `assign_indices`) were the
    /// last per-batch allocations on the hot path.
    #[cfg(debug_assertions)]
    fn cross_check_scan_offsets(&mut self, batch_len: usize) {
        if let Some(exec) = &self.executor {
            let counts_i32: Vec<i32> = self.scratch.counts.iter().map(|&c| c as i32).collect();
            if let Ok((offsets, total)) = exec.scan_offsets("scan_warp_i32_", &counts_i32) {
                debug_assert_eq!(total as usize, batch_len);
                let expect: Vec<i64> = {
                    let counts_u32: Vec<u32> =
                        self.scratch.counts.iter().map(|&c| c as u32).collect();
                    let (o, _) = crate::insertion::assign_indices(0, &counts_u32);
                    o.iter().map(|&x| x as i64).collect()
                };
                debug_assert_eq!(offsets, expect, "AOT scan disagrees with host oracle");
                self.metrics.pjrt_executions += 1;
            }
        }
    }

    fn handle(&mut self, req: Request) -> Response {
        // Contained-fault site: an injected panic here unwinds into
        // `complete_call`'s catch_unwind — the request is lost
        // (HandlerPanic) but the worker keeps serving. The `.slow` twin
        // stalls the whole request instead, for tail-latency chaos.
        crate::faults::point("service.worker.handle");
        crate::faults::stall("service.worker.handle.slow");
        match req {
            Request::Insert { values } => {
                self.metrics.inserts_requested += 1;
                let count = values.len() as u64;
                if let Some(batch) = self.batcher.push(&values) {
                    if let Some(err) = self.apply_batch(batch.values, batch.requests) {
                        return Response::Failed(err);
                    }
                }
                Response::Inserted {
                    count,
                    sim_us: 0.0,
                    len: self.total_len() + self.batcher.pending_len() as u64,
                }
            }
            Request::Work { calls } => {
                self.barrier();
                let marks = self.clock_marks();
                let mut pjrt = 0u64;
                let wall0 = Instant::now();
                for _ in 0..calls {
                    self.charge_dispatch();
                    if let Some(sched) = &self.scheduler {
                        // Real numeric update + modeled rw_b per shard,
                        // concurrently on the workers (empty live shards
                        // still skip the rw_b launch). The shared
                        // executor handle rides along, so pooled Work
                        // runs the AOT kernels whenever the serial path
                        // would — there is no artifacts-live serial
                        // special case anymore.
                        match sched.run_work(
                            &mut self.shards,
                            self.executor.as_ref(),
                            self.cfg.work_iters,
                        ) {
                            Ok(p) => pjrt += p,
                            Err(err) => {
                                // Abort: the pre-charged rw_b launches
                                // were rewound; completed calls of this
                                // request stand (each was fully ledgered).
                                eprintln!("[coordinator] {err}");
                                self.metrics.errors += 1;
                                self.metrics.wall_work_us +=
                                    wall0.elapsed().as_secs_f64() * 1e6;
                                return Response::Failed(err);
                            }
                        }
                    } else {
                        // Real numeric update on the live epoch (PJRT
                        // when possible), then the modeled rw_b cost per
                        // shard — concurrent launches, so the ledger sees
                        // the max. Empty live shards get no rw_b launch
                        // at all: on a mostly-sealed store the live pass
                        // is free.
                        pjrt += self.one_work_pass();
                        for shard in &mut self.shards {
                            if !shard.is_empty() {
                                shard.charge_rw_block(self.cfg.work_iters as f64);
                            }
                        }
                    }
                    // Sealed prefix: real update + static-array cost —
                    // the fast path the two-phase pattern buys. One
                    // kernel over the whole flat store, serial behind
                    // the per-shard launches.
                    self.epochs.work(self.cfg.work_iters);
                }
                self.metrics.wall_work_us += wall0.elapsed().as_secs_f64() * 1e6;
                self.metrics.work_calls += calls as u64;
                self.metrics.pjrt_executions += pjrt;
                let cost = self.cost_since(&marks);
                self.metrics.charge_work(cost);
                Response::Worked {
                    calls,
                    sim_us: cost.critical_path_us,
                    device_us: cost.total_device_us,
                    pjrt_executions: pjrt,
                }
            }
            Request::Flatten => {
                self.barrier();
                let marks = self.clock_marks();
                self.charge_dispatch();
                // Sealed prefix is already flat; append a non-destructive
                // flatten of the live epoch — per-shard gathers over
                // disjoint block ranges, concurrent on the device (and,
                // with the scheduler, on the host: stealable range
                // chunks write disjoint sub-slices of the buffer). The
                // destination is the worker's pooled snapshot buffer
                // (cleared per call, capacity retained), so steady-state
                // snapshots reuse one gather buffer.
                let mut data = std::mem::take(&mut self.flatten_pool);
                data.clear();
                data.reserve(self.total_len() as usize);
                for segment in self.epochs.segments() {
                    data.extend_from_slice(segment);
                }
                let wall0 = Instant::now();
                let mut failed = None;
                if self.scheduler.is_some() && gather_demand_fits(&self.shards) {
                    let base = data.len();
                    let live: usize = self.shards.iter().map(|s| s.len()).sum();
                    // The zero-fill is a serial pass the workers then
                    // overwrite; unlike the seal (whose gather buffer
                    // supports an uncleared lease), the snapshot buffer
                    // interleaves a variable sealed-segment prefix, so
                    // the simple fill is kept on this ungated path.
                    data.resize(base + live, 0.0);
                    self.scratch.fill_gather_ranges(self.shards.iter().map(|s| s.len()));
                    let sched = self.scheduler.as_ref().expect("scheduler checked");
                    match sched.run_flatten_temp(
                        &mut self.shards,
                        &mut data[base..],
                        &self.scratch.gather_ranges,
                    ) {
                        Ok(()) => {}
                        Err(PhaseAbort::Oom(e)) => failed = Some(e),
                        Err(PhaseAbort::Panic(err)) => {
                            // Worker-panic abort: the gather charges were
                            // rewound and the half-written snapshot is
                            // discarded — the store is untouched.
                            eprintln!("[coordinator] {err}");
                            self.metrics.errors += 1;
                            self.metrics.wall_flatten_us +=
                                wall0.elapsed().as_secs_f64() * 1e6;
                            self.flatten_pool = data;
                            return Response::Failed(err);
                        }
                    }
                } else {
                    // Serial path (no scheduler, or a fit is not
                    // guaranteed — the appending loop aborts at the
                    // first OOM shard).
                    for shard in &mut self.shards {
                        if let Err(e) = shard.flatten_temp_into(&mut data) {
                            failed = Some(e);
                            break;
                        }
                    }
                }
                self.metrics.wall_flatten_us += wall0.elapsed().as_secs_f64() * 1e6;
                if let Some(e) = failed {
                    self.metrics.errors += 1;
                    self.flatten_pool = data;
                    return Response::Error(format!("flatten OOM: {e}"));
                }
                self.metrics.flattens += 1;
                let cost = self.cost_since(&marks);
                self.metrics.charge_flatten(cost);
                let resp = Response::Flattened {
                    len: data.len() as u64,
                    sim_us: cost.critical_path_us,
                    device_us: cost.total_device_us,
                    checksum: checksum(&data),
                };
                self.flatten_pool = data;
                resp
            }
            Request::Seal => {
                self.barrier();
                let marks = self.clock_marks();
                self.charge_dispatch();
                // Two-phase commit across shards. Phase 1 — prepare:
                // flatten every shard into the pooled gather destination
                // (leased from the epoch store, sized by the largest
                // seal seen; each shard's simulated destination is still
                // a fresh allocation in its own heap), then reserve
                // epoch-store capacity for the whole seal. Any failure
                // aborts the entire transaction before a single byte
                // commits. With the scheduler (and a pre-screened
                // guaranteed fit) the gathers run as stealable range
                // chunks into disjoint sub-slices of the shared
                // destination — the paper's per-block flatten kernels,
                // for real.
                let wall0 = Instant::now();
                let mut parts: Vec<SealPart> = Vec::with_capacity(self.shards.len());
                let mut failed = None;
                let pooled_gather = self.scheduler.is_some() && gather_demand_fits(&self.shards);
                let mut dst = if pooled_gather {
                    // Uncleared lease: the workers overwrite exactly
                    // [0, live), so stale banked elements never need the
                    // serial zero-fill memset a cleared `resize` would
                    // pay ahead of the parallel writes — only capacity
                    // the buffer has never reached gets initialized.
                    self.epochs.take_gather_buffer_uncleared()
                } else {
                    self.epochs.take_gather_buffer()
                };
                if pooled_gather {
                    let live: usize = self.shards.iter().map(|s| s.len()).sum();
                    dst.truncate(live);
                    if dst.len() < live {
                        dst.resize(live, 0.0);
                    }
                    self.scratch.fill_gather_ranges(self.shards.iter().map(|s| s.len()));
                    let sched = self.scheduler.as_ref().expect("scheduler checked");
                    let mut results = Vec::with_capacity(self.shards.len());
                    if let Err(err) = sched.run_seal(
                        &mut self.shards,
                        &mut dst,
                        &self.scratch.gather_ranges,
                        &mut results,
                    ) {
                        // Worker-panic abort: run_seal already unwound —
                        // every shard reopened with its costs rewound —
                        // so banking the gather buffer is all that's left.
                        eprintln!("[coordinator] {err}");
                        self.epochs.bank_gather_buffer(dst);
                        self.metrics.errors += 1;
                        self.metrics.wall_flatten_us += wall0.elapsed().as_secs_f64() * 1e6;
                        return Response::Failed(err);
                    }
                    if results.iter().any(|r| r.is_err()) {
                        // Cannot happen (pre-screened fit) — but unwind
                        // faithfully anyway: failed shards reopened
                        // themselves, flattened shards release their
                        // destination. Unlike the serial prefix abort,
                        // every shard ran its gather here.
                        let msg = results
                            .iter()
                            .find_map(|r| r.as_ref().err())
                            .map(|e| format!("seal OOM: {e}"))
                            .expect("checked any err");
                        for (shard, r) in self.shards.iter_mut().zip(results) {
                            if let Ok(mut p) = r {
                                shard.abort_seal(p.alloc.take());
                            }
                        }
                        self.epochs.bank_gather_buffer(dst);
                        self.metrics.errors += 1;
                        self.metrics.wall_flatten_us += wall0.elapsed().as_secs_f64() * 1e6;
                        return Response::Error(msg);
                    }
                    parts.extend(results.into_iter().map(|r| r.expect("no errors checked")));
                } else {
                    for shard in &mut self.shards {
                        match shard.seal_flatten_into(&mut dst) {
                            Ok(p) => parts.push(p),
                            Err(e) => {
                                failed = Some(format!("seal OOM: {e}"));
                                break;
                            }
                        }
                    }
                }
                if failed.is_none() {
                    // Reserve: the epoch store must be able to adopt
                    // every destination before any shard commits, so the
                    // per-shard transfers below can never fail half-way.
                    let sealed_bytes: u64 = parts.iter().map(|p| p.len as u64 * 4).sum();
                    if let Err(e) = self.epochs.can_accept(sealed_bytes) {
                        failed = Some(format!("seal OOM (epoch store): {e}"));
                    }
                }
                if let Some(msg) = failed {
                    // Single-pass abort: shards that flattened release
                    // their fresh destination and reopen; the tail (the
                    // failure shard included) never flattened and just
                    // reopens — every shard is visited exactly once, so
                    // nothing is double-reopened or double-freed. The
                    // gather destination returns to the pool.
                    let mut parts = parts.into_iter();
                    for shard in &mut self.shards {
                        match parts.next() {
                            Some(mut p) => shard.abort_seal(p.alloc.take()),
                            None => shard.reopen(),
                        }
                    }
                    self.epochs.bank_gather_buffer(dst);
                    self.metrics.errors += 1;
                    self.metrics.wall_flatten_us += wall0.elapsed().as_secs_f64() * 1e6;
                    return Response::Error(msg);
                }
                // Phase 2 — commit: transfer every destination out of
                // its shard heap into the epoch-owned heap (reservation
                // checked above, so the transfers are infallible) and
                // open the next inserting epoch behind the seal.
                let mut seg_allocs = Vec::with_capacity(parts.len());
                for (shard, part) in self.shards.iter_mut().zip(&mut parts) {
                    seg_allocs.extend(shard.commit_seal(part.alloc.take(), self.epochs.heap_mut()));
                }
                let flat: ShardedFlattened<f32> = concat_parts(&parts, dst);
                let epoch_len = flat.len() as u64;
                let sum = checksum(&flat.data);
                let epoch = self.epochs.absorb(flat, seg_allocs);
                // Segment-count hygiene: one modeled gather pass merges
                // the sealed segments once there are too many (charged
                // to the flat-path clock, so it lands in this op's cost).
                // The gather is its own VRAM transaction — sources and
                // merged destination resident at once — and a budget too
                // tight for that transient aborts it byte-identically:
                // the seal stands, the segments stay, and the OOM is
                // surfaced here and in the metrics.
                let mut compaction_oom = None;
                match self.epochs.maybe_compact(self.cfg.compact_segments) {
                    Some(Ok(_us)) => self.metrics.compactions += 1,
                    Some(Err(e)) => {
                        self.metrics.compaction_ooms += 1;
                        self.metrics.errors += 1;
                        compaction_oom = Some(format!("compaction OOM (segments retained): {e}"));
                    }
                    None => {}
                }
                self.metrics.seals += 1;
                self.metrics.wall_flatten_us += wall0.elapsed().as_secs_f64() * 1e6;
                let cost = self.cost_since(&marks);
                self.metrics.charge_flatten(cost);
                Response::Sealed {
                    epoch,
                    epoch_len,
                    sealed_len: self.epochs.sealed_len(),
                    sealed_segments: self.epochs.sealed_epochs(),
                    sim_us: cost.critical_path_us,
                    device_us: cost.total_device_us,
                    checksum: sum,
                    compaction_oom,
                }
            }
            Request::Query { index } => {
                self.barrier();
                self.metrics.queries += 1;
                Response::Value(self.read_global(index))
            }
            Request::Stats => {
                // Pending inserts are observable state: flush them so
                // `len`, `overhead_ratio()` and `coalescing()` include
                // everything submitted. Callers previously had to
                // barrier with a dummy Query to see accurate stats.
                self.barrier();
                let len = self.total_len();
                let capacity = self.shards.iter().map(|s| s.capacity() as u64).sum::<u64>()
                    + self.epochs.sealed_len();
                // Allocation accounting is a real ledger now: live-epoch
                // bucket bytes in the shard heaps plus the epoch-owned
                // sealed store — not a `sealed_len * 4` estimate.
                let allocated = self.shards.iter().map(|s| s.allocated_bytes()).sum::<u64>()
                    + self.epochs.sealed_bytes();
                let heap_used = self.shards.iter().map(|s| s.heap_used()).sum::<u64>()
                    + self.epochs.sealed_bytes();
                let snap = self
                    .metrics
                    .snapshot(len, capacity, allocated)
                    .with_sharding(
                        self.shards.len(),
                        self.epochs.seq(),
                        self.epochs.sealed_len(),
                        self.epochs.sealed_epochs(),
                        self.shards.iter().map(|s| s.len() as u64).collect(),
                    )
                    .with_memory(self.epochs.sealed_bytes(), heap_used)
                    .with_batching(self.batcher.flushes(), self.batcher.coalesced_total())
                    .with_executors(self.scheduler.as_ref().map(|s| s.threads()).unwrap_or(1))
                    .with_scheduler(
                        self.scheduler.as_ref().map(|s| s.counters()).unwrap_or_default(),
                    )
                    .with_frontend(self.shared.sessions(), self.shared.shed_total());
                Response::Stats(snap)
            }
            Request::Clear => {
                // Discard pending inserts too: Clear means "empty now".
                let _ = self.batcher.flush();
                for shard in &mut self.shards {
                    shard.reopen_clear();
                }
                // The epoch store owns the sealed bytes — it releases
                // them itself.
                self.epochs.reset();
                Response::Cleared
            }
            Request::Shutdown => {
                self.barrier();
                Response::ShuttingDown
            }
        }
    }

    /// Apply the real +1×`work_iters` numeric update to the live epoch,
    /// through the AOT PJRT kernels when possible. Returns PJRT
    /// executions done.
    fn one_work_pass(&mut self) -> u64 {
        let exec = self.executor.as_deref();
        let iters = self.cfg.work_iters;
        let mut pjrt = 0u64;
        for shard in &mut self.shards {
            pjrt += shard.work_pass(exec, iters);
        }
        pjrt
    }
}

// ---------------------------------------------------------------------
// Workload driver
// ---------------------------------------------------------------------

/// Summary of driving a [`WorkloadSpec`] through a coordinator.
#[derive(Debug, Clone, Default)]
pub struct WorkloadRun {
    /// Total elements submitted.
    pub inserted: u64,
    /// Checksum of each sealed epoch, in seal order.
    pub seal_checksums: Vec<u64>,
    /// Checksum of each full-flatten snapshot, in order.
    pub flatten_checksums: Vec<u64>,
    /// Wall-model (critical-path) simulated µs across all Work steps.
    pub work_sim_us: f64,
    /// Wall-model (critical-path) simulated µs across all Seal steps.
    pub seal_sim_us: f64,
    /// Aggregate device-seconds (µs) across all Work steps.
    pub work_device_us: f64,
    /// Aggregate device-seconds (µs) across all Seal steps.
    pub seal_device_us: f64,
    /// Seals whose compaction pass aborted on the epoch heap's transient
    /// 2× residency (the seal itself committed; segments retained).
    pub compaction_ooms: u64,
}

/// Drive a workload trace through the service. `Insert` steps synthesise
/// deterministic f32 values in exactly `chunk`-sized requests, so batch
/// boundaries — and therefore global routing decisions — are reproducible
/// across runs and shard counts (pair with `BatchConfig::max_values ==
/// chunk` for fully deterministic flushes). Panics on service errors:
/// this is a test/experiment driver, not production plumbing.
pub fn drive_workload(c: &Coordinator, w: &WorkloadSpec, chunk: usize) -> WorkloadRun {
    assert!(chunk > 0);
    let mut run = WorkloadRun::default();
    let mut counter = 0u64;
    for step in &w.steps {
        match step {
            Step::Insert(n) => {
                let mut sent = 0u64;
                while sent < *n {
                    let take = chunk.min((*n - sent) as usize);
                    let values: Vec<f32> =
                        (0..take).map(|i| synth_f32(counter + i as u64)).collect();
                    match c.call(Request::Insert { values }) {
                        Response::Inserted { .. } => {}
                        other => panic!("insert failed: {other:?}"),
                    }
                    counter += take as u64;
                    sent += take as u64;
                }
                run.inserted = counter;
            }
            Step::Work(calls) => match c.call(Request::Work { calls: *calls }) {
                Response::Worked { sim_us, device_us, .. } => {
                    run.work_sim_us += sim_us;
                    run.work_device_us += device_us;
                }
                other => panic!("work failed: {other:?}"),
            },
            Step::Flatten => match c.call(Request::Flatten) {
                Response::Flattened { checksum, .. } => run.flatten_checksums.push(checksum),
                other => panic!("flatten failed: {other:?}"),
            },
            Step::Seal => match c.call(Request::Seal) {
                Response::Sealed { checksum, sim_us, device_us, compaction_oom, .. } => {
                    run.seal_checksums.push(checksum);
                    run.seal_sim_us += sim_us;
                    run.seal_device_us += device_us;
                    run.compaction_ooms += u64::from(compaction_oom.is_some());
                }
                other => panic!("seal failed: {other:?}"),
            },
        }
    }
    run
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cfg(blocks: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            blocks,
            first_bucket_size: 16,
            use_artifacts: false, // unit tests must not depend on `make artifacts`
            batch: BatchConfig { max_values: 64, max_delay: Duration::from_millis(1) },
            ..CoordinatorConfig::default()
        }
    }

    fn sharded_cfg(blocks: usize, shards: usize) -> CoordinatorConfig {
        CoordinatorConfig { shards, ..test_cfg(blocks) }
    }

    #[test]
    fn insert_query_roundtrip() {
        let c = Coordinator::start(test_cfg(4));
        c.call(Request::Insert { values: (0..100).map(|i| i as f32).collect() });
        // Query barriers pending batches, so this is totally ordered.
        let v = c.call(Request::Query { index: 0 }).expect_value();
        assert_eq!(v, Some(0.0));
        let v = c.call(Request::Query { index: 99 }).expect_value();
        assert!(v.is_some());
        let v = c.call(Request::Query { index: 100 }).expect_value();
        assert_eq!(v, None);
        c.shutdown();
    }

    #[test]
    fn work_applies_numeric_update() {
        let cfg = test_cfg(2);
        let iters = cfg.work_iters as f32;
        let c = Coordinator::start(cfg);
        c.call(Request::Insert { values: vec![1.0, 2.0, 3.0] });
        c.call(Request::Work { calls: 2 });
        assert_eq!(c.call(Request::Query { index: 0 }).expect_value(), Some(1.0 + 2.0 * iters));
        assert_eq!(c.call(Request::Query { index: 2 }).expect_value(), Some(3.0 + 2.0 * iters));
        c.shutdown();
    }

    #[test]
    fn flatten_checksum_stable() {
        let c = Coordinator::start(test_cfg(4));
        c.call(Request::Insert { values: (0..500).map(|i| i as f32).collect() });
        let a = match c.call(Request::Flatten) {
            Response::Flattened { checksum, len, .. } => {
                assert_eq!(len, 500);
                checksum
            }
            other => panic!("{other:?}"),
        };
        let b = match c.call(Request::Flatten) {
            Response::Flattened { checksum, .. } => checksum,
            other => panic!("{other:?}"),
        };
        assert_eq!(a, b);
        c.shutdown();
    }

    #[test]
    fn batching_coalesces_small_inserts() {
        let c = Coordinator::start(test_cfg(4));
        for i in 0..200 {
            c.call(Request::Insert { values: vec![i as f32] });
        }
        // Stats barriers pending inserts itself — no dummy Query needed.
        let snap = match c.call(Request::Stats) {
            Response::Stats(s) => s,
            other => panic!("{other:?}"),
        };
        assert_eq!(snap.elements_inserted, 200);
        assert!(snap.batches < 200, "batching should coalesce: {} batches", snap.batches);
        assert!(snap.coalescing() > 1.5, "coalescing {:.2}", snap.coalescing());
        assert_eq!(snap.len, 200);
        c.shutdown();
    }

    #[test]
    fn stats_overhead_bounded() {
        let c = Coordinator::start(test_cfg(8));
        c.call(Request::Insert { values: vec![1.0; 10_000] });
        let snap = match c.call(Request::Stats) {
            Response::Stats(s) => s,
            other => panic!("{other:?}"),
        };
        assert!(snap.overhead_ratio() < 2.3, "overhead {:.2}", snap.overhead_ratio());
        c.shutdown();
    }

    #[test]
    fn stats_barriers_pending_inserts() {
        // Regression: Stats used to read state without flushing the
        // batcher, silently excluding pending inserts from len/overhead/
        // coalescing (callers worked around it with a dummy Query).
        let cfg = CoordinatorConfig {
            // A huge size threshold + long deadline: nothing flushes on
            // its own, so the 50 values below stay pending until an op
            // barriers them.
            batch: BatchConfig { max_values: 1 << 20, max_delay: Duration::from_secs(3600) },
            ..test_cfg(4)
        };
        let c = Coordinator::start(cfg);
        c.call(Request::Insert { values: vec![2.5; 50] });
        let snap = c.call(Request::Stats).expect_stats();
        assert_eq!(snap.len, 50, "Stats must observe pending inserts");
        assert_eq!(snap.elements_inserted, 50);
        assert!(snap.batches >= 1, "the barrier flush must be recorded");
        assert!(snap.overhead_ratio().is_finite());
        c.shutdown();
    }

    #[test]
    fn validate_rejects_bad_geometry_with_typed_errors() {
        assert_eq!(
            CoordinatorConfig { shards: 0, ..test_cfg(4) }.validate(),
            Err(ConfigError::NoShards)
        );
        assert_eq!(
            CoordinatorConfig { blocks: 0, shards: 1, ..test_cfg(4) }.validate(),
            Err(ConfigError::NoBlocks)
        );
        // The old path silently dropped blocks (10 / 4 = 2 per shard →
        // 8 live blocks) and only tripped an assert at the first batch.
        let err = CoordinatorConfig { shards: 4, ..test_cfg(10) }.validate().unwrap_err();
        assert_eq!(err, ConfigError::UnevenBlocks { blocks: 10, shards: 4 });
        assert!(err.to_string().contains("2 remainder"), "{err}");
        assert!(Coordinator::try_start(CoordinatorConfig { shards: 4, ..test_cfg(10) }).is_err());
        // The epoch store cannot be promised more than the whole budget.
        let err = CoordinatorConfig {
            heap_capacity: Some(1024),
            epoch_heap: Some(2048),
            ..test_cfg(4)
        }
        .validate()
        .unwrap_err();
        assert_eq!(err, ConfigError::EpochHeapExceedsBudget { epoch_heap: 2048, total: 1024 });
        assert!(err.to_string().contains("epoch heap"), "{err}");
        // And a valid geometry still starts.
        let c = Coordinator::try_start(test_cfg(4)).expect("valid config");
        c.shutdown();
    }

    #[test]
    fn heap_carve_splits_epoch_store_from_shard_budgets() {
        let cfg = CoordinatorConfig {
            heap_capacity: Some(1000),
            epoch_heap: Some(300),
            ..test_cfg(4)
        };
        assert_eq!(cfg.heap_carve(), (300, 700));
        // Default: half the budget each way.
        let cfg = CoordinatorConfig { heap_capacity: Some(1000), ..test_cfg(4) };
        assert_eq!(cfg.heap_carve(), (500, 500));
        // epoch_heap == total is legal: a seal-only store with no
        // live-epoch growth headroom (every insert OOMs — failure
        // injection territory).
        let cfg = CoordinatorConfig {
            heap_capacity: Some(64),
            epoch_heap: Some(64),
            ..test_cfg(4)
        };
        assert!(cfg.validate().is_ok());
        assert_eq!(cfg.heap_carve(), (64, 0));
    }

    #[test]
    fn heap_budget_split_conserves_every_byte() {
        for (total, shards) in [(10u64, 3usize), (7, 7), (0, 2), (1 << 30, 6), (5, 8), (1, 1)] {
            let budgets = split_heap_budget(total, shards);
            assert_eq!(budgets.len(), shards);
            assert_eq!(budgets.iter().sum::<u64>(), total, "{total}B over {shards} shards");
            // Remainder lands one byte per shard on the first shards.
            let max = *budgets.iter().max().unwrap();
            let min = *budgets.iter().min().unwrap();
            assert!(max - min <= 1, "{budgets:?}");
            assert!(budgets.windows(2).all(|w| w[0] >= w[1]), "{budgets:?}");
        }
    }

    #[test]
    fn insert_critical_path_shrinks_with_shards() {
        // The tentpole invariant at unit scale: the same even insert
        // stream charged to 4 shards must report a smaller wall-model
        // time than 1 shard (concurrent sub-batches), while the device
        // total stays comparable (same work issued, different clock
        // model).
        let run = |shards: usize| {
            let c = Coordinator::start(sharded_cfg(16, shards));
            c.call(Request::Insert { values: vec![1.0; 1 << 14] });
            let snap = c.call(Request::Stats).expect_stats();
            c.shutdown();
            (snap.sim_insert_ms, snap.device_insert_ms)
        };
        let (sim1, dev1) = run(1);
        let (sim4, dev4) = run(4);
        assert!(
            sim4 < sim1,
            "4-shard critical path {sim4} ms must beat 1-shard {sim1} ms"
        );
        assert!(dev4 > sim4, "device total must exceed critical path on 4 shards");
        // Single shard: no parallelism, wall-model == device total.
        assert!((dev1 - sim1).abs() < 1e-9);
    }

    #[test]
    fn executor_thread_resolution_follows_the_field() {
        // Explicit values override everything (env-independent).
        assert!(!CoordinatorConfig { executor_threads: 1, ..sharded_cfg(8, 4) }.pooled_execution());
        assert!(CoordinatorConfig { executor_threads: 2, ..sharded_cfg(8, 4) }.pooled_execution());
        assert!(CoordinatorConfig { executor_threads: 2, ..test_cfg(4) }.pooled_execution(),
            "explicit pooling works even at one shard (mode-identity tests rely on it)");
        assert!(!CoordinatorConfig { executor_threads: 1, ..test_cfg(4) }.pooled_execution());
    }

    #[test]
    fn serial_and_pooled_executors_are_byte_identical() {
        // Unit-scale version of the property test: the same workload
        // through executor_threads = 1 (serial worker) and = 2 (the
        // work-stealing scheduler, 2 workers draining 4 shards' chunks)
        // must produce identical response payloads — checksums, lengths
        // AND simulated times (per-shard clocks advance by the same
        // charges in both modes).
        let run = |threads: usize| {
            let cfg = CoordinatorConfig { executor_threads: threads, ..sharded_cfg(8, 4) };
            let c = Coordinator::start(cfg);
            c.call(Request::Insert { values: (0..500).map(|i| i as f32).collect() });
            let worked = match c.call(Request::Work { calls: 2 }) {
                Response::Worked { sim_us, device_us, .. } => (sim_us, device_us),
                other => panic!("{other:?}"),
            };
            let sealed = c.call(Request::Seal).expect_sealed();
            c.call(Request::Insert { values: (500..700).map(|i| i as f32).collect() });
            let flat = match c.call(Request::Flatten) {
                Response::Flattened { len, sim_us, device_us, checksum } => {
                    (len, sim_us, device_us, checksum)
                }
                other => panic!("{other:?}"),
            };
            let q = c.call(Request::Query { index: 650 }).expect_value();
            let snap = c.call(Request::Stats).expect_stats();
            c.shutdown();
            (worked, sealed, flat, q, snap)
        };
        let (work_s, seal_s, flat_s, q_s, snap_s) = run(1);
        let (work_p, seal_p, flat_p, q_p, snap_p) = run(2);
        assert_eq!(work_s, work_p, "Work sim/device must match exactly");
        assert_eq!(seal_s, seal_p, "Sealed payload must match exactly");
        assert_eq!(flat_s, flat_p, "Flattened payload must match exactly");
        assert_eq!(q_s, q_p);
        assert_eq!(snap_s.executors, 1);
        assert_eq!(
            snap_p.executors, 2,
            "the scheduler runs exactly the configured worker count (decoupled from shards)"
        );
        assert_eq!(snap_s.len, snap_p.len);
        assert_eq!(snap_s.sealed_len, snap_p.sealed_len);
        assert_eq!(snap_s.heap_used_bytes, snap_p.heap_used_bytes);
        assert_eq!(snap_s.sim_insert_ms, snap_p.sim_insert_ms, "sim ledger identical across modes");
        // The measured ledger ran in both modes (it can't be compared for
        // equality — it is real time — but it must be populated).
        assert!(snap_s.wall_insert_ms > 0.0 && snap_p.wall_insert_ms > 0.0);
        assert!(snap_p.wall_flatten_ms > 0.0);
    }

    #[test]
    fn stats_expose_the_scheduler_ledger() {
        // Scheduled mode: the steal/park/chunk ledger is live and the
        // finish barrier (all chunks done + all workers parked) means a
        // post-op Stats always observes every park. Serial mode reports
        // a zeroed ledger — no scheduler exists.
        let run = |threads: usize| {
            let cfg = CoordinatorConfig { executor_threads: threads, ..sharded_cfg(8, 4) };
            let c = Coordinator::start(cfg);
            c.call(Request::Insert { values: (0..500).map(|i| i as f32).collect() });
            c.call(Request::Work { calls: 2 });
            c.call(Request::Flatten);
            let snap = c.call(Request::Stats).expect_stats();
            c.shutdown();
            snap
        };
        let pooled = run(2);
        assert!(pooled.chunks_executed > 0, "fan-outs must be accounted as chunks");
        assert!(pooled.parks >= 2, "both workers park at every finish barrier");
        let serial = run(1);
        assert_eq!(serial.chunks_executed, 0);
        assert_eq!(serial.steals, 0);
        assert_eq!(serial.parks, 0);
    }

    #[test]
    fn pooled_insert_falls_back_to_serial_prefix_semantics_on_tight_budget() {
        // A batch too big for the shard budgets must take the serial
        // fallback (stop at the first OOMing shard) even with the
        // scheduler enabled: the surviving prefix and error accounting must be
        // identical to executor_threads = 1.
        let run = |threads: usize| {
            let cfg = CoordinatorConfig {
                executor_threads: threads,
                heap_capacity: Some(4096),
                epoch_heap: Some(1024),
                ..sharded_cfg(4, 2)
            };
            let c = Coordinator::start(cfg);
            c.call(Request::Insert { values: (0..4000).map(|i| i as f32).collect() });
            let snap = c.call(Request::Stats).expect_stats();
            // Contents of the surviving prefix, via the flat view.
            let q0 = c.call(Request::Query { index: 0 }).expect_value();
            let q_last = c.call(Request::Query { index: snap.len.saturating_sub(1) }).expect_value();
            c.shutdown();
            (snap.len, snap.errors, snap.heap_used_bytes, q0, q_last)
        };
        let serial = run(1);
        let pooled = run(2);
        assert_eq!(serial, pooled, "OOM traces must be byte-identical across executor modes");
        assert!(serial.0 < 4000, "the tight budget must actually OOM");
        assert_eq!(serial.1, 1, "exactly one dispatch error");
    }

    #[test]
    fn repeated_seals_stay_within_compaction_threshold() {
        let cfg = CoordinatorConfig { compact_segments: 3, ..sharded_cfg(8, 2) };
        let c = Coordinator::start(cfg);
        let mut saw_at_threshold = false;
        for k in 0..10u32 {
            c.call(Request::Insert { values: vec![k as f32; 50] });
            match c.call(Request::Seal) {
                Response::Sealed { sealed_segments, sealed_len, .. } => {
                    assert!(
                        sealed_segments <= 3,
                        "seal {k}: {sealed_segments} segments > threshold"
                    );
                    saw_at_threshold |= sealed_segments == 3;
                    assert_eq!(sealed_len, 50 * (k as u64 + 1));
                }
                other => panic!("{other:?}"),
            }
        }
        assert!(saw_at_threshold, "threshold should be reached between compactions");
        let snap = c.call(Request::Stats).expect_stats();
        assert!(snap.compactions >= 2, "10 seals over threshold 3: {} compactions", snap.compactions);
        assert!(snap.sealed_segments <= 3);
        assert_eq!(snap.sealed_len, 500);
        // Reads resolve across merged segments.
        assert_eq!(c.call(Request::Query { index: 0 }).expect_value(), Some(0.0));
        assert_eq!(c.call(Request::Query { index: 499 }).expect_value(), Some(9.0));
        c.shutdown();
    }

    #[test]
    fn drop_without_shutdown_is_clean() {
        let c = Coordinator::start(test_cfg(2));
        c.call(Request::Insert { values: vec![1.0] });
        drop(c); // Drop impl joins the worker
    }

    #[test]
    fn seal_moves_data_to_flat_path_and_opens_fresh_epoch() {
        let c = Coordinator::start(sharded_cfg(8, 2));
        c.call(Request::Insert { values: (0..300).map(|i| i as f32).collect() });
        let (epoch, epoch_len, sealed_len) = match c.call(Request::Seal) {
            Response::Sealed { epoch, epoch_len, sealed_len, .. } => (epoch, epoch_len, sealed_len),
            other => panic!("{other:?}"),
        };
        assert_eq!(epoch, 1);
        assert_eq!(epoch_len, 300);
        assert_eq!(sealed_len, 300);
        // Sealed data reads back; epoch 1 inserts land after it.
        assert!(c.call(Request::Query { index: 0 }).expect_value().is_some());
        c.call(Request::Insert { values: vec![7.0; 10] });
        assert_eq!(c.call(Request::Query { index: 300 }).expect_value(), Some(7.0));
        let snap = match c.call(Request::Stats) {
            Response::Stats(s) => s,
            other => panic!("{other:?}"),
        };
        assert_eq!(snap.len, 310);
        assert_eq!(snap.sealed_len, 300);
        assert_eq!(snap.epoch, 1);
        assert_eq!(snap.seals, 1);
        assert_eq!(snap.shards, 2);
        assert_eq!(snap.per_shard_len.iter().sum::<u64>(), 10);
        assert_eq!(c.call(Request::Query { index: 310 }).expect_value(), None);
        c.shutdown();
    }

    #[test]
    fn work_updates_sealed_and_live_epochs_alike() {
        let cfg = sharded_cfg(4, 2);
        let iters = cfg.work_iters as f32;
        let c = Coordinator::start(cfg);
        c.call(Request::Insert { values: vec![1.0, 2.0, 3.0, 4.0] });
        c.call(Request::Seal);
        c.call(Request::Insert { values: vec![100.0, 200.0] });
        c.call(Request::Work { calls: 1 });
        // Sealed element and live element both advanced by one work call.
        assert_eq!(c.call(Request::Query { index: 0 }).expect_value(), Some(1.0 + iters));
        assert_eq!(c.call(Request::Query { index: 4 }).expect_value(), Some(100.0 + iters));
        c.shutdown();
    }

    #[test]
    fn flatten_spans_sealed_prefix_plus_live_epoch() {
        let c = Coordinator::start(sharded_cfg(4, 1));
        c.call(Request::Insert { values: (0..64).map(|i| i as f32).collect() });
        c.call(Request::Seal);
        c.call(Request::Insert { values: (64..80).map(|i| i as f32).collect() });
        match c.call(Request::Flatten) {
            Response::Flattened { len, .. } => assert_eq!(len, 80),
            other => panic!("{other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn clear_drops_sealed_epochs_too() {
        let c = Coordinator::start(sharded_cfg(4, 2));
        c.call(Request::Insert { values: vec![1.0; 50] });
        c.call(Request::Seal);
        c.call(Request::Insert { values: vec![2.0; 10] });
        c.call(Request::Clear);
        let snap = match c.call(Request::Stats) {
            Response::Stats(s) => s,
            other => panic!("{other:?}"),
        };
        assert_eq!(snap.len, 0);
        assert_eq!(snap.sealed_len, 0);
        assert_eq!(snap.sealed_bytes, 0, "Clear must release the epoch-owned store");
        assert_eq!(snap.heap_used_bytes, 0, "Clear must release every heap byte");
        assert_eq!(c.call(Request::Query { index: 0 }).expect_value(), None);
        c.shutdown();
    }

    #[test]
    fn seal_frees_shard_budgets_by_transferring_to_the_epoch_store() {
        // The tentpole invariant at unit scale: after a committed seal
        // the sealed bytes live in the epoch-owned heap, not the shard
        // heaps — old epochs cannot squat on live-epoch growth budgets.
        let c = Coordinator::start(sharded_cfg(4, 2));
        c.call(Request::Insert { values: vec![1.0; 200] });
        let before = c.call(Request::Stats).expect_stats();
        assert_eq!(before.sealed_bytes, 0);
        assert!(before.heap_used_bytes > 0);
        c.call(Request::Seal);
        let after = c.call(Request::Stats).expect_stats();
        assert_eq!(after.sealed_bytes, 200 * 4, "sealed bytes accounted to the epoch heap");
        assert_eq!(
            after.heap_used_bytes, after.sealed_bytes,
            "shard heaps fully released after commit (live epoch is empty)"
        );
        assert_eq!(after.allocated_bytes, after.heap_used_bytes, "ledger conserves every byte");
        c.shutdown();
    }

    #[test]
    fn aborted_seal_restores_every_shard_in_one_pass() {
        // Shard-side OOM: blocks=4 / shards=2 / fbs=16. 60 elements fill
        // the first buckets to 15/16 per block (128 B per shard, 32 B
        // free), so the flatten destination (30 × 4 B = 120 B) cannot be
        // reserved and the seal aborts. Every shard must come back
        // unsealed, byte-identical, and insertable.
        let cfg = CoordinatorConfig {
            heap_capacity: Some(320 + 1024),
            epoch_heap: Some(1024),
            ..sharded_cfg(4, 2)
        };
        let c = Coordinator::start(cfg);
        c.call(Request::Insert { values: (0..60).map(|i| i as f32).collect() });
        let before = c.call(Request::Stats).expect_stats();
        assert_eq!(before.heap_used_bytes, 256, "two shards × two full first buckets");
        for round in 1..=2u64 {
            match c.call(Request::Seal) {
                Response::Error(msg) => assert!(msg.contains("seal OOM"), "{msg}"),
                other => panic!("expected seal abort, got {other:?}"),
            }
            let after = c.call(Request::Stats).expect_stats();
            // VRAM restored byte-identically; nothing sealed; the epoch
            // counter never advanced; repeated aborts do not leak.
            assert_eq!(after.heap_used_bytes, before.heap_used_bytes, "round {round}");
            assert_eq!(after.sealed_len, 0);
            assert_eq!(after.sealed_bytes, 0);
            assert_eq!(after.epoch, 0);
            assert_eq!(after.len, 60);
            assert_eq!(after.errors, round);
        }
        // Contents untouched and every shard still insertable (the last
        // free slot of each first bucket takes one element without any
        // new allocation).
        assert_eq!(c.call(Request::Query { index: 0 }).expect_value(), Some(0.0));
        assert_eq!(c.call(Request::Query { index: 59 }).expect_value(), Some(59.0));
        c.call(Request::Insert { values: vec![99.0; 4] });
        let snap = c.call(Request::Stats).expect_stats();
        assert_eq!(snap.len, 64, "aborted seal must leave every shard insertable");
        assert_eq!(snap.errors, 2, "the post-abort insert fits without OOM");
        c.shutdown();
    }

    #[test]
    fn seal_admission_failure_aborts_after_every_shard_flattened() {
        // Epoch-store-side OOM: the shard heaps can hold their flatten
        // destinations (free 384 B each ≥ the 32-element dst), but the
        // 64-byte epoch store cannot adopt the 256 sealed bytes. Every
        // shard took the abort_seal path (destination freed + reopen) —
        // the single-pass abort with parts.len() == shards.
        let cfg = CoordinatorConfig {
            heap_capacity: Some(1024 + 64),
            epoch_heap: Some(64),
            ..sharded_cfg(4, 2)
        };
        let c = Coordinator::start(cfg);
        c.call(Request::Insert { values: (0..64).map(|i| i as f32).collect() });
        let before = c.call(Request::Stats).expect_stats();
        assert_eq!(before.heap_used_bytes, 256);
        match c.call(Request::Seal) {
            Response::Error(msg) => {
                assert!(msg.contains("epoch store"), "admission failure must say so: {msg}")
            }
            other => panic!("expected seal abort, got {other:?}"),
        }
        let after = c.call(Request::Stats).expect_stats();
        assert_eq!(after.heap_used_bytes, 256, "flatten destinations freed on abort");
        assert_eq!(after.sealed_len, 0);
        assert_eq!(after.len, 64);
        // Shards stay fully usable: growing into the second bucket still
        // fits the untouched shard budgets.
        c.call(Request::Insert { values: vec![7.0; 64] });
        let grown = c.call(Request::Stats).expect_stats();
        assert_eq!(grown.len, 128);
        assert_eq!(grown.errors, 1, "only the aborted seal errored");
        c.shutdown();
    }
}
