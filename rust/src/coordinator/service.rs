//! The coordinator service: a worker thread owning N independent GGArray
//! [`Shard`]s plus the sealed-epoch store, fed by an mpsc request
//! channel. Insert requests are routed globally (per [`router`]) across
//! the shards' combined block space, batched (per [`batcher`]), and
//! sliced per shard; Work/Flatten run through the PJRT runtime when AOT
//! artifacts are available and fall back to host compute when not (the
//! numerics are identical — the integration tests assert it).
//!
//! The two-phase lifecycle (paper §VI.D) is first-class: `Request::Seal`
//! drains in-flight batches, flattens every shard, concatenates the
//! results into one contiguous [`ShardedFlattened`] view held by the
//! [`EpochManager`], and opens a fresh insert epoch behind it. Reads and
//! work over the sealed prefix run at static-array (coalesced) cost; the
//! live epoch keeps paying GGArray costs until it, too, is sealed.
//!
//! No async runtime is available offline; the event loop is a plain
//! blocking channel with deadline-aware `recv_timeout`, which for an
//! in-process service is equivalent to (and simpler than) a tokio
//! single-worker runtime.

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::ggarray::flatten::{self, ShardedFlattened};
use crate::insertion::InsertionKind;
use crate::runtime::Executor;
use crate::sim::spec::DeviceSpec;
use crate::workload::{synth_f32, Step, WorkloadSpec};

use super::batcher::{BatchConfig, Batcher};
use super::metrics::Metrics;
use super::request::{checksum, Request, Response};
use super::router::{self, Policy};
use super::shard::{EpochManager, Shard, ShardConfig};

/// Service configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub device: DeviceSpec,
    /// Total LFVectors (thread blocks) across ALL shards; must divide
    /// evenly by `shards`. Keeping the total fixed while varying the
    /// shard count leaves the global data layout unchanged.
    pub blocks: usize,
    pub first_bucket_size: usize,
    pub insertion: InsertionKind,
    pub routing: Policy,
    pub batch: BatchConfig,
    /// Try to load AOT artifacts; fall back to host compute when absent.
    pub use_artifacts: bool,
    /// +1 iterations per work call (paper: 30).
    pub work_iters: u32,
    /// Simulated VRAM budget in bytes (None = the device's full memory),
    /// carved evenly into per-shard heap budgets.
    /// Used by failure-injection tests and multi-tenant scenarios.
    pub heap_capacity: Option<u64>,
    /// Independent GGArray shards, each owning `blocks / shards`
    /// consecutive blocks of the global block space.
    pub shards: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> CoordinatorConfig {
        CoordinatorConfig {
            device: DeviceSpec::a100(),
            blocks: 512,
            first_bucket_size: 1024,
            insertion: InsertionKind::WarpScan,
            routing: Policy::Even,
            batch: BatchConfig::default(),
            use_artifacts: true,
            work_iters: 30,
            heap_capacity: None,
            shards: 1,
        }
    }
}

enum Envelope {
    Call(Request, mpsc::Sender<Response>),
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Envelope>,
    worker: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the worker thread.
    pub fn start(cfg: CoordinatorConfig) -> Coordinator {
        assert!(cfg.shards > 0, "coordinator needs at least one shard");
        assert_eq!(
            cfg.blocks % cfg.shards,
            0,
            "blocks ({}) must divide evenly into shards ({})",
            cfg.blocks,
            cfg.shards
        );
        let (tx, rx) = mpsc::channel::<Envelope>();
        let worker = std::thread::Builder::new()
            .name("ggarray-coordinator".into())
            .spawn(move || Worker::new(cfg).run(rx))
            .expect("spawn coordinator worker");
        Coordinator { tx, worker: Some(worker) }
    }

    /// Synchronous call (delegates to a [`Client`] over the same
    /// channel).
    pub fn call(&self, req: Request) -> Response {
        self.client().call(req)
    }

    /// Fire-and-forget insert (no response wait) — throughput path.
    pub fn insert_nowait(&self, values: Vec<f32>) {
        self.client().insert_nowait(values);
    }

    /// A cloneable client handle for concurrent callers (each thread gets
    /// its own reply channel; the worker serialises requests).
    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone() }
    }

    /// Graceful stop.
    pub fn shutdown(mut self) {
        let _ = self.call(Request::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(h) = self.worker.take() {
            let (rtx, _r) = mpsc::channel();
            let _ = self.tx.send(Envelope::Call(Request::Shutdown, rtx));
            let _ = h.join();
        }
    }
}

/// Cloneable, `Send` handle to a running coordinator — hand one to each
/// client thread.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Envelope>,
}

impl Client {
    /// Synchronous call (same contract as [`Coordinator::call`]).
    pub fn call(&self, req: Request) -> Response {
        let (rtx, rrx) = mpsc::channel();
        if self.tx.send(Envelope::Call(req, rtx)).is_err() {
            return Response::Error("coordinator stopped".into());
        }
        rrx.recv().unwrap_or_else(|_| Response::Error("coordinator dropped reply".into()))
    }

    /// Fire-and-forget insert (no response wait) — throughput path.
    pub fn insert_nowait(&self, values: Vec<f32>) {
        let (rtx, _rrx) = mpsc::channel();
        let _ = self.tx.send(Envelope::Call(Request::Insert { values }, rtx));
    }
}

struct Worker {
    cfg: CoordinatorConfig,
    shards: Vec<Shard>,
    blocks_per_shard: usize,
    epochs: EpochManager,
    batcher: Batcher,
    metrics: Metrics,
    executor: Option<Executor>,
    batch_seq: u64,
}

impl Worker {
    fn new(cfg: CoordinatorConfig) -> Worker {
        let blocks_per_shard = cfg.blocks / cfg.shards;
        let executor = if cfg.use_artifacts {
            match Executor::from_default_dir() {
                Ok(e) => Some(e),
                Err(err) => {
                    eprintln!("[coordinator] artifacts unavailable, using host fallback: {err}");
                    None
                }
            }
        } else {
            None
        };
        // Each shard's heap budget is carved from the shared device (or
        // from the configured budget).
        let total_heap = cfg.heap_capacity.unwrap_or_else(|| cfg.device.memory_bytes());
        let per_shard_heap = (total_heap / cfg.shards as u64).max(1);
        let shards: Vec<Shard> = (0..cfg.shards)
            .map(|id| {
                Shard::new(ShardConfig {
                    id,
                    blocks: blocks_per_shard,
                    first_bucket_size: cfg.first_bucket_size,
                    insertion: cfg.insertion,
                    device: cfg.device.clone(),
                    heap_bytes: per_shard_heap,
                })
            })
            .collect();
        Worker {
            shards,
            blocks_per_shard,
            epochs: EpochManager::new(cfg.device.clone()),
            batcher: Batcher::new(cfg.batch.clone()),
            metrics: Metrics::new(),
            executor,
            batch_seq: 0,
            cfg,
        }
    }

    fn run(mut self, rx: mpsc::Receiver<Envelope>) {
        loop {
            let wait = self
                .batcher
                .time_to_deadline()
                .unwrap_or(Duration::from_millis(50))
                .max(Duration::from_micros(100));
            match rx.recv_timeout(wait) {
                Ok(Envelope::Call(req, reply)) => {
                    let t0 = Instant::now();
                    let stop = matches!(req, Request::Shutdown);
                    let resp = self.handle(req);
                    self.metrics.observe_latency_us(t0.elapsed().as_secs_f64() * 1e6);
                    let _ = reply.send(resp);
                    if stop {
                        return;
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if let Some(batch) = self.batcher.poll_deadline() {
                        self.apply_batch(batch.values, batch.requests);
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }
    }

    // ---------- aggregate views ----------

    /// Elements in the live (unsealed) epoch across all shards.
    fn live_len(&self) -> u64 {
        self.shards.iter().map(|s| s.len() as u64).sum()
    }

    /// Total elements: sealed prefix + live epoch.
    fn total_len(&self) -> u64 {
        self.epochs.sealed_len() + self.live_len()
    }

    /// Total simulated time across shard clocks and the flat-path clock.
    fn sim_total_us(&self) -> f64 {
        self.shards.iter().map(|s| s.sim_now_us()).sum::<f64>() + self.epochs.now_us()
    }

    /// Per-block sizes over the global (all-shard) block space.
    fn global_sizes(&self) -> Vec<u64> {
        let mut sizes = Vec::with_capacity(self.cfg.blocks);
        for shard in &self.shards {
            sizes.extend(shard.block_sizes());
        }
        sizes
    }

    /// Read a global index: the sealed prefix first, then the live epoch
    /// in shard order.
    fn read_global(&self, i: u64) -> Option<f32> {
        let sealed = self.epochs.sealed_len();
        if i < sealed {
            return self.epochs.get(i);
        }
        let mut j = i - sealed;
        for shard in &self.shards {
            let n = shard.len() as u64;
            if j < n {
                return shard.get(j);
            }
            j -= n;
        }
        None
    }

    /// Flush pending inserts before any op that observes array state.
    fn barrier(&mut self) {
        if let Some(batch) = self.batcher.flush() {
            self.apply_batch(batch.values, batch.requests);
        }
    }

    fn apply_batch(&mut self, values: Vec<f32>, requests: usize) {
        if values.is_empty() {
            return;
        }
        let sizes = self.global_sizes();
        let counts = router::route(self.cfg.routing, &sizes, values.len(), self.batch_seq);
        self.batch_seq += 1;
        // Cross-check the per-block offsets against the AOT scan kernel
        // when available (the real index-assignment path).
        if let Some(exec) = &self.executor {
            let counts_i32: Vec<i32> = counts.iter().map(|&c| c as i32).collect();
            if let Ok((offsets, total)) = exec.scan_offsets("scan_warp_i32_", &counts_i32) {
                debug_assert_eq!(total as usize, values.len());
                let expect: Vec<i64> = {
                    let (o, _) = crate::insertion::assign_indices(0, &counts.iter().map(|&c| c as u32).collect::<Vec<_>>());
                    o.iter().map(|&x| x as i64).collect()
                };
                debug_assert_eq!(offsets, expect, "AOT scan disagrees with host oracle");
                self.metrics.pjrt_executions += 1;
            }
        }
        // Slice the global decision per shard: shard k owns blocks
        // [k·bps, (k+1)·bps) and its values are contiguous in the batch.
        let mut applied = 0u64;
        for (shard, (offset, sub)) in
            self.shards.iter_mut().zip(router::split_for_shards(&counts, self.blocks_per_shard))
        {
            let take: usize = sub.iter().sum();
            let out = shard.apply_counts(sub, &values[offset..offset + take]);
            self.metrics.sim_insert_us += out.sim_us;
            applied += out.applied as u64;
            if let Some(e) = out.error {
                eprintln!("[coordinator] simulated OOM during insert on shard {}: {e}", shard.id());
                // No rollback — elements placed before the OOM stay
                // visible, matching device semantics; the shard left its
                // index consistent.
                self.metrics.errors += 1;
            }
        }
        self.metrics.batches += 1;
        self.metrics.elements_inserted += applied;
        let _ = requests;
    }

    fn handle(&mut self, req: Request) -> Response {
        match req {
            Request::Insert { values } => {
                self.metrics.inserts_requested += 1;
                let count = values.len() as u64;
                if let Some(batch) = self.batcher.push(&values) {
                    self.apply_batch(batch.values, batch.requests);
                }
                Response::Inserted {
                    count,
                    sim_us: 0.0,
                    len: self.total_len() + self.batcher.pending_len() as u64,
                }
            }
            Request::Work { calls } => {
                self.barrier();
                let sim0 = self.sim_total_us();
                let mut pjrt = 0u64;
                for _ in 0..calls {
                    // Real numeric update on the live epoch (PJRT when
                    // possible), then the modeled rw_b cost per shard.
                    pjrt += self.one_work_pass();
                    for shard in &mut self.shards {
                        shard.charge_rw_block(self.cfg.work_iters as f64);
                    }
                    // Sealed prefix: real update + static-array cost —
                    // the fast path the two-phase pattern buys.
                    self.epochs.work(self.cfg.work_iters);
                }
                self.metrics.work_calls += calls as u64;
                self.metrics.pjrt_executions += pjrt;
                let sim_us = self.sim_total_us() - sim0;
                self.metrics.sim_work_us += sim_us;
                Response::Worked { calls, sim_us, pjrt_executions: pjrt }
            }
            Request::Flatten => {
                self.barrier();
                let sim0 = self.sim_total_us();
                // Sealed prefix is already flat; append a non-destructive
                // flatten of the live epoch, shard by shard.
                let mut data: Vec<f32> = Vec::with_capacity(self.total_len() as usize);
                for segment in self.epochs.segments() {
                    data.extend_from_slice(segment);
                }
                let mut failed = None;
                for shard in &mut self.shards {
                    match shard.flatten_temp() {
                        Ok(f) => data.extend_from_slice(&f.data),
                        Err(e) => {
                            failed = Some(e);
                            break;
                        }
                    }
                }
                if let Some(e) = failed {
                    self.metrics.errors += 1;
                    return Response::Error(format!("flatten OOM: {e}"));
                }
                self.metrics.flattens += 1;
                let sim_us = self.sim_total_us() - sim0;
                self.metrics.sim_flatten_us += sim_us;
                Response::Flattened { len: data.len() as u64, sim_us, checksum: checksum(&data) }
            }
            Request::Seal => {
                self.barrier();
                let sim0 = self.sim_total_us();
                // Two-phase commit across shards: flatten everything
                // first, commit VRAM residency only if every shard
                // succeeded, otherwise release the fresh destinations
                // and reopen with contents untouched.
                let mut parts = Vec::with_capacity(self.shards.len());
                let mut failed = None;
                for shard in &mut self.shards {
                    match shard.seal_flatten() {
                        Ok(f) => parts.push(f),
                        Err(e) => {
                            failed = Some(e);
                            break;
                        }
                    }
                }
                if let Some(e) = failed {
                    for (shard, mut part) in self.shards.iter_mut().zip(parts) {
                        shard.abort_seal(part.alloc.take());
                    }
                    // Shards past the failure point never flattened —
                    // just reopen them (zip stopped at `parts`' length,
                    // so handle the tail, failure shard included).
                    for shard in &mut self.shards {
                        shard.reopen();
                    }
                    self.metrics.errors += 1;
                    return Response::Error(format!("seal OOM: {e}"));
                }
                for (shard, part) in self.shards.iter_mut().zip(&mut parts) {
                    shard.commit_seal(part.alloc.take());
                }
                let flat: ShardedFlattened<f32> = flatten::concat(parts);
                let epoch_len = flat.len() as u64;
                let sum = checksum(&flat.data);
                let epoch = self.epochs.absorb(flat);
                self.metrics.seals += 1;
                let sim_us = self.sim_total_us() - sim0;
                self.metrics.sim_flatten_us += sim_us;
                Response::Sealed {
                    epoch,
                    epoch_len,
                    sealed_len: self.epochs.sealed_len(),
                    sim_us,
                    checksum: sum,
                }
            }
            Request::Query { index } => {
                self.barrier();
                self.metrics.queries += 1;
                Response::Value(self.read_global(index))
            }
            Request::Stats => {
                let len = self.total_len();
                let capacity = self.shards.iter().map(|s| s.capacity() as u64).sum::<u64>()
                    + self.epochs.sealed_len();
                let allocated = self.shards.iter().map(|s| s.allocated_bytes()).sum::<u64>()
                    + self.epochs.sealed_len() * 4;
                let snap = self.metrics.snapshot(len, capacity, allocated).with_sharding(
                    self.shards.len(),
                    self.epochs.seq(),
                    self.epochs.sealed_len(),
                    self.shards.iter().map(|s| s.len() as u64).collect(),
                );
                Response::Stats(snap)
            }
            Request::Clear => {
                // Discard pending inserts too: Clear means "empty now".
                let _ = self.batcher.flush();
                for shard in &mut self.shards {
                    shard.reset();
                }
                self.epochs.reset();
                Response::Cleared
            }
            Request::Shutdown => {
                self.barrier();
                Response::ShuttingDown
            }
        }
    }

    /// Apply the real +1×`work_iters` numeric update to the live epoch,
    /// through the AOT PJRT kernels when possible. Returns PJRT
    /// executions done.
    fn one_work_pass(&mut self) -> u64 {
        let exec = self.executor.as_ref();
        let iters = self.cfg.work_iters;
        let mut pjrt = 0u64;
        for shard in &mut self.shards {
            pjrt += shard.work_pass(exec, iters);
        }
        pjrt
    }
}

// ---------------------------------------------------------------------
// Workload driver
// ---------------------------------------------------------------------

/// Summary of driving a [`WorkloadSpec`] through a coordinator.
#[derive(Debug, Clone, Default)]
pub struct WorkloadRun {
    /// Total elements submitted.
    pub inserted: u64,
    /// Checksum of each sealed epoch, in seal order.
    pub seal_checksums: Vec<u64>,
    /// Checksum of each full-flatten snapshot, in order.
    pub flatten_checksums: Vec<u64>,
    /// Simulated µs across all Work steps.
    pub work_sim_us: f64,
    /// Simulated µs across all Seal steps.
    pub seal_sim_us: f64,
}

/// Drive a workload trace through the service. `Insert` steps synthesise
/// deterministic f32 values in exactly `chunk`-sized requests, so batch
/// boundaries — and therefore global routing decisions — are reproducible
/// across runs and shard counts (pair with `BatchConfig::max_values ==
/// chunk` for fully deterministic flushes). Panics on service errors:
/// this is a test/experiment driver, not production plumbing.
pub fn drive_workload(c: &Coordinator, w: &WorkloadSpec, chunk: usize) -> WorkloadRun {
    assert!(chunk > 0);
    let mut run = WorkloadRun::default();
    let mut counter = 0u64;
    for step in &w.steps {
        match step {
            Step::Insert(n) => {
                let mut sent = 0u64;
                while sent < *n {
                    let take = chunk.min((*n - sent) as usize);
                    let values: Vec<f32> =
                        (0..take).map(|i| synth_f32(counter + i as u64)).collect();
                    match c.call(Request::Insert { values }) {
                        Response::Inserted { .. } => {}
                        other => panic!("insert failed: {other:?}"),
                    }
                    counter += take as u64;
                    sent += take as u64;
                }
                run.inserted = counter;
            }
            Step::Work(calls) => match c.call(Request::Work { calls: *calls }) {
                Response::Worked { sim_us, .. } => run.work_sim_us += sim_us,
                other => panic!("work failed: {other:?}"),
            },
            Step::Flatten => match c.call(Request::Flatten) {
                Response::Flattened { checksum, .. } => run.flatten_checksums.push(checksum),
                other => panic!("flatten failed: {other:?}"),
            },
            Step::Seal => match c.call(Request::Seal) {
                Response::Sealed { checksum, sim_us, .. } => {
                    run.seal_checksums.push(checksum);
                    run.seal_sim_us += sim_us;
                }
                other => panic!("seal failed: {other:?}"),
            },
        }
    }
    run
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cfg(blocks: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            blocks,
            first_bucket_size: 16,
            use_artifacts: false, // unit tests must not depend on `make artifacts`
            batch: BatchConfig { max_values: 64, max_delay: Duration::from_millis(1) },
            ..CoordinatorConfig::default()
        }
    }

    fn sharded_cfg(blocks: usize, shards: usize) -> CoordinatorConfig {
        CoordinatorConfig { shards, ..test_cfg(blocks) }
    }

    #[test]
    fn insert_query_roundtrip() {
        let c = Coordinator::start(test_cfg(4));
        c.call(Request::Insert { values: (0..100).map(|i| i as f32).collect() });
        // Query barriers pending batches, so this is totally ordered.
        let v = c.call(Request::Query { index: 0 }).expect_value();
        assert_eq!(v, Some(0.0));
        let v = c.call(Request::Query { index: 99 }).expect_value();
        assert!(v.is_some());
        let v = c.call(Request::Query { index: 100 }).expect_value();
        assert_eq!(v, None);
        c.shutdown();
    }

    #[test]
    fn work_applies_numeric_update() {
        let cfg = test_cfg(2);
        let iters = cfg.work_iters as f32;
        let c = Coordinator::start(cfg);
        c.call(Request::Insert { values: vec![1.0, 2.0, 3.0] });
        c.call(Request::Work { calls: 2 });
        assert_eq!(c.call(Request::Query { index: 0 }).expect_value(), Some(1.0 + 2.0 * iters));
        assert_eq!(c.call(Request::Query { index: 2 }).expect_value(), Some(3.0 + 2.0 * iters));
        c.shutdown();
    }

    #[test]
    fn flatten_checksum_stable() {
        let c = Coordinator::start(test_cfg(4));
        c.call(Request::Insert { values: (0..500).map(|i| i as f32).collect() });
        let a = match c.call(Request::Flatten) {
            Response::Flattened { checksum, len, .. } => {
                assert_eq!(len, 500);
                checksum
            }
            other => panic!("{other:?}"),
        };
        let b = match c.call(Request::Flatten) {
            Response::Flattened { checksum, .. } => checksum,
            other => panic!("{other:?}"),
        };
        assert_eq!(a, b);
        c.shutdown();
    }

    #[test]
    fn batching_coalesces_small_inserts() {
        let c = Coordinator::start(test_cfg(4));
        for i in 0..200 {
            c.call(Request::Insert { values: vec![i as f32] });
        }
        // Barrier via query, then inspect stats.
        let _ = c.call(Request::Query { index: 0 });
        let snap = match c.call(Request::Stats) {
            Response::Stats(s) => s,
            other => panic!("{other:?}"),
        };
        assert_eq!(snap.elements_inserted, 200);
        assert!(snap.batches < 200, "batching should coalesce: {} batches", snap.batches);
        assert!(snap.coalescing() > 1.5, "coalescing {:.2}", snap.coalescing());
        assert_eq!(snap.len, 200);
        c.shutdown();
    }

    #[test]
    fn stats_overhead_bounded() {
        let c = Coordinator::start(test_cfg(8));
        c.call(Request::Insert { values: vec![1.0; 10_000] });
        let _ = c.call(Request::Query { index: 0 });
        let snap = match c.call(Request::Stats) {
            Response::Stats(s) => s,
            other => panic!("{other:?}"),
        };
        assert!(snap.overhead_ratio() < 2.3, "overhead {:.2}", snap.overhead_ratio());
        c.shutdown();
    }

    #[test]
    fn drop_without_shutdown_is_clean() {
        let c = Coordinator::start(test_cfg(2));
        c.call(Request::Insert { values: vec![1.0] });
        drop(c); // Drop impl joins the worker
    }

    #[test]
    fn seal_moves_data_to_flat_path_and_opens_fresh_epoch() {
        let c = Coordinator::start(sharded_cfg(8, 2));
        c.call(Request::Insert { values: (0..300).map(|i| i as f32).collect() });
        let (epoch, epoch_len, sealed_len) = match c.call(Request::Seal) {
            Response::Sealed { epoch, epoch_len, sealed_len, .. } => (epoch, epoch_len, sealed_len),
            other => panic!("{other:?}"),
        };
        assert_eq!(epoch, 1);
        assert_eq!(epoch_len, 300);
        assert_eq!(sealed_len, 300);
        // Sealed data reads back; epoch 1 inserts land after it.
        assert!(c.call(Request::Query { index: 0 }).expect_value().is_some());
        c.call(Request::Insert { values: vec![7.0; 10] });
        // Query barriers the pending batch before Stats observes state.
        assert_eq!(c.call(Request::Query { index: 300 }).expect_value(), Some(7.0));
        let snap = match c.call(Request::Stats) {
            Response::Stats(s) => s,
            other => panic!("{other:?}"),
        };
        assert_eq!(snap.len, 310);
        assert_eq!(snap.sealed_len, 300);
        assert_eq!(snap.epoch, 1);
        assert_eq!(snap.seals, 1);
        assert_eq!(snap.shards, 2);
        assert_eq!(snap.per_shard_len.iter().sum::<u64>(), 10);
        assert_eq!(c.call(Request::Query { index: 310 }).expect_value(), None);
        c.shutdown();
    }

    #[test]
    fn work_updates_sealed_and_live_epochs_alike() {
        let cfg = sharded_cfg(4, 2);
        let iters = cfg.work_iters as f32;
        let c = Coordinator::start(cfg);
        c.call(Request::Insert { values: vec![1.0, 2.0, 3.0, 4.0] });
        c.call(Request::Seal);
        c.call(Request::Insert { values: vec![100.0, 200.0] });
        c.call(Request::Work { calls: 1 });
        // Sealed element and live element both advanced by one work call.
        assert_eq!(c.call(Request::Query { index: 0 }).expect_value(), Some(1.0 + iters));
        assert_eq!(c.call(Request::Query { index: 4 }).expect_value(), Some(100.0 + iters));
        c.shutdown();
    }

    #[test]
    fn flatten_spans_sealed_prefix_plus_live_epoch() {
        let c = Coordinator::start(sharded_cfg(4, 1));
        c.call(Request::Insert { values: (0..64).map(|i| i as f32).collect() });
        c.call(Request::Seal);
        c.call(Request::Insert { values: (64..80).map(|i| i as f32).collect() });
        match c.call(Request::Flatten) {
            Response::Flattened { len, .. } => assert_eq!(len, 80),
            other => panic!("{other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn clear_drops_sealed_epochs_too() {
        let c = Coordinator::start(sharded_cfg(4, 2));
        c.call(Request::Insert { values: vec![1.0; 50] });
        c.call(Request::Seal);
        c.call(Request::Insert { values: vec![2.0; 10] });
        c.call(Request::Clear);
        let snap = match c.call(Request::Stats) {
            Response::Stats(s) => s,
            other => panic!("{other:?}"),
        };
        assert_eq!(snap.len, 0);
        assert_eq!(snap.sealed_len, 0);
        assert_eq!(c.call(Request::Query { index: 0 }).expect_value(), None);
        c.shutdown();
    }
}
