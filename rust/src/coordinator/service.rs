//! The coordinator service: a worker thread owning the GGArray, fed by an
//! mpsc request channel. Insert requests are routed (per [`router`]) and
//! batched (per [`batcher`]); Work/Flatten run through the PJRT runtime
//! when AOT artifacts are available and fall back to host compute when
//! not (the numerics are identical — the integration tests assert it).
//!
//! No async runtime is available offline; the event loop is a plain
//! blocking channel with deadline-aware `recv_timeout`, which for an
//! in-process service is equivalent to (and simpler than) a tokio
//! single-worker runtime.

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::ggarray::array::{GgArray, GgConfig};
use crate::ggarray::flatten;
use crate::insertion::InsertionKind;
use crate::runtime::Executor;
use crate::sim::spec::DeviceSpec;

use super::batcher::{BatchConfig, Batcher};
use super::metrics::Metrics;
use super::request::{checksum, Request, Response};
use super::router::{self, Policy};

/// Service configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub device: DeviceSpec,
    pub blocks: usize,
    pub first_bucket_size: usize,
    pub insertion: InsertionKind,
    pub routing: Policy,
    pub batch: BatchConfig,
    /// Try to load AOT artifacts; fall back to host compute when absent.
    pub use_artifacts: bool,
    /// +1 iterations per work call (paper: 30).
    pub work_iters: u32,
    /// Simulated VRAM budget in bytes (None = the device's full memory).
    /// Used by failure-injection tests and multi-tenant scenarios.
    pub heap_capacity: Option<u64>,
}

impl Default for CoordinatorConfig {
    fn default() -> CoordinatorConfig {
        CoordinatorConfig {
            device: DeviceSpec::a100(),
            blocks: 512,
            first_bucket_size: 1024,
            insertion: InsertionKind::WarpScan,
            routing: Policy::Even,
            batch: BatchConfig::default(),
            use_artifacts: true,
            work_iters: 30,
            heap_capacity: None,
        }
    }
}

enum Envelope {
    Call(Request, mpsc::Sender<Response>),
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Envelope>,
    worker: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the worker thread.
    pub fn start(cfg: CoordinatorConfig) -> Coordinator {
        let (tx, rx) = mpsc::channel::<Envelope>();
        let worker = std::thread::Builder::new()
            .name("ggarray-coordinator".into())
            .spawn(move || Worker::new(cfg).run(rx))
            .expect("spawn coordinator worker");
        Coordinator { tx, worker: Some(worker) }
    }

    /// Synchronous call.
    pub fn call(&self, req: Request) -> Response {
        let (rtx, rrx) = mpsc::channel();
        if self.tx.send(Envelope::Call(req, rtx)).is_err() {
            return Response::Error("coordinator stopped".into());
        }
        rrx.recv().unwrap_or_else(|_| Response::Error("coordinator dropped reply".into()))
    }

    /// Fire-and-forget insert (no response wait) — throughput path.
    pub fn insert_nowait(&self, values: Vec<f32>) {
        let (rtx, _rrx) = mpsc::channel();
        let _ = self.tx.send(Envelope::Call(Request::Insert { values }, rtx));
    }

    /// A cloneable client handle for concurrent callers (each thread gets
    /// its own reply channel; the worker serialises requests).
    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone() }
    }

    /// Graceful stop.
    pub fn shutdown(mut self) {
        let _ = self.call(Request::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(h) = self.worker.take() {
            let (rtx, _r) = mpsc::channel();
            let _ = self.tx.send(Envelope::Call(Request::Shutdown, rtx));
            let _ = h.join();
        }
    }
}

/// Cloneable, `Send` handle to a running coordinator — hand one to each
/// client thread.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Envelope>,
}

impl Client {
    /// Synchronous call (same contract as [`Coordinator::call`]).
    pub fn call(&self, req: Request) -> Response {
        let (rtx, rrx) = mpsc::channel();
        if self.tx.send(Envelope::Call(req, rtx)).is_err() {
            return Response::Error("coordinator stopped".into());
        }
        rrx.recv().unwrap_or_else(|_| Response::Error("coordinator dropped reply".into()))
    }
}

struct Worker {
    cfg: CoordinatorConfig,
    gg: GgArray<f32>,
    batcher: Batcher,
    metrics: Metrics,
    executor: Option<Executor>,
    batch_seq: u64,
}

impl Worker {
    fn new(cfg: CoordinatorConfig) -> Worker {
        let gg_cfg = GgConfig {
            num_blocks: cfg.blocks,
            threads_per_block: 1024,
            first_bucket_size: cfg.first_bucket_size,
            insertion: cfg.insertion,
        };
        let executor = if cfg.use_artifacts {
            match Executor::from_default_dir() {
                Ok(e) => Some(e),
                Err(err) => {
                    eprintln!("[coordinator] artifacts unavailable, using host fallback: {err}");
                    None
                }
            }
        } else {
            None
        };
        let gg = match cfg.heap_capacity {
            Some(cap) => GgArray::with_heap(
                gg_cfg,
                cfg.device.clone(),
                crate::sim::memory::VramHeap::with_capacity(cfg.device.clone(), cap),
            ),
            None => GgArray::new(gg_cfg, cfg.device.clone()),
        };
        Worker {
            gg,
            batcher: Batcher::new(cfg.batch.clone()),
            metrics: Metrics::new(),
            executor,
            batch_seq: 0,
            cfg,
        }
    }

    fn run(mut self, rx: mpsc::Receiver<Envelope>) {
        loop {
            let wait = self
                .batcher
                .time_to_deadline()
                .unwrap_or(Duration::from_millis(50))
                .max(Duration::from_micros(100));
            match rx.recv_timeout(wait) {
                Ok(Envelope::Call(req, reply)) => {
                    let t0 = Instant::now();
                    let stop = matches!(req, Request::Shutdown);
                    let resp = self.handle(req);
                    self.metrics.observe_latency_us(t0.elapsed().as_secs_f64() * 1e6);
                    let _ = reply.send(resp);
                    if stop {
                        return;
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if let Some(batch) = self.batcher.poll_deadline() {
                        self.apply_batch(batch.values, batch.requests);
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }
    }

    /// Flush pending inserts before any op that observes array state.
    fn barrier(&mut self) {
        if let Some(batch) = self.batcher.flush() {
            self.apply_batch(batch.values, batch.requests);
        }
    }

    fn apply_batch(&mut self, values: Vec<f32>, requests: usize) {
        let sizes = self.gg.block_sizes();
        let counts = router::route(self.cfg.routing, &sizes, values.len(), self.batch_seq);
        self.batch_seq += 1;
        // Cross-check the per-block offsets against the AOT scan kernel
        // when available (the real index-assignment path).
        if let Some(exec) = &self.executor {
            let counts_i32: Vec<i32> = counts.iter().map(|&c| c as i32).collect();
            if let Ok((offsets, total)) = exec.scan_offsets("scan_warp_i32_", &counts_i32) {
                debug_assert_eq!(total as usize, values.len());
                let expect: Vec<i64> = {
                    let (o, _) = crate::insertion::assign_indices(0, &counts.iter().map(|&c| c as u32).collect::<Vec<_>>());
                    o.iter().map(|&x| x as i64).collect()
                };
                debug_assert_eq!(offsets, expect, "AOT scan disagrees with host oracle");
                self.metrics.pjrt_executions += 1;
            }
        }
        let sim0 = self.gg.clock().now_us();
        let mut off = 0usize;
        for (b, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if let Err(e) = self.gg.push_bulk_to_block(b, &values[off..off + c]) {
                eprintln!("[coordinator] simulated OOM during insert: {e}");
                self.metrics.errors += 1;
                // Keep the index consistent with whatever landed before
                // the OOM (no rollback — matches device semantics where
                // earlier blocks' writes are already visible).
                self.gg.rebuild_index_charged();
                self.metrics.elements_inserted += off as u64;
                return;
            }
            off += c;
        }
        // Charge the modeled insertion kernel + index rebuild.
        let shape = crate::insertion::InsertShape {
            threads: values.len().max(self.gg.len()) as u64,
            inserts: values.len() as u64,
            elem_bytes: 4,
            blocks: self.cfg.blocks as u64,
            threads_per_block: 1024,
            counters: self.cfg.blocks as u64,
            write_eff: self.cfg.device.cost.ggarray_insert_eff,
        };
        let profile = crate::insertion::profile(&self.cfg.device, self.cfg.insertion, &shape);
        {
            let (_, _, clock, spec, _, _) = self.gg.parts_mut();
            crate::sim::kernel::launch(spec, clock, &profile);
        }
        self.gg.rebuild_index_charged();
        self.metrics.sim_insert_us += self.gg.clock().now_us() - sim0;
        self.metrics.batches += 1;
        self.metrics.elements_inserted += values.len() as u64;
        let _ = requests;
    }

    fn handle(&mut self, req: Request) -> Response {
        match req {
            Request::Insert { values } => {
                self.metrics.inserts_requested += 1;
                let count = values.len() as u64;
                if let Some(batch) = self.batcher.push(&values) {
                    self.apply_batch(batch.values, batch.requests);
                }
                Response::Inserted { count, sim_us: 0.0, len: self.gg.len() as u64 + self.batcher.pending_len() as u64 }
            }
            Request::Work { calls } => {
                self.barrier();
                let sim0 = self.gg.clock().now_us();
                let mut pjrt = 0u64;
                for _ in 0..calls {
                    pjrt += self.one_work_pass();
                    let _ = self.gg.read_write_block(self.cfg.work_iters as f64, |_| {});
                }
                self.metrics.work_calls += calls as u64;
                self.metrics.pjrt_executions += pjrt;
                let sim_us = self.gg.clock().now_us() - sim0;
                self.metrics.sim_work_us += sim_us;
                Response::Worked { calls, sim_us, pjrt_executions: pjrt }
            }
            Request::Flatten => {
                self.barrier();
                let sim0 = self.gg.clock().now_us();
                match flatten::flatten(&mut self.gg) {
                    Ok(flat) => {
                        self.metrics.flattens += 1;
                        let sim_us = self.gg.clock().now_us() - sim0;
                        self.metrics.sim_flatten_us += sim_us;
                        Response::Flattened { len: flat.data.len() as u64, sim_us, checksum: checksum(&flat.data) }
                    }
                    Err(e) => {
                        self.metrics.errors += 1;
                        Response::Error(format!("flatten OOM: {e}"))
                    }
                }
            }
            Request::Query { index } => {
                self.barrier();
                self.metrics.queries += 1;
                Response::Value(self.gg.get(index))
            }
            Request::Stats => {
                let snap = self.metrics.snapshot(
                    self.gg.len() as u64,
                    self.gg.capacity() as u64,
                    self.gg.allocated_bytes(),
                );
                Response::Stats(snap)
            }
            Request::Clear => {
                // Discard pending inserts too: Clear means "empty now".
                let _ = self.batcher.flush();
                self.gg.clear();
                self.gg.rebuild_index_charged();
                Response::Cleared
            }
            Request::Shutdown => {
                self.barrier();
                Response::ShuttingDown
            }
        }
    }

    /// Apply the real +1×`work_iters` numeric update, through the AOT
    /// PJRT kernel when possible. Returns PJRT executions done.
    fn one_work_pass(&mut self) -> u64 {
        let n = self.gg.len();
        if n == 0 {
            return 0;
        }
        if let Some(exec) = &self.executor {
            // Flatten (host copy), run through the artifact family in
            // chunks, write back.
            let data = self.gg.to_vec();
            if let Ok(name) = exec.pick_chunking("work_f32_", data.len()) {
                let spec_cap = exec.manifest().get(&name).map(|s| s.inputs[0].elements()).unwrap_or(0);
                if spec_cap > 0 {
                    let mut out = Vec::with_capacity(data.len());
                    let mut execs = 0u64;
                    let mut ok = true;
                    for chunk in data.chunks(spec_cap) {
                        match exec.run_f32(&name, &[chunk], chunk.len()) {
                            Ok(mut r) => {
                                out.extend(r.swap_remove(0));
                                execs += 1;
                            }
                            Err(e) => {
                                eprintln!("[coordinator] PJRT work failed, host fallback: {e}");
                                ok = false;
                                break;
                            }
                        }
                    }
                    if ok {
                        self.gg.overwrite_from(&out);
                        return execs;
                    }
                }
            }
        }
        // Host fallback: identical numerics (30 sequential f32 adds, like
        // the kernel), applied in place per block.
        let iters = self.cfg.work_iters;
        let (vectors, _, _, _, _, _) = self.gg.parts_mut();
        for v in vectors.iter_mut() {
            v.for_each_mut(|x| {
                for _ in 0..iters {
                    *x += 1.0;
                }
            });
        }
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cfg(blocks: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            blocks,
            first_bucket_size: 16,
            use_artifacts: false, // unit tests must not depend on `make artifacts`
            batch: BatchConfig { max_values: 64, max_delay: Duration::from_millis(1) },
            ..CoordinatorConfig::default()
        }
    }

    #[test]
    fn insert_query_roundtrip() {
        let c = Coordinator::start(test_cfg(4));
        c.call(Request::Insert { values: (0..100).map(|i| i as f32).collect() });
        // Query barriers pending batches, so this is totally ordered.
        let v = c.call(Request::Query { index: 0 }).expect_value();
        assert_eq!(v, Some(0.0));
        let v = c.call(Request::Query { index: 99 }).expect_value();
        assert!(v.is_some());
        let v = c.call(Request::Query { index: 100 }).expect_value();
        assert_eq!(v, None);
        c.shutdown();
    }

    #[test]
    fn work_applies_numeric_update() {
        let cfg = test_cfg(2);
        let iters = cfg.work_iters as f32;
        let c = Coordinator::start(cfg);
        c.call(Request::Insert { values: vec![1.0, 2.0, 3.0] });
        c.call(Request::Work { calls: 2 });
        assert_eq!(c.call(Request::Query { index: 0 }).expect_value(), Some(1.0 + 2.0 * iters));
        assert_eq!(c.call(Request::Query { index: 2 }).expect_value(), Some(3.0 + 2.0 * iters));
        c.shutdown();
    }

    #[test]
    fn flatten_checksum_stable() {
        let c = Coordinator::start(test_cfg(4));
        c.call(Request::Insert { values: (0..500).map(|i| i as f32).collect() });
        let a = match c.call(Request::Flatten) {
            Response::Flattened { checksum, len, .. } => {
                assert_eq!(len, 500);
                checksum
            }
            other => panic!("{other:?}"),
        };
        let b = match c.call(Request::Flatten) {
            Response::Flattened { checksum, .. } => checksum,
            other => panic!("{other:?}"),
        };
        assert_eq!(a, b);
        c.shutdown();
    }

    #[test]
    fn batching_coalesces_small_inserts() {
        let c = Coordinator::start(test_cfg(4));
        for i in 0..200 {
            c.call(Request::Insert { values: vec![i as f32] });
        }
        // Barrier via query, then inspect stats.
        let _ = c.call(Request::Query { index: 0 });
        let snap = match c.call(Request::Stats) {
            Response::Stats(s) => s,
            other => panic!("{other:?}"),
        };
        assert_eq!(snap.elements_inserted, 200);
        assert!(snap.batches < 200, "batching should coalesce: {} batches", snap.batches);
        assert!(snap.coalescing() > 1.5, "coalescing {:.2}", snap.coalescing());
        assert_eq!(snap.len, 200);
        c.shutdown();
    }

    #[test]
    fn stats_overhead_bounded() {
        let c = Coordinator::start(test_cfg(8));
        c.call(Request::Insert { values: vec![1.0; 10_000] });
        let _ = c.call(Request::Query { index: 0 });
        let snap = match c.call(Request::Stats) {
            Response::Stats(s) => s,
            other => panic!("{other:?}"),
        };
        assert!(snap.overhead_ratio() < 2.3, "overhead {:.2}", snap.overhead_ratio());
        c.shutdown();
    }

    #[test]
    fn drop_without_shutdown_is_clean() {
        let c = Coordinator::start(test_cfg(2));
        c.call(Request::Insert { values: vec![1.0] });
        drop(c); // Drop impl joins the worker
    }
}
