//! Shards and epochs: the scale-out layer of the coordinator.
//!
//! A [`Shard`] is one independent `GgArray<f32>` running over its own
//! [`VramHeap`] budget carved from a shared [`DeviceSpec`] — the
//! DynaSOAr-style hierarchy: global routing picks a shard, the shard's
//! per-block LFVectors pick a bucket. Shards own disjoint *consecutive*
//! runs of the global block space, so a batch routed globally and sliced
//! per shard (see [`crate::coordinator::router::split_for_shards`])
//! produces exactly the layout a single GgArray with all the blocks
//! would: the sealed flatten concatenation is byte-identical for any
//! shard count.
//!
//! The [`EpochManager`] implements the paper's §VI.D two-phase lifecycle
//! as a first-class API: an epoch is [`Epoch::Inserting`] while data
//! grows inside the shard GgArrays, and moves to [`Epoch::Sealed`] when
//! the coordinator drains in-flight batches, flattens every shard, and
//! concatenates the results into one contiguous [`ShardedFlattened`]
//! view. Reads and work over sealed data run at static-array (coalesced)
//! cost — the fast regular-access phase — while a fresh inserting epoch
//! opens behind the seal.
//!
//! Sealed residency is **epoch-owned**: at commit each shard *transfers*
//! its flatten-destination allocation out of its own heap into the
//! [`EpochManager`]'s heap ([`VramHeap::transfer_to`] — an accounting
//! move, not allocator traffic), so old epochs never squat on the
//! live-epoch budgets. [`EpochManager::compact`] is a real reserve-then-
//! commit transaction over that heap: the merged destination is
//! allocated while every source segment is still resident (the gather's
//! transient 2× residency), and a budget too tight for the transient
//! makes compaction OOM and abort byte-identically — segments,
//! allocations and `sealed_len` untouched.

use crate::ggarray::array::{GgArray, GgConfig, OpReport};
use crate::ggarray::flatten::{self, Flattened, ShardedFlattened};
use crate::ggarray::lfvector::LfVector;
use crate::insertion::{self, InsertionKind, InsertShape};
use crate::runtime::Executor;
use crate::sim::clock::ClockMark;
use crate::sim::kernel::{self, KernelProfile};
use crate::sim::memory::{AllocId, HeapMark, OomError, VramHeap};
use crate::sim::spec::DeviceSpec;

/// Construction parameters for one shard.
#[derive(Debug, Clone)]
pub struct ShardConfig {
    pub id: usize,
    /// LFVectors (thread blocks) owned by this shard.
    pub blocks: usize,
    pub first_bucket_size: usize,
    pub insertion: InsertionKind,
    pub device: DeviceSpec,
    /// Simulated VRAM budget for this shard's heap.
    pub heap_bytes: u64,
}

/// Outcome of applying one routed sub-batch to a shard.
#[derive(Debug)]
pub struct ShardInsertOutcome {
    /// Elements actually placed (= the sub-batch size unless OOM).
    pub applied: usize,
    /// Simulated GPU time charged to this shard for the sub-batch (µs).
    pub sim_us: f64,
    /// The OOM, if the shard's budget ran out mid-batch.
    pub error: Option<OomError>,
}

/// One shard's contribution to a pooled cross-shard seal
/// ([`Shard::seal_flatten_into`]): how many elements it appended to the
/// shared gather destination, its flatten timing report, and the (still
/// shard-heap-resident) destination allocation whose fate the caller
/// decides — [`Shard::commit_seal`] or [`Shard::abort_seal`].
#[derive(Debug)]
pub struct SealPart {
    pub len: usize,
    pub report: OpReport,
    pub alloc: Option<AllocId>,
}

/// Assemble the [`ShardedFlattened`] view of a pooled seal from the
/// per-shard [`SealPart`]s and the shared gather destination they wrote
/// (shard-major, in seal order) — the zero-extra-copy counterpart of
/// [`flatten::concat`].
pub fn concat_parts(parts: &[SealPart], data: Vec<f32>) -> ShardedFlattened<f32> {
    debug_assert_eq!(parts.iter().map(|p| p.len).sum::<usize>(), data.len());
    let mut index = crate::ggarray::index::PrefixIndex::new();
    index.rebuild(parts.iter().map(|p| p.len as u64));
    let mut report = OpReport::default();
    for p in parts {
        report.absorb(&p.report);
    }
    ShardedFlattened { data, index, report }
}

/// One independent GGArray shard with its own VRAM budget. The budget
/// covers only the *live* epoch (growable buckets plus the transient
/// flatten destination of a seal in flight): committed sealed bytes are
/// transferred to the epoch-owned heap ([`EpochManager`]), so old epochs
/// never squat on a shard's growth headroom.
#[derive(Debug)]
pub struct Shard {
    id: usize,
    gg: GgArray<f32>,
    insertion: InsertionKind,
    /// Pre-op cost snapshot for abort rollback ([`Shard::save_abort_mark`]):
    /// `Copy` marks, so arming one is allocation-free on the dispatch hot
    /// path. Ops never nest, so a single slot suffices.
    abort_clock: ClockMark,
    abort_heap: HeapMark,
}

impl Shard {
    pub fn new(cfg: ShardConfig) -> Shard {
        let gg_cfg = GgConfig {
            num_blocks: cfg.blocks,
            threads_per_block: 1024,
            first_bucket_size: cfg.first_bucket_size,
            insertion: cfg.insertion,
        };
        let heap = VramHeap::with_capacity(cfg.device.clone(), cfg.heap_bytes);
        Shard {
            id: cfg.id,
            gg: GgArray::with_heap(gg_cfg, cfg.device, heap),
            insertion: cfg.insertion,
            abort_clock: ClockMark::default(),
            abort_heap: HeapMark::default(),
        }
    }

    pub fn id(&self) -> usize {
        self.id
    }

    pub fn len(&self) -> usize {
        self.gg.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gg.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.gg.capacity()
    }

    pub fn allocated_bytes(&self) -> u64 {
        self.gg.allocated_bytes()
    }

    pub fn heap_used(&self) -> u64 {
        self.gg.heap().used()
    }

    /// Free bytes left in this shard's VRAM budget — the shard
    /// scheduler's OOM pre-screen compares bucket/flatten demand against
    /// this before fanning an op out (a guaranteed-fit op cannot OOM
    /// mid-flight, so the parallel path never has to unwind a
    /// half-applied batch).
    pub fn heap_free(&self) -> u64 {
        self.gg.heap().free_bytes()
    }

    /// First-bucket size of this shard's LFVectors (bucket-demand
    /// arithmetic for the insert pre-screen).
    pub fn first_bucket_size(&self) -> usize {
        self.gg.config().first_bucket_size
    }

    pub fn block_sizes(&self) -> Vec<u64> {
        self.gg.block_sizes()
    }

    /// Per-block sizes without materialising a vector (dispatch hot
    /// path: the router extends its scratch buffer from this).
    pub fn block_sizes_iter(&self) -> impl Iterator<Item = u64> + '_ {
        self.gg.block_sizes_iter()
    }

    pub fn sim_now_us(&self) -> f64 {
        self.gg.clock().now_us()
    }

    pub fn gg(&self) -> &GgArray<f32> {
        &self.gg
    }

    /// Exclusive per-block access for the scheduler's insert-fill
    /// chunks: the caller carves the slice into disjoint block ranges
    /// (`split_at_mut`) so several chunks may fill one shard's tails
    /// concurrently. Pure data movement only — all heap/clock charges
    /// for the tails happened in [`Shard::prepare_counts`].
    pub(crate) fn vectors_mut(&mut self) -> &mut [LfVector<f32>] {
        self.gg.parts_mut().0
    }

    /// Read a shard-local global index (the shard's own block-major
    /// order).
    pub fn get(&self, i: u64) -> Option<f32> {
        self.gg.get(i)
    }

    /// Apply a routed sub-batch: `counts[b]` values to block `b`, in
    /// order, then charge the shard-local insertion kernel and index
    /// rebuild. On OOM the elements placed before the failure stay
    /// visible (device semantics) and the index is left consistent.
    pub fn apply_counts(&mut self, counts: &[usize], values: &[f32]) -> ShardInsertOutcome {
        debug_assert_eq!(counts.len(), self.gg.num_blocks());
        debug_assert_eq!(counts.iter().sum::<usize>(), values.len());
        let sim0 = self.gg.clock().now_us();
        let mut off = 0usize;
        for (b, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if let Err(e) = self.gg.push_bulk_to_block(b, &values[off..off + c]) {
                self.gg.rebuild_index_charged();
                return ShardInsertOutcome {
                    applied: off,
                    sim_us: self.gg.clock().now_us() - sim0,
                    error: Some(e),
                };
            }
            off += c;
        }
        // Modeled insertion kernel over this shard's grid.
        let blocks = self.gg.num_blocks() as u64;
        let shape = InsertShape {
            threads: values.len().max(self.gg.len()) as u64,
            inserts: values.len() as u64,
            elem_bytes: 4,
            blocks,
            threads_per_block: 1024,
            counters: blocks,
            write_eff: self.gg.spec().cost.ggarray_insert_eff,
        };
        let profile = insertion::profile(self.gg.spec(), self.insertion, &shape);
        {
            let (_, _, clock, spec, _, _) = self.gg.parts_mut();
            kernel::launch(spec, clock, &profile);
        }
        self.gg.rebuild_index_charged();
        ShardInsertOutcome { applied: off, sim_us: self.gg.clock().now_us() - sim0, error: None }
    }

    /// Charge half of [`Shard::apply_counts`]: reserve buckets, extend
    /// block lengths, charge the insertion kernel and the index rebuild
    /// — everything that touches the simulated heap/clock — without
    /// copying any batch values. The host-side copies are free in
    /// simulated time, so the charges (and the returned `sim_us`) are
    /// *identical* to `apply_counts` on the same state; the scheduler
    /// runs this serially in shard order for deterministic clocks and
    /// hands the pure fills to stealable chunks
    /// ([`Shard::fill_counts`]). OOM semantics match exactly: blocks
    /// before the failure stay extended (their fill is still owed),
    /// the index is rebuilt, and `applied` is the prefix length.
    pub fn prepare_counts(&mut self, counts: &[usize], total: usize) -> ShardInsertOutcome {
        debug_assert_eq!(counts.len(), self.gg.num_blocks());
        debug_assert_eq!(counts.iter().sum::<usize>(), total);
        let sim0 = self.gg.clock().now_us();
        let mut off = 0usize;
        for (b, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if let Err(e) = self.gg.push_bulk_uninit_to_block(b, c) {
                self.gg.rebuild_index_charged();
                return ShardInsertOutcome {
                    applied: off,
                    sim_us: self.gg.clock().now_us() - sim0,
                    error: Some(e),
                };
            }
            off += c;
        }
        // Identical kernel charge to `apply_counts`: the uninit pushes
        // already extended `len`, so `total.max(len)` sees the same
        // post-insert size the copying path does.
        let blocks = self.gg.num_blocks() as u64;
        let shape = InsertShape {
            threads: total.max(self.gg.len()) as u64,
            inserts: total as u64,
            elem_bytes: 4,
            blocks,
            threads_per_block: 1024,
            counters: blocks,
            write_eff: self.gg.spec().cost.ggarray_insert_eff,
        };
        let profile = insertion::profile(self.gg.spec(), self.insertion, &shape);
        {
            let (_, _, clock, spec, _, _) = self.gg.parts_mut();
            kernel::launch(spec, clock, &profile);
        }
        self.gg.rebuild_index_charged();
        ShardInsertOutcome { applied: off, sim_us: self.gg.clock().now_us() - sim0, error: None }
    }

    /// Pure data-movement half of the charge/copy split: write the
    /// routed `values` into the tail slots [`Shard::prepare_counts`]
    /// reserved (block order, values consumed in order). `applied` is
    /// the prepare outcome's count — after a prepare OOM only the
    /// fully-extended block prefix is filled, matching `apply_counts`'s
    /// prefix semantics. Touches no heap/clock state.
    pub fn fill_counts(&mut self, counts: &[usize], values: &[f32], applied: usize) {
        let mut off = 0usize;
        for (b, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if off + c > applied {
                break;
            }
            self.gg.fill_block_tail(b, &values[off..off + c]);
            off += c;
        }
        debug_assert_eq!(off, applied, "fill must cover exactly the prepared prefix");
    }

    /// Pure gather: copy this shard's elements
    /// `start..start + dst.len()` (block-major flattened order — the
    /// exact byte order of [`Shard::seal_flatten_to_slice`]) into
    /// `dst`. `&self` only, so the scheduler may run several range
    /// chunks of one large shard concurrently.
    pub fn gather_copy_range(&self, mut start: usize, dst: &mut [f32]) {
        let mut written = 0usize;
        for v in self.gg.vectors() {
            if written == dst.len() {
                break;
            }
            let n = v.len();
            if start >= n {
                start -= n;
                continue;
            }
            let take = (n - start).min(dst.len() - written);
            v.copy_range_to_slice(start, &mut dst[written..written + take]);
            written += take;
            start = 0;
        }
        assert_eq!(written, dst.len(), "gather range past shard len");
    }

    /// Charge half of [`Shard::seal_flatten_to_slice`]: seal the epoch
    /// and advance heap/clock exactly as the flatten would (destination
    /// malloc + gather kernel) without moving bytes — the scheduler's
    /// gather chunks owe the data via [`Shard::gather_copy_range`]. On
    /// error the shard is reopened untouched, exactly like the copying
    /// path.
    pub fn seal_flatten_charge(&mut self) -> Result<SealPart, OomError> {
        self.gg.seal();
        let len = self.gg.len();
        match flatten::flatten_charge_only(&mut self.gg) {
            Ok((report, alloc)) => Ok(SealPart { len, report, alloc }),
            Err(e) => {
                self.gg.reopen();
                Err(e)
            }
        }
    }

    /// Charge half of [`Shard::flatten_temp_to_slice`]: snapshot-flatten
    /// charges with the temp destination released immediately, no data
    /// movement. Returns the shard length the gather chunks must copy.
    pub fn flatten_temp_charge(&mut self) -> Result<usize, OomError> {
        let (_report, alloc) = flatten::flatten_charge_only(&mut self.gg)?;
        if let Some(a) = alloc {
            let (_, heap, clock, _, _, _) = self.gg.parts_mut();
            heap.free(a, clock);
        }
        Ok(self.gg.len())
    }

    /// Seal this shard's epoch and flatten its contents. The returned
    /// [`Flattened`] still carries its destination allocation: the
    /// caller decides the transaction's fate — [`Shard::commit_seal`]
    /// once every shard of the store succeeded, or [`Shard::abort_seal`]
    /// if any failed — so a cross-shard seal never half-commits VRAM.
    /// On error this shard is reopened untouched.
    ///
    /// Collecting wrapper over [`Shard::seal_flatten_into`] — the
    /// coordinator's seal gathers every shard into one pooled
    /// destination instead.
    pub fn seal_flatten(&mut self) -> Result<Flattened<f32>, OomError> {
        let mut data = Vec::new();
        let part = self.seal_flatten_into(&mut data)?;
        debug_assert_eq!(part.len, data.len());
        Ok(Flattened { data, report: part.report, alloc: part.alloc })
    }

    /// Pooled seal-flatten: append this shard's contents to the shared
    /// gather destination `dst` (shards land back-to-back in seal order)
    /// and return the [`SealPart`] bookkeeping — appended length, timing
    /// report, and the still-shard-heap-resident destination allocation
    /// whose fate the caller decides. On error nothing is appended and
    /// this shard is reopened untouched.
    pub fn seal_flatten_into(&mut self, dst: &mut Vec<f32>) -> Result<SealPart, OomError> {
        self.gg.seal();
        let before = dst.len();
        match flatten::flatten_into(&mut self.gg, dst) {
            Ok((report, alloc)) => Ok(SealPart { len: dst.len() - before, report, alloc }),
            Err(e) => {
                debug_assert_eq!(dst.len(), before, "failed flatten must not append");
                self.gg.reopen();
                Err(e)
            }
        }
    }

    /// Slice-target [`Shard::seal_flatten_into`]: gather this shard's
    /// contents into `dst` (exactly `len` slots, carved by the caller out
    /// of the shared seal destination) with identical simulated charges —
    /// the scheduler's phase-1 seal gather runs one of these per
    /// shard concurrently, each into its disjoint sub-slice. On error
    /// nothing meaningful was written and this shard is reopened
    /// untouched, exactly like the appending path.
    pub fn seal_flatten_to_slice(&mut self, dst: &mut [f32]) -> Result<SealPart, OomError> {
        self.gg.seal();
        let len = dst.len();
        match flatten::flatten_to_slice(&mut self.gg, dst) {
            Ok((report, alloc)) => Ok(SealPart { len, report, alloc }),
            Err(e) => {
                self.gg.reopen();
                Err(e)
            }
        }
    }

    /// Commit a successful seal: *transfer* the epoch's flatten
    /// destination out of this shard's heap into the epoch-owned sealed
    /// store (the bytes stay resident on the device; only the accounting
    /// owner changes, freeing this shard's budget for the next epoch),
    /// drop the growable storage, and open the next inserting epoch.
    /// Returns the allocation's id in the epoch heap.
    ///
    /// The caller must have reserved epoch-store capacity for the whole
    /// seal ([`EpochManager::can_accept`]) *before* committing any
    /// shard: a transfer failing mid-commit would tear the cross-shard
    /// transaction, so it is a contract violation here.
    pub fn commit_seal(&mut self, alloc: Option<AllocId>, epoch_heap: &mut VramHeap) -> Option<AllocId> {
        let transferred = alloc.map(|a| {
            let (_, heap, _, _, _, _) = self.gg.parts_mut();
            heap.transfer_to(a, epoch_heap)
                .expect("epoch-store capacity must be reserved (can_accept) before commit")
        });
        self.reopen_clear();
        transferred
    }

    /// Abort a seal whose sibling shard failed: release this shard's
    /// fresh flatten destination and reopen with contents untouched
    /// (the per-shard flatten is non-destructive).
    pub fn abort_seal(&mut self, alloc: Option<AllocId>) {
        if let Some(a) = alloc {
            let (_, heap, clock, _, _, _) = self.gg.parts_mut();
            heap.free(a, clock);
        }
        self.gg.reopen();
    }

    /// Non-destructive flatten for a read-only snapshot: the temporary
    /// destination is released immediately (the data lives on the host
    /// side of the response).
    pub fn flatten_temp(&mut self) -> Result<Flattened<f32>, OomError> {
        let mut f = flatten::flatten(&mut self.gg)?;
        if let Some(dst) = f.alloc.take() {
            let (_, heap, clock, _, _, _) = self.gg.parts_mut();
            heap.free(dst, clock);
        }
        Ok(f)
    }

    /// Pooled [`Shard::flatten_temp`]: append this shard's contents to
    /// the caller's reusable snapshot buffer and release the simulated
    /// destination immediately. Returns the appended length.
    pub fn flatten_temp_into(&mut self, dst: &mut Vec<f32>) -> Result<usize, OomError> {
        let before = dst.len();
        let (_report, alloc) = flatten::flatten_into(&mut self.gg, dst)?;
        if let Some(a) = alloc {
            let (_, heap, clock, _, _, _) = self.gg.parts_mut();
            heap.free(a, clock);
        }
        Ok(dst.len() - before)
    }

    /// Slice-target [`Shard::flatten_temp_into`] for the scheduler's
    /// parallel snapshot gather: write this shard's contents into `dst`
    /// (exactly `len` slots) and release the simulated destination
    /// immediately, with charges identical to the appending path.
    pub fn flatten_temp_to_slice(&mut self, dst: &mut [f32]) -> Result<usize, OomError> {
        let len = dst.len();
        let (_report, alloc) = flatten::flatten_to_slice(&mut self.gg, dst)?;
        if let Some(a) = alloc {
            let (_, heap, clock, _, _, _) = self.gg.parts_mut();
            heap.free(a, clock);
        }
        Ok(len)
    }

    /// Reopen without clearing — the abort path when a multi-shard seal
    /// fails partway: contents stay in place and inserts resume.
    pub fn reopen(&mut self) {
        self.gg.reopen();
    }

    /// Drop the growable storage and open the next inserting epoch —
    /// after a successful seal (the sealed data lives on in the epoch
    /// manager's heap) or a service `Clear` (the epoch store resets
    /// itself separately: it owns the sealed bytes, not the shards).
    pub fn reopen_clear(&mut self) {
        self.gg.clear();
        self.gg.rebuild_index_charged();
        self.gg.reopen();
    }

    /// Charge one modeled `rw_b` pass over this shard without touching
    /// data (the real numeric update goes through [`Shard::work_pass`]).
    pub fn charge_rw_block(&mut self, flops_per_elem: f64) -> f64 {
        self.gg.read_write_block(flops_per_elem, |_| {}).us
    }

    /// Snapshot this shard's simulated costs (clock ledger + heap
    /// counters) so a mid-phase worker panic can abort the op
    /// byte-identically. Called by the scheduler at the start of each
    /// serial charge pass; `Copy` marks, so allocation-free.
    pub fn save_abort_mark(&mut self) {
        let (cm, hm) = self.gg.cost_marks();
        self.abort_clock = cm;
        self.abort_heap = hm;
    }

    /// Rewind this shard's clock ledger and heap counters to the last
    /// [`Shard::save_abort_mark`]. The caller must first undo any real
    /// heap traffic the op performed (e.g. free the op's fresh buckets
    /// or destination allocation) so `used` matches the mark again.
    pub fn rewind_abort(&mut self) {
        self.gg.rewind_costs(self.abort_clock, self.abort_heap);
    }

    /// Abort half of the insert charge/copy split: undo a
    /// [`Shard::prepare_counts`] whose phase died before the fills ran.
    /// `counts`/`applied` are exactly the prepare's inputs/outcome — the
    /// extended block prefix is recomputed from them, each block is
    /// shrunk back (freeing the op's fresh buckets), and the costs are
    /// rewound to the [`Shard::save_abort_mark`] taken before the
    /// prepare. Afterwards the shard is byte-identical to the op never
    /// having started: length, bucket layout, CAS ledger, heap
    /// residency/counters and the exact clock all match.
    pub fn rollback_insert(&mut self, counts: &[usize], applied: usize) {
        let mut remaining = applied;
        let old_lens: Vec<usize> = self
            .gg
            .vectors()
            .iter()
            .zip(counts)
            .map(|(v, &c)| {
                let take = c.min(remaining);
                remaining -= take;
                v.len() - take
            })
            .collect();
        debug_assert_eq!(remaining, 0, "prepare outcome must be a block-count prefix");
        self.gg.rollback_growth(&old_lens);
        self.rewind_abort();
    }

    /// Apply the real +1×`iters` numeric update to this shard's data,
    /// through the AOT PJRT kernel when available. Returns PJRT
    /// executions performed (0 on the host fallback path).
    pub fn work_pass(&mut self, exec: Option<&Executor>, iters: u32) -> u64 {
        let n = self.gg.len();
        if n == 0 {
            return 0;
        }
        if let Some(exec) = exec {
            let data = self.gg.to_vec();
            if let Ok(name) = exec.pick_chunking("work_f32_", data.len()) {
                let spec_cap = exec.manifest().get(&name).map(|s| s.inputs[0].elements()).unwrap_or(0);
                if spec_cap > 0 {
                    let mut out = Vec::with_capacity(data.len());
                    let mut execs = 0u64;
                    let mut ok = true;
                    for chunk in data.chunks(spec_cap) {
                        match exec.run_f32(&name, &[chunk], chunk.len()) {
                            Ok(mut r) => {
                                out.extend(r.swap_remove(0));
                                execs += 1;
                            }
                            Err(e) => {
                                eprintln!("[coordinator] PJRT work failed on shard {}, host fallback: {e}", self.id);
                                ok = false;
                                break;
                            }
                        }
                    }
                    if ok {
                        self.gg.overwrite_from(&out);
                        return execs;
                    }
                }
            }
        }
        // Host fallback: identical numerics (iters sequential f32 adds).
        let (vectors, _, _, _, _, _) = self.gg.parts_mut();
        for v in vectors.iter_mut() {
            v.for_each_mut(|x| {
                for _ in 0..iters {
                    *x += 1.0;
                }
            });
        }
        0
    }
}

// ---------------------------------------------------------------------
// Epochs
// ---------------------------------------------------------------------

/// Lifecycle state of one epoch of the sharded store (paper §VI.D).
#[derive(Debug)]
pub enum Epoch<T> {
    /// High-uncertainty insertion phase: contents grow inside the shard
    /// GgArrays.
    Inserting,
    /// Fast regular-access phase: the epoch's contents flattened into a
    /// contiguous shard-indexed view.
    Sealed(ShardedFlattened<T>),
}

impl<T: Copy> Epoch<T> {
    pub fn is_sealed(&self) -> bool {
        matches!(self, Epoch::Sealed(_))
    }

    pub fn sealed(&self) -> Option<&ShardedFlattened<T>> {
        match self {
            Epoch::Sealed(v) => Some(v),
            Epoch::Inserting => None,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Epoch::Sealed(v) => v.len(),
            Epoch::Inserting => 0,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Owns the sealed epochs, their VRAM, and the simulated cost of the
/// flat access path. Global index order: sealed epochs in seal order
/// (each shard-major internally), then the live inserting epoch.
///
/// The manager's [`VramHeap`] is the sealed store's budget, carved from
/// the same device as the shard heaps: every sealed segment's backing
/// allocation lives here (transferred in at seal commit), and the
/// compaction gather's transient 2× residency pushes through it — so a
/// tight budget makes [`EpochManager::compact`] OOM and abort, exactly
/// like the seal two-phase commit.
#[derive(Debug)]
pub struct EpochManager {
    device: DeviceSpec,
    clock: crate::sim::clock::Clock,
    /// Epoch-owned VRAM: sealed segments + compaction transients.
    heap: VramHeap,
    /// Recycled gather buffer, sized to the largest seal/compaction seen:
    /// the next pooled gather leases it ([`EpochManager::take_gather_buffer`])
    /// instead of allocating, and freed segment buffers are banked back
    /// ([`EpochManager::bank_gather_buffer`]).
    pool: Vec<f32>,
    /// Sequence number of the *current inserting* epoch (starts at 0;
    /// each seal advances it).
    seq: u64,
    /// Epoch history in seal order — every entry here is
    /// [`Epoch::Sealed`]; the current [`Epoch::Inserting`] lives in the
    /// shard GgArrays, not in this store.
    sealed: Vec<Epoch<f32>>,
    /// Backing allocations of each sealed segment, parallel to `sealed`
    /// (one allocation per shard destination transferred at commit; a
    /// single merged allocation after compaction).
    allocs: Vec<Vec<AllocId>>,
    /// Global start offset of each sealed epoch.
    starts: Vec<u64>,
    total: u64,
}

impl EpochManager {
    /// Epoch store with `heap_bytes` of sealed-store VRAM budget.
    pub fn new(device: DeviceSpec, heap_bytes: u64) -> EpochManager {
        EpochManager {
            clock: crate::sim::clock::Clock::new(),
            heap: VramHeap::with_capacity(device.clone(), heap_bytes),
            device,
            seq: 0,
            sealed: Vec::new(),
            allocs: Vec::new(),
            starts: Vec::new(),
            total: 0,
            pool: Vec::new(),
        }
    }

    /// Lease the pooled gather buffer: cleared, with the capacity of the
    /// largest gather banked so far. The caller writes a flat segment
    /// into it and either absorbs it (sealed epochs own their bytes) or
    /// banks it back after an abort.
    pub fn take_gather_buffer(&mut self) -> Vec<f32> {
        let mut buf = std::mem::take(&mut self.pool);
        buf.clear();
        buf
    }

    /// Lease the pooled gather buffer **without clearing**: stale
    /// elements from the banked buffer are retained (they are
    /// initialized memory). For callers that overwrite an exact prefix
    /// anyway — the scheduler's parallel seal gather writes every
    /// slot of its carve — this skips the `resize` zero-fill a cleared
    /// lease would force, which would otherwise be a serial full-buffer
    /// memset ahead of the parallel writes.
    pub fn take_gather_buffer_uncleared(&mut self) -> Vec<f32> {
        std::mem::take(&mut self.pool)
    }

    /// Return a buffer to the gather pool (aborted seal, freed
    /// compaction source, cleared store): the larger capacity wins, so
    /// the pool converges on the largest seal seen and steady churn
    /// stops allocating gather destinations. Contents are retained (and
    /// never read as data) so an uncleared re-lease can size itself
    /// without re-initializing slots it is about to overwrite.
    pub fn bank_gather_buffer(&mut self, buf: Vec<f32>) {
        if buf.capacity() > self.pool.capacity() {
            self.pool = buf;
        }
    }

    /// Capacity of the banked gather buffer (observability/tests).
    pub fn gather_pool_capacity(&self) -> usize {
        self.pool.capacity()
    }

    /// Current inserting-epoch sequence number.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Total elements across all sealed epochs.
    pub fn sealed_len(&self) -> u64 {
        self.total
    }

    pub fn sealed_epochs(&self) -> usize {
        self.sealed.len()
    }

    pub fn now_us(&self) -> f64 {
        self.clock.now_us()
    }

    /// The epoch-owned heap (sealed bytes + compaction transients).
    pub fn heap(&self) -> &VramHeap {
        &self.heap
    }

    /// Mutable heap access for the commit step of a seal: shards
    /// transfer their flatten destinations in here
    /// ([`Shard::commit_seal`]).
    pub fn heap_mut(&mut self) -> &mut VramHeap {
        &mut self.heap
    }

    /// Bytes of VRAM currently held by the sealed store.
    pub fn sealed_bytes(&self) -> u64 {
        self.heap.used()
    }

    /// Reserve-check for the commit phase of a seal: can the epoch store
    /// adopt `bytes` more sealed bytes? Checked once for the whole
    /// cross-shard seal *before* any shard commits, so the per-shard
    /// transfers ([`Shard::commit_seal`]) can never fail mid-commit.
    pub fn can_accept(&self, bytes: u64) -> Result<(), OomError> {
        if bytes > self.heap.free_bytes() {
            Err(OomError {
                requested: bytes,
                free: self.heap.free_bytes(),
                capacity: self.heap.capacity(),
            })
        } else {
            Ok(())
        }
    }

    /// Absorb a freshly sealed epoch (`Inserting → Sealed` transition)
    /// together with its backing allocations — already transferred into
    /// this manager's heap by the shards' commit step. Returns the new
    /// inserting-epoch sequence number.
    pub fn absorb(&mut self, flat: ShardedFlattened<f32>, allocs: Vec<AllocId>) -> u64 {
        debug_assert_eq!(
            allocs
                .iter()
                .map(|&a| self.heap.size_of(a).expect("segment alloc must live in the epoch heap"))
                .sum::<u64>(),
            flat.len() as u64 * 4,
            "sealed segment allocations must cover exactly the segment bytes"
        );
        self.starts.push(self.total);
        self.total += flat.len() as u64;
        self.sealed.push(Epoch::Sealed(flat));
        self.allocs.push(allocs);
        self.seq += 1;
        self.seq
    }

    /// Read a global index from the sealed prefix ([0, sealed_len)).
    pub fn get(&self, i: u64) -> Option<f32> {
        if i >= self.total {
            return None;
        }
        // Few epochs: linear scan from the back beats a binary search.
        for (k, &start) in self.starts.iter().enumerate().rev() {
            if i >= start {
                return self.sealed[k].sealed().and_then(|v| v.get(i - start));
            }
        }
        None
    }

    /// The sealed epochs' flat segments in global order — callers
    /// bulk-copy (`extend_from_slice`) instead of pushing per element.
    pub fn segments(&self) -> impl Iterator<Item = &[f32]> {
        self.sealed.iter().filter_map(|e| e.sealed()).map(|v| v.data.as_slice())
    }

    /// Apply the +1×`iters` work op to all sealed data at static-array
    /// cost: fully-coalesced streaming traffic, no bucket indirection and
    /// no per-chunk pointer chases — the payoff of the two-phase pattern.
    ///
    /// Each sealed segment is its own device buffer, so the pass is one
    /// kernel launch *per segment*: a fragmented store pays a launch
    /// overhead (and small-grid occupancy) per epoch, which is exactly
    /// the modeled cost [`EpochManager::compact`] buys back. Returns the
    /// simulated µs charged.
    pub fn work(&mut self, iters: u32) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let t0 = self.clock.now_us();
        let tpb = 1024u32;
        for epoch in &mut self.sealed {
            if let Epoch::Sealed(view) = epoch {
                for x in &mut view.data {
                    for _ in 0..iters {
                        *x += 1.0;
                    }
                }
                let n_seg = view.len() as u64;
                if n_seg == 0 {
                    continue;
                }
                let profile = KernelProfile {
                    blocks: crate::util::math::ceil_div(n_seg, tpb as u64),
                    threads_per_block: tpb,
                    bytes: 2.0 * 4.0 * n_seg as f64,
                    coalescing_eff: self.device.cost.coalesced_eff,
                    flops_fp32: iters as f64 * n_seg as f64,
                    flops_mxu: 0.0,
                    mxu_utilisation: 1.0,
                    per_block_us: 0.0,
                    atomic_us: 0.0,
                    extra_us: 0.0,
                };
                kernel::launch(&self.device, &mut self.clock, &profile);
            }
        }
        self.clock.now_us() - t0
    }

    /// Merge every sealed segment into one contiguous segment with a
    /// single modeled gather pass (read each segment, write the merged
    /// destination — both coalesced streaming traffic). Contents and
    /// order are untouched, so reads, checksums, and `sealed_len` are
    /// unaffected; what changes is the segment count — and with it the
    /// per-segment launch overhead [`EpochManager::work`] pays on every
    /// sealed pass (the per-segment space overhead is what Tarjan–Zwick
    /// resizable-array bounds target). Returns the simulated µs charged.
    ///
    /// A real VRAM transaction, mirroring the seal two-phase commit:
    ///
    /// 1. **Reserve** — the merged destination is allocated from the
    ///    epoch heap while every source segment is still resident (the
    ///    gather's transient 2× residency). A budget too tight for the
    ///    transient fails *here*, and the abort is byte-identical:
    ///    segments, backing allocations, contents and `sealed_len` are
    ///    exactly as before, and no time beyond the failed reserve is
    ///    charged.
    /// 2. **Commit** — one gather pass into the destination, then the
    ///    source allocations are freed and the store re-indexes over the
    ///    single merged segment.
    pub fn compact(&mut self) -> Result<f64, OomError> {
        if self.sealed.len() <= 1 {
            return Ok(0.0);
        }
        let t0 = self.clock.now_us();
        // Phase 1 — reserve the merged destination (2× transient).
        let bytes = self.total * 4;
        let dst = self.heap.alloc(bytes, &mut self.clock)?;
        // Phase 2 — commit: gather into the pooled destination, free the
        // sources, keep the merge. The host-side mirror of the VRAM
        // discipline: the gather buffer is leased from the pool and the
        // largest freed source is banked back, so repeated
        // seal → compact churn stops allocating host buffers too.
        let mut data = self.take_gather_buffer();
        let parts: Vec<ShardedFlattened<f32>> = self
            .sealed
            .drain(..)
            .filter_map(|e| match e {
                Epoch::Sealed(v) => Some(v),
                Epoch::Inserting => None,
            })
            .collect();
        let (index, report) = flatten::merge_segments_into(&parts, &mut data);
        let merged = ShardedFlattened { data, index, report };
        debug_assert_eq!(merged.len() as u64, self.total);
        for p in parts {
            self.bank_gather_buffer(p.data);
        }
        let n = self.total;
        let tpb = 1024u32;
        let blocks = crate::util::math::ceil_div(n, tpb as u64);
        let profile = KernelProfile::streaming(
            blocks.max(1),
            tpb,
            2.0 * 4.0 * n as f64,
            self.device.cost.coalesced_eff,
        );
        kernel::launch(&self.device, &mut self.clock, &profile);
        for id in self.allocs.drain(..).flatten() {
            self.heap.free(id, &mut self.clock);
        }
        self.starts = vec![0];
        self.sealed = vec![Epoch::Sealed(merged)];
        self.allocs = vec![vec![dst]];
        Ok(self.clock.now_us() - t0)
    }

    /// Compact when the sealed-segment count exceeds `max_segments`
    /// (`0` disables compaction). `Some(Ok(µs))` when a gather ran,
    /// `Some(Err(oom))` when a pass was due but the epoch heap cannot
    /// hold the transient 2× (the store is left untouched and keeps
    /// serving; the next seal retries).
    pub fn maybe_compact(&mut self, max_segments: usize) -> Option<Result<f64, OomError>> {
        if max_segments == 0 || self.sealed.len() <= max_segments {
            None
        } else {
            Some(self.compact())
        }
    }

    /// Drop all sealed epochs and release their VRAM (service `Clear`).
    /// The epoch counter keeps advancing — epochs are points in time,
    /// not storage.
    pub fn reset(&mut self) {
        for id in self.allocs.drain(..).flatten() {
            self.heap.free(id, &mut self.clock);
        }
        // Bank the largest dropped segment so the store's next seal
        // gathers into recycled capacity.
        for e in self.sealed.drain(..) {
            if let Epoch::Sealed(v) = e {
                self.bank_gather_buffer(v.data);
            }
        }
        self.starts.clear();
        self.total = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard(blocks: usize, heap_bytes: u64) -> Shard {
        Shard::new(ShardConfig {
            id: 0,
            blocks,
            first_bucket_size: 4,
            insertion: InsertionKind::WarpScan,
            device: DeviceSpec::a100(),
            heap_bytes,
        })
    }

    #[test]
    fn apply_counts_places_values_in_block_order() {
        let mut s = shard(4, 1 << 24);
        let values: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let out = s.apply_counts(&[3, 3, 2, 2], &values);
        assert_eq!(out.applied, 10);
        assert!(out.error.is_none());
        assert!(out.sim_us > 0.0);
        assert_eq!(s.len(), 10);
        // Block-major order equals the routed order here.
        for i in 0..10u64 {
            assert_eq!(s.get(i), Some(i as f32));
        }
    }

    #[test]
    fn apply_counts_oom_keeps_prefix_and_reports() {
        let mut s = shard(2, 2048); // tiny budget: 2 blocks × fbs 4 f32 fit, not much more
        let values: Vec<f32> = (0..4000).map(|i| i as f32).collect();
        let out = s.apply_counts(&[2000, 2000], &values);
        assert!(out.error.is_some());
        assert!(out.applied < 4000);
        assert_eq!(s.len(), out.applied);
        // Index stayed consistent.
        if out.applied > 0 {
            assert!(s.get(0).is_some());
        }
        assert_eq!(s.get(out.applied as u64), None);
    }

    #[test]
    fn prepare_then_fill_matches_apply_counts_exactly() {
        // The scheduler's charge/copy split must be indistinguishable
        // from the fused path: bytes, length, heap residency and the
        // exact simulated clock.
        let mut fused = shard(4, 1 << 24);
        let mut split = shard(4, 1 << 24);
        for round in 0..4 {
            let counts = [[3usize, 0, 2, 5], [0, 0, 0, 0], [40, 1, 0, 9], [7, 7, 7, 7]][round];
            let total: usize = counts.iter().sum();
            let values: Vec<f32> = (0..total).map(|i| (i * 13 + round) as f32).collect();
            let a = fused.apply_counts(&counts, &values);
            let b = split.prepare_counts(&counts, total);
            split.fill_counts(&counts, &values, b.applied);
            assert_eq!(a.applied, b.applied, "round {round}");
            assert!((a.sim_us - b.sim_us).abs() < 1e-12, "round {round}");
            assert!(a.error.is_none() && b.error.is_none());
            assert_eq!(fused.len(), split.len());
            assert_eq!(fused.heap_used(), split.heap_used(), "round {round}");
            assert_eq!(fused.sim_now_us(), split.sim_now_us(), "round {round}: exact clock");
        }
        for i in 0..fused.len() as u64 {
            assert_eq!(fused.get(i), split.get(i), "slot {i}");
        }
    }

    #[test]
    fn prepare_then_fill_oom_matches_apply_counts_prefix() {
        let mut fused = shard(2, 2048);
        let mut split = shard(2, 2048);
        let values: Vec<f32> = (0..4000).map(|i| i as f32).collect();
        let a = fused.apply_counts(&[2000, 2000], &values);
        let b = split.prepare_counts(&[2000, 2000], 4000);
        split.fill_counts(&[2000, 2000], &values, b.applied);
        assert!(a.error.is_some() && b.error.is_some());
        assert_eq!(a.applied, b.applied);
        assert!((a.sim_us - b.sim_us).abs() < 1e-12);
        assert_eq!(fused.len(), split.len());
        assert_eq!(fused.heap_used(), split.heap_used());
        assert_eq!(fused.sim_now_us(), split.sim_now_us());
        for i in 0..fused.len() as u64 {
            assert_eq!(fused.get(i), split.get(i), "slot {i}");
        }
    }

    #[test]
    fn seal_charge_plus_gather_chunks_match_seal_flatten_to_slice() {
        let build = || {
            let mut s = shard(4, 1 << 24);
            let values: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5).collect();
            s.apply_counts(&[100, 400, 250, 250], &values);
            s
        };
        let mut copy = build();
        let mut charge = build();
        let mut dst_a = vec![0.0f32; 1000];
        let mut pa = copy.seal_flatten_to_slice(&mut dst_a).unwrap();
        let mut pb = charge.seal_flatten_charge().unwrap();
        assert_eq!(pa.len, pb.len);
        assert!((pa.report.us - pb.report.us).abs() < 1e-12);
        assert_eq!(copy.heap_used(), charge.heap_used());
        assert_eq!(copy.sim_now_us(), charge.sim_now_us(), "exact clock");
        // The owed data movement, in three uneven range chunks (as the
        // scheduler would steal them), reproduces the flatten bytes.
        let mut dst_b = vec![0.0f32; 1000];
        for (start, len) in [(0usize, 7usize), (7, 600), (607, 393)] {
            charge.gather_copy_range(start, &mut dst_b[start..start + len]);
        }
        assert_eq!(dst_b, dst_a);
        copy.abort_seal(pa.alloc.take());
        charge.abort_seal(pb.alloc.take());
        assert_eq!(copy.len(), charge.len());
    }

    #[test]
    fn flatten_temp_charge_plus_gather_matches_flatten_temp_to_slice() {
        let build = || {
            let mut s = shard(2, 1 << 24);
            s.apply_counts(&[30, 12], &(0..42).map(|i| i as f32).collect::<Vec<_>>());
            s
        };
        let mut copy = build();
        let mut charge = build();
        let mut dst_a = vec![0.0f32; 42];
        assert_eq!(copy.flatten_temp_to_slice(&mut dst_a).unwrap(), 42);
        assert_eq!(charge.flatten_temp_charge().unwrap(), 42);
        let mut dst_b = vec![0.0f32; 42];
        charge.gather_copy_range(0, &mut dst_b);
        assert_eq!(dst_b, dst_a);
        assert_eq!(copy.heap_used(), charge.heap_used(), "temp destination released in both");
        assert_eq!(copy.sim_now_us(), charge.sim_now_us(), "exact clock");
        // Seal-charge OOM reopens untouched, like the copying path.
        let mut tight = shard(2, 512);
        tight.apply_counts(&[40, 40], &vec![1.0; 80]);
        if tight.len() > 0 {
            let before = tight.heap_used();
            if tight.seal_flatten_charge().is_err() {
                assert_eq!(tight.heap_used(), before);
                assert!(!tight.gg().is_sealed(), "failed seal charge must reopen");
            }
        }
    }

    #[test]
    fn commit_seal_transfers_destination_to_the_epoch_heap() {
        let mut s = shard(4, 1 << 24);
        let mut eh = VramHeap::with_capacity(DeviceSpec::a100(), 1 << 20);
        s.apply_counts(&[25, 25, 25, 25], &vec![1.0; 100]);
        let used_growable = s.heap_used();
        let mut f1 = s.seal_flatten().unwrap();
        assert_eq!(f1.data.len(), 100);
        assert!(f1.alloc.is_some(), "caller owns the destination until commit/abort");
        assert!(s.heap_used() > used_growable, "sealed dst resident in the shard heap pre-commit");
        let id1 = s.commit_seal(f1.alloc.take(), &mut eh).expect("destination transferred");
        // Growable storage released AND the sealed dst moved out: the
        // shard's budget is fully free for the next epoch, while the
        // epoch heap owns the 100 × 4 B segment.
        assert_eq!(s.heap_used(), 0, "sealed bytes must not squat on the shard budget");
        assert_eq!(eh.used(), 400);
        assert_eq!(eh.size_of(id1), Some(400));
        assert_eq!(s.len(), 0);
        // Next epoch: insert, seal again — both epochs accumulate in the
        // epoch heap, none in the shard heap.
        s.apply_counts(&[5, 5, 5, 5], &vec![2.0; 20]);
        let mut f2 = s.seal_flatten().unwrap();
        assert_eq!(f2.data.len(), 20);
        s.commit_seal(f2.alloc.take(), &mut eh);
        assert_eq!(s.heap_used(), 0);
        assert_eq!(eh.used(), 480, "both sealed epochs live in the epoch-owned heap");
    }

    #[test]
    fn pooled_seal_flatten_appends_shard_after_shard() {
        // Two shards gather into one shared destination; the assembled
        // view is byte-identical to the collecting per-shard path.
        let mut a = shard(2, 1 << 24);
        let mut b = shard(2, 1 << 24);
        a.apply_counts(&[3, 2], &[1.0, 2.0, 3.0, 4.0, 5.0]);
        b.apply_counts(&[1, 2], &[9.0, 8.0, 7.0]);
        let mut dst = Vec::new();
        let mut p1 = a.seal_flatten_into(&mut dst).unwrap();
        let mut p2 = b.seal_flatten_into(&mut dst).unwrap();
        assert_eq!((p1.len, p2.len), (5, 3));
        assert_eq!(dst, vec![1.0, 2.0, 3.0, 4.0, 5.0, 9.0, 8.0, 7.0]);
        assert!(p1.alloc.is_some() && p2.alloc.is_some());
        let (alloc1, alloc2) = (p1.alloc.take(), p2.alloc.take());
        let flat = concat_parts(&[p1, p2], dst);
        assert_eq!(flat.len(), 8);
        assert_eq!(flat.shard_start(1), 5);
        assert_eq!(flat.locate(5), Some((1, 0)));
        // Clean up the simulated destinations (abort path).
        a.abort_seal(alloc1);
        b.abort_seal(alloc2);
        assert_eq!(a.len(), 5);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn gather_pool_banks_the_largest_buffer() {
        let mut em = EpochManager::new(DeviceSpec::a100(), 1 << 20);
        assert_eq!(em.gather_pool_capacity(), 0);
        let first = em.take_gather_buffer();
        assert_eq!(first.capacity(), 0, "nothing banked yet");
        em.bank_gather_buffer(Vec::with_capacity(64));
        em.bank_gather_buffer(Vec::with_capacity(16));
        assert!(em.gather_pool_capacity() >= 64, "larger capacity wins");
        let leased = em.take_gather_buffer();
        assert!(leased.capacity() >= 64);
        assert!(leased.is_empty(), "leased buffer arrives cleared");
        assert_eq!(em.gather_pool_capacity(), 0, "pool is empty while leased");
        // Compaction refills the pool from its freed sources.
        absorb_vals(&mut em, vec![1.0; 32]);
        absorb_vals(&mut em, vec![2.0; 48]);
        em.compact().unwrap();
        assert!(em.gather_pool_capacity() >= 48, "largest freed source banked");
        // Reset banks a dropped segment too.
        em.reset();
        assert!(em.gather_pool_capacity() >= 80, "merged segment banked on reset");
    }

    #[test]
    fn commit_seal_panics_without_epoch_reservation() {
        // The contract: can_accept must be checked for the whole seal
        // before any shard commits. A too-small epoch heap at commit
        // time is a torn transaction — it must fail loudly, not leak.
        let mut s = shard(2, 1 << 24);
        let mut eh = VramHeap::with_capacity(DeviceSpec::a100(), 16);
        s.apply_counts(&[10, 10], &vec![1.0; 20]);
        let mut f = s.seal_flatten().unwrap();
        let alloc = f.alloc.take();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            s.commit_seal(alloc, &mut eh);
        }));
        assert!(result.is_err(), "unreserved commit must panic");
    }

    #[test]
    fn abort_seal_releases_destination_and_keeps_contents() {
        let mut s = shard(2, 1 << 24);
        s.apply_counts(&[10, 10], &vec![4.0; 20]);
        let used_before = s.heap_used();
        let mut f = s.seal_flatten().unwrap();
        assert!(s.heap_used() > used_before);
        s.abort_seal(f.alloc.take());
        // VRAM back to the pre-seal state, data untouched, inserts legal.
        assert_eq!(s.heap_used(), used_before);
        assert_eq!(s.len(), 20);
        assert_eq!(s.get(0), Some(4.0));
        let out = s.apply_counts(&[1, 1], &[5.0, 6.0]);
        assert!(out.error.is_none());
        assert_eq!(s.len(), 22);
    }

    #[test]
    fn flatten_temp_releases_destination() {
        let mut s = shard(2, 1 << 24);
        s.apply_counts(&[10, 10], &vec![3.0; 20]);
        let used = s.heap_used();
        let f = s.flatten_temp().unwrap();
        assert_eq!(f.data.len(), 20);
        assert_eq!(s.heap_used(), used, "temp flatten must not retain VRAM");
    }

    #[test]
    fn rollback_insert_restores_pre_op_state_byte_identically() {
        let mut s = shard(4, 1 << 24);
        s.apply_counts(&[3, 3, 2, 2], &(0..10).map(|i| i as f32).collect::<Vec<_>>());
        let (len0, cap0, used0, t0) = (s.len(), s.capacity(), s.heap_used(), s.sim_now_us());
        // A batch big enough to force fresh buckets in several blocks.
        let counts = [40usize, 1, 0, 9];
        let total: usize = counts.iter().sum();
        s.save_abort_mark();
        let out = s.prepare_counts(&counts, total);
        assert!(out.error.is_none());
        assert_eq!(s.len(), len0 + total);
        assert!(s.heap_used() > used0);
        s.rollback_insert(&counts, out.applied);
        assert_eq!(s.len(), len0);
        assert_eq!(s.capacity(), cap0, "fresh buckets freed");
        assert_eq!(s.heap_used(), used0);
        assert_eq!(s.sim_now_us(), t0, "abort must be byte-identical in sim time");
        for i in 0..10u64 {
            assert_eq!(s.get(i), Some(i as f32), "pre-op data survives the rollback");
        }
        // The shard keeps serving after the abort.
        let out2 = s.apply_counts(&[1, 1, 1, 1], &[50.0, 51.0, 52.0, 53.0]);
        assert!(out2.error.is_none());
        assert_eq!(s.len(), len0 + 4);
    }

    #[test]
    fn work_charge_rewinds_to_abort_mark() {
        let mut s = shard(2, 1 << 24);
        s.apply_counts(&[2, 1], &[1.0, 2.0, 3.0]);
        let t0 = s.sim_now_us();
        s.save_abort_mark();
        assert!(s.charge_rw_block(30.0) > 0.0);
        assert!(s.sim_now_us() > t0);
        s.rewind_abort();
        assert_eq!(s.sim_now_us(), t0, "rw_b pre-charge rewinds exactly");
    }

    #[test]
    fn work_pass_host_fallback_updates_every_element() {
        let mut s = shard(2, 1 << 24);
        s.apply_counts(&[2, 1], &[1.0, 2.0, 3.0]);
        let pjrt = s.work_pass(None, 30);
        assert_eq!(pjrt, 0);
        assert_eq!(s.get(0), Some(31.0));
        assert_eq!(s.get(2), Some(33.0));
    }

    /// Absorb host-built values into an [`EpochManager`] the way the
    /// service does: one backing allocation in the epoch heap per
    /// segment (a throwaway clock takes the malloc charge).
    fn absorb_vals(em: &mut EpochManager, vals: Vec<f32>) -> u64 {
        let bytes = vals.len() as u64 * 4;
        let mut c = crate::sim::clock::Clock::new();
        let id = em.heap_mut().alloc(bytes, &mut c).expect("test epoch heap too small");
        em.absorb(
            flatten::concat(vec![Flattened { data: vals, report: Default::default(), alloc: None }]),
            vec![id],
        )
    }

    #[test]
    fn epoch_manager_orders_and_reads_sealed_epochs() {
        let mut em = EpochManager::new(DeviceSpec::a100(), 1 << 20);
        assert_eq!(em.seq(), 0);
        assert_eq!(em.get(0), None);
        assert_eq!(absorb_vals(&mut em, vec![1.0, 2.0, 3.0]), 1);
        assert_eq!(absorb_vals(&mut em, vec![10.0]), 2);
        assert_eq!(em.sealed_len(), 4);
        assert_eq!(em.sealed_bytes(), 16, "epoch heap holds exactly the sealed bytes");
        assert_eq!(em.sealed_epochs(), 2);
        assert_eq!(em.get(0), Some(1.0));
        assert_eq!(em.get(2), Some(3.0));
        assert_eq!(em.get(3), Some(10.0));
        assert_eq!(em.get(4), None);
        let mut all: Vec<f32> = Vec::new();
        for segment in em.segments() {
            all.extend_from_slice(segment);
        }
        assert_eq!(all, vec![1.0, 2.0, 3.0, 10.0]);
        // Work applies everywhere and charges the flat-path clock.
        let us = em.work(30);
        assert!(us > 0.0);
        assert_eq!(em.get(0), Some(31.0));
        assert_eq!(em.get(3), Some(40.0));
        assert!((em.now_us() - us).abs() < 1e-9);
        em.reset();
        assert_eq!(em.sealed_len(), 0);
        assert_eq!(em.sealed_bytes(), 0, "reset must release the sealed store's VRAM");
        assert_eq!(em.seq(), 2, "epoch counter survives reset");
    }

    #[test]
    fn compaction_merges_segments_byte_identically() {
        let mut em = EpochManager::new(DeviceSpec::a100(), 1 << 20);
        absorb_vals(&mut em, vec![1.0, 2.0]);
        absorb_vals(&mut em, vec![3.0]);
        absorb_vals(&mut em, vec![4.0, 5.0, 6.0]);
        let before: Vec<f32> = em.segments().flat_map(|s| s.to_vec()).collect();
        assert_eq!(em.sealed_epochs(), 3);
        assert!(em.maybe_compact(4).is_none(), "under threshold: no pass");
        assert!(em.maybe_compact(0).is_none(), "0 disables compaction");
        let us = em.maybe_compact(2).expect("over threshold: gather pass").expect("budget fits");
        assert!(us > 0.0, "gather pass must charge the flat-path clock");
        assert_eq!(em.sealed_epochs(), 1);
        assert_eq!(em.sealed_len(), 6);
        let after: Vec<f32> = em.segments().flat_map(|s| s.to_vec()).collect();
        assert_eq!(after, before, "compaction must not change sealed bytes");
        assert_eq!(em.sealed_bytes(), 24, "steady-state residency unchanged by the merge");
        assert_eq!(em.heap().peak(), 48, "the gather's transient 2× went through the heap");
        assert_eq!(em.get(0), Some(1.0));
        assert_eq!(em.get(5), Some(6.0));
        assert_eq!(em.get(6), None);
        assert_eq!(em.seq(), 3, "compaction is storage-only; epochs are points in time");
        // A single segment is already compact: no-op, no charge.
        assert_eq!(em.compact().unwrap(), 0.0);
        assert_eq!(em.sealed_epochs(), 1);
    }

    #[test]
    fn compaction_oom_aborts_byte_identically() {
        // Budget fits the sealed bytes (3 × 8 elements = 96 B) but not
        // the merge's transient 2× (needs another 96 B, only 32 free).
        let mut em = EpochManager::new(DeviceSpec::a100(), 128);
        absorb_vals(&mut em, (0..8).map(|i| i as f32).collect());
        absorb_vals(&mut em, (8..16).map(|i| i as f32).collect());
        absorb_vals(&mut em, (16..24).map(|i| i as f32).collect());
        assert_eq!(em.sealed_bytes(), 96);
        let before: Vec<f32> = em.segments().flat_map(|s| s.to_vec()).collect();
        let t_before = em.now_us();
        let err = em.maybe_compact(2).expect("over threshold").unwrap_err();
        assert_eq!(err.requested, 96);
        assert_eq!(err.free, 32);
        // Abort is byte-identical: segments, bytes, length, residency and
        // even the flat-path clock are exactly as before.
        assert_eq!(em.sealed_epochs(), 3, "segments retained");
        assert_eq!(em.sealed_len(), 24);
        assert_eq!(em.sealed_bytes(), 96);
        let after: Vec<f32> = em.segments().flat_map(|s| s.to_vec()).collect();
        assert_eq!(after, before);
        assert_eq!(em.now_us(), t_before, "failed reserve must not charge time");
        assert_eq!(em.get(23), Some(23.0));
        // An adequate budget commits: same bytes, one segment, sources
        // freed (residency back to 1× after the transient).
        let mut big = EpochManager::new(DeviceSpec::a100(), 192);
        absorb_vals(&mut big, (0..8).map(|i| i as f32).collect());
        absorb_vals(&mut big, (8..16).map(|i| i as f32).collect());
        absorb_vals(&mut big, (16..24).map(|i| i as f32).collect());
        let us = big.maybe_compact(2).expect("over threshold").expect("2× transient fits");
        assert!(us > 0.0);
        assert_eq!(big.sealed_epochs(), 1);
        assert_eq!(big.sealed_bytes(), 96, "sources freed on commit");
        assert_eq!(big.heap().peak(), 192);
        let merged: Vec<f32> = big.segments().flat_map(|s| s.to_vec()).collect();
        assert_eq!(merged, before, "compaction under a tight-but-adequate budget is byte-identical");
    }

    #[test]
    fn epoch_enum_lifecycle() {
        let e: Epoch<f32> = Epoch::Inserting;
        assert!(!e.is_sealed());
        assert!(e.sealed().is_none());
        assert_eq!(e.len(), 0);
        let sealed = Epoch::Sealed(flatten::concat(vec![Flattened {
            data: vec![5.0f32, 6.0],
            report: Default::default(),
            alloc: None,
        }]));
        assert!(sealed.is_sealed());
        assert_eq!(sealed.len(), 2);
        assert_eq!(sealed.sealed().unwrap().get(1), Some(6.0));
    }

    #[test]
    fn sealed_work_cheaper_than_unsealed_rw_b_per_element() {
        // The acceptance shape: one work pass over n elements costs less
        // through the sealed flat path than through the GgArray rw_b path.
        let n = 1 << 20;
        let mut s = shard(32, 1 << 30);
        let counts = vec![n / 32; 32];
        s.apply_counts(&counts, &vec![0.5; n]);
        let unsealed_us = s.charge_rw_block(30.0);
        let mut em = EpochManager::new(DeviceSpec::a100(), 1 << 30);
        let mut flat = s.seal_flatten().unwrap();
        let id = s.commit_seal(flat.alloc.take(), em.heap_mut()).expect("transferred");
        em.absorb(flatten::concat(vec![flat]), vec![id]);
        let sealed_us = em.work(30);
        assert!(
            sealed_us < unsealed_us / 2.0,
            "sealed {sealed_us} µs !≪ unsealed {unsealed_us} µs"
        );
    }
}
