//! Service-worker supervisor: detect → respawn → replay.
//!
//! [`Coordinator::try_start`](super::service::Coordinator::try_start)
//! spawns ONE OS thread, and that thread runs [`supervise`] — not the
//! worker loop directly. The supervisor owns the [`Worker`] state and
//! the request receiver, and drives the handler loop
//! ([`Worker::serve`]) under a containment net:
//!
//! ```text
//!            ┌────────────────────────────────────────────┐
//!            ▼                                            │
//!   SERVING: catch_unwind(worker.serve(rx, &mut inflight))│
//!      │ Ok(())                  │ Err(payload)           │
//!      ▼                         ▼                        │
//!   STOPPED                   DETECTED: worker died       │
//!   (graceful shutdown,          │ note_restart()         │
//!    or every sender gone)       ▼                        │
//!                             REPLAY: inflight.take()     │
//!                                │ Some(f): replay f      │
//!                                │   exactly once         │
//!                                │   (note_replay)        │
//!                                │ None: nothing un-acked │
//!                                └── RESPAWN: loop ───────┘
//! ```
//!
//! The worker *state* — shards, sealed epochs, batcher, metrics,
//! scheduler, client lanes — survives the death untouched: the "respawn"
//! re-enters the handler loop over the same `Worker` value on the same
//! OS thread, so every channel stays connected and no session ever
//! observes `Closed`. What makes the replay **exactly-once** is the
//! record/clear protocol in [`Worker::serve`]: the in-flight call is
//! recorded *before* the fatal-fault site (before any mutation the call
//! performs) and cleared only *after* it was fully handled and acked.
//! A death therefore finds either `None` (the last call completed — its
//! effects and ack stand, nothing to redo) or `Some` of a call that has
//! mutated nothing — replaying it is indistinguishable from a fresh
//! execution. There is no state in which a half-applied call could be
//! replayed. The `tests/model_check.rs` supervisor suite pins this
//! (no lost and no doubled replay in any interleaving), and the chaos
//! matrix's Fatal tier asserts the client-observable consequence:
//! byte-identical traces vs the fault-free oracle with sessions open.
//!
//! A panic escaping the *replay* itself is the one non-transparent
//! case: the request's reply sender is dropped un-acked, so the caller
//! gets a typed `ServiceDown` (never a hang) and the loss is ledgered
//! (`errors`); the supervisor then resumes serving. Model-checker
//! cancellation tokens pass through both nets untouched, as everywhere.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

use crate::sync::mpsc::Receiver;

use super::service::{Envelope, InFlight, Worker};

/// Run the worker's handler loop to completion, surviving loop-level
/// panics by respawning the loop over the same state and replaying the
/// un-acked request exactly once. Restarts and replays are ledgered in
/// the worker's metrics (`worker_restarts` / `replayed_requests`).
pub(crate) fn supervise(mut worker: Worker, rx: Receiver<Envelope>) {
    let mut inflight: Option<InFlight> = None;
    loop {
        match catch_unwind(AssertUnwindSafe(|| worker.serve(&rx, &mut inflight))) {
            // Clean exit: Shutdown handled+acked, or all senders gone.
            Ok(()) => return,
            Err(payload) => {
                // The model checker cancels losing branches by unwinding
                // a private token through every frame — scheduler
                // machinery, not a worker fault; pass it through.
                if crate::checker::rt::cancelled() {
                    resume_unwind(payload);
                }
                worker.note_restart();
                if let Some(f) = inflight.take() {
                    worker.note_replay();
                    // The replay runs the full call path (barrier drain
                    // + handle + ack) but NOT the fatal-fault site —
                    // that lives in `serve`'s receive arm — so one armed
                    // fatal plan cannot re-kill its own replay; chaos
                    // composes a second step for that instead.
                    match catch_unwind(AssertUnwindSafe(|| {
                        worker.complete_call(f.req, f.reply)
                    })) {
                        Ok(stop) => {
                            if stop {
                                return;
                            }
                        }
                        Err(payload) => {
                            if crate::checker::rt::cancelled() {
                                resume_unwind(payload);
                            }
                            // Replay died too: the reply sender is gone
                            // (caller sees typed ServiceDown), the loss
                            // is ledgered, and serving resumes.
                            worker.note_failed_replay();
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::frontend::FrontendShared;
    use crate::coordinator::request::{Request, Response};
    use crate::coordinator::service::CoordinatorConfig;
    use crate::sync::mpsc;
    use crate::sync::thread;
    use crate::sync::Arc;

    fn cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            blocks: 4,
            first_bucket_size: 16,
            use_artifacts: false,
            ..CoordinatorConfig::default()
        }
    }

    /// Drive `supervise` directly (no `Coordinator` wrapper): the
    /// fault-free path must behave exactly like the plain worker loop —
    /// serve calls, ack them, stop on Shutdown, zero restarts.
    #[test]
    fn supervisor_is_transparent_without_faults() {
        let (tx, rx) = mpsc::channel();
        let shared = Arc::new(FrontendShared::default());
        let worker = Worker::new(cfg(), shared);
        let h = thread::Builder::new()
            .name("supervise-test".into())
            .spawn(move || supervise(worker, rx))
            .expect("spawn");
        let call = |req: Request| {
            let (rtx, rrx) = mpsc::channel();
            tx.send(Envelope::Call(req, rtx)).expect("send");
            rrx.recv().expect("reply")
        };
        let (count, _, _) = call(Request::Insert { values: vec![1.0, 2.0, 3.0] }).expect_inserted();
        assert_eq!(count, 3);
        let snap = call(Request::Stats).expect_stats();
        assert_eq!(snap.len, 3);
        assert_eq!(snap.worker_restarts, 0);
        assert_eq!(snap.replayed_requests, 0);
        assert!(matches!(call(Request::Shutdown), Response::ShuttingDown));
        h.join().expect("clean join after shutdown");
    }

    /// Dropping every sender (no Shutdown request) must also end the
    /// supervisor loop — the Disconnected exit is a clean one.
    #[test]
    fn supervisor_exits_when_all_senders_drop() {
        let (tx, rx) = mpsc::channel::<Envelope>();
        let shared = Arc::new(FrontendShared::default());
        let worker = Worker::new(cfg(), shared);
        let h = thread::Builder::new()
            .name("supervise-drop".into())
            .spawn(move || supervise(worker, rx))
            .expect("spawn");
        drop(tx);
        h.join().expect("clean join after disconnect");
    }
}
