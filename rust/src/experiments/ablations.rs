//! Ablation studies over the design choices DESIGN.md calls out:
//!
//! * **A1 — first bucket size**: memory overhead vs grow cost (more,
//!   smaller buckets track the live size tighter but pay more
//!   allocations).
//! * **A2 — insertion algorithm × structure**: the Fig 4 col-1 matrix
//!   extended to GGArray shapes (per-block counters change the atomic
//!   story).
//! * **A3 — routing policy**: block-size imbalance (and therefore the
//!   rw_b critical path) under skewed insert batches.
//! * **A4 — batching**: simulated per-insert cost vs batch size — why
//!   the coordinator amortises kernel launches.

use crate::coordinator::router::{self, Policy};
use crate::ggarray::array::{GgArray, GgConfig};
use crate::insertion::{self, InsertionKind, InsertShape};
use crate::sim::spec::DeviceSpec;
use crate::util::csv::CsvTable;
use crate::util::rng::Rng;

use super::report::Report;

/// A1: first-bucket-size sweep on a real 1e6-element structure.
pub fn first_bucket_sweep() -> CsvTable {
    let spec = DeviceSpec::a100();
    let mut t = CsvTable::new(["first_bucket", "buckets_allocated", "grow+insert_sim_ms", "overhead_x"]);
    let data: Vec<u32> = (0..1_000_000).collect();
    for fbs in [64usize, 256, 1024, 4096, 16384] {
        let mut gg: GgArray<u32> = GgArray::new(GgConfig::new(512).with_first_bucket(fbs), spec.clone());
        let rep = gg.grow_and_insert(&data, InsertionKind::WarpScan);
        t.push_display([
            fbs.to_string(),
            rep.buckets_allocated.to_string(),
            format!("{:.4}", rep.total_ms()),
            format!("{:.3}", gg.overhead_ratio()),
        ]);
    }
    t
}

/// A2: insertion algorithm × (counters, write-eff) matrix at 5.12e8.
pub fn insertion_matrix() -> CsvTable {
    let spec = DeviceSpec::a100();
    let n = 512_000_000u64;
    let mut t = CsvTable::new(["structure", "atomic_ms", "warp_scan_ms", "mxu_scan_ms"]);
    let shapes = [
        ("static (1 counter)", InsertShape::static_array(&spec, n, n, 4)),
        (
            "GGArray512 (512 counters)",
            InsertShape {
                threads: n,
                inserts: n,
                elem_bytes: 4,
                blocks: 512,
                threads_per_block: 1024,
                counters: 512,
                write_eff: spec.cost.ggarray_insert_eff,
            },
        ),
        (
            "GGArray32 (32 counters)",
            InsertShape {
                threads: n,
                inserts: n,
                elem_bytes: 4,
                blocks: 32,
                threads_per_block: 1024,
                counters: 32,
                write_eff: spec.cost.ggarray_insert_eff,
            },
        ),
    ];
    for (name, shape) in shapes {
        let ms = |k| insertion::cost_us(&spec, k, &shape) / 1e3;
        t.push_display([
            name.to_string(),
            format!("{:.2}", ms(InsertionKind::Atomic)),
            format!("{:.2}", ms(InsertionKind::WarpScan)),
            format!("{:.2}", ms(InsertionKind::MxuScan)),
        ]);
    }
    t
}

/// A3: routing policy vs imbalance under skewed batches.
pub fn routing_imbalance() -> CsvTable {
    let mut t = CsvTable::new(["policy", "batches", "final_max/min", "rw_b_critical_path_x"]);
    for policy in [Policy::Even, Policy::LeastLoaded, Policy::Hash] {
        let mut rng = Rng::new(77);
        let blocks = 64usize;
        let mut sizes = vec![0u64; blocks];
        // Skew: batches arrive in bursts sized LogNormal, and between
        // batches a random block gets hot direct appends (hot-key skew).
        let batches = 200;
        for seq in 0..batches {
            let n = (rng.lognormal(0.0, 1.0) * 500.0).max(1.0) as usize;
            let counts = router::route(policy, &sizes, n, seq);
            for (b, c) in counts.iter().enumerate() {
                sizes[b] += *c as u64;
            }
            // Hot-key appends bypassing the router (worst case for Even).
            let hot = rng.below(blocks as u64) as usize;
            sizes[hot] += rng.below(200);
        }
        let max = *sizes.iter().max().unwrap() as f64;
        let min = *sizes.iter().min().unwrap() as f64;
        let mean = sizes.iter().sum::<u64>() as f64 / blocks as f64;
        t.push_display([
            policy.name().to_string(),
            batches.to_string(),
            format!("{:.3}", max / min.max(1.0)),
            // rw_b ends when the largest LFVector finishes.
            format!("{:.3}", max / mean),
        ]);
    }
    t
}

/// A4: batch size vs simulated per-element insert cost (launch/scan
/// amortisation) at 512 blocks.
pub fn batching_amortisation() -> CsvTable {
    let spec = DeviceSpec::a100();
    let mut t = CsvTable::new(["batch_size", "sim_us_per_batch", "sim_ns_per_element"]);
    for batch in [64u64, 512, 4096, 32768, 262144, 2097152] {
        let shape = InsertShape {
            threads: batch,
            inserts: batch,
            elem_bytes: 4,
            blocks: 512.min(batch / 32).max(1),
            threads_per_block: 1024,
            counters: 512,
            write_eff: spec.cost.ggarray_insert_eff,
        };
        let us = insertion::cost_us(&spec, InsertionKind::WarpScan, &shape);
        t.push_display([
            batch.to_string(),
            format!("{:.3}", us),
            format!("{:.2}", us * 1e3 / batch as f64),
        ]);
    }
    t
}

/// A5: bucket allocation through the buddy sub-allocator vs driver
/// mallocs — the §II.D "memory managers can complement GGArray" claim,
/// quantified on the grow phase.
pub fn suballoc_grow() -> CsvTable {
    use crate::sim::clock::Clock;
    use crate::sim::memory::VramHeap;
    use crate::sim::suballoc::BuddyAllocator;
    let spec = DeviceSpec::a100();
    let mut t = CsvTable::new(["buckets", "bucket_kib", "driver_ms", "buddy_ms", "speedup", "buddy_slab_allocs"]);
    for (buckets, bucket_kib) in [(32u32, 4096u64), (512, 256), (2048, 64), (8192, 16)] {
        let bytes = bucket_kib * 1024;
        // Driver path: one cudaMalloc per bucket (what GGArray's
        // new_bucket does today).
        let mut heap = VramHeap::new(spec.clone());
        let mut clock = Clock::new();
        for _ in 0..buckets {
            heap.alloc(bytes, &mut clock).unwrap();
        }
        let driver_us = clock.now_us();
        // Buddy path: slabs of 64 MiB, device-side splits.
        let mut heap2 = VramHeap::new(spec.clone());
        let mut clock2 = Clock::new();
        let mut buddy = BuddyAllocator::new(64 << 20, 4096);
        for _ in 0..buckets {
            buddy.alloc(bytes, &mut heap2, &mut clock2).unwrap();
        }
        let buddy_us = clock2.now_us();
        t.push_display([
            buckets.to_string(),
            bucket_kib.to_string(),
            format!("{:.3}", driver_us / 1e3),
            format!("{:.3}", buddy_us / 1e3),
            format!("{:.1}", driver_us / buddy_us),
            buddy.slab_allocs().to_string(),
        ]);
    }
    t
}

/// A6: shard-parallel scaling under the parallel time model — the same
/// insert-heavy stream at 1..8 shards, reporting the critical-path
/// wall-model, the aggregate device-seconds, and the speedup the old
/// sum-over-shards ledger could never show.
pub fn shard_scaling() -> CsvTable {
    use crate::coordinator::batcher::BatchConfig;
    use crate::coordinator::request::Request;
    use crate::coordinator::service::{Coordinator, CoordinatorConfig};
    let mut t = CsvTable::new(["shards", "sim_insert_ms", "device_insert_ms", "speedup_vs_1shard"]);
    let inserts = 1usize << 16;
    let chunk = 4096usize;
    let mut sim1 = f64::NAN;
    for shards in [1usize, 2, 4, 8] {
        let c = Coordinator::start(CoordinatorConfig {
            blocks: 64,
            shards,
            first_bucket_size: 64,
            use_artifacts: false,
            batch: BatchConfig { max_values: chunk, max_delay: std::time::Duration::from_secs(3600) },
            ..CoordinatorConfig::default()
        });
        let mut sent = 0usize;
        while sent < inserts {
            let n = chunk.min(inserts - sent);
            c.call(Request::Insert { values: vec![1.0f32; n] });
            sent += n;
        }
        // Stats barriers pending batches itself.
        let snap = c.call(Request::Stats).expect_stats();
        c.shutdown();
        if shards == 1 {
            sim1 = snap.sim_insert_ms;
        }
        t.push_display([
            shards.to_string(),
            format!("{:.4}", snap.sim_insert_ms),
            format!("{:.4}", snap.device_insert_ms),
            // Defined 1.0 for an idle insert ledger — no silent 0/0.
            format!(
                "{:.2}",
                if snap.sim_insert_ms > 0.0 { sim1 / snap.sim_insert_ms } else { 1.0 }
            ),
        ]);
    }
    t
}

pub fn run() -> Report {
    let mut rep = Report::new("ablations", "Design-choice ablations (first bucket, insertion, routing, batching)");
    rep.add_with_notes(
        "A1 first bucket size",
        first_bucket_sweep(),
        vec![
            "Smaller first buckets → tighter memory but more allocations; 1024 balances both (the default).".into(),
            "fbs=16384 at 512 blocks shows the floor pathology: min capacity B·fbs = 8.4M slots ≫ 1M live → 8.4× overhead.".into(),
        ],
    );
    rep.add_with_notes(
        "A2 insertion algorithm x structure",
        insertion_matrix(),
        vec![
            "Single global counter (static): scan wins by ~4× — the paper's Fig 4 result.".into(),
            "Per-LFVector counters dilute atomic contention ~B×, making atomic competitive again (it also skips the scan's aux traffic) — an insight the per-block design enables but the paper does not explore.".into(),
        ],
    );
    rep.add_with_notes(
        "A3 routing policy under skew",
        routing_imbalance(),
        vec!["LeastLoaded bounds the rw_b critical path under hot-key skew; Even does not.".into()],
    );
    rep.add_with_notes(
        "A4 batching amortisation",
        batching_amortisation(),
        vec!["Per-element cost falls ~100x from 64-element to 2M-element batches — the batcher's reason to exist.".into()],
    );
    rep.add_with_notes(
        "A5 buddy sub-allocator grow phase",
        suballoc_grow(),
        vec!["Slab + device-side buddy splits vs one driver malloc per bucket (§II.D: why allocator research complements GGArray). GGArray512's 8.76 ms grow drops to sub-ms.".into()],
    );
    rep.add_with_notes(
        "A6 shard-parallel scaling (parallel time model)",
        shard_scaling(),
        vec![
            "Critical-path sim time falls with shard count (shards are concurrent block groups); device totals stay ~flat — the ledger now models the paper's block-parallel speedup instead of summing shard clocks.".into(),
        ],
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a1_fbs_tradeoff() {
        let t = first_bucket_sweep();
        let ovh: Vec<f64> = t.rows().iter().map(|r| r[3].parse().unwrap()).collect();
        let allocs: Vec<f64> = t.rows().iter().map(|r| r[1].parse().unwrap()).collect();
        // Bigger first buckets → fewer allocations...
        for w in allocs.windows(2) {
            assert!(w[1] <= w[0], "{allocs:?}");
        }
        // ...and overhead bounded by 2+ε while above the B·fbs floor
        // (fbs ≤ 1024 at 512 blocks / 1e6 elements)...
        for (row, o) in t.rows().iter().zip(&ovh) {
            let fbs: usize = row[0].parse().unwrap();
            if fbs <= 1024 {
                assert!(*o < 2.2, "fbs {fbs}: {o}");
            }
        }
        // ...but the floor pathology bites at fbs=16384: wasteful.
        assert!(*ovh.last().unwrap() > 4.0, "{ovh:?}");
    }

    #[test]
    fn a2_counter_count_changes_the_winner() {
        let t = insertion_matrix();
        // Single global counter (the paper's Fig 4 setting): scan wins.
        let static_row = &t.rows()[0];
        let (st_atomic, st_scan): (f64, f64) = (static_row[1].parse().unwrap(), static_row[2].parse().unwrap());
        assert!(st_scan < st_atomic, "paper result must hold: {static_row:?}");
        // Per-block counters relieve atomic contention by ~B×.
        let gg512_atomic: f64 = t.rows()[1][1].parse().unwrap();
        assert!(gg512_atomic < st_atomic / 2.0);
        // And the scan's relative advantage disappears (the ablation's
        // finding — aux traffic dominates once contention is gone).
        let gg512_scan: f64 = t.rows()[1][2].parse().unwrap();
        assert!(gg512_atomic < gg512_scan * 1.2, "atomic should be competitive: {gg512_atomic} vs {gg512_scan}");
    }

    #[test]
    fn a3_least_loaded_best_balance() {
        let t = routing_imbalance();
        let get = |p: &str| -> f64 {
            t.rows().iter().find(|r| r[0] == p).unwrap()[2].parse().unwrap()
        };
        assert!(get("least_loaded") < get("even"));
        assert!(get("least_loaded") < get("hash"));
    }

    #[test]
    fn a5_buddy_speedup_everywhere() {
        let t = suballoc_grow();
        for row in t.rows() {
            let speedup: f64 = row[4].parse().unwrap();
            assert!(speedup > 3.0, "{row:?}");
            // Slab count far below bucket count (driver-path savings).
            let buckets: f64 = row[0].parse().unwrap();
            let slabs: f64 = row[5].parse().unwrap();
            assert!(slabs < buckets / 4.0, "{row:?}");
        }
    }

    #[test]
    fn a6_shard_scaling_speedup_visible() {
        let t = shard_scaling();
        let sim: Vec<f64> = t.rows().iter().map(|r| r[1].parse().unwrap()).collect();
        let dev: Vec<f64> = t.rows().iter().map(|r| r[2].parse().unwrap()).collect();
        // Critical path shrinks from 1 shard to 4.
        assert!(sim[2] < sim[0], "{sim:?}");
        // Device totals are the sum view: never below the wall-model.
        for (s, d) in sim.iter().zip(&dev) {
            assert!(d >= s, "device {d} < sim {s}");
        }
    }

    #[test]
    fn a4_amortisation_two_orders() {
        let t = batching_amortisation();
        let first: f64 = t.rows().first().unwrap()[2].parse().unwrap();
        let last: f64 = t.rows().last().unwrap()[2].parse().unwrap();
        assert!(first / last > 50.0, "amortisation {first} → {last}");
    }
}
