//! Fig 3: theoretic memory usage of GGArray vs static/semi-static arrays
//! under a LogNormal(0, σ) growth factor, σ ∈ [0, 2].
//!
//! Series (all relative to the base size `s`):
//! optimal, static (1% failure provision = q99), semi-static doubling
//! (copy peak), memMap (page-mapped doubling), GGArray expected, and the
//! worst GGArray ratio observed — which §V bounds by 2×.

use crate::theory::memory_model;
use crate::util::csv::CsvTable;

use super::report::Report;

pub struct Params {
    pub base_size: u64,
    pub blocks: u64,
    pub first_bucket: u64,
    pub sigma_max: f64,
    pub steps: u32,
    pub draws: u32,
    pub seed: u64,
}

impl Default for Params {
    fn default() -> Params {
        Params {
            base_size: 1_000_000,
            blocks: 512,
            // Small first buckets keep the B·fbs floor (524k slots at
            // fbs=1024) well below the 1e6 base size — the asymptotic
            // regime Fig 3 plots.
            first_bucket: 64,
            sigma_max: 2.0,
            steps: 40,
            draws: 4000,
            seed: 42,
        }
    }
}

pub fn run(p: &Params) -> Report {
    let curve = memory_model::sweep(p.sigma_max, p.steps, p.base_size, p.blocks, p.first_bucket, p.draws, p.seed);
    let mut t = CsvTable::new([
        "sigma",
        "optimal",
        "static_p99",
        "semistatic_peak",
        "memmap_peak",
        "ggarray",
        "ggarray_worst_ratio",
    ]);
    for pt in &curve.points {
        t.push_display([
            format!("{:.3}", pt.sigma),
            format!("{:.4}", pt.optimal),
            format!("{:.4}", pt.static_p99),
            format!("{:.4}", pt.semistatic),
            format!("{:.4}", pt.memmap),
            format!("{:.4}", pt.ggarray),
            format!("{:.4}", pt.ggarray_worst_ratio),
        ]);
    }
    let mut rep = Report::new("fig3", "Theoretic memory usage vs growth-factor uncertainty");
    rep.add_with_notes(
        "memory vs sigma",
        t,
        vec![
            format!(
                "base size {} elements, {} LFVectors, first bucket {}",
                p.base_size, p.blocks, p.first_bucket
            ),
            "Expected paper shape: static_p99 explodes (e^{2.326σ}); GGArray tracks optimal within 2×.".into(),
        ],
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_shape_holds() {
        let p = Params { steps: 8, draws: 800, ..Params::default() };
        let rep = run(&p);
        let table = &rep.sections[0].table;
        assert_eq!(table.len(), 9);
        let first = &table.rows()[0];
        let last = table.rows().last().unwrap();
        let static_lo: f64 = first[2].parse().unwrap();
        let static_hi: f64 = last[2].parse().unwrap();
        assert!((static_lo - 1.0).abs() < 1e-6);
        assert!(static_hi > 100.0);
        // GGArray expected usage ≤ 2× optimal at every σ; worst asymptotic
        // draw ratio ≤ ~2.15 (bucket-boundary overshoot, see theory docs).
        for row in table.rows() {
            let expected: f64 = row[5].parse::<f64>().unwrap() / row[1].parse::<f64>().unwrap();
            assert!(expected < 2.1, "sigma {} expected ratio {expected}", row[0]);
            let worst: f64 = row[6].parse().unwrap();
            assert!(worst < 2.2, "sigma {} worst {worst}", row[0]);
        }
    }
}
