//! Fig 4: (col 1) insertion-algorithm comparison over 10 doublings,
//! (col 2) grow+insert time vs number of LFVectors, (col 3) rw_g / rw_b
//! time vs number of LFVectors — on both device models.
//!
//! Paper-scale sizes (up to 1.024e9 elements) don't fit host RAM as real
//! buffers, so these runners evaluate the calibrated cost model directly;
//! the same code paths are validated against real data movement at small
//! sizes by the unit/integration tests.

use crate::insertion::{self, InsertionKind, InsertShape};
use crate::sim::kernel::{self, KernelProfile};
use crate::sim::spec::DeviceSpec;
use crate::util::csv::CsvTable;

use super::report::Report;

pub struct Params {
    pub start_size: u64,
    pub doublings: u32,
    pub block_sweep: Vec<u64>,
    pub elem_bytes: u64,
}

impl Default for Params {
    fn default() -> Params {
        Params {
            start_size: 1_000_000,
            doublings: 10,
            block_sweep: (0..=14).map(|i| 1u64 << i).collect(), // 1 … 16384
            elem_bytes: 4,
        }
    }
}

fn specs() -> [DeviceSpec; 2] {
    [DeviceSpec::titan_rtx(), DeviceSpec::a100()]
}

/// Col 1: insertion algorithms on a static array over the doubling sweep.
pub fn insertion_part(p: &Params) -> CsvTable {
    let mut t = CsvTable::new(["gpu", "iteration", "size", "atomic_ms", "warp_scan_ms", "mxu_scan_ms"]);
    for spec in specs() {
        let mut size = p.start_size;
        for it in 0..p.doublings {
            let shape = InsertShape::static_array(&spec, size, size, p.elem_bytes);
            let ms = |k| insertion::cost_us(&spec, k, &shape) / 1e3;
            t.push_display([
                spec.name.to_string(),
                it.to_string(),
                size.to_string(),
                format!("{:.4}", ms(InsertionKind::Atomic)),
                format!("{:.4}", ms(InsertionKind::WarpScan)),
                format!("{:.4}", ms(InsertionKind::MxuScan)),
            ]);
            size *= 2;
        }
    }
    t
}

/// Modeled GGArray grow cost: each LFVector allocates one doubling bucket
/// sized ≈ its current share; allocations serialise on the device heap.
pub fn modeled_grow_us(spec: &DeviceSpec, blocks: u64, total_new_bytes: u64) -> f64 {
    let per_block_mib = total_new_bytes as f64 / blocks as f64 / (1024.0 * 1024.0);
    spec.cost.kernel_launch_us
        + blocks as f64 * (spec.cost.malloc_base_us + spec.cost.malloc_per_mib_us * per_block_mib)
}

/// Modeled GGArray insert cost for `n` elements into a `blocks`-LFVector
/// structure.
pub fn modeled_insert_us(spec: &DeviceSpec, blocks: u64, n: u64, elem_bytes: u64) -> f64 {
    let shape = InsertShape {
        threads: n,
        inserts: n,
        elem_bytes,
        blocks,
        threads_per_block: 1024,
        counters: blocks,
        write_eff: spec.cost.ggarray_insert_eff,
    };
    insertion::cost_us(spec, InsertionKind::WarpScan, &shape)
}

/// Modeled rw_b cost over `n` elements.
pub fn modeled_rw_b_us(spec: &DeviceSpec, blocks: u64, n: u64, elem_bytes: u64, flops_per_elem: f64) -> f64 {
    let chunks = crate::util::math::ceil_div(crate::util::math::ceil_div(n.max(1), blocks), 1024);
    let p = KernelProfile {
        blocks,
        threads_per_block: 1024,
        bytes: 2.0 * elem_bytes as f64 * n as f64,
        coalescing_eff: spec.cost.ggarray_block_eff,
        flops_fp32: flops_per_elem * n as f64,
        flops_mxu: 0.0,
        mxu_utilisation: 1.0,
        per_block_us: chunks as f64 * spec.cost.rw_chunk_overhead_us,
        atomic_us: 0.0,
        extra_us: 0.0,
    };
    kernel::model(spec, &p).total_us
}

/// Modeled rw_g cost (one thread per element, binary search over B).
pub fn modeled_rw_g_us(spec: &DeviceSpec, blocks: u64, n: u64, elem_bytes: u64, flops_per_elem: f64) -> f64 {
    let depth = (blocks.max(1) as f64).log2().ceil();
    let p = KernelProfile {
        blocks: crate::util::math::ceil_div(n.max(1), 1024),
        threads_per_block: 1024,
        bytes: 2.0 * elem_bytes as f64 * n as f64,
        coalescing_eff: spec.cost.ggarray_global_eff,
        flops_fp32: (flops_per_elem + 4.0 * depth) * n as f64,
        flops_mxu: 0.0,
        mxu_utilisation: 1.0,
        per_block_us: 0.0,
        atomic_us: 0.0,
        extra_us: 0.0,
    };
    kernel::model(spec, &p).total_us
}

/// Col 2: grow+insert duplication time vs #LFVectors at the final size.
pub fn blocks_part(p: &Params) -> CsvTable {
    let final_inserts = p.start_size << (p.doublings - 1); // last duplication
    let mut t = CsvTable::new(["gpu", "blocks", "grow_ms", "insert_ms", "total_ms"]);
    for spec in specs() {
        for &b in &p.block_sweep {
            let grow = modeled_grow_us(&spec, b, final_inserts * p.elem_bytes);
            let ins = modeled_insert_us(&spec, b, final_inserts, p.elem_bytes);
            t.push_display([
                spec.name.to_string(),
                b.to_string(),
                format!("{:.4}", grow / 1e3),
                format!("{:.4}", ins / 1e3),
                format!("{:.4}", (grow + ins) / 1e3),
            ]);
        }
    }
    t
}

/// Col 3: rw_g vs rw_b vs #LFVectors at the final size.
pub fn rw_part(p: &Params) -> CsvTable {
    let n = p.start_size << p.doublings;
    let mut t = CsvTable::new(["gpu", "blocks", "rw_g_ms", "rw_b_ms"]);
    for spec in specs() {
        for &b in &p.block_sweep {
            t.push_display([
                spec.name.to_string(),
                b.to_string(),
                format!("{:.4}", modeled_rw_g_us(&spec, b, n, p.elem_bytes, 30.0) / 1e3),
                format!("{:.4}", modeled_rw_b_us(&spec, b, n, p.elem_bytes, 30.0) / 1e3),
            ]);
        }
    }
    t
}

pub fn run(p: &Params) -> Report {
    let mut rep = Report::new("fig4", "Insertion, grow+insert and r/w times over size and number of LFVectors");
    rep.add_with_notes(
        "col1 insertion algorithms",
        insertion_part(p),
        vec!["Expected: atomic slowest; warp scan fastest; tensor/MXU scan between, with a smaller gap on A100.".into()],
    );
    rep.add_with_notes(
        "col2 grow+insert vs blocks",
        blocks_part(p),
        vec!["Expected: grow grows linearly with #blocks (serialised allocs); insert improves until bandwidth saturates (~32–512 blocks optimal).".into()],
    );
    rep.add_with_notes(
        "col3 rw vs blocks",
        rw_part(p),
        vec!["Expected: rw_b time inversely related to #blocks above 32; rw_g flat and slowest.".into()],
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn col1_ordering_every_row() {
        let p = Params { doublings: 4, ..Params::default() };
        let t = insertion_part(&p);
        for row in t.rows() {
            let atomic: f64 = row[3].parse().unwrap();
            let scan: f64 = row[4].parse().unwrap();
            let mxu: f64 = row[5].parse().unwrap();
            assert!(atomic > scan, "row {row:?}");
            assert!(mxu >= scan, "row {row:?}");
        }
    }

    #[test]
    fn col2_optimum_between_extremes() {
        let p = Params::default();
        let t = blocks_part(&p);
        let a100: Vec<_> = t.rows().iter().filter(|r| r[0] == "A100").collect();
        let total = |r: &&&Vec<String>| -> f64 { r[4].parse().unwrap() };
        let _ = total;
        let totals: Vec<f64> = a100.iter().map(|r| r[4].parse().unwrap()).collect();
        let blocks: Vec<u64> = a100.iter().map(|r| r[1].parse().unwrap()).collect();
        // Best total in the sweep should be at an intermediate block count
        // (not 1, not 16384) — the paper lands on 32–512.
        let best = totals
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| blocks[i])
            .unwrap();
        assert!((32..=2048).contains(&best), "best blocks {best}");
        // Grow strictly increases with #blocks.
        let grows: Vec<f64> = a100.iter().map(|r| r[2].parse().unwrap()).collect();
        for w in grows.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn col3_rwb_improves_with_blocks_and_rwg_slowest() {
        let p = Params::default();
        let t = rw_part(&p);
        let titan: Vec<_> = t.rows().iter().filter(|r| r[0] == "TITAN RTX").collect();
        let rwb: Vec<f64> = titan.iter().map(|r| r[3].parse().unwrap()).collect();
        // rw_b decreases (weakly) until saturation.
        assert!(rwb[0] > *rwb.last().unwrap());
        for row in &titan {
            let rwg: f64 = row[2].parse().unwrap();
            let rwb: f64 = row[3].parse().unwrap();
            let blocks: u64 = row[1].parse().unwrap();
            if blocks >= 64 {
                assert!(rwg > rwb, "blocks {blocks}: rw_g {rwg} !> rw_b {rwb}");
            }
        }
    }

    #[test]
    fn table2_grow_values_match() {
        // Cross-check the modeled grow against Table II.
        let spec = DeviceSpec::a100();
        let bytes = 512_000_000u64 * 4;
        let g512 = modeled_grow_us(&spec, 512, bytes) / 1e3;
        let g32 = modeled_grow_us(&spec, 32, bytes) / 1e3;
        assert!((g512 - 8.76).abs() < 0.6, "GGArray512 grow {g512:.2} vs 8.76");
        assert!((g32 - 0.52).abs() < 0.15, "GGArray32 grow {g32:.2} vs 0.52");
    }
}
