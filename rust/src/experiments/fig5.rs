//! Fig 5: time of grow / insertion / read-write per duplication iteration
//! (start 1e6 elements, duplicate 10×) for static, memMap, GGArray512 and
//! GGArray32, on both device models.
//!
//! The GGArray capacity evolution is tracked exactly (bucket envelopes per
//! LFVector), which reproduces the paper's observation that "the third
//! resize barely takes time" — growth over-shoots 2× early, so some
//! iterations find the capacity already sufficient.

use crate::insertion::{self, InsertionKind, InsertShape};
use crate::sim::kernel;
use crate::sim::spec::DeviceSpec;
use crate::util::csv::CsvTable;

use super::fig4::{modeled_grow_us, modeled_insert_us, modeled_rw_b_us};
use super::report::Report;

pub struct Params {
    pub start_size: u64,
    pub doublings: u32,
    pub elem_bytes: u64,
    pub first_bucket: u64,
    pub rw_flops: f64,
}

impl Default for Params {
    fn default() -> Params {
        Params { start_size: 1_000_000, doublings: 10, elem_bytes: 4, first_bucket: 1024, rw_flops: 30.0 }
    }
}

/// Pure capacity evolution of one LFVector (no data): mirrors
/// `LfVector::buckets_for`.
#[derive(Debug, Clone)]
pub struct CapSim {
    pub fbs: u64,
    pub buckets: u32,
}

impl CapSim {
    pub fn new(fbs: u64) -> CapSim {
        CapSim { fbs, buckets: 0 }
    }

    pub fn capacity(&self) -> u64 {
        self.fbs * ((1u64 << self.buckets) - 1)
    }

    /// Grow to hold `len`; returns (new buckets allocated, bytes allocated).
    pub fn grow_to(&mut self, len: u64, elem_bytes: u64) -> (u32, u64) {
        let mut allocated = 0;
        let mut bytes = 0;
        while self.capacity() < len {
            bytes += self.fbs * (1u64 << self.buckets) * elem_bytes;
            self.buckets += 1;
            allocated += 1;
        }
        (allocated, bytes)
    }
}

/// One structure's per-iteration modeled times.
#[derive(Debug, Clone, Copy)]
pub struct IterTimes {
    pub grow_ms: Option<f64>,
    pub insert_ms: f64,
    pub rw_ms: f64,
}

/// Run the duplication schedule for one structure kind on one device.
pub fn duplication_series(spec: &DeviceSpec, structure: &str, p: &Params) -> Vec<IterTimes> {
    let mut out = Vec::new();
    match structure {
        "static" => {
            let mut size = 0u64;
            let mut inserts = p.start_size;
            for _ in 0..=p.doublings {
                let shape = InsertShape::static_array(spec, inserts.max(size), inserts, p.elem_bytes);
                let ins = insertion::cost_us(spec, InsertionKind::WarpScan, &shape);
                size += inserts;
                let rw = kernel::streaming_us(spec, 2.0 * (size * p.elem_bytes) as f64, spec.cost.coalesced_eff)
                    + spec.cost.kernel_launch_us;
                out.push(IterTimes { grow_ms: None, insert_ms: ins / 1e3, rw_ms: rw / 1e3 });
                inserts = size;
            }
        }
        "memMap" => {
            let mut size = 0u64;
            let mut mapped = 0u64;
            let mut inserts = p.start_size;
            let page = spec.cost.vmm_page_bytes;
            for _ in 0..=p.doublings {
                let need = (size + inserts) * p.elem_bytes;
                let need_pages = crate::util::math::ceil_div(need, page);
                let new_pages = need_pages.saturating_sub(mapped);
                let grow = if new_pages > 0 {
                    spec.cost.host_sync_us + new_pages as f64 * spec.cost.vmm_map_page_us
                } else {
                    0.0
                };
                mapped = mapped.max(need_pages);
                let shape = InsertShape::static_array(spec, inserts.max(size), inserts, p.elem_bytes);
                let ins = insertion::cost_us(spec, InsertionKind::WarpScan, &shape);
                size += inserts;
                let rw = kernel::streaming_us(spec, 2.0 * (size * p.elem_bytes) as f64, spec.cost.coalesced_eff)
                    + spec.cost.kernel_launch_us;
                out.push(IterTimes { grow_ms: Some(grow / 1e3), insert_ms: ins / 1e3, rw_ms: rw / 1e3 });
                inserts = size;
            }
        }
        gg if gg.starts_with("GGArray") => {
            let blocks: u64 = gg.trim_start_matches("GGArray").parse().expect("GGArray<N>");
            let mut cap = CapSim::new(p.first_bucket);
            let mut size = 0u64;
            let mut inserts = p.start_size;
            for _ in 0..=p.doublings {
                let per_block_target = crate::util::math::ceil_div(size + inserts, blocks);
                let (nb, bytes) = cap.grow_to(per_block_target, p.elem_bytes);
                let grow = if nb > 0 {
                    // nb buckets per LFVector × blocks LFVectors, serialised.
                    modeled_grow_us(spec, blocks * nb as u64, bytes * blocks)
                } else {
                    spec.cost.kernel_launch_us // capacity check kernel only
                };
                let ins = modeled_insert_us(spec, blocks, inserts, p.elem_bytes);
                size += inserts;
                let rw = modeled_rw_b_us(spec, blocks, size, p.elem_bytes, p.rw_flops);
                out.push(IterTimes { grow_ms: Some(grow / 1e3), insert_ms: ins / 1e3, rw_ms: rw / 1e3 });
                inserts = size;
            }
        }
        other => panic!("unknown structure {other}"),
    }
    out
}

pub const STRUCTURES: [&str; 4] = ["static", "memMap", "GGArray512", "GGArray32"];

pub fn run(p: &Params) -> Report {
    let mut rep = Report::new("fig5", "Grow / insertion / read-write per duplication iteration");
    for spec in [DeviceSpec::titan_rtx(), DeviceSpec::a100()] {
        let mut t = CsvTable::new(["structure", "iteration", "size_after", "grow_ms", "insert_ms", "rw_ms"]);
        for s in STRUCTURES {
            let series = duplication_series(&spec, s, p);
            let mut size = 0u64;
            let mut inserts = p.start_size;
            for (i, it) in series.iter().enumerate() {
                size += inserts;
                t.push_display([
                    s.to_string(),
                    i.to_string(),
                    size.to_string(),
                    it.grow_ms.map(|g| format!("{g:.4}")).unwrap_or_else(|| "_".into()),
                    format!("{:.4}", it.insert_ms),
                    format!("{:.4}", it.rw_ms),
                ]);
                inserts = size;
            }
        }
        rep.add_with_notes(
            &format!("{} duplication series", spec.name),
            t,
            vec![
                "Expected: GGArray grow occasionally ~free (capacity overshoot); rw for GGArray ≫ static/memMap; insert GGArray512 < GGArray32.".into(),
            ],
        );
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capsim_growth() {
        let mut c = CapSim::new(1024);
        assert_eq!(c.capacity(), 0);
        let (nb, bytes) = c.grow_to(1000, 4);
        assert_eq!(nb, 1);
        assert_eq!(bytes, 1024 * 4);
        assert_eq!(c.capacity(), 1024);
        let (nb, _) = c.grow_to(3000, 4);
        assert_eq!(nb, 1);
        assert_eq!(c.capacity(), 3072);
        let (nb, _) = c.grow_to(3072, 4);
        assert_eq!(nb, 0, "capacity already sufficient");
    }

    #[test]
    fn some_iteration_has_free_grow() {
        // Paper: "the third resize barely takes time".
        let spec = DeviceSpec::a100();
        let series = duplication_series(&spec, "GGArray512", &Params::default());
        let free = series.iter().filter(|t| t.grow_ms.unwrap() < 0.01).count();
        assert!(free >= 1, "no nearly-free grow iteration found");
        // But not all free.
        let paid = series.iter().filter(|t| t.grow_ms.unwrap() > 0.1).count();
        assert!(paid >= 5);
    }

    #[test]
    fn ggarray_rw_much_slower_than_static() {
        let spec = DeviceSpec::a100();
        let p = Params::default();
        let st = duplication_series(&spec, "static", &p);
        let gg = duplication_series(&spec, "GGArray512", &p);
        let last = p.doublings as usize;
        let ratio = gg[last].rw_ms / st[last].rw_ms;
        assert!(ratio > 8.0 && ratio < 16.0, "rw ratio {ratio} (paper ~11×)");
    }

    #[test]
    fn memmap_insert_close_to_static() {
        let spec = DeviceSpec::a100();
        let p = Params::default();
        let st = duplication_series(&spec, "static", &p);
        let mm = duplication_series(&spec, "memMap", &p);
        let last = p.doublings as usize;
        // Table II: 7.87 vs 7.07 ms — within ~15%.
        let rel = (mm[last].insert_ms - st[last].insert_ms).abs() / st[last].insert_ms;
        assert!(rel < 0.2, "rel {rel}");
    }
}
