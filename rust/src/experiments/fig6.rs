//! Fig 6: speedup of GGArray over memMap in a two-phase application —
//! insert phases grow the array to 1e9 elements over 5 iterations, each
//! followed by a work phase of `w` calls of the +1 kernel (w ∈ [1, 1000]).
//! GGArray flattens once per phase so the work runs at static-array speed.

use crate::sim::spec::DeviceSpec;
use crate::util::csv::CsvTable;

use super::fig4::{modeled_grow_us, modeled_insert_us};
use super::fig5::CapSim;
use super::report::Report;
use crate::insertion::{self, InsertionKind, InsertShape};
use crate::sim::kernel;

pub struct Params {
    pub final_size: u64,
    pub phases: u32,
    pub blocks: u64,
    pub first_bucket: u64,
    pub elem_bytes: u64,
    pub inserts_per_elem: Vec<u64>,
    pub work_calls: Vec<u32>,
}

impl Default for Params {
    fn default() -> Params {
        Params {
            final_size: 1_000_000_000,
            phases: 5,
            blocks: 512,
            first_bucket: 1024,
            elem_bytes: 4,
            inserts_per_elem: vec![1, 3, 10],
            work_calls: vec![1, 2, 5, 10, 20, 50, 100, 200, 500, 1000],
        }
    }
}

/// Total modeled time (µs) of the two-phase application on each structure.
pub fn two_phase_times(spec: &DeviceSpec, p: &Params, k: u64, w: u32) -> (f64, f64) {
    let growth = (k + 1).pow(p.phases);
    let start = (p.final_size / growth).max(1);
    let page = spec.cost.vmm_page_bytes;

    // ---- memMap ----
    let mut t_mm = 0.0;
    {
        let mut size = start;
        let mut mapped_pages = crate::util::math::ceil_div(size * p.elem_bytes, page);
        t_mm += spec.cost.vmm_reserve_us
            + mapped_pages as f64 * spec.cost.vmm_map_page_us
            + insertion::cost_us(
                spec,
                InsertionKind::WarpScan,
                &InsertShape::static_array(spec, size, size, p.elem_bytes),
            );
        for _ in 0..p.phases {
            let ins = size * k;
            let need_pages = crate::util::math::ceil_div((size + ins) * p.elem_bytes, page);
            t_mm += spec.cost.host_sync_us
                + need_pages.saturating_sub(mapped_pages) as f64 * spec.cost.vmm_map_page_us;
            mapped_pages = mapped_pages.max(need_pages);
            t_mm += insertion::cost_us(
                spec,
                InsertionKind::WarpScan,
                &InsertShape::static_array(spec, size.max(ins), ins, p.elem_bytes),
            );
            size += ins;
            // Work phase on the contiguous array.
            let rw = kernel::streaming_us(spec, 2.0 * (size * p.elem_bytes) as f64, spec.cost.coalesced_eff)
                + spec.cost.kernel_launch_us;
            t_mm += w as f64 * rw;
        }
    }

    // ---- GGArray + flatten ----
    let mut t_gg = 0.0;
    {
        let mut size = start;
        let mut cap = CapSim::new(p.first_bucket);
        let (nb, bytes) = cap.grow_to(crate::util::math::ceil_div(size, p.blocks), p.elem_bytes);
        t_gg += modeled_grow_us(spec, p.blocks * nb.max(1) as u64, bytes * p.blocks)
            + modeled_insert_us(spec, p.blocks, size, p.elem_bytes);
        for _ in 0..p.phases {
            let ins = size * k;
            let (nb, bytes) = cap.grow_to(crate::util::math::ceil_div(size + ins, p.blocks), p.elem_bytes);
            t_gg += if nb > 0 {
                modeled_grow_us(spec, p.blocks * nb as u64, bytes * p.blocks)
            } else {
                spec.cost.kernel_launch_us
            };
            t_gg += modeled_insert_us(spec, p.blocks, ins, p.elem_bytes);
            size += ins;
            // Flatten once: read at block eff, write coalesced, + dst alloc.
            let read = (size * p.elem_bytes) as f64;
            let eff = crate::insertion::warp_scan::blended_eff(
                read,
                spec.cost.ggarray_block_eff,
                read,
                spec.cost.coalesced_eff,
            );
            t_gg += spec.cost.kernel_launch_us
                + spec.cost.malloc_base_us
                + 2.0 * read / (spec.bw_bytes_per_us() * eff);
            // Work phase at static speed on the flattened buffer.
            let rw = kernel::streaming_us(spec, 2.0 * (size * p.elem_bytes) as f64, spec.cost.coalesced_eff)
                + spec.cost.kernel_launch_us;
            t_gg += w as f64 * rw;
        }
    }
    (t_mm, t_gg)
}

pub fn run(p: &Params) -> Report {
    let mut rep = Report::new("fig6", "Two-phase application: speedup of GGArray over memMap");
    for spec in [DeviceSpec::titan_rtx(), DeviceSpec::a100()] {
        let mut t = CsvTable::new(["inserts_per_elem", "work_calls", "t_memmap_ms", "t_ggarray_ms", "speedup"]);
        for &k in &p.inserts_per_elem {
            for &w in &p.work_calls {
                let (mm, gg) = two_phase_times(&spec, p, k, w);
                t.push_display([
                    k.to_string(),
                    w.to_string(),
                    format!("{:.2}", mm / 1e3),
                    format!("{:.2}", gg / 1e3),
                    format!("{:.4}", mm / gg),
                ]);
            }
        }
        rep.add_with_notes(
            &format!("{} two-phase speedup", spec.name),
            t,
            vec![
                "Expected: speedup < 1 at tiny work counts (structure overhead visible), → 1 as work dominates; k ∈ {1,3,10} barely moves the curve.".into(),
            ],
        );
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_approaches_one_with_work() {
        let p = Params::default();
        let spec = DeviceSpec::a100();
        let (mm1, gg1) = two_phase_times(&spec, &p, 1, 1);
        let (mm1000, gg1000) = two_phase_times(&spec, &p, 1, 1000);
        let s1 = mm1 / gg1;
        let s1000 = mm1000 / gg1000;
        assert!(s1 < s1000, "s1 {s1} !< s1000 {s1000}");
        assert!(s1 < 0.97, "overhead should be visible at w=1: {s1}");
        assert!(s1000 > 0.975 && s1000 <= 1.001, "s1000 {s1000}");
    }

    #[test]
    fn k_has_little_impact() {
        // Paper: "Inserting 1, 3, or 10 times the size of the array each
        // iteration does not have an impact on the speedup."
        let p = Params::default();
        let spec = DeviceSpec::a100();
        let speeds: Vec<f64> = [1u64, 3, 10]
            .iter()
            .map(|&k| {
                let (mm, gg) = two_phase_times(&spec, &p, k, 100);
                mm / gg
            })
            .collect();
        let max = speeds.iter().cloned().fold(f64::MIN, f64::max);
        let min = speeds.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max - min < 0.05, "speedups {speeds:?}");
    }

    #[test]
    fn five_repetitions_land_on_final_size() {
        let p = Params::default();
        for k in [1u64, 3, 10] {
            let growth = (k + 1).pow(p.phases);
            let start = p.final_size / growth;
            let finals = start * growth;
            let rel = (finals as f64 - 1e9).abs() / 1e9;
            assert!(rel < 0.05, "k={k} final {finals}");
        }
    }
}
