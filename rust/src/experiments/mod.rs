//! Experiment harness: one runner per paper figure/table.
//!
//! | runner            | reproduces                              |
//! |-------------------|------------------------------------------|
//! | [`table1`]        | Table I (device specs)                   |
//! | [`fig3`]          | Fig 3 (theoretical memory usage)         |
//! | [`fig4`]          | Fig 4 (insertion algorithms; #LFVectors) |
//! | [`fig5`]          | Fig 5 (grow/insert/rw per iteration)     |
//! | [`table2`]        | Table II (last-iteration times, A100)    |
//! | [`fig6`]          | Fig 6 (two-phase speedup)                |
//!
//! Each runner returns a [`report::Report`] (CSV + markdown) and writes it
//! under `reports/`.

pub mod ablations;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod report;
pub mod table1;
pub mod table2;
