//! Experiment report: a titled set of CSV tables with markdown rendering,
//! saved under `reports/`.

use std::path::{Path, PathBuf};

use crate::util::csv::CsvTable;
use crate::util::tables;

/// One named table within a report.
#[derive(Debug, Clone)]
pub struct Section {
    pub name: String,
    pub table: CsvTable,
    /// Free-text commentary (expected paper shape, calibration notes).
    pub notes: Vec<String>,
}

/// A full experiment report.
#[derive(Debug, Clone)]
pub struct Report {
    /// Experiment id ("fig3", "table2", …).
    pub id: String,
    pub title: String,
    pub sections: Vec<Section>,
}

impl Report {
    pub fn new(id: &str, title: &str) -> Report {
        Report { id: id.to_string(), title: title.to_string(), sections: Vec::new() }
    }

    pub fn add(&mut self, name: &str, table: CsvTable) -> &mut Section {
        self.sections.push(Section { name: name.to_string(), table, notes: Vec::new() });
        self.sections.last_mut().unwrap()
    }

    pub fn add_with_notes(&mut self, name: &str, table: CsvTable, notes: Vec<String>) {
        self.sections.push(Section { name: name.to_string(), table, notes });
    }

    /// Render the whole report as markdown.
    pub fn markdown(&self) -> String {
        let mut out = format!("## {} — {}\n\n", self.id, self.title);
        for s in &self.sections {
            out.push_str(&format!("### {}\n\n", s.name));
            out.push_str(&tables::markdown(&s.table));
            out.push('\n');
            for n in &s.notes {
                out.push_str(&format!("> {n}\n"));
            }
            if !s.notes.is_empty() {
                out.push('\n');
            }
        }
        out
    }

    /// Save CSVs (one per section) + the markdown summary under `dir`.
    /// Returns the written paths.
    pub fn save(&self, dir: &Path) -> std::io::Result<Vec<PathBuf>> {
        std::fs::create_dir_all(dir)?;
        let mut written = Vec::new();
        for s in &self.sections {
            let path = dir.join(format!("{}_{}.csv", self.id, sanitise(&s.name)));
            s.table.save(&path)?;
            written.push(path);
        }
        let md = dir.join(format!("{}.md", self.id));
        std::fs::write(&md, self.markdown())?;
        written.push(md);
        Ok(written)
    }
}

fn sanitise(name: &str) -> String {
    name.chars().map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '_' }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_roundtrip() {
        let mut r = Report::new("figX", "demo");
        let mut t = CsvTable::new(["a", "b"]);
        t.push(["1", "2"]);
        r.add_with_notes("main table", t, vec!["expected shape: up".into()]);
        let md = r.markdown();
        assert!(md.contains("## figX"));
        assert!(md.contains("### main table"));
        assert!(md.contains("> expected shape: up"));

        let dir = std::env::temp_dir().join("ggarray_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        let paths = r.save(&dir).unwrap();
        assert_eq!(paths.len(), 2);
        assert!(paths[0].to_str().unwrap().contains("figX_main_table"));
        assert!(dir.join("figX.md").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
