//! Table I: GPU specifications — rendered from the `DeviceSpec` presets so
//! the simulated testbed is auditable against the paper.

use crate::sim::spec::DeviceSpec;
use crate::util::csv::CsvTable;

use super::report::Report;

pub fn run() -> Report {
    let mut rep = Report::new("table1", "GPUs specifications (simulated device models)");
    let specs = [DeviceSpec::titan_rtx(), DeviceSpec::a100()];
    let mut t = CsvTable::new(["", "TITAN RTX", "A100"]);
    let row = |name: &str, f: &dyn Fn(&DeviceSpec) -> String| {
        let mut r = vec![name.to_string()];
        for s in &specs {
            r.push(f(s));
        }
        r
    };
    t.push(row("CUDA Cores", &|s| s.cuda_cores.to_string()));
    t.push(row("Tensor cores", &|s| s.tensor_cores.to_string()));
    t.push(row("Memory", &|s| format!("{} GB", s.memory_gib)));
    t.push(row("FP16 performance", &|s| format!("{:.2} TFLOPS", s.fp16_tflops)));
    t.push(row("FP32 performance", &|s| format!("{:.2} TFLOPS", s.fp32_tflops)));
    t.push(row("Base Clock Speed", &|s| format!("{:.0} MHz", s.base_clock_mhz)));
    // Derived (not in the paper's table, used by the cost model):
    t.push(row("SMs (derived)", &|s| s.sm_count.to_string()));
    t.push(row("Mem BW (derived)", &|s| format!("{:.0} GB/s", s.mem_bw_gbps)));
    rep.add_with_notes(
        "Table I",
        t,
        vec!["First six rows are the paper's Table I verbatim; the derived rows parameterise the cost model.".into()],
    );
    rep
}

#[cfg(test)]
mod tests {
    #[test]
    fn renders_paper_values() {
        let r = super::run();
        let md = r.markdown();
        assert!(md.contains("4608"));
        assert!(md.contains("6912"));
        assert!(md.contains("77.97 TFLOPS"));
        assert!(md.contains("1350 MHz"));
    }
}
