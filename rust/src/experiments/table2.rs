//! Table II: time (ms) to duplicate an array of size 5.12e8 in the last
//! iteration, on the A100 model — grow / insert / read-write for static,
//! memMap, GGArray512 and GGArray32.

use crate::sim::spec::DeviceSpec;
use crate::util::csv::CsvTable;

use super::fig5::{self, Params};
use super::report::Report;

/// Paper's Table II values (ms) for the fidelity columns.
pub const PAPER: [(&str, Option<f64>, f64, f64); 4] = [
    ("static", None, 7.07, 6.27),
    ("memMap", Some(5.21), 7.87, 6.28),
    ("GGArray512", Some(8.76), 11.79, 69.73),
    ("GGArray32", Some(0.52), 27.90, 198.32),
];

pub fn run() -> Report {
    let p = Params::default();
    let spec = DeviceSpec::a100();
    let last = p.doublings as usize;
    let mut t = CsvTable::new([
        "structure",
        "grow_ms",
        "insert_ms",
        "rw_ms",
        "paper_grow_ms",
        "paper_insert_ms",
        "paper_rw_ms",
    ]);
    for (name, paper_grow, paper_insert, paper_rw) in PAPER {
        let series = fig5::duplication_series(&spec, name, &p);
        let it = series[last];
        t.push_display([
            name.to_string(),
            it.grow_ms.map(|g| format!("{g:.2}")).unwrap_or_else(|| "_".into()),
            format!("{:.2}", it.insert_ms),
            format!("{:.2}", it.rw_ms),
            paper_grow.map(|g| format!("{g:.2}")).unwrap_or_else(|| "_".into()),
            format!("{paper_insert:.2}"),
            format!("{paper_rw:.2}"),
        ]);
    }
    let mut rep = Report::new("table2", "Time (ms) to duplicate an array of size 5.12e8, last iteration, A100 model");
    rep.add_with_notes(
        "Table II",
        t,
        vec!["Columns 2–4 are the calibrated model; 5–7 the paper's measurements. Shapes (orderings, ratios) must match; absolute values are calibration targets.".into()],
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The headline fidelity check of the whole reproduction: every
    /// modeled Table II cell lands within a factor band of the paper's
    /// measurement, and every qualitative ordering holds.
    #[test]
    fn table2_fidelity() {
        let rep = run();
        let rows = rep.sections[0].table.rows().to_vec();
        let get = |r: &Vec<String>, c: usize| -> f64 { r[c].parse().unwrap_or(f64::NAN) };
        // Parse modeled values.
        let m: std::collections::HashMap<String, (f64, f64, f64)> = rows
            .iter()
            .map(|r| (r[0].clone(), (get(r, 1), get(r, 2), get(r, 3))))
            .collect();
        let (_, st_ins, st_rw) = m["static"];
        let (mm_grow, mm_ins, mm_rw) = m["memMap"];
        let (g512_grow, g512_ins, g512_rw) = m["GGArray512"];
        let (g32_grow, g32_ins, g32_rw) = m["GGArray32"];
        // Quantitative bands (±35% of the paper's value).
        let close = |model: f64, paper: f64| (model - paper).abs() / paper < 0.35;
        assert!(close(st_ins, 7.07), "static insert {st_ins}");
        assert!(close(st_rw, 6.27), "static rw {st_rw}");
        assert!(close(mm_grow, 5.21), "memMap grow {mm_grow}");
        assert!(close(mm_ins, 7.87) || close(mm_ins, 7.07), "memMap insert {mm_ins}");
        assert!(close(mm_rw, 6.28), "memMap rw {mm_rw}");
        assert!(close(g512_grow, 8.76), "GG512 grow {g512_grow}");
        assert!(close(g512_ins, 11.79), "GG512 insert {g512_ins}");
        assert!(close(g512_rw, 69.73), "GG512 rw {g512_rw}");
        assert!(close(g32_grow, 0.52), "GG32 grow {g32_grow}");
        assert!(close(g32_ins, 27.90), "GG32 insert {g32_ins}");
        assert!((g32_rw - 198.32).abs() / 198.32 < 0.45, "GG32 rw {g32_rw}");
        // Qualitative orderings.
        assert!(g32_grow < mm_grow && mm_grow < g512_grow);
        assert!(st_ins < g512_ins && g512_ins < g32_ins);
        assert!(st_rw < g512_rw && g512_rw < g32_rw);
        assert!(g512_rw / st_rw > 10.0, "paper: >10× slower r/w");
    }
}
