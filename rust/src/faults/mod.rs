//! Deterministic fault injection (`--cfg ggfault`).
//!
//! Mirrors the `ggcheck` pattern: named fault sites are sprinkled
//! through the coordinator (`faults::point("scheduler.worker.copy")`)
//! and compile to **nothing** in normal builds — `point`/`injected`
//! are `#[inline(always)]` empty functions unless the crate is built
//! with `RUSTFLAGS='--cfg ggfault'`. Under `ggfault`, a test arms a
//! [`FaultPlan`] naming a site and the Nth crossing that should blow
//! up; the crossing then panics with a typed [`InjectedFault`] payload
//! (for [`SiteKind::Abort`]/[`SiteKind::Fatal`] sites, via
//! [`point`]) or reports `true` (for [`SiteKind::Degrade`] sites, via
//! [`injected`] — e.g. a simulated thread-spawn failure). Every
//! registered site is listed in [`SITES`] so the chaos suite
//! (`tests/chaos.rs`) can enumerate the full matrix mechanically; see
//! EXPERIMENTS.md §Robustness for the registry table and the
//! abort-byte-identity contract each site's containment must satisfy.
//!
//! Exactly one plan may be armed at a time (the injector state is a
//! process-wide slot); [`FaultPlan::arm`] blocks until the slot frees,
//! so concurrently running `#[test]`s serialize instead of corrupting
//! each other's plans, and the returned [`FaultGuard`] disarms on drop
//! and answers whether the fault actually fired.

/// What a site does when its plan fires — determines which arm of the
/// chaos contract applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SiteKind {
    /// The in-flight op aborts with a typed error; state rolls back
    /// byte-identically and the store keeps serving.
    Abort,
    /// No error escapes: the component permanently degrades (fewer
    /// scheduler workers, floor 1) and results stay byte-identical to
    /// the fault-free run.
    Degrade,
    /// The service worker thread dies: every subsequent call observes
    /// a typed `ServiceDown` / `Admission::Closed`, never a hang.
    Fatal,
}

/// One registered fault site.
#[derive(Debug, Clone, Copy)]
pub struct Site {
    /// Dotted path passed to [`point`]/[`injected`] at the site.
    pub name: &'static str,
    pub kind: SiteKind,
    /// Where the site sits and what failing there simulates.
    pub what: &'static str,
}

/// Every fault site compiled into the crate. The chaos suite iterates
/// this — adding a `point()` call without registering it here leaves
/// the new site untested, so keep them in lockstep.
pub const SITES: &[Site] = &[
    Site {
        name: "scheduler.worker.fill",
        kind: SiteKind::Abort,
        what: "worker panic at the top of an insert fill chunk (before any write)",
    },
    Site {
        name: "scheduler.worker.work",
        kind: SiteKind::Abort,
        what: "worker panic at the top of a work-pass chunk",
    },
    Site {
        name: "scheduler.worker.copy",
        kind: SiteKind::Abort,
        what: "worker panic at the top of a gather-copy chunk (flatten/seal/snapshot)",
    },
    Site {
        name: "scheduler.spawn",
        kind: SiteKind::Degrade,
        what: "thread::Builder::spawn failure while building or respawning the worker group",
    },
    Site {
        name: "service.worker.handle",
        kind: SiteKind::Abort,
        what: "coordinator worker panic at the top of request handling (before any mutation)",
    },
    Site {
        name: "service.worker.fatal",
        kind: SiteKind::Fatal,
        what: "coordinator worker death outside the containment net (loop-level panic)",
    },
];

#[cfg(ggfault)]
mod active {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Mutex};

    /// Panic payload of a fired [`super::point`] — typed so contained
    /// injections are distinguishable from genuine bugs in test
    /// assertions and the quiet panic hook.
    #[derive(Debug)]
    pub struct InjectedFault {
        pub site: &'static str,
    }

    struct Armed {
        site: &'static str,
        /// 1-based crossing index that fires.
        nth: u64,
        seen: u64,
        fired: Arc<AtomicBool>,
    }

    static ARMED: Mutex<Option<Armed>> = Mutex::new(None);

    /// A deterministic fault: blow up the `nth` crossing of `site`
    /// (1-based). Inert until [`FaultPlan::arm`].
    #[derive(Debug, Clone, Copy)]
    pub struct FaultPlan {
        pub site: &'static str,
        pub nth: u64,
    }

    impl FaultPlan {
        /// Fire the first crossing of `site`.
        pub fn first(site: &'static str) -> FaultPlan {
            FaultPlan { site, nth: 1 }
        }

        /// Install the plan. Blocks until no other plan is armed (so
        /// parallel tests serialize), and disarms when the returned
        /// guard drops.
        pub fn arm(self) -> FaultGuard {
            assert!(self.nth >= 1, "FaultPlan.nth is 1-based");
            let fired = Arc::new(AtomicBool::new(false));
            loop {
                let mut slot = ARMED.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                if slot.is_none() {
                    *slot = Some(Armed {
                        site: self.site,
                        nth: self.nth,
                        seen: 0,
                        fired: Arc::clone(&fired),
                    });
                    return FaultGuard { fired };
                }
                drop(slot);
                std::thread::yield_now();
            }
        }
    }

    /// Disarms the armed plan on drop; reports whether it fired.
    pub struct FaultGuard {
        fired: Arc<AtomicBool>,
    }

    impl FaultGuard {
        /// Did the armed crossing actually happen? A plan targeting the
        /// second crossing of a site the run only crosses once never
        /// fires — the chaos contract then demands byte-identity with
        /// the fault-free run.
        pub fn fired(&self) -> bool {
            self.fired.load(Ordering::SeqCst)
        }
    }

    impl Drop for FaultGuard {
        fn drop(&mut self) {
            *ARMED.lock().unwrap_or_else(std::sync::PoisonError::into_inner) = None;
        }
    }

    /// Count a crossing of `site`; true iff the armed plan fires here.
    pub fn crossing(site: &'static str) -> bool {
        let mut slot = ARMED.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some(armed) = slot.as_mut() {
            if armed.site == site {
                armed.seen += 1;
                if armed.seen == armed.nth {
                    armed.fired.store(true, Ordering::SeqCst);
                    return true;
                }
            }
        }
        false
    }
}

#[cfg(ggfault)]
pub use active::{FaultGuard, FaultPlan, InjectedFault};

/// A fault site that *panics* when its plan fires (Abort/Fatal sites).
/// Zero-cost no-op unless built with `--cfg ggfault`.
#[inline(always)]
pub fn point(site: &'static str) {
    #[cfg(ggfault)]
    if active::crossing(site) {
        std::panic::panic_any(active::InjectedFault { site });
    }
    #[cfg(not(ggfault))]
    let _ = site;
}

/// A fault site that *reports* when its plan fires (Degrade sites —
/// the caller turns `true` into the failure it simulates, e.g. a
/// spawn error). Always `false` unless built with `--cfg ggfault`.
#[inline(always)]
#[must_use]
pub fn injected(site: &'static str) -> bool {
    #[cfg(ggfault)]
    {
        active::crossing(site)
    }
    #[cfg(not(ggfault))]
    {
        let _ = site;
        false
    }
}

/// Marker prefix for deliberate test panics (model-check / unit suites
/// that panic inside contained jobs): payloads carrying it are
/// silenced by [`quiet_panic_hook`].
pub const EXPECTED_PANIC: &str = "[expected-test-panic]";

/// Install (once) a panic hook that suppresses the default
/// stderr-spew for *expected* panics — injected faults and payloads
/// tagged [`EXPECTED_PANIC`] — while delegating everything else to
/// the previous hook. Chaos and containment tests cross panics by the
/// hundred; without this every one prints a backtrace banner.
pub fn quiet_panic_hook() {
    use std::sync::Once;
    static INSTALL: Once = Once::new();
    INSTALL.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            #[cfg(ggfault)]
            if info.payload().downcast_ref::<active::InjectedFault>().is_some() {
                return;
            }
            let expected = info
                .payload()
                .downcast_ref::<&str>()
                .map(|s| s.contains(EXPECTED_PANIC))
                .or_else(|| {
                    info.payload().downcast_ref::<String>().map(|s| s.contains(EXPECTED_PANIC))
                })
                .unwrap_or(false);
            if !expected {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_dotted() {
        for (i, s) in SITES.iter().enumerate() {
            assert!(s.name.contains('.'), "{} is not a dotted path", s.name);
            assert!(!s.what.is_empty());
            for other in &SITES[i + 1..] {
                assert_ne!(s.name, other.name, "duplicate site");
            }
        }
    }

    #[test]
    fn sites_are_inert_without_a_plan() {
        // In non-ggfault builds this is the whole story; under ggfault
        // it checks the unarmed path.
        for s in SITES {
            point(s.name);
            assert!(!injected(s.name));
        }
    }

    #[cfg(ggfault)]
    #[test]
    fn plan_fires_exactly_the_nth_crossing() {
        quiet_panic_hook();
        let guard = FaultPlan { site: "scheduler.worker.copy", nth: 3 }.arm();
        assert!(!injected("scheduler.worker.copy")); // crossing 1
        point("scheduler.worker.work"); // other sites don't count
        assert!(!injected("scheduler.worker.copy")); // crossing 2
        assert!(!guard.fired());
        let err = std::panic::catch_unwind(|| point("scheduler.worker.copy")).unwrap_err();
        let fault = err.downcast_ref::<InjectedFault>().expect("typed payload");
        assert_eq!(fault.site, "scheduler.worker.copy");
        assert!(guard.fired());
        // Crossings after the shot are clean again.
        point("scheduler.worker.copy");
        drop(guard);
        // And a dropped guard fully disarms.
        point("scheduler.worker.copy");
    }

    #[cfg(ggfault)]
    #[test]
    fn degrade_sites_report_instead_of_panicking() {
        let guard = FaultPlan::first("scheduler.spawn").arm();
        assert!(injected("scheduler.spawn"));
        assert!(guard.fired());
        assert!(!injected("scheduler.spawn"), "one-shot");
    }
}
