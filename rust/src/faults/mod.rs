//! Deterministic fault injection (`--cfg ggfault`).
//!
//! Mirrors the `ggcheck` pattern: named fault sites are sprinkled
//! through the coordinator (`faults::point("scheduler.worker.copy")`)
//! and compile to **nothing** in normal builds — `point`/`injected`/
//! `stall` are `#[inline(always)]` empty functions unless the crate is
//! built with `RUSTFLAGS='--cfg ggfault'`. Under `ggfault`, a test arms
//! a [`FaultPlan`] naming a site and the Nth crossing that should blow
//! up; the crossing then panics with a typed [`InjectedFault`] payload
//! (for [`SiteKind::Abort`]/[`SiteKind::Fatal`] sites, via
//! [`point`]), reports `true` (for [`SiteKind::Degrade`] sites, via
//! [`injected`] — e.g. a simulated thread-spawn failure), or stalls
//! the executing thread for [`DELAY_STALL`] wall-clock (for
//! [`SiteKind::Delay`] sites, via [`stall`] — a simulated straggler).
//! Every registered site is listed in [`SITES`] so the chaos suite
//! (`tests/chaos.rs`) can enumerate the full matrix mechanically; see
//! EXPERIMENTS.md §Robustness for the registry table and the
//! abort-byte-identity contract each site's containment must satisfy.
//!
//! Plans compose into an **ordered multi-plan** with [`FaultPlan::then`]:
//! each step counts crossings of its own site only after every earlier
//! step has fired, so chaos runs can express second-order failures —
//! a panic during the *heal* respawn, a fault while a degraded group
//! drains inline — deterministically.
//!
//! Exactly one plan may be armed at a time (the injector state is a
//! process-wide slot); [`FaultPlan::arm`] blocks until the slot frees,
//! so concurrently running `#[test]`s serialize instead of corrupting
//! each other's plans, and the returned [`FaultGuard`] disarms on drop
//! and answers whether the fault (every step of it) actually fired.

use std::time::Duration;

/// How long a fired [`SiteKind::Delay`] crossing stalls the executing
/// thread. Wall-clock, not sim-clock: a straggled chunk is pure data
/// movement whose charges were pre-paid serially, so the stall changes
/// nothing observable except latency — which is exactly what the
/// tail-latency ledger and the steal gate assert against.
pub const DELAY_STALL: Duration = Duration::from_millis(25);

/// What a site does when its plan fires — determines which arm of the
/// chaos contract applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SiteKind {
    /// The in-flight op aborts with a typed error; state rolls back
    /// byte-identically and the store keeps serving.
    Abort,
    /// No error escapes: the component permanently degrades (fewer
    /// scheduler workers, floor 1) and results stay byte-identical to
    /// the fault-free run.
    Degrade,
    /// The service worker thread dies: the supervisor respawns the
    /// handler loop over the surviving store state and replays the
    /// un-acked request exactly once — sessions observe at most a
    /// latency blip, never `Closed`, and results stay byte-identical
    /// to the fault-free run.
    Fatal,
    /// No error at all: the executing thread stalls for [`DELAY_STALL`]
    /// wall-clock (a simulated straggler). Results stay byte-identical;
    /// the contract is on the latency ledger — and, for scheduler
    /// sites, that survivors steal around the straggler instead of
    /// waiting on it.
    Delay,
}

/// One registered fault site.
#[derive(Debug, Clone, Copy)]
pub struct Site {
    /// Dotted path passed to [`point`]/[`injected`]/[`stall`] at the
    /// site.
    pub name: &'static str,
    pub kind: SiteKind,
    /// Where the site sits and what failing there simulates.
    pub what: &'static str,
}

/// Every fault site compiled into the crate. The chaos suite iterates
/// this — adding a `point()` call without registering it here leaves
/// the new site untested, so keep them in lockstep.
pub const SITES: &[Site] = &[
    Site {
        name: "scheduler.worker.fill",
        kind: SiteKind::Abort,
        what: "worker panic at the top of an insert fill chunk (before any write)",
    },
    Site {
        name: "scheduler.worker.work",
        kind: SiteKind::Abort,
        what: "worker panic at the top of a work-pass chunk",
    },
    Site {
        name: "scheduler.worker.copy",
        kind: SiteKind::Abort,
        what: "worker panic at the top of a gather-copy chunk (flatten/seal/snapshot)",
    },
    Site {
        name: "scheduler.spawn",
        kind: SiteKind::Degrade,
        what: "thread::Builder::spawn failure while building or respawning the worker group",
    },
    Site {
        name: "service.worker.handle",
        kind: SiteKind::Abort,
        what: "coordinator worker panic at the top of request handling (before any mutation)",
    },
    Site {
        name: "service.worker.fatal",
        kind: SiteKind::Fatal,
        what: "coordinator worker death outside the containment net (loop-level panic)",
    },
    Site {
        name: "scheduler.worker.fill.slow",
        kind: SiteKind::Delay,
        what: "straggling worker: wall-clock stall at the top of an insert fill chunk",
    },
    Site {
        name: "scheduler.worker.work.slow",
        kind: SiteKind::Delay,
        what: "straggling worker: wall-clock stall at the top of a work-pass chunk",
    },
    Site {
        name: "scheduler.worker.copy.slow",
        kind: SiteKind::Delay,
        what: "straggling worker: wall-clock stall at the top of a gather-copy chunk",
    },
    Site {
        name: "service.worker.handle.slow",
        kind: SiteKind::Delay,
        what: "slow coordinator worker: wall-clock stall at the top of request handling",
    },
];

#[cfg(ggfault)]
mod active {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::{Arc, Mutex};

    /// Panic payload of a fired [`super::point`] — typed so contained
    /// injections are distinguishable from genuine bugs in test
    /// assertions and the quiet panic hook.
    #[derive(Debug)]
    pub struct InjectedFault {
        pub site: &'static str,
    }

    /// The armed multi-plan: `steps[idx]` is the live step; a crossing
    /// of its site bumps `seen`, and at `seen == nth` the step fires
    /// (ledgered in `fired`) and the next step goes live. Crossings of
    /// a later step's site before its turn do not count — that ordering
    /// is what lets a composed plan target "the first spawn crossing
    /// *after* the fill panic" deterministically.
    struct Armed {
        steps: Vec<FaultPlan>,
        idx: usize,
        seen: u64,
        fired: Arc<AtomicU64>,
    }

    static ARMED: Mutex<Option<Armed>> = Mutex::new(None);

    /// A deterministic fault: blow up the `nth` crossing of `site`
    /// (1-based). Inert until [`FaultPlan::arm`]; chains into an
    /// ordered multi-plan with [`FaultPlan::then`].
    #[derive(Debug, Clone, Copy)]
    pub struct FaultPlan {
        pub site: &'static str,
        pub nth: u64,
    }

    impl FaultPlan {
        /// Fire the first crossing of `site`.
        pub fn first(site: &'static str) -> FaultPlan {
            FaultPlan { site, nth: 1 }
        }

        /// Compose: after this plan fires, start counting crossings for
        /// `next`. Chains — `a.then(b).then(c)` fires a, then b, then c.
        pub fn then(self, next: FaultPlan) -> ComposedPlan {
            ComposedPlan { steps: vec![self, next] }
        }

        /// Install the plan. Blocks until no other plan is armed (so
        /// parallel tests serialize), and disarms when the returned
        /// guard drops.
        pub fn arm(self) -> FaultGuard {
            ComposedPlan { steps: vec![self] }.arm()
        }
    }

    /// An ordered sequence of [`FaultPlan`] steps, armed as one unit.
    /// Step `k+1` starts counting its site's crossings only after step
    /// `k` fired.
    #[derive(Debug, Clone)]
    pub struct ComposedPlan {
        pub steps: Vec<FaultPlan>,
    }

    impl ComposedPlan {
        /// Append another step to the sequence.
        pub fn then(mut self, next: FaultPlan) -> ComposedPlan {
            self.steps.push(next);
            self
        }

        /// Install the multi-plan (see [`FaultPlan::arm`]).
        pub fn arm(self) -> FaultGuard {
            assert!(!self.steps.is_empty(), "a composed plan needs at least one step");
            for step in &self.steps {
                assert!(step.nth >= 1, "FaultPlan.nth is 1-based");
            }
            let total_steps = self.steps.len() as u64;
            let fired = Arc::new(AtomicU64::new(0));
            loop {
                let mut slot = ARMED.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                if slot.is_none() {
                    *slot = Some(Armed {
                        steps: self.steps,
                        idx: 0,
                        seen: 0,
                        fired: Arc::clone(&fired),
                    });
                    return FaultGuard { fired, total_steps };
                }
                drop(slot);
                std::thread::yield_now();
            }
        }
    }

    /// Disarms the armed plan on drop; reports whether it fired.
    pub struct FaultGuard {
        fired: Arc<AtomicU64>,
        total_steps: u64,
    }

    impl FaultGuard {
        /// Did every armed step actually fire? A plan targeting the
        /// second crossing of a site the run only crosses once never
        /// fires — the chaos contract then demands byte-identity with
        /// the fault-free run.
        pub fn fired(&self) -> bool {
            self.fired_steps() == self.total_steps
        }

        /// How many steps of the armed sequence fired (in order, from
        /// the front). Equals 1 on a fired single plan.
        pub fn fired_steps(&self) -> u64 {
            self.fired.load(Ordering::SeqCst)
        }
    }

    impl Drop for FaultGuard {
        fn drop(&mut self) {
            *ARMED.lock().unwrap_or_else(std::sync::PoisonError::into_inner) = None;
        }
    }

    /// Count a crossing of `site`; true iff the armed plan's *live*
    /// step fires here.
    pub fn crossing(site: &'static str) -> bool {
        let mut slot = ARMED.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some(armed) = slot.as_mut() {
            if let Some(step) = armed.steps.get(armed.idx) {
                if step.site == site {
                    armed.seen += 1;
                    if armed.seen == step.nth {
                        armed.idx += 1;
                        armed.seen = 0;
                        armed.fired.fetch_add(1, Ordering::SeqCst);
                        return true;
                    }
                }
            }
        }
        false
    }
}

#[cfg(ggfault)]
pub use active::{ComposedPlan, FaultGuard, FaultPlan, InjectedFault};

/// A fault site that *panics* when its plan fires (Abort/Fatal sites).
/// Zero-cost no-op unless built with `--cfg ggfault`.
#[inline(always)]
pub fn point(site: &'static str) {
    #[cfg(ggfault)]
    if active::crossing(site) {
        std::panic::panic_any(active::InjectedFault { site });
    }
    #[cfg(not(ggfault))]
    let _ = site;
}

/// A fault site that *reports* when its plan fires (Degrade sites —
/// the caller turns `true` into the failure it simulates, e.g. a
/// spawn error). Always `false` unless built with `--cfg ggfault`.
#[inline(always)]
#[must_use]
pub fn injected(site: &'static str) -> bool {
    #[cfg(ggfault)]
    {
        active::crossing(site)
    }
    #[cfg(not(ggfault))]
    {
        let _ = site;
        false
    }
}

/// A fault site that *stalls* when its plan fires (Delay sites): the
/// executing thread sleeps [`DELAY_STALL`] wall-clock, simulating a
/// straggler. Returns whether it stalled. Zero-cost no-op (always
/// `false`) unless built with `--cfg ggfault`.
#[inline(always)]
pub fn stall(site: &'static str) -> bool {
    #[cfg(ggfault)]
    {
        if active::crossing(site) {
            std::thread::sleep(DELAY_STALL);
            return true;
        }
        false
    }
    #[cfg(not(ggfault))]
    {
        let _ = site;
        false
    }
}

/// Marker prefix for deliberate test panics (model-check / unit suites
/// that panic inside contained jobs): payloads carrying it are
/// silenced by [`quiet_panic_hook`].
pub const EXPECTED_PANIC: &str = "[expected-test-panic]";

/// Install (once) a panic hook that suppresses the default
/// stderr-spew for *expected* panics — injected faults and payloads
/// tagged [`EXPECTED_PANIC`] — while delegating everything else to
/// the previous hook. Chaos and containment tests cross panics by the
/// hundred; without this every one prints a backtrace banner.
pub fn quiet_panic_hook() {
    use std::sync::Once;
    static INSTALL: Once = Once::new();
    INSTALL.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            #[cfg(ggfault)]
            if info.payload().downcast_ref::<active::InjectedFault>().is_some() {
                return;
            }
            let expected = info
                .payload()
                .downcast_ref::<&str>()
                .map(|s| s.contains(EXPECTED_PANIC))
                .or_else(|| {
                    info.payload().downcast_ref::<String>().map(|s| s.contains(EXPECTED_PANIC))
                })
                .unwrap_or(false);
            if !expected {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_dotted() {
        for (i, s) in SITES.iter().enumerate() {
            assert!(s.name.contains('.'), "{} is not a dotted path", s.name);
            assert!(!s.what.is_empty());
            for other in &SITES[i + 1..] {
                assert_ne!(s.name, other.name, "duplicate site");
            }
        }
    }

    #[test]
    fn delay_twins_shadow_registered_sites() {
        // Every `*.slow` site must be the Delay twin of a registered
        // non-Delay site, so the chaos matrix can pair each straggler
        // with the panic contract it shadows.
        for s in SITES.iter().filter(|s| s.kind == SiteKind::Delay) {
            let base = s.name.strip_suffix(".slow").expect("Delay sites are named <base>.slow");
            assert!(
                SITES.iter().any(|b| b.name == base && b.kind != SiteKind::Delay),
                "{} has no registered base site",
                s.name
            );
        }
    }

    #[test]
    fn sites_are_inert_without_a_plan() {
        // In non-ggfault builds this is the whole story; under ggfault
        // it checks the unarmed path.
        for s in SITES {
            point(s.name);
            assert!(!injected(s.name));
            assert!(!stall(s.name));
        }
    }

    #[cfg(ggfault)]
    #[test]
    fn plan_fires_exactly_the_nth_crossing() {
        quiet_panic_hook();
        let guard = FaultPlan { site: "scheduler.worker.copy", nth: 3 }.arm();
        assert!(!injected("scheduler.worker.copy")); // crossing 1
        point("scheduler.worker.work"); // other sites don't count
        assert!(!injected("scheduler.worker.copy")); // crossing 2
        assert!(!guard.fired());
        assert_eq!(guard.fired_steps(), 0);
        let err = std::panic::catch_unwind(|| point("scheduler.worker.copy")).unwrap_err();
        let fault = err.downcast_ref::<InjectedFault>().expect("typed payload");
        assert_eq!(fault.site, "scheduler.worker.copy");
        assert!(guard.fired());
        assert_eq!(guard.fired_steps(), 1);
        // Crossings after the shot are clean again.
        point("scheduler.worker.copy");
        drop(guard);
        // And a dropped guard fully disarms.
        point("scheduler.worker.copy");
    }

    #[cfg(ggfault)]
    #[test]
    fn degrade_sites_report_instead_of_panicking() {
        let guard = FaultPlan::first("scheduler.spawn").arm();
        assert!(injected("scheduler.spawn"));
        assert!(guard.fired());
        assert!(!injected("scheduler.spawn"), "one-shot");
    }

    #[cfg(ggfault)]
    #[test]
    fn delay_sites_stall_for_the_contracted_duration() {
        let guard = FaultPlan::first("scheduler.worker.fill.slow").arm();
        let t0 = std::time::Instant::now();
        assert!(stall("scheduler.worker.fill.slow"));
        assert!(t0.elapsed() >= DELAY_STALL, "stall must sleep the full DELAY_STALL");
        assert!(guard.fired());
        assert!(!stall("scheduler.worker.fill.slow"), "one-shot");
    }

    #[cfg(ggfault)]
    #[test]
    fn composed_plan_fires_steps_in_order() {
        // Step 2's site does not count crossings until step 1 fired.
        let guard = FaultPlan::first("scheduler.spawn")
            .then(FaultPlan { site: "scheduler.worker.fill.slow", nth: 2 })
            .arm();
        assert!(!stall("scheduler.worker.fill.slow"), "step 2 is not live yet");
        assert!(injected("scheduler.spawn"), "step 1 fires");
        assert_eq!(guard.fired_steps(), 1);
        assert!(!guard.fired(), "one of two steps is not 'fired'");
        assert!(!stall("scheduler.worker.fill.slow"), "crossing 1 of 2 for step 2");
        assert!(stall("scheduler.worker.fill.slow"), "crossing 2 fires step 2");
        assert_eq!(guard.fired_steps(), 2);
        assert!(guard.fired());
        // A fully-fired plan is inert.
        assert!(!injected("scheduler.spawn"));
        assert!(!stall("scheduler.worker.fill.slow"));
    }

    #[cfg(ggfault)]
    #[test]
    fn three_step_chains_compose() {
        quiet_panic_hook();
        let guard = FaultPlan::first("scheduler.spawn")
            .then(FaultPlan::first("scheduler.spawn"))
            .then(FaultPlan::first("scheduler.worker.work"))
            .arm();
        assert!(injected("scheduler.spawn"));
        assert!(injected("scheduler.spawn"));
        assert_eq!(guard.fired_steps(), 2);
        let err = std::panic::catch_unwind(|| point("scheduler.worker.work")).unwrap_err();
        assert!(err.downcast_ref::<InjectedFault>().is_some());
        assert_eq!(guard.fired_steps(), 3);
        assert!(guard.fired());
    }
}
