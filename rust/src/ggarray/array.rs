//! The GGArray macro-structure (paper §IV): one LFVector per thread
//! block + a prefix-sum index for global addressing.
//!
//! Operations mirror the paper's evaluation:
//!
//! * [`GgArray::grow_for`] — the *grow/resize* phase: allocate missing
//!   buckets (device-side, serialising on the allocator);
//! * [`GgArray::insert_bulk`] — the *insertion* phase: scan-based index
//!   assignment + element writes inside a kernel;
//! * [`GgArray::read_write_block`] (`rw_b`) and
//!   [`GgArray::read_write_global`] (`rw_g`) — the two §VI.B access
//!   patterns;
//! * [`crate::ggarray::flatten`] — move data out to a contiguous array for
//!   the two-phase pattern of §VI.D.
//!
//! Real data lives in host buffers (exact numerics); modeled GPU time
//! accrues on the owned simulation [`Clock`].

use crate::insertion::{self, InsertionKind, InsertShape};
use crate::sim::clock::{Category, Clock, ClockMark, Phase};
use crate::sim::kernel::{self, KernelProfile};
use crate::sim::memory::{HeapMark, OomError, VramHeap};
use crate::sim::spec::DeviceSpec;

use super::index::PrefixIndex;
use super::lfvector::LfVector;

/// Construction parameters.
#[derive(Debug, Clone)]
pub struct GgConfig {
    /// Number of LFVectors (= thread blocks). Paper sweeps 1…16384 and
    /// settles on 32 and 512 as the interesting configurations.
    pub num_blocks: usize,
    /// Threads per block for the structure's kernels.
    pub threads_per_block: u32,
    /// First bucket size per LFVector (power of two).
    pub first_bucket_size: usize,
    /// Default insertion algorithm.
    pub insertion: InsertionKind,
}

impl GgConfig {
    /// Defaults from the paper's setup: 1024-thread blocks, warp-scan
    /// insertion, 1024-element first buckets.
    pub fn new(num_blocks: usize) -> GgConfig {
        GgConfig {
            num_blocks,
            threads_per_block: 1024,
            first_bucket_size: 1024,
            insertion: InsertionKind::WarpScan,
        }
    }

    pub fn with_first_bucket(mut self, fbs: usize) -> GgConfig {
        self.first_bucket_size = fbs;
        self
    }

    pub fn with_insertion(mut self, kind: InsertionKind) -> GgConfig {
        self.insertion = kind;
        self
    }
}

/// Timing/allocation report for one structure operation.
#[derive(Debug, Clone, Copy, Default)]
pub struct OpReport {
    /// Simulated time of the operation, µs.
    pub us: f64,
    /// Buckets allocated during the operation.
    pub buckets_allocated: usize,
    /// Elements touched.
    pub elements: u64,
}

impl OpReport {
    pub fn total_ms(&self) -> f64 {
        self.us / 1e3
    }

    /// Fold another report into this one (concat/merge bookkeeping).
    pub fn absorb(&mut self, other: &OpReport) {
        self.us += other.us;
        self.buckets_allocated += other.buckets_allocated;
        self.elements += other.elements;
    }
}

/// The growable GPU array.
#[derive(Debug)]
pub struct GgArray<T> {
    cfg: GgConfig,
    spec: DeviceSpec,
    heap: VramHeap,
    clock: Clock,
    vectors: Vec<LfVector<T>>,
    index: PrefixIndex,
    /// Epoch hook (paper §VI.D two-phase pattern): a sealed array rejects
    /// growth/insertion until [`GgArray::reopen`] — the flatten window.
    sealed: bool,
}

impl<T: Copy + Default> GgArray<T> {
    /// New empty GGArray with a heap covering the device's full VRAM.
    pub fn new(cfg: GgConfig, spec: DeviceSpec) -> GgArray<T> {
        let heap = VramHeap::new(spec.clone());
        Self::with_heap(cfg, spec, heap)
    }

    /// New GGArray over an explicit heap (budget experiments).
    pub fn with_heap(cfg: GgConfig, spec: DeviceSpec, heap: VramHeap) -> GgArray<T> {
        assert!(cfg.num_blocks > 0, "GGArray needs at least one LFVector");
        let vectors = (0..cfg.num_blocks).map(|_| LfVector::new(cfg.first_bucket_size)).collect();
        GgArray { cfg, spec, heap, clock: Clock::new(), vectors, index: PrefixIndex::new(), sealed: false }
    }

    // ---------- epoch lifecycle (two-phase pattern, §VI.D) ----------

    /// Seal the array for the flatten window of a two-phase epoch:
    /// subsequent `grow_for`/`insert_bulk`/`push_*` calls panic until
    /// [`GgArray::reopen`]. Reads, flatten, shrink and clear stay legal.
    pub fn seal(&mut self) {
        self.sealed = true;
    }

    /// Reopen after a seal: the next insert epoch may grow the array
    /// again.
    pub fn reopen(&mut self) {
        self.sealed = false;
    }

    pub fn is_sealed(&self) -> bool {
        self.sealed
    }

    // ---------- introspection ----------

    pub fn len(&self) -> usize {
        self.vectors.iter().map(|v| v.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.vectors.iter().map(|v| v.capacity()).sum()
    }

    pub fn num_blocks(&self) -> usize {
        self.cfg.num_blocks
    }

    pub fn config(&self) -> &GgConfig {
        &self.cfg
    }

    pub fn spec(&self) -> &DeviceSpec {
        &self.spec
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    pub fn heap(&self) -> &VramHeap {
        &self.heap
    }

    pub fn vectors(&self) -> &[LfVector<T>] {
        &self.vectors
    }

    /// Allocated bytes (simulated VRAM) for element storage.
    pub fn allocated_bytes(&self) -> u64 {
        self.vectors.iter().map(|v| v.allocated_bytes()).sum()
    }

    /// Memory overhead ratio: allocated / optimal. §V bounds this by 2
    /// (plus the O(B·fbs) floor for nearly-empty arrays).
    pub fn overhead_ratio(&self) -> f64 {
        let live = (self.len() * std::mem::size_of::<T>()) as f64;
        if live == 0.0 {
            return f64::INFINITY;
        }
        self.allocated_bytes() as f64 / live
    }

    // ---------- element access ----------

    /// Read via the global prefix index (host-side; the kernel-side cost
    /// is modeled by [`GgArray::read_write_global`]).
    pub fn get(&self, i: u64) -> Option<T> {
        let (b, l) = self.index.locate(i)?;
        self.vectors[b].get(l as usize)
    }

    /// Write via the global prefix index.
    pub fn set(&mut self, i: u64, v: T) -> bool {
        match self.index.locate(i) {
            Some((b, l)) => {
                self.vectors[b].set(l as usize, v);
                true
            }
            None => false,
        }
    }

    /// Per-block sizes (for tests and the coordinator's router).
    pub fn block_sizes(&self) -> Vec<u64> {
        self.block_sizes_iter().collect()
    }

    /// Per-block sizes without materialising a vector — the router input
    /// on the dispatch hot path (callers extend a reusable buffer).
    pub fn block_sizes_iter(&self) -> impl Iterator<Item = u64> + '_ {
        self.vectors.iter().map(|v| v.len() as u64)
    }

    // ---------- the paper's operations ----------

    /// Even distribution of `n` new elements over the blocks
    /// (`counts[b] = ⌊n/B⌋ + (b < n mod B)` — the paper's duplication test
    /// inserts one element per existing element, which is exactly even).
    pub fn even_split(&self, n: usize) -> Vec<usize> {
        let b = self.cfg.num_blocks;
        (0..b).map(|i| n / b + usize::from(i < n % b)).collect()
    }

    /// Grow phase: ensure every block can hold `extra[b]` more elements.
    /// Device-side bucket allocations serialise on the heap allocator —
    /// this is why GGArray512 grows slower than GGArray32 (Table II).
    pub fn grow_for(&mut self, extra: &[usize]) -> Result<OpReport, OomError> {
        assert_eq!(extra.len(), self.cfg.num_blocks);
        assert!(!self.sealed, "grow_for on a sealed GgArray (reopen the epoch first)");
        let phase = Phase::start(&self.clock);
        // One kernel launches the growth; blocks then race on CAS flags.
        self.clock.charge(Category::Launch, self.spec.cost.kernel_launch_us);
        let mut buckets = 0;
        for (v, &e) in self.vectors.iter_mut().zip(extra) {
            if e == 0 {
                continue;
            }
            buckets += v.reserve(v.len() + e, &mut self.heap, &mut self.clock)?;
        }
        Ok(OpReport {
            us: phase.elapsed_us(&self.clock),
            buckets_allocated: buckets,
            elements: extra.iter().map(|&e| e as u64).sum(),
        })
    }

    /// Insert `values`, splitting them evenly over the LFVectors, using
    /// algorithm `kind`. Any buckets not pre-grown are allocated on
    /// demand (Algorithm 1's `new_bucket` path).
    pub fn insert_bulk(&mut self, values: &[T], kind: InsertionKind) -> Result<OpReport, OomError> {
        assert!(!self.sealed, "insert_bulk on a sealed GgArray (reopen the epoch first)");
        let phase = Phase::start(&self.clock);
        let counts = self.even_split(values.len());
        // Real data placement: per-block bulk push_back (the intra-block
        // scan fixes the order; cross-block order follows block id).
        let mut buckets = 0;
        let mut off = 0usize;
        let before_allocs = self.heap.alloc_calls();
        for (b, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let before = self.vectors[b].bucket_count();
            self.vectors[b].push_back_bulk(&values[off..off + c], &mut self.heap, &mut self.clock)?;
            buckets += self.vectors[b].bucket_count() - before;
            off += c;
        }
        debug_assert_eq!(off, values.len());
        let _ = before_allocs;
        // Modeled kernel cost of the insertion itself.
        let shape = self.insert_shape(values.len() as u64);
        kernel::launch(&self.spec, &mut self.clock, &insertion::profile(&self.spec, kind, &shape));
        // Index rebuild: a B-wide scan kernel.
        self.rebuild_index_charged();
        Ok(OpReport {
            us: phase.elapsed_us(&self.clock),
            buckets_allocated: buckets,
            elements: values.len() as u64,
        })
    }

    /// The `InsertShape` for inserting `n` elements into this structure.
    fn insert_shape(&self, n: u64) -> InsertShape {
        InsertShape {
            // The paper: every thread of every block participates in the
            // scan/sync even when not inserting; threads = current size
            // rounded up to the grid.
            threads: n.max(self.len() as u64),
            inserts: n,
            elem_bytes: std::mem::size_of::<T>() as u64,
            blocks: self.cfg.num_blocks as u64,
            threads_per_block: self.cfg.threads_per_block,
            counters: self.cfg.num_blocks as u64,
            write_eff: self.spec.cost.ggarray_insert_eff,
        }
    }

    /// Convenience for docs/quickstart: grow + insert in one call with the
    /// configured algorithm.
    pub fn grow_and_insert(&mut self, values: &[T], kind: InsertionKind) -> OpReport {
        let split = self.even_split(values.len());
        let g = self.grow_for(&split).expect("simulated OOM in grow_and_insert");
        let i = self.insert_bulk(values, kind).expect("simulated OOM in insert_bulk");
        OpReport {
            us: g.us + i.us,
            buckets_allocated: g.buckets_allocated + i.buckets_allocated,
            elements: i.elements,
        }
    }

    /// Rebuild the prefix index, charging the small scan kernel.
    pub fn rebuild_index_charged(&mut self) {
        self.clock.charge(Category::Launch, self.spec.cost.kernel_launch_us);
        // B-element exclusive scan: trivially bandwidth-bound.
        let bytes = (self.cfg.num_blocks * 8) as f64 * 2.0;
        self.clock.charge(Category::Memory, bytes / (self.spec.bw_bytes_per_us() * self.spec.cost.coalesced_eff).max(1.0));
        self.index.rebuild(self.vectors.iter().map(|v| v.len() as u64));
    }

    /// `rw_b` (paper §VI.B): one GPU block walks each LFVector — no
    /// per-element search, but bucket-pointer indirection and poor
    /// coalescing. Applies `f` to every element for real.
    pub fn read_write_block(&mut self, flops_per_elem: f64, mut f: impl FnMut(&mut T)) -> OpReport {
        let phase = Phase::start(&self.clock);
        let n: u64 = self.len() as u64;
        for v in &mut self.vectors {
            v.for_each_mut(&mut f);
        }
        let elem = std::mem::size_of::<T>() as f64;
        let chunks_per_block = crate::util::math::ceil_div(
            crate::util::math::ceil_div(n.max(1), self.cfg.num_blocks as u64),
            self.cfg.threads_per_block as u64,
        );
        let profile = KernelProfile {
            blocks: self.cfg.num_blocks as u64,
            threads_per_block: self.cfg.threads_per_block,
            bytes: 2.0 * elem * n as f64,
            coalescing_eff: self.spec.cost.ggarray_block_eff,
            flops_fp32: flops_per_elem * n as f64,
            flops_mxu: 0.0,
            mxu_utilisation: 1.0,
            per_block_us: chunks_per_block as f64 * self.spec.cost.rw_chunk_overhead_us,
            atomic_us: 0.0,
            extra_us: 0.0,
        };
        kernel::launch(&self.spec, &mut self.clock, &profile);
        OpReport { us: phase.elapsed_us(&self.clock), buckets_allocated: 0, elements: n }
    }

    /// `rw_g` (paper §VI.B): one thread per element, each binary-searching
    /// the prefix index — the slow path. Applies `f` for real.
    pub fn read_write_global(&mut self, flops_per_elem: f64, mut f: impl FnMut(&mut T)) -> OpReport {
        let phase = Phase::start(&self.clock);
        // Make sure the index matches the data (cheap host-side check).
        debug_assert_eq!(self.index.total(), self.len() as u64);
        let n = self.len() as u64;
        // Host side: global order IS block-major order, so a per-block
        // walk applies `f` in exactly the sequence the per-element
        // binary-search loop would (perf pass: avoids a locate() per
        // element; the *device* cost model below still charges the
        // binary-search path — that is rw_g's defining cost).
        for v in &mut self.vectors {
            v.for_each_mut(&mut f);
        }
        let elem = std::mem::size_of::<T>() as f64;
        let depth = self.index.search_depth() as f64;
        let profile = KernelProfile {
            blocks: crate::util::math::ceil_div(n.max(1), self.cfg.threads_per_block as u64),
            threads_per_block: self.cfg.threads_per_block,
            bytes: 2.0 * elem * n as f64,
            coalescing_eff: self.spec.cost.ggarray_global_eff,
            // binary search: ~4 ops per level + the op itself
            flops_fp32: (flops_per_elem + 4.0 * depth) * n as f64,
            flops_mxu: 0.0,
            mxu_utilisation: 1.0,
            per_block_us: 0.0,
            atomic_us: 0.0,
            extra_us: 0.0,
        };
        kernel::launch(&self.spec, &mut self.clock, &profile);
        OpReport { us: phase.elapsed_us(&self.clock), buckets_allocated: 0, elements: n }
    }

    /// Overwrite the whole contents from a block-major flat slice (the
    /// inverse of [`GgArray::to_vec`]) — used by the coordinator to write
    /// kernel outputs back without a per-element index lookup.
    pub fn overwrite_from(&mut self, data: &[T]) {
        assert_eq!(data.len(), self.len(), "overwrite_from length mismatch");
        let mut it = data.iter();
        for v in &mut self.vectors {
            v.for_each_mut(|x| *x = *it.next().expect("length checked"));
        }
    }

    /// Shrink every LFVector's logical length proportionally to a global
    /// target and release now-unused buckets (paper future work: "grow or
    /// shrink as required"). Keeps the paper's block-major semantics:
    /// each block keeps its prefix. Returns buckets freed.
    pub fn shrink_to(&mut self, target_len: usize) -> usize {
        let split: Vec<usize> = {
            let b = self.cfg.num_blocks;
            (0..b).map(|i| target_len / b + usize::from(i < target_len % b)).collect()
        };
        let mut freed = 0;
        for (v, &keep) in self.vectors.iter_mut().zip(&split) {
            v.truncate(keep.min(v.len()));
            freed += v.shrink_to_fit(&mut self.heap, &mut self.clock);
        }
        self.rebuild_index_charged();
        freed
    }

    /// Free all storage (simulated VRAM back to the heap).
    pub fn clear(&mut self) {
        for v in &mut self.vectors {
            v.free_all(&mut self.heap, &mut self.clock);
        }
        self.index.rebuild(std::iter::empty());
    }

    // ---------- op-abort rollback (fault containment) ----------

    /// Capture the cost state (clock + heap counters) before an op that
    /// may abort. Pair with [`GgArray::rewind_costs`].
    pub fn cost_marks(&self) -> (ClockMark, HeapMark) {
        (self.clock.mark(), self.heap.mark())
    }

    /// Rewind the clock and heap counters to marks captured by
    /// [`GgArray::cost_marks`]. Every allocation made since the marks
    /// must already be freed (see [`VramHeap::restore_mark`]).
    pub fn rewind_costs(&mut self, clock_mark: ClockMark, heap_mark: HeapMark) {
        self.clock.rewind(clock_mark);
        self.heap.restore_mark(heap_mark);
    }

    /// Abort path of a growth op: roll every block back to
    /// `old_lens[b]`, freeing the buckets the op allocated and erasing
    /// their CAS bookkeeping, then rebuild the prefix index *without*
    /// charging — the caller rewinds to its pre-op cost marks right
    /// after, which erases both the op's charges and the transient
    /// `free` charges this method makes.
    pub fn rollback_growth(&mut self, old_lens: &[usize]) {
        assert_eq!(old_lens.len(), self.cfg.num_blocks, "rollback_growth lens mismatch");
        for (v, &old) in self.vectors.iter_mut().zip(old_lens) {
            if old < v.len() {
                v.rollback_growth(old, &mut self.heap, &mut self.clock);
            }
        }
        self.index.rebuild(self.vectors.iter().map(|v| v.len() as u64));
    }

    /// Direct access for the flatten module / coordinator.
    pub(crate) fn parts_mut(&mut self) -> (&mut Vec<LfVector<T>>, &mut VramHeap, &mut Clock, &DeviceSpec, &GgConfig, &PrefixIndex) {
        (&mut self.vectors, &mut self.heap, &mut self.clock, &self.spec, &self.cfg, &self.index)
    }

    /// Push a single element to a specific block (coordinator routing
    /// path).
    pub fn push_to_block(&mut self, block: usize, v: T) -> Result<usize, OomError> {
        assert!(block < self.cfg.num_blocks);
        assert!(!self.sealed, "push_to_block on a sealed GgArray (reopen the epoch first)");
        self.vectors[block].push_back(v, &mut self.heap, &mut self.clock)
    }

    /// Bulk push to a specific block.
    pub fn push_bulk_to_block(&mut self, block: usize, vs: &[T]) -> Result<std::ops::Range<usize>, OomError> {
        assert!(block < self.cfg.num_blocks);
        assert!(!self.sealed, "push_bulk_to_block on a sealed GgArray (reopen the epoch first)");
        self.vectors[block].push_back_bulk(vs, &mut self.heap, &mut self.clock)
    }

    /// Charge half of [`GgArray::push_bulk_to_block`]: reserve + extend
    /// the block by `n` slots with identical heap/clock charges, no
    /// data. The scheduler fills the slots later with the pure
    /// [`GgArray::fill_block_tail`].
    pub fn push_bulk_uninit_to_block(&mut self, block: usize, n: usize) -> Result<std::ops::Range<usize>, OomError> {
        assert!(block < self.cfg.num_blocks);
        assert!(!self.sealed, "push_bulk_uninit_to_block on a sealed GgArray (reopen the epoch first)");
        self.vectors[block].push_bulk_uninit(n, &mut self.heap, &mut self.clock)
    }

    /// Pure data movement: write `vs` into the *last* `vs.len()` live
    /// slots of `block` (previously extended by
    /// [`GgArray::push_bulk_uninit_to_block`]). Touches no heap/clock
    /// state, so scheduler workers may run it off the coordinator
    /// thread.
    pub fn fill_block_tail(&mut self, block: usize, vs: &[T]) {
        assert!(block < self.cfg.num_blocks);
        let v = &mut self.vectors[block];
        let start = v.len().checked_sub(vs.len()).expect("fill_block_tail larger than block");
        v.write_range(start, vs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> GgArray<u32> {
        GgArray::new(GgConfig { num_blocks: 8, threads_per_block: 256, first_bucket_size: 4, insertion: InsertionKind::WarpScan }, DeviceSpec::a100())
    }

    #[test]
    fn even_split_exact() {
        let g = small();
        assert_eq!(g.even_split(17), vec![3, 2, 2, 2, 2, 2, 2, 2]);
        assert_eq!(g.even_split(0), vec![0; 8]);
        assert_eq!(g.even_split(8), vec![1; 8]);
        let s: usize = g.even_split(1_000_003).iter().sum();
        assert_eq!(s, 1_000_003);
    }

    #[test]
    fn insert_then_read_back_global_order() {
        let mut g = small();
        let data: Vec<u32> = (0..1000).collect();
        let rep = g.insert_bulk(&data, InsertionKind::WarpScan).unwrap();
        assert_eq!(rep.elements, 1000);
        assert_eq!(g.len(), 1000);
        // Global order = block-major; block b got counts[b] consecutive
        // input values.
        let counts = g.even_split(1000);
        let mut expected = vec![];
        let mut off = 0;
        for &c in &counts {
            expected.extend(off as u32..(off + c) as u32);
            off += c;
        }
        let got: Vec<u32> = (0..1000).map(|i| g.get(i).unwrap()).collect();
        assert_eq!(got, expected);
        assert_eq!(g.get(1000), None);
    }

    #[test]
    fn grow_then_insert_allocates_nothing_new() {
        let mut g = small();
        let data = vec![7u32; 500];
        let split = g.even_split(500);
        let grow = g.grow_for(&split).unwrap();
        assert!(grow.buckets_allocated > 0);
        let ins = g.insert_bulk(&data, InsertionKind::WarpScan).unwrap();
        assert_eq!(ins.buckets_allocated, 0, "grow should have pre-allocated all buckets");
    }

    #[test]
    fn second_grow_is_cheap_when_capacity_suffices() {
        // Paper: "the third resize barely takes time" — growth factor >2
        // early on means a later grow may be free.
        let mut g = small();
        g.insert_bulk(&vec![1u32; 64], InsertionKind::WarpScan).unwrap();
        let cap_before = g.capacity();
        if g.capacity() >= 2 * g.len() {
            let rep = g.grow_for(&g.even_split(g.len())).unwrap();
            assert_eq!(rep.buckets_allocated, 0);
            assert_eq!(g.capacity(), cap_before);
        }
    }

    #[test]
    fn overhead_ratio_bounded_by_two_ish() {
        let mut g = small();
        for round in 0..6 {
            let n = g.len().max(64);
            g.insert_bulk(&vec![round as u32; n], InsertionKind::WarpScan).unwrap();
            let r = g.overhead_ratio();
            // ≤ 2 + small floor effect from 8 blocks × fbs 4.
            assert!(r < 2.3, "round {round}: ratio {r}");
        }
    }

    #[test]
    fn rw_block_applies_op_and_charges() {
        let mut g = small();
        g.insert_bulk(&(0..100u32).collect::<Vec<_>>(), InsertionKind::WarpScan).unwrap();
        let before = g.clock().now_us();
        let rep = g.read_write_block(30.0, |x| *x += 1);
        assert!(rep.us > 0.0);
        assert!(g.clock().now_us() > before);
        let got: Vec<u32> = (0..100).map(|i| g.get(i).unwrap()).collect();
        let want: Vec<u32> = (1..101).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn rw_global_equals_rw_block_semantics() {
        let mut a = small();
        let mut b = small();
        let data: Vec<u32> = (0..500).map(|i| i * 7).collect();
        a.insert_bulk(&data, InsertionKind::WarpScan).unwrap();
        b.insert_bulk(&data, InsertionKind::WarpScan).unwrap();
        a.read_write_block(1.0, |x| *x = x.wrapping_mul(3));
        b.read_write_global(1.0, |x| *x = x.wrapping_mul(3));
        for i in 0..500 {
            assert_eq!(a.get(i), b.get(i), "i={i}");
        }
    }

    #[test]
    fn rw_global_slower_than_rw_block() {
        let mut g = GgArray::<u32>::new(GgConfig::new(512), DeviceSpec::a100());
        g.insert_bulk(&vec![1u32; 1 << 20], InsertionKind::WarpScan).unwrap();
        let b = g.read_write_block(30.0, |x| *x += 1);
        let gl = g.read_write_global(30.0, |x| *x += 1);
        assert!(gl.us > b.us, "rw_g {} !> rw_b {}", gl.us, b.us);
    }

    #[test]
    fn clear_releases_everything() {
        let mut g = small();
        g.insert_bulk(&vec![1u32; 1000], InsertionKind::WarpScan).unwrap();
        assert!(g.heap().used() > 0);
        g.clear();
        assert_eq!(g.heap().used(), 0);
        assert_eq!(g.len(), 0);
        assert_eq!(g.get(0), None);
    }

    #[test]
    fn shrink_releases_memory_and_keeps_prefixes() {
        let mut g = small();
        g.insert_bulk(&(0..8000u32).collect::<Vec<_>>(), InsertionKind::WarpScan).unwrap();
        let used_before = g.heap().used();
        let freed = g.shrink_to(800);
        assert!(freed > 0);
        assert!(g.heap().used() < used_before);
        assert_eq!(g.len(), 800);
        // Each block kept its prefix: global get still coherent.
        for i in 0..800u64 {
            assert!(g.get(i).is_some(), "i={i}");
        }
        assert_eq!(g.get(800), None);
        // Can grow again after shrinking.
        g.insert_bulk(&vec![9u32; 1000], InsertionKind::WarpScan).unwrap();
        assert_eq!(g.len(), 1800);
    }

    #[test]
    fn seal_reopen_lifecycle() {
        let mut g = small();
        g.insert_bulk(&vec![1u32; 100], InsertionKind::WarpScan).unwrap();
        assert!(!g.is_sealed());
        g.seal();
        assert!(g.is_sealed());
        // Reads stay legal while sealed.
        assert_eq!(g.get(0), Some(1));
        assert_eq!(g.len(), 100);
        g.reopen();
        g.insert_bulk(&vec![2u32; 10], InsertionKind::WarpScan).unwrap();
        assert_eq!(g.len(), 110);
    }

    #[test]
    #[should_panic(expected = "sealed")]
    fn sealed_rejects_insert() {
        let mut g = small();
        g.seal();
        let _ = g.insert_bulk(&[1u32], InsertionKind::WarpScan);
    }

    #[test]
    fn rollback_growth_restores_array_byte_identically() {
        let mut g = small();
        g.insert_bulk(&(0..100u32).collect::<Vec<_>>(), InsertionKind::WarpScan).unwrap();
        let old_lens: Vec<usize> = g.vectors().iter().map(|v| v.len()).collect();
        let (len0, cap0, used0, t0) = (g.len(), g.capacity(), g.heap().used(), g.clock().now_us());
        let (cm, hm) = g.cost_marks();
        // A growth op that aborts mid-flight: some blocks extended.
        for b in 0..4 {
            g.push_bulk_uninit_to_block(b, 300).unwrap();
        }
        assert!(g.capacity() > cap0);
        g.rollback_growth(&old_lens);
        g.rewind_costs(cm, hm);
        assert_eq!(g.len(), len0);
        assert_eq!(g.capacity(), cap0);
        assert_eq!(g.heap().used(), used0);
        assert_eq!(g.clock().now_us(), t0);
        for i in 0..len0 as u64 {
            assert!(g.get(i).is_some(), "index coherent after rollback, i={i}");
        }
        assert_eq!(g.get(len0 as u64), None);
        // The array keeps serving inserts after the abort.
        g.insert_bulk(&vec![5u32; 50], InsertionKind::WarpScan).unwrap();
        assert_eq!(g.len(), len0 + 50);
    }

    #[test]
    fn table2_ggarray_insert_shape() {
        // GGArray512 insert of 5.12e8 u32 on A100: paper 11.79 ms.
        // (Pure cost model — no real data at this size.)
        let spec = DeviceSpec::a100();
        let shape = InsertShape {
            threads: 512_000_000,
            inserts: 512_000_000,
            elem_bytes: 4,
            blocks: 512,
            threads_per_block: 1024,
            counters: 512,
            write_eff: spec.cost.ggarray_insert_eff,
        };
        let ms = insertion::cost_us(&spec, InsertionKind::WarpScan, &shape) / 1e3;
        assert!((ms - 11.79).abs() < 2.5, "modeled {ms:.2} vs paper 11.79");
        // GGArray32: paper 27.90 ms.
        let shape32 = InsertShape { blocks: 32, counters: 32, ..shape };
        let ms32 = insertion::cost_us(&spec, InsertionKind::WarpScan, &shape32) / 1e3;
        assert!((ms32 - 27.90).abs() < 7.0, "modeled {ms32:.2} vs paper 27.90");
        assert!(ms32 > ms * 1.8);
    }

    #[test]
    fn table2_rw_b_cost_shape() {
        // GGArray512 rw of 1.024e9 u32 on A100: paper 69.73 ms;
        // GGArray32: 198.32 ms. Check the modeled costs land in range.
        let spec = DeviceSpec::a100();
        let model_rw = |blocks: u64| {
            let n = 1.024e9;
            let chunks = (n / blocks as f64 / 1024.0).ceil();
            let p = KernelProfile {
                blocks,
                threads_per_block: 1024,
                bytes: 2.0 * 4.0 * n,
                coalescing_eff: spec.cost.ggarray_block_eff,
                flops_fp32: 30.0 * n,
                flops_mxu: 0.0,
                mxu_utilisation: 1.0,
                per_block_us: chunks * spec.cost.rw_chunk_overhead_us,
                atomic_us: 0.0,
                extra_us: 0.0,
            };
            kernel::model(&spec, &p).total_us / 1e3
        };
        let ms512 = model_rw(512);
        let ms32 = model_rw(32);
        assert!((ms512 - 69.73).abs() < 8.0, "GGArray512 rw modeled {ms512:.1} vs 69.73");
        assert!(ms32 > 140.0 && ms32 < 230.0, "GGArray32 rw modeled {ms32:.1} vs 198.32");
        assert!(ms32 > 2.0 * ms512);
    }
}
