//! Flatten: move a GGArray's contents into a contiguous array (paper
//! §VI.C/D — the two-phase pattern: grow with GGArray, flatten once, then
//! run the work phase at static-array speed).
//!
//! The device kernel is a per-block gather: block `b` copies its LFVector
//! into `flat[prefix[b] .. prefix[b]+len_b]`. Reads pay the bucket
//! indirection; writes are fully coalesced.

use crate::sim::kernel::{self, KernelProfile};
use crate::sim::memory::{AllocId, OomError};

use super::array::{GgArray, OpReport};
use super::index::PrefixIndex;

/// Result of a flatten: the contiguous data plus the timing report.
#[derive(Debug)]
pub struct Flattened<T> {
    pub data: Vec<T>,
    pub report: OpReport,
    /// The destination allocation in the source array's heap, so callers
    /// can govern the flat copy's simulated VRAM: release it for a
    /// throwaway snapshot, or — for a sealed epoch — *transfer* it into
    /// the epoch-owned heap at commit
    /// ([`crate::sim::memory::VramHeap::transfer_to`]), so the shard's
    /// budget is freed for the next epoch while the bytes stay resident.
    pub alloc: Option<AllocId>,
}

/// A multi-shard flatten: per-shard flattened contents concatenated into
/// one contiguous array, plus a shard-offset index so a global index can
/// be mapped back to its (shard, local) coordinates — the sealed-epoch
/// analogue of the per-block [`PrefixIndex`].
#[derive(Debug)]
pub struct ShardedFlattened<T> {
    /// Shard-major concatenation (shard 0's flat data, then shard 1's, …).
    pub data: Vec<T>,
    /// Prefix sums of per-shard lengths: `index.locate(i)` yields
    /// `(shard, local_index)`.
    pub index: PrefixIndex,
    /// Summed per-shard flatten reports.
    pub report: OpReport,
}

impl<T: Copy> ShardedFlattened<T> {
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn shards(&self) -> usize {
        self.index.blocks()
    }

    /// Global start offset of shard `s` in the concatenated data.
    pub fn shard_start(&self, s: usize) -> u64 {
        self.index.start_of(s)
    }

    /// Map a global index to `(shard, local_index)`.
    pub fn locate(&self, i: u64) -> Option<(usize, u64)> {
        self.index.locate(i)
    }

    /// Read a global index.
    pub fn get(&self, i: u64) -> Option<T> {
        self.data.get(i as usize).copied()
    }
}

/// Concatenate per-shard [`Flattened`] results (in shard order) into one
/// [`ShardedFlattened`] with the shard-offset index. Pure host-side
/// bookkeeping: the per-shard gather kernels were already charged by the
/// individual flattens, and the shard outputs land directly in their
/// final offsets (writes are disjoint), so no extra device pass is due.
///
/// Any `alloc` still attached to a part is dropped *untracked* here —
/// callers that govern simulated VRAM (e.g. the coordinator's seal
/// transaction) must `take()` the allocations first.
pub fn concat<T: Copy + Default>(parts: Vec<Flattened<T>>) -> ShardedFlattened<T> {
    let mut index = PrefixIndex::new();
    index.rebuild(parts.iter().map(|p| p.data.len() as u64));
    let total: usize = parts.iter().map(|p| p.data.len()).sum();
    let mut data = Vec::with_capacity(total);
    let mut report = OpReport::default();
    for p in parts {
        report.absorb(&p.report);
        data.extend_from_slice(&p.data);
    }
    ShardedFlattened { data, index, report }
}

/// Merge successive sealed segments into one contiguous segment — the
/// compaction gather of the epoch store. Order is preserved (segment 0's
/// data, then segment 1's, …) so the merged bytes are identical to the
/// concatenation of the inputs; the rebuilt index maps global offsets to
/// `(original_segment, local)` coordinates.
///
/// Host-side data movement only: the caller owns the *transaction* —
/// both the modeled time (one read+write gather pass over the merged
/// bytes) and the simulated VRAM (the merged destination must be
/// reserved while the source segments are still resident, the gather's
/// transient 2×, and the sources freed only on commit). See
/// [`crate::coordinator::shard::EpochManager::compact`], which can
/// therefore OOM and abort without calling this at all.
pub fn merge_segments<T: Copy + Default>(parts: Vec<ShardedFlattened<T>>) -> ShardedFlattened<T> {
    let mut data = Vec::new();
    let (index, report) = merge_segments_into(&parts, &mut data);
    ShardedFlattened { data, index, report }
}

/// Pooled core of [`merge_segments`]: append every segment's data to
/// `dst` (not cleared — the caller leases and clears the pool) and
/// return the rebuilt per-segment index plus the summed report. The
/// sources are only borrowed, so the caller can recycle their buffers —
/// the epoch store banks the largest freed segment as the gather pool
/// for the next seal/compaction.
pub fn merge_segments_into<T: Copy>(
    parts: &[ShardedFlattened<T>],
    dst: &mut Vec<T>,
) -> (PrefixIndex, OpReport) {
    let mut index = PrefixIndex::new();
    index.rebuild(parts.iter().map(|p| p.len() as u64));
    dst.reserve(parts.iter().map(|p| p.data.len()).sum());
    let mut report = OpReport::default();
    for p in parts {
        report.absorb(&p.report);
        dst.extend_from_slice(&p.data);
    }
    (index, report)
}

/// Flatten every shard and concatenate with a shard-offset index — the
/// sealing step of the sharded two-phase lifecycle. Shard order defines
/// global order, so with block-sliced routing the result is byte-identical
/// to flattening one GgArray holding all the blocks.
///
/// The per-shard flatten destinations are released before returning: the
/// concatenated view lives host-side, so holding simulated VRAM for it
/// would leak a destination per call. Callers that want VRAM-resident
/// sealed views manage the allocations themselves (as the coordinator's
/// seal transaction does).
pub fn flatten_concat<T: Copy + Default>(
    shards: &mut [GgArray<T>],
) -> Result<ShardedFlattened<T>, OomError> {
    let mut data = Vec::new();
    let (index, report) = flatten_concat_into(shards, &mut data)?;
    Ok(ShardedFlattened { data, index, report })
}

/// Pooled [`flatten_concat`]: gather every shard's contents directly
/// into `dst` (appended in shard order — one copy instead of the
/// flatten-then-concat two) and return the shard-offset index and the
/// summed report. The per-shard destination allocations are released
/// before returning, exactly like the collecting version.
pub fn flatten_concat_into<T: Copy + Default>(
    shards: &mut [GgArray<T>],
    dst: &mut Vec<T>,
) -> Result<(PrefixIndex, OpReport), OomError> {
    let mut lens = Vec::with_capacity(shards.len());
    let mut report = OpReport::default();
    for gg in shards.iter_mut() {
        let before = dst.len();
        let (r, alloc) = flatten_into(gg, dst)?;
        if let Some(a) = alloc {
            let (_, heap, clock, _, _, _) = gg.parts_mut();
            heap.free(a, clock);
        }
        report.absorb(&r);
        lens.push((dst.len() - before) as u64);
    }
    let mut index = PrefixIndex::new();
    index.rebuild(lens.into_iter());
    Ok((index, report))
}

/// Flatten the GGArray into a fresh contiguous (simulated-VRAM-resident)
/// array. The GGArray keeps its storage — callers typically `clear()` it
/// afterwards or reuse it for the next growth phase.
///
/// Collecting wrapper over [`flatten_into`] — seal/snapshot hot paths
/// pass a pooled destination instead of taking a fresh vector per call.
pub fn flatten<T: Copy + Default>(gg: &mut GgArray<T>) -> Result<Flattened<T>, OomError> {
    let mut data = Vec::new();
    let (report, alloc) = flatten_into(gg, &mut data)?;
    Ok(Flattened { data, report, alloc })
}

/// Pooled [`flatten`]: append the GGArray's contents to `dst` (the
/// caller-provided reusable destination — not cleared, so multi-shard
/// gathers land shard-after-shard in one buffer) and return the timing
/// report plus the destination allocation in the source heap. Charges
/// are identical to the collecting path: one destination `cudaMalloc`
/// and one gather kernel; the host copy stays `LfVector::copy_into`'s
/// segment-wise bulk copy.
pub fn flatten_into<T: Copy + Default>(
    gg: &mut GgArray<T>,
    dst: &mut Vec<T>,
) -> Result<(OpReport, Option<AllocId>), OomError> {
    let n = gg.len();
    let start = dst.len();
    dst.reserve(n);
    let out = flatten_charged(gg, |vectors| {
        for v in vectors.iter() {
            v.copy_into(dst);
        }
    })?;
    debug_assert_eq!(dst.len() - start, n);
    Ok(out)
}

/// Slice-target [`flatten_into`]: gather the GGArray's contents into
/// `dst`, which must hold exactly `gg.len()` slots — the caller carved it
/// out of a larger pre-sized buffer. Simulated charges (one destination
/// `cudaMalloc`, one gather kernel) are identical to the appending path;
/// what changes is only where the host copy lands, which is what lets
/// the shard scheduler run per-shard gathers concurrently into disjoint
/// sub-slices of one seal destination.
pub fn flatten_to_slice<T: Copy + Default>(
    gg: &mut GgArray<T>,
    dst: &mut [T],
) -> Result<(OpReport, Option<AllocId>), OomError> {
    let n = gg.len();
    assert_eq!(dst.len(), n, "flatten destination must be exactly len slots");
    flatten_charged(gg, |vectors| {
        let mut off = 0usize;
        for v in vectors.iter() {
            off += v.copy_to_slice(&mut dst[off..]);
        }
        debug_assert_eq!(off, n);
    })
}

/// Charge-only [`flatten_to_slice`]: advance the heap/clock exactly as
/// a flatten would — one destination `cudaMalloc`, one gather kernel —
/// without moving any bytes. The host copy is free in simulated time,
/// so the charges here are *identical* to the copying variants; the
/// scheduler runs this serially per shard (deterministic `sim_us`) and
/// hands the pure data movement to stealable gather chunks
/// ([`crate::ggarray::lfvector::LfVector::copy_to_slice`] over
/// disjoint destination sub-slices).
pub fn flatten_charge_only<T: Copy + Default>(
    gg: &mut GgArray<T>,
) -> Result<(OpReport, Option<AllocId>), OomError> {
    flatten_charged(gg, |_| {})
}

/// Shared core of [`flatten_into`] / [`flatten_to_slice`]: one
/// destination `cudaMalloc` in the source heap, the host copy (the
/// caller decides where it lands), one gather kernel — charged in that
/// order so both variants advance the shard clock identically.
fn flatten_charged<T: Copy + Default>(
    gg: &mut GgArray<T>,
    copy: impl FnOnce(&[crate::ggarray::lfvector::LfVector<T>]),
) -> Result<(OpReport, Option<AllocId>), OomError> {
    let n = gg.len();
    let elem = std::mem::size_of::<T>();
    let spec = gg.spec().clone();
    let blocks = gg.num_blocks() as u64;
    let tpb = gg.config().threads_per_block;
    let (vectors, heap, clock, _, _, _) = gg.parts_mut();

    let phase = crate::sim::clock::Phase::start(clock);
    // Destination allocation (one cudaMalloc).
    let dst_alloc = heap.alloc((n * elem) as u64, clock)?;
    // Real copy.
    copy(vectors.as_slice());
    // Gather kernel: read at block-structured efficiency, write coalesced.
    let read = (n * elem) as f64;
    let write = (n * elem) as f64;
    let eff = crate::insertion::warp_scan::blended_eff(
        read,
        spec.cost.ggarray_block_eff,
        write,
        spec.cost.coalesced_eff,
    );
    let profile = KernelProfile::streaming(blocks.max(1), tpb, read + write, eff);
    kernel::launch(&spec, clock, &profile);
    let report = OpReport { us: phase.elapsed_us(clock), buckets_allocated: 0, elements: n as u64 };
    Ok((report, Some(dst_alloc)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ggarray::array::GgConfig;
    use crate::insertion::InsertionKind;
    use crate::sim::spec::DeviceSpec;

    #[test]
    fn flatten_preserves_global_order() {
        let mut g: GgArray<u32> =
            GgArray::new(GgConfig { num_blocks: 8, threads_per_block: 256, first_bucket_size: 4, insertion: InsertionKind::WarpScan }, DeviceSpec::a100());
        let data: Vec<u32> = (0..1234).map(|i| i * 3).collect();
        g.insert_bulk(&data, InsertionKind::WarpScan).unwrap();
        let flat = flatten(&mut g).unwrap();
        assert_eq!(flat.data.len(), 1234);
        let via_get: Vec<u32> = (0..1234).map(|i| g.get(i).unwrap()).collect();
        assert_eq!(flat.data, via_get);
        assert!(flat.report.us > 0.0);
    }

    #[test]
    fn flatten_empty() {
        let mut g: GgArray<u64> = GgArray::new(GgConfig::new(4), DeviceSpec::titan_rtx());
        g.rebuild_index_charged();
        let flat = flatten(&mut g).unwrap();
        assert!(flat.data.is_empty());
    }

    #[test]
    fn flatten_cost_cheaper_than_rw_b() {
        // One flatten ≈ one read at block eff + one coalesced write; it
        // must cost less than an rw_b pass (read+write both at block eff).
        let mut g: GgArray<u32> = GgArray::new(GgConfig::new(512), DeviceSpec::a100());
        g.insert_bulk(&vec![1u32; 1 << 20], InsertionKind::WarpScan).unwrap();
        let rw = g.read_write_block(30.0, |x| *x += 1);
        let fl = flatten(&mut g).unwrap();
        assert!(fl.report.us < rw.us, "flatten {} !< rw_b {}", fl.report.us, rw.us);
    }

    #[test]
    fn flatten_concat_matches_single_array_layout() {
        // 2 shards × 4 blocks receiving the same per-block pushes as one
        // 8-block array must flatten to byte-identical contents, with the
        // shard-offset index at the 4-block boundary.
        let cfg4 = GgConfig { num_blocks: 4, threads_per_block: 256, first_bucket_size: 4, insertion: InsertionKind::WarpScan };
        let cfg8 = GgConfig { num_blocks: 8, ..cfg4.clone() };
        let mut single: GgArray<u32> = GgArray::new(cfg8, DeviceSpec::a100());
        let mut shards: Vec<GgArray<u32>> = (0..2).map(|_| GgArray::new(cfg4.clone(), DeviceSpec::a100())).collect();
        let mut counter = 0u32;
        for b in 0..8usize {
            let n = [5usize, 0, 17, 3, 9, 1, 0, 30][b];
            let chunk: Vec<u32> = (counter..counter + n as u32).collect();
            counter += n as u32;
            single.push_bulk_to_block(b, &chunk).unwrap();
            shards[b / 4].push_bulk_to_block(b % 4, &chunk).unwrap();
        }
        let flat_single = flatten(&mut single).unwrap();
        let sharded = super::flatten_concat(&mut shards).unwrap();
        assert_eq!(sharded.data, flat_single.data);
        assert_eq!(sharded.shards(), 2);
        assert_eq!(sharded.shard_start(0), 0);
        assert_eq!(sharded.shard_start(1), 25); // 5 + 0 + 17 + 3
        assert_eq!(sharded.len(), 65);
        // locate maps every global index to the shard that owns it.
        assert_eq!(sharded.locate(24), Some((0, 24)));
        assert_eq!(sharded.locate(25), Some((1, 0)));
        assert_eq!(sharded.locate(64), Some((1, 39)));
        assert_eq!(sharded.locate(65), None);
        assert_eq!(sharded.get(30), Some(flat_single.data[30]));
    }

    #[test]
    fn concat_sums_reports_and_handles_empty_shards() {
        let mk = |n: u32| Flattened::<u32> {
            data: (0..n).collect(),
            report: OpReport { us: 10.0, buckets_allocated: 1, elements: n as u64 },
            alloc: None,
        };
        let s = super::concat(vec![mk(3), mk(0), mk(2)]);
        assert_eq!(s.data, vec![0, 1, 2, 0, 1]);
        assert_eq!(s.shards(), 3);
        assert!((s.report.us - 30.0).abs() < 1e-12);
        assert_eq!(s.report.elements, 5);
        // Empty middle shard: index skips it.
        assert_eq!(s.locate(3), Some((2, 0)));
        let empty: ShardedFlattened<u32> = super::concat(vec![]);
        assert!(empty.is_empty());
        assert_eq!(empty.locate(0), None);
    }

    #[test]
    fn merge_segments_preserves_bytes_and_order() {
        let mk = |vals: Vec<u32>| {
            concat(vec![Flattened {
                data: vals,
                report: OpReport { us: 5.0, buckets_allocated: 0, elements: 0 },
                alloc: None,
            }])
        };
        let parts = vec![mk(vec![1, 2, 3]), mk(vec![]), mk(vec![9, 8])];
        let want: Vec<u32> = vec![1, 2, 3, 9, 8];
        let merged = super::merge_segments(parts);
        assert_eq!(merged.data, want);
        assert_eq!(merged.len(), 5);
        // Index maps globals back to (original segment, local).
        assert_eq!(merged.locate(2), Some((0, 2)));
        assert_eq!(merged.locate(3), Some((2, 0)));
        assert_eq!(merged.locate(5), None);
        assert!((merged.report.us - 15.0).abs() < 1e-12);
        let empty: ShardedFlattened<u32> = super::merge_segments(vec![]);
        assert!(empty.is_empty());
    }

    #[test]
    fn flatten_into_appends_and_matches_collecting_path() {
        let cfg = GgConfig { num_blocks: 4, threads_per_block: 256, first_bucket_size: 4, insertion: InsertionKind::WarpScan };
        let mk = |lo: u32, hi: u32| {
            let mut g: GgArray<u32> = GgArray::new(cfg.clone(), DeviceSpec::a100());
            g.insert_bulk(&(lo..hi).collect::<Vec<_>>(), InsertionKind::WarpScan).unwrap();
            g
        };
        let (mut a, mut b) = (mk(0, 100), mk(100, 150));
        let want_a = flatten(&mut mk(0, 100)).unwrap().data;
        let want_b = flatten(&mut mk(100, 150)).unwrap().data;
        // Append semantics: pre-existing contents survive, shards land
        // back-to-back in one destination.
        let mut dst = vec![7u32];
        let (ra, alloc_a) = flatten_into(&mut a, &mut dst).unwrap();
        let (rb, _alloc_b) = flatten_into(&mut b, &mut dst).unwrap();
        assert_eq!(dst.len(), 151);
        assert_eq!(dst[0], 7);
        assert_eq!(&dst[1..101], &want_a[..]);
        assert_eq!(&dst[101..], &want_b[..]);
        assert!(ra.us > 0.0 && rb.us > 0.0);
        assert!(alloc_a.is_some(), "destination allocation returned to the caller");
    }

    #[test]
    fn flatten_to_slice_matches_appending_path_bytes_and_charges() {
        let cfg = GgConfig { num_blocks: 4, threads_per_block: 256, first_bucket_size: 4, insertion: InsertionKind::WarpScan };
        let build = || {
            let mut g: GgArray<u32> = GgArray::new(cfg.clone(), DeviceSpec::a100());
            g.insert_bulk(&(0..333).collect::<Vec<_>>(), InsertionKind::WarpScan).unwrap();
            g
        };
        let mut a = build();
        let mut b = build();
        let mut via_into = Vec::new();
        let (ra, alloc_a) = flatten_into(&mut a, &mut via_into).unwrap();
        // The carved-destination twin: same bytes, same simulated charges,
        // same destination allocation in the source heap.
        let mut via_slice = vec![0u32; 333];
        let (rb, alloc_b) = flatten_to_slice(&mut b, &mut via_slice).unwrap();
        assert_eq!(via_slice, via_into);
        assert!((ra.us - rb.us).abs() < 1e-12, "identical simulated charge");
        assert_eq!(a.clock().now_us(), b.clock().now_us(), "identical clock advance");
        assert!(alloc_a.is_some() && alloc_b.is_some());
        assert_eq!(a.heap().used(), b.heap().used());
    }

    #[test]
    fn flatten_charge_only_matches_copying_charges() {
        let cfg = GgConfig { num_blocks: 4, threads_per_block: 256, first_bucket_size: 4, insertion: InsertionKind::WarpScan };
        let build = || {
            let mut g: GgArray<u32> = GgArray::new(cfg.clone(), DeviceSpec::a100());
            g.insert_bulk(&(0..500).collect::<Vec<_>>(), InsertionKind::WarpScan).unwrap();
            g
        };
        let mut a = build();
        let mut b = build();
        let mut dst = vec![0u32; 500];
        let (ra, alloc_a) = flatten_to_slice(&mut a, &mut dst).unwrap();
        let (rb, alloc_b) = flatten_charge_only(&mut b).unwrap();
        assert!((ra.us - rb.us).abs() < 1e-12, "identical simulated charge");
        assert_eq!(a.clock().now_us(), b.clock().now_us(), "identical clock advance");
        assert_eq!(a.heap().used(), b.heap().used(), "identical destination allocation");
        assert_eq!(ra.elements, rb.elements);
        assert!(alloc_a.is_some() && alloc_b.is_some());
        // And the data can still be gathered afterwards, pure-copy.
        let mut late = vec![0u32; 500];
        let mut off = 0usize;
        for v in b.vectors() {
            off += v.copy_to_slice(&mut late[off..]);
        }
        assert_eq!(late, dst, "late pure copy reproduces the flatten bytes");
    }

    #[test]
    fn merge_segments_into_reuses_the_destination_buffer() {
        let mk = |vals: Vec<u32>| {
            concat(vec![Flattened { data: vals, report: OpReport::default(), alloc: None }])
        };
        let parts = vec![mk(vec![1, 2, 3]), mk(vec![9, 8])];
        let mut dst: Vec<u32> = Vec::with_capacity(64);
        let ptr = dst.as_ptr();
        let (index, _report) = merge_segments_into(&parts, &mut dst);
        assert_eq!(dst, vec![1, 2, 3, 9, 8]);
        assert_eq!(dst.as_ptr(), ptr, "pooled destination must not reallocate");
        assert_eq!(index.locate(3), Some((1, 0)));
        // Identical bytes to the consuming version.
        assert_eq!(merge_segments(parts).data, dst);
    }

    #[test]
    fn flatten_concat_into_matches_flatten_concat() {
        let cfg = GgConfig { num_blocks: 2, threads_per_block: 256, first_bucket_size: 4, insertion: InsertionKind::WarpScan };
        let build = || -> Vec<GgArray<u32>> {
            (0..3u32)
                .map(|k| {
                    let mut g: GgArray<u32> = GgArray::new(cfg.clone(), DeviceSpec::a100());
                    g.insert_bulk(&(k * 50..k * 50 + 30).collect::<Vec<_>>(), InsertionKind::WarpScan).unwrap();
                    g
                })
                .collect()
        };
        let want = flatten_concat(&mut build()).unwrap();
        let mut shards = build();
        let mut dst = Vec::new();
        let (index, report) = flatten_concat_into(&mut shards, &mut dst).unwrap();
        assert_eq!(dst, want.data);
        assert_eq!(index.blocks(), 3);
        assert_eq!(index.start_of(1), want.shard_start(1));
        assert_eq!(report.elements, 90);
        // Temp destinations were released: only bucket storage is live.
        for gg in &shards {
            assert_eq!(gg.heap().used(), gg.allocated_bytes());
        }
    }

    #[test]
    fn flatten_charges_destination_allocation() {
        let mut g: GgArray<u32> = GgArray::new(GgConfig::new(4), DeviceSpec::a100());
        g.insert_bulk(&vec![9u32; 10_000], InsertionKind::WarpScan).unwrap();
        let used_before = g.heap().used();
        let _ = flatten(&mut g).unwrap();
        assert!(g.heap().used() > used_before, "flat destination not accounted");
    }
}
