//! Flatten: move a GGArray's contents into a contiguous array (paper
//! §VI.C/D — the two-phase pattern: grow with GGArray, flatten once, then
//! run the work phase at static-array speed).
//!
//! The device kernel is a per-block gather: block `b` copies its LFVector
//! into `flat[prefix[b] .. prefix[b]+len_b]`. Reads pay the bucket
//! indirection; writes are fully coalesced.

use crate::sim::kernel::{self, KernelProfile};
use crate::sim::memory::OomError;

use super::array::{GgArray, OpReport};

/// Result of a flatten: the contiguous data plus the timing report.
#[derive(Debug)]
pub struct Flattened<T> {
    pub data: Vec<T>,
    pub report: OpReport,
}

/// Flatten the GGArray into a fresh contiguous (simulated-VRAM-resident)
/// array. The GGArray keeps its storage — callers typically `clear()` it
/// afterwards or reuse it for the next growth phase.
pub fn flatten<T: Copy + Default>(gg: &mut GgArray<T>) -> Result<Flattened<T>, OomError> {
    let n = gg.len();
    let elem = std::mem::size_of::<T>();
    let spec = gg.spec().clone();
    let blocks = gg.num_blocks() as u64;
    let tpb = gg.config().threads_per_block;
    let (vectors, heap, clock, _, _, _) = gg.parts_mut();

    let phase = crate::sim::clock::Phase::start(clock);
    // Destination allocation (one cudaMalloc).
    let _dst = heap.alloc((n * elem) as u64, clock)?;
    // Real copy.
    let mut data = Vec::with_capacity(n);
    for v in vectors.iter() {
        v.copy_into(&mut data);
    }
    debug_assert_eq!(data.len(), n);
    // Gather kernel: read at block-structured efficiency, write coalesced.
    let read = (n * elem) as f64;
    let write = (n * elem) as f64;
    let eff = crate::insertion::warp_scan::blended_eff(
        read,
        spec.cost.ggarray_block_eff,
        write,
        spec.cost.coalesced_eff,
    );
    let profile = KernelProfile::streaming(blocks.max(1), tpb, read + write, eff);
    kernel::launch(&spec, clock, &profile);
    let report = OpReport { us: phase.elapsed_us(clock), buckets_allocated: 0, elements: n as u64 };
    Ok(Flattened { data, report })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ggarray::array::GgConfig;
    use crate::insertion::InsertionKind;
    use crate::sim::spec::DeviceSpec;

    #[test]
    fn flatten_preserves_global_order() {
        let mut g: GgArray<u32> =
            GgArray::new(GgConfig { num_blocks: 8, threads_per_block: 256, first_bucket_size: 4, insertion: InsertionKind::WarpScan }, DeviceSpec::a100());
        let data: Vec<u32> = (0..1234).map(|i| i * 3).collect();
        g.insert_bulk(&data, InsertionKind::WarpScan).unwrap();
        let flat = flatten(&mut g).unwrap();
        assert_eq!(flat.data.len(), 1234);
        let via_get: Vec<u32> = (0..1234).map(|i| g.get(i).unwrap()).collect();
        assert_eq!(flat.data, via_get);
        assert!(flat.report.us > 0.0);
    }

    #[test]
    fn flatten_empty() {
        let mut g: GgArray<u64> = GgArray::new(GgConfig::new(4), DeviceSpec::titan_rtx());
        g.rebuild_index_charged();
        let flat = flatten(&mut g).unwrap();
        assert!(flat.data.is_empty());
    }

    #[test]
    fn flatten_cost_cheaper_than_rw_b() {
        // One flatten ≈ one read at block eff + one coalesced write; it
        // must cost less than an rw_b pass (read+write both at block eff).
        let mut g: GgArray<u32> = GgArray::new(GgConfig::new(512), DeviceSpec::a100());
        g.insert_bulk(&vec![1u32; 1 << 20], InsertionKind::WarpScan).unwrap();
        let rw = g.read_write_block(30.0, |x| *x += 1);
        let fl = flatten(&mut g).unwrap();
        assert!(fl.report.us < rw.us, "flatten {} !< rw_b {}", fl.report.us, rw.us);
    }

    #[test]
    fn flatten_charges_destination_allocation() {
        let mut g: GgArray<u32> = GgArray::new(GgConfig::new(4), DeviceSpec::a100());
        g.insert_bulk(&vec![9u32; 10_000], InsertionKind::WarpScan).unwrap();
        let used_before = g.heap().used();
        let _ = flatten(&mut g).unwrap();
        assert!(g.heap().used() > used_before, "flat destination not accounted");
    }
}
