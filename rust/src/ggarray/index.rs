//! Prefix-sum index over the per-LFVector sizes (paper §IV).
//!
//! GGArray needs to answer "which LFVector holds global index *i*" for the
//! `rw_g` access pattern. The paper keeps an exclusive prefix sum of all
//! LFVector sizes in a plain device array — rebuilt with a (cheap, B-sized)
//! scan after each insertion epoch — and binary-searches it per access.

/// Exclusive prefix sums of the per-block sizes, plus the total.
#[derive(Debug, Clone, Default)]
pub struct PrefixIndex {
    /// `starts[b]` = global index of the first element of block `b`.
    starts: Vec<u64>,
    total: u64,
}

impl PrefixIndex {
    pub fn new() -> PrefixIndex {
        PrefixIndex::default()
    }

    /// Rebuild from per-block sizes.
    pub fn rebuild(&mut self, sizes: impl Iterator<Item = u64>) {
        self.starts.clear();
        let mut acc = 0u64;
        for s in sizes {
            self.starts.push(acc);
            acc += s;
        }
        self.total = acc;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn blocks(&self) -> usize {
        self.starts.len()
    }

    /// Global start of block `b`.
    pub fn start_of(&self, b: usize) -> u64 {
        self.starts[b]
    }

    /// Size of block `b`.
    pub fn size_of(&self, b: usize) -> u64 {
        let end = if b + 1 < self.starts.len() { self.starts[b + 1] } else { self.total };
        end - self.starts[b]
    }

    /// Binary-search the block containing global index `i`, returning
    /// `(block, local_index)`. `None` if `i ≥ total`.
    ///
    /// Exactly the lookup every `rw_g` thread performs on device; its
    /// log2(B) pointer chases are what the cost model charges for.
    #[inline]
    pub fn locate(&self, i: u64) -> Option<(usize, u64)> {
        if i >= self.total || self.starts.is_empty() {
            return None;
        }
        // partition_point: first index with start > i, minus one.
        let b = self.starts.partition_point(|&s| s <= i) - 1;
        Some((b, i - self.starts[b]))
    }

    /// Number of binary-search steps per lookup (for the cost model).
    pub fn search_depth(&self) -> u32 {
        (self.starts.len().max(1) as f64).log2().ceil() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idx(sizes: &[u64]) -> PrefixIndex {
        let mut p = PrefixIndex::new();
        p.rebuild(sizes.iter().copied());
        p
    }

    #[test]
    fn rebuild_and_totals() {
        let p = idx(&[3, 0, 5, 2]);
        assert_eq!(p.total(), 10);
        assert_eq!(p.blocks(), 4);
        assert_eq!(p.start_of(0), 0);
        assert_eq!(p.start_of(1), 3);
        assert_eq!(p.start_of(2), 3);
        assert_eq!(p.start_of(3), 8);
        assert_eq!(p.size_of(0), 3);
        assert_eq!(p.size_of(1), 0);
        assert_eq!(p.size_of(2), 5);
        assert_eq!(p.size_of(3), 2);
    }

    #[test]
    fn locate_every_index() {
        let sizes = [3u64, 0, 5, 2];
        let p = idx(&sizes);
        let mut expect = vec![];
        for (b, &s) in sizes.iter().enumerate() {
            for l in 0..s {
                expect.push((b, l));
            }
        }
        for (i, want) in expect.iter().enumerate() {
            assert_eq!(p.locate(i as u64), Some(*want), "i={i}");
        }
        assert_eq!(p.locate(10), None);
        assert_eq!(p.locate(u64::MAX), None);
    }

    #[test]
    fn empty_index() {
        let p = PrefixIndex::new();
        assert_eq!(p.total(), 0);
        assert_eq!(p.locate(0), None);
    }

    #[test]
    fn zero_leading_blocks() {
        let p = idx(&[0, 0, 4]);
        assert_eq!(p.locate(0), Some((2, 0)));
        assert_eq!(p.locate(3), Some((2, 3)));
    }

    #[test]
    fn search_depth_log2() {
        assert_eq!(idx(&[1; 1]).search_depth(), 0);
        assert_eq!(idx(&[1; 32]).search_depth(), 5);
        assert_eq!(idx(&[1; 33]).search_depth(), 6);
        assert_eq!(idx(&[1; 512]).search_depth(), 9);
    }
}
