//! Iteration over GGArray contents in global (block-major) order.

use super::array::GgArray;

/// Immutable iterator over all elements in global index order.
pub struct Iter<'a, T> {
    gg: &'a GgArray<T>,
    i: u64,
    n: u64,
}

impl<'a, T: Copy + Default> Iterator for Iter<'a, T> {
    type Item = T;

    fn next(&mut self) -> Option<T> {
        if self.i >= self.n {
            return None;
        }
        let v = self.gg.get(self.i);
        self.i += 1;
        v
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let rem = (self.n - self.i) as usize;
        (rem, Some(rem))
    }
}

impl<'a, T: Copy + Default> ExactSizeIterator for Iter<'a, T> {}

impl<T: Copy + Default> GgArray<T> {
    /// Iterate elements in global order. Requires the prefix index to be
    /// current (`insert_bulk` rebuilds it; manual `push_to_block` callers
    /// must call `rebuild_index_charged` first).
    pub fn iter(&self) -> Iter<'_, T> {
        Iter { gg: self, i: 0, n: self.len() as u64 }
    }

    /// Collect to a host Vec in global (block-major) order. Uses
    /// per-bucket segment copies rather than per-element index lookups
    /// (perf pass — this sits on the coordinator's work path).
    pub fn to_vec(&self) -> Vec<T> {
        let mut out = Vec::with_capacity(self.len());
        for v in self.vectors() {
            v.copy_into(&mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use crate::ggarray::array::{GgArray, GgConfig};
    use crate::insertion::InsertionKind;
    use crate::sim::spec::DeviceSpec;

    #[test]
    fn iter_matches_gets() {
        let mut g: GgArray<u32> = GgArray::new(GgConfig::new(4).with_first_bucket(8), DeviceSpec::a100());
        let data: Vec<u32> = (0..333).collect();
        g.insert_bulk(&data, InsertionKind::WarpScan).unwrap();
        let collected: Vec<u32> = g.iter().collect();
        assert_eq!(collected.len(), 333);
        for (i, v) in collected.iter().enumerate() {
            assert_eq!(g.get(i as u64), Some(*v));
        }
        assert_eq!(g.iter().len(), 333);
    }

    #[test]
    fn empty_iter() {
        let g: GgArray<u8> = GgArray::new(GgConfig::new(2), DeviceSpec::titan_rtx());
        assert_eq!(g.iter().count(), 0);
    }
}
