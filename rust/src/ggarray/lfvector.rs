//! LFVector (Dechev et al. 2006) adapted per the paper: the per-block
//! growable vector with **doubling buckets**.
//!
//! Bucket `b` holds `first_bucket_size · 2^b` slots, so the capacity with
//! `k` buckets is `fbs·(2^k − 1)` and growing *never* relocates elements —
//! the property that lets thousands of device threads keep raw pointers
//! valid across a resize. Bucket allocation follows the paper's
//! Algorithm 2: threads race on a per-bucket CAS flag, the winner
//! allocates from the (simulated) device heap.
//!
//! The element index ↔ (bucket, offset) mapping:
//! `bucket(i) = ⌊log2(i/fbs + 1)⌋`, `offset(i) = i − fbs·(2^bucket − 1)`.

use crate::sim::clock::Clock;
use crate::sim::memory::{AllocId, OomError, VramHeap};
use crate::util::math::{ceil_div, ilog2};

/// One allocated bucket: a simulated-VRAM allocation plus the host-side
/// backing store holding the real elements.
#[derive(Debug)]
struct Bucket<T> {
    alloc: AllocId,
    data: Vec<T>,
}

/// Number of buckets needed to hold `n` elements with first-bucket size
/// `fbs` (the smallest `k` with `fbs·(2^k − 1) ≥ n`). Free-standing so
/// admission prechecks (e.g. the shard scheduler's OOM pre-screen) can
/// compute bucket demand without holding a vector.
#[inline]
pub fn buckets_for_len(fbs: usize, n: usize) -> usize {
    if n == 0 {
        return 0;
    }
    let fbs = fbs as u64;
    // smallest k with fbs·(2^k − 1) ≥ n
    let blocks = ceil_div(n as u64 + fbs, fbs); // (n + fbs)/fbs rounded up = 2^k lower bound
    (64 - (blocks - 1).leading_zeros()) as usize
}

/// A single LFVector — in GGArray there is exactly one per thread block.
#[derive(Debug)]
pub struct LfVector<T> {
    first_bucket_size: usize,
    /// `log2(first_bucket_size)` — the constructor asserts a power of
    /// two, so the sealed-query index math divides by shifting.
    fbs_log2: u32,
    len: usize,
    buckets: Vec<Option<Bucket<T>>>,
    /// CAS guards of Algorithm 2 (`isbucket`): true once some thread has
    /// claimed the right to allocate bucket `b`.
    isbucket: Vec<bool>,
    /// Simulated-CAS statistics: how many allocation races were run.
    cas_attempts: u64,
}

impl<T: Copy + Default> LfVector<T> {
    /// New empty LFVector. `first_bucket_size` must be a power of two.
    pub fn new(first_bucket_size: usize) -> LfVector<T> {
        assert!(first_bucket_size.is_power_of_two(), "first bucket size must be a power of two");
        LfVector {
            first_bucket_size,
            fbs_log2: first_bucket_size.trailing_zeros(),
            len: 0,
            buckets: Vec::new(),
            isbucket: Vec::new(),
            cas_attempts: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn first_bucket_size(&self) -> usize {
        self.first_bucket_size
    }

    /// Number of allocated buckets.
    pub fn bucket_count(&self) -> usize {
        self.buckets.iter().filter(|b| b.is_some()).count()
    }

    /// Capacity of bucket `b`: `fbs · 2^b` (paper Algorithm 2:
    /// `bsize = 2^{log(first_block_size)+b}`).
    #[inline]
    pub fn bucket_capacity(&self, b: usize) -> usize {
        self.first_bucket_size << b
    }

    /// Total allocated capacity (sum over allocated buckets).
    pub fn capacity(&self) -> usize {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, b)| b.is_some())
            .map(|(i, _)| self.bucket_capacity(i))
            .sum()
    }

    /// Allocated bytes in the simulated heap.
    pub fn allocated_bytes(&self) -> u64 {
        (self.capacity() * std::mem::size_of::<T>()) as u64
    }

    /// Map an element index to (bucket, offset). Panics if out of the
    /// addressable range.
    ///
    /// `first_bucket_size` is a power of two (constructor invariant), so
    /// the `idx / fbs` division and the `fbs · (2^b − 1)` bucket-start
    /// product both reduce to shifts — this runs once per element on the
    /// sealed-query bench path.
    #[inline]
    pub fn locate(&self, idx: usize) -> (usize, usize) {
        let b = ilog2(((idx >> self.fbs_log2) + 1) as u64) as usize;
        let start = ((1usize << b) - 1) << self.fbs_log2;
        (b, idx - start)
    }

    /// Number of buckets needed for a length of `n`.
    #[inline]
    pub fn buckets_for(&self, n: usize) -> usize {
        buckets_for_len(self.first_bucket_size, n)
    }

    /// Paper Algorithm 2 (`new_bucket`): ensure bucket `b` exists,
    /// simulating the CAS race among `racers` threads and charging the
    /// winner's allocation to the heap/clock. Returns true if this call
    /// performed the allocation.
    pub fn new_bucket(
        &mut self,
        b: usize,
        racers: u64,
        heap: &mut VramHeap,
        clock: &mut Clock,
    ) -> Result<bool, OomError> {
        if self.buckets.len() <= b {
            self.buckets.resize_with(b + 1, || None);
            self.isbucket.resize(b + 1, false);
        }
        // CAS race: every racer pays one CAS attempt, even when the flag
        // is already set (they observe `isbucket` via the failed CAS).
        self.cas_attempts += racers.max(1);
        if self.isbucket[b] {
            return Ok(false);
        }
        clock.charge(
            crate::sim::clock::Category::Compute,
            crate::sim::block::cas_us(heap.spec()),
        );
        let cap = self.bucket_capacity(b);
        let bytes = (cap * std::mem::size_of::<T>()) as u64;
        let alloc = heap.alloc(bytes, clock)?;
        self.isbucket[b] = true;
        self.buckets[b] = Some(Bucket { alloc, data: vec![T::default(); cap] });
        Ok(true)
    }

    /// Ensure capacity ≥ `n`, allocating any missing buckets. Returns the
    /// number of buckets allocated.
    ///
    /// Starts at the first unallocated bucket rather than bucket 0:
    /// buckets are always a contiguous prefix (growth fills from 0,
    /// shrink frees from the tail), so re-walking the allocated prefix
    /// only charged a phantom CAS race per existing bucket per call —
    /// N bulk appends paid O(N·log N) CAS-attempt bookkeeping for
    /// allocations that could never happen.
    pub fn reserve(&mut self, n: usize, heap: &mut VramHeap, clock: &mut Clock) -> Result<usize, OomError> {
        let needed = self.buckets_for(n);
        let start = self.buckets.iter().take_while(|b| b.is_some()).count();
        let mut allocated = 0;
        for b in start..needed {
            if self.new_bucket(b, 1, heap, clock)? {
                allocated += 1;
            }
        }
        Ok(allocated)
    }

    /// Paper Algorithm 1: append one element (bucket allocated on demand).
    pub fn push_back(&mut self, e: T, heap: &mut VramHeap, clock: &mut Clock) -> Result<usize, OomError> {
        let idx = self.len;
        let (b, off) = self.locate(idx);
        self.new_bucket(b, 1, heap, clock)?;
        let bucket = self.buckets[b].as_mut().expect("just ensured");
        bucket.data[off] = e;
        self.len += 1;
        Ok(idx)
    }

    /// Bulk append — the per-block half of a GGArray insertion kernel:
    /// all elements of `es` get consecutive indices starting at the old
    /// length (the intra-block scan has already ordered them).
    pub fn push_back_bulk(
        &mut self,
        es: &[T],
        heap: &mut VramHeap,
        clock: &mut Clock,
    ) -> Result<std::ops::Range<usize>, OomError> {
        let start = self.len;
        let end = start + es.len();
        self.reserve(end, heap, clock)?;
        // Segment-wise copy: one `locate` + `copy_from_slice` per bucket
        // touched instead of per element (perf pass: 3.2 ms → ~0.4 ms for
        // 1e6 u32; see EXPERIMENTS.md §Perf).
        let mut src = 0usize;
        let mut idx = start;
        while idx < end {
            let (b, off) = self.locate(idx);
            let cap = self.bucket_capacity(b);
            let take = (cap - off).min(end - idx);
            self.buckets[b].as_mut().expect("reserved").data[off..off + take]
                .copy_from_slice(&es[src..src + take]);
            src += take;
            idx += take;
        }
        self.len = end;
        Ok(start..end)
    }

    /// The charge half of [`LfVector::push_back_bulk`]: reserve buckets
    /// for `n` more elements and extend the logical length, without
    /// copying any data (slots come up `T::default()` from bucket
    /// allocation). Heap/clock charges are *identical* to
    /// `push_back_bulk(&es[..n], ..)` — the copy is host-side and free
    /// in simulated time — so a scheduler can run this serially for
    /// deterministic charging and fill the reserved range later with
    /// the pure [`LfVector::write_range`] on any thread.
    pub fn push_bulk_uninit(
        &mut self,
        n: usize,
        heap: &mut VramHeap,
        clock: &mut Clock,
    ) -> Result<std::ops::Range<usize>, OomError> {
        let start = self.len;
        let end = start + n;
        self.reserve(end, heap, clock)?;
        self.len = end;
        Ok(start..end)
    }

    /// Pure data movement: write `es` into the live slots
    /// `start..start + es.len()` (all must be `< len`, i.e. previously
    /// extended by [`LfVector::push_bulk_uninit`] or an append). Touches
    /// no heap or clock state — the scheduler's fill chunks call this
    /// from worker threads after the coordinator has charged the
    /// reserve.
    pub fn write_range(&mut self, start: usize, es: &[T]) {
        let end = start + es.len();
        assert!(end <= self.len, "write_range({start}..{end}) past len {}", self.len);
        // Same segment-wise copy as `push_back_bulk`.
        let mut src = 0usize;
        let mut idx = start;
        while idx < end {
            let (b, off) = self.locate(idx);
            let cap = self.bucket_capacity(b);
            let take = (cap - off).min(end - idx);
            self.buckets[b].as_mut().expect("within len ⇒ allocated").data[off..off + take]
                .copy_from_slice(&es[src..src + take]);
            src += take;
            idx += take;
        }
    }

    /// Read element `idx`.
    #[inline]
    pub fn get(&self, idx: usize) -> Option<T> {
        if idx >= self.len {
            return None;
        }
        let (b, off) = self.locate(idx);
        self.buckets[b].as_ref().map(|bk| bk.data[off])
    }

    /// Write element `idx` (must be < len).
    #[inline]
    pub fn set(&mut self, idx: usize, v: T) {
        assert!(idx < self.len, "set({idx}) out of bounds (len {})", self.len);
        let (b, off) = self.locate(idx);
        self.buckets[b].as_mut().expect("within len ⇒ allocated").data[off] = v;
    }

    /// Apply `f` to every live element in index order (the real data side
    /// of an `rw_b` pass).
    pub fn for_each_mut(&mut self, mut f: impl FnMut(&mut T)) {
        let mut remaining = self.len;
        for b in 0..self.buckets.len() {
            if remaining == 0 {
                break;
            }
            let cap = self.bucket_capacity(b);
            if let Some(bucket) = self.buckets[b].as_mut() {
                let take = remaining.min(cap);
                for v in &mut bucket.data[..take] {
                    f(v);
                }
                remaining -= take;
            }
        }
    }

    /// Copy the live elements into `out` (flatten building block).
    pub fn copy_into(&self, out: &mut Vec<T>) {
        let mut remaining = self.len;
        for b in 0..self.buckets.len() {
            if remaining == 0 {
                break;
            }
            let cap = self.bucket_capacity(b);
            if let Some(bucket) = self.buckets[b].as_ref() {
                let take = remaining.min(cap);
                out.extend_from_slice(&bucket.data[..take]);
                remaining -= take;
            }
        }
    }

    /// Copy the live elements into the front of `out` (which must hold at
    /// least `len` slots) and return the count written — the slice-target
    /// twin of [`LfVector::copy_into`] for gathers whose destination
    /// ranges are carved up front (the shard scheduler's parallel
    /// flatten writes disjoint sub-slices of one buffer concurrently).
    pub fn copy_to_slice(&self, out: &mut [T]) -> usize {
        debug_assert!(out.len() >= self.len, "destination slice too small");
        let mut written = 0usize;
        for b in 0..self.buckets.len() {
            if written == self.len {
                break;
            }
            let cap = self.bucket_capacity(b);
            if let Some(bucket) = self.buckets[b].as_ref() {
                let take = (self.len - written).min(cap);
                out[written..written + take].copy_from_slice(&bucket.data[..take]);
                written += take;
            }
        }
        written
    }

    /// Pure sub-range read: copy the live elements
    /// `start..start + out.len()` into `out` — the stealable-chunk twin
    /// of [`LfVector::copy_to_slice`], so a large shard's gather can be
    /// decomposed into range chunks that read the same vector
    /// concurrently (`&self` only).
    pub fn copy_range_to_slice(&self, start: usize, out: &mut [T]) {
        let end = start + out.len();
        assert!(end <= self.len, "copy_range_to_slice({start}..{end}) past len {}", self.len);
        let mut dst = 0usize;
        let mut idx = start;
        while idx < end {
            let (b, off) = self.locate(idx);
            let cap = self.bucket_capacity(b);
            let take = (cap - off).min(end - idx);
            out[dst..dst + take].copy_from_slice(
                &self.buckets[b].as_ref().expect("within len ⇒ allocated").data[off..off + take],
            );
            dst += take;
            idx += take;
        }
    }

    /// Drop all buckets, releasing simulated VRAM.
    pub fn free_all(&mut self, heap: &mut VramHeap, clock: &mut Clock) {
        for b in self.buckets.drain(..).flatten() {
            heap.free(b.alloc, clock);
        }
        self.isbucket.clear();
        self.len = 0;
    }

    /// Truncate the logical length (buckets stay allocated).
    pub fn truncate(&mut self, n: usize) {
        self.len = self.len.min(n);
    }

    /// Remove and return the last element (paper future work: "grow or
    /// shrink as required").
    pub fn pop_back(&mut self) -> Option<T> {
        if self.len == 0 {
            return None;
        }
        self.len -= 1;
        let (b, off) = self.locate(self.len);
        Some(self.buckets[b].as_ref().expect("within old len ⇒ allocated").data[off])
    }

    /// Release buckets that are entirely past the live length — the
    /// shrink counterpart of Algorithm 2. Keeps the hysteresis bucket
    /// (the one containing `len`) so grow-after-shrink doesn't thrash.
    pub fn shrink_to_fit(&mut self, heap: &mut VramHeap, clock: &mut Clock) -> usize {
        let needed = self.buckets_for(self.len);
        let mut freed = 0;
        for b in needed..self.buckets.len() {
            if let Some(bucket) = self.buckets[b].take() {
                heap.free(bucket.alloc, clock);
                self.isbucket[b] = false;
                freed += 1;
            }
        }
        freed
    }

    pub fn cas_attempts(&self) -> u64 {
        self.cas_attempts
    }

    /// Undo a single growth operation: truncate back to `old_len`, free
    /// every bucket past `buckets_for(old_len)` and erase the CAS
    /// bookkeeping those allocations charged, leaving the vector
    /// byte-identical to before the growth.
    ///
    /// Sound because the coordinator keeps buckets exactly matched to
    /// the length at op boundaries (`reserve` allocates precisely the
    /// missing suffix, one CAS attempt per bucket; nothing pre-grows
    /// excess buckets), so the freed tail *is* the set of buckets the
    /// aborted op allocated. The `heap.free` clock charges this makes
    /// are transient: the caller rewinds the clock to its op mark right
    /// after (see `Shard::rollback_insert`).
    pub fn rollback_growth(&mut self, old_len: usize, heap: &mut VramHeap, clock: &mut Clock) {
        debug_assert!(old_len <= self.len, "rollback_growth to a longer length");
        self.len = old_len;
        let keep = self.buckets_for(old_len);
        let mut freed = 0u64;
        for b in keep..self.buckets.len() {
            if let Some(bucket) = self.buckets[b].take() {
                heap.free(bucket.alloc, clock);
                self.isbucket[b] = false;
                freed += 1;
            }
        }
        self.buckets.truncate(keep);
        self.isbucket.truncate(keep);
        self.cas_attempts -= freed;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::spec::DeviceSpec;

    fn fixture() -> (VramHeap, Clock) {
        (VramHeap::with_capacity(DeviceSpec::a100(), 1 << 30), Clock::new())
    }

    #[test]
    fn locate_mapping_is_exact() {
        let v: LfVector<u32> = LfVector::new(4);
        // fbs=4: bucket0 = idx 0..4, bucket1 = 4..12, bucket2 = 12..28 …
        assert_eq!(v.locate(0), (0, 0));
        assert_eq!(v.locate(3), (0, 3));
        assert_eq!(v.locate(4), (1, 0));
        assert_eq!(v.locate(11), (1, 7));
        assert_eq!(v.locate(12), (2, 0));
        assert_eq!(v.locate(27), (2, 15));
        assert_eq!(v.locate(28), (3, 0));
    }

    #[test]
    fn buckets_for_boundary_values() {
        let v: LfVector<u32> = LfVector::new(4);
        assert_eq!(v.buckets_for(0), 0);
        assert_eq!(v.buckets_for(1), 1);
        assert_eq!(v.buckets_for(4), 1);
        assert_eq!(v.buckets_for(5), 2);
        assert_eq!(v.buckets_for(12), 2);
        assert_eq!(v.buckets_for(13), 3);
        assert_eq!(v.buckets_for(28), 3);
        assert_eq!(v.buckets_for(29), 4);
    }

    #[test]
    fn push_back_sequence() {
        let (mut heap, mut clock) = fixture();
        let mut v: LfVector<u32> = LfVector::new(2);
        for i in 0..100u32 {
            let idx = v.push_back(i * 10, &mut heap, &mut clock).unwrap();
            assert_eq!(idx as u32, i);
        }
        assert_eq!(v.len(), 100);
        for i in 0..100usize {
            assert_eq!(v.get(i), Some(i as u32 * 10));
        }
        assert_eq!(v.get(100), None);
    }

    #[test]
    fn capacity_never_exceeds_twice_len_plus_fbs() {
        // The ≤2× memory bound of §V: cap < 2n + 2·fbs for any fill level.
        let (mut heap, mut clock) = fixture();
        let mut v: LfVector<u64> = LfVector::new(8);
        for i in 0..5000u64 {
            v.push_back(i, &mut heap, &mut clock).unwrap();
            let cap = v.capacity() as f64;
            let bound = 2.0 * v.len() as f64 + 2.0 * v.first_bucket_size() as f64;
            assert!(cap <= bound, "len={} cap={cap} bound={bound}", v.len());
        }
    }

    #[test]
    fn bulk_matches_singles() {
        let (mut heap, mut clock) = fixture();
        let mut a: LfVector<u32> = LfVector::new(4);
        let mut b: LfVector<u32> = LfVector::new(4);
        let data: Vec<u32> = (0..1000).map(|i| i * 3 + 1).collect();
        let range = a.push_back_bulk(&data, &mut heap, &mut clock).unwrap();
        assert_eq!(range, 0..1000);
        for &d in &data {
            b.push_back(d, &mut heap, &mut clock).unwrap();
        }
        for i in 0..1000 {
            assert_eq!(a.get(i), b.get(i));
        }
        assert_eq!(a.capacity(), b.capacity());
    }

    #[test]
    fn new_bucket_races_only_first_allocates() {
        let (mut heap, mut clock) = fixture();
        let mut v: LfVector<u8> = LfVector::new(16);
        assert!(v.new_bucket(0, 256, &mut heap, &mut clock).unwrap());
        assert!(!v.new_bucket(0, 256, &mut heap, &mut clock).unwrap());
        assert_eq!(v.bucket_count(), 1);
        assert_eq!(v.cas_attempts(), 512);
        assert_eq!(heap.alloc_calls(), 1);
    }

    #[test]
    fn reserve_skips_the_allocated_bucket_prefix() {
        // Regression: reserve used to re-run the new_bucket CAS race on
        // every existing bucket, so each bulk append charged O(log n)
        // phantom CAS attempts even when no bucket was due.
        let (mut heap, mut clock) = fixture();
        let mut v: LfVector<u32> = LfVector::new(4);
        v.push_back_bulk(&vec![1; 100], &mut heap, &mut clock).unwrap();
        // buckets_for(100) = 5 with fbs 4 → capacity 124; the next 10
        // elements fit with no allocation and must cost no bookkeeping.
        let (cas0, allocs0) = (v.cas_attempts(), heap.alloc_calls());
        v.push_back_bulk(&vec![2; 10], &mut heap, &mut clock).unwrap();
        assert_eq!(heap.alloc_calls(), allocs0, "no bucket was due");
        assert_eq!(v.cas_attempts(), cas0, "no phantom CAS race on the allocated prefix");
        // Growing past capacity races (and allocates) only the new
        // buckets, and grow-after-shrink still works through the same
        // prefix logic.
        v.push_back_bulk(&vec![3; 100], &mut heap, &mut clock).unwrap();
        assert_eq!(v.cas_attempts(), cas0 + 1, "exactly the one missing bucket raced");
        assert_eq!(v.len(), 210);
        assert_eq!(v.get(209), Some(3));
        v.truncate(3);
        v.shrink_to_fit(&mut heap, &mut clock);
        v.push_back_bulk(&(0..60).collect::<Vec<_>>(), &mut heap, &mut clock).unwrap();
        assert_eq!(v.len(), 63);
        assert_eq!(v.get(62), Some(59));
    }

    #[test]
    fn uninit_then_write_range_matches_push_back_bulk_exactly() {
        // The scheduler's charge/copy split: reserve-and-extend on the
        // coordinator, pure write on a worker. Bytes, heap charges and
        // clock must all equal the fused bulk append.
        let spec = DeviceSpec::a100();
        let mut heap_a = VramHeap::with_capacity(spec.clone(), 1 << 20);
        let mut heap_b = VramHeap::with_capacity(spec, 1 << 20);
        let (mut clock_a, mut clock_b) = (Clock::new(), Clock::new());
        let mut a: LfVector<u32> = LfVector::new(4);
        let mut b: LfVector<u32> = LfVector::new(4);
        for (step, batch) in [7usize, 0, 30, 1, 200].into_iter().enumerate() {
            let data: Vec<u32> = (0..batch as u32).map(|i| i * 5 + step as u32).collect();
            let ra = a.push_back_bulk(&data, &mut heap_a, &mut clock_a).unwrap();
            let rb = b.push_bulk_uninit(data.len(), &mut heap_b, &mut clock_b).unwrap();
            b.write_range(rb.start, &data);
            assert_eq!(ra, rb, "step {step}");
            assert_eq!(heap_a.used(), heap_b.used(), "step {step}");
            assert_eq!(clock_a.now_us(), clock_b.now_us(), "step {step}");
            assert_eq!(a.cas_attempts(), b.cas_attempts(), "step {step}");
        }
        assert_eq!(a.len(), b.len());
        for i in 0..a.len() {
            assert_eq!(a.get(i), b.get(i), "slot {i}");
        }
        // OOM parity: both variants fail the same way and leave len alone.
        let spec = DeviceSpec::a100();
        let mut tiny = VramHeap::with_capacity(spec, 16);
        let mut clock = Clock::new();
        let mut v: LfVector<u64> = LfVector::new(8);
        assert!(v.push_bulk_uninit(9, &mut tiny, &mut clock).is_err());
        assert_eq!(v.len(), 0);
    }

    #[test]
    #[should_panic(expected = "past len")]
    fn write_range_rejects_unreserved_tail() {
        let (mut heap, mut clock) = fixture();
        let mut v: LfVector<u32> = LfVector::new(4);
        v.push_bulk_uninit(3, &mut heap, &mut clock).unwrap();
        v.write_range(2, &[1, 2]);
    }

    #[test]
    fn set_and_for_each_mut() {
        let (mut heap, mut clock) = fixture();
        let mut v: LfVector<i64> = LfVector::new(4);
        v.push_back_bulk(&[1, 2, 3, 4, 5, 6, 7], &mut heap, &mut clock).unwrap();
        v.set(2, 30);
        let mut sum = 0;
        v.for_each_mut(|x| {
            *x += 1;
            sum += *x;
        });
        assert_eq!(sum, 2 + 3 + 31 + 5 + 6 + 7 + 8);
        assert_eq!(v.get(2), Some(31));
    }

    #[test]
    fn copy_to_slice_matches_copy_into() {
        let (mut heap, mut clock) = fixture();
        let mut v: LfVector<u32> = LfVector::new(4);
        let data: Vec<u32> = (0..77).map(|i| i * 7 + 1).collect();
        v.push_back_bulk(&data, &mut heap, &mut clock).unwrap();
        let mut via_into = Vec::new();
        v.copy_into(&mut via_into);
        // An oversized destination: only the front `len` slots written.
        let mut via_slice = vec![u32::MAX; 100];
        assert_eq!(v.copy_to_slice(&mut via_slice), 77);
        assert_eq!(&via_slice[..77], &via_into[..]);
        assert!(via_slice[77..].iter().all(|&x| x == u32::MAX));
        // Empty vector writes nothing.
        let e: LfVector<u32> = LfVector::new(4);
        assert_eq!(e.copy_to_slice(&mut via_slice), 0);
    }

    #[test]
    fn copy_range_to_slice_matches_full_copy_for_every_split() {
        let (mut heap, mut clock) = fixture();
        let mut v: LfVector<u32> = LfVector::new(4);
        let data: Vec<u32> = (0..61).map(|i| i * 3 + 2).collect();
        v.push_back_bulk(&data, &mut heap, &mut clock).unwrap();
        let mut full = vec![0u32; 61];
        v.copy_to_slice(&mut full);
        for start in 0..=61usize {
            for end in start..=61usize {
                let mut part = vec![u32::MAX; end - start];
                v.copy_range_to_slice(start, &mut part);
                assert_eq!(&part[..], &full[start..end], "range {start}..{end}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "past len")]
    fn copy_range_to_slice_rejects_past_len() {
        let (mut heap, mut clock) = fixture();
        let mut v: LfVector<u32> = LfVector::new(4);
        v.push_back_bulk(&[1, 2, 3], &mut heap, &mut clock).unwrap();
        let mut out = vec![0u32; 2];
        v.copy_range_to_slice(2, &mut out);
    }

    #[test]
    fn buckets_for_len_free_fn_matches_method() {
        let v: LfVector<u32> = LfVector::new(4);
        for n in 0..200usize {
            assert_eq!(buckets_for_len(4, n), v.buckets_for(n), "n={n}");
        }
        for fbs in [1usize, 2, 8, 1024] {
            let v: LfVector<u8> = LfVector::new(fbs);
            for n in [0usize, 1, fbs, fbs + 1, 3 * fbs, 100 * fbs] {
                assert_eq!(buckets_for_len(fbs, n), v.buckets_for(n), "fbs={fbs} n={n}");
            }
        }
    }

    #[test]
    fn locate_shift_math_handles_fbs_one() {
        // fbs=1 (fbs_log2=0): bucket0 = idx 0, bucket1 = 1..3, bucket2 = 3..7.
        let v: LfVector<u8> = LfVector::new(1);
        assert_eq!(v.locate(0), (0, 0));
        assert_eq!(v.locate(1), (1, 0));
        assert_eq!(v.locate(2), (1, 1));
        assert_eq!(v.locate(3), (2, 0));
        assert_eq!(v.locate(6), (2, 3));
        assert_eq!(v.locate(7), (3, 0));
    }

    #[test]
    fn copy_into_preserves_order() {
        let (mut heap, mut clock) = fixture();
        let mut v: LfVector<u32> = LfVector::new(2);
        let data: Vec<u32> = (0..77).collect();
        v.push_back_bulk(&data, &mut heap, &mut clock).unwrap();
        let mut out = Vec::new();
        v.copy_into(&mut out);
        assert_eq!(out, data);
    }

    #[test]
    fn free_all_returns_memory() {
        let (mut heap, mut clock) = fixture();
        let mut v: LfVector<u64> = LfVector::new(64);
        v.push_back_bulk(&vec![7u64; 10_000], &mut heap, &mut clock).unwrap();
        assert!(heap.used() > 0);
        v.free_all(&mut heap, &mut clock);
        assert_eq!(heap.used(), 0);
        assert_eq!(v.len(), 0);
        assert_eq!(v.capacity(), 0);
    }

    #[test]
    fn oom_propagates() {
        let spec = DeviceSpec::a100();
        let mut heap = VramHeap::with_capacity(spec, 1024);
        let mut clock = Clock::new();
        let mut v: LfVector<u64> = LfVector::new(1024); // first bucket 8 KiB > 1 KiB heap
        assert!(v.push_back(1, &mut heap, &mut clock).is_err());
        assert_eq!(v.len(), 0);
    }

    #[test]
    #[should_panic]
    fn non_pow2_fbs_rejected() {
        let _: LfVector<u8> = LfVector::new(3);
    }

    #[test]
    fn pop_back_and_shrink() {
        let (mut heap, mut clock) = fixture();
        let mut v: LfVector<u32> = LfVector::new(4);
        v.push_back_bulk(&(0..100).collect::<Vec<_>>(), &mut heap, &mut clock).unwrap();
        assert_eq!(v.pop_back(), Some(99));
        assert_eq!(v.pop_back(), Some(98));
        assert_eq!(v.len(), 98);
        // Shrink far down: buckets past the live range are released.
        v.truncate(3);
        let used_before = heap.used();
        let freed = v.shrink_to_fit(&mut heap, &mut clock);
        assert!(freed >= 3, "freed {freed}");
        assert!(heap.used() < used_before);
        assert_eq!(v.capacity(), 4); // bucket 0 only
        // Data below the truncation point survives.
        assert_eq!(v.get(0), Some(0));
        assert_eq!(v.get(2), Some(2));
        assert_eq!(v.get(3), None);
        // And the vector can grow again cleanly (CAS flags were reset).
        v.push_back_bulk(&[7, 8, 9, 10, 11], &mut heap, &mut clock).unwrap();
        assert_eq!(v.get(7), Some(11));
        let empty: LfVector<u32> = {
            let mut e = LfVector::new(4);
            assert_eq!(e.pop_back(), None);
            e
        };
        drop(empty);
    }

    #[test]
    fn rollback_growth_is_byte_identical() {
        let (mut heap, mut clock) = fixture();
        let mut v: LfVector<u32> = LfVector::new(4);
        v.push_back_bulk(&(0..50).collect::<Vec<_>>(), &mut heap, &mut clock).unwrap();
        let (len0, cap0, cas0, used0) = (v.len(), v.capacity(), v.cas_attempts(), heap.used());
        let heap_mark = heap.mark();
        let clock_mark = clock.mark();
        let t0 = clock.now_us();
        // A growth op that then aborts.
        let r = v.push_bulk_uninit(500, &mut heap, &mut clock).unwrap();
        v.write_range(r.start, &vec![9u32; 500]);
        assert!(v.cas_attempts() > cas0);
        v.rollback_growth(len0, &mut heap, &mut clock);
        clock.rewind(clock_mark);
        heap.restore_mark(heap_mark);
        assert_eq!(v.len(), len0);
        assert_eq!(v.capacity(), cap0);
        assert_eq!(v.cas_attempts(), cas0, "op CAS bookkeeping erased");
        assert_eq!(heap.used(), used0);
        assert_eq!(clock.now_us(), t0);
        for i in 0..50 {
            assert_eq!(v.get(i), Some(i as u32), "pre-op data survives");
        }
        // Zero-growth rollback is a no-op.
        let cas1 = v.cas_attempts();
        v.rollback_growth(v.len(), &mut heap, &mut clock);
        assert_eq!(v.cas_attempts(), cas1);
        // The vector grows again cleanly after a rollback.
        v.push_back_bulk(&[100, 101], &mut heap, &mut clock).unwrap();
        assert_eq!(v.get(51), Some(101));
    }

    #[test]
    fn growth_factor_tends_to_two() {
        // Paper §VI.C: "the growth in capacity … tends to two as the size
        // increases".
        let v: LfVector<u32> = LfVector::new(4);
        let caps: Vec<usize> = (1..20).map(|k| v.first_bucket_size() * ((1 << k) - 1)).collect();
        let ratios: Vec<f64> = caps.windows(2).map(|w| w[1] as f64 / w[0] as f64).collect();
        assert!(ratios[0] > 2.5); // early growth is super-2×
        assert!((ratios.last().unwrap() - 2.0).abs() < 0.01);
    }
}
