//! The paper's contribution: **GGArray**, a dynamically growable GPU
//! array built as an array of LFVectors — one LFVector per thread block —
//! with a prefix-sum index for global addressing.
//!
//! Module map (paper section → code):
//!
//! * §IV Algorithm 1/2 (`push_back`, `new_bucket`)  → [`lfvector`]
//! * §IV prefix-sum index + binary search            → [`index`]
//! * §IV macro structure, grow/insert/rw_g/rw_b      → [`array`]
//! * §VI.C flatten for two-phase applications        → [`flatten`]
//!
//! Every operation performs the *real* data movement on host-side buffers
//! backed by the simulated VRAM heap, while charging modeled GPU time to
//! the simulation clock (see [`crate::sim`]).

pub mod array;
pub mod flatten;
pub mod index;
pub mod iter;
pub mod lfvector;

pub use array::{GgArray, GgConfig, OpReport};
pub use lfvector::LfVector;
