//! Atomic insertion (paper §III.B.1): every inserting thread performs
//! `atomicAdd(&size, count)` to claim its slot. Simple, but the single
//! counter serialises at the L2 atomic unit — warp aggregation divides the
//! op count by 32, yet at Fig 4 sizes it is still the slowest algorithm by
//! a wide margin.

use super::InsertShape;
use crate::sim::{atomicmodel, kernel::KernelProfile, spec::DeviceSpec};

/// Cost profile of one atomic-insertion launch.
pub fn profile(spec: &DeviceSpec, shape: &InsertShape) -> KernelProfile {
    // Traffic: read source elements + write them (no scan aux arrays).
    let read = (shape.inserts * shape.elem_bytes) as f64;
    let write = (shape.inserts * shape.elem_bytes) as f64;
    let eff = super::warp_scan::blended_eff(read, spec.cost.coalesced_eff, write, shape.write_eff);
    // One warp-aggregated atomic per inserting thread, spread across the
    // structure's size counters.
    let atomic_us = atomicmodel::multi_addr_atomic_us(spec, shape.inserts, shape.counters, true);
    KernelProfile {
        blocks: shape.blocks,
        threads_per_block: shape.threads_per_block,
        bytes: read + write,
        coalescing_eff: eff,
        flops_fp32: 0.0,
        flops_mxu: 0.0,
        mxu_utilisation: 1.0,
        per_block_us: 0.0,
        atomic_us,
        extra_us: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::insertion::InsertShape;

    #[test]
    fn atomic_dominated_at_scale() {
        let spec = DeviceSpec::a100();
        let n = 512_000_000u64;
        let shape = InsertShape::static_array(&spec, n, n, 4);
        let p = profile(&spec, &shape);
        let b = crate::sim::kernel::model(&spec, &p);
        // The atomic serialisation exceeds the streaming time.
        assert!(b.atomic_us > b.memory_us, "atomic {} vs mem {}", b.atomic_us, b.memory_us);
    }

    #[test]
    fn per_lfvector_counters_relieve_contention() {
        // GGArray gives each LFVector its own size counter: 512 counters
        // make the atomic path far cheaper than one global counter.
        let spec = DeviceSpec::a100();
        let n = 16_000_000u64;
        let mut one = InsertShape::static_array(&spec, n, n, 4);
        one.counters = 1;
        let mut many = one;
        many.counters = 512;
        let t_one = crate::sim::kernel::model(&spec, &profile(&spec, &one)).total_us;
        let t_many = crate::sim::kernel::model(&spec, &profile(&spec, &many)).total_us;
        assert!(t_one > t_many * 2.0, "one {t_one} many {t_many}");
    }
}
