//! Parallel insertion algorithms (paper §III.B, evaluated in Fig 4 col 1).
//!
//! The job of an insertion algorithm is to hand every inserting thread a
//! **unique index** `old_size ≤ i < new_size` and to update the size —
//! i.e. to compute an exclusive prefix sum over the per-thread insertion
//! counts. Three schemes from the paper:
//!
//! * [`atomic`] — one `atomicAdd(&size, count)` per inserting thread
//!   (warp-aggregated by hardware/compiler), serialising at L2;
//! * [`warp_scan`] — `__shfl_up_sync` hierarchical block scan + one atomic
//!   per block for the global offset (the winner in Fig 4);
//! * [`mxu_scan`] — the tensor-core matmul scan of Dakkak et al. (2019),
//!   reproduced on the MXU: intra-tile `L·X` with a lower-triangular ones
//!   matrix + inter-tile carry fix-up. At a 1:1 data:thread ratio only ⅛
//!   of the warps do matmuls, which is why the paper measures it slower
//!   than the shuffle scan (and closer on the A100, whose tensor-core
//!   uplift is larger).
//!
//! Each algorithm provides (a) a **reference index assignment** on host
//! data (used to validate the Pallas kernels and to actually place
//! elements), and (b) a **cost profile** for the simulated device.
//! [`assign_indices`] is shared: the semantics of all three algorithms are
//! identical — only their cost differs — which the property tests assert.

pub mod atomic;
pub mod mxu_scan;
pub mod warp_scan;

use crate::sim::kernel::KernelProfile;
use crate::sim::spec::DeviceSpec;

/// Which insertion algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum InsertionKind {
    Atomic,
    WarpScan,
    MxuScan,
}

impl InsertionKind {
    pub const ALL: [InsertionKind; 3] = [InsertionKind::Atomic, InsertionKind::WarpScan, InsertionKind::MxuScan];

    pub fn name(&self) -> &'static str {
        match self {
            InsertionKind::Atomic => "atomic",
            InsertionKind::WarpScan => "warp_scan",
            InsertionKind::MxuScan => "mxu_scan",
        }
    }

    pub fn by_name(name: &str) -> Option<InsertionKind> {
        match name.to_ascii_lowercase().as_str() {
            "atomic" => Some(InsertionKind::Atomic),
            "warp_scan" | "scan" | "shuffle" | "warpscan" => Some(InsertionKind::WarpScan),
            "mxu_scan" | "tensor" | "tensor_scan" | "mxuscan" => Some(InsertionKind::MxuScan),
            _ => None,
        }
    }
}

/// Parameters describing one insertion kernel launch.
#[derive(Debug, Clone, Copy)]
pub struct InsertShape {
    /// Threads participating (= current array size in the paper's tests:
    /// even non-inserting threads join the scan and syncs).
    pub threads: u64,
    /// Elements actually inserted.
    pub inserts: u64,
    /// Element size in bytes.
    pub elem_bytes: u64,
    /// Grid blocks available (the GGArray's LFVector count, or a full
    /// grid for the static-array tests).
    pub blocks: u64,
    /// Threads per block.
    pub threads_per_block: u32,
    /// Distinct size counters (1 for static/global, = #LFVectors for
    /// GGArray where each block owns its own counter).
    pub counters: u64,
    /// Write-side bandwidth efficiency (coalesced for static, bucket
    /// indirection for GGArray).
    pub write_eff: f64,
}

impl InsertShape {
    /// The paper's static-array insertion test shape: one thread per
    /// element, saturating grid, one global counter.
    pub fn static_array(spec: &DeviceSpec, threads: u64, inserts: u64, elem_bytes: u64) -> InsertShape {
        let tpb = 1024u32;
        InsertShape {
            threads,
            inserts,
            elem_bytes,
            blocks: crate::util::math::ceil_div(threads, tpb as u64),
            threads_per_block: tpb,
            counters: 1,
            write_eff: spec.cost.coalesced_eff,
        }
    }
}

/// Exclusive-prefix-sum index assignment shared by all three algorithms:
/// thread `t` with `counts[t]` items gets indices
/// `[base + prefix[t], base + prefix[t] + counts[t])`.
///
/// Returns the per-thread start offsets and the new total. This is the
/// semantic oracle the Pallas scan kernels are validated against.
pub fn assign_indices(base: u64, counts: &[u32]) -> (Vec<u64>, u64) {
    let mut offsets = Vec::with_capacity(counts.len());
    let mut acc = base;
    for &c in counts {
        offsets.push(acc);
        acc += c as u64;
    }
    (offsets, acc)
}

/// Cost profile for one insertion launch of the given algorithm.
pub fn profile(spec: &DeviceSpec, kind: InsertionKind, shape: &InsertShape) -> KernelProfile {
    match kind {
        InsertionKind::Atomic => atomic::profile(spec, shape),
        InsertionKind::WarpScan => warp_scan::profile(spec, shape),
        InsertionKind::MxuScan => mxu_scan::profile(spec, shape),
    }
}

/// Modeled time (µs) of one insertion launch.
pub fn cost_us(spec: &DeviceSpec, kind: InsertionKind, shape: &InsertShape) -> f64 {
    crate::sim::kernel::model(spec, &profile(spec, kind, shape)).total_us
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_indices_unique_and_dense() {
        let counts = vec![1u32, 0, 3, 2, 0, 1];
        let (offs, total) = assign_indices(100, &counts);
        assert_eq!(total, 107);
        assert_eq!(offs, vec![100, 101, 101, 104, 106, 106]);
        // Expanded indices are exactly 100..107, each once.
        let mut seen = vec![];
        for (t, &c) in counts.iter().enumerate() {
            for k in 0..c {
                seen.push(offs[t] + k as u64);
            }
        }
        seen.sort();
        assert_eq!(seen, (100..107).collect::<Vec<_>>());
    }

    #[test]
    fn kind_names_roundtrip() {
        for k in InsertionKind::ALL {
            assert_eq!(InsertionKind::by_name(k.name()), Some(k));
        }
        assert_eq!(InsertionKind::by_name("tensor"), Some(InsertionKind::MxuScan));
        assert!(InsertionKind::by_name("bogus").is_none());
    }

    #[test]
    fn fig4_ordering_on_both_gpus() {
        // Fig 4 col 1: atomic slowest; shuffle scan fastest, tensor close.
        for spec in [DeviceSpec::titan_rtx(), DeviceSpec::a100()] {
            let n = 512_000_000u64;
            let shape = InsertShape::static_array(&spec, n, n, 4);
            let t_atomic = cost_us(&spec, InsertionKind::Atomic, &shape);
            let t_scan = cost_us(&spec, InsertionKind::WarpScan, &shape);
            let t_mxu = cost_us(&spec, InsertionKind::MxuScan, &shape);
            assert!(t_atomic > t_scan, "{}: atomic {t_atomic} !> scan {t_scan}", spec.name);
            assert!(t_atomic > t_mxu, "{}: atomic {t_atomic} !> mxu {t_mxu}", spec.name);
            assert!(t_mxu >= t_scan, "{}: mxu {t_mxu} !>= scan {t_scan}", spec.name);
        }
    }

    #[test]
    fn tensor_gap_smaller_on_a100() {
        // Paper: "the difference between the two scan versions is lower in
        // the A100" (bigger tensor-core generation uplift).
        let n = 512_000_000u64;
        let gap = |spec: &DeviceSpec| {
            let shape = InsertShape::static_array(spec, n, n, 4);
            cost_us(spec, InsertionKind::MxuScan, &shape) / cost_us(spec, InsertionKind::WarpScan, &shape)
        };
        let titan = gap(&DeviceSpec::titan_rtx());
        let a100 = gap(&DeviceSpec::a100());
        assert!(a100 < titan, "gap a100 {a100} !< titan {titan}");
    }

    #[test]
    fn insertion_scales_with_n() {
        let spec = DeviceSpec::a100();
        for kind in InsertionKind::ALL {
            let small = cost_us(&spec, kind, &InsertShape::static_array(&spec, 1_000_000, 1_000_000, 4));
            let large = cost_us(&spec, kind, &InsertShape::static_array(&spec, 512_000_000, 512_000_000, 4));
            assert!(large > small * 50.0, "{}: small {small} large {large}", kind.name());
        }
    }
}
