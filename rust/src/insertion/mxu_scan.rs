//! Tensor-core / MXU matmul prefix-sum insertion (paper §III.B.3,
//! following Dakkak et al. 2019, "Accelerating reduction and scan using
//! tensor core units").
//!
//! Algorithm skeleton (reproduced as a real Pallas kernel in
//! `python/compile/kernels/scan_mxu.py`):
//!
//! 1. reshape the count vector into 16×16 tiles;
//! 2. intra-tile inclusive scan = `L · X` where `L` is the lower-
//!    triangular ones matrix (one MMA per tile);
//! 3. tile sums = last row of step 2; scan of tile sums = second small
//!    matmul; broadcast-add carries.
//!
//! ≈ 64 FP16 FLOPs per element. At the paper's 1:1 data:thread ratio only
//! one eighth of the warps own a tile, so effective tensor utilisation is
//! ⅛ on Turing ([`DeviceSpec::cost::tensor_scan_utilisation`]); Ampere's
//! per-instruction tensor throughput is ~4× Turing's, which shrinks the
//! stall fraction — modeled as a higher utilisation, matching the paper's
//! observation that the tensor-vs-shuffle gap is smaller on the A100.

use super::InsertShape;
use crate::sim::{atomicmodel, kernel::KernelProfile, spec::DeviceSpec};

/// FP16 FLOPs per scanned element (two 16×16×16 MMAs per 256-element
/// tile: 2 × 2·16³ / 256 = 64).
pub const FLOPS_PER_ELEMENT: f64 = 64.0;

/// Effective MXU utilisation for the scan on this device. Turing pays the
/// full ⅛ warp-occupancy penalty; Ampere's fatter tensor pipes hide more
/// of it.
pub fn utilisation(spec: &DeviceSpec) -> f64 {
    let base = spec.cost.tensor_scan_utilisation; // 1/8
    if spec.name == "A100" {
        base * 1.8 // Ampere 3rd-gen tensor cores: fewer issue stalls
    } else {
        base
    }
}

/// Cost profile of one MXU-scan insertion launch.
pub fn profile(spec: &DeviceSpec, shape: &InsertShape) -> KernelProfile {
    let (bytes, eff) = super::warp_scan::scan_traffic(shape, spec);
    let slots_per_wave = shape.blocks * shape.threads_per_block as u64;
    let chunks = crate::util::math::ceil_div(shape.threads.max(1), slots_per_wave.max(1));
    // Tile staging through shared memory adds a small per-block cost.
    let per_block_us = chunks as f64
        * crate::sim::block::smem_stage_us(spec, shape.threads_per_block as u64 * 4);
    let atomic_us = atomicmodel::multi_addr_atomic_us(spec, shape.blocks * chunks, shape.counters, false);
    // The matmul pipeline does not overlap the streaming traffic at a 1:1
    // data:thread ratio (idle warps stall the memory pipeline too), so its
    // cost is additive — folded into per-block path per chunk.
    let mxu_flops = shape.threads as f64 * FLOPS_PER_ELEMENT;
    let mxu_us_total = mxu_flops / (spec.fp16_flops_per_us() * utilisation(spec));
    KernelProfile {
        blocks: shape.blocks,
        threads_per_block: shape.threads_per_block,
        bytes,
        coalescing_eff: eff,
        flops_fp32: 0.0,
        flops_mxu: 0.0, // accounted additively via extra_us
        mxu_utilisation: 1.0,
        per_block_us,
        atomic_us,
        extra_us: mxu_us_total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::insertion::{cost_us, InsertionKind, InsertShape};

    #[test]
    fn utilisation_ordering() {
        assert!(utilisation(&DeviceSpec::a100()) > utilisation(&DeviceSpec::titan_rtx()));
        assert!(utilisation(&DeviceSpec::a100()) < 1.0);
    }

    #[test]
    fn slower_than_shuffle_but_same_order() {
        for spec in [DeviceSpec::titan_rtx(), DeviceSpec::a100()] {
            let n = 128_000_000u64;
            let shape = InsertShape::static_array(&spec, n, n, 4);
            let mxu = cost_us(&spec, InsertionKind::MxuScan, &shape);
            let scan = cost_us(&spec, InsertionKind::WarpScan, &shape);
            let ratio = mxu / scan;
            assert!(ratio > 1.0 && ratio < 3.0, "{}: ratio {ratio}", spec.name);
        }
    }
}
