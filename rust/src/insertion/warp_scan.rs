//! Warp-shuffle prefix-sum insertion (paper §III.B.2) — the fastest
//! algorithm in Fig 4 and the one GGArray uses by default.
//!
//! Scheme: each block scans its threads' insertion counts with
//! `__shfl_up_sync` (3-phase block scan), the block leader reserves a
//! global range with a single atomic, and every thread writes its
//! element(s) at `block_base + local_prefix`. With fewer blocks than
//! elements (GGArray with B LFVectors), blocks iterate over chunks with a
//! running carry — "thread coarsening" in the paper's terms.

use super::InsertShape;
use crate::sim::{atomicmodel, block, kernel::KernelProfile, spec::DeviceSpec};

/// Equivalent single-efficiency for split read/write traffic.
pub(crate) fn blended_eff(read_bytes: f64, read_eff: f64, write_bytes: f64, write_eff: f64) -> f64 {
    let total = read_bytes + write_bytes;
    if total == 0.0 {
        return 1.0;
    }
    total / (read_bytes / read_eff + write_bytes / write_eff)
}

/// Traffic common to the scan-based algorithms:
/// * read per-thread insert flags/counts (4 B/thread),
/// * write per-thread offsets (4 B/thread — kept for the r/w phase),
/// * read source elements + write them at their assigned slots.
pub(crate) fn scan_traffic(shape: &InsertShape, spec: &DeviceSpec) -> (f64, f64) {
    let read = (shape.threads * 4 + shape.inserts * shape.elem_bytes) as f64;
    let write = (shape.threads * 4 + shape.inserts * shape.elem_bytes) as f64;
    let eff = blended_eff(read, spec.cost.coalesced_eff, write, shape.write_eff);
    (read + write, eff)
}

/// Cost profile of one warp-scan insertion launch.
pub fn profile(spec: &DeviceSpec, shape: &InsertShape) -> KernelProfile {
    let (bytes, eff) = scan_traffic(shape, spec);
    // Chunks each block must serially process (thread coarsening).
    let slots_per_wave = shape.blocks * shape.threads_per_block as u64;
    let chunks = crate::util::math::ceil_div(shape.threads.max(1), slots_per_wave.max(1));
    let per_block_us = chunks as f64 * block::shfl_block_scan_us(spec, shape.threads_per_block);
    // One global-offset atomic per block per chunk, spread over `counters`.
    let atomic_us = atomicmodel::multi_addr_atomic_us(spec, shape.blocks * chunks, shape.counters, false);
    KernelProfile {
        blocks: shape.blocks,
        threads_per_block: shape.threads_per_block,
        bytes,
        coalescing_eff: eff,
        flops_fp32: 2.0 * shape.threads as f64, // shuffle adds
        flops_mxu: 0.0,
        mxu_utilisation: 1.0,
        per_block_us,
        atomic_us,
        extra_us: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::insertion::InsertShape;

    #[test]
    fn blended_eff_bounds() {
        let e = blended_eff(100.0, 0.8, 100.0, 0.2);
        assert!(e > 0.2 && e < 0.8);
        // All-read degenerates to read eff.
        assert!((blended_eff(100.0, 0.8, 0.0, 0.1) - 0.8).abs() < 1e-12);
        assert_eq!(blended_eff(0.0, 0.5, 0.0, 0.5), 1.0);
    }

    #[test]
    fn static_insert_lands_near_table2() {
        // Table II: static insert of 5.12e8 elements on A100 = 7.07 ms.
        let spec = DeviceSpec::a100();
        let n = 512_000_000u64;
        let shape = InsertShape::static_array(&spec, n, n, 4);
        let ms = crate::insertion::cost_us(&spec, crate::insertion::InsertionKind::WarpScan, &shape) / 1e3;
        assert!((ms - 7.07).abs() < 1.2, "modeled {ms:.2} ms vs paper 7.07 ms");
    }

    #[test]
    fn coarsening_multiplies_block_path() {
        let spec = DeviceSpec::a100();
        let mut shape = InsertShape::static_array(&spec, 1 << 20, 1 << 20, 4);
        shape.blocks = 32; // heavy coarsening
        let p = profile(&spec, &shape);
        let full = InsertShape::static_array(&spec, 1 << 20, 1 << 20, 4);
        let p_full = profile(&spec, &full);
        assert!(p.per_block_us > p_full.per_block_us * 10.0);
    }
}
