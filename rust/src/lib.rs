#![deny(unsafe_op_in_unsafe_fn)]
//! # GGArray — a dynamically growable GPU array
//!
//! Full-system reproduction of *"GGArray: A Dynamically Growable GPU
//! Array"* (Meneses, Navarro, Ferrada — 2022) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the GGArray data structure (an array of
//!   LFVectors, one per thread block), its baselines (static, semi-static,
//!   memMap/VMM), the three parallel insertion algorithms, a calibrated
//!   GPU execution cost model, and a **sharded coordinator service** that
//!   drives dynamic-memory workloads at traffic-serving scale.
//! * **Layer 2 (JAX, build time)** — the compute graphs (insert step, work
//!   phase, flatten) lowered AOT to HLO text.
//! * **Layer 1 (Pallas, build time)** — prefix-sum kernels (vector-unit
//!   hierarchical scan and MXU matmul scan) and the work kernel; executed
//!   at runtime through the PJRT CPU client by [`runtime`].
//!
//! See `DESIGN.md` for the experiment index and hardware-adaptation notes.
//!
//! ## Shards and epochs (two-phase lifecycle at service scale)
//!
//! The paper's headline usage pattern (§VI.D) is *phase-structured*: grow
//! with the GGArray while the final size is uncertain, then flatten once
//! and run the regular-access work phase at static-array speed. The
//! coordinator makes that lifecycle first-class and scales it out:
//!
//! * **Shards** — [`coordinator::shard::Shard`]: N independent
//!   `GgArray<f32>`s, each owning `blocks/N` consecutive blocks of the
//!   global block space and a VRAM budget carved from the shared
//!   [`sim::spec::DeviceSpec`]. Insert batches are routed *globally*
//!   (per [`coordinator::router`]) and sliced per shard, so the data
//!   layout — and therefore the sealed flatten bytes — is identical for
//!   any shard count.
//! * **Epochs** — [`coordinator::shard::EpochManager`]:
//!   `Epoch::Inserting → Epoch::Sealed(flat)`. `Request::Seal` drains
//!   in-flight batches, runs [`ggarray::flatten`] per shard, concatenates
//!   into one [`ggarray::flatten::ShardedFlattened`] view with a
//!   shard-offset index, and opens a fresh insert epoch behind it.
//!   Reads/work over the sealed prefix are charged fully-coalesced
//!   static-array cost; the live epoch keeps paying GGArray costs until
//!   it, too, seals — exactly the paper's insert-fast/access-fast split.
//! * **Parallel time model** — shards are concurrent thread-block
//!   groups of one device, so the service ledger charges each op the
//!   *max* over the participating shards' simulated deltas (the
//!   critical path) plus an explicit serial coordinator term — not the
//!   sum. [`coordinator::metrics::ParallelCost`] carries both the
//!   wall-model (`sim_*`, critical path) and the aggregate
//!   device-seconds (`device_*`), whose ratio is the observed
//!   shard-parallel speedup — the quantity the paper measures and a
//!   summed ledger can never show.
//! * **Epoch-owned VRAM** — one physical budget, carved once: the
//!   sealed store's heap (`CoordinatorConfig::epoch_heap`) first, the
//!   per-shard heaps from the remainder. A seal is a real memory
//!   transaction: flatten every shard, reserve epoch-store admission
//!   for the whole seal, then *transfer* each flatten destination out
//!   of its shard heap into the [`coordinator::shard::EpochManager`]'s
//!   heap ([`sim::memory::VramHeap::transfer_to`] — an accounting move,
//!   not allocator traffic). Old epochs never squat on live-epoch
//!   growth budgets, any failure aborts the whole seal in a single pass
//!   with every byte restored, and `Stats` reports a real ledger
//!   (`sealed_bytes`, `heap_used_bytes`) that conserves every byte
//!   across seal → compact → clear.
//! * **Sealed-epoch compaction** — each seal adds one flat segment, and
//!   the sealed work pass launches one kernel per segment (separate
//!   device buffers), so fragmentation costs launch overhead on every
//!   pass. Once the count passes `CoordinatorConfig::compact_segments`,
//!   one modeled gather pass
//!   ([`coordinator::shard::EpochManager::compact`]) merges the
//!   segments byte-identically into one, buying those launches back.
//!   The gather is its own VRAM transaction — the merged destination is
//!   reserved while the sources are still resident (the transient 2× a
//!   real gather needs) — so a tight epoch heap makes compaction OOM
//!   and abort byte-identically, surfaced in `Response::Sealed` and the
//!   `compaction_ooms` metric while the store keeps serving. `Work`
//!   also skips the `rw_b` launch on empty live shards, so a
//!   fully-sealed store pays only the flat-path passes.
//! * **Real shard parallelism** — the worker owns a persistent
//!   work-stealing [`coordinator::scheduler::Scheduler`]: a group of
//!   long-lived workers (spawned once at `Coordinator::start`, never
//!   per batch) parked on one shared Mutex+Condvar monitor with
//!   per-worker deques and steal-on-empty. Insert dispatch, work
//!   passes, snapshot gathers and the seal's phase-1 gather decompose
//!   into stealable per-shard (and sub-shard-range) chunks — the
//!   host-side analogue of the paper's per-block synchronization, minus
//!   the fork/join max-shard barrier: a hot shard's chunks are drained
//!   by every worker, so the *measured* wall ledger
//!   (`MetricsSnapshot::wall_*_ms`) tracks the modeled `sim_*` critical
//!   path instead of the `device_*` sum. Ops that could OOM mid-flight
//!   are pre-screened against exact VRAM demand and fall back to the
//!   serial loop, keeping every trace byte-identical across executor
//!   modes (`CoordinatorConfig::executor_threads` / `GG_THREADS`;
//!   property-tested at 1/2/4 shards, zero-alloc across the chunk
//!   handoff, measured 4-vs-1 and skewed-routing speedups gated in
//!   `bench_hotpath`).
//! * **Zero-copy hot path** — the steady-state dispatch loop is
//!   allocation-free and copy-minimal on the host side: a
//!   [`coordinator::router::DispatchScratch`] arena owned by the worker
//!   holds every per-batch buffer (sizes, counts, per-shard ranges,
//!   clock marks — cleared, never dropped), routing writes in place and
//!   hands each shard a `&[f32]` sub-slice of the original batch, the
//!   batcher recycles its flush buffers, and flatten/seal/compaction
//!   gather into pooled destinations (the [`coordinator::shard::EpochManager`]
//!   keeps a gather pool sized to the largest seal seen). Debug-only
//!   self-checks (the AOT scan cross-check) are compiled out of release
//!   builds. Guarded by a counting-allocator regression test
//!   (`tests/alloc_guard.rs`), a byte-identity property test against
//!   the copying reference path (`tests/properties.rs`), and a
//!   wall-clock trajectory with a regression gate
//!   (`BENCH_hotpath.json` via `benches/bench_hotpath.rs`; see
//!   EXPERIMENTS.md §Perf).
//! * **Multi-client admission frontend** — the coordinator serves many
//!   concurrent clients through bounded
//!   [`coordinator::frontend::ClientSession`] handles: each session
//!   owns a bounded request
//!   channel (the admission window) and a monotonic sequence counter,
//!   and the worker coalesces all client pools into the shared batcher
//!   in client-id order (per-client FIFO) before every sync point.
//!   Backpressure *sheds instead of blocking* — a full window returns a
//!   typed [`coordinator::request::Admission::Rejected`] with the
//!   payload handed back and a retry hint; the worker never waits on a
//!   slow client and every shed lands in the `shed_requests` metric.
//!   Under [`coordinator::frontend::MergePolicy::AtBarrier`] the merged
//!   value stream — and therefore the sealed layout, byte-for-byte — is
//!   a pure function of the per-client traces, independent of thread
//!   timing (property-tested at 1/4/16 clients × 1/2/4 shards × both
//!   executor modes against a serial single-session replay; sustained
//!   req/s and p50/p99 admission latency tracked in
//!   `BENCH_frontend.json` via `benches/bench_frontend.rs`; see
//!   EXPERIMENTS.md §Frontend).
//!
//! * **Panic-safe, self-healing coordinator** — every failure a
//!   participant can suffer mid-operation becomes either a typed,
//!   ledger-conserving abort or an invisible recovery. Scheduler
//!   workers contain chunk panics with `catch_unwind` (monitor
//!   counters restored, never poisoned), the in-flight op aborts with
//!   a typed [`coordinator::request::ExecError`] and rolls back its
//!   serially pre-charged sim/heap deltas byte-identically (clock and
//!   heap marks + bucket-growth rollback — the PR 3 seal-abort
//!   discipline extended to insert/work/flatten), and the group
//!   respawns dead workers or permanently degrades (floor 1 ≡ serial,
//!   ledgered as `worker_respawns`/`degraded_workers`/
//!   `spawn_failures`). A coordinator-worker panic is caught at the
//!   request boundary (`Response::Failed`), and a dead worker thread
//!   surfaces as `ExecError::ServiceDown` / `Admission::Closed` on
//!   every session — never a hang. All of it is driven by the
//!   deterministic fault-injection framework in [`faults`]
//!   (`--cfg ggfault`, zero-cost in release builds): named sites, a
//!   per-test `FaultPlan` firing the Nth crossing, and a chaos suite
//!   (`tests/chaos.rs`) enumerating every registered site × occurrence
//!   × shard count × executor mode against the abort-or-byte-identical
//!   contract. See EXPERIMENTS.md §Robustness.
//! * **Machine-checked concurrency** — the coordinator's locks,
//!   condvars, atomics, channels and threads all come from the
//!   [`sync`] facade (std re-exports in normal builds). Under
//!   `--cfg ggcheck` the facade swaps in instrumented primitives
//!   driven by the [`checker`] — a bounded exhaustive-interleaving
//!   model checker (loom-style DFS over yield points, vendor-free)
//!   that enumerates every schedule of the scheduler's
//!   park/steal/termination monitor protocol, the admission
//!   shed/rollback path, and the `AtBarrier` drain order, printing a
//!   replayable schedule seed on failure
//!   (`tests/model_check.rs`). Pointer hand-offs to scheduler workers
//!   use the provenance-preserving [`sync::SendPtr`] family instead of
//!   `usize` laundering, and a repo lint (`cargo run --bin lint`)
//!   gates `unsafe` hygiene, pointer casts, facade bypasses, and
//!   hot-path allocations in CI. See EXPERIMENTS.md §Analysis.
//!
//! See `examples/sharded_two_phase.rs` for the end-to-end flow and
//! `rust/benches/bench_shards.rs` for the scaling shape.
//!
//! ## Quick start
//!
//! ```no_run
//! use ggarray::prelude::*;
//!
//! let spec = DeviceSpec::a100();
//! let mut gg = GgArray::<u32>::new(GgConfig::new(32), spec);
//! // Simulated in-kernel push_back of 1000 elements round-robin:
//! let report = gg.grow_and_insert(&vec![1u32; 1000], InsertionKind::WarpScan);
//! assert_eq!(gg.len(), 1000);
//! println!("simulated insert time: {:.3} ms", report.total_ms());
//! ```

pub mod baselines;
pub mod checker;
pub mod coordinator;
pub mod experiments;
pub mod faults;
pub mod ggarray;
pub mod insertion;
pub mod runtime;
pub mod sim;
pub mod sync;
pub mod testkit;
pub mod theory;
pub mod util;
pub mod workload;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::baselines::{
        memmap::MemMapArray, semistatic::SemiStaticArray, static_array::StaticArray, GrowableArray,
    };
    pub use crate::coordinator::{
        frontend::{ClientSession, FrontendConfig, MergePolicy},
        request::{Admission, Request, Response},
        service::{drive_workload, Coordinator, CoordinatorConfig, WorkloadRun},
        shard::{Epoch, EpochManager, Shard, ShardConfig},
    };
    pub use crate::ggarray::{
        array::{GgArray, GgConfig, OpReport},
        flatten::{Flattened, ShardedFlattened},
        lfvector::LfVector,
    };
    pub use crate::insertion::InsertionKind;
    pub use crate::sim::spec::DeviceSpec;
    pub use crate::util::rng::Rng;
    pub use crate::workload::WorkloadSpec;
}

/// Crate-level result alias.
pub type Result<T> = anyhow::Result<T>;
