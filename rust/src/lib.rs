//! # GGArray — a dynamically growable GPU array
//!
//! Full-system reproduction of *"GGArray: A Dynamically Growable GPU
//! Array"* (Meneses, Navarro, Ferrada — 2022) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the GGArray data structure (an array of
//!   LFVectors, one per thread block), its baselines (static, semi-static,
//!   memMap/VMM), the three parallel insertion algorithms, a calibrated
//!   GPU execution cost model, and a coordinator service that drives
//!   dynamic-memory workloads.
//! * **Layer 2 (JAX, build time)** — the compute graphs (insert step, work
//!   phase, flatten) lowered AOT to HLO text.
//! * **Layer 1 (Pallas, build time)** — prefix-sum kernels (vector-unit
//!   hierarchical scan and MXU matmul scan) and the work kernel; executed
//!   at runtime through the PJRT CPU client by [`runtime`].
//!
//! See `DESIGN.md` for the experiment index and hardware-adaptation notes.
//!
//! ## Quick start
//!
//! ```no_run
//! use ggarray::prelude::*;
//!
//! let spec = DeviceSpec::a100();
//! let mut gg = GgArray::<u32>::new(GgConfig::new(32), spec);
//! // Simulated in-kernel push_back of 1000 elements round-robin:
//! let report = gg.grow_and_insert(&vec![1u32; 1000], InsertionKind::WarpScan);
//! assert_eq!(gg.len(), 1000);
//! println!("simulated insert time: {:.3} ms", report.total_ms());
//! ```

pub mod baselines;
pub mod coordinator;
pub mod experiments;
pub mod ggarray;
pub mod insertion;
pub mod runtime;
pub mod sim;
pub mod testkit;
pub mod theory;
pub mod util;
pub mod workload;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::baselines::{
        memmap::MemMapArray, semistatic::SemiStaticArray, static_array::StaticArray, GrowableArray,
    };
    pub use crate::coordinator::{
        request::{Request, Response},
        service::{Coordinator, CoordinatorConfig},
    };
    pub use crate::ggarray::{
        array::{GgArray, GgConfig, OpReport},
        lfvector::LfVector,
    };
    pub use crate::insertion::InsertionKind;
    pub use crate::sim::spec::DeviceSpec;
    pub use crate::util::rng::Rng;
    pub use crate::workload::WorkloadSpec;
}

/// Crate-level result alias.
pub type Result<T> = anyhow::Result<T>;
