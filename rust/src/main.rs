//! `repro` — CLI entry point: regenerate every paper figure/table, run the
//! coordinator demo, or the quickstart.
//!
//! ```text
//! repro table1                 # Table I (device models)
//! repro fig3  [--steps 40 --draws 4000]
//! repro fig4  [--doublings 10]
//! repro fig5
//! repro table2
//! repro fig6
//! repro all   [--out reports]
//! repro quickstart
//! repro serve [--blocks 512 --inserts 100000]
//! ```

use ggarray::experiments::{ablations, fig3, fig4, fig5, fig6, report::Report, table1, table2};
use ggarray::util::argparse::{flag, opt, Cli, CmdSpec};

fn cli() -> Cli {
    Cli {
        prog: "repro",
        about: "GGArray paper reproduction (Rust + JAX + Pallas, AOT via PJRT)",
        commands: vec![
            CmdSpec { name: "table1", help: "Table I: GPU specifications", opts: vec![] },
            CmdSpec {
                name: "fig3",
                help: "Fig 3: theoretic memory usage vs sigma",
                opts: vec![
                    opt("steps", Some("40"), "sigma sweep steps"),
                    opt("draws", Some("4000"), "Monte-Carlo draws per point"),
                    opt("blocks", Some("512"), "LFVectors"),
                ],
            },
            CmdSpec {
                name: "fig4",
                help: "Fig 4: insertion algorithms; grow+insert and r/w vs #LFVectors",
                opts: vec![opt("doublings", Some("10"), "duplication iterations")],
            },
            CmdSpec { name: "fig5", help: "Fig 5: grow/insert/rw per duplication iteration", opts: vec![] },
            CmdSpec { name: "table2", help: "Table II: last-iteration times on the A100 model", opts: vec![] },
            CmdSpec { name: "fig6", help: "Fig 6: two-phase application speedup", opts: vec![] },
            CmdSpec { name: "ablations", help: "design-choice ablation studies", opts: vec![] },
            CmdSpec { name: "all", help: "run every experiment", opts: vec![] },
            CmdSpec { name: "quickstart", help: "minimal GGArray usage demo", opts: vec![] },
            CmdSpec {
                name: "serve",
                help: "run the coordinator service demo workload",
                opts: vec![
                    opt("blocks", Some("512"), "LFVectors (total across shards)"),
                    opt("shards", Some("1"), "independent GGArray shards"),
                    opt("inserts", Some("100000"), "total elements to insert"),
                    opt("work", Some("3"), "work calls after the insert phase"),
                    flag("seal", "seal the epoch (flat fast path) before the work phase"),
                    flag("no-artifacts", "skip AOT artifacts (host fallback)"),
                ],
            },
        ],
        global_opts: vec![
            opt("out", Some("reports"), "report output directory"),
            opt("seed", Some("42"), "rng seed"),
            flag("quiet", "suppress markdown output"),
            flag("plot", "render an ASCII chart of the figure"),
        ],
    }
}

fn emit(rep: Report, out_dir: &str, quiet: bool) -> anyhow::Result<()> {
    if !quiet {
        print!("{}", rep.markdown());
    }
    let paths = rep.save(std::path::Path::new(out_dir))?;
    eprintln!("[repro] wrote {} files under {out_dir}/ ({})", paths.len(), rep.id);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match cli().parse(&args) {
        Ok(p) => p,
        Err(help) => {
            println!("{help}");
            return Ok(());
        }
    };
    let out = parsed.get("out").unwrap_or("reports").to_string();
    let quiet = parsed.flag("quiet");
    let seed: u64 = parsed.get_parse("seed")?;

    match parsed.command.as_str() {
        "table1" => emit(table1::run(), &out, quiet)?,
        "fig3" => {
            let p = fig3::Params {
                steps: parsed.get_parse("steps")?,
                draws: parsed.get_parse("draws")?,
                blocks: parsed.get_parse("blocks")?,
                seed,
                ..fig3::Params::default()
            };
            let rep = fig3::run(&p);
            if parsed.flag("plot") {
                plot_columns(&rep, 0, 0, &[(2, "static_p99"), (5, "ggarray"), (1, "optimal")], true, "Fig 3: memory vs sigma (log y)");
            }
            emit(rep, &out, quiet)?;
        }
        "fig4" => {
            let p = fig4::Params { doublings: parsed.get_parse("doublings")?, ..fig4::Params::default() };
            emit(fig4::run(&p), &out, quiet)?;
        }
        "fig5" => emit(fig5::run(&fig5::Params::default()), &out, quiet)?,
        "table2" => emit(table2::run(), &out, quiet)?,
        "fig6" => {
            let rep = fig6::run(&fig6::Params::default());
            if parsed.flag("plot") {
                // A100 section, k=1 rows only → speedup vs work calls.
                let table = &rep.sections[1].table;
                let pts: Vec<(f64, f64)> = table
                    .rows()
                    .iter()
                    .filter(|r| r[0] == "1")
                    .map(|r| (r[1].parse().unwrap(), r[4].parse().unwrap()))
                    .collect();
                let s = vec![ggarray::util::plot::Series { name: "speedup (k=1, A100)".into(), points: pts }];
                println!(
                    "{}",
                    ggarray::util::plot::render(
                        &s,
                        &ggarray::util::plot::PlotConfig {
                            log_x: true,
                            title: "Fig 6: two-phase speedup vs work calls (log x)".into(),
                            ..Default::default()
                        }
                    )
                );
            }
            emit(rep, &out, quiet)?;
        }
        "ablations" => emit(ablations::run(), &out, quiet)?,
        "all" => {
            emit(table1::run(), &out, quiet)?;
            emit(fig3::run(&fig3::Params { seed, ..fig3::Params::default() }), &out, quiet)?;
            emit(fig4::run(&fig4::Params::default()), &out, quiet)?;
            emit(fig5::run(&fig5::Params::default()), &out, quiet)?;
            emit(table2::run(), &out, quiet)?;
            emit(fig6::run(&fig6::Params::default()), &out, quiet)?;
            emit(ablations::run(), &out, quiet)?;
        }
        "quickstart" => quickstart(),
        "serve" => {
            serve(
                parsed.get_parse("blocks")?,
                parsed.get_parse("shards")?,
                parsed.get_parse("inserts")?,
                parsed.get_parse("work")?,
                parsed.flag("seal"),
                !parsed.flag("no-artifacts"),
            );
        }
        other => anyhow::bail!("unhandled command {other}"),
    }
    Ok(())
}

/// Plot columns of a report section: x from `xcol`, one series per
/// (column, label).
fn plot_columns(rep: &Report, section: usize, xcol: usize, ys: &[(usize, &str)], log_y: bool, title: &str) {
    use ggarray::util::plot::{render, PlotConfig, Series};
    let table = &rep.sections[section].table;
    let series: Vec<Series> = ys
        .iter()
        .map(|&(c, name)| Series {
            name: name.to_string(),
            points: table
                .rows()
                .iter()
                .filter_map(|r| Some((r[xcol].parse().ok()?, r[c].parse().ok()?)))
                .collect(),
        })
        .collect();
    println!("{}", render(&series, &PlotConfig { log_y, title: title.to_string(), ..Default::default() }));
}

fn quickstart() {
    use ggarray::ggarray::array::{GgArray, GgConfig};
    use ggarray::insertion::InsertionKind;
    use ggarray::sim::spec::DeviceSpec;

    let spec = DeviceSpec::a100();
    let mut gg: GgArray<u32> = GgArray::new(GgConfig::new(32), spec);
    let report = gg.grow_and_insert(&(0..100_000u32).collect::<Vec<_>>(), InsertionKind::WarpScan);
    println!("inserted {} elements in {:.3} ms (simulated)", report.elements, report.total_ms());
    let rw = gg.read_write_block(30.0, |x| *x += 1);
    println!("rw_b over {} elements: {:.3} ms (simulated)", rw.elements, rw.total_ms());
    println!("len {} capacity {} overhead {:.2}×", gg.len(), gg.capacity(), gg.overhead_ratio());
    assert_eq!(gg.get(0), Some(1));
    println!("quickstart OK");
}

fn serve(blocks: usize, shards: usize, inserts: usize, work: u32, seal: bool, use_artifacts: bool) {
    use ggarray::coordinator::request::{Request, Response};
    use ggarray::coordinator::service::{Coordinator, CoordinatorConfig};

    let cfg = CoordinatorConfig { blocks, shards, use_artifacts, ..CoordinatorConfig::default() };
    let c = Coordinator::start(cfg);
    let chunk = 1024;
    let mut sent = 0usize;
    while sent < inserts {
        let n = chunk.min(inserts - sent);
        let values: Vec<f32> = (sent..sent + n).map(|i| i as f32).collect();
        c.call(Request::Insert { values });
        sent += n;
    }
    if seal {
        match c.call(Request::Seal) {
            Response::Sealed { epoch, sealed_len, sealed_segments, sim_us, .. } => {
                println!(
                    "sealed epoch → {epoch}: {sealed_len} elements on the flat path ({sealed_segments} segments, sim {:.3} ms)",
                    sim_us / 1e3
                )
            }
            other => println!("seal: {other:?}"),
        }
    }
    c.call(Request::Work { calls: work });
    match c.call(Request::Flatten) {
        Response::Flattened { len, sim_us, device_us, checksum } => {
            println!(
                "flattened {len} elements (sim {:.3} ms critical path, {:.3} ms device total, checksum {checksum:#x})",
                sim_us / 1e3,
                device_us / 1e3
            )
        }
        other => println!("flatten: {other:?}"),
    }
    if let Response::Stats(s) = c.call(Request::Stats) {
        println!("{s}");
    }
    c.shutdown();
}
