//! AOT artifact manifest: `artifacts/manifest.json` written by
//! `python/compile/aot.py` describing every lowered HLO module —
//! entry-point name, file, input/output tensor specs and the lowering
//! parameters. The Rust side never guesses shapes: everything comes from
//! here.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// Tensor shape + dtype as recorded by the AOT pipeline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    /// "i32" | "u32" | "f32" | "bf16" — jax dtype names.
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> anyhow::Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow::anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|v| v.as_u64().map(|u| u as usize).ok_or_else(|| anyhow::anyhow!("bad dim")))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(|d| d.as_str())
            .ok_or_else(|| anyhow::anyhow!("tensor spec missing dtype"))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT-compiled entry point.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    dir: PathBuf,
    entries: BTreeMap<String, ArtifactSpec>,
}

impl ArtifactManifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<ArtifactManifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {} (run `make artifacts`): {e}", path.display()))?;
        let j = json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse error: {e}"))?;
        let mut entries = BTreeMap::new();
        let obj = j
            .get("entries")
            .and_then(|e| e.as_obj())
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'entries'"))?;
        for (name, spec) in obj {
            let file = spec
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow::anyhow!("entry {name} missing file"))?
                .to_string();
            let parse_specs = |key: &str| -> anyhow::Result<Vec<TensorSpec>> {
                spec.get(key)
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow::anyhow!("entry {name} missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            entries.insert(
                name.clone(),
                ArtifactSpec { name: name.clone(), file, inputs: parse_specs("inputs")?, outputs: parse_specs("outputs")? },
            );
        }
        Ok(ArtifactManifest { dir: dir.to_path_buf(), entries })
    }

    /// Default artifact directory: `$GGARRAY_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("GGARRAY_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Does the default manifest exist? (Tests skip gracefully when the
    /// build-time artifacts haven't been generated yet.)
    pub fn available() -> bool {
        Self::default_dir().join("manifest.json").exists()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.entries.get(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Entries whose name starts with `prefix`, e.g. all `scan_i32_*`
    /// size variants, sorted by their first input's element count.
    pub fn family(&self, prefix: &str) -> Vec<&ArtifactSpec> {
        let mut v: Vec<&ArtifactSpec> =
            self.entries.values().filter(|s| s.name.starts_with(prefix)).collect();
        v.sort_by_key(|s| s.inputs.first().map(|i| i.elements()).unwrap_or(0));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parse_roundtrip() {
        let dir = std::env::temp_dir().join("ggarray_manifest_test");
        write_manifest(
            &dir,
            r#"{
              "version": 1,
              "entries": {
                "scan_i32_1024": {
                  "file": "scan_i32_1024.hlo.txt",
                  "inputs": [{"shape": [1024], "dtype": "i32"}],
                  "outputs": [{"shape": [1024], "dtype": "i32"}]
                },
                "scan_i32_4096": {
                  "file": "scan_i32_4096.hlo.txt",
                  "inputs": [{"shape": [4096], "dtype": "i32"}],
                  "outputs": [{"shape": [4096], "dtype": "i32"}]
                },
                "work_f32_1024": {
                  "file": "work_f32_1024.hlo.txt",
                  "inputs": [{"shape": [1024], "dtype": "f32"}],
                  "outputs": [{"shape": [1024], "dtype": "f32"}]
                }
              }
            }"#,
        );
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.len(), 3);
        let s = m.get("scan_i32_1024").unwrap();
        assert_eq!(s.inputs[0].shape, vec![1024]);
        assert_eq!(s.inputs[0].dtype, "i32");
        assert_eq!(s.inputs[0].elements(), 1024);
        assert!(m.path_of(s).ends_with("scan_i32_1024.hlo.txt"));
        let fam = m.family("scan_i32_");
        assert_eq!(fam.len(), 2);
        assert!(fam[0].inputs[0].elements() < fam[1].inputs[0].elements());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = ArtifactManifest::load(Path::new("/nonexistent-dir-xyz")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn malformed_manifest_rejected() {
        let dir = std::env::temp_dir().join("ggarray_manifest_bad");
        write_manifest(&dir, r#"{"entries": {"x": {"file": "x.hlo"}}}"#);
        assert!(ArtifactManifest::load(&dir).is_err());
        write_manifest(&dir, "not json");
        assert!(ArtifactManifest::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
