//! PJRT CPU client wrapper.
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 serialises
//! `HloModuleProto`s with 64-bit instruction ids that xla_extension 0.5.1
//! rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
//! round-trips cleanly (see /opt/xla-example/README.md).
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (neither `Send` nor
//! `Sync`), so the client is **thread-local**: each thread that touches
//! PJRT lazily creates its own CPU client. In this architecture that is
//! the coordinator worker plus the shard scheduler's workers (each
//! compiles the shared `Executor`'s kernels into its own thread-local
//! cache on first use), plus test threads.

use std::cell::RefCell;
use std::path::Path;

thread_local! {
    static CLIENT: RefCell<Option<xla::PjRtClient>> = const { RefCell::new(None) };
}

/// Run `f` with this thread's PJRT CPU client (created on first use).
pub fn with_client<R>(f: impl FnOnce(&xla::PjRtClient) -> anyhow::Result<R>) -> anyhow::Result<R> {
    CLIENT.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            *slot = Some(xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?);
        }
        f(slot.as_ref().expect("just initialised"))
    })
}

/// Platform name of this thread's client (diagnostics).
pub fn platform_name() -> anyhow::Result<String> {
    with_client(|c| Ok(c.platform_name()))
}

/// Load an HLO-text file and compile it for this thread's CPU client.
pub fn compile_hlo_file(path: &Path) -> anyhow::Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
    )
    .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    with_client(|c| c.compile(&comp).map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display())))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_is_cpu() {
        assert_eq!(platform_name().unwrap(), "cpu");
        // Second use reuses the thread-local (no way to observe identity
        // directly; absence of re-init cost is covered by bench_hotpath).
        assert_eq!(platform_name().unwrap(), "cpu");
    }

    #[test]
    fn missing_file_errors_cleanly() {
        let err = match compile_hlo_file(Path::new("/no/such/file.hlo.txt")) {
            Err(e) => e,
            Ok(_) => panic!("expected error for missing file"),
        };
        assert!(err.to_string().contains("file.hlo.txt"));
    }
}
