//! Executable cache + typed execution over the AOT artifacts.
//!
//! The coordinator's hot path calls [`Executor::run_i32`] /
//! [`Executor::run_f32`]; compilation happens once per artifact *per
//! thread* (cached), inputs are validated against the manifest's tensor
//! specs, and padding to the artifact's fixed shape is handled here
//! (XLA executables are shape-monomorphic; `aot.py` emits a small
//! family of power-of-two sizes per kernel).
//!
//! ## Sharing across scheduler workers
//!
//! The `xla` crate's PJRT types are `Rc`-based (`!Send`), and the CPU
//! client itself is thread-local (see [`super::client`]). [`Executor`]
//! is nevertheless `Send + Sync`: it owns only the manifest and an
//! execution counter, while compiled executables live in a
//! **thread-local** cache keyed by (executor instance, artifact name).
//! An `Arc<Executor>` can therefore be handed to every scheduler
//! worker; each worker lazily compiles its own copy of the artifacts it
//! actually runs (once per thread lifetime — the workers are
//! persistent). [`Executor::warm_up`] warms the *calling* thread's
//! cache only.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use super::artifact::{ArtifactManifest, ArtifactSpec};
use super::client;

/// Instance counter: keys the thread-local executable cache so two
/// `Executor`s over different artifact dirs never share entries.
static NEXT_EXECUTOR_ID: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

thread_local! {
    /// Per-thread compiled executables: (executor id, artifact name) →
    /// loaded executable. Entries persist for the thread's lifetime
    /// (scheduler workers are persistent, so each artifact compiles at
    /// most once per worker).
    static COMPILED: RefCell<HashMap<(u64, String), Rc<xla::PjRtLoadedExecutable>>> =
        RefCell::new(HashMap::new());
}

/// A typed input for [`Executor::run_mixed`].
#[derive(Debug, Clone, Copy)]
pub enum ArgValue<'a> {
    I32(&'a [i32]),
    F32(&'a [f32]),
}

/// A typed output from [`Executor::run_mixed`].
#[derive(Debug, Clone)]
pub enum OutValue {
    I32(Vec<i32>),
    F32(Vec<f32>),
}

impl OutValue {
    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            OutValue::I32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            OutValue::F32(v) => Some(v),
            _ => None,
        }
    }
}

/// Cached, compiled AOT artifacts. `Send + Sync` (shareable across
/// scheduler workers via `Arc`) because compiled executables live in a
/// thread-local cache, not in this struct — see the module doc.
pub struct Executor {
    id: u64,
    manifest: ArtifactManifest,
    executions: std::sync::atomic::AtomicU64,
}

impl Executor {
    /// Load the manifest from `dir` (usually `artifacts/`).
    pub fn new(dir: &Path) -> anyhow::Result<Executor> {
        Ok(Executor {
            id: NEXT_EXECUTOR_ID.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            manifest: ArtifactManifest::load(dir)?,
            executions: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Executor over the default artifact directory.
    pub fn from_default_dir() -> anyhow::Result<Executor> {
        Self::new(&ArtifactManifest::default_dir())
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Number of PJRT executions performed (metrics).
    pub fn executions(&self) -> u64 {
        self.executions.load(std::sync::atomic::Ordering::Relaxed)
    }

    fn compiled(&self, name: &str) -> anyhow::Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = COMPILED.with(|c| c.borrow().get(&(self.id, name.to_string())).cloned()) {
            return Ok(e);
        }
        let spec = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}' (have: {:?})", self.manifest.names().collect::<Vec<_>>()))?;
        let exe = Rc::new(client::compile_hlo_file(&self.manifest.path_of(spec))?);
        COMPILED.with(|c| c.borrow_mut().insert((self.id, name.to_string()), exe.clone()));
        Ok(exe)
    }

    /// Pre-compile every artifact on the *calling* thread (startup
    /// warm-up so this thread's request path never compiles; scheduler
    /// workers warm their own caches lazily on first use).
    pub fn warm_up(&self) -> anyhow::Result<usize> {
        let names: Vec<String> = self.manifest.names().map(|s| s.to_string()).collect();
        for n in &names {
            self.compiled(n)?;
        }
        Ok(names.len())
    }

    fn run_literals(&self, name: &str, inputs: Vec<xla::Literal>) -> anyhow::Result<Vec<xla::Literal>> {
        let exe = self.compiled(name)?;
        let result = exe
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e}"))?;
        self.executions.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result of {name}: {e}"))?;
        // aot.py lowers with return_tuple=True: unwrap the tuple.
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untuple {name}: {e}"))
    }

    fn spec_checked(&self, name: &str, ninputs: usize) -> anyhow::Result<&ArtifactSpec> {
        let spec = self.manifest.get(name).ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))?;
        anyhow::ensure!(
            spec.inputs.len() == ninputs,
            "artifact {name} expects {} inputs, got {ninputs}",
            spec.inputs.len()
        );
        Ok(spec)
    }

    /// Run an i32→i32 artifact. Each input slice must be ≤ the artifact's
    /// fixed size; it is zero-padded up. Outputs are truncated back to
    /// `out_len`.
    pub fn run_i32(&self, name: &str, inputs: &[&[i32]], out_len: usize) -> anyhow::Result<Vec<Vec<i32>>> {
        let spec = self.spec_checked(name, inputs.len())?;
        let mut lits = Vec::with_capacity(inputs.len());
        for (k, (inp, ts)) in inputs.iter().zip(&spec.inputs).enumerate() {
            anyhow::ensure!(ts.dtype == "i32", "artifact {name} input {k} is {}, not i32", ts.dtype);
            anyhow::ensure!(
                inp.len() <= ts.elements(),
                "artifact {name} input {k}: {} > capacity {}",
                inp.len(),
                ts.elements()
            );
            let mut padded = inp.to_vec();
            padded.resize(ts.elements(), 0);
            let lit = xla::Literal::vec1(&padded)
                .reshape(&ts.shape.iter().map(|&d| d as i64).collect::<Vec<_>>())
                .map_err(|e| anyhow::anyhow!("reshape input {k} of {name}: {e}"))?;
            lits.push(lit);
        }
        let outs = self.run_literals(name, lits)?;
        outs.into_iter()
            .map(|o| {
                let mut v = o.to_vec::<i32>().map_err(|e| anyhow::anyhow!("read output of {name}: {e}"))?;
                v.truncate(out_len.min(v.len()));
                Ok(v)
            })
            .collect()
    }

    /// Run an f32→f32 artifact (same padding/truncation contract).
    pub fn run_f32(&self, name: &str, inputs: &[&[f32]], out_len: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        let spec = self.spec_checked(name, inputs.len())?;
        let mut lits = Vec::with_capacity(inputs.len());
        for (k, (inp, ts)) in inputs.iter().zip(&spec.inputs).enumerate() {
            anyhow::ensure!(ts.dtype == "f32", "artifact {name} input {k} is {}, not f32", ts.dtype);
            anyhow::ensure!(
                inp.len() <= ts.elements(),
                "artifact {name} input {k}: {} > capacity {}",
                inp.len(),
                ts.elements()
            );
            let mut padded = inp.to_vec();
            padded.resize(ts.elements(), 0.0);
            let lit = xla::Literal::vec1(&padded)
                .reshape(&ts.shape.iter().map(|&d| d as i64).collect::<Vec<_>>())
                .map_err(|e| anyhow::anyhow!("reshape input {k} of {name}: {e}"))?;
            lits.push(lit);
        }
        let outs = self.run_literals(name, lits)?;
        outs.into_iter()
            .map(|o| {
                let mut v = o.to_vec::<f32>().map_err(|e| anyhow::anyhow!("read output of {name}: {e}"))?;
                v.truncate(out_len.min(v.len()));
                Ok(v)
            })
            .collect()
    }

    /// Pick the smallest artifact in `family` that fits `n` elements
    /// (family = name prefix, e.g. "scan_warp_i32_").
    pub fn pick_size(&self, family: &str, n: usize) -> anyhow::Result<String> {
        self.manifest
            .family(family)
            .into_iter()
            .find(|s| s.inputs.first().map(|i| i.elements()).unwrap_or(0) >= n)
            .map(|s| s.name.clone())
            .ok_or_else(|| anyhow::anyhow!("no artifact in family '{family}' fits {n} elements"))
    }

    /// Largest artifact in `family` — callers chunk bigger inputs through
    /// it (elementwise kernels like the work op are chunk-safe).
    pub fn largest(&self, family: &str) -> anyhow::Result<String> {
        self.manifest
            .family(family)
            .into_iter()
            .last()
            .map(|s| s.name.clone())
            .ok_or_else(|| anyhow::anyhow!("no artifacts in family '{family}'"))
    }

    /// Smallest fitting artifact, or the largest one for chunked use.
    pub fn pick_or_largest(&self, family: &str, n: usize) -> anyhow::Result<String> {
        self.pick_size(family, n).or_else(|_| self.largest(family))
    }

    /// Pick the artifact size minimising modeled total execution cost for
    /// chunking `n` elements through it:
    /// `ceil(n/cap) × (EXEC_OVERHEAD + cap·PER_ELEM)`. A too-small size
    /// pays per-execution overhead; a too-big one pays zero-padding
    /// (perf pass: 60k elements through the 262144 artifact cost ~3 ms;
    /// through 16384 ~0.4 ms).
    pub fn pick_chunking(&self, family: &str, n: usize) -> anyhow::Result<String> {
        const EXEC_OVERHEAD_US: f64 = 40.0;
        const PER_ELEM_US: f64 = 0.004;
        let fam = self.manifest.family(family);
        anyhow::ensure!(!fam.is_empty(), "no artifacts in family '{family}'");
        let n = n.max(1);
        let best = fam
            .into_iter()
            .min_by(|a, b| {
                let cost = |s: &&ArtifactSpec| {
                    let cap = s.inputs.first().map(|i| i.elements()).unwrap_or(1).max(1);
                    let chunks = n.div_ceil(cap) as f64;
                    chunks * (EXEC_OVERHEAD_US + cap as f64 * PER_ELEM_US)
                };
                cost(a).partial_cmp(&cost(b)).unwrap()
            })
            .expect("non-empty");
        Ok(best.name.clone())
    }

    /// Run an artifact with mixed input dtypes (e.g. `insert_pack_f32_*`:
    /// i32 mask + f32 values → i32 offsets + f32 packed + i32 total).
    /// Inputs are zero-padded to the artifact shapes; outputs come back
    /// full-length (callers slice).
    pub fn run_mixed(&self, name: &str, inputs: &[ArgValue]) -> anyhow::Result<Vec<OutValue>> {
        let spec = self.spec_checked(name, inputs.len())?;
        let mut lits = Vec::with_capacity(inputs.len());
        for (k, (inp, ts)) in inputs.iter().zip(&spec.inputs).enumerate() {
            let dims: Vec<i64> = ts.shape.iter().map(|&d| d as i64).collect();
            let lit = match (inp, ts.dtype.as_str()) {
                (ArgValue::I32(v), "i32") => {
                    anyhow::ensure!(v.len() <= ts.elements(), "{name} input {k} too large");
                    let mut p = v.to_vec();
                    p.resize(ts.elements(), 0);
                    xla::Literal::vec1(&p).reshape(&dims)
                }
                (ArgValue::F32(v), "f32") => {
                    anyhow::ensure!(v.len() <= ts.elements(), "{name} input {k} too large");
                    let mut p = v.to_vec();
                    p.resize(ts.elements(), 0.0);
                    xla::Literal::vec1(&p).reshape(&dims)
                }
                (_, want) => anyhow::bail!("artifact {name} input {k}: dtype mismatch (artifact wants {want})"),
            }
            .map_err(|e| anyhow::anyhow!("reshape input {k} of {name}: {e}"))?;
            lits.push(lit);
        }
        let outs = self.run_literals(name, lits)?;
        outs.iter()
            .zip(&spec.outputs)
            .map(|(o, ts)| match ts.dtype.as_str() {
                "i32" => Ok(OutValue::I32(o.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{name}: {e}"))?)),
                "f32" => Ok(OutValue::F32(o.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{name}: {e}"))?)),
                other => anyhow::bail!("artifact {name}: unsupported output dtype {other}"),
            })
            .collect()
    }

    /// Exclusive prefix sum of `counts` via the AOT scan kernel family.
    /// Returns (offsets, total). The Pallas kernels compute an *inclusive*
    /// scan; exclusive = shift right by one.
    pub fn scan_offsets(&self, family: &str, counts: &[i32]) -> anyhow::Result<(Vec<i64>, i64)> {
        if counts.is_empty() {
            return Ok((vec![], 0));
        }
        let name = self.pick_size(family, counts.len())?;
        let incl = self.run_i32(&name, &[counts], counts.len())?.swap_remove(0);
        let total = *incl.last().expect("non-empty") as i64;
        let mut offsets = Vec::with_capacity(counts.len());
        offsets.push(0i64);
        offsets.extend(incl[..counts.len() - 1].iter().map(|&x| x as i64));
        Ok((offsets, total))
    }
}

#[cfg(test)]
mod tests {
    // Executor tests that need real artifacts live in
    // rust/tests/runtime_artifacts.rs and skip when `make artifacts`
    // hasn't run. Here: manifest-independent behaviour.
    use super::*;

    #[test]
    fn unknown_dir_fails() {
        assert!(Executor::new(Path::new("/definitely/not/here")).is_err());
    }

    #[test]
    fn executor_is_shareable_across_threads() {
        // The scheduler hands one Arc<Executor> to every worker; this
        // pins the auto-trait obligation that makes that legal (the
        // Rc-based PJRT executables live in thread-local caches, never
        // in the struct).
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Executor>();
        assert_send_sync::<std::sync::Arc<Executor>>();
    }

    #[test]
    fn distinct_executors_get_distinct_cache_keys() {
        let dir = std::env::temp_dir().join("ggarray_exec_id_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"version":1,"entries":{}}"#).unwrap();
        let a = Executor::new(&dir).unwrap();
        let b = Executor::new(&dir).unwrap();
        assert_ne!(a.id, b.id, "thread-local cache entries must never collide");
        assert_eq!(a.executions(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
