//! PJRT runtime: load AOT-compiled HLO-text artifacts (produced by
//! `python/compile/aot.py`) and execute them from the Rust hot path.
//! Python never runs at request time.

pub mod artifact;
pub mod client;
pub mod executor;

pub use artifact::{ArtifactManifest, ArtifactSpec};
pub use executor::{ArgValue, Executor, OutValue};
