//! Atomic-operation cost model.
//!
//! Same-address atomics (the paper's *atomic* insertion algorithm does one
//! `atomicAdd(&size, 1)` per inserting thread) serialise at the L2 atomic
//! unit. Modern compilers/hardware apply **warp aggregation** — one atomic
//! per warp plus lane offsets from a ballot — so the serialised op count is
//! `ceil(n / warp_size)`. Atomics spread over `k` distinct addresses (one
//! size counter per LFVector) proceed in parallel across addresses and
//! serialise only within each.

use super::spec::DeviceSpec;

/// Cost (µs) of `n_ops` atomic updates to a single address, with warp
/// aggregation if `aggregated`.
pub fn same_addr_atomic_us(spec: &DeviceSpec, n_ops: u64, aggregated: bool) -> f64 {
    let effective = if aggregated {
        crate::util::math::ceil_div(n_ops, spec.warp_size as u64)
    } else {
        n_ops
    };
    effective as f64 * spec.cost.atomic_same_addr_ns / 1e3
}

/// Cost (µs) of `n_ops` atomics uniformly spread over `n_addrs` distinct
/// addresses (e.g. one per LFVector): the critical path is the most
/// contended address; under a uniform spread that is `ceil(n/k)` ops.
pub fn multi_addr_atomic_us(spec: &DeviceSpec, n_ops: u64, n_addrs: u64, aggregated: bool) -> f64 {
    assert!(n_addrs > 0);
    let per_addr = crate::util::math::ceil_div(n_ops, n_addrs);
    same_addr_atomic_us(spec, per_addr, aggregated)
}

/// Cost (µs) of the worst-contended address given an explicit per-address
/// op distribution (used when routing is skewed).
pub fn skewed_atomic_us(spec: &DeviceSpec, ops_per_addr: &[u64], aggregated: bool) -> f64 {
    let max = ops_per_addr.iter().copied().max().unwrap_or(0);
    same_addr_atomic_us(spec, max, aggregated)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregation_divides_by_warp() {
        let spec = DeviceSpec::a100();
        let raw = same_addr_atomic_us(&spec, 3200, false);
        let agg = same_addr_atomic_us(&spec, 3200, true);
        assert!((raw / agg - 32.0).abs() < 1e-9);
    }

    #[test]
    fn multi_addr_parallelises() {
        let spec = DeviceSpec::a100();
        let one = multi_addr_atomic_us(&spec, 1_000_000, 1, true);
        let many = multi_addr_atomic_us(&spec, 1_000_000, 512, true);
        assert!(one / many > 400.0, "one={one} many={many}");
    }

    #[test]
    fn skew_dominates() {
        let spec = DeviceSpec::a100();
        let balanced = skewed_atomic_us(&spec, &[100, 100, 100], true);
        let skewed = skewed_atomic_us(&spec, &[10, 10, 280], true);
        assert!(skewed > balanced * 2.0);
    }

    #[test]
    fn atomic_insert_magnitude() {
        // 5.12e8 inserting threads on one counter, warp-aggregated:
        // 1.6e7 serialized atomics × 1.9 ns ≈ 30 ms — the "slowest"
        // insertion algorithm of Fig 4 at large n (scan ≈ 7–12 ms).
        let spec = DeviceSpec::a100();
        let ms = same_addr_atomic_us(&spec, 512_000_000, true) / 1e3;
        assert!(ms > 15.0 && ms < 60.0, "{ms} ms");
    }

    #[test]
    fn zero_ops_zero_cost() {
        let spec = DeviceSpec::titan_rtx();
        assert_eq!(same_addr_atomic_us(&spec, 0, true), 0.0);
        assert_eq!(skewed_atomic_us(&spec, &[], true), 0.0);
    }
}
