//! Thread-block-level cost primitives: `__syncthreads` barriers, the
//! warp-shuffle inclusive scan (`__shfl_up_sync`), and shared-memory
//! staging. These are the per-block building blocks the insertion
//! algorithms compose; costs are in µs of *per-block* critical path, which
//! `kernel::launch_blocks` then folds over the grid with SM-wave
//! scheduling.

use super::spec::DeviceSpec;

/// Cycles → µs on the base clock.
fn cycles_us(spec: &DeviceSpec, cycles: f64) -> f64 {
    cycles / spec.base_clock_mhz // cycles / (MHz) = µs
}

/// Cost of one `__syncthreads()` barrier for a block of `threads`.
/// Roughly 20–40 cycles plus a small per-warp convergence term.
pub fn barrier_us(spec: &DeviceSpec, threads: u32) -> f64 {
    let warps = crate::util::math::ceil_div(threads as u64, spec.warp_size as u64) as f64;
    cycles_us(spec, 24.0 + 2.0 * warps)
}

/// Critical-path cost of an intra-block inclusive scan of `threads`
/// elements via warp shuffles: log2(32) shuffle steps within each warp,
/// a shared-memory stage for warp totals, a scan of warp totals by the
/// first warp, and a broadcast add — the classic 3-phase block scan.
pub fn shfl_block_scan_us(spec: &DeviceSpec, threads: u32) -> f64 {
    let w = spec.warp_size as f64;
    let warps = crate::util::math::ceil_div(threads as u64, spec.warp_size as u64) as f64;
    // ~2 cycles per shuffle-add step.
    let warp_scan = 2.0 * w.log2().ceil();
    // Stage warp sums to smem + barrier + first-warp scan + barrier + add.
    let stage = 8.0 + 2.0 * warps.log2().max(1.0).ceil();
    cycles_us(spec, warp_scan + stage) + 2.0 * barrier_us(spec, threads)
}

/// Cost of one CAS attempt on a block-shared flag (bucket allocation
/// guard in `new_bucket`).
pub fn cas_us(spec: &DeviceSpec) -> f64 {
    // L2 round-trip, ~300 cycles.
    cycles_us(spec, 300.0)
}

/// Per-block cost of staging `bytes` through shared memory (one round
/// trip at ~128 B/cycle/SM).
pub fn smem_stage_us(spec: &DeviceSpec, bytes: u64) -> f64 {
    cycles_us(spec, bytes as f64 / 128.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn barrier_sub_microsecond() {
        let spec = DeviceSpec::a100();
        let b = barrier_us(&spec, 1024);
        assert!(b > 0.0 && b < 1.0, "{b}");
        // Bigger blocks pay slightly more.
        assert!(barrier_us(&spec, 1024) > barrier_us(&spec, 128));
    }

    #[test]
    fn block_scan_cost_reasonable() {
        let spec = DeviceSpec::a100();
        let s = shfl_block_scan_us(&spec, 1024);
        // Tens of cycles + 2 barriers ⇒ well under 1 µs, over 10 ns.
        assert!(s > 0.01 && s < 1.0, "{s}");
    }

    #[test]
    fn scan_grows_with_block_size() {
        let spec = DeviceSpec::titan_rtx();
        assert!(shfl_block_scan_us(&spec, 1024) > shfl_block_scan_us(&spec, 64));
    }

    #[test]
    fn cas_is_l2_roundtrip_scale() {
        let spec = DeviceSpec::a100();
        let c = cas_us(&spec);
        assert!(c > 0.1 && c < 1.0, "{c}"); // ~0.39 µs at 765 MHz
    }

    #[test]
    fn clock_speed_matters() {
        // TITAN RTX clocks higher → cheaper cycles.
        let a = cas_us(&DeviceSpec::a100());
        let t = cas_us(&DeviceSpec::titan_rtx());
        assert!(t < a);
    }
}
