//! Simulated time accounting.
//!
//! A [`Clock`] is a monotonically advancing microsecond counter plus a
//! per-category ledger, shared by every simulated component (heap, VMM,
//! kernels). Operations *advance* it by their modeled cost; experiments
//! read phase totals out of the ledger.

use std::collections::BTreeMap;

/// Cost categories charged by the simulated operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Category {
    /// Kernel launch overheads.
    Launch,
    /// DRAM traffic time.
    Memory,
    /// ALU/MXU compute time.
    Compute,
    /// Atomic serialisation.
    Atomic,
    /// Device allocator (`cudaMalloc`/`free`).
    Alloc,
    /// CUDA VMM operations (reserve/map/unmap).
    Vmm,
    /// Host synchronisation / host↔device transfers.
    Host,
}

impl Category {
    pub const ALL: [Category; 7] = [
        Category::Launch,
        Category::Memory,
        Category::Compute,
        Category::Atomic,
        Category::Alloc,
        Category::Vmm,
        Category::Host,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Category::Launch => "launch",
            Category::Memory => "memory",
            Category::Compute => "compute",
            Category::Atomic => "atomic",
            Category::Alloc => "alloc",
            Category::Vmm => "vmm",
            Category::Host => "host",
        }
    }
}

/// Simulated clock + cost ledger (microseconds).
#[derive(Debug, Clone, Default)]
pub struct Clock {
    now_us: f64,
    ledger: BTreeMap<Category, f64>,
}

impl Clock {
    pub fn new() -> Clock {
        Clock::default()
    }

    /// Current simulated time (µs since construction).
    pub fn now_us(&self) -> f64 {
        self.now_us
    }

    pub fn now_ms(&self) -> f64 {
        self.now_us / 1e3
    }

    /// Advance by `us`, charging `cat`.
    pub fn charge(&mut self, cat: Category, us: f64) {
        debug_assert!(us >= 0.0, "negative cost {us}");
        self.now_us += us;
        *self.ledger.entry(cat).or_insert(0.0) += us;
    }

    /// Total charged to a category.
    pub fn total(&self, cat: Category) -> f64 {
        self.ledger.get(&cat).copied().unwrap_or(0.0)
    }

    /// Snapshot of the ledger (µs per category).
    pub fn snapshot(&self) -> BTreeMap<Category, f64> {
        self.ledger.clone()
    }

    /// Time elapsed since a mark (µs).
    pub fn since(&self, mark_us: f64) -> f64 {
        self.now_us - mark_us
    }

    /// Reset time and ledger.
    pub fn reset(&mut self) {
        self.now_us = 0.0;
        self.ledger.clear();
    }

    /// Capture the full clock state (time + per-category totals) into a
    /// `Copy` mark. Paired with [`Clock::rewind`] this gives aborted
    /// operations a way to erase their pre-charged costs so the abort
    /// is byte-identical to the operation never running.
    pub fn mark(&self) -> ClockMark {
        let mut totals = [0.0; Category::ALL.len()];
        for (slot, cat) in totals.iter_mut().zip(Category::ALL) {
            *slot = self.total(cat);
        }
        ClockMark { now_us: self.now_us, totals }
    }

    /// Rewind to a previously captured mark, erasing every charge made
    /// since. Categories whose restored total is zero are removed from
    /// the ledger entirely, so [`Clock::snapshot`] compares equal to a
    /// clock that never charged them.
    pub fn rewind(&mut self, mark: ClockMark) {
        debug_assert!(self.now_us >= mark.now_us, "rewind to a future mark");
        self.now_us = mark.now_us;
        for (cat, &total) in Category::ALL.iter().zip(mark.totals.iter()) {
            if total == 0.0 {
                self.ledger.remove(cat);
            } else {
                self.ledger.insert(*cat, total);
            }
        }
    }
}

/// A `Copy` snapshot of the full clock state, for op-abort rollback.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClockMark {
    now_us: f64,
    totals: [f64; Category::ALL.len()],
}

impl Default for ClockMark {
    fn default() -> ClockMark {
        ClockMark { now_us: 0.0, totals: [0.0; Category::ALL.len()] }
    }
}

/// A scoped phase measurement: captures the clock at construction and
/// reports the delta. Used by experiment runners to attribute grow /
/// insert / r-w phases.
pub struct Phase {
    start_us: f64,
}

impl Phase {
    pub fn start(clock: &Clock) -> Phase {
        Phase { start_us: clock.now_us() }
    }

    pub fn elapsed_us(&self, clock: &Clock) -> f64 {
        clock.since(self.start_us)
    }

    pub fn elapsed_ms(&self, clock: &Clock) -> f64 {
        self.elapsed_us(clock) / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_accumulate() {
        let mut c = Clock::new();
        c.charge(Category::Memory, 10.0);
        c.charge(Category::Memory, 5.0);
        c.charge(Category::Launch, 4.0);
        assert_eq!(c.now_us(), 19.0);
        assert_eq!(c.total(Category::Memory), 15.0);
        assert_eq!(c.total(Category::Launch), 4.0);
        assert_eq!(c.total(Category::Vmm), 0.0);
    }

    #[test]
    fn phase_scoping() {
        let mut c = Clock::new();
        c.charge(Category::Alloc, 3.0);
        let p = Phase::start(&c);
        c.charge(Category::Memory, 7.0);
        c.charge(Category::Compute, 1.0);
        assert_eq!(p.elapsed_us(&c), 8.0);
        assert!((p.elapsed_ms(&c) - 0.008).abs() < 1e-12);
    }

    #[test]
    fn reset_clears() {
        let mut c = Clock::new();
        c.charge(Category::Host, 2.0);
        c.reset();
        assert_eq!(c.now_us(), 0.0);
        assert_eq!(c.total(Category::Host), 0.0);
    }

    #[test]
    fn mark_rewind_is_byte_identical() {
        let mut c = Clock::new();
        c.charge(Category::Memory, 10.0);
        let baseline = c.clone();
        let mark = c.mark();
        c.charge(Category::Memory, 7.0);
        c.charge(Category::Vmm, 3.0); // a category the baseline never charged
        c.rewind(mark);
        assert_eq!(c.now_us(), baseline.now_us());
        assert_eq!(c.snapshot(), baseline.snapshot());
        // The Vmm entry must be gone, not present-as-zero.
        assert!(!c.snapshot().contains_key(&Category::Vmm));
        // The clock stays usable after a rewind.
        c.charge(Category::Launch, 1.0);
        assert_eq!(c.now_us(), 11.0);
    }

    #[test]
    fn ms_conversion() {
        let mut c = Clock::new();
        c.charge(Category::Memory, 2500.0);
        assert!((c.now_ms() - 2.5).abs() < 1e-12);
    }
}
