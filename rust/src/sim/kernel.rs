//! Grid-level kernel cost model.
//!
//! A kernel is summarised by a [`KernelProfile`] — DRAM traffic, FP32 and
//! MXU/tensor FLOPs, per-block critical path, atomic serialisation — and
//! [`launch`] folds it over the device: SM-wave scheduling for the block
//! critical path, bandwidth occupancy for the memory time, and the usual
//! `max(memory, compute)` overlap for a well-pipelined kernel.

use super::clock::{Category, Clock};
use super::spec::DeviceSpec;

/// Cost description of one kernel launch.
#[derive(Debug, Clone)]
pub struct KernelProfile {
    /// Grid size in thread blocks.
    pub blocks: u64,
    /// Threads per block.
    pub threads_per_block: u32,
    /// Total DRAM traffic in bytes.
    pub bytes: f64,
    /// Fraction of peak bandwidth this traffic can use (coalescing /
    /// access-pattern efficiency), before grid-occupancy scaling.
    pub coalescing_eff: f64,
    /// CUDA-core FP32 work.
    pub flops_fp32: f64,
    /// Tensor-core / MXU FP16 work.
    pub flops_mxu: f64,
    /// Utilisation of the tensor path (paper: 1/8 of warps active for the
    /// tensor scan at a 1:1 data:thread ratio).
    pub mxu_utilisation: f64,
    /// Per-block critical-path time (barriers, intra-block scans) — paid
    /// once per *wave* of resident blocks, not per block.
    pub per_block_us: f64,
    /// Pre-computed atomic serialisation time (see `atomicmodel`).
    pub atomic_us: f64,
    /// Additional non-overlapped pipeline time (e.g. the MXU matmul stage
    /// of the tensor scan, which cannot hide behind the streaming traffic
    /// at a 1:1 data:thread ratio).
    pub extra_us: f64,
}

impl KernelProfile {
    /// A pure streaming kernel: `bytes` of traffic at `eff` efficiency.
    pub fn streaming(blocks: u64, threads_per_block: u32, bytes: f64, eff: f64) -> KernelProfile {
        KernelProfile {
            blocks,
            threads_per_block,
            bytes,
            coalescing_eff: eff,
            flops_fp32: 0.0,
            flops_mxu: 0.0,
            mxu_utilisation: 1.0,
            per_block_us: 0.0,
            atomic_us: 0.0,
            extra_us: 0.0,
        }
    }
}

/// Breakdown of a launch's modeled time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LaunchBreakdown {
    pub launch_us: f64,
    pub memory_us: f64,
    pub compute_us: f64,
    pub block_path_us: f64,
    pub atomic_us: f64,
    pub total_us: f64,
}

/// Model a launch without charging a clock.
pub fn model(spec: &DeviceSpec, p: &KernelProfile) -> LaunchBreakdown {
    assert!(p.blocks > 0, "kernel with zero blocks");
    let resident = (p.blocks).min(spec.max_resident_blocks(p.threads_per_block) as u64);
    // Bandwidth occupancy: a small resident grid cannot saturate DRAM.
    let occ_bw = spec.occupancy_frac(resident);
    let memory_us = if p.bytes > 0.0 {
        p.bytes / (spec.bw_bytes_per_us() * p.coalescing_eff.clamp(1e-6, 1.0) * occ_bw)
    } else {
        0.0
    };
    // Compute occupancy: fraction of the device's thread capacity in flight.
    let total_threads = (p.blocks * p.threads_per_block as u64) as f64;
    let capacity = (spec.sm_count * spec.max_threads_per_sm) as f64;
    let occ_cp = (total_threads / capacity).min(1.0).max(1e-6);
    let compute_us = p.flops_fp32 / (spec.fp32_flops_per_us() * occ_cp)
        + p.flops_mxu / (spec.fp16_flops_per_us() * p.mxu_utilisation.clamp(1e-6, 1.0) * occ_cp);
    // The per-block critical path is paid once per wave of resident blocks.
    let waves = crate::util::math::ceil_div(p.blocks, resident.max(1)) as f64;
    let block_path_us = waves * p.per_block_us;
    let total_us =
        spec.cost.kernel_launch_us + memory_us.max(compute_us) + block_path_us + p.atomic_us + p.extra_us;
    LaunchBreakdown {
        launch_us: spec.cost.kernel_launch_us,
        memory_us,
        compute_us,
        block_path_us: block_path_us + p.extra_us,
        atomic_us: p.atomic_us,
        total_us,
    }
}

/// Model a launch and charge it to `clock` by category. Returns the
/// breakdown.
pub fn launch(spec: &DeviceSpec, clock: &mut Clock, p: &KernelProfile) -> LaunchBreakdown {
    let b = model(spec, p);
    clock.charge(Category::Launch, b.launch_us);
    if b.memory_us >= b.compute_us {
        clock.charge(Category::Memory, b.memory_us);
    } else {
        clock.charge(Category::Compute, b.compute_us);
    }
    if b.block_path_us > 0.0 {
        clock.charge(Category::Compute, b.block_path_us);
    }
    if b.atomic_us > 0.0 {
        clock.charge(Category::Atomic, b.atomic_us);
    }
    b
}

/// Convenience: time (µs) for a fully-parallel streaming pass over `bytes`
/// at efficiency `eff` with a saturating grid.
pub fn streaming_us(spec: &DeviceSpec, bytes: f64, eff: f64) -> f64 {
    bytes / (spec.bw_bytes_per_us() * eff)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_rw_kernel_matches_table2() {
        // The paper's r/w op: +1, 30 times, on 1.024e9 u32 elements,
        // static array, A100 → 6.27 ms (Table II).
        let spec = DeviceSpec::a100();
        let n = 1.024e9;
        let p = KernelProfile {
            blocks: 1_000_000, // one thread per element, plenty of blocks
            threads_per_block: 1024,
            bytes: 2.0 * 4.0 * n,
            coalescing_eff: spec.cost.coalesced_eff,
            flops_fp32: 30.0 * n,
            flops_mxu: 0.0,
            mxu_utilisation: 1.0,
            per_block_us: 0.0,
            atomic_us: 0.0,
            extra_us: 0.0,
        };
        let b = model(&spec, &p);
        let ms = b.total_us / 1e3;
        assert!((ms - 6.27).abs() < 0.4, "modeled {ms:.2} ms vs 6.27 ms");
        // It must be memory-bound: 30 adds/elem ≪ bandwidth time.
        assert!(b.memory_us > b.compute_us);
    }

    #[test]
    fn occupancy_penalty_small_grids() {
        let spec = DeviceSpec::a100();
        let mk = |blocks| KernelProfile::streaming(blocks, 1024, 4e9, spec.cost.coalesced_eff);
        let t32 = model(&spec, &mk(32)).total_us;
        let t512 = model(&spec, &mk(512)).total_us;
        // 32 blocks can't saturate bandwidth: ~2.2× slower, as in the
        // paper's GGArray32-vs-512 insert gap.
        let ratio = t32 / t512;
        assert!(ratio > 1.8 && ratio < 3.0, "ratio {ratio}");
    }

    #[test]
    fn waves_multiply_block_path() {
        let spec = DeviceSpec::a100();
        let mut p = KernelProfile::streaming(216, 1024, 0.0, 1.0);
        p.per_block_us = 2.0;
        let one_wave = model(&spec, &p);
        p.blocks = 216 * 3;
        let three_waves = model(&spec, &p);
        assert!((one_wave.block_path_us - 2.0).abs() < 1e-9);
        assert!((three_waves.block_path_us - 6.0).abs() < 1e-9);
    }

    #[test]
    fn compute_bound_kernel_uses_flops() {
        let spec = DeviceSpec::a100();
        let p = KernelProfile {
            blocks: 10_000,
            threads_per_block: 1024,
            bytes: 1e6,
            coalescing_eff: 1.0,
            flops_fp32: 1e12, // 1 TFLOP on a 19.49 TFLOPS part ≈ 51 ms
            flops_mxu: 0.0,
            mxu_utilisation: 1.0,
            per_block_us: 0.0,
            atomic_us: 0.0,
            extra_us: 0.0,
        };
        let b = model(&spec, &p);
        assert!(b.compute_us > b.memory_us);
        assert!((b.compute_us / 1e3 - 51.3).abs() < 2.0, "{}", b.compute_us / 1e3);
    }

    #[test]
    fn launch_charges_categories() {
        let spec = DeviceSpec::a100();
        let mut clock = Clock::new();
        let mut p = KernelProfile::streaming(1000, 256, 1e9, 0.8);
        p.atomic_us = 5.0;
        p.per_block_us = 0.1;
        let b = launch(&spec, &mut clock, &p);
        assert!((clock.now_us() - b.total_us).abs() < 1e-9);
        assert_eq!(clock.total(Category::Atomic), 5.0);
        assert!(clock.total(Category::Memory) > 0.0);
        assert_eq!(clock.total(Category::Launch), spec.cost.kernel_launch_us);
    }

    #[test]
    fn mxu_path_respects_utilisation() {
        let spec = DeviceSpec::titan_rtx();
        let mk = |util| KernelProfile {
            blocks: 100_000,
            threads_per_block: 1024,
            bytes: 0.0,
            coalescing_eff: 1.0,
            flops_fp32: 0.0,
            flops_mxu: 1e12,
            mxu_utilisation: util,
            per_block_us: 0.0,
            atomic_us: 0.0,
            extra_us: 0.0,
        };
        let full = model(&spec, &mk(1.0)).compute_us;
        let eighth = model(&spec, &mk(0.125)).compute_us;
        assert!((eighth / full - 8.0).abs() < 1e-6);
    }
}
