//! Simulated VRAM heap (`cudaMalloc`-style allocator).
//!
//! Tracks capacity, live bytes and peak usage for the memory-efficiency
//! experiments (Fig 3), and charges allocation latency to the simulated
//! clock. Device-side allocations from concurrently-running blocks
//! serialise on the allocator — the effect the paper leans on when GGArray
//! with many LFVectors pays more for `grow` than with few (Table II:
//! GGArray512 grow 8.76 ms vs GGArray32 0.52 ms).

use super::clock::{Category, Clock};
use super::spec::DeviceSpec;
use std::collections::BTreeMap;

/// Opaque handle to a live allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct AllocId(u64);

/// Out-of-memory error carrying the shortfall.
#[derive(Debug)]
pub struct OomError {
    pub requested: u64,
    pub free: u64,
    pub capacity: u64,
}

impl std::fmt::Display for OomError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "simulated VRAM OOM: requested {} B, free {} B of {} B",
            self.requested, self.free, self.capacity
        )
    }
}

impl std::error::Error for OomError {}

/// The simulated device heap.
#[derive(Debug)]
pub struct VramHeap {
    spec: DeviceSpec,
    capacity: u64,
    used: u64,
    peak: u64,
    next_id: u64,
    allocs: BTreeMap<AllocId, u64>,
    alloc_calls: u64,
    free_calls: u64,
}

impl VramHeap {
    /// Heap sized to the device's full VRAM.
    pub fn new(spec: DeviceSpec) -> VramHeap {
        let capacity = spec.memory_bytes();
        VramHeap::with_capacity(spec, capacity)
    }

    /// Heap with an explicit capacity (used to emulate a VRAM budget).
    pub fn with_capacity(spec: DeviceSpec, capacity: u64) -> VramHeap {
        VramHeap {
            spec,
            capacity,
            used: 0,
            peak: 0,
            next_id: 1,
            allocs: BTreeMap::new(),
            alloc_calls: 0,
            free_calls: 0,
        }
    }

    /// Latency of a single allocation of `bytes`.
    fn alloc_cost_us(&self, bytes: u64) -> f64 {
        let mib = bytes as f64 / (1024.0 * 1024.0);
        self.spec.cost.malloc_base_us + self.spec.cost.malloc_per_mib_us * mib
    }

    /// Allocate `bytes`, charging the clock.
    pub fn alloc(&mut self, bytes: u64, clock: &mut Clock) -> Result<AllocId, OomError> {
        if self.used + bytes > self.capacity {
            return Err(OomError { requested: bytes, free: self.capacity - self.used, capacity: self.capacity });
        }
        clock.charge(Category::Alloc, self.alloc_cost_us(bytes));
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        let id = AllocId(self.next_id);
        self.next_id += 1;
        self.allocs.insert(id, bytes);
        self.alloc_calls += 1;
        Ok(id)
    }

    /// `count` device-side allocations issued by concurrently-running
    /// blocks: they serialise on the allocator lock, so the charged time is
    /// the *sum* of individual latencies.
    pub fn alloc_many(&mut self, sizes: &[u64], clock: &mut Clock) -> Result<Vec<AllocId>, OomError> {
        let total: u64 = sizes.iter().sum();
        if self.used + total > self.capacity {
            return Err(OomError { requested: total, free: self.capacity - self.used, capacity: self.capacity });
        }
        let mut ids = Vec::with_capacity(sizes.len());
        for &s in sizes {
            ids.push(self.alloc(s, clock).expect("checked capacity above"));
        }
        Ok(ids)
    }

    /// Move a live allocation into `dst`, a heap drawing on a different
    /// simulated budget (e.g. a shard heap → the epoch-owned sealed
    /// store). The backing bytes stay resident at the same device
    /// address — no `cudaMalloc`/`cudaFree` is issued and no latency is
    /// charged; only the accounting owner changes. Fails with `dst`'s
    /// shortfall — and leaves **both** heaps untouched — when `dst`
    /// lacks capacity, so callers can use it as the commit step of a
    /// reserve-then-commit transaction.
    pub fn transfer_to(&mut self, id: AllocId, dst: &mut VramHeap) -> Result<AllocId, OomError> {
        let bytes = *self.allocs.get(&id).expect("transfer of unknown AllocId");
        if dst.used + bytes > dst.capacity {
            return Err(OomError {
                requested: bytes,
                free: dst.capacity - dst.used,
                capacity: dst.capacity,
            });
        }
        self.allocs.remove(&id);
        self.used -= bytes;
        dst.used += bytes;
        dst.peak = dst.peak.max(dst.used);
        let new_id = AllocId(dst.next_id);
        dst.next_id += 1;
        dst.allocs.insert(new_id, bytes);
        Ok(new_id)
    }

    /// Free an allocation.
    pub fn free(&mut self, id: AllocId, clock: &mut Clock) {
        let bytes = self.allocs.remove(&id).expect("double free / unknown AllocId");
        self.used -= bytes;
        self.free_calls += 1;
        clock.charge(Category::Alloc, self.spec.cost.free_us);
    }

    /// Size of a live allocation.
    pub fn size_of(&self, id: AllocId) -> Option<u64> {
        self.allocs.get(&id).copied()
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn free_bytes(&self) -> u64 {
        self.capacity - self.used
    }

    pub fn live_allocations(&self) -> usize {
        self.allocs.len()
    }

    pub fn alloc_calls(&self) -> u64 {
        self.alloc_calls
    }

    pub fn free_calls(&self) -> u64 {
        self.free_calls
    }

    pub fn spec(&self) -> &DeviceSpec {
        &self.spec
    }

    /// Reset the peak-tracking watermark to current usage (used between
    /// experiment phases).
    pub fn reset_peak(&mut self) {
        self.peak = self.used;
    }

    /// Capture the observable heap counters into a `Copy` mark for
    /// op-abort rollback. `next_id` is deliberately *not* captured: it
    /// is internal allocator state invisible to every accessor, and
    /// never reusing ids keeps stale [`AllocId`]s detectably dead.
    pub fn mark(&self) -> HeapMark {
        HeapMark {
            used: self.used,
            peak: self.peak,
            alloc_calls: self.alloc_calls,
            free_calls: self.free_calls,
        }
    }

    /// Restore the counters captured by [`VramHeap::mark`]. The caller
    /// must already have freed every allocation made since the mark
    /// (the `allocs` map is keyed state that cannot be blindly reset);
    /// this then erases the alloc/free call traffic and the peak
    /// excursion so the abort is byte-identical to the op never
    /// running.
    pub fn restore_mark(&mut self, mark: HeapMark) {
        debug_assert_eq!(
            self.used, mark.used,
            "restore_mark with live bytes differing from the mark — free op allocations first"
        );
        self.used = mark.used;
        self.peak = mark.peak;
        self.alloc_calls = mark.alloc_calls;
        self.free_calls = mark.free_calls;
    }
}

/// A `Copy` snapshot of a heap's observable counters, for op-abort
/// rollback (see [`VramHeap::mark`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HeapMark {
    used: u64,
    peak: u64,
    alloc_calls: u64,
    free_calls: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn heap() -> (VramHeap, Clock) {
        (VramHeap::with_capacity(DeviceSpec::a100(), 1024 * 1024 * 1024), Clock::new())
    }

    #[test]
    fn alloc_free_roundtrip() {
        let (mut h, mut c) = heap();
        let id = h.alloc(1000, &mut c).unwrap();
        assert_eq!(h.used(), 1000);
        assert_eq!(h.size_of(id), Some(1000));
        assert_eq!(h.live_allocations(), 1);
        h.free(id, &mut c);
        assert_eq!(h.used(), 0);
        assert_eq!(h.live_allocations(), 0);
        assert_eq!(h.peak(), 1000);
        assert!(c.total(Category::Alloc) > 0.0);
    }

    #[test]
    fn oom_when_exceeding_capacity() {
        let (mut h, mut c) = heap();
        let cap = h.capacity();
        let _a = h.alloc(cap - 10, &mut c).unwrap();
        let err = h.alloc(11, &mut c).unwrap_err();
        assert_eq!(err.requested, 11);
        assert_eq!(err.free, 10);
        // Failed alloc must not charge time or mutate state.
        assert_eq!(h.used(), cap - 10);
    }

    #[test]
    fn peak_tracks_high_watermark() {
        let (mut h, mut c) = heap();
        let a = h.alloc(500, &mut c).unwrap();
        let b = h.alloc(300, &mut c).unwrap();
        h.free(a, &mut c);
        let _c2 = h.alloc(100, &mut c).unwrap();
        assert_eq!(h.peak(), 800);
        assert_eq!(h.used(), 400);
        h.free(b, &mut c);
        h.reset_peak();
        assert_eq!(h.peak(), h.used());
    }

    #[test]
    fn alloc_many_serialises_cost() {
        let (mut h, mut c) = heap();
        let sizes = vec![1024 * 1024; 8];
        let before = c.now_us();
        let ids = h.alloc_many(&sizes, &mut c).unwrap();
        assert_eq!(ids.len(), 8);
        let elapsed = c.now_us() - before;
        // 8 × (base 16.8 + 0.002/MiB) = 134.416 µs — strictly serialised.
        assert!((elapsed - 8.0 * 16.802).abs() < 1e-6, "elapsed {elapsed}");
    }

    #[test]
    fn alloc_many_all_or_nothing() {
        let (mut h, mut c) = heap();
        let cap = h.capacity();
        let err = h.alloc_many(&[cap / 2, cap / 2, cap / 2], &mut c).unwrap_err();
        assert_eq!(err.requested, cap / 2 * 3);
        assert_eq!(h.used(), 0);
        assert_eq!(h.live_allocations(), 0);
    }

    #[test]
    fn alloc_cost_mostly_size_independent() {
        // cudaMalloc latency is dominated by the allocator lock, not the
        // size (Table II back-calculation) — a 256 MiB allocation costs
        // only slightly more than a 1 KiB one.
        let (mut h, mut c) = heap();
        let t0 = c.now_us();
        h.alloc(1024, &mut c).unwrap();
        let small = c.now_us() - t0;
        let t1 = c.now_us();
        h.alloc(256 * 1024 * 1024, &mut c).unwrap();
        let big = c.now_us() - t1;
        assert!(big > small, "big {big} small {small}");
        assert!(big < small * 1.1, "big {big} small {small}");
    }

    #[test]
    fn transfer_moves_accounting_without_allocator_traffic() {
        let (mut src, mut c) = heap();
        let mut dst = VramHeap::with_capacity(DeviceSpec::a100(), 4096);
        let id = src.alloc(1000, &mut c).unwrap();
        let (allocs_before, frees_before) = (src.alloc_calls(), src.free_calls());
        let t_before = c.now_us();
        let new_id = src.transfer_to(id, &mut dst).unwrap();
        // Ownership moved: bytes left src, arrived in dst, same size.
        assert_eq!(src.used(), 0);
        assert_eq!(src.live_allocations(), 0);
        assert_eq!(dst.used(), 1000);
        assert_eq!(dst.peak(), 1000);
        assert_eq!(dst.size_of(new_id), Some(1000));
        assert_eq!(src.size_of(id), None, "old id is dead in the source heap");
        // No cudaMalloc/cudaFree and no latency: pure accounting.
        assert_eq!((src.alloc_calls(), src.free_calls()), (allocs_before, frees_before));
        assert_eq!(dst.alloc_calls(), 0);
        assert_eq!(c.now_us(), t_before);
        // The transferred allocation is freeable in its new heap.
        dst.free(new_id, &mut c);
        assert_eq!(dst.used(), 0);
    }

    #[test]
    fn transfer_oom_leaves_both_heaps_untouched() {
        let (mut src, mut c) = heap();
        let mut dst = VramHeap::with_capacity(DeviceSpec::a100(), 512);
        let resident = dst.alloc(300, &mut c).unwrap();
        let id = src.alloc(400, &mut c).unwrap();
        let err = src.transfer_to(id, &mut dst).unwrap_err();
        assert_eq!(err.requested, 400);
        assert_eq!(err.free, 212);
        assert_eq!(err.capacity, 512);
        // Abort is byte-identical on both sides.
        assert_eq!(src.used(), 400);
        assert_eq!(src.size_of(id), Some(400));
        assert_eq!(dst.used(), 300);
        assert_eq!(dst.size_of(resident), Some(300));
    }

    #[test]
    fn mark_restore_erases_op_traffic() {
        let (mut h, mut c) = heap();
        let keep = h.alloc(700, &mut c).unwrap();
        let mark = h.mark();
        // Simulated op: allocate, then abort by freeing and restoring.
        let a = h.alloc(500, &mut c).unwrap();
        let b = h.alloc(900, &mut c).unwrap();
        h.free(a, &mut c);
        h.free(b, &mut c);
        h.restore_mark(mark);
        assert_eq!(h.used(), 700);
        assert_eq!(h.peak(), 700);
        assert_eq!(h.alloc_calls(), 1);
        assert_eq!(h.free_calls(), 0);
        assert_eq!(h.size_of(keep), Some(700));
        // The heap stays usable, and stale op ids stay dead.
        let later = h.alloc(100, &mut c).unwrap();
        assert_ne!(later, a);
        assert_ne!(later, b);
        assert_eq!(h.used(), 800);
    }

    #[test]
    #[should_panic]
    fn double_free_panics() {
        let (mut h, mut c) = heap();
        let id = h.alloc(10, &mut c).unwrap();
        h.free(id, &mut c);
        h.free(id, &mut c);
    }
}
