//! GPU execution cost model — the testbed substrate.
//!
//! The paper evaluates on TITAN RTX and A100 GPUs; this environment has
//! neither, so per the substitution rule we reproduce the *performance
//! shape* on an analytic, event-accounted GPU model calibrated against
//! Table I of the paper plus published micro-benchmarks (kernel-launch
//! latency, `cudaMalloc` latency, CUDA VMM map cost, atomic throughput).
//! Every GGArray/baseline operation charges its cost to a [`clock::Clock`]
//! while performing the *real* data movement on host buffers, so numerics
//! are exact and timings are modeled.
//!
//! Cost model summary (see `DESIGN.md` §Hardware-Adaptation):
//!
//! * kernel time = `launch + max(compute, bytes / effective_bandwidth)`
//! * `effective_bandwidth = peak_bw × coalescing_eff × occupancy(blocks)`
//! * `occupancy(blocks) = min(1, blocks / bw_saturation_blocks)` — a small
//!   grid cannot saturate DRAM; this reproduces the paper's observation
//!   that GGArray with 32 LFVectors inserts ~2.4× slower than with 512.
//! * same-address atomics serialise at L2 (with warp aggregation).
//! * `cudaMalloc`-style allocations serialise on the device allocator.
//! * VMM page mapping costs a fixed latency per 2 MiB page, no copy.

pub mod atomicmodel;
pub mod block;
pub mod clock;
pub mod kernel;
pub mod memory;
pub mod spec;
pub mod suballoc;
pub mod trace;
pub mod vmm;

pub use clock::Clock;
pub use spec::DeviceSpec;
