//! Device specifications (paper Table I) plus the calibration constants of
//! the cost model.
//!
//! The two presets, [`DeviceSpec::titan_rtx`] and [`DeviceSpec::a100`],
//! carry the paper's Table I numbers directly (CUDA cores, tensor cores,
//! memory, FP16/FP32 TFLOPS, base clock). Derived quantities (SM count,
//! memory bandwidth) come from the public spec sheets of the same parts.
//! Latency constants are documented per field; `memory-model` unit tests
//! and `experiments::table2` validate that the calibrated model lands in
//! the neighbourhood of the paper's Table II.

/// Full device model used by the simulator.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    /// Marketing name ("TITAN RTX", "A100").
    pub name: &'static str,
    /// Table I: CUDA cores.
    pub cuda_cores: u32,
    /// Table I: tensor cores.
    pub tensor_cores: u32,
    /// Table I: device memory in GiB.
    pub memory_gib: u32,
    /// Table I: FP16 peak, TFLOPS.
    pub fp16_tflops: f64,
    /// Table I: FP32 peak, TFLOPS.
    pub fp32_tflops: f64,
    /// Table I: base clock, MHz.
    pub base_clock_mhz: f64,
    /// Streaming multiprocessors (spec sheet: 72 for TITAN RTX, 108 for A100).
    pub sm_count: u32,
    /// Peak DRAM bandwidth, GB/s (672 TITAN RTX, 1555 A100).
    pub mem_bw_gbps: f64,
    /// Threads per warp.
    pub warp_size: u32,
    /// Max resident threads per SM (1024 Turing, 2048 Ampere).
    pub max_threads_per_sm: u32,
    /// Cost-model calibration constants.
    pub cost: CostParams,
}

/// Calibration constants for the analytic cost model. All latencies in
/// microseconds unless noted. Sources: paper Table II back-calculation +
/// published microbenchmarks (see DESIGN.md §8).
#[derive(Debug, Clone, PartialEq)]
pub struct CostParams {
    /// Kernel launch latency (µs).
    pub kernel_launch_us: f64,
    /// `cudaMalloc`/device-heap allocation base latency (µs). Allocations
    /// serialise on the device allocator lock. Back-calculated from
    /// Table II: GGArray512 grow = 8.76 ms / 512 buckets ≈ 17 µs;
    /// GGArray32 grow = 0.52 ms / 32 ≈ 16 µs — size-independent.
    pub malloc_base_us: f64,
    /// Extra allocation latency per MiB requested (µs/MiB) — page-table
    /// population; nearly free on current drivers until the multi-GiB
    /// range.
    pub malloc_per_mib_us: f64,
    /// `cudaFree` latency (µs).
    pub free_us: f64,
    /// CUDA VMM: `cuMemAddressReserve` per call (µs).
    pub vmm_reserve_us: f64,
    /// CUDA VMM: `cuMemCreate`+`cuMemMap`+`cuMemSetAccess` per 2 MiB page (µs).
    /// Back-calculated from Table II: 5.21 ms to map 1024 pages ⇒ ~5.1 µs.
    pub vmm_map_page_us: f64,
    /// CUDA VMM: unmap+release per page (µs).
    pub vmm_unmap_page_us: f64,
    /// VMM page granularity (bytes) — 2 MiB on current CUDA.
    pub vmm_page_bytes: u64,
    /// Same-address atomic update throughput at L2, ns per (warp-aggregated)
    /// atomic.
    pub atomic_same_addr_ns: f64,
    /// Fraction of peak DRAM bandwidth achieved by fully-coalesced
    /// streaming kernels (static-array r/w lands ~84% per Table II).
    pub coalesced_eff: f64,
    /// Fraction of peak bandwidth for GGArray block-structured access
    /// (`rw_b`): bucket-pointer indirection + intra-bucket strides.
    /// Table II: 69.73 ms vs 6.27 ms static ⇒ ~9% of coalesced.
    pub ggarray_block_eff: f64,
    /// Write-side efficiency of GGArray insertions (writes land
    /// contiguously inside each block's current bucket, so they are far
    /// better than rw_b's scattered access). Back-calculated from
    /// Table II: GGArray512 insert 11.79 ms vs static 7.07 ms.
    pub ggarray_insert_eff: f64,
    /// Serial per-1024-element-chunk overhead of an rw_b pass (bucket
    /// locate + pointer chase at L2/DRAM latency), µs.
    pub rw_chunk_overhead_us: f64,
    /// Fraction of peak bandwidth for global-index access (`rw_g`):
    /// binary search over the prefix index per element dominates.
    pub ggarray_global_eff: f64,
    /// Number of resident blocks needed to saturate DRAM bandwidth,
    /// expressed as a fraction of `sm_count` (memory-bound kernels saturate
    /// with ~0.65 blocks/SM of 1024 threads).
    pub bw_saturation_blocks_per_sm: f64,
    /// Effective MXU/tensor-core utilisation for the matmul scan when the
    /// data:thread ratio is 1:1 — the paper measures one eighth of warps
    /// active.
    pub tensor_scan_utilisation: f64,
    /// Host↔device copy bandwidth (GB/s, PCIe/NVLink effective) for
    /// semi-static resize staging.
    pub h2d_bw_gbps: f64,
    /// Host synchronisation round-trip (µs) — the cost of using the host
    /// as a barrier (semi-static resize path).
    pub host_sync_us: f64,
}

impl DeviceSpec {
    /// Paper Table I, column "TITAN RTX" (Turing TU102).
    pub fn titan_rtx() -> DeviceSpec {
        DeviceSpec {
            name: "TITAN RTX",
            cuda_cores: 4608,
            tensor_cores: 576,
            memory_gib: 24,
            fp16_tflops: 32.62,
            fp32_tflops: 16.31,
            base_clock_mhz: 1350.0,
            sm_count: 72,
            mem_bw_gbps: 672.0,
            warp_size: 32,
            max_threads_per_sm: 1024,
            cost: CostParams::default_for_turing(),
        }
    }

    /// Paper Table I, column "A100" (Ampere GA100, 40 GB).
    pub fn a100() -> DeviceSpec {
        DeviceSpec {
            name: "A100",
            cuda_cores: 6912,
            tensor_cores: 432,
            memory_gib: 40,
            fp16_tflops: 77.97,
            fp32_tflops: 19.49,
            base_clock_mhz: 765.0,
            sm_count: 108,
            mem_bw_gbps: 1555.0,
            warp_size: 32,
            max_threads_per_sm: 2048,
            cost: CostParams::default_for_ampere(),
        }
    }

    /// Look a preset up by CLI name.
    pub fn by_name(name: &str) -> Option<DeviceSpec> {
        match name.to_ascii_lowercase().as_str() {
            "titan" | "titan_rtx" | "titanrtx" | "titan-rtx" => Some(Self::titan_rtx()),
            "a100" => Some(Self::a100()),
            _ => None,
        }
    }

    /// Total VRAM in bytes.
    pub fn memory_bytes(&self) -> u64 {
        self.memory_gib as u64 * 1024 * 1024 * 1024
    }

    /// Peak bandwidth in bytes/µs.
    pub fn bw_bytes_per_us(&self) -> f64 {
        // GB/s = 1e9 B / 1e6 µs = 1e3 B/µs
        self.mem_bw_gbps * 1e3
    }

    /// FP32 peak in FLOP/µs.
    pub fn fp32_flops_per_us(&self) -> f64 {
        self.fp32_tflops * 1e6
    }

    /// FP16 (tensor path) peak in FLOP/µs.
    pub fn fp16_flops_per_us(&self) -> f64 {
        self.fp16_tflops * 1e6
    }

    /// Max concurrently-resident thread blocks for a given block size.
    pub fn max_resident_blocks(&self, block_threads: u32) -> u32 {
        let per_sm = (self.max_threads_per_sm / block_threads.max(1)).max(1);
        // Hardware also caps resident blocks/SM (16 Turing, 32 Ampere);
        // with our 256–1024-thread blocks the threads limit binds first.
        self.sm_count * per_sm
    }

    /// Number of resident blocks that saturates DRAM bandwidth.
    pub fn bw_saturation_blocks(&self) -> f64 {
        (self.sm_count as f64 * self.cost.bw_saturation_blocks_per_sm).max(1.0)
    }

    /// Bandwidth occupancy factor for a kernel run with `blocks` blocks:
    /// fraction of peak DRAM bandwidth reachable.
    pub fn occupancy_frac(&self, blocks: u64) -> f64 {
        ((blocks as f64) / self.bw_saturation_blocks()).min(1.0)
    }
}

impl CostParams {
    /// Turing-generation constants.
    pub fn default_for_turing() -> CostParams {
        CostParams {
            kernel_launch_us: 4.0,
            malloc_base_us: 16.0,
            malloc_per_mib_us: 0.004,
            free_us: 6.0,
            vmm_reserve_us: 25.0,
            vmm_map_page_us: 6.5,
            vmm_unmap_page_us: 4.0,
            vmm_page_bytes: 2 * 1024 * 1024,
            atomic_same_addr_ns: 2.4,
            coalesced_eff: 0.82,
            ggarray_block_eff: 0.075,
            ggarray_insert_eff: 0.30,
            rw_chunk_overhead_us: 0.40,
            ggarray_global_eff: 0.022,
            bw_saturation_blocks_per_sm: 0.65,
            tensor_scan_utilisation: 1.0 / 8.0,
            h2d_bw_gbps: 12.0,
            host_sync_us: 9.0,
        }
    }

    /// Ampere-generation constants. Calibrated against Table II
    /// (A100 column) — see `experiments::table2` tests.
    pub fn default_for_ampere() -> CostParams {
        CostParams {
            kernel_launch_us: 3.5,
            malloc_base_us: 16.8,
            malloc_per_mib_us: 0.002,
            free_us: 5.0,
            vmm_reserve_us: 20.0,
            vmm_map_page_us: 5.1,
            vmm_unmap_page_us: 3.5,
            vmm_page_bytes: 2 * 1024 * 1024,
            atomic_same_addr_ns: 1.9,
            coalesced_eff: 0.84,
            ggarray_block_eff: 0.076,
            ggarray_insert_eff: 0.31,
            rw_chunk_overhead_us: 0.35,
            ggarray_global_eff: 0.024,
            bw_saturation_blocks_per_sm: 0.65,
            tensor_scan_utilisation: 1.0 / 8.0,
            h2d_bw_gbps: 22.0,
            host_sync_us: 7.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_table1() {
        let t = DeviceSpec::titan_rtx();
        assert_eq!(t.cuda_cores, 4608);
        assert_eq!(t.tensor_cores, 576);
        assert_eq!(t.memory_gib, 24);
        assert!((t.fp16_tflops - 32.62).abs() < 1e-9);
        assert!((t.fp32_tflops - 16.31).abs() < 1e-9);
        assert!((t.base_clock_mhz - 1350.0).abs() < 1e-9);

        let a = DeviceSpec::a100();
        assert_eq!(a.cuda_cores, 6912);
        assert_eq!(a.tensor_cores, 432);
        assert_eq!(a.memory_gib, 40);
        assert!((a.fp16_tflops - 77.97).abs() < 1e-9);
        assert!((a.fp32_tflops - 19.49).abs() < 1e-9);
        assert!((a.base_clock_mhz - 765.0).abs() < 1e-9);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(DeviceSpec::by_name("a100").unwrap().name, "A100");
        assert_eq!(DeviceSpec::by_name("TITAN").unwrap().name, "TITAN RTX");
        assert_eq!(DeviceSpec::by_name("titan-rtx").unwrap().name, "TITAN RTX");
        assert!(DeviceSpec::by_name("h100").is_none());
    }

    #[test]
    fn derived_quantities() {
        let a = DeviceSpec::a100();
        assert_eq!(a.memory_bytes(), 40 * (1u64 << 30));
        assert!((a.bw_bytes_per_us() - 1.555e6).abs() < 1.0);
        // 2048 threads/SM with 1024-thread blocks → 2 blocks/SM → 216.
        assert_eq!(a.max_resident_blocks(1024), 216);
        assert_eq!(a.max_resident_blocks(256), 864);
    }

    #[test]
    fn occupancy_shape() {
        let a = DeviceSpec::a100();
        assert!((a.occupancy_frac(10_000) - 1.0).abs() < 1e-12);
        let at32 = a.occupancy_frac(32);
        let at512 = a.occupancy_frac(512);
        assert!(at32 < at512);
        assert!(at512 == 1.0);
        // ~32/70.2 ≈ 0.456: the paper's GGArray32-vs-512 insert gap.
        assert!((at32 - 0.456).abs() < 0.01, "{at32}");
    }

    #[test]
    fn static_rw_lands_near_table2() {
        // Table II: static read/write of 1.024e9 × u32 on A100 = 6.27 ms.
        // Model: 2 passes (read+write) at coalesced efficiency.
        let a = DeviceSpec::a100();
        let bytes = 2.0 * 4.0 * 1.024e9;
        let us = bytes / (a.bw_bytes_per_us() * a.cost.coalesced_eff);
        let ms = us / 1e3;
        assert!((ms - 6.27).abs() < 0.35, "modeled {ms:.2} ms vs paper 6.27 ms");
    }

    #[test]
    fn memmap_grow_lands_near_table2() {
        // Table II: memMap grow (map 2.048 GB = 1024 pages) = 5.21 ms.
        let a = DeviceSpec::a100();
        let pages = 2.048e9 / a.cost.vmm_page_bytes as f64;
        let ms = pages * a.cost.vmm_map_page_us / 1e3;
        assert!((ms - 5.21).abs() < 0.3, "modeled {ms:.2} ms vs paper 5.21 ms");
    }
}
