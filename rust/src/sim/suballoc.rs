//! Device-side buddy sub-allocator.
//!
//! The paper's §II.D surveys GPU dynamic memory managers (XMalloc,
//! ScatterAlloc, Ouroboros; Winter et al. 2021 benchmarks) as "potential
//! tools that can complement" GGArray: device `malloc` is slow because
//! every allocation takes the global driver path. A sub-allocator grabs
//! large slabs once and serves bucket-sized requests from a buddy tree,
//! turning GGArray's grow phase from B driver calls into B cheap
//! device-side splits.
//!
//! Implemented as a classic power-of-two buddy system over slabs obtained
//! from [`VramHeap`]; used by the A5 ablation (`experiments::ablations`)
//! to quantify the grow-phase saving.

use super::clock::{Category, Clock};
use super::memory::{AllocId, OomError, VramHeap};
use std::collections::BTreeSet;

/// Cost of a device-side buddy split/coalesce step (µs) — a few atomic
/// CAS operations on the free bitmap, ~100 cycles at 1 GHz.
const BUDDY_OP_US: f64 = 0.1;

/// One slab: a contiguous VramHeap allocation managed as a buddy tree.
#[derive(Debug)]
struct Slab {
    #[allow(dead_code)]
    backing: AllocId,
    /// Free blocks per order: `free[k]` holds offsets of free blocks of
    /// size `min_block << k`.
    free: Vec<BTreeSet<u64>>,
}

/// Handle to a sub-allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubAlloc {
    pub slab: usize,
    pub offset: u64,
    pub order: u32,
}

/// Buddy allocator over device slabs.
#[derive(Debug)]
pub struct BuddyAllocator {
    slab_bytes: u64,
    min_block: u64,
    max_order: u32,
    slabs: Vec<Slab>,
    live: u64,
    /// Stats.
    slab_allocs: u64,
    buddy_ops: u64,
}

impl BuddyAllocator {
    /// `slab_bytes` and `min_block` must be powers of two, slab ≥ min.
    pub fn new(slab_bytes: u64, min_block: u64) -> BuddyAllocator {
        assert!(slab_bytes.is_power_of_two() && min_block.is_power_of_two());
        assert!(slab_bytes >= min_block);
        let max_order = (slab_bytes / min_block).trailing_zeros();
        BuddyAllocator {
            slab_bytes,
            min_block,
            max_order,
            slabs: Vec::new(),
            live: 0,
            slab_allocs: 0,
            buddy_ops: 0,
        }
    }

    fn order_for(&self, bytes: u64) -> u32 {
        let blocks = crate::util::math::ceil_div(bytes.max(1), self.min_block);
        crate::util::math::next_pow2(blocks).trailing_zeros()
    }

    /// Bytes actually reserved for a request of `bytes`.
    pub fn block_size(&self, bytes: u64) -> u64 {
        self.min_block << self.order_for(bytes)
    }

    /// Allocate `bytes` (rounded to a buddy block). Grabs a new slab from
    /// the heap when no free block fits — that is the only driver-path
    /// (expensive) operation.
    pub fn alloc(&mut self, bytes: u64, heap: &mut VramHeap, clock: &mut Clock) -> Result<SubAlloc, OomError> {
        let order = self.order_for(bytes);
        assert!(
            order <= self.max_order,
            "request {bytes} B exceeds slab size {} B",
            self.slab_bytes
        );
        // Find a slab with a free block of order ≥ requested.
        for slab_idx in 0..self.slabs.len() {
            if let Some(sub) = self.try_alloc_in(slab_idx, order, clock) {
                self.live += self.min_block << order;
                return Ok(sub);
            }
        }
        // Driver path: new slab.
        let backing = heap.alloc(self.slab_bytes, clock)?;
        self.slab_allocs += 1;
        let mut free = vec![BTreeSet::new(); self.max_order as usize + 1];
        free[self.max_order as usize].insert(0);
        self.slabs.push(Slab { backing, free });
        let idx = self.slabs.len() - 1;
        let sub = self.try_alloc_in(idx, order, clock).expect("fresh slab must satisfy");
        self.live += self.min_block << order;
        Ok(sub)
    }

    fn try_alloc_in(&mut self, slab_idx: usize, order: u32, clock: &mut Clock) -> Option<SubAlloc> {
        let slab = &mut self.slabs[slab_idx];
        // Find the smallest free order ≥ requested.
        let mut k = order;
        while k <= self.max_order && slab.free[k as usize].is_empty() {
            k += 1;
        }
        if k > self.max_order {
            return None;
        }
        // Pop and split down to the requested order.
        let offset = *slab.free[k as usize].iter().next().unwrap();
        slab.free[k as usize].remove(&offset);
        // Split down to the requested order; the allocation keeps the
        // left child, each right buddy goes on its free list.
        while k > order {
            k -= 1;
            let buddy = offset + (self.min_block << k);
            slab.free[k as usize].insert(buddy);
            self.buddy_ops += 1;
            clock.charge(Category::Alloc, BUDDY_OP_US);
        }
        Some(SubAlloc { slab: slab_idx, offset, order })
    }

    /// Free a sub-allocation, coalescing buddies.
    pub fn free(&mut self, sub: SubAlloc, clock: &mut Clock) {
        let slab = &mut self.slabs[sub.slab];
        self.live -= self.min_block << sub.order;
        let mut order = sub.order;
        let mut offset = sub.offset;
        loop {
            let size = self.min_block << order;
            let buddy = offset ^ size;
            if order < self.max_order && slab.free[order as usize].remove(&buddy) {
                // Coalesce with the buddy and continue up.
                offset = offset.min(buddy);
                order += 1;
                self.buddy_ops += 1;
                clock.charge(Category::Alloc, BUDDY_OP_US);
            } else {
                slab.free[order as usize].insert(offset);
                break;
            }
        }
    }

    /// Bytes held in slabs (driver-visible footprint).
    pub fn slab_bytes_total(&self) -> u64 {
        self.slabs.len() as u64 * self.slab_bytes
    }

    /// Live sub-allocated bytes.
    pub fn live_bytes(&self) -> u64 {
        self.live
    }

    /// Internal fragmentation of the buddy policy for a request size.
    pub fn internal_frag(&self, bytes: u64) -> f64 {
        self.block_size(bytes) as f64 / bytes.max(1) as f64
    }

    pub fn slab_allocs(&self) -> u64 {
        self.slab_allocs
    }

    pub fn buddy_ops(&self) -> u64 {
        self.buddy_ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::spec::DeviceSpec;

    fn setup() -> (BuddyAllocator, VramHeap, Clock) {
        (
            BuddyAllocator::new(1 << 20, 1 << 10), // 1 MiB slabs, 1 KiB min
            VramHeap::with_capacity(DeviceSpec::a100(), 1 << 30),
            Clock::new(),
        )
    }

    #[test]
    fn alloc_rounds_to_buddy_blocks() {
        let (b, _, _) = setup();
        assert_eq!(b.block_size(1), 1024);
        assert_eq!(b.block_size(1024), 1024);
        assert_eq!(b.block_size(1025), 2048);
        assert_eq!(b.block_size(3000), 4096);
        assert_eq!(b.block_size(1 << 20), 1 << 20);
    }

    #[test]
    fn one_slab_serves_many_buckets() {
        let (mut b, mut heap, mut clock) = setup();
        // 256 × 4 KiB buckets = 1 MiB: exactly one driver allocation.
        let subs: Vec<SubAlloc> = (0..256).map(|_| b.alloc(4096, &mut heap, &mut clock).unwrap()).collect();
        assert_eq!(b.slab_allocs(), 1);
        assert_eq!(heap.alloc_calls(), 1);
        assert_eq!(b.live_bytes(), 1 << 20);
        // All offsets distinct and within the slab.
        let mut offsets: Vec<u64> = subs.iter().map(|s| s.offset).collect();
        offsets.sort_unstable();
        offsets.dedup();
        assert_eq!(offsets.len(), 256);
        assert!(offsets.iter().all(|&o| o < (1 << 20)));
        // One more triggers slab #2.
        b.alloc(4096, &mut heap, &mut clock).unwrap();
        assert_eq!(b.slab_allocs(), 2);
    }

    #[test]
    fn free_coalesces_back_to_full_slab() {
        let (mut b, mut heap, mut clock) = setup();
        let subs: Vec<SubAlloc> = (0..16).map(|_| b.alloc(64 * 1024, &mut heap, &mut clock).unwrap()).collect();
        assert_eq!(b.live_bytes(), 1 << 20);
        for s in subs {
            b.free(s, &mut clock);
        }
        assert_eq!(b.live_bytes(), 0);
        // Fully coalesced: a max-order alloc fits again without a new slab.
        let before = b.slab_allocs();
        let big = b.alloc(1 << 20, &mut heap, &mut clock).unwrap();
        assert_eq!(b.slab_allocs(), before);
        assert_eq!(big.order, 10); // 1 MiB / 1 KiB = 2^10
    }

    #[test]
    fn mixed_sizes_no_overlap() {
        let (mut b, mut heap, mut clock) = setup();
        let mut live: Vec<(SubAlloc, u64)> = Vec::new();
        let mut rng = crate::util::rng::Rng::new(31);
        for step in 0..2000 {
            if live.is_empty() || rng.bernoulli(0.6) {
                let bytes = 1u64 << rng.range(0, 15); // 1 B … 16 KiB
                let sub = b.alloc(bytes, &mut heap, &mut clock).unwrap();
                let size = b.block_size(bytes);
                // Overlap check against all live blocks in the same slab.
                for (other, osize) in &live {
                    if other.slab == sub.slab {
                        let a0 = sub.offset;
                        let a1 = sub.offset + size;
                        let b0 = other.offset;
                        let b1 = other.offset + osize;
                        assert!(a1 <= b0 || b1 <= a0, "overlap at step {step}: {sub:?} vs {other:?}");
                    }
                }
                live.push((sub, size));
            } else {
                let k = rng.below(live.len() as u64) as usize;
                let (sub, _) = live.swap_remove(k);
                b.free(sub, &mut clock);
            }
        }
        // Accounting holds.
        let expect: u64 = live.iter().map(|(_, s)| s).sum();
        assert_eq!(b.live_bytes(), expect);
    }

    #[test]
    fn grow_phase_cheaper_than_driver_mallocs() {
        // The §II.D argument quantified: 512 bucket allocations through
        // the buddy vs 512 driver mallocs.
        let spec = DeviceSpec::a100();
        let (mut b, mut heap, mut clock) = (
            BuddyAllocator::new(1 << 26, 1 << 12), // 64 MiB slabs
            VramHeap::with_capacity(spec.clone(), 1 << 32),
            Clock::new(),
        );
        let t0 = clock.now_us();
        for _ in 0..512 {
            b.alloc(128 * 1024, &mut heap, &mut clock).unwrap(); // 128 KiB buckets
        }
        let buddy_us = clock.now_us() - t0;
        let mut heap2 = VramHeap::with_capacity(spec, 1 << 32);
        let mut clock2 = Clock::new();
        for _ in 0..512 {
            heap2.alloc(128 * 1024, &mut clock2).unwrap();
        }
        let driver_us = clock2.now_us();
        assert!(
            buddy_us < driver_us / 3.0,
            "buddy {buddy_us:.1} µs should be ≪ driver {driver_us:.1} µs"
        );
    }
}
