//! Event trace: an optional, bounded record of simulated operations used
//! by tests, debugging, and the `--trace` CLI flag. Each event carries the
//! simulated start time, duration, a label, and the cost category.

use super::clock::Category;

/// One recorded simulated event.
#[derive(Debug, Clone)]
pub struct Event {
    pub t_start_us: f64,
    pub dur_us: f64,
    pub category: Category,
    pub label: String,
}

/// Bounded event recorder. Disabled by default (zero overhead beyond a
/// branch); enable with [`Trace::enabled`].
#[derive(Debug, Default)]
pub struct Trace {
    events: Vec<Event>,
    enabled: bool,
    capacity: usize,
    dropped: u64,
}

impl Trace {
    /// A disabled trace (records nothing).
    pub fn disabled() -> Trace {
        Trace { events: Vec::new(), enabled: false, capacity: 0, dropped: 0 }
    }

    /// An enabled trace bounded to `capacity` events; further events are
    /// counted in [`Trace::dropped`] instead of stored.
    pub fn enabled(capacity: usize) -> Trace {
        Trace { events: Vec::with_capacity(capacity.min(4096)), enabled: true, capacity, dropped: 0 }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Record an event (no-op when disabled).
    pub fn record(&mut self, t_start_us: f64, dur_us: f64, category: Category, label: impl Into<String>) {
        if !self.enabled {
            return;
        }
        if self.events.len() >= self.capacity {
            self.dropped += 1;
            return;
        }
        self.events.push(Event { t_start_us, dur_us, category, label: label.into() });
    }

    pub fn events(&self) -> &[Event] {
        &self.events
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Total recorded duration per category label (for summaries).
    pub fn total_for(&self, category: Category) -> f64 {
        self.events.iter().filter(|e| e.category == category).map(|e| e.dur_us).sum()
    }

    /// Render a compact text timeline (first `n` events).
    pub fn render(&self, n: usize) -> String {
        let mut out = String::new();
        for e in self.events.iter().take(n) {
            out.push_str(&format!(
                "{:>12.3}µs  +{:<10.3}  {:<7}  {}\n",
                e.t_start_us,
                e.dur_us,
                e.category.name(),
                e.label
            ));
        }
        if self.events.len() > n {
            out.push_str(&format!("… {} more events\n", self.events.len() - n));
        }
        if self.dropped > 0 {
            out.push_str(&format!("… {} events dropped (capacity {})\n", self.dropped, self.capacity));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing() {
        let mut t = Trace::disabled();
        t.record(0.0, 1.0, Category::Memory, "x");
        assert!(t.events().is_empty());
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn bounded_capacity() {
        let mut t = Trace::enabled(2);
        for i in 0..5 {
            t.record(i as f64, 1.0, Category::Alloc, format!("e{i}"));
        }
        assert_eq!(t.events().len(), 2);
        assert_eq!(t.dropped(), 3);
        assert!(t.render(10).contains("dropped"));
    }

    #[test]
    fn totals_by_category() {
        let mut t = Trace::enabled(10);
        t.record(0.0, 2.0, Category::Memory, "a");
        t.record(2.0, 3.0, Category::Memory, "b");
        t.record(5.0, 1.0, Category::Launch, "c");
        assert_eq!(t.total_for(Category::Memory), 5.0);
        assert_eq!(t.total_for(Category::Launch), 1.0);
        assert_eq!(t.total_for(Category::Vmm), 0.0);
    }

    #[test]
    fn render_truncates() {
        let mut t = Trace::enabled(10);
        for i in 0..4 {
            t.record(i as f64, 0.5, Category::Compute, format!("k{i}"));
        }
        let s = t.render(2);
        assert!(s.contains("k0") && s.contains("k1"));
        assert!(!s.contains("k3"));
        assert!(s.contains("2 more"));
    }
}
