//! Simulated CUDA low-level virtual memory management API
//! (`cuMemAddressReserve` / `cuMemCreate` / `cuMemMap` / `cuMemSetAccess`),
//! the substrate behind the paper's **memMap** semi-static baseline
//! (Perry & Sakharnykh 2020).
//!
//! Semantics reproduced:
//! * a large **virtual address range** is reserved once, cheaply;
//! * **physical pages** (2 MiB granularity) are created+mapped on demand —
//!   growing never copies data, indexing stays contiguous in VA space;
//! * memory is consumed in whole pages → *page slack* fragmentation;
//! * map/unmap cost a per-page latency charged to the simulated clock.

use super::clock::{Category, Clock};
use super::spec::DeviceSpec;

/// Error from VMM operations.
#[derive(Debug)]
pub enum VmmError {
    ReservationExhausted { need: u64, reserved: u64 },
    PhysicalExhausted { need: u64, available: u64 },
    BadShrink { mapped: u64, target: u64 },
}

impl std::fmt::Display for VmmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            VmmError::ReservationExhausted { need, reserved } => {
                write!(f, "VA reservation exhausted: need {need} B mapped, reserved {reserved} B")
            }
            VmmError::PhysicalExhausted { need, available } => {
                write!(f, "physical memory exhausted: need {need} pages, available {available}")
            }
            VmmError::BadShrink { mapped, target } => {
                write!(f, "cannot shrink below {mapped} mapped bytes to {target}")
            }
        }
    }
}

impl std::error::Error for VmmError {}

/// A reserved VA range with on-demand page mapping.
#[derive(Debug)]
pub struct VmmRange {
    page_bytes: u64,
    reserved_bytes: u64,
    mapped_pages: u64,
    /// Bytes the client actually asked to be usable (≤ mapped).
    committed_bytes: u64,
    map_calls: u64,
    unmap_calls: u64,
}

/// Physical page pool shared by all ranges on a device (models the GPU's
/// physical memory for fragmentation accounting).
#[derive(Debug)]
pub struct PhysicalPool {
    page_bytes: u64,
    total_pages: u64,
    used_pages: u64,
    peak_pages: u64,
}

impl PhysicalPool {
    pub fn new(spec: &DeviceSpec) -> PhysicalPool {
        let page_bytes = spec.cost.vmm_page_bytes;
        PhysicalPool {
            page_bytes,
            total_pages: spec.memory_bytes() / page_bytes,
            used_pages: 0,
            peak_pages: 0,
        }
    }

    /// Pool with explicit capacity in bytes (for budget experiments).
    pub fn with_capacity(spec: &DeviceSpec, capacity_bytes: u64) -> PhysicalPool {
        let page_bytes = spec.cost.vmm_page_bytes;
        PhysicalPool { page_bytes, total_pages: capacity_bytes / page_bytes, used_pages: 0, peak_pages: 0 }
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_pages * self.page_bytes
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak_pages * self.page_bytes
    }

    pub fn available_pages(&self) -> u64 {
        self.total_pages - self.used_pages
    }

    fn take(&mut self, pages: u64) -> Result<(), VmmError> {
        if pages > self.available_pages() {
            return Err(VmmError::PhysicalExhausted { need: pages, available: self.available_pages() });
        }
        self.used_pages += pages;
        self.peak_pages = self.peak_pages.max(self.used_pages);
        Ok(())
    }

    fn give_back(&mut self, pages: u64) {
        debug_assert!(pages <= self.used_pages);
        self.used_pages -= pages;
    }
}

impl VmmRange {
    /// Reserve a VA range of `va_bytes` (rounded up to page granularity).
    /// Cheap: one `cuMemAddressReserve` call.
    pub fn reserve(spec: &DeviceSpec, va_bytes: u64, clock: &mut Clock) -> VmmRange {
        let page = spec.cost.vmm_page_bytes;
        let reserved = crate::util::math::ceil_div(va_bytes, page) * page;
        clock.charge(Category::Vmm, spec.cost.vmm_reserve_us);
        VmmRange {
            page_bytes: page,
            reserved_bytes: reserved,
            mapped_pages: 0,
            committed_bytes: 0,
            map_calls: 0,
            unmap_calls: 0,
        }
    }

    /// Grow the usable prefix to `target_bytes`, mapping new physical pages
    /// as needed. No data copy — existing mappings are untouched (this is
    /// the whole point of the VMM baseline).
    pub fn grow_to(
        &mut self,
        spec: &DeviceSpec,
        pool: &mut PhysicalPool,
        target_bytes: u64,
        clock: &mut Clock,
    ) -> Result<(), VmmError> {
        if target_bytes > self.reserved_bytes {
            return Err(VmmError::ReservationExhausted { need: target_bytes, reserved: self.reserved_bytes });
        }
        let need_pages = crate::util::math::ceil_div(target_bytes, self.page_bytes);
        if need_pages > self.mapped_pages {
            let new_pages = need_pages - self.mapped_pages;
            pool.take(new_pages)?;
            clock.charge(Category::Vmm, new_pages as f64 * spec.cost.vmm_map_page_us);
            self.mapped_pages = need_pages;
            self.map_calls += 1;
        }
        self.committed_bytes = self.committed_bytes.max(target_bytes);
        Ok(())
    }

    /// Shrink the usable prefix, unmapping whole pages past the new end.
    pub fn shrink_to(
        &mut self,
        spec: &DeviceSpec,
        pool: &mut PhysicalPool,
        target_bytes: u64,
        clock: &mut Clock,
    ) -> Result<(), VmmError> {
        if target_bytes > self.committed_bytes {
            return Err(VmmError::BadShrink { mapped: self.committed_bytes, target: target_bytes });
        }
        let need_pages = crate::util::math::ceil_div(target_bytes, self.page_bytes);
        if need_pages < self.mapped_pages {
            let drop_pages = self.mapped_pages - need_pages;
            pool.give_back(drop_pages);
            clock.charge(Category::Vmm, drop_pages as f64 * spec.cost.vmm_unmap_page_us);
            self.mapped_pages = need_pages;
            self.unmap_calls += 1;
        }
        self.committed_bytes = target_bytes;
        Ok(())
    }

    /// Release everything (drop mappings back to the pool).
    pub fn release(&mut self, spec: &DeviceSpec, pool: &mut PhysicalPool, clock: &mut Clock) {
        pool.give_back(self.mapped_pages);
        clock.charge(Category::Vmm, self.mapped_pages as f64 * spec.cost.vmm_unmap_page_us);
        self.mapped_pages = 0;
        self.committed_bytes = 0;
        self.unmap_calls += 1;
    }

    pub fn mapped_bytes(&self) -> u64 {
        self.mapped_pages * self.page_bytes
    }

    pub fn committed_bytes(&self) -> u64 {
        self.committed_bytes
    }

    pub fn reserved_bytes(&self) -> u64 {
        self.reserved_bytes
    }

    /// Page slack: mapped-but-unused bytes (internal fragmentation).
    pub fn page_slack(&self) -> u64 {
        self.mapped_bytes() - self.committed_bytes
    }

    pub fn map_calls(&self) -> u64 {
        self.map_calls
    }

    pub fn unmap_calls(&self) -> u64 {
        self.unmap_calls
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PAGE: u64 = 2 * 1024 * 1024;

    fn setup() -> (DeviceSpec, PhysicalPool, Clock) {
        let spec = DeviceSpec::a100();
        let pool = PhysicalPool::new(&spec);
        (spec, pool, Clock::new())
    }

    #[test]
    fn reserve_rounds_to_pages() {
        let (spec, _pool, mut clock) = setup();
        let r = VmmRange::reserve(&spec, PAGE + 1, &mut clock);
        assert_eq!(r.reserved_bytes(), 2 * PAGE);
        assert_eq!(r.mapped_bytes(), 0);
        assert!(clock.total(Category::Vmm) > 0.0);
    }

    #[test]
    fn grow_maps_only_new_pages() {
        let (spec, mut pool, mut clock) = setup();
        let mut r = VmmRange::reserve(&spec, 100 * PAGE, &mut clock);
        r.grow_to(&spec, &mut pool, 3 * PAGE, &mut clock).unwrap();
        assert_eq!(r.mapped_bytes(), 3 * PAGE);
        assert_eq!(pool.used_bytes(), 3 * PAGE);
        let t0 = clock.now_us();
        // Growing within already-mapped pages is free.
        r.grow_to(&spec, &mut pool, 3 * PAGE - 5, &mut clock).unwrap();
        assert_eq!(clock.now_us(), t0);
        // Growing by one byte past the mapped prefix maps exactly one page.
        r.grow_to(&spec, &mut pool, 3 * PAGE + 1, &mut clock).unwrap();
        assert_eq!(r.mapped_bytes(), 4 * PAGE);
        assert!((clock.now_us() - t0 - spec.cost.vmm_map_page_us).abs() < 1e-9);
    }

    #[test]
    fn grow_cost_matches_table2_shape() {
        // Mapping 2.048 GB should land near the paper's 5.21 ms memMap grow.
        let (spec, mut pool, mut clock) = setup();
        let mut r = VmmRange::reserve(&spec, 8 * 1024 * 1024 * 1024u64, &mut clock);
        let t0 = clock.now_us();
        r.grow_to(&spec, &mut pool, 2_048_000_000, &mut clock).unwrap();
        let ms = (clock.now_us() - t0) / 1e3;
        assert!((ms - 5.21).abs() < 0.35, "modeled {ms} ms");
    }

    #[test]
    fn page_slack_accounting() {
        let (spec, mut pool, mut clock) = setup();
        let mut r = VmmRange::reserve(&spec, 10 * PAGE, &mut clock);
        r.grow_to(&spec, &mut pool, PAGE / 2, &mut clock).unwrap();
        assert_eq!(r.page_slack(), PAGE / 2);
        assert_eq!(r.committed_bytes(), PAGE / 2);
    }

    #[test]
    fn reservation_exhausted() {
        let (spec, mut pool, mut clock) = setup();
        let mut r = VmmRange::reserve(&spec, 2 * PAGE, &mut clock);
        let err = r.grow_to(&spec, &mut pool, 3 * PAGE, &mut clock).unwrap_err();
        assert!(matches!(err, VmmError::ReservationExhausted { .. }));
    }

    #[test]
    fn physical_exhausted() {
        let spec = DeviceSpec::a100();
        let mut pool = PhysicalPool::with_capacity(&spec, 4 * PAGE);
        let mut clock = Clock::new();
        let mut r = VmmRange::reserve(&spec, 100 * PAGE, &mut clock);
        r.grow_to(&spec, &mut pool, 4 * PAGE, &mut clock).unwrap();
        let err = r.grow_to(&spec, &mut pool, 5 * PAGE, &mut clock).unwrap_err();
        assert!(matches!(err, VmmError::PhysicalExhausted { .. }));
    }

    #[test]
    fn shrink_and_release() {
        let (spec, mut pool, mut clock) = setup();
        let mut r = VmmRange::reserve(&spec, 10 * PAGE, &mut clock);
        r.grow_to(&spec, &mut pool, 5 * PAGE, &mut clock).unwrap();
        r.shrink_to(&spec, &mut pool, 2 * PAGE, &mut clock).unwrap();
        assert_eq!(r.mapped_bytes(), 2 * PAGE);
        assert_eq!(pool.used_bytes(), 2 * PAGE);
        assert!(r.shrink_to(&spec, &mut pool, 3 * PAGE, &mut clock).is_err());
        r.release(&spec, &mut pool, &mut clock);
        assert_eq!(pool.used_bytes(), 0);
        assert_eq!(r.mapped_bytes(), 0);
        // Peak sticks at the high watermark.
        assert_eq!(pool.peak_bytes(), 5 * PAGE);
    }
}
