//! Synchronisation facade for the coordinator's concurrency core.
//!
//! Every lock, condvar, atomic, channel, and thread primitive used by
//! `coordinator/` imports from here instead of `std::sync` /
//! `std::thread` (the `lint` binary enforces it). In normal builds the
//! facade is a zero-cost re-export of `std`. Under `--cfg ggcheck` it
//! resolves to [`model`] — instrumented primitives that route every
//! operation through the [`crate::checker::rt`] scheduler hooks, which
//! is what lets `rust/tests/model_check.rs` exhaustively enumerate the
//! protocols' bounded interleavings.
//!
//! The model flavor is *dual*: each primitive decides at construction
//! time (via [`crate::checker::rt::active`]) whether it lives inside a
//! model-checked execution. Outside one it delegates straight to
//! `std`, so a `ggcheck` build still runs the ordinary unit tests
//! unchanged; inside one it becomes deterministic and schedulable.
//!
//! [`sendptr`] rides along in both flavors: the provenance-preserving
//! `Send` wrappers the shard scheduler uses instead of pointer→`usize`
//! laundering.

pub mod sendptr;

pub use sendptr::{SendPtr, SendSlice, SendSliceMut};

/// `Arc` is pure data sharing — no scheduling decisions — so both
/// flavors use `std`'s.
pub use std::sync::Arc;

/// Poison-tolerant lock: take the mutex, recovering the guard when a
/// previous holder panicked. The coordinator contains worker panics
/// with `catch_unwind` and restores its monitor invariants on the
/// containment path, so a poisoned flag carries no extra information —
/// propagating it would only cascade one contained panic into every
/// later metrics/frontend read. All non-test `lock()` calls in
/// `coordinator/` go through this (lint rule X enforces it).
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(ggcheck)]
pub mod model;

#[cfg(ggcheck)]
pub use model::{Condvar, Mutex, MutexGuard};
#[cfg(ggcheck)]
pub use model::{atomic, mpsc, thread};

#[cfg(not(ggcheck))]
pub use std::sync::{Condvar, Mutex, MutexGuard};

/// Atomics (std flavor): plain re-export.
#[cfg(not(ggcheck))]
pub mod atomic {
    pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
}

/// Channels (std flavor): plain re-export.
#[cfg(not(ggcheck))]
pub mod mpsc {
    pub use std::sync::mpsc::{
        channel, sync_channel, Receiver, RecvError, RecvTimeoutError, SendError, Sender,
        SyncSender, TryRecvError, TrySendError,
    };
}

/// Threads (std flavor): plain re-export.
#[cfg(not(ggcheck))]
pub mod thread {
    pub use std::thread::{sleep, spawn, yield_now, Builder, JoinHandle};
}
